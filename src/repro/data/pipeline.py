"""Deterministic synthetic data pipeline.

Produces reproducible token streams (and stub frame/patch embeddings for
the audio/VLM families) with double-buffered prefetch.  Batches are a
pure function of (seed, step), so restarted workers regenerate identical
data — which is what makes checkpoint/restart exactly resumable and
multi-host sharding trivially consistent (each host slices its rows).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 1234
    # multi-host slicing: this process serves rows [row_start, row_end)
    row_start: int = 0
    row_end: Optional[int] = None


def _rng_for_step(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synth_batch(cfg: ModelConfig, dcfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens: learnable short-range structure so a
    few hundred training steps show a real loss decrease."""
    rng = _rng_for_step(dcfg.seed, step)
    B, L, V = dcfg.global_batch, dcfg.seq_len, cfg.vocab_size
    base = rng.integers(0, V, size=(B, 1), dtype=np.int64)
    drift = rng.integers(0, 17, size=(B, L), dtype=np.int64)
    tokens = (base + np.cumsum(drift, axis=1)) % V
    tokens = tokens.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = tokens[:, 0]
    out: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels}
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        out["patch_embeds"] = rng.standard_normal((B, cfg.n_patches, cfg.d_model)).astype(np.float32)
    row_end = dcfg.row_end if dcfg.row_end is not None else B
    return {k: v[dcfg.row_start : row_end] for k, v in out.items()}


class Pipeline:
    """Double-buffered prefetching iterator over synth batches."""

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0,
                 put_fn=None):
        self.cfg, self.dcfg = cfg, dcfg
        self.step = start_step
        self._put = put_fn or jax.device_put
        self._next = self._make(self.step)

    def _make(self, step: int):
        host = synth_batch(self.cfg, self.dcfg, step)
        dtype = jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32
        dev = {}
        for k, v in host.items():
            arr = jnp.asarray(v, dtype=dtype) if v.dtype == np.float32 else jnp.asarray(v)
            dev[k] = self._put(arr)
        return dev

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        batch = self._next
        self.step += 1
        self._next = self._make(self.step)  # prefetch while caller computes
        return batch

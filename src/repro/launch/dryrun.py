import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the REAL step function (full train_step with
AdamW update for train_4k; prefill forward; one-token decode with a
seq_len KV/state cache), lowers it with ShapeDtypeStruct stand-ins (no
allocation), compiles it for the production mesh, and records
``memory_analysis()`` (proves it fits) + ``cost_analysis()`` (FLOPs and
bytes for the roofline) + the per-device collective byte count parsed
from the post-SPMD HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.registry import ARCHS, all_cells, shape_applicable
from repro.launch.mesh import fitted_shardings, make_production_mesh
from repro.models.model_api import SHAPES, build_model
from repro.optim.adamw import OptConfig, init_opt_state, make_train_step

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+(" + "|".join(_COLLECTIVES) + r")[\.\s(]"
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device communication bytes by collective kind, from the
    post-partitioning HLO (result-shape bytes per op; see EXPERIMENTS.md
    for the convention)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m:
            out[m.group(2)] += _shape_bytes(m.group(1))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _apply_overrides(cfg, overrides: Optional[Dict[str, Any]]):
    if not overrides:
        return cfg
    import dataclasses as _dc

    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in (True, "true", "True", "1", 1)
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return _dc.replace(cfg, **typed)


def build_step(arch: str, shape_name: str, overrides: Optional[Dict[str, Any]] = None):
    """Returns (fn, arg_structs, in_specs, out_specs_or_None)."""
    cfg = _apply_overrides(get_config(arch), overrides)
    model = build_model(cfg)
    sh = SHAPES[shape_name]
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(model.init, key)

    if sh.kind == "train":
        pspecs = model.param_specs("train")
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        from repro.optim.adamw import opt_state_specs, zero1_opt_specs

        ospecs = (
            zero1_opt_specs(pspecs, opt_shape) if cfg.fsdp_all_axes else opt_state_specs(pspecs)
        )
        fn = make_train_step(model.loss, OptConfig())
        batch = model.input_specs(shape_name)
        bspecs = model.batch_specs(shape_name)
        metric_specs = {"lr": P(), "grad_norm": P(), "loss": P()}
        return fn, (params_shape, opt_shape, batch), (pspecs, ospecs, bspecs), (pspecs, ospecs, metric_specs)

    if sh.kind == "prefill":
        pspecs = model.param_specs("serve")
        batch = model.input_specs(shape_name)
        bspecs = model.batch_specs(shape_name)
        return model.prefill, (params_shape, batch), (pspecs, bspecs), P()

    # decode
    pspecs = model.param_specs("serve")
    inputs = model.input_specs(shape_name)
    ispecs = model.batch_specs(shape_name)
    fn = lambda p, t, c, pos: model.decode_step(p, t, c, pos)
    out_specs = (P(), ispecs["cache"])
    return (
        fn,
        (params_shape, inputs["token"], inputs["cache"], inputs["pos"]),
        (pspecs, ispecs["token"], ispecs["cache"], ispecs["pos"]),
        out_specs,
    )


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    verbose: bool = True,
    overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, in_specs, out_specs = build_step(arch, shape_name, overrides)
    in_sh = fitted_shardings(in_specs, args, mesh)
    out_shapes = jax.eval_shape(fn, *args)
    out_sh = fitted_shardings(out_specs, out_shapes, mesh)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    from repro.launch.roofline import cost_analysis_dict

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    report = {
        "arch": arch,
        "shape": shape_name,
        "overrides": overrides or {},
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": float(cost.get("flops", -1.0)),
        "bytes_per_device": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    if verbose:
        print(json.dumps(report))
        sys.stdout.flush()
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true", help="use the 2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL reports here")
    ap.add_argument("--set", action="append", default=[], metavar="KEY=VALUE",
                    help="config field overrides (perf experiments)")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set) or None

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        cfg = get_config(arch)
        if not shape_applicable(cfg, shape):
            continue
        for mp in meshes:
            try:
                report = run_cell(arch, shape, mp, overrides=overrides)
            except Exception as e:  # a failure here is a bug in our system
                failures += 1
                report = {
                    "arch": arch, "shape": shape,
                    "mesh": "pod2x16x16" if mp else "16x16",
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                }
                print(json.dumps(report))
                traceback.print_exc()
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(report) + "\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Serving driver: prefill + decode loop for any arch (reduced on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --tokens 16

Demonstrates the full serve path end-to-end: cache init, per-token
decode_step, greedy sampling.  On a TPU fleet the same entry point runs
full configs with the serve-mode shardings; the multi-model deadline
scheduling layer above this lives in repro.runtime.serve_runtime.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.registry import ARCHS
from repro.models.model_api import build_model


def run(arch: str, tokens: int = 16, batch: int = 2, ctx: int = 64, reduced: bool = True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(batch, ctx)
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((batch,), jnp.int32)
    out_tokens = []
    t0 = time.time()
    for i in range(tokens):
        logits, cache = step(params, tok, cache, jnp.int32(i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    seq = jnp.stack(out_tokens, axis=1)
    print(f"[serve] {arch}: generated {tokens} tokens x{batch} in {dt*1e3:.0f} ms "
          f"({dt/tokens*1e3:.1f} ms/token incl. first-call compile)")
    print(f"[serve] sample: {seq[0][:12].tolist()}")
    return seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), required=True)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(args.arch, tokens=args.tokens, batch=args.batch, reduced=not args.full)


if __name__ == "__main__":
    main()

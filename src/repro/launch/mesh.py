"""Production mesh construction + logical-axis resolution.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single-pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips — the pod axis extends
the data/FSDP dimension across pods.

Model code writes PartitionSpecs against *logical* axes (the AX_DATA
tuple ("pod", "data") and "model"); ``resolve_specs`` drops axes that a
given mesh does not have, so the same spec tree serves both meshes.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def _resolve_entry(entry, mesh_axes):
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in mesh_axes else None
    # tuple of axes: keep only those present
    kept = tuple(a for a in entry if a in mesh_axes)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def resolve_spec(spec: P, mesh: Mesh) -> P:
    axes = set(mesh.axis_names)
    return P(*[_resolve_entry(e, axes) for e in spec])


def resolve_specs(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: resolve_spec(s, mesh),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def named_shardings(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, mesh)),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for a in entry:
        n *= mesh.shape[a]
    return n


def fit_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Resolve ``spec`` against ``mesh`` and drop axes (rightmost first
    within each dim) until every dim divides evenly — pjit requires exact
    divisibility of argument shardings."""
    resolved = resolve_spec(spec, mesh)
    out = []
    for d, entry in enumerate(resolved):
        if d >= len(shape):
            break
        if entry is None:
            out.append(None)
            continue
        axes = [entry] if isinstance(entry, str) else list(entry)
        while axes and shape[d] % _axis_size(mesh, tuple(axes)) != 0:
            axes.pop()
        out.append(None if not axes else (axes[0] if len(axes) == 1 else tuple(axes)))
    return P(*out)


def fitted_shardings(spec_tree: Any, shape_tree: Any, mesh: Mesh) -> Any:
    """named_shardings with per-leaf divisibility fallback (shape-aware)."""

    def one(s, arr):
        return NamedSharding(mesh, fit_spec(s, tuple(arr.shape), mesh))

    return jax.tree.map(
        one,
        spec_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )

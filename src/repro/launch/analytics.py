"""Analytic roofline terms per (arch x shape x mesh).

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``scan``
body ONCE instead of multiplying by the trip count (verified in
tests/test_roofline.py), so for depth-scanned models the raw dry-run
FLOPs under-report by ~n_layers.  The dry-run numbers are still recorded
raw; this module supplies the corrected terms from exact closed-form
counts of the math the model performs — validated against published
parameter totals (400B / 235B / 1.3B / ...) and against cost_analysis on
small UNROLLED configs where XLA counts are exact.

Hardware constants (TPU v5e targets, per the assignment):
  197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.models.config import ModelConfig
from repro.models.model_api import SHAPES, ShapeSpec

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

BYTES = {"bfloat16": 2, "float32": 4}


# ------------------------------------------------------------- parameters ---


def attn_params(cfg: ModelConfig) -> int:
    dh = cfg.resolved_head_dim
    return cfg.d_model * cfg.n_heads * dh + 2 * cfg.d_model * cfg.n_kv_heads * dh + cfg.n_heads * dh * cfg.d_model


def dense_block_params(cfg: ModelConfig) -> int:
    return attn_params(cfg) + 3 * cfg.d_model * cfg.d_ff + 2 * cfg.d_model


def moe_block_params(cfg: ModelConfig) -> int:
    return (
        attn_params(cfg)
        + cfg.d_model * cfg.n_experts
        + cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
        + 2 * cfg.d_model
    )


def mamba_block_params(cfg: ModelConfig) -> int:
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    d_in = 2 * di + 2 * N + H
    return cfg.d_model * d_in + cfg.ssm_conv_width * (di + 2 * N) + di * cfg.d_model + 3 * H + di + cfg.d_model


def whisper_enc_block_params(cfg: ModelConfig) -> int:
    return attn_params(cfg) + 2 * cfg.d_model * cfg.d_ff + 2 * cfg.d_model


def whisper_dec_block_params(cfg: ModelConfig) -> int:
    return 2 * attn_params(cfg) + 2 * cfg.d_model * cfg.d_ff + 3 * cfg.d_model


def total_params(cfg: ModelConfig) -> int:
    emb = cfg.vocab_size * cfg.d_model
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    f = cfg.family
    if f in ("dense", "vlm"):
        return emb + head + cfg.n_layers * dense_block_params(cfg)
    if f == "moe":
        n_moe = cfg.n_layers // cfg.moe_every
        n_dense = cfg.n_layers - n_moe
        return emb + head + n_moe * moe_block_params(cfg) + n_dense * dense_block_params(cfg)
    if f == "ssm":
        return emb + head + cfg.n_layers * mamba_block_params(cfg)
    if f == "hybrid":
        return emb + head + cfg.n_layers * mamba_block_params(cfg) + dense_block_params(cfg)
    if f == "encdec":
        return emb + head + cfg.n_encoder_layers * whisper_enc_block_params(cfg) + cfg.n_layers * whisper_dec_block_params(cfg)
    raise ValueError(f)


def active_params(cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE: top-k experts only)."""
    if cfg.family != "moe":
        return total_params(cfg)
    n_moe = cfg.n_layers // cfg.moe_every
    n_dense = cfg.n_layers - n_moe
    moe_active = (
        attn_params(cfg)
        + cfg.d_model * cfg.n_experts  # router
        + cfg.experts_per_token * 3 * cfg.d_model * cfg.moe_d_ff
        + 2 * cfg.d_model
    )
    emb = cfg.vocab_size * cfg.d_model
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    return emb + head + n_moe * moe_active + n_dense * dense_block_params(cfg)


def matmul_params(cfg: ModelConfig, active: bool = True) -> int:
    """Parameters that participate in per-token matmuls (excludes the
    embedding GATHER but includes the LM head projection)."""
    p = (active_params(cfg) if active else total_params(cfg))
    # embedding gather is not a matmul; LM head is. Tied embeddings still
    # do the head matmul.
    p -= cfg.vocab_size * cfg.d_model  # remove gather-side table
    if cfg.tie_embeddings:
        p += cfg.vocab_size * cfg.d_model  # head matmul still happens
    return p


# ------------------------------------------------------------------ flops ---


def attn_flops_fwd(cfg: ModelConfig, B: int, L: int, n_attn_layers: int) -> float:
    """Computed attention score+value FLOPs (full L^2 tiles; our flash
    computes masked tiles too)."""
    dh = cfg.resolved_head_dim
    return 4.0 * B * L * L * cfg.n_heads * dh * n_attn_layers


def _n_attn_layers(cfg: ModelConfig) -> int:
    f = cfg.family
    if f in ("dense", "vlm", "moe"):
        return cfg.n_layers
    if f == "ssm":
        return 0
    if f == "hybrid":
        return cfg.n_layers // cfg.hybrid_attn_every
    if f == "encdec":
        return cfg.n_encoder_layers + 2 * cfg.n_layers  # self + cross
    raise ValueError(f)


def ssd_flops_fwd(cfg: ModelConfig, B: int, L: int) -> float:
    """Chunked SSD: intra-chunk quadratic + state terms per mamba block."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    Q = cfg.ssm_chunk
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    di = cfg.d_inner
    per_block = (
        2.0 * B * L * Q * N  # C.B^T within chunks
        + 2.0 * B * L * Q * H * P  # M @ x
        + 4.0 * B * L * N * di  # state build + state read
    )
    return per_block * cfg.n_layers


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, float]:
    """Returns useful (6ND / 2ND) and computed (incl. attention + remat)
    global FLOPs for this cell."""
    B, L = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * L
        mm = 2.0 * matmul_params(cfg, active=True) * tokens  # fwd
        attn = attn_flops_fwd(cfg, B, L, _n_attn_layers(cfg)) + ssd_flops_fwd(cfg, B, L)
        if cfg.family == "encdec":
            tokens_enc = B * cfg.encoder_seq
            mm += 2.0 * whisper_enc_block_params(cfg) * cfg.n_encoder_layers * tokens_enc
        fwd = mm + attn
        # bwd = 2x fwd; remat recomputes fwd once inside bwd
        computed = fwd * (3.0 + (1.0 if cfg.remat else 0.0))
        useful = 6.0 * active_params(cfg) * tokens
        return {"useful": useful, "computed": computed}
    if shape.kind == "prefill":
        tokens = B * L
        fwd = 2.0 * matmul_params(cfg, active=True) * tokens + attn_flops_fwd(
            cfg, B, L, _n_attn_layers(cfg)
        ) + ssd_flops_fwd(cfg, B, L)
        return {"useful": 2.0 * active_params(cfg) * tokens, "computed": fwd}
    # decode: one token per sequence
    dh = cfg.resolved_head_dim
    mm = 2.0 * matmul_params(cfg, active=True) * B
    attn = 4.0 * B * L * cfg.n_heads * dh * _n_attn_layers(cfg)
    if cfg.family in ("ssm", "hybrid"):
        H, Pd, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
        attn += 4.0 * B * H * Pd * N * cfg.n_layers
        if cfg.family == "ssm":
            attn = 4.0 * B * H * Pd * N * cfg.n_layers  # no KV attention at all
    return {"useful": 2.0 * active_params(cfg) * B, "computed": mm + attn}


# ------------------------------------------------------------------ bytes ---


def cache_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    B, L = shape.global_batch, shape.seq_len
    dh = cfg.resolved_head_dim
    bt = 1 if cfg.kv_cache_quant else BYTES[cfg.dtype]
    f = cfg.family
    if f in ("dense", "vlm", "moe"):
        return 2.0 * cfg.n_layers * B * L * cfg.n_kv_heads * dh * bt
    if f == "ssm":
        st = cfg.n_layers * B * cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim * 4
        conv = cfg.n_layers * B * (cfg.ssm_conv_width - 1) * (cfg.d_inner + 2 * cfg.ssm_state) * bt
        return st + conv
    if f == "hybrid":
        n_sites = cfg.n_layers // cfg.hybrid_attn_every
        kv = 2.0 * n_sites * B * L * cfg.n_kv_heads * dh * bt
        st = cfg.n_layers * B * cfg.ssm_nheads * cfg.ssm_state * cfg.ssm_headdim * 4
        return kv + st
    if f == "encdec":
        self_kv = 2.0 * cfg.n_layers * B * L * cfg.n_kv_heads * dh * bt
        cross_kv = 2.0 * cfg.n_layers * B * cfg.encoder_seq * cfg.n_kv_heads * dh * bt
        return self_kv + cross_kv
    raise ValueError(f)


def hbm_bytes(cfg: ModelConfig, shape: ShapeSpec, n_dev: int = 256, tp: int = 16) -> float:
    """GLOBAL HBM traffic estimate for one step (divide by n_dev for the
    per-chip roofline term).

    Key subtlety: FSDP reduces *storage*, not HBM streaming — each device
    still streams its TP slice of every layer (P/tp per pass).  The
    ZeRO-1 profile (tp_eff = 1) streams full weights per device but cuts
    per-device activation traffic by tp x."""
    bt = BYTES[cfg.dtype]
    P_all = total_params(cfg)
    B, L = shape.global_batch, shape.seq_len
    tp_eff = 1 if cfg.fsdp_all_axes else tp
    dp = n_dev if cfg.fsdp_all_axes else n_dev // tp
    if shape.kind == "train":
        tokens_dev = B * L / max(1, dp)
        per_dev = (
            3.0 * P_all * bt / tp_eff  # weight stream: fwd + remat + bwd
            + 16.0 * P_all / n_dev  # f32 m/v read+write (sharded)
            + 3.0 * cfg.n_layers * tokens_dev * cfg.d_model * bt  # acts
        )
        return per_dev * n_dev
    if shape.kind == "prefill":
        tokens_dev = B * L / max(1, dp)
        per_dev = P_all * bt / tp_eff + 2.0 * cfg.n_layers * tokens_dev * cfg.d_model * bt
        return per_dev * n_dev
    # decode: weights (sharded over the full mesh in serve mode) + cache
    return active_params(cfg) * BYTES[cfg.dtype] + cache_bytes(cfg, shape)


# ------------------------------------------------------------ collectives ---


def expert_params(cfg: ModelConfig) -> int:
    if cfg.family != "moe":
        return 0
    n_moe = cfg.n_layers // cfg.moe_every
    return n_moe * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff


def collective_bytes_est(cfg: ModelConfig, shape: ShapeSpec, n_dev: int, tp: int = 16) -> float:
    """Per-device collective bytes per step under the IMPLEMENTED
    sharding strategy (validated against the dry-run HLO parse,
    EXPERIMENTS.md §Perf):

    * dense train: FSDP all-gather (fwd + remat-bwd) + grad
      reduce-scatter over data, plus TP all-reduces of activations per
      block (1 with ``parallel_block``, else 2).
    * moe train: experts are a2a expert-parallel (E->data, F->model) —
      weights never move; FSDP applies only to non-expert params; each
      MoE layer adds 2 token-sized a2a (fwd; 2 more bwd) + 1 expert-out
      TP all-reduce.
    * ``fsdp_all_axes`` (ZeRO-1): one grad all-reduce + updated-param
      all-gather, nothing per-layer.
    Ring collectives: wire bytes per device ~= 2(n-1)/n (AR) or
    (n-1)/n (AG/RS) x payload.
    """
    bt = BYTES[cfg.dtype]
    B, L = shape.global_batch, shape.seq_len
    dp = n_dev // tp
    P_all = total_params(cfg)
    f = cfg.family
    n_blocks = cfg.n_layers
    ar_per_block = 1 if cfg.parallel_block else 2
    out = 0.0
    if shape.kind == "train":
        if cfg.fsdp_all_axes:  # ZeRO-1
            # grad all-reduce over all devices + new-param all-gather
            out += 2.0 * (n_dev - 1) / n_dev * P_all * bt
            out += (n_dev - 1) / n_dev * P_all * bt
            return out
        tokens_dev = B * L / max(1, dp)
        P_fsdp = P_all - expert_params(cfg)
        shard = P_fsdp * bt / n_dev
        out += (2 + 1) * shard * (dp - 1)
        out += ar_per_block * n_blocks * tokens_dev * cfg.d_model * bt * 2 * (tp - 1) / tp
        if f == "moe":
            n_moe = cfg.n_layers // cfg.moe_every
            # dispatched volume scales with top-k (each token occupies k
            # expert-capacity slots)
            a2a = tokens_dev * cfg.d_model * bt * cfg.capacity_factor * cfg.experts_per_token
            # 2 a2a fwd + 2 bwd, + expert-out AR over model (fwd+bwd)
            out += n_moe * (4 * a2a + 2 * a2a * 2 * (tp - 1) / tp)
        return out
    tokens_dev = B * L / max(1, dp)
    if shape.kind == "prefill":
        out += (ar_per_block / 2 if cfg.parallel_block else 1) * 2 * n_blocks * tokens_dev * cfg.d_model * bt * (tp - 1) / tp
        if f == "moe":
            n_moe = cfg.n_layers // cfg.moe_every
            a2a = tokens_dev * cfg.d_model * bt * cfg.capacity_factor * cfg.experts_per_token
            out += n_moe * (2 * a2a + a2a * 2 * (tp - 1) / tp)
        return out
    # decode
    b_dev = max(1.0, B / max(1, dp))
    out += 2 * n_blocks * b_dev * cfg.d_model * bt * (tp - 1) / tp
    if f == "moe":
        n_moe = cfg.n_layers // cfg.moe_every
        out += n_moe * 3 * b_dev * cfg.d_model * bt
    return out


# ---------------------------------------------------------------- roofline --


@dataclasses.dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    n_dev: int
    compute_s: float
    memory_s: float
    collective_s: float
    useful_flops: float
    computed_flops: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.useful_flops / max(self.computed_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful FLOP throughput achieved / peak, at the modeled step time
        (== MFU when compute-bound with zero waste)."""
        return self.useful_flops / (self.step_s * self.n_dev * PEAK_FLOPS)


def roofline(cfg: ModelConfig, shape_name: str, n_dev: int = 256, tp: int = 16) -> Roofline:
    shape = SHAPES[shape_name]
    fl = model_flops(cfg, shape)
    mem = hbm_bytes(cfg, shape, n_dev, tp)
    coll = collective_bytes_est(cfg, shape, n_dev, tp)
    return Roofline(
        arch=cfg.name,
        shape=shape_name,
        n_dev=n_dev,
        compute_s=fl["computed"] / (n_dev * PEAK_FLOPS),
        memory_s=mem / (n_dev * HBM_BW),
        collective_s=coll / ICI_BW,
        useful_flops=fl["useful"],
        computed_flops=fl["computed"],
    )

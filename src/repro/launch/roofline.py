"""Roofline report: per (arch x shape) three-term analysis.

Sources:
 * analytic terms from ``repro.launch.analytics`` (primary — XLA's
   cost_analysis counts scan bodies once, verified in
   tests/test_roofline.py, so raw dry-run FLOPs under-report scanned
   depth; the analytic counts are validated against published parameter
   totals and against cost_analysis on unrolled reduced configs);
 * raw dry-run numbers from results/dryrun_all.jsonl (memory fit proof +
   collective mix).

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --dryrun results/dryrun_all.jsonl
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.configs.registry import all_cells
from repro.launch.analytics import HBM_BW, ICI_BW, PEAK_FLOPS, roofline, total_params


def cost_analysis_dict(compiled) -> Dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Older JAX returns one dict; newer versions return a list with one
    entry per compiled module (the main module first).  Always hand back
    a plain dict so callers can ``.get("flops")`` either way.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def load_dryrun(path: Optional[str]) -> Dict:
    if not path:
        return {}
    out = {}
    try:
        for line in open(path):
            r = json.loads(line)
            if r.get("ok"):
                out[(r["arch"], r["shape"], r["mesh"])] = r
    except FileNotFoundError:
        pass
    return out


def improvement_hint(r) -> str:
    if r.bottleneck == "compute":
        if r.useful_ratio < 0.6:
            return "cut recompute (remat policy) / masked-tile waste in attention"
        return "compute-bound near useful peak; larger per-chip batch or fewer pods"
    if r.bottleneck == "memory":
        return "raise arithmetic intensity: larger decode batch / fuse cache+weight streams / quantize weights"
    return "shrink collective volume: 2D expert sharding, overlap a2a with expert compute, fewer TP hops"


# Best-known per-cell config from the §Perf hillclimb (EXPERIMENTS.md):
# small models train ZeRO-1 (no TP), MoE trains use the a2a EP
# choreography with parallel blocks (on by default in the code), and
# attention-family decode quantizes the KV cache.
def optimized_overrides(arch: str, shape: str) -> dict:
    cfg = get_config(arch)
    out = {}
    if shape == "train_4k":
        if total_params(cfg) < 3e9 and cfg.family in ("ssm", "dense", "encdec"):
            out["fsdp_all_axes"] = True
        if cfg.family in ("dense", "vlm", "moe"):
            out["parallel_block"] = True
    if shape in ("decode_32k", "long_500k") and cfg.family in ("dense", "vlm", "moe", "encdec", "hybrid"):
        out["kv_cache_quant"] = True
    return out


def build_table(
    dryrun_path: Optional[str] = None, n_dev: int = 256, optimized: bool = False
) -> List[dict]:
    import dataclasses as _dc

    dr = load_dryrun(dryrun_path)
    rows = []
    for arch, shape in all_cells():
        cfg = get_config(arch)
        if optimized:
            ov = optimized_overrides(arch, shape)
            if ov:
                cfg = _dc.replace(cfg, **ov)
        r = roofline(cfg, shape, n_dev=n_dev)
        raw = dr.get((arch, shape, "16x16"), {})
        rows.append({
            "arch": arch,
            "shape": shape,
            "bottleneck": r.bottleneck,
            "compute_s": r.compute_s,
            "memory_s": r.memory_s,
            "collective_s": r.collective_s,
            "step_s": r.step_s,
            "useful_flops_6ND": r.useful_flops,
            "computed_flops": r.computed_flops,
            "useful_ratio": r.useful_ratio,
            "roofline_fraction": r.roofline_fraction,
            "dryrun_ok": bool(raw),
            "dryrun_args_gb_per_dev": (raw.get("memory", {}) or {}).get("argument_bytes", 0) / 1e9 if raw else None,
            "dryrun_collective_gb_per_dev": (raw.get("collective_bytes_per_device", {}) or {}).get("total", 0) / 1e9 if raw else None,
            "hint": improvement_hint(r),
        })
    return rows


def print_table(rows: List[dict]) -> None:
    hdr = f"{'arch':>26} {'shape':>11} {'bneck':>10} {'compute':>9} {'memory':>9} {'collect':>9} {'roofline%':>9} {'useful%':>8}"
    print(hdr)
    for r in rows:
        print(
            f"{r['arch']:>26} {r['shape']:>11} {r['bottleneck']:>10} "
            f"{_fmt_s(r['compute_s']):>9} {_fmt_s(r['memory_s']):>9} "
            f"{_fmt_s(r['collective_s']):>9} {100*r['roofline_fraction']:>8.1f}% "
            f"{100*r['useful_ratio']:>7.1f}%"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun_all.jsonl")
    ap.add_argument("--json", default=None)
    ap.add_argument("--optimized", action="store_true",
                    help="apply the best-known per-cell perf config")
    args = ap.parse_args()
    rows = build_table(args.dryrun, optimized=args.optimized)
    print_table(rows)
    if args.json:
        with open(args.json, "w") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()

"""Training driver: real steps on the local mesh, fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On CPU this trains REDUCED configs end-to-end (the quickstart example
drives a ~100M-param model for a few hundred steps); on a TPU fleet the
same entry point runs the full configs on the production mesh.  The loop
is supervised by :class:`repro.runtime.ft.Supervisor` — checkpoints,
restart, bad-step rollback, straggler events.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.registry import ARCHS
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch.mesh import fitted_shardings, make_production_mesh
from repro.models.model_api import build_model
from repro.optim.adamw import OptConfig, init_opt_state, make_train_step, opt_state_specs
from repro.runtime.ft import Supervisor


def run(
    arch: str,
    steps: int = 100,
    batch: int = 8,
    seq: int = 128,
    reduced: bool = True,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    use_mesh: bool = False,
    log_every: int = 10,
    seed: int = 0,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    opt_cfg = OptConfig(warmup_steps=max(1, steps // 20), total_steps=steps)

    if use_mesh:
        mesh = make_production_mesh()
        pspecs = model.param_specs("train")
        in_sh = fitted_shardings(pspecs, params, mesh)
        params = jax.device_put(params, in_sh)
        train_step = jax.jit(make_train_step(model.loss, opt_cfg))
    else:
        train_step = jax.jit(make_train_step(model.loss, opt_cfg))

    sup = Supervisor(ckpt_dir or "/tmp/repro_ckpt", ckpt_every=ckpt_every)
    sup.install_signal_handler()
    start_step = 0
    resume = sup.resume_step() if ckpt_dir else None
    if resume is not None:
        state = sup.restore(resume, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start_step = resume
        print(f"[train] resumed from step {resume}")

    dcfg = DataConfig(global_batch=batch, seq_len=seq, seed=1234)
    pipe = Pipeline(cfg, dcfg, start_step=start_step)
    losses = []
    step = start_step
    while step < steps:
        batch_data = next(pipe)
        t0 = time.time()
        params, opt, metrics = train_step(params, opt, batch_data)
        loss = float(metrics["loss"])  # blocks
        dt = time.time() - t0
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        step += 1
        # checkpoint convention: a checkpoint at N is the state BEFORE
        # running step N, so restart resumes with data step N exactly.
        action, rb = sup.on_step(step, dt, metrics, {"params": params, "opt": opt})
        if action == "rollback" and rb is not None:
            state = sup.restore(rb, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            step = rb
            pipe = Pipeline(cfg, dcfg, start_step=step + 1)  # shift past bad data
            print(f"[train] non-finite step; rolled back to {rb}")
            continue
        if action == "checkpoint_and_exit":
            print("[train] SIGTERM: checkpointed and exiting")
            break
    if ckpt_dir:
        sup.checkpoint(step, {"params": params, "opt": opt})
    return {"final_loss": losses[-1] if losses else None, "losses": losses,
            "straggler_events": sup.straggler.events, "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    out = run(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        reduced=not args.full,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )
    print(f"[train] done; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()

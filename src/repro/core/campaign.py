"""Monte-Carlo simulation campaign engine.

Every scheduling claim in this repo reduces to "metric X of policy A
beats policy B over a set of (scenario, platform, arrival-model, seed)
conditions".  The seed benchmarks ground those claims in a handful of
serial `simulate()` loops with 3 seeds and strictly periodic arrivals —
too few trials for confidence intervals and zero arrival diversity.
This module turns that into a declarative campaign:

* :class:`Campaign` expands a grid of scenario x platform x theta x
  scheduler x arrival-process x seed into :class:`TrialSpec` values
  (plain strings + numbers, picklable, printable);
* :func:`run_trial` executes one spec — offline plan build (memoized
  per process), arrival generation, event-driven simulation — with a
  deterministic per-trial PRNG stream, so parallel == serial always;
* execution fans out over ``concurrent.futures.ProcessPoolExecutor``
  (the simulator is pure Python/NumPy, threads would serialize on the
  GIL), warming the plan cache in the parent first so fork()ed workers
  inherit it instead of rebuilding plans per worker;
* :class:`CampaignResult` aggregates metric distributions with
  deterministic bootstrap confidence intervals.

The default grid (periodic arrivals) reproduces the seed benchmarks
bit-for-bit — pinned by ``tests/test_campaign.py``.
"""

from __future__ import annotations

import concurrent.futures
import concurrent.futures.process
import dataclasses
import multiprocessing
import os
import sys
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import ALL_SCHEDULERS, make_scheduler
from repro.core.simulator import SimResult, make_arrival_process, simulate
from repro.core.workload import SCENARIOS, get_scenario
from repro.costmodel.maestro import PLATFORMS


# ------------------------------------------------------------- trials ----


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """One fully determined simulator run.

    All fields are strings/numbers: a spec survives pickling to pool
    workers and doubles as the row identity in result tables.  The
    ``arrival`` and ``scheduler`` fields are call-spec strings (see
    ``repro.core.specs``), e.g. ``"mmpp(burstiness=4)"``.
    """

    scenario: str
    platform: str
    scheduler: str
    arrival: str = "periodic"
    seed: int = 0
    duration: float = 5.0
    theta: float = 0.90
    enable_variants: bool = True
    # Online virtual-budget policy call-spec ("static" | "reclaim" |
    # "adaptive(tick=...,beta=...)"); "static" is the paper's offline
    # budgets and reproduces the pre-policy simulator bit-for-bit.
    budget_policy: str = "static"
    # Admission/shedding policy call-spec ("none" | "shed_early(margin=...)"
    # | "token_bucket(rate=...,burst=...)"); "none" admits everything and
    # reproduces the pre-admission simulator bit-for-bit.
    admission: str = "none"
    # Simulator engine: "auto" (SoA fast path with reference fallback),
    # "soa", "reference", or "batch" — see
    # repro.core.simulator.SIM_ENGINES.  The throughput benchmark pins
    # engines against each other on the same grid; results are
    # bit-identical, so this axis never changes any metric.  "batch"
    # specs are grouped by seed inside TrialExecutor and run as one
    # device program per cell (run_trial_batch) instead of per-trial
    # pool tasks; unsupported axes raise BatchUnsupportedError rather
    # than silently falling back.
    engine: str = "auto"
    # Terastal round kernel for deep ready queues: "auto" | "python" |
    # "jax" — see repro.core.engine_soa.ROUND_KERNELS.  Like ``engine``,
    # bit-identical by construction (pinned by the round-kernel
    # differential tests); a perf knob, never a result knob.
    round_kernel: str = "auto"
    # Accelerator fault-model call-spec (see repro.core.faults):
    # "scenario" (the default) resolves to the scenario's own
    # ``Scenario.faults`` — "none" for every pre-fault-axis catalog, so
    # existing specs stay bit-identical — while an explicit spec like
    # "down(acc=0,start=0.5,duration=1.0)" overrides it per trial.
    faults: str = "scenario"


@dataclasses.dataclass(frozen=True)
class TrialResult:
    spec: TrialSpec
    mean_miss_rate: float
    mean_accuracy_loss: float
    released: int
    completed: int
    dropped: int
    variants_applied: int
    utilization: Tuple[float, ...]
    wall_s: float
    # Scheduling rounds the trial executed (SimResult.rounds telemetry;
    # travels with the result, so pool workers report real values).
    rounds: int = 0
    # Requests shed at the admission door (subset of ``dropped``); 0 under
    # admission="none".  Defaulted so journals written before the
    # admission axis still resume cleanly.
    shed: int = 0
    # Variant-bearing models that actually completed requests — the
    # denominator behind mean_accuracy_loss (NaN when 0; see
    # SimResult.accuracy_loss_stats).  -1 on rows resumed from journals
    # written before the honest-metric fix.
    models_counted: int = -1
    # Fault-axis telemetry (0 on fault-free trials and on rows resumed
    # from journals written before the fault axis): layers evicted by
    # down events, and evicted requests later re-dispatched.
    evicted: int = 0
    remapped: int = 0

    def row(self) -> Dict:
        d = dataclasses.asdict(self.spec)
        d.update(
            mean_miss_rate=self.mean_miss_rate,
            mean_accuracy_loss=self.mean_accuracy_loss,
            released=self.released,
            completed=self.completed,
            dropped=self.dropped,
            variants_applied=self.variants_applied,
            wall_s=self.wall_s,
            rounds=self.rounds,
            shed=self.shed,
            models_counted=self.models_counted,
            evicted=self.evicted,
            remapped=self.remapped,
        )
        return d


# Offline plan construction (Algorithm 1 + variant design) dominates a
# short trial's cost and depends only on these keys — memoize per process.
# With the fork start method the parent warms this cache before creating
# the pool, so workers inherit every cell's plans for free.
_PLAN_CACHE: Dict[Tuple[str, str, float, bool], tuple] = {}


def _plans_for(scenario: str, platform: str, theta: float, enable_variants: bool):
    key = (scenario, platform, theta, enable_variants)
    if key not in _PLAN_CACHE:
        sc = get_scenario(scenario)  # paper catalog + saturation family
        _PLAN_CACHE[key] = sc.plans(
            PLATFORMS[platform], theta=theta, enable_variants=enable_variants
        )
    return _PLAN_CACHE[key]


def _resolve_faults(spec: TrialSpec) -> str:
    """Resolve a spec's fault axis: ``"scenario"`` defers to the
    scenario's own default (None -> ``"none"``), anything else is a
    fault-model call-spec passed through verbatim."""
    if spec.faults == "scenario":
        return get_scenario(spec.scenario).faults or "none"
    return spec.faults


def _warm_plan_cache(keys: Sequence[Tuple[str, str, float, bool]]) -> None:
    """Pool-worker initializer: prime ``_PLAN_CACHE`` for the campaign's
    cells at worker startup.  Fork workers inherit the parent's warm cache
    (this is then a no-op); spawn workers start from a cold interpreter
    and would otherwise each rebuild the offline plans (Algorithm 1 +
    variant design) inside their first ``run_trial``."""
    for key in keys:
        _plans_for(*key)


#: test hook (tests/test_executor_crash.py): when set, :func:`run_trial`
#: kills its process before simulating — "always" unconditionally, any
#: other value is a sentinel path killed through exactly once (the first
#: process to atomically create the file dies; every later call runs
#: normally).  Exercises the pool-crash recovery below under both fork
#: and spawn start methods; unset in production.
_CRASH_ENV = "REPRO_TRIAL_CRASH"

#: pool-crash recovery budget: how many times :class:`TrialExecutor`
#: rebuilds a broken worker pool before raising
#: :class:`ExecutorCrashError`.  Default 1 preserves the historical
#: rebuild-once semantics; raise it on flaky shared hosts where more
#: than one unrelated OOM-kill per campaign is plausible.  Rebuild n
#: waits ``min(_REBUILD_BACKOFF_CAP_S, _REBUILD_BACKOFF_BASE_S *
#: 2**(n-1))`` seconds first so a transiently-starved machine gets
#: breathing room instead of an immediate re-crash.
_RETRIES_ENV = "REPRO_EXECUTOR_RETRIES"
_REBUILD_BACKOFF_BASE_S = 0.1
_REBUILD_BACKOFF_CAP_S = 5.0


def _executor_retries() -> int:
    raw = os.environ.get(_RETRIES_ENV)
    if raw is None or not raw.strip():
        return 1
    try:
        n = int(raw)
    except ValueError:
        raise ValueError(
            f"{_RETRIES_ENV}={raw!r}: expected a non-negative integer"
        ) from None
    if n < 0:
        raise ValueError(
            f"{_RETRIES_ENV}={raw!r}: expected a non-negative integer"
        )
    return n


def _maybe_crash() -> None:
    how = os.environ.get(_CRASH_ENV)
    if not how:
        return
    if how != "always":
        try:
            fd = os.open(how, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return
        os.close(fd)
    os._exit(1)


def run_trial(spec: TrialSpec) -> TrialResult:
    """Execute one trial: reusable by the pool, benchmarks, and tests.

    The per-trial PRNG stream is fully determined by ``spec.seed`` (the
    arrival generator seeds ``np.random.default_rng(seed)`` itself), so
    re-running a spec anywhere — serially, in a pool worker, on another
    host — yields the identical :class:`TrialResult`.
    """
    _maybe_crash()
    t0 = time.perf_counter()
    plans, tasks = _plans_for(spec.scenario, spec.platform, spec.theta, spec.enable_variants)
    # spec.arrival is the default for the cell; an entry that pins its own
    # process in the scenario definition keeps it (Scenario.plans contract).
    proc = make_arrival_process(spec.arrival)
    res: SimResult = simulate(
        plans,
        tasks,
        spec.duration,
        make_scheduler(spec.scheduler),
        seed=spec.seed,
        processes=[t.arrival or proc for t in tasks],
        budget_policy=spec.budget_policy,
        admission=spec.admission,
        engine=spec.engine,
        round_kernel=spec.round_kernel,
        faults=_resolve_faults(spec),
    )
    agg = {"released": 0, "completed": 0, "dropped": 0, "variants_applied": 0,
           "shed": 0, "evicted": 0, "remapped": 0}
    for st in res.per_model.values():
        agg["released"] += st.released
        agg["completed"] += st.completed
        agg["dropped"] += st.dropped
        agg["variants_applied"] += st.variants_applied
        agg["shed"] += st.shed
        agg["evicted"] += st.evicted
        agg["remapped"] += st.remapped
    loss, counted, _ = res.accuracy_loss_stats(plans)
    return TrialResult(
        spec=spec,
        mean_miss_rate=res.mean_miss_rate,
        mean_accuracy_loss=loss,
        utilization=tuple(float(u) for u in res.utilization()),
        wall_s=time.perf_counter() - t0,
        rounds=res.rounds or 0,
        models_counted=counted,
        **agg,
    )


def run_trial_batch(specs: Sequence[TrialSpec]) -> List[TrialResult]:
    """Execute a seed batch of one cell as ONE device program.

    ``specs`` must be identical except for ``seed`` — one campaign cell's
    seed replicates, the exact shape ``engine="batch"`` exists for.  Each
    returned :class:`TrialResult` matches ``run_trial(spec)`` field for
    field (same metrics bit-for-bit — the batched engine is
    fingerprint-identical — and the same aggregation arithmetic); only
    ``wall_s`` differs in meaning: the batch wall clock divided evenly
    across the seeds, so campaign wall-time accounting still sums to
    reality.  Unsupported axes raise
    :class:`repro.core.engine_batch.BatchUnsupportedError` — a cell that
    cannot be batched must be requested with a scalar engine, never
    silently downgraded.
    """
    from repro.core.engine_batch import simulate_batch

    specs = list(specs)
    if not specs:
        return []
    base = dataclasses.replace(specs[0], seed=0)
    for sp in specs[1:]:
        if dataclasses.replace(sp, seed=0) != base:
            raise ValueError(
                "run_trial_batch needs specs identical except seed; got "
                f"{sp} vs {specs[0]}"
            )
    t0 = time.perf_counter()
    plans, tasks = _plans_for(
        base.scenario, base.platform, base.theta, base.enable_variants
    )
    proc = make_arrival_process(base.arrival)
    sims = simulate_batch(
        plans,
        tasks,
        base.duration,
        make_scheduler(base.scheduler),
        [sp.seed for sp in specs],
        processes=[t.arrival or proc for t in tasks],
        budget_policy=base.budget_policy,
        admission=base.admission,
        faults=_resolve_faults(base),
    )
    wall = (time.perf_counter() - t0) / len(specs)
    out: List[TrialResult] = []
    for sp, res in zip(specs, sims):
        agg = {"released": 0, "completed": 0, "dropped": 0,
               "variants_applied": 0, "shed": 0, "evicted": 0, "remapped": 0}
        for st in res.per_model.values():
            agg["released"] += st.released
            agg["completed"] += st.completed
            agg["dropped"] += st.dropped
            agg["variants_applied"] += st.variants_applied
            agg["shed"] += st.shed
            agg["evicted"] += st.evicted
            agg["remapped"] += st.remapped
        loss, counted, _ = res.accuracy_loss_stats(plans)
        out.append(TrialResult(
            spec=sp,
            mean_miss_rate=res.mean_miss_rate,
            mean_accuracy_loss=loss,
            utilization=tuple(float(u) for u in res.utilization()),
            wall_s=wall,
            rounds=res.rounds or 0,
            models_counted=counted,
            **agg,
        ))
    return out


# ---------------------------------------------------- trial execution ----


_POOL_ERRORS = (
    OSError,
    PermissionError,
    concurrent.futures.process.BrokenProcessPool,
)

_BrokenPool = concurrent.futures.process.BrokenProcessPool


class ExecutorCrashError(RuntimeError):
    """The trial worker pool crashed twice (``BrokenProcessPool``).

    One crash is survivable — a worker OOM-killed or segfaulted once —
    so :class:`TrialExecutor` rebuilds the pool and retries the
    in-flight trials.  A second crash means some trial kills its worker
    deterministically; retrying it in the parent would kill the whole
    campaign, so the executor surfaces this named error instead (run
    the offending spec with ``parallel=False`` to debug in-process)."""


class _ImmediateFuture:
    """Future-alike for the serial fallback: runs the trial at result()."""

    __slots__ = ("_spec",)

    def __init__(self, spec: TrialSpec):
        self._spec = spec

    def result(self) -> TrialResult:
        return run_trial(self._spec)


class TrialExecutor:
    """Streaming submit/collect executor for campaign trials.

    The process pool that used to live inside ``Campaign.run`` as a
    one-shot ``map``, refactored into a reusable resource so callers
    that do not know their trial list up front — the sequential sampler
    grows cells round by round — can keep submitting against one warm
    pool.  Semantics preserved from ``Campaign.run``:

    * fork start method when safe (workers inherit the parent's warm
      offline-plan cache), spawn otherwise, with ``_warm_plan_cache`` as
      the pool initializer primed with this campaign's cell keys;
    * any pool-unavailability error (sandbox, no ``fork``, spawn without
      an importable ``__main__``) degrades to serial execution with a
      warning, never to a crash — results are identical either way
      because trials are pure functions of their spec;
    * a pool that BREAKS mid-flight (``BrokenProcessPool`` — a worker
      was killed) is rebuilt once and the in-flight trials are retried
      in the new pool, never in the parent (a trial that kills its
      worker would kill the campaign); a second crash raises
      :class:`ExecutorCrashError`.

    The pool is created lazily on first use, so constructing an executor
    for a grid that turns out to be fully journal-cached costs nothing.
    """

    def __init__(
        self,
        cell_keys: Sequence[Tuple[str, str, float, bool]] = (),
        parallel: bool = True,
        max_workers: Optional[int] = None,
    ):
        self.cell_keys = list(cell_keys)
        self.max_workers = max_workers or os.cpu_count() or 1
        self.parallel = parallel and self.max_workers > 1
        self._pool = None
        # pool rebuilds spent / allowed (REPRO_EXECUTOR_RETRIES, default
        # 1 — the historical rebuild-once-then-ExecutorCrashError)
        self._rebuilds = 0
        self.max_rebuilds = _executor_retries()

    # -- lifecycle ---------------------------------------------------------

    def __enter__(self) -> "TrialExecutor":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def close(self) -> None:
        pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown()

    def _degrade(self, err: BaseException) -> None:
        warnings.warn(f"process pool unavailable ({err!r}); running serially")
        self.parallel = False
        self.close()

    def _rebuild(self, err: BaseException) -> None:
        """A worker crash broke the pool: tear it down so the next
        ``_ensure_pool`` builds a fresh one.  Allowed ``max_rebuilds``
        times (REPRO_EXECUTOR_RETRIES, default 1) with capped
        exponential backoff between attempts — exhausting the budget
        raises :class:`ExecutorCrashError` (never degrade a crashing
        trial into the parent process)."""
        if self._rebuilds >= self.max_rebuilds:
            raise ExecutorCrashError(
                f"trial worker pool crashed again after "
                f"{self._rebuilds} rebuild(s) ({err!r}); a trial is "
                "killing its worker deterministically — run it with "
                "parallel=False to debug in-process, or raise "
                f"{_RETRIES_ENV} if the host is genuinely flaky"
            ) from err
        self._rebuilds += 1
        delay = min(
            _REBUILD_BACKOFF_CAP_S,
            _REBUILD_BACKOFF_BASE_S * 2 ** (self._rebuilds - 1),
        )
        warnings.warn(
            f"trial worker pool crashed ({err!r}); rebuilding the pool "
            f"(attempt {self._rebuilds}/{self.max_rebuilds}, backoff "
            f"{delay:.1f}s) and retrying the in-flight trials"
        )
        time.sleep(delay)
        self.close()

    def _ensure_pool(self):
        if not self.parallel:
            return None
        if self._pool is None:
            # fork is fastest (workers inherit the warm plan cache), but
            # JAX's runtime is multi-threaded and fork()ing after it
            # loads can deadlock — fall back to spawn when jax is
            # already in-process.
            methods = multiprocessing.get_all_start_methods()
            method = "fork" if ("fork" in methods and "jax" not in sys.modules) else "spawn"
            if method == "fork":
                # Warm the offline-plan cache before the pool exists so
                # lazily-created workers inherit it and skip the expensive
                # Algorithm-1 rebuild.  Spawn workers can't inherit memory
                # — the pool initializer primes each one at startup
                # instead of paying the rebuild inside its first run_trial.
                _warm_plan_cache(self.cell_keys)
            try:
                self._pool = concurrent.futures.ProcessPoolExecutor(
                    max_workers=self.max_workers,
                    mp_context=multiprocessing.get_context(method),
                    initializer=_warm_plan_cache,
                    initargs=(self.cell_keys,),
                )
            except _POOL_ERRORS as e:
                self._degrade(e)
        return self._pool

    # -- execution ---------------------------------------------------------

    def submit(self, spec: TrialSpec):
        """Schedule one trial; returns a future-alike with ``result()``."""
        pool = self._ensure_pool()
        if pool is not None:
            try:
                return pool.submit(run_trial, spec)
            except _BrokenPool as e:
                # the pool broke under an earlier submission: rebuild
                # once (raises ExecutorCrashError on the second crash)
                # and resubmit into the fresh pool
                self._rebuild(e)
                pool = self._ensure_pool()
                if pool is not None:
                    try:
                        return pool.submit(run_trial, spec)
                    except (_POOL_ERRORS + (RuntimeError,)) as e2:
                        self._degrade(e2)
            except (_POOL_ERRORS + (RuntimeError,)) as e:
                self._degrade(e)
        return _ImmediateFuture(spec)

    def run_batch(self, specs: Sequence[TrialSpec], on_result=None) -> List[TrialResult]:
        """Execute ``specs``; results come back in specs order regardless
        of completion order.  ``on_result`` (if given) fires once per
        trial in that same deterministic order — the sampler's journal
        hook, so an interrupted run leaves a clean specs-order prefix on
        disk.  A pool that breaks mid-batch is rebuilt once and the
        uncollected trials are resubmitted (results still emit in specs
        order); a second break raises :class:`ExecutorCrashError`."""
        specs = list(specs)
        # engine="batch" specs never go to the pool: the batched engine's
        # whole point is replacing process-per-trial with one in-process
        # device program per seed group.  Group by everything-but-seed in
        # first-appearance order, run each group through run_trial_batch,
        # then emit all results (pool and batch) in specs order.
        done: Dict[int, TrialResult] = {}
        groups: Dict[TrialSpec, List[int]] = {}
        for i, s in enumerate(specs):
            if s.engine == "batch":
                groups.setdefault(dataclasses.replace(s, seed=0), []).append(i)
        for idxs in groups.values():
            for i, res in zip(idxs, run_trial_batch([specs[i] for i in idxs])):
                done[i] = res
        futures = [
            None if i in done else self.submit(s) for i, s in enumerate(specs)
        ]
        results: List[TrialResult] = []
        i = 0
        while i < len(specs):
            fut = futures[i]
            if fut is None:
                res = done[i]
            else:
                try:
                    res = fut.result()
                except _BrokenPool as e:
                    # a worker crash voided every outstanding future:
                    # rebuild the pool once (second crash raises
                    # ExecutorCrashError) and resubmit the uncollected
                    # tail — never run a suspect trial in the parent
                    self._rebuild(e)
                    for j in range(i, len(specs)):
                        if futures[j] is not None:
                            futures[j] = self.submit(specs[j])
                    continue
                except _POOL_ERRORS as e:
                    self._degrade(e)
                    res = run_trial(specs[i])
            results.append(res)
            if on_result is not None:
                on_result(res)
            i += 1
        return results

    def map(self, specs: Sequence[TrialSpec], chunksize: int = 1) -> List[TrialResult]:
        """One-shot chunked map over a known grid (``Campaign.run``)."""
        specs = list(specs)
        if any(s.engine == "batch" for s in specs):
            # seed-grouped in-process path (plus pool for the rest)
            return self.run_batch(specs)
        pool = self._ensure_pool()
        while pool is not None:
            try:
                return list(pool.map(run_trial, specs, chunksize=chunksize))
            except _BrokenPool as e:
                # trials are pure functions of their spec: re-mapping the
                # whole list after the one allowed rebuild is safe
                self._rebuild(e)
                pool = self._ensure_pool()
            except _POOL_ERRORS as e:
                self._degrade(e)
                pool = None
        return [run_trial(s) for s in specs]


# -------------------------------------------------------- aggregation ----


class DegenerateSampleError(ValueError):
    """A confidence interval was requested over a degenerate sample.

    Raised by :func:`bootstrap_ci` (and therefore
    :meth:`CampaignResult.aggregate`) on < 2 values: an empty sample has
    no mean and a single value has no resampling distribution, so the
    old behaviors — a silent NaN interval and a zero-width point
    interval — both read as "statistically grounded" in result tables
    while meaning nothing.  Callers that genuinely want a point estimate
    should report the mean without an interval."""


def bootstrap_ci(
    values: Sequence[float],
    n_boot: int = 1000,
    alpha: float = 0.05,
    seed: int = 0,
) -> Tuple[float, float]:
    """Percentile bootstrap CI for the mean of ``values`` (deterministic).

    Raises :class:`DegenerateSampleError` on fewer than 2 values."""
    vals = np.asarray(list(values), dtype=float)
    if vals.size < 2:
        raise DegenerateSampleError(
            f"bootstrap_ci needs >= 2 values, got {vals.size}; a "
            "degenerate sample has no resampling distribution (report "
            "the point estimate without an interval instead)"
        )
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, vals.size, size=(n_boot, vals.size))
    means = vals[idx].mean(axis=1)
    lo, hi = np.percentile(means, [100 * alpha / 2, 100 * (1 - alpha / 2)])
    return (float(lo), float(hi))


@dataclasses.dataclass
class CampaignResult:
    trials: List[TrialResult]

    def rows(self) -> List[Dict]:
        return [t.row() for t in self.trials]

    def grouped(self, by: Sequence[str]) -> "Dict[Tuple, List[TrialResult]]":
        """Trials keyed by spec fields, in first-appearance (grid) order."""
        out: Dict[Tuple, List[TrialResult]] = {}
        for t in self.trials:
            key = tuple(getattr(t.spec, f) for f in by)
            out.setdefault(key, []).append(t)
        return out

    def aggregate(
        self,
        by: Sequence[str] = ("scenario", "platform", "scheduler", "arrival"),
        metric: str = "mean_miss_rate",
        n_boot: int = 1000,
        alpha: float = 0.05,
        ci_seed: int = 0,
    ) -> List[Dict]:
        """One row per group: mean of ``metric`` + bootstrap CI over trials
        (normally the seed axis).  Group order follows the grid."""
        rows = []
        for key, ts in self.grouped(by).items():
            vals = [getattr(t, metric) for t in ts]
            lo, hi = bootstrap_ci(vals, n_boot=n_boot, alpha=alpha, seed=ci_seed)
            row = dict(zip(by, key))
            row.update(
                {
                    metric: float(np.mean(vals)),
                    f"{metric}_ci_lo": lo,
                    f"{metric}_ci_hi": hi,
                    "n_trials": len(vals),
                }
            )
            rows.append(row)
        return rows


# ------------------------------------------------------------ campaign ----


@dataclasses.dataclass
class Campaign:
    """Declarative (scenario x platform x theta x scheduler x arrival x
    budget-policy x admission x faults x seed) grid plus its executor.

    ``platforms=None`` pairs each scenario with its Table-I hardware
    settings (the Fig. 5 cells); an explicit list applies every platform
    to every scenario.  Grid expansion order is deterministic: cell,
    then theta, then scheduler, then arrival, then budget policy, then
    admission, then faults, then seed — benchmark tables depend on it.
    """

    scenarios: Sequence[str] = ()
    platforms: Optional[Sequence[str]] = None
    schedulers: Sequence[str] = ALL_SCHEDULERS
    arrivals: Sequence[str] = ("periodic",)
    budget_policies: Sequence[str] = ("static",)
    admissions: Sequence[str] = ("none",)
    seeds: Sequence[int] = (0, 1, 2)
    duration: float = 5.0
    thetas: Sequence[float] = (0.90,)
    enable_variants: bool = True
    engine: str = "auto"  # simulator engine for every trial in the grid
    round_kernel: str = "auto"  # Terastal round kernel (engine_soa.ROUND_KERNELS)
    # Fault-model axis: "scenario" defers to each scenario's own default
    # (fault-free outside FAULT_SCENARIOS); explicit call-specs compare
    # fault shapes on one workload.
    faults: Sequence[str] = ("scenario",)

    def cells(self) -> List[Tuple[str, str]]:
        # explicit names may come from either catalog (the saturation
        # family included); the default grid stays the paper's SCENARIOS
        names = list(self.scenarios) or list(SCENARIOS)
        out = []
        for name in names:
            pns = (
                self.platforms
                if self.platforms is not None
                else get_scenario(name).platform_names
            )
            for pn in pns:
                out.append((name, pn))
        return out

    def trials(self) -> List[TrialSpec]:
        out = []
        for sc, pn in self.cells():
            for theta in self.thetas:
                for sched in self.schedulers:
                    for arr in self.arrivals:
                        for pol in self.budget_policies:
                            for adm in self.admissions:
                                for flt in self.faults:
                                    for seed in self.seeds:
                                        out.append(
                                            TrialSpec(
                                                scenario=sc,
                                                platform=pn,
                                                scheduler=sched,
                                                arrival=arr,
                                                seed=int(seed),
                                                duration=self.duration,
                                                theta=theta,
                                                enable_variants=self.enable_variants,
                                                budget_policy=pol,
                                                admission=adm,
                                                engine=self.engine,
                                                round_kernel=self.round_kernel,
                                                faults=flt,
                                            )
                                        )
        return out

    def cell_keys(self) -> List[Tuple[str, str, float, bool]]:
        """Offline-plan cache keys for every cell — the pool-initializer
        payload shared by :class:`TrialExecutor` users."""
        return [
            (sc, pn, theta, self.enable_variants)
            for sc, pn in self.cells()
            for theta in self.thetas
        ]

    def run(
        self,
        parallel: bool = True,
        max_workers: Optional[int] = None,
        chunksize: Optional[int] = None,
    ) -> CampaignResult:
        """Execute the grid; results come back in grid order regardless of
        completion order, and parallel output equals serial output exactly
        (per-trial PRNG streams depend only on the spec)."""
        specs = self.trials()
        n_workers = max_workers or os.cpu_count() or 1
        if not parallel or n_workers <= 1 or len(specs) <= 1:
            return CampaignResult([run_trial(s) for s in specs])
        cs = chunksize or max(1, len(specs) // (n_workers * 4))
        with TrialExecutor(
            self.cell_keys(), parallel=True, max_workers=n_workers
        ) as ex:
            return CampaignResult(ex.map(specs, chunksize=cs))

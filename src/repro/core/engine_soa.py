"""Structure-of-arrays simulation engine — bit-identical, much faster.

The reference event loop (``repro.core.simulator._simulate_reference``)
spends its time on per-event object churn: every scheduler invocation
rebuilds a :class:`SchedView` (``list(ready)`` + ``acc_busy_until.copy()``),
every decision re-derives per-request quantities (virtual deadlines,
latency rows, remaining-min sums) through NumPy scalar ops on 3-element
arrays, and the ready queue pays O(n) ``list.remove`` / ``req not in
ready`` scans.  At campaign scale (fig5-fig8 run tens of thousands of
trials) the sweeps are bound by interpreter overhead, not by the
simulated hardware.

This engine keeps the exact event semantics but restructures the state:

* per-request state lives in preallocated parallel arrays (the
  :class:`_ReadyBlock`): request slot -> deadline / remaining-min /
  latency rows / virtual deadlines / sort keys, computed once at push
  time instead of once per scheduler invocation;
* the ready set is indexed — removal is an O(1) swap-with-last, and
  membership never needs scanning;
* ``drop_hopeless`` is one masked compare over the ready block, and a
  conservative scalar guard (``_ReadyBlock.guard``) skips even that
  until the clock is within 1e-9 of the earliest possible drop;
* scheduler decisions run as specialized kernels over the block's
  cached Python floats (for n_acc ~ 3 and a handful of ready layers,
  scalar arithmetic beats tiny-ndarray dispatch by ~10x; IEEE float64
  ops are identical either way, so results match bit-for-bit).  FCFS/EDF
  placement walks a precomputed per-layer accelerator-preference order
  (``ModelPlan.acc_pref_rows``) instead of comparing latencies at all;
* uncontended request chains run in a fused loop: while exactly one
  request is outstanding and no other event interrupts (``heap[0]``
  check), each layer advances with no event-queue traffic — the same
  kernels decide placement on a single-slot block, so the decision logic
  has one source of truth.

Budget policies run natively: each request is still materialized once as
a :class:`Request` record (that is O(requests), not O(events) — the
churn the reference pays is per *invocation*), and the unchanged policy
hooks mutate ``Request.vdl_abs`` exactly as in the reference engine.
Policies must REBIND ``vdl_abs`` rather than mutate it in place (all
built-ins do): the engine detects chain updates by identity to refresh
its cached virtual-deadline scalars.  ``on_tick`` receives the ready set
in block-slot order (the reference passes insertion order; built-in
policies are per-request and order-independent) and a copy of
``acc_busy_until``.

Deep-queue fast path (saturation regime, NJ >> 16)
---------------------------------------------------
The scalar kernels above are tuned for the paper's grids (a handful of
ready layers); their per-round cost is O(NJ * n_acc) *interpreted* ops,
which dominates exactly when overload makes ready queues deep.  Above a
queue-depth threshold the engine switches representation and kernel:

* the block activates **deep mirrors** — numpy arrays (``lat_arr``,
  ``latv_arr``, ``vdl_arr``, ...) maintained *incrementally* alongside
  the scalar lists: only arrivals, finishes, and vdl-rebinds write a
  slot (push / ``_fill_vdl`` / ``swap_remove``); a scheduling round
  re-keys nothing per-slot and runs as a few C-speed vector ops;
* FCFS/EDF keep their ready order **incrementally sorted** across
  rounds (``bisect.insort`` on push, bisect-remove on pop) — exact,
  because their sort keys are static per slot — so a round walks at
  most ``n_idle`` entries instead of re-sorting NJ tuples;
* Terastal and DREAM keys depend on ``now``/tau through per-slot
  roundings, so an incrementally sorted order cannot stay bit-identical
  (ordering by the algebraically equivalent static key differs near
  float ties — a measured negative result); their deep rounds instead
  recompute keys vectorized and ``np.lexsort`` them: O(NJ log NJ) with
  C constants, against the reference's interpreted re-scan;
* Terastal stage 2 scores every (remaining layer x idle accelerator)
  pair as masked vector arithmetic with an argmax whose tie-breaking
  reproduces the reference's strictly-greater ``(delta, -use_var)``
  replacement scan.  (A per-accelerator candidate *heap* was
  considered and rejected: every backfill score depends on the
  round-local tau of *all* accelerators through ``s*``, so heap keys
  go stale on every assignment and exact revalidation costs more than
  the vectorized rescan.)
* rounds deeper than a calibrated crossover can ride the **jitted
  kernel** (``scheduler_jax.terastal_round``): the block mirrors stage
  into ``pack_arrays``'s persistent pow2 bucket buffers (batched
  host->device copies) and the outputs come back in one device sync,
  in the exact reference emission order via ``assign_seq``.  Kernel
  choice: ``REPRO_ROUND_KERNEL`` in {python, jax, auto}; "auto" uses
  the jitted round only above :func:`round_crossover` (env
  ``REPRO_ROUND_CROSSOVER``, or set from measurement by
  ``benchmarks/bench_scheduler_round.py`` — on CPU-only hosts the
  measured crossover is typically infinity and auto == python).

Bit-parity is enforced by differential tests (``tests/test_engine_soa.py``):
every ``SimResult`` field — per-model counters, ``retained_sum`` floats,
busy-time arrays — must equal the reference engine's exactly, across
schedulers x arrival processes x budget policies, and the deep kernels
are additionally pinned against the scalar ones at every pow2 bucket
boundary (``tests/test_round_kernels.py``).
"""

from __future__ import annotations

import os
from bisect import bisect_left, insort
from heapq import heappop, heappush
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.budget_online import BudgetPolicy, StaticBudgetPolicy
from repro.core.dag import DagRun
from repro.core.scheduler import (
    DreamScheduler,
    EdfScheduler,
    FcfsScheduler,
    Request,
    Scheduler,
    TerastalScheduler,
)
from repro.core.admission import AdmissionPolicy, NoAdmission
from repro.core.faults import (
    FaultModel,
    degraded_work_tables,
    effective_plans,
    evict_busy_adjust,
    fault_multipliers,
    retightened_vdl,
    retime_busy_adjust,
)
from repro.core.simulator import (
    ArrivalProcess,
    ModelStats,
    SimResult,
    TaskSpec,
    generate_release_events,
)
from repro.core.variants import ModelPlan

#: schedulers with a SoA kernel.  Exact types only: a subclass may
#: override ``schedule()``, which the kernels bypass — ``engine="auto"``
#: falls back to the reference loop for those.
_SUPPORTED = (FcfsScheduler, EdfScheduler, DreamScheduler, TerastalScheduler)

#: policies with no per-event side effects: the fused uncontended-chain
#: loop (which skips the policy hooks entirely) engages only for these.
_INERT_POLICIES = (StaticBudgetPolicy, BudgetPolicy)

_INF = float("inf")
_NEGINF = float("-inf")
_ONE = (0,)

# ------------------------------------------------- round-kernel dispatch ----

#: Terastal round-kernel choices: "python" (scalar/vectorized kernels,
#: depth-dispatched), "jax" (force the jitted ``terastal_round`` for
#: every block round), "auto" (python below :func:`round_crossover`,
#: jitted above).  Per-trial override: ``simulate(round_kernel=...)`` /
#: ``TrialSpec.round_kernel``; process-wide: ``REPRO_ROUND_KERNEL``.
ROUND_KERNELS = ("auto", "python", "jax")

#: ready-queue depth at which a Terastal/DREAM round switches from the
#: scalar kernel to the vectorized one (and the block activates its deep
#: mirrors).  Calibrated on captured saturation-round states (see
#: ``benchmarks/bench_scheduler_round.py``): the vectorized round costs
#: a ~13us flat floor of numpy dispatch, which the scalar kernel crosses
#: between NJ ~ 24 and 32; by NJ >= 64 the vectorized round is 2.6-7x
#: faster and essentially depth-independent.  ``REPRO_ROUND_VEC_MIN``
#: overrides (tests use it to force either path at any depth).
VEC_MIN_NJ = 24

_round_crossover: Optional[float] = None

#: memoized (raw env string, parsed value) for :func:`round_crossover` —
#: campaign trials call it once per simulation, and every pool worker
#: re-resolves it from a fresh process, so the parse is cached on the
#: raw string (``REPRO_ROUND_CROSSOVER=inf`` in particular hits this
#: fast path instead of re-parsing per trial).
_crossover_env: Tuple[Optional[str], float] = (None, _INF)


def _vec_min() -> int:
    env = os.environ.get("REPRO_ROUND_VEC_MIN")
    return int(env) if env else VEC_MIN_NJ


def round_crossover() -> float:
    """NJ above which ``REPRO_ROUND_KERNEL=auto`` rides the jitted round.

    Resolution order: ``REPRO_ROUND_CROSSOVER`` env (a number, or
    ``inf``), else the value installed by :func:`set_round_crossover`
    (``benchmarks/bench_scheduler_round.py`` measures and installs it at
    benchmark-smoke time), else +inf — the honest default for CPU-only
    hosts, where per-round dispatch overhead keeps the jitted kernel
    behind the vectorized Python round at every measured depth.

    When the resolved value is INF, ``simulate_soa`` drops the jax
    branch from its per-round dispatch entirely (``jax_on`` below):
    ``auto`` is then end-to-end identical to ``round_kernel="python"``
    and never imports ``scheduler_jax`` (pinned by
    ``tests/test_round_kernels.py::test_auto_inf_crossover_is_python``)."""
    global _crossover_env
    env = os.environ.get("REPRO_ROUND_CROSSOVER")
    if env:
        raw, val = _crossover_env
        if raw != env:
            val = float(env)
            _crossover_env = (env, val)
        return val
    if _round_crossover is not None:
        return _round_crossover
    return _INF


def set_round_crossover(nj: Optional[float]) -> None:
    """Install a measured python->jax crossover depth (None clears)."""
    global _round_crossover
    _round_crossover = None if nj is None else float(nj)


_SJ = None  # lazily imported repro.core.scheduler_jax (pulls in jax)


def _jax_mod():
    """Lazy scheduler_jax import.  NOTE: importing it enables jax x64
    process-wide (bit-parity with the f64 Python kernels requires it),
    so the first jitted round in a process changes the default dtype of
    any *later-created* default-dtype jax arrays.  In-repo jax code is
    dtype-explicit (pinned by running the suite under JAX_ENABLE_X64=1);
    embedders mixing this engine with dtype-implicit jax code should
    import scheduler_jax up front rather than mid-run."""
    global _SJ
    if _SJ is None:
        from repro.core import scheduler_jax

        _SJ = scheduler_jax
    return _SJ


def supports_scheduler(scheduler: Scheduler) -> bool:
    return type(scheduler) in _SUPPORTED


# ------------------------------------------------------------ ready set ----


class _ReadyBlock:
    """Indexed structure-of-arrays ready set.

    Parallel per-slot fields; removal swaps the last slot in (O(1)).
    ``min_rem_arr`` / ``dl_eps_arr`` mirror the drop-test operands as
    ndarrays so the early-drop test is a single masked compare over the
    block; ``guard`` is a conservative scalar bound (min over slots of
    the approximate drop threshold, minus a 1e-9 safety margin that
    dwarfs the ~1e-15 re-association error) below which no slot can
    possibly drop — the exact vectorized compare runs only when ``now``
    crosses it.
    """

    __slots__ = (
        "n", "cap", "req", "rid", "model", "layer", "dl", "mr",
        "lat", "latv", "vdl", "vdl_next", "next_min", "fkey", "ekey", "pref",
        "min_rem_arr", "dl_eps_arr", "guard_arr", "guard",
        # deep mirrors (None until a deep round activates them; from then
        # on maintained incrementally by push/_fill_vdl/swap_remove only)
        "deep", "rid_arr", "dl_arr", "vdl_arr", "vdl_next_arr",
        "next_min_arr", "lat_arr", "latv_arr", "okey", "order_sl", "rid2slot",
    )

    def __init__(self, cap: int = 64):
        self.n = 0
        self.cap = cap
        self.req: List[Optional[Request]] = [None] * cap
        self.rid = [0] * cap
        self.model = [0] * cap
        self.layer = [0] * cap
        self.dl = [0.0] * cap
        self.mr = [0.0] * cap
        self.lat: List[Optional[Tuple[float, ...]]] = [None] * cap
        self.latv: List[Optional[Tuple[float, ...]]] = [None] * cap
        self.vdl = [0.0] * cap
        self.vdl_next = [0.0] * cap
        self.next_min = [0.0] * cap
        self.fkey: List = [None] * cap  # (arrival, rid) — FCFS order
        self.ekey: List = [None] * cap  # (edf deadline, rid) — EDF order
        self.pref: List = [None] * cap  # per-layer accelerator preference
        self.min_rem_arr = np.zeros(cap)
        self.dl_eps_arr = np.zeros(cap)
        self.guard_arr = np.zeros(cap)
        self.guard = _INF
        self.deep = False
        self.rid_arr = None  # [cap] int64 (terastal/dream vec kernels)
        self.dl_arr = None  # [cap] (dream vec order)
        self.vdl_arr = None  # [cap] (terastal vec/jax rounds)
        self.vdl_next_arr = None
        self.next_min_arr = None
        self.lat_arr = None  # [cap, n_acc]
        self.latv_arr = None  # [cap, n_acc]; +inf rows where no variant
        self.okey = None  # the fkey/ekey list the sorted order is keyed on
        self.order_sl = None  # incrementally sorted key list (FCFS/EDF)
        self.rid2slot = None  # rid -> live slot (FCFS/EDF deep walk)

    def clone(self) -> "_ReadyBlock":
        """Deep copy of the live state — benchmark/test helper, so round
        kernels can be re-run and timed on captured mid-trial states."""
        C = _ReadyBlock(self.cap)
        for name in ("req", "rid", "model", "layer", "dl", "mr", "lat",
                     "latv", "vdl", "vdl_next", "next_min", "fkey", "ekey",
                     "pref"):
            setattr(C, name, list(getattr(self, name)))
        for name in ("min_rem_arr", "dl_eps_arr", "guard_arr"):
            setattr(C, name, getattr(self, name).copy())
        C.n = self.n
        C.guard = self.guard
        C.deep = self.deep
        for name in ("rid_arr", "dl_arr", "vdl_arr", "vdl_next_arr",
                     "next_min_arr", "lat_arr", "latv_arr"):
            arr = getattr(self, name)
            if arr is not None:
                setattr(C, name, arr.copy())
        if self.order_sl is not None:
            C.order_sl = list(self.order_sl)
            C.rid2slot = dict(self.rid2slot)
            C.okey = C.fkey if self.okey is self.fkey else C.ekey
        return C

    # -- deep-mirror activation (once per trial, on the first deep round) --

    def activate_deep_terastal(self, n_acc: int) -> None:
        cap, nb = self.cap, self.n
        self.rid_arr = np.empty(cap, np.int64)
        self.rid_arr[:nb] = self.rid[:nb]
        self.vdl_arr = np.empty(cap)
        self.vdl_arr[:nb] = self.vdl[:nb]
        self.vdl_next_arr = np.empty(cap)
        self.vdl_next_arr[:nb] = self.vdl_next[:nb]
        self.next_min_arr = np.empty(cap)
        self.next_min_arr[:nb] = self.next_min[:nb]
        # transposed [n_acc, cap]: the vectorized round reads whole
        # accelerator columns, which this layout keeps contiguous
        self.lat_arr = np.empty((n_acc, cap))
        self.latv_arr = np.empty((n_acc, cap))
        for i in range(nb):
            self.lat_arr[:, i] = self.lat[i]
            rv = self.latv[i]
            self.latv_arr[:, i] = rv if rv is not None else np.inf
        self.deep = True

    def activate_deep_dream(self) -> None:
        cap, nb = self.cap, self.n
        self.rid_arr = np.empty(cap, np.int64)
        self.rid_arr[:nb] = self.rid[:nb]
        self.dl_arr = np.empty(cap)
        self.dl_arr[:nb] = self.dl[:nb]
        self.deep = True

    def activate_deep_pref(self, use_fkey: bool) -> None:
        nb = self.n
        self.okey = self.fkey if use_fkey else self.ekey
        self.order_sl = sorted(self.okey[:nb])
        self.rid2slot = {self.rid[i]: i for i in range(nb)}
        self.deep = True

    def grow(self) -> None:
        pad = self.cap
        self.cap *= 2
        for name in ("req", "lat", "latv", "fkey", "ekey", "pref"):
            getattr(self, name).extend([None] * pad)
        for name in ("rid", "model", "layer", "dl", "mr", "vdl", "vdl_next", "next_min"):
            getattr(self, name).extend([0] * pad)
        self.min_rem_arr = np.concatenate([self.min_rem_arr, np.zeros(pad)])
        self.dl_eps_arr = np.concatenate([self.dl_eps_arr, np.zeros(pad)])
        self.guard_arr = np.concatenate([self.guard_arr, np.zeros(pad)])
        if self.rid_arr is not None:
            self.rid_arr = np.concatenate([self.rid_arr, np.empty(pad, np.int64)])
        for name in ("dl_arr", "vdl_arr", "vdl_next_arr", "next_min_arr"):
            arr = getattr(self, name)
            if arr is not None:
                setattr(self, name, np.concatenate([arr, np.empty(pad)]))
        for name in ("lat_arr", "latv_arr"):
            arr = getattr(self, name)
            if arr is not None:
                setattr(
                    self, name,
                    np.concatenate([arr, np.empty((arr.shape[0], pad))], axis=1),
                )
        # okey aliases fkey/ekey, which extend() above grew in place.

    def swap_remove(self, i: int) -> None:
        n1 = self.n - 1
        if self.deep:
            sl = self.order_sl
            if sl is not None:
                del sl[bisect_left(sl, self.okey[i])]
                del self.rid2slot[self.rid[i]]
                if i != n1:
                    self.rid2slot[self.rid[n1]] = i
            elif i != n1:
                self.rid_arr[i] = self.rid_arr[n1]
                la = self.lat_arr
                if la is not None:
                    la[:, i] = la[:, n1]
                    self.latv_arr[:, i] = self.latv_arr[:, n1]
                    self.vdl_arr[i] = self.vdl_arr[n1]
                    self.vdl_next_arr[i] = self.vdl_next_arr[n1]
                    self.next_min_arr[i] = self.next_min_arr[n1]
                else:
                    self.dl_arr[i] = self.dl_arr[n1]
        if i != n1:
            self.req[i] = self.req[n1]
            self.rid[i] = self.rid[n1]
            self.model[i] = self.model[n1]
            self.layer[i] = self.layer[n1]
            self.dl[i] = self.dl[n1]
            self.mr[i] = self.mr[n1]
            self.lat[i] = self.lat[n1]
            self.latv[i] = self.latv[n1]
            self.vdl[i] = self.vdl[n1]
            self.vdl_next[i] = self.vdl_next[n1]
            self.next_min[i] = self.next_min[n1]
            self.fkey[i] = self.fkey[n1]
            self.ekey[i] = self.ekey[n1]
            self.pref[i] = self.pref[n1]
            self.min_rem_arr[i] = self.min_rem_arr[n1]
            self.dl_eps_arr[i] = self.dl_eps_arr[n1]
            self.guard_arr[i] = self.guard_arr[n1]
        self.req[n1] = None  # release the reference
        self.n = n1
        # self.guard is left stale-low on removal; the drop path recomputes
        # it after every exact check, so staleness only costs a re-check.


# -------------------------------------------------------------- kernels ----
#
# Each kernel mirrors one Scheduler.schedule() implementation over the
# ready block, returning [(slot, acc, use_variant, latency)] in the exact
# order the reference emits assignments (the engine assigns finish-event
# push counters in that order, which fixes how simultaneous finishes tie-
# break for the rest of the run).  All comparisons/arithmetic reproduce
# the reference expressions operation-for-operation — see the inline
# notes where an algebraic shortcut is exact (first-min scans, shared
# ef_all/f0 minima, precomputed preference orders).


def _order_by(keys, n: int):
    if n == 1:
        return _ONE
    if n == 2:
        return (0, 1) if keys[0] <= keys[1] else (1, 0)
    return sorted(range(n), key=keys.__getitem__)


def _assign_pref(B: _ReadyBlock, order, idle_mask: int, n_idle: int):
    """Shared FCFS/EDF body: walk the order, place each layer on the
    first idle accelerator in its precomputed preference order (exactly
    ``min(idle, key=latency)`` — static floats, stable argsort)."""
    out = []
    for i in order:
        if not n_idle:
            break
        for k in B.pref[i]:
            if idle_mask >> k & 1:
                out.append((i, k, False, B.lat[i][k]))
                idle_mask &= ~(1 << k)
                n_idle -= 1
                break
    return out


def _kern_fcfs(B, now, busy, idle_mask, n_idle):
    return _assign_pref(B, _order_by(B.fkey, B.n), idle_mask, n_idle)


def _kern_edf(B, now, busy, idle_mask, n_idle):
    return _assign_pref(B, _order_by(B.ekey, B.n), idle_mask, n_idle)


def _dream_assign(B, order, now, busy, idle_mask, n_idle):
    # DREAM maps by earliest estimated finish with ROUND-START tau (busy
    # never changes inside a round); first minimum wins, ascending order
    lat = B.lat
    nacc = len(busy)
    out = []
    for i in order:
        if not n_idle:
            break
        row = lat[i]
        bk = -1
        bc = 0.0
        for k in range(nacc):
            if idle_mask >> k & 1:
                b = busy[k]
                f = (b if b > now else now) + row[k]
                if bk < 0 or f < bc:
                    bc, bk = f, k
        out.append((i, bk, False, row[bk]))
        idle_mask &= ~(1 << bk)
        n_idle -= 1
    return out


def _kern_dream(B, now, busy, idle_mask, n_idle):
    n = B.n
    if n == 1:
        order = _ONE
    else:
        # reference: slack = deadline_abs - now - crit_from (left-assoc);
        # the layer id totalizes ties among DAG sibling entries
        dl, mr, rid, layer = B.dl, B.mr, B.rid, B.layer
        keys = [((dl[i] - now) - mr[i], rid[i], layer[i]) for i in range(n)]
        order = _order_by(keys, n)
    return _dream_assign(B, order, now, busy, idle_mask, n_idle)


def _kern_dream_deep(B, now, busy, idle_mask, n_idle):
    """DREAM round over the deep mirrors: the slack keys are the same
    left-associated ``(dl - now) - mr`` floats computed as one vector op,
    and ``lexsort((rid, keys))`` is exactly ``sorted(key=(slack, rid))``;
    the assignment walk (<= n_idle entries) is shared with the scalar
    kernel.  The walk can stay scalar because DREAM always places every
    entry it visits, so its cost is bounded by n_idle, not NJ."""
    n = B.n
    keys = (B.dl_arr[:n] - now) - B.min_rem_arr[:n]
    order = np.lexsort((B.rid_arr[:n], keys))
    return _dream_assign(B, [int(i) for i in order[: n_idle]], now, busy,
                         idle_mask, n_idle)


def _kern_pref_deep(B, idle_mask, n_idle):
    """FCFS/EDF round over the incrementally sorted ready order: the
    shared ``_assign_pref`` walk on a lazily resolved slot order —
    nothing is re-sorted, the order was maintained at push/remove time,
    and only the entries the walk actually visits are resolved.  Exact
    at every depth: the sort keys (``fkey``/``ekey``) are static per
    slot, so the incremental order IS the per-round sorted order."""
    rid2slot = B.rid2slot
    return _assign_pref(
        B, (rid2slot[key[1]] for key in B.order_sl), idle_mask, n_idle
    )


def _solo_terastal(row, rv, vdl, vdl_next, next_min, now, busy, idle_mask, n_acc, mode):
    """Terastal round for a single ready layer, operating on scalars only
    (no block traffic).  Mirrors ``_kern_terastal`` at n == 1 — the
    differential tests pin the two paths against the reference together.
    Returns ``(acc, use_variant, latency)`` or ``None``."""
    d = vdl + 1e-15
    rng = range(n_acc)
    # ---- stage 1: original, then variant, on an idle acc meeting d_v ----
    bk = -1
    bf = 0.0
    for k in rng:
        if idle_mask >> k & 1:
            b = busy[k]
            f = (b if b > now else now) + row[k]
            if f <= d and (bk < 0 or f < bf):
                bf, bk = f, k
    if bk >= 0:
        return bk, False, row[bk]
    if rv is not None:
        for k in rng:
            if idle_mask >> k & 1:
                b = busy[k]
                f = (b if b > now else now) + rv[k]
                if f <= d and (bk < 0 or f < bf):
                    bf, bk = f, k
        if bk >= 0:
            return bk, True, rv[bk]
    # ---- stage 2: first idle acc (ascending) with an allowed backfill ----
    # tau is constant until the first assignment, which ends the round, so
    # f0 / s_star / ef_all are loop invariants here.
    b = busy[0]
    f0 = (b if b > now else now) + row[0]
    for k in range(1, n_acc):
        b = busy[k]
        f = (b if b > now else now) + row[k]
        if f < f0:
            f0 = f
    s_star = vdl - f0
    ea = None  # variant ef_all, computed lazily
    for k in rng:
        if not (idle_mask >> k & 1):
            continue
        b = busy[k]
        tk = b if b > now else now
        best_d = None
        best_v = False
        best_c = 0.0
        c = row[k]
        finish = tk + c
        if mode != "ef" or finish <= f0 + 1e-15:
            best_d = (vdl_next - finish - next_min) - s_star
            best_c = c
        if rv is not None:
            cv = rv[k]
            fv = tk + cv
            ok = True
            if mode == "ef":
                if ea is None:
                    b = busy[0]
                    ea = (b if b > now else now) + rv[0]
                    for kk in range(1, n_acc):
                        b = busy[kk]
                        f = (b if b > now else now) + rv[kk]
                        if f < ea:
                            ea = f
                ok = fv <= ea + 1e-15
            if ok:
                dv = (vdl_next - fv - next_min) - s_star
                # (delta, -use_var) strictly-greater: var never wins ties
                if best_d is None or dv > best_d:
                    best_d, best_v, best_c = dv, True, cv
        if best_d is None:
            continue
        if mode == "positive" and best_d <= 0.0:
            continue
        return k, best_v, best_c
    return None


def _kern_terastal(B, now, busy, idle_mask, n_idle, mode):
    n = B.n
    rid, lat, latv, vdl = B.rid, B.lat, B.latv, B.vdl
    nacc = len(busy)
    tau = [b if b > now else now for b in busy]
    idle = [k for k in range(nacc) if idle_mask >> k & 1]

    if n == 1:
        order = _ONE  # the sort key (best-case slack) is order-irrelevant
    else:
        # stage-1 ordering: best-case slack at round-start tau (Eq. 6-7);
        # the layer id totalizes ties among DAG sibling entries
        layer = B.layer
        keys = []
        for i in range(n):
            row = lat[i]
            f = tau[0] + row[0]
            for k in range(1, nacc):
                v = tau[k] + row[k]
                if v < f:
                    f = v
            keys.append((vdl[i] - f, rid[i], layer[i]))
        order = _order_by(keys, n)

    out = []
    remaining: List[int] = []
    for i in order:
        d = vdl[i] + 1e-15
        row = lat[i]
        # original on an idle accelerator meeting d_v (lines 4-10);
        # strict < keeps min()'s first-minimum over ascending idle order
        bk = -1
        bf = 0.0
        for k in idle:
            f = tau[k] + row[k]
            if f <= d and (bk < 0 or f < bf):
                bf, bk = f, k
        if bk >= 0:
            c = row[bk]
            out.append((i, bk, False, c))
            idle.remove(bk)
            tau[bk] += c  # round-local update (Sec. IV-C)
            continue
        rv = latv[i]  # non-None iff LayerVariantFeasible held at push time
        if rv is not None:
            bk = -1
            for k in idle:
                f = tau[k] + rv[k]
                if f <= d and (bk < 0 or f < bf):
                    bf, bk = f, k
            if bk >= 0:
                c = rv[bk]
                out.append((i, bk, True, c))
                idle.remove(bk)
                tau[bk] += c
                continue
        remaining.append(i)

    # stage 2: backfill remaining idle accelerators (lines 19-23)
    if remaining and idle:
        vdl_next, next_min = B.vdl_next, B.next_min
        for k in list(idle):
            if not remaining:
                break
            tk = tau[k]
            best_d = None
            best_r = 0
            best_i = -1
            best_v = False
            best_c = 0.0
            for i in remaining:
                row = lat[i]
                # s* with CURRENT tau (the reference recomputes per probe)
                f0 = tau[0] + row[0]
                for kk in range(1, nacc):
                    v = tau[kk] + row[kk]
                    if v < f0:
                        f0 = v
                s_star = vdl[i] - f0
                vn = vdl_next[i]
                nm = next_min[i]
                # use_var=False; ef_all of the original row IS f0
                c = row[k]
                finish = tk + c
                if mode != "ef" or finish <= f0 + 1e-15:
                    delta = (vn - finish - nm) - s_star  # Eq. 8-9
                    if best_d is None or delta > best_d or (delta == best_d and 0 > best_r):
                        best_d, best_r, best_i, best_v, best_c = delta, 0, i, False, c
                rv = latv[i]
                if rv is not None:
                    c = rv[k]
                    finish = tk + c
                    ok = True
                    if mode == "ef":
                        ea = tau[0] + rv[0]
                        for kk in range(1, nacc):
                            v = tau[kk] + rv[kk]
                            if v < ea:
                                ea = v
                        ok = finish <= ea + 1e-15
                    if ok:
                        delta = (vn - finish - nm) - s_star
                        # strictly-greater (delta, -use_var) replacement
                        if best_d is None or delta > best_d or (delta == best_d and -1 > best_r):
                            best_d, best_r, best_i, best_v, best_c = delta, -1, i, True, c
            if best_i < 0:
                continue
            if mode == "positive" and best_d <= 0.0:
                continue
            out.append((best_i, k, best_v, best_c))
            tau[k] += best_c
            remaining.remove(best_i)
    return out


def _pick_first(mask, keys, rid):
    """Index of the (keys, rid)-lexicographic minimum among ``mask`` —
    the first slot a walk over ``sorted(key=(keys[i], rid[i]))`` order
    would visit with ``mask`` true, or -1 if none is.  float key ties
    resolve through the exact rid comparison, so this equals the
    reference's stable sort without ever building the sort."""
    mk = np.where(mask, keys, _INF)
    i = int(mk.argmin())
    m = mk[i]
    if m == _INF:
        return -1
    eq = mk == m
    if np.count_nonzero(eq) > 1:
        return int(min(np.flatnonzero(eq), key=rid.__getitem__))
    return i


def _kern_terastal_vec(B, now, busy, idle_mask, n_idle, mode):
    """Vectorized Terastal round over the deep block mirrors.

    Bit-identical to ``_kern_terastal`` (pinned at every pow2 bucket
    boundary by ``tests/test_round_kernels.py``): every add/sub/compare
    is the same IEEE-f64 op, reductions are exact (min/max/compare
    introduce no rounding), and all tie-breaks reproduce the reference's
    first-minimum scans and strictly-greater replacement scans exactly
    (see ``_pick_first`` and the stage-2 tie handling).

    The round never materializes the stage-1 sort.  Key facts it leans
    on, each inherited from the reference semantics:

    * stage-1 feasibility of a slot on a still-idle accelerator is
      STATIC across the round — tau of an idle accelerator only changes
      when it gets assigned, which also removes it from ``idle`` — so
      per-accelerator finish columns are computed once;
    * feasibility only shrinks as ``idle`` shrinks, so "walk the sorted
      order forward, assign the first feasible slot" is exactly "pick
      the (slack, rid)-minimum feasible slot, repeat" — a masked argmin
      per assignment (<= n_idle of them) instead of an O(NJ log NJ)
      sort + O(NJ) walk;
    * stage-2 deltas are masked vector arithmetic over all remaining
      slots per idle accelerator, with the reference's replacement-scan
      tie-break (max delta, original beats variant, then earliest in
      stage-1 order == (slack, rid)-minimum among the tied).

    The dominant deep round (one freed accelerator, one assignment —
    >95% under saturation) therefore costs ~15 contiguous [NJ] vector
    ops, independent of how deep the queue is beyond them."""
    n = B.n
    nacc = len(busy)
    lat = B.lat_arr
    latv = B.latv_arr
    vdl = B.vdl_arr[:n]
    rid = B.rid
    tau = [b if b > now else now for b in busy]

    # per-accelerator finish columns at round-start tau; fmin/keys = the
    # stage-1 best-case slack (Eq. 6-7), shared with stage-2 tie-breaks
    fo = [lat[k, :n] + tau[k] for k in range(nacc)]
    fmin = np.minimum(fo[0], fo[1]) if nacc > 1 else fo[0]
    for k in range(2, nacc):
        fmin = np.minimum(fmin, fo[k])
    keys = vdl - fmin
    d_eps = vdl + 1e-15
    idle = [k for k in range(nacc) if idle_mask >> k & 1]
    oko = [fo[k] <= d_eps for k in idle]
    fv = [latv[k, :n] + tau[k] for k in idle]
    okv = [f <= d_eps for f in fv]  # +inf rows (no variant) fail naturally

    out = []
    alive = None  # "unassigned" mask, materialized on first assignment

    # ---- stage 1: most-urgent-first, meet virtual deadlines ------------
    while idle:
        feas = oko[0] | okv[0]
        for j in range(1, len(idle)):
            feas |= oko[j]
            feas |= okv[j]
        if alive is not None:
            feas &= alive
        i = _pick_first(feas, keys, rid)
        if i < 0:
            break
        # original first (lines 4-10), then variant (11-18); candidate
        # accelerator = first-minimum finish over ascending idle order
        bk = -1
        bj = -1
        bf = 0.0
        for j, k in enumerate(idle):
            if oko[j][i]:
                f = fo[k][i]
                if bk < 0 or f < bf:
                    bf, bk, bj = f, k, j
        if bk >= 0:
            use_var = False
            c = B.lat[i][bk]  # Python float, as the scalar kernel emits
        else:
            for j, k in enumerate(idle):
                if okv[j][i]:
                    f = fv[j][i]
                    if bk < 0 or f < bf:
                        bf, bk, bj = f, k, j
            use_var = True
            c = B.latv[i][bk]
        out.append((i, bk, use_var, c))
        tau[bk] += c  # round-local update (Sec. IV-C); bk leaves idle,
        del idle[bj], oko[bj], fv[bj], okv[bj]  # surviving columns exact
        if alive is None:
            alive = np.ones(n, bool)
        alive[i] = False

    # ---- stage 2: backfill remaining idle accelerators -----------------
    if idle and len(out) < n:
        if alive is None:
            alive = np.ones(n, bool)
        vn = B.vdl_next_arr[:n]
        nm = B.next_min_arr[:n]
        f0 = None  # min finish over ALL accs at CURRENT tau (lazy/cached,
        ev = None  # like the variant-row ev; both invalidate on assignment)
        for k in idle:
            if len(out) == n:
                break
            if f0 is None:
                f0 = lat[0, :n] + tau[0]
                for kk in range(1, nacc):
                    f0 = np.minimum(f0, lat[kk, :n] + tau[kk])
                s_star = vdl - f0
            tk = tau[k]
            fino = lat[k, :n] + tk
            t = vn - fino
            t -= nm
            t -= s_star  # Eq. 8-9: ((vn - finish) - nm) - s*, left-assoc
            if mode == "ef":
                # ef_all of the original row IS f0; variant rows guard
                # against their own earliest finish across ALL accs
                ok = fino <= f0 + 1e-15
                ok &= alive
            else:
                ok = alive
            do = np.where(ok, t, _NEGINF)
            cv = latv[k, :n]
            finv = cv + tk  # +inf where no variant -> delta = -inf below
            t2 = vn - finv
            t2 -= nm
            t2 -= s_star
            if mode == "ef":
                if ev is None:
                    ev = latv[0, :n] + tau[0]
                    for kk in range(1, nacc):
                        ev = np.minimum(ev, latv[kk, :n] + tau[kk])
                ok2 = finv <= ev + 1e-15
                ok2 &= np.isfinite(cv)
            else:
                ok2 = np.isfinite(cv)
            ok2 &= alive
            dv = np.where(ok2, t2, _NEGINF)
            mo = do.max()
            mv = dv.max()
            best = mo if mo >= mv else mv
            if best == _NEGINF:
                continue
            if mode == "positive" and best <= 0.0:
                continue
            # winner: max delta; ties prefer original over variant (the
            # strictly-greater (delta, -use_var) replacement), then the
            # earliest slot in stage-1 order among the tied
            if mo >= mv:
                d_sel = do
                use_var = False
            else:
                d_sel = dv
                use_var = True
            idxs = np.flatnonzero(d_sel == best)
            if len(idxs) == 1:
                i = int(idxs[0])
            else:
                i = int(min(idxs, key=lambda j: (keys[j], rid[j])))
            c = B.latv[i][k] if use_var else B.lat[i][k]
            out.append((i, k, use_var, c))
            tau[k] += c
            f0 = ev = None  # tau changed: recompute s*/ev for the next acc
            alive[i] = False
    return out


def _jax_round(B, now, busy, idle_mask, n_acc, mode):
    """One Terastal round on the jitted kernel (``REPRO_ROUND_KERNEL=jax``
    or NJ past the calibrated crossover): stage the deep mirrors into
    ``pack_arrays``'s persistent bucket buffers in ascending-rid order
    (stable argsort ties == (slack, rid)), run ``terastal_round``, and
    fetch all three outputs in one device sync.  ``assign_seq`` restores
    the reference emission order, which fixes how simultaneous finish
    events tie-break downstream."""
    SJ = _jax_mod()
    n = B.n
    perm = np.argsort(B.rid_arr[:n])
    tau = np.array([b if b > now else now for b in busy])
    idle = np.array([bool(idle_mask >> k & 1) for k in range(n_acc)])
    inp = SJ.pack_arrays(
        B.vdl_arr[:n][perm],
        B.vdl_next_arr[:n][perm],
        B.next_min_arr[:n][perm],
        B.lat_arr[:, :n].T[perm],  # mirrors are [n_acc, cap]; pack [NJ, NA]
        B.latv_arr[:, :n].T[perm],
        tau,
        idle,
    )
    o = SJ.terastal_round(inp, mode=mode)
    acc, var, seq = SJ.jax.device_get((o.assign_acc, o.assign_var, o.assign_seq))
    acc = acc[:n]
    hit = np.flatnonzero(acc >= 0)
    if not hit.size:
        return []
    emit = hit[np.argsort(seq[:n][hit])]
    out = []
    for i in emit:
        slot = int(perm[i])
        k = int(acc[i])
        uv = bool(var[i])
        row = B.latv[slot] if uv else B.lat[slot]
        out.append((slot, k, uv, row[k]))
    return out


# --------------------------------------------------------------- engine ----

_ARRIVAL, _FINISH, _TICK, _FAULT = 0, 1, 2, 3  # reference kind codes


def simulate_soa(
    plans: Sequence[ModelPlan],
    tasks: Sequence[TaskSpec],
    duration: float,
    scheduler: Scheduler,
    seed: int,
    processes: Optional[Sequence[Optional[ArrivalProcess]]],
    policy: BudgetPolicy,
    round_kernel: Optional[str] = None,
    admission: Optional[AdmissionPolicy] = None,
    fault_model: Optional[FaultModel] = None,
) -> SimResult:
    """SoA counterpart of ``_simulate_reference`` (same contract).

    ``round_kernel`` selects the Terastal round implementation for deep
    ready queues (see :data:`ROUND_KERNELS`); ``None`` falls back to the
    ``REPRO_ROUND_KERNEL`` environment variable, then ``"auto"``.

    An active ``fault_model`` forces the scalar kernels (the deep
    mirrors, the vectorized round, and the jitted round cache per-slot
    latency rows that every capability event would have to rewrite
    wholesale — even an explicit ``round_kernel="jax"`` is downgraded,
    which is bit-identical by construction, just not deep).  Fault
    events swap the hot plan tables for ``effective_plans`` copies and
    rewrite the live slot caches, so scheduling decisions match the
    reference loop float for float."""
    n_acc = plans[0].platform.n_acc
    n_plans = len(plans)
    rng_acc = range(n_acc)
    all_idle_mask = (1 << n_acc) - 1

    kind = type(scheduler)
    terastal = kind is TerastalScheduler
    if terastal:
        use_budgets = scheduler.use_budgets
        use_variants = scheduler.use_variants
        mode = scheduler.backfill_mode
        kern = kern_deep = None
    else:
        use_budgets = use_variants = False
        mode = ""
        kern = {FcfsScheduler: _kern_fcfs, EdfScheduler: _kern_edf,
                DreamScheduler: _kern_dream}[kind]
        kern_deep = _kern_dream_deep if kind is DreamScheduler else None
    need_fkey = kind is FcfsScheduler  # push-time sort keys are per-family
    need_ekey = kind is EdfScheduler
    need_pref = need_fkey or need_ekey
    policy_inert = type(policy) in _INERT_POLICIES

    # ---- round-kernel dispatch thresholds (deep-queue fast path) --------
    # "auto" (the TrialSpec default) defers to the env var, mirroring how
    # REPRO_SIM_ENGINE reaches campaign trials; an explicit python/jax
    # argument always wins.
    rk = round_kernel
    if rk is None or rk == "auto":
        rk = os.environ.get("REPRO_ROUND_KERNEL") or "auto"
    if rk not in ROUND_KERNELS:
        raise ValueError(f"unknown round kernel {rk!r} (have {ROUND_KERNELS})")
    vec_min = _vec_min()
    if terastal:
        if rk == "jax":
            jax_min = 1.0  # force the jitted round for every block round
        elif rk == "python":
            jax_min = _INF
        else:
            jax_min = round_crossover()
        deep_min = jax_min if jax_min < vec_min else vec_min
    else:
        jax_min = _INF
        deep_min = vec_min
    # crossover-INF fast path: with no finite crossover the jitted round
    # can never engage, so "auto" skips the per-round jax probe entirely
    # and is end-to-end identical to round_kernel="python" (never even
    # imports scheduler_jax — pinned by tests/test_round_kernels.py)
    jax_on = jax_min != _INF

    # hot per-plan scalar tables (cached on the plans, shared across trials)
    LAT = [p.lat_rows for p in plans]
    LATV = [p.lat_var_rows for p in plans]
    RM = [p.remaining_min_list for p in plans]
    CF = [p.crit_from_list for p in plans]  # == RM[:-1] slice on linear
    CA = [p.crit_after_list for p in plans]  # == RM[1:] slice on linear
    VDLR = [p.vdl_rel_list for p in plans]
    MINL = [p.min_lat_list for p in plans]
    SVOK = [p.single_variant_ok for p in plans]
    PREF = [p.acc_pref_rows for p in plans]
    NL = [len(p.model.layers) for p in plans]
    DEADLINE = [p.deadline for p in plans]
    LAT_NP = [p.lat for p in plans]  # ndarray rows for the deep mirrors
    LATV_NP = [p.lat_var for p in plans]

    # ---- DAG axis (``repro.core.dag``) ----------------------------------
    # A DAG plan splits one logical request over sibling ready entries
    # (one per precedence-unblocked node) sharing a ``DagRun``.  The deep
    # mirrors, the vectorized round, and the jitted round are disabled
    # for the trial (their rid-keyed sort ties and per-slot drop masks
    # assume one entry per request) — the scalar kernels carry DAG sort
    # keys totalized with the node id, matching the reference schedulers.
    DAGS = [p.dag for p in plans]
    dag_present = any(d is not None for d in DAGS)

    # ---- fault axis (``repro.core.faults``) -----------------------------
    # Same contract as the reference loop: capability events rebuild the
    # swappable tables above (LAT/LATV/RM/MINL/PREF) from
    # ``effective_plans`` — SVOK/NL/DEADLINE and ``plans`` keep serving
    # combo validity, budget hooks, and ``combo_retained``.  With
    # ``retighten=false`` VDLR and the admission work tables stay frozen
    # at offline values (the original fault axis); ``retighten=true``
    # re-runs the tightening kernel and re-derives the admission tables
    # on every capability event (see ``_fault_refresh``).  The
    # deep/vectorized/jitted fast paths are disabled for the whole trial
    # (their mirrors cache rows a fault event would have to rewrite
    # wholesale).
    fm = fault_model if fault_model is not None and fault_model.active else None
    faulted_spans = 0
    retighten = fm is not None and fm.retighten
    cur_chain: List[Optional[np.ndarray]] = [None] * n_plans
    if fm is not None:
        fault_events, faulted_spans = fm.timeline(n_acc, duration, seed)
        avail = [True] * n_acc
        fscale = [1.0] * n_acc
        cur_fin = [-1] * n_acc  # counter of each acc's valid finish event
        disp_start = [0.0] * n_acc  # in-flight dispatch: start time and the
        disp_w = [0.0] * n_acc  # wall / in-horizon busy amounts credited
        disp_h = [0.0] * n_acc
        run_var = [False] * n_acc  # did the running layer apply a variant
        resume = fm.interrupted == "resume"
        deep_min = _INF
        jax_min = _INF
        jax_on = False
    if dag_present:
        # simulate() gates non-static budget policies off for DAG plans
        # before either engine runs (faults now compose — the fault
        # handlers below are DAG-aware), so only the kernel dispatch
        # needs forcing here
        deep_min = _INF
        jax_min = _INF
        jax_on = False

    # per-model stat accumulators (dict built in reference order at the end)
    released = [0] * n_plans
    completed = [0] * n_plans
    missed = [0] * n_plans
    dropped = [0] * n_plans
    variants_applied = [0] * n_plans
    retained_sum = [0.0] * n_plans
    shed = [0] * n_plans
    in_flight = [0] * n_plans
    evicted = [0] * n_plans
    remapped = [0] * n_plans

    busy = [0.0] * n_acc  # acc_busy_until
    busy_t = [0.0] * n_acc  # acc_busy_time
    busy_h = [0.0] * n_acc  # horizon-clamped busy time

    # admission state — integer-ns backlog exactly as in the reference
    # (integer adds are order-independent, so the two engines' differing
    # within-round drop orders cannot produce divergent backlog values)
    adm = None if admission is None or type(admission) is NoAdmission else admission
    if adm is not None:
        adm.bind(n_acc)
    need_backlog = adm is not None and adm.needs_backlog
    backlog_ns = 0
    min_work_s = [p.crit_total for p in plans]
    work_ns = [int(round(w * 1e9)) for w in min_work_s]

    B = _ReadyBlock()

    # ---- event heap: exactly the reference's (time, counter, kind, pay) --
    # generate_release_events returns a sorted list, which IS a valid heap;
    # the counters 0..n_ev-1 match the reference's push order exactly.
    events, clients = generate_release_events(tasks, duration, seed, processes)
    cl_active = bool(clients)
    if cl_active:
        heap: List[tuple] = [
            (e[0], i, _ARRIVAL, e[1] if e[2] < 0 else (e[1], e[2], e[3]))
            for i, e in enumerate(events)
        ]
        MODEL_OF_TASK = [t.model_idx for t in tasks]
    else:
        heap = [(t, i, _ARRIVAL, m) for i, (t, m) in enumerate(events)]
    cnt = len(heap)
    if fm is not None:
        # capability events enter the heap after all arrivals and before
        # the tick, so same-timestamp ordering (arrival < fault < tick <
        # finish) is fixed by counters identically in both engines
        for fe in fault_events:
            heappush(heap, (fe.t, cnt, _FAULT, fe))
            cnt += 1
    if policy.tick_interval > 0 and heap:
        heappush(heap, (policy.tick_interval, cnt, _TICK, None))
        cnt += 1
    tick_dt = policy.tick_interval

    def push_release(client: Tuple[int, int], t: float) -> None:
        """Closed-loop gate: schedule the user's next release after its
        request left the system at ``t`` (counter parity: both engines
        call this at the same points in the same order)."""
        nonlocal cnt
        t_idx, u = client
        nxt = clients[t_idx].next_release(u, t)
        if nxt is not None:
            heappush(heap, (nxt, cnt, _ARRIVAL, (MODEL_OF_TASK[t_idx], t_idx, u)))
            cnt += 1

    running: List[Optional[Request]] = [None] * n_acc  # acc -> running request
    n_running = 0
    next_rid = 0
    rounds = 0  # scheduling rounds, reported on SimResult.rounds

    def _fill_vdl(n: int, req: Request, m: int, l: int) -> None:
        """Cache a slot's Terastal scalars (single source: tera_scalars)."""
        vdl, vdl_next, nm, rv = tera_scalars(req, m, l, RM[m])
        B.vdl[n] = vdl
        B.vdl_next[n] = vdl_next
        B.next_min[n] = nm
        B.latv[n] = rv
        if B.deep:
            B.vdl_arr[n] = vdl
            B.vdl_next_arr[n] = vdl_next
            B.next_min_arr[n] = nm
            B.latv_arr[:, n] = LATV_NP[m][l] if rv is not None else np.inf

    def push(req: Request) -> None:
        """Enter the ready set: cache every per-slot scalar the kernels
        and the vectorized drop read (constant while the slot lives)."""
        n = B.n
        if n == B.cap:
            B.grow()
        m = req.model_idx
        l = req.next_layer
        dl = req.deadline_abs
        rid = req.rid
        B.req[n] = req
        B.rid[n] = rid
        B.model[n] = m
        B.layer[n] = l
        B.dl[n] = dl
        mr = CF[m][l]
        B.mr[n] = mr
        dle = dl + 1e-12
        B.min_rem_arr[n] = mr
        B.dl_eps_arr[n] = dle
        g = dle - mr
        B.guard_arr[n] = g
        if g < B.guard:
            B.guard = g
        B.lat[n] = LAT[m][l]
        if need_pref:
            B.pref[n] = PREF[m][l]
            # keys carry the node id third: a no-op while rids are unique
            # (linear chains), a total order for DAG sibling entries —
            # mirrors the reference schedulers' (key, rid, next_layer)
            if need_fkey:
                B.fkey[n] = (req.arrival, rid, l)
            else:
                B.ekey[n] = (dl - CA[m][l], rid, l)
            if B.deep:
                insort(B.order_sl, B.okey[n])
                B.rid2slot[rid] = n
        elif terastal:
            if B.deep:
                B.rid_arr[n] = rid
                B.lat_arr[:, n] = LAT_NP[m][l]
            _fill_vdl(n, req, m, l)
        elif B.deep:  # DREAM
            B.rid_arr[n] = rid
            B.dl_arr[n] = dl
        B.n = n + 1

    def tera_scalars(req, m, l, rm):
        """(vdl, vdl_next, next_min, variant_row) for one ready layer —
        the single source of the Terastal per-slot derivation, consumed
        by the block cache (via ``_fill_vdl``), the solo fast path, and
        the fused chain loop (mirrors ``TerastalScheduler.vdl`` +
        ``_variant_ok`` exactly)."""
        dl = req.deadline_abs
        dg = DAGS[m]
        if dg is not None:
            # DAG node: virtual deadline of node l, then Eq. 8's binding
            # successor s* = first-min over succs of vdl(s) - min_lat(s)
            # (finish-independent, so the pair caches per slot) — mirrors
            # ``scheduler.binding_successor`` float for float
            va = req.vdl_abs
            if use_budgets:
                if va is not None:
                    vdl = float(va[l])
                else:
                    vdl = req.arrival + VDLR[m][l]
            else:
                vdl = dl - CA[m][l]
            minl = MINL[m]
            best = -1
            bv = 0.0
            for s in dg.succs[l]:
                if use_budgets:
                    vs = float(va[s]) if va is not None else req.arrival + VDLR[m][s]
                else:
                    vs = dl - CA[m][s]
                v = vs - minl[s]
                if best < 0 or v < bv:
                    bv, best = v, s
            if best >= 0:
                if use_budgets:
                    vdl_next = (
                        float(va[best]) if va is not None
                        else req.arrival + VDLR[m][best]
                    )
                else:
                    vdl_next = dl - CA[m][best]
                nm = minl[best]
            else:  # sink: s_f = deadline - finish (the - 0.0 is exact)
                vdl_next = dl
                nm = 0.0
            lv = LATV[m][l]
            rv = None
            if lv is not None and use_variants:
                ap = req.applied_variants
                if SVOK[m][l] if not ap else plans[m].is_valid_combo(ap | {l}):
                    rv = lv
            return vdl, vdl_next, nm, rv
        if use_budgets:
            va = req.vdl_abs
            if va is not None:
                vdl = float(va[l])
            else:
                vdl = req.arrival + VDLR[m][l]
        else:
            vdl = dl - rm[l + 1]
        if l + 1 < NL[m]:
            if use_budgets:
                va = req.vdl_abs
                if va is not None:
                    vdl_next = float(va[l + 1])
                else:
                    vdl_next = req.arrival + VDLR[m][l + 1]
            else:
                vdl_next = dl - rm[l + 2]
            nm = MINL[m][l + 1]
        else:
            vdl_next = dl
            nm = 0.0
        lv = LATV[m][l]
        rv = None
        if lv is not None and use_variants:
            ap = req.applied_variants
            if SVOK[m][l] if not ap else plans[m].is_valid_combo(ap | {l}):
                rv = lv
        return vdl, vdl_next, nm, rv

    def _activate_deep() -> None:
        """First deep round of the trial: build the kernel family's
        mirrors from the live slots; push/_fill_vdl/swap_remove maintain
        them incrementally from here on (deep stays on for the trial)."""
        if terastal:
            B.activate_deep_terastal(n_acc)
        elif need_pref:
            B.activate_deep_pref(need_fkey)
        else:
            B.activate_deep_dream()

    def _fault_refresh(now: float) -> None:
        """Rebuild the swappable plan tables from the current capability
        state and rewrite every live slot cache derived from them.  The
        deep mirrors are off under faults, so only the scalar caches —
        exactly the fields ``push`` derives from LAT/RM/MINL/PREF — need
        rewriting; ``B.guard`` is recomputed exactly (it may rise after
        an ``up`` event restores a fast column).  Under ``retighten``
        the virtual-deadline chains are re-derived from the effective
        tables and every in-flight request is re-bound (reference
        parity: ``refresh_tables`` in the scalar loop), the admission
        work tables are re-derived from degraded capacity, and the
        budget policy's ``on_capability`` hook fires last."""
        nonlocal LAT, LATV, RM, CF, CA, MINL, PREF, min_work_s, work_ns, solo
        eff = effective_plans(plans, fault_multipliers(fscale, avail))
        LAT = [p.lat_rows for p in eff]
        LATV = [p.lat_var_rows for p in eff]
        RM = [p.remaining_min_list for p in eff]
        CF = [p.crit_from_list for p in eff]
        CA = [p.crit_after_list for p in eff]
        MINL = [p.min_lat_list for p in eff]
        PREF = [p.acc_pref_rows for p in eff]
        if retighten:
            cur_chain[:] = retightened_vdl(plans, eff)
            for i in range(B.n):
                r = B.req[i]
                ch = cur_chain[r.model_idx]
                r.vdl_abs = None if ch is None else r.arrival + ch
            if solo is not None:
                ch = cur_chain[solo.model_idx]
                solo.vdl_abs = None if ch is None else solo.arrival + ch
            for r in running:
                if r is not None:
                    ch = cur_chain[r.model_idx]
                    r.vdl_abs = None if ch is None else r.arrival + ch
            if adm is not None:
                min_work_s, work_ns = degraded_work_tables(eff, duration)
                adm.bind(max(1, sum(avail)))
        g_min = _INF
        for i in range(B.n):
            m = B.model[i]
            l = B.layer[i]
            mr = CF[m][l]
            B.mr[i] = mr
            B.min_rem_arr[i] = mr
            g = B.dl_eps_arr[i] - mr
            B.guard_arr[i] = g
            if g < g_min:
                g_min = g
            B.lat[i] = LAT[m][l]
            if need_pref:
                B.pref[i] = PREF[m][l]
                if need_ekey:
                    B.ekey[i] = (B.dl[i] - CA[m][l], B.rid[i], l)
            elif terastal:
                _fill_vdl(i, B.req[i], m, l)
        B.guard = g_min
        if not policy_inert:
            # capability hook: same REBIND contract as ``on_tick`` —
            # materialize solo so the policy sees the whole ready set
            if solo is not None:
                push(solo)
                solo = None
            nb = B.n
            ready_list = B.req[:nb]
            before = [r.vdl_abs for r in ready_list]
            policy.on_capability(now, ready_list, plans, eff, np.array(busy))
            if terastal:
                for i in range(nb):
                    r = B.req[i]
                    if r.vdl_abs is not before[i]:
                        _fill_vdl(i, r, B.model[i], B.layer[i])

    # The single ready request, kept OUT of the block: most rounds see
    # exactly one ready layer, and for those the push/swap_remove round
    # trip through the block is pure overhead.  Invariant: ``solo`` is
    # only ever non-None while ``B.n == 0``; any event that would add a
    # second ready item materializes it into the block first (insertion
    # order — and therefore reference parity — is preserved because the
    # solo request always entered the ready set earlier).
    solo: Optional[Request] = None

    while heap:
        now, ecnt, ev, payload = heappop(heap)
        if ev == _ARRIVAL:
            if cl_active and type(payload) is tuple:
                m, t_idx, u = payload
                client = (t_idx, u)
            else:
                m = payload
                client = None
            req = Request(
                rid=next_rid,
                model_idx=m,
                arrival=now,
                deadline_abs=now + DEADLINE[m],
                client=client,
            )
            next_rid += 1
            dg = DAGS[m]
            if dg is not None:
                # one logical request, one rid, one shared DagRun; the
                # lowest source node is the representative admission judges
                req.next_layer = dg.sources[0]
                req.dag = DagRun.fresh(dg)
            if adm is not None and not adm.admit(req, now, backlog_ns, min_work_s[m]):
                # shed at the door: released+missed+dropped+shed, never
                # enters ready and the budget policy never sees it
                req.dropped = True
                released[m] += 1
                missed[m] += 1
                dropped[m] += 1
                shed[m] += 1
                if client is not None:
                    push_release(client, now)
            else:
                if not policy_inert:
                    policy.on_release(req, plans[m], now)
                if retighten and cur_chain[m] is not None:
                    # bind the retightened chain in force at release time;
                    # later capability events re-bind via ``_fault_refresh``
                    req.vdl_abs = now + cur_chain[m]
                released[m] += 1
                if need_backlog:
                    req.work_ns = work_ns[m]
                    backlog_ns += req.work_ns
                if solo is None and not B.n:
                    solo = req
                else:
                    if solo is not None:
                        push(solo)
                        solo = None
                    push(req)
                if dg is not None and len(dg.sources) > 1:
                    # sibling entries for the remaining source nodes,
                    # ascending — reference ready order
                    if solo is not None:
                        push(solo)
                        solo = None
                    for s in dg.sources[1:]:
                        push(
                            Request(
                                rid=req.rid,
                                model_idx=m,
                                arrival=now,
                                deadline_abs=req.deadline_abs,
                                next_layer=s,
                                client=client,
                                dag=req.dag,
                                vdl_abs=req.vdl_abs,
                                work_ns=req.work_ns,
                            )
                        )
        elif ev == _FINISH:
            k = payload
            if fm is not None and ecnt != cur_fin[k]:
                pass  # stale finish: its dispatch was evicted or re-timed
            else:
                req = running[k]
                running[k] = None
                n_running -= 1
                dr = req.dag
                if dr is not None:
                    # DAG node finish: no layer increment — the entry IS
                    # one node.  A dropped request's still-running sibling
                    # finishes as a no-op (busy time already accrued; the
                    # drop was counted once at drop time).
                    if not dr.dropped:
                        m = req.model_idx
                        dg = DAGS[m]
                        node = req.next_layer
                        dr.n_done += 1
                        if node == dg.sink:
                            # every node is an ancestor of the unique
                            # sink, so sink finish == request completion
                            req.done_time = now
                            completed[m] += 1
                            if now > req.deadline_abs + 1e-12:
                                missed[m] += 1
                            retained_sum[m] += plans[m].combo_retained(
                                dr.applied_variants
                            )
                            if need_backlog:
                                backlog_ns -= req.work_ns
                            if req.client is not None:
                                push_release(req.client, now)
                        else:
                            for s in dg.succs[node]:
                                dr.pending[s] -= 1
                                if dr.pending[s] == 0:
                                    nr = Request(
                                        rid=req.rid,
                                        model_idx=m,
                                        arrival=req.arrival,
                                        deadline_abs=req.deadline_abs,
                                        next_layer=s,
                                        applied_variants=dr.applied_variants,
                                        client=req.client,
                                        dag=dr,
                                        vdl_abs=req.vdl_abs,
                                        work_ns=req.work_ns,
                                    )
                                    if solo is None and not B.n:
                                        solo = nr
                                    else:
                                        if solo is not None:
                                            push(solo)
                                            solo = None
                                        push(nr)
                else:
                    req.next_layer += 1
                    if fm is not None:
                        req.layer_frac = 0.0
                    m = req.model_idx
                    if req.next_layer >= NL[m]:
                        req.done_time = now
                        completed[m] += 1
                        if now > req.deadline_abs + 1e-12:
                            missed[m] += 1
                        retained_sum[m] += plans[m].combo_retained(req.applied_variants)
                        if need_backlog:
                            backlog_ns -= req.work_ns
                        if req.client is not None:
                            push_release(req.client, now)
                    else:
                        if not policy_inert:
                            policy.on_layer_finish(req, plans[m], req.next_layer - 1, now)
                        if solo is None and not B.n:
                            solo = req
                        else:
                            if solo is not None:
                                push(solo)
                                solo = None
                            push(req)
        elif ev == _FAULT:
            fe = payload
            k = fe.acc
            if fe.code == "down":
                avail[k] = False
                r = running[k]
                if r is not None:
                    # undo the dispatch: variant bookkeeping, un-run busy
                    # time; carry layer progress under ``resume``; then
                    # re-enter the ready set for re-mapping (entry order
                    # matches the reference's ``ready.append``)
                    running[k] = None
                    n_running -= 1
                    dr = r.dag
                    run_dropped = dr is not None and dr.dropped
                    if run_var[k]:
                        r.applied_variants = r.applied_variants - {r.next_layer}
                        variants_applied[r.model_idx] -= 1
                        if dr is not None:
                            # retract from the shared DagRun and refresh
                            # the live siblings' snapshots (their cached
                            # scalars are rebuilt by ``_fault_refresh``)
                            dr.applied_variants = dr.applied_variants - {
                                r.next_layer
                            }
                            for i2 in range(B.n):
                                r2 = B.req[i2]
                                if r2.dag is dr:
                                    r2.applied_variants = dr.applied_variants
                            if solo is not None and solo.dag is dr:
                                solo.applied_variants = dr.applied_variants
                    fin_old = busy[k]
                    t0 = disp_start[k]
                    if resume and fin_old > t0:
                        r.layer_frac = r.layer_frac + (1.0 - r.layer_frac) * (
                            (now - t0) / (fin_old - t0)
                        )
                    else:
                        r.layer_frac = 0.0
                    dw, dh = evict_busy_adjust(t0, now, duration, disp_w[k], disp_h[k])
                    busy_t[k] += dw
                    busy_h[k] += dh
                    if not run_dropped:
                        # a dropped DagRun's evicted node is not re-mapped:
                        # the drop was already counted once at drop time
                        r.evicted_pending = True
                        evicted[r.model_idx] += 1
                        if solo is None and not B.n:
                            solo = r
                        else:
                            if solo is not None:
                                push(solo)
                                solo = None
                            push(r)
                busy[k] = _INF  # down == busy forever
                cur_fin[k] = -1
            elif fe.code == "up":
                avail[k] = True
                busy[k] = now
            else:  # scale: throttle multiplier transition
                old = fscale[k]
                fscale[k] = fe.value
                if running[k] is not None and fe.value != old:
                    # re-time the in-flight layer: remaining wall time
                    # stretches (or shrinks) by new_scale / old_scale
                    fin_old = busy[k]
                    fin_new = now + (fin_old - now) * (fe.value / old)
                    busy[k] = fin_new
                    dw, dh, disp_w[k], disp_h[k] = retime_busy_adjust(
                        disp_start[k], fin_new, duration, disp_w[k], disp_h[k]
                    )
                    busy_t[k] += dw
                    busy_h[k] += dh
                    heappush(heap, (fin_new, cnt, _FINISH, k))
                    cur_fin[k] = cnt
                    cnt += 1
            _fault_refresh(now)
        else:  # _TICK
            if solo is not None:
                push(solo)
                solo = None
            nb = B.n
            ready_list = B.req[:nb]
            before = [r.vdl_abs for r in ready_list]
            policy.on_tick(now, ready_list, plans, np.array(busy))
            if terastal:
                # a policy signals a chain update by REBINDING vdl_abs;
                # refresh the cached virtual-deadline scalars it touched
                for i in range(nb):
                    r = B.req[i]
                    if r.vdl_abs is not before[i]:
                        _fill_vdl(i, r, B.model[i], B.layer[i])
            if heap:  # keep ticking only while real events remain
                heappush(heap, (now + tick_dt, cnt, _TICK, None))
                cnt += 1

        # ---- batch simultaneous events before scheduling -----------------
        if heap and -1e-15 < heap[0][0] - now < 1e-15:
            continue

        # ---- scheduling round --------------------------------------------
        rounds += 1
        if solo is not None:
            # single-ready fast path: decide straight from the plan tables
            req = solo
            m = req.model_idx
            l = req.next_layer
            if now + CF[m][l] > req.deadline_abs + 1e-12:  # early-drop
                req.dropped = True
                if req.dag is not None:
                    # running siblings may exist: their finishes no-op
                    req.dag.dropped = True
                missed[m] += 1
                dropped[m] += 1
                if need_backlog:
                    backlog_ns -= req.work_ns
                if req.client is not None:
                    push_release(req.client, now)
                solo = None
                continue
            eps_now = now + 1e-15
            idle_mask = 0
            n_idle = 0
            for k in rng_acc:
                if busy[k] <= eps_now:
                    idle_mask |= 1 << k
                    n_idle += 1
            if not n_idle:
                continue
            if need_pref:  # FCFS/EDF: first idle accelerator by preference
                row = LAT[m][l]
                for k in PREF[m][l]:
                    if idle_mask >> k & 1:
                        c = row[k]
                        break
                use_var = False
            elif not terastal:  # DREAM: earliest estimated finish
                row = LAT[m][l]
                bk = -1
                bc = 0.0
                for k in rng_acc:
                    if idle_mask >> k & 1:
                        b = busy[k]
                        f = (b if b > now else now) + row[k]
                        if bk < 0 or f < bc:
                            bc, bk = f, k
                k = bk
                c = row[k]
                use_var = False
            else:  # Terastal: scalar single-layer round
                vdl, vdl_next, nm, rv = tera_scalars(req, m, l, RM[m])
                got = _solo_terastal(LAT[m][l], rv, vdl, vdl_next, nm,
                                     now, busy, idle_mask, n_acc, mode)
                if got is None:
                    continue  # cannot place within budget: stays solo
                k, use_var, c = got
            solo = None
            lay = l
        else:
            n = B.n
            if n and now > B.guard - 1e-9:
                # within the safety margin of the earliest possible drop:
                # run the exact masked compare (same floats as reference)
                drop_mask = now + B.min_rem_arr[:n] > B.dl_eps_arr[:n]
                if drop_mask.any():
                    dropped_clients: List[Tuple[int, int]] = []
                    if dag_present:
                        # reference drop-once semantics: one hopeless entry
                        # of a DAG request is its counted representative;
                        # every sibling entry (hopeless or not) is swept
                        # uncounted.  The dropped SET — and therefore every
                        # counter — is iteration-order independent.
                        for i in np.flatnonzero(drop_mask):
                            i = int(i)
                            r = B.req[i]
                            dr2 = r.dag
                            if dr2 is not None:
                                if dr2.dropped:
                                    continue  # sibling already counted
                                dr2.dropped = True
                            r.dropped = True
                            m = B.model[i]
                            missed[m] += 1
                            dropped[m] += 1
                            if need_backlog:
                                backlog_ns -= r.work_ns
                            if r.client is not None:
                                dropped_clients.append(r.client)
                        # sweep descending so swap_remove never moves an
                        # unexamined live slot (drop_mask indices < i stay
                        # valid throughout)
                        for i in range(n - 1, -1, -1):
                            r = B.req[i]
                            if (
                                r.dag.dropped
                                if r.dag is not None
                                else bool(drop_mask[i])
                            ):
                                r.dropped = True
                                B.swap_remove(i)
                    else:
                        for i in np.flatnonzero(drop_mask)[::-1]:
                            i = int(i)
                            r = B.req[i]
                            r.dropped = True
                            m = B.model[i]
                            missed[m] += 1
                            dropped[m] += 1
                            if need_backlog:
                                backlog_ns -= r.work_ns
                            if r.client is not None:
                                dropped_clients.append(r.client)
                            B.swap_remove(i)
                    n = B.n
                    if dropped_clients:
                        # canonical per-round release order (sorted by
                        # client): the reference drops the same SET in
                        # ready-insertion order, so both engines sort the
                        # release pushes to keep event counters identical
                        dropped_clients.sort()
                        for cl in dropped_clients:
                            push_release(cl, now)
                B.guard = float(B.guard_arr[:n].min()) if n else _INF
            if not n:
                continue
            eps_now = now + 1e-15
            idle_mask = 0
            n_idle = 0
            for k in rng_acc:
                if busy[k] <= eps_now:
                    idle_mask |= 1 << k
                    n_idle += 1
            if not n_idle:
                continue
            if n >= deep_min and not B.deep:
                _activate_deep()
            if terastal:
                if jax_on and n >= jax_min:
                    out = _jax_round(B, now, busy, idle_mask, n_acc, mode)
                elif B.deep and n >= vec_min:
                    out = _kern_terastal_vec(B, now, busy, idle_mask, n_idle, mode)
                else:
                    out = _kern_terastal(B, now, busy, idle_mask, n_idle, mode)
            elif B.deep:
                if kern_deep is not None and n >= vec_min:
                    out = kern_deep(B, now, busy, idle_mask, n_idle)
                elif need_pref:
                    out = _kern_pref_deep(B, idle_mask, n_idle)
                else:
                    out = kern(B, now, busy, idle_mask, n_idle)
            else:
                out = kern(B, now, busy, idle_mask, n_idle)
            if not out:
                continue
            # apply in reference order: the emit order fixes the finish-
            # event push counters (how simultaneous finishes tie-break)
            if len(out) > 1:
                for slot, k, use_var, c in out:
                    req = B.req[slot]
                    if use_var:
                        lay2 = B.layer[slot]
                        req.applied_variants = req.applied_variants | {lay2}
                        variants_applied[req.model_idx] += 1
                        dr = req.dag
                        if dr is not None:
                            # request-wide set lives on the DagRun; live
                            # sibling entries (still in the block — slots
                            # are removed after this loop) refresh their
                            # snapshot AND their cached variant row
                            dr.applied_variants = dr.applied_variants | {lay2}
                            for i2 in range(B.n):
                                r2 = B.req[i2]
                                if r2 is not req and r2.dag is dr:
                                    r2.applied_variants = dr.applied_variants
                                    _fill_vdl(i2, r2, B.model[i2], B.layer[i2])
                    if fm is not None:
                        if req.evicted_pending:
                            req.evicted_pending = False
                            remapped[req.model_idx] += 1
                        if req.layer_frac > 0.0:
                            # resume policy: only the un-executed remainder
                            # of the interrupted layer runs
                            c = c * (1.0 - req.layer_frac)
                    busy[k] = now + c
                    busy_t[k] += c
                    rem = duration - now
                    hh = c if c <= rem else (rem if rem > 0.0 else 0.0)
                    busy_h[k] += hh
                    running[k] = req
                    n_running += 1
                    heappush(heap, (now + c, cnt, _FINISH, k))
                    if fm is not None:
                        cur_fin[k] = cnt
                        run_var[k] = use_var
                        disp_start[k] = now
                        disp_w[k] = c
                        disp_h[k] = hh
                    cnt += 1
                slots = [s for s, _, _, _ in out]
                slots.sort(reverse=True)  # swap-remove must not move live slots
                for slot in slots:
                    B.swap_remove(slot)
                continue
            slot, k, use_var, c = out[0]
            req = B.req[slot]
            lay = B.layer[slot]
            B.swap_remove(slot)

        # ---- apply the single assignment; maybe enter the fused chain ----
        if use_var:
            req.applied_variants = req.applied_variants | {lay}
            variants_applied[req.model_idx] += 1
            dr = req.dag
            if dr is not None:
                dr.applied_variants = dr.applied_variants | {lay}
                for i2 in range(B.n):
                    r2 = B.req[i2]
                    if r2.dag is dr:
                        r2.applied_variants = dr.applied_variants
                        _fill_vdl(i2, r2, B.model[i2], B.layer[i2])
        if fm is not None:
            if req.evicted_pending:
                req.evicted_pending = False
                remapped[req.model_idx] += 1
            if req.layer_frac > 0.0:
                c = c * (1.0 - req.layer_frac)
        fin = now + c
        busy[k] = fin
        busy_t[k] += c
        rem = duration - now  # min(c, max(0.0, rem)) without the C calls
        hh = c if c <= rem else (rem if rem > 0.0 else 0.0)
        busy_h[k] += hh
        # -- fused uncontended chain: this request is alone in the system
        # and nothing interrupts before its layer finishes — advance it
        # layer-by-layer with no event-queue traffic.
        if (
            policy_inert
            and fm is None  # fault events must interrupt the chain
            and not dag_present  # the chain loop advances layers linearly
            and not n_running
            and not B.n
            and (not heap or heap[0][0] > fin + 1e-15)
        ):
            m = req.model_idx
            rm = RM[m]
            L = NL[m]
            fin_cnt = cnt
            cnt += 1
            alive = True
            while True:
                now = fin
                req.next_layer += 1
                l = req.next_layer
                rounds += 1  # the round at this finish timestamp
                if l >= L:  # chain complete (its empty-ready round still runs)
                    req.done_time = now
                    completed[m] += 1
                    if now > req.deadline_abs + 1e-12:
                        missed[m] += 1
                    retained_sum[m] += plans[m].combo_retained(req.applied_variants)
                    if need_backlog:
                        backlog_ns -= req.work_ns
                    if req.client is not None:
                        # counter parity: the last layer's finish consumed
                        # fin_cnt == cnt-1, so the release push takes the
                        # same counter the reference allocates for it
                        push_release(req.client, now)
                    alive = False
                    break
                if now + rm[l] > req.deadline_abs + 1e-12:  # early-drop
                    req.dropped = True
                    missed[m] += 1
                    dropped[m] += 1
                    if need_backlog:
                        backlog_ns -= req.work_ns
                    if req.client is not None:
                        push_release(req.client, now)
                    alive = False
                    break
                # decide via the shared kernels on the 1-slot scratch block
                # (all accelerators idle, tau uniform == now)
                if need_pref:
                    k = PREF[m][l][0]  # all idle: first preference wins
                    c = LAT[m][l][k]
                    use_var = False
                elif not terastal:  # DREAM, all idle: first-min of now + c_k
                    row = LAT[m][l]
                    bk = 0
                    bc = now + row[0]
                    for kk in range(1, n_acc):
                        f = now + row[kk]
                        if f < bc:
                            bc, bk = f, kk
                    k = bk
                    c = row[k]
                    use_var = False
                else:
                    vdl, vdl_next, nm, rv = tera_scalars(req, m, l, rm)
                    got = _solo_terastal(LAT[m][l], rv, vdl, vdl_next, nm,
                                         now, busy, all_idle_mask, n_acc, mode)
                    if got is None:  # cannot place within budget: leave fused
                        solo = req
                        alive = False
                        break
                    k, use_var, c = got
                    if use_var:
                        req.applied_variants = req.applied_variants | {l}
                        variants_applied[m] += 1
                fin = now + c
                busy[k] = fin
                busy_t[k] += c
                rem = duration - now
                busy_h[k] += c if c <= rem else (rem if rem > 0.0 else 0.0)
                fin_cnt = cnt
                cnt += 1
                if heap and heap[0][0] <= fin + 1e-15:
                    break  # interrupted: materialize and rejoin the loop
            if alive:
                running[k] = req
                n_running += 1
                heappush(heap, (fin, fin_cnt, _FINISH, k))
            continue
        running[k] = req
        n_running += 1
        heappush(heap, (fin, cnt, _FINISH, k))
        if fm is not None:
            cur_fin[k] = cnt
            run_var[k] = use_var
            disp_start[k] = now
            disp_w[k] = c
            disp_h[k] = hh
        cnt += 1

    # Horizon drain: a DAG request may be split over several sibling
    # entries (ready and/or running) — count the logical request once,
    # and not at all if it was already counted dropped.
    seen_runs: set = set()

    def drain_in_flight(r: Request) -> None:
        if r.dag is None:
            in_flight[r.model_idx] += 1
        elif not r.dag.dropped and id(r.dag) not in seen_runs:
            seen_runs.add(id(r.dag))
            in_flight[r.model_idx] += 1

    for i in range(B.n):
        drain_in_flight(B.req[i])
    if solo is not None:
        drain_in_flight(solo)
    for r in running:
        if r is not None:
            drain_in_flight(r)

    stats: Dict[int, ModelStats] = {t.model_idx: ModelStats() for t in tasks}
    for m in stats:
        stats[m] = ModelStats(
            released=released[m],
            completed=completed[m],
            missed=missed[m],
            dropped=dropped[m],
            retained_sum=retained_sum[m],
            variants_applied=variants_applied[m],
            shed=shed[m],
            in_flight=in_flight[m],
            evicted=evicted[m],
            remapped=remapped[m],
        )
    return SimResult(
        duration=duration,
        per_model=stats,
        acc_busy_time=np.array(busy_t),
        scheduler_name=scheduler.name,
        acc_busy_in_horizon=np.array(busy_h),
        rounds=rounds,
        faulted_spans=faulted_spans,
    )

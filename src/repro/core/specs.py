"""Tiny call-style spec strings for grid dimensions.

Campaign grids name their axes with strings — ``"poisson"``,
``"mmpp(burstiness=4,on_fraction=0.2)"``, ``"terastal(backfill_mode=paper)"``
— so trial specs stay picklable (process-pool workers) and printable
(result rows).  This module parses that one shape: ``name`` or
``name(key=value, ...)`` with bool/int/float/str literals.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Tuple

_SPEC_RE = re.compile(r"^\s*([A-Za-z_][\w.-]*)\s*(?:\((.*)\))?\s*$")


def _parse_literal(text: str) -> Any:
    t = text.strip()
    low = t.lower()
    if low in ("true", "false"):
        return low == "true"
    if low in ("none", "null"):
        return None
    try:
        return int(t)
    except ValueError:
        pass
    try:
        return float(t)
    except ValueError:
        pass
    return t.strip("\"'")


def parse_call_spec(spec: str) -> Tuple[str, Dict[str, Any]]:
    """``"name"`` or ``"name(k=v, ...)"`` -> ``(name, {k: v, ...})``."""
    m = _SPEC_RE.match(spec)
    if not m or (m.group(2) is None and "(" in spec):
        raise ValueError(f"malformed spec {spec!r}; expected 'name' or 'name(k=v, ...)'")
    name, argstr = m.group(1), m.group(2)
    kwargs: Dict[str, Any] = {}
    if argstr and ("(" in argstr or ")" in argstr):
        # greedy (.*) would swallow stray parens ("periodic(jitter=0.5))")
        # into a string value and defer the crash deep into a pool worker
        raise ValueError(f"malformed spec {spec!r}: unbalanced or nested parentheses")
    if argstr and argstr.strip():
        for part in argstr.split(","):
            if "=" not in part:
                raise ValueError(f"malformed spec {spec!r}: argument {part!r} is not key=value")
            k, v = part.split("=", 1)
            kwargs[k.strip()] = _parse_literal(v)
    return name, kwargs


def format_call_spec(name: str, kwargs: Dict[str, Any]) -> str:
    if not kwargs:
        return name
    inner = ",".join(f"{k}={v}" for k, v in sorted(kwargs.items()))
    return f"{name}({inner})"


def format_dag_edges(preds) -> str:
    """Compact positional edge spec for a layer DAG's predecessor lists:
    nodes joined with ``;``, each node's predecessor ids joined with
    ``,``, a source left empty — ``((), (0,), (0,), (1, 2))`` becomes
    ``";0;0;1,2"``.  Printable in result rows and benchmark labels the
    same way call specs are."""
    return ";".join(",".join(str(p) for p in ps) for ps in preds)


def parse_dag_edges(spec: str) -> Tuple[Tuple[int, ...], ...]:
    """Inverse of :func:`format_dag_edges` (structure only — acyclicity
    and id validation happen in ``repro.core.dag.LayerDag``)."""
    out = []
    for l, part in enumerate(spec.split(";")):
        part = part.strip()
        try:
            out.append(
                tuple(int(p) for p in part.split(",")) if part else ()
            )
        except ValueError:
            raise ValueError(
                f"malformed DAG edge spec {spec!r}: node {l} part {part!r} "
                "is not a comma-separated id list"
            ) from None
    return tuple(out)

"""Device-resident mega-batched trial engine: B seeds, ONE device program.

The third engine behind ``simulate()`` (after the reference event loop
and the SoA engine) and the first one where the JAX path wins on CPU:
instead of jitting a single scheduler round (PR 5's honest negative —
~1ms dispatch per round, crossover INF), the WHOLE trial event loop runs
on device as a jitted ``lax.while_loop``, ``vmap``-ed across the seed
axis.  One host sync per trial *batch* instead of one per round — the
amortization ROADMAP item 4 calls for.

How it stays bit-identical to the reference engine
--------------------------------------------------
* **Events.**  Open-loop arrivals are pre-generated per seed on the host
  (``workload.batch_release_events`` — the exact per-seed variate
  streams) and staged seed-major into pow2 (B, NR) bucket buffers
  (``scheduler_jax.pack_trials``).  In the reference heap, arrival
  counters 0..n_ev-1 are assigned in sorted-stream order and every
  finish counter is larger, so (a) arrivals pop in stream order — the
  arrival index IS the rid, giving slot == rid on device — and (b) an
  arrival always beats a same-time finish.  Outstanding finishes are at
  most one per accelerator, so the heap reduces to per-accelerator
  ``(fin_t, fin_cnt)`` slots: pop = lexicographic (time, counter) min
  with arrivals winning time ties.
* **Rounds.**  The per-round kernels transcribe ``engine_soa``'s
  vectorized round (``_kern_terastal_vec``) and the reference
  FCFS/EDF/DREAM walks op-for-op in jnp: same IEEE-f64 adds/subs/
  compares, first-minimum argmins (slot == rid makes ``argmin``'s
  first-occurrence rule the rid tie-break), the stage-2 strictly-greater
  replacement scan, and reference emission order (stage-1 pick order
  then stage-2 ascending k) so finish-event counters tie-break
  identically.
* **Accounting.**  Per-request state lives in parallel device arrays
  (the SoA layout lifted wholesale into jnp); per-model counters are
  integer reductions on the host afterwards.  ``retained_sum`` is
  re-accumulated on the host in completion order by replaying each
  completed request's variant-application sequence through the same
  frozenset unions and ``ModelPlan.combo_retained`` calls the reference
  performs — CPython set iteration order and float accumulation order
  included — so the float sums are bit-equal, not just close.

Speculation and its host-side validation
----------------------------------------
The device program is a speculative rollout of the *entire* event
horizon: it assumes every event is either a pre-generated arrival or a
finish of its own making.  ``simulate_batch`` validates that assumption
twice — statically, by rejecting any axis that could inject events the
speculation cannot cover (closed-loop release coupling, admission
policies, non-inert budget policies, custom schedulers) with the named
:class:`BatchUnsupportedError`, and dynamically, by checking the
returned ``drained`` flag (every lane consumed its horizon within the
exact event-count bound).  Unsupported axes NEVER silently fall back —
callers choose the scalar engines explicitly.

Fault injection (``restart`` interrupted-work policy)
-----------------------------------------------------
Capability events don't break the speculation: a fault timeline is
seed-deterministic, so the host pre-binds it as a time-indexed epoch
schedule (``scheduler_jax.pack_fault_epochs``) — the event stream plus,
per epoch, the latency multiplier and every capability-derived table
(re-tightened virtual-deadline chains under ``retighten=true``).  On
device the lane tracks an epoch cursor, evicts/re-times in-flight
layers op-for-op (``evict_busy_adjust``/``retime_busy_adjust``
replicated in jnp, exact variant undo via a saved pre-apply retained
product), and replays orphaned finish events as *ghost* pops, because
the scalar engines' stale heap pops still trigger scheduling rounds.
Only ``interrupted="resume"`` stays rejected: fractional layer progress
re-times re-dispatches mid-rollout, which pre-bound epochs cannot
express.

Known exactness hazard (documented, not observed): the device-side
variant-combination validity check accumulates the retained-accuracy
product incrementally in application order, while the reference
recomputes it from scratch in frozenset iteration order.  Products of
<= 2 factors are bit-equal (IEEE multiplication is commutative); with
>= 3 applied variants a different association order could differ by an
ulp and flip the ``>= theta`` verdict if the product lands within an
ulp of theta.  The pinned differential grid (tests/test_engine_batch.py)
would catch it; ``retained_sum`` itself is immune (host replay above).
"""

from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.scheduler import (
    DreamScheduler,
    EdfScheduler,
    FcfsScheduler,
    Scheduler,
    TerastalScheduler,
)
from repro.core.simulator import (
    ArrivalProcess,
    ClosedLoopClients,
    DEFAULT_ARRIVAL,
    ModelStats,
    SimResult,
    TaskSpec,
)
from repro.core.variants import ModelPlan

# Pulls in jax and enables x64 process-wide (bit-parity requires f64).
from repro.core import scheduler_jax
from repro.core.scheduler_jax import jax, jnp

lax = jax.lax

_INF = float("inf")


class BatchUnsupportedError(ValueError):
    """A simulation axis the batched engine does not cover.

    Raised by :func:`simulate_batch` validation — never a silent
    fallback.  The message names the axis; use ``engine="soa"`` /
    ``engine="reference"`` (or ``engine="auto"``) for these cells.
    """


class _Tables(NamedTuple):
    """Shared per-model device tables (broadcast across the seed axis)."""

    lat: "jnp.ndarray"     # [M, LP, NA] original latencies, +inf pad
    latv: "jnp.ndarray"    # [M, LP, NA] variant latencies, +inf where none
    vdlr: "jnp.ndarray"    # [M, LP+1]  relative virtual deadlines (pad 0)
    rm: "jnp.ndarray"      # [M, LP+2]  remaining-min suffix sums (pad 0)
    minl: "jnp.ndarray"    # [M, LP]    per-layer min latency (pad 0)
    nl: "jnp.ndarray"      # [M] i32    layer counts
    factor: "jnp.ndarray"  # [M, LP]    per-variant retained factor (pad 0)
    hasv: "jnp.ndarray"    # [M, LP] bool  layer has a variant
    theta: "jnp.ndarray"   # [M]


class _Out(NamedTuple):
    """Per-lane device outputs fetched in the single host sync."""

    state: "jnp.ndarray"     # [B, NR] final status: 3 completed / 4 dropped
    #                          / 0 still ready/running (or unreleased)
    missed: "jnp.ndarray"    # [B, NR] bool
    app_seq: "jnp.ndarray"   # [B, NR, LP] application order index, -1 unused
    app_cnt: "jnp.ndarray"   # [B, NR] i32 variants applied per request
    done_seq: "jnp.ndarray"  # [B, NR] global completion order, -1 if not
    busy_t: "jnp.ndarray"    # [B, NA]
    busy_h: "jnp.ndarray"    # [B, NA]
    rounds: "jnp.ndarray"    # [B] i32
    drained: "jnp.ndarray"   # [B] bool — horizon fully consumed
    evict_cnt: "jnp.ndarray"  # [B, NR] i32 in-flight evictions (faults)
    remap_cnt: "jnp.ndarray"  # [B, NR] i32 post-eviction re-dispatches


def _build_tables(plans: Sequence[ModelPlan]) -> Tuple[_Tables, int, int]:
    """Numpy-precompute the per-model tables; returns (tables, LP, NA)."""
    from repro.core.accuracy import combo_retained_fraction

    M = len(plans)
    NA = plans[0].platform.n_acc
    LP = max(len(p.model.layers) for p in plans)
    lat = np.full((M, LP, NA), np.inf)
    latv = np.full((M, LP, NA), np.inf)
    vdlr = np.zeros((M, LP + 1))
    rm = np.zeros((M, LP + 2))
    minl = np.zeros((M, LP))
    nl = np.zeros(M, np.int32)
    factor = np.zeros((M, LP))
    hasv = np.zeros((M, LP), bool)
    theta = np.zeros(M)
    for m, p in enumerate(plans):
        L = len(p.model.layers)
        nl[m] = L
        lat[m, :L] = p.lat
        latv[m, :L] = p.lat_var
        vdlr[m, :L] = p.vdl_rel
        rm[m, : L + 1] = p.remaining_min
        minl[m, :L] = p.min_lat
        theta[m] = p.theta
        for l, v in p.variants.items():
            hasv[m, l] = True
            factor[m, l] = combo_retained_fraction((v.loss,))
    t = _Tables(
        lat=jnp.asarray(lat), latv=jnp.asarray(latv), vdlr=jnp.asarray(vdlr),
        rm=jnp.asarray(rm), minl=jnp.asarray(minl),
        nl=jnp.asarray(nl), factor=jnp.asarray(factor),
        hasv=jnp.asarray(hasv), theta=jnp.asarray(theta),
    )
    return t, LP, NA


@functools.partial(
    jax.jit,
    static_argnames=(
        "kind", "mode", "use_budgets", "use_variants", "na", "lp", "faulted",
    ),
)
def _run_trials(
    T: _Tables,
    arr_t, arr_m, dl, dl12, n_ev,  # [B, NR+1], [B, NR], [B, NR], [B, NR], [B]
    duration, max_it,
    # fault lane (dummy minimal arrays when ``faulted=False``): the
    # pre-bound capability timeline — per-lane event stream plus the
    # time-indexed epoch planes (scheduler_jax.pack_fault_epochs)
    fe_t, fe_acc, fe_code, fe_val, n_f,  # [B,NF+1],[B,NF],[B,NF],[B,NF],[B]
    mult_ep,  # [B, NF+1, NA]
    vdlr_ep,  # [B, NF+1, M, LP+1]
    rm_ep,    # [B, NF+1, M, LP+2]
    minl_ep,  # [B, NF+1, M, LP]
    *, kind: str, mode: str, use_budgets: bool, use_variants: bool,
    na: int, lp: int, faulted: bool = False,
) -> _Out:
    """The whole-trial device program: vmap(lane while_loop) over seeds.

    Compiles once per ((B, NR) shape bucket x scheduler config) — pinned
    via ``_run_trials._cache_size()`` by the compilation-counter test.
    jax's batched ``while_loop`` masks carry updates for lanes whose
    predicate is already false, so lanes drain independently; the loop
    runs until the slowest lane finishes.

    Request slot == rid == arrival-stream index, so ``argmin``'s
    first-occurrence rule IS the reference's rid tie-break.  (A ring-
    window variant — per-round state in a ``rid % W`` ring so kernels
    scan O(W) instead of O(NR) slots — was tried and reverted: the
    saturation family keeps requests live for nearly their whole
    deadline, so the window that avoids reuse-overflow is ~NR anyway,
    and the explicit two-phase rid tie-breaks it forces cost more than
    the width they save.)
    """
    NA, LP = na, lp
    NR = arr_m.shape[-1]
    NF = fe_acc.shape[-1]
    I32 = jnp.int32

    class St(NamedTuple):
        ai: object; it: object; cnt: object; rounds: object; done_ctr: object
        state: object; layer: object
        c_lat: object; c_latv: object
        c_vdl: object; c_vdln: object; c_nm: object; c_rm: object; c_ek: object
        ret: object; app_seq: object; app_cnt: object
        missed: object; done_seq: object
        busy: object; busy_t: object; busy_h: object
        fin_t: object; fin_cnt: object; run_req: object
        # fault lane (zero-cost placeholders when ``faulted=False``):
        # epoch cursor, per-acc throttle state, ghost-finish slots (stale
        # heap entries the scalar engines pop as no-ops — their pops
        # still trigger rounds, so the device must reproduce them), the
        # in-flight dispatch bookkeeping eviction needs to undo, and the
        # per-request eviction/remap counters
        fi: object; fscale: object
        gh_t: object; gh_cnt: object; gh_n: object
        disp_t0: object; disp_w: object; disp_h: object
        run_uv: object; run_prev_ret: object
        ev_pend: object; evict_cnt: object; remap_cnt: object

    def one_lane(at, am, d_abs, d_eps12, ne,
                 fe_t, fe_acc, fe_code, fe_val, nf,
                 MULT_EP, VDLR_EP, RM_EP, MINL_EP):
        # State updates are ONE-HOT PREDICATED SELECTS, not scatters: a
        # single-row write becomes ``where(arange == idx, val, arr)`` with
        # an out-of-range sentinel index meaning "masked, write nothing".
        # Two earlier drafts were 2-3x slower end to end: lax.cond +
        # whole-carry tree-selects (vmap executes both branches and copies
        # the full ~65KB/lane carry per select), then ``.at[idx].set(...,
        # mode="drop")`` scatters (bit-correct, but a vmapped scatter
        # lowers to a slow per-row loop on CPU, and the body had ~65 of
        # them).  One-hot selects fuse into the surrounding elementwise
        # work; only the [NR, LP] variant-sequence table keeps a real
        # scatter (a 2D one-hot mask would touch NR*LP lanes per pick).
        NRi = jnp.asarray(NR, I32)  # sentinel: matches no row
        NAi = jnp.asarray(NA, I32)
        NFi = jnp.asarray(NF, I32)
        IMAXi = jnp.asarray(jnp.iinfo(I32).max, I32)
        NRa = jnp.arange(NR, dtype=I32)
        NAa = jnp.arange(NA, dtype=I32)
        NFa = jnp.arange(NF, dtype=I32)

        # -- per-event row bind: request r becomes ready at layer l ---------
        def bind(st: St, pred, r, l, m):
            a = at[r]
            dr = d_abs[r]
            lat_row = T.lat[m, l]
            if use_variants:
                # LayerVariantFeasible at push time (static while ready):
                # empty-combo / singleton cases are exact; see the module
                # docstring for the >= 3-variant ulp hazard.
                vok = T.hasv[m, l] & (st.ret[r] * T.factor[m, l] >= T.theta[m])
                latv_row = jnp.where(vok, T.latv[m, l], _INF)
            else:
                latv_row = jnp.full((NA,), _INF)
            has_next = (l + 1) < T.nl[m]
            if use_budgets:
                vdl = a + T.vdlr[m, l]
                vdln = jnp.where(has_next, a + T.vdlr[m, l + 1], dr)
            else:
                vdl = dr - T.rm[m, l + 1]
                vdln = jnp.where(has_next, dr - T.rm[m, l + 2], dr)
            nm = jnp.where(has_next, T.minl[m, l + 1], 0.0)
            rb = jnp.where(pred, r, NRi)
            hit = NRa == rb
            # the two [NR, NA] cache planes: one-hot select rewrites the
            # whole plane (cheap while it fits in cache), a row scatter
            # writes 3 elements but pays the vmapped-scatter thunk; the
            # crossover sits around the 128-slot bucket (measured)
            if NR <= 128:
                c_lat = jnp.where(hit[:, None], lat_row[None, :], st.c_lat)
                c_latv = jnp.where(hit[:, None], latv_row[None, :], st.c_latv)
            else:
                c_lat = st.c_lat.at[rb].set(lat_row, mode="drop")
                c_latv = st.c_latv.at[rb].set(latv_row, mode="drop")
            return st._replace(
                c_lat=c_lat,
                c_latv=c_latv,
                c_vdl=jnp.where(hit, vdl, st.c_vdl),
                c_vdln=jnp.where(hit, vdln, st.c_vdln),
                c_nm=jnp.where(hit, nm, st.c_nm),
                c_rm=jnp.where(hit, T.rm[m, l], st.c_rm),
                c_ek=jnp.where(hit, dr - T.rm[m, l + 1], st.c_ek),
            )

        # -- scheduler kernels ----------------------------------------------
        # Each kernel returns a PYTHON list of (valid, i, k, use_var, cost)
        # traced-scalar tuples in reference emission order (stage-1 pick
        # order, then stage-2 ascending k); the unrolled pick loops make
        # the emission buffer a compile-time structure instead of a device
        # array, so applying emissions needs no compaction scatters.
        def kern_terastal(st: St, ready, idle0, now):
            # Column-unrolled over the NA accelerators: a static-k slice
            # fuses into its elementwise consumers, so the round never
            # materializes an [NR, NA] f64 temporary (fo/fv/f0/ev live as
            # per-column [NR] chains).  Same IEEE adds/compares — pairwise
            # jnp.minimum and per-column adds are the exact ops the
            # materialized form ran, in the same per-element order.
            tau0 = jnp.maximum(st.busy, now)                 # [NA]
            fo_c = [st.c_lat[:, k] + tau0[k] for k in range(NA)]
            fv_c = [st.c_latv[:, k] + tau0[k] for k in range(NA)]
            fmin = fo_c[0]
            for k in range(1, NA):
                fmin = jnp.minimum(fmin, fo_c[k])
            keys = st.c_vdl - fmin        # stage-1 (slack, rid) sort key
            d_eps = st.c_vdl + 1e-15
            oko_c = [f <= d_eps for f in fo_c]
            okv_c = [f <= d_eps for f in fv_c]   # +inf (no variant) fails
            tau = tau0
            idle = idle0
            alive = ready
            picks = []
            # stage 1: repeated (slack, rid)-argmin over feasible slots;
            # argmin's first-occurrence rule == rid tie-break (slot == rid)
            for _ in range(NA):
                feas_any = (oko_c[0] | okv_c[0]) & idle[0]
                for k in range(1, NA):
                    feas_any = feas_any | ((oko_c[k] | okv_c[k]) & idle[k])
                feas = alive & feas_any
                mk = jnp.where(feas, keys, _INF)
                i = jnp.argmin(mk).astype(I32)
                valid = mk[i] < _INF
                fo_i = st.c_lat[i] + tau0          # [NA], round-start tau
                fv_i = st.c_latv[i] + tau0
                vo = jnp.where(idle & (fo_i <= d_eps[i]), fo_i, _INF)
                ko = jnp.argmin(vo).astype(I32)
                any_o = vo[ko] < _INF     # original first (lines 4-10)
                vv = jnp.where(idle & (fv_i <= d_eps[i]), fv_i, _INF)
                kv = jnp.argmin(vv).astype(I32)
                use_var = ~any_o
                k_sel = jnp.where(any_o, ko, kv)
                c = jnp.where(use_var, st.c_latv[i, k_sel], st.c_lat[i, k_sel])
                picks.append((valid, i, k_sel, use_var, c))
                hitk = (NAa == k_sel) & valid
                tau = jnp.where(hitk, tau + c, tau)
                idle = idle & ~hitk
                alive = alive & ~((NRa == i) & valid)
            # stage 2: backfill remaining idle accelerators, ascending k
            for k in range(NA):
                f0 = st.c_lat[:, 0] + tau[0]       # s* at CURRENT tau
                for kk in range(1, NA):
                    f0 = jnp.minimum(f0, st.c_lat[:, kk] + tau[kk])
                s_star = st.c_vdl - f0
                tk = tau[k]
                fino = st.c_lat[:, k] + tk
                t = ((st.c_vdln - fino) - st.c_nm) - s_star  # Eq. 8-9
                if mode == "ef":
                    okm = (fino <= f0 + 1e-15) & alive
                else:
                    okm = alive
                do = jnp.where(okm, t, -_INF)
                cv = st.c_latv[:, k]
                finv = cv + tk
                t2 = ((st.c_vdln - finv) - st.c_nm) - s_star
                if mode == "ef":
                    ev = st.c_latv[:, 0] + tau[0]
                    for kk in range(1, NA):
                        ev = jnp.minimum(ev, st.c_latv[:, kk] + tau[kk])
                    ok2 = (finv <= ev + 1e-15) & jnp.isfinite(cv)
                else:
                    ok2 = jnp.isfinite(cv)
                ok2 = ok2 & alive
                dv = jnp.where(ok2, t2, -_INF)
                mo = jnp.max(do)
                mv = jnp.max(dv)
                orig_wins = mo >= mv     # (delta, -use_var) strictly-greater
                best = jnp.where(orig_wins, mo, mv)
                valid = idle[k] & (best > -_INF)
                if mode == "positive":
                    valid = valid & (best > 0.0)
                d_sel = jnp.where(orig_wins, do, dv)
                tb = jnp.where(d_sel == best, keys, _INF)
                i = jnp.argmin(tb).astype(I32)  # earliest in stage-1 order
                use_var = ~orig_wins
                c = jnp.where(use_var, st.c_latv[i, k], st.c_lat[i, k])
                picks.append((valid, i, jnp.asarray(k, I32), use_var, c))
                tau = jnp.where((NAa == k) & valid, tau + c, tau)
                alive = alive & ~((NRa == i) & valid)
            return picks

        def kern_greedy(st: St, ready, idle0, now):
            if kind == "fcfs":
                key = at[:NR]                       # (arrival, rid)
            elif kind == "edf":
                key = st.c_ek                       # (edf deadline, rid)
            else:  # dream
                key = (d_abs - now) - st.c_rm       # (slack, rid)
            tau0 = jnp.maximum(st.busy, now)        # round-start, not updated
            idle = idle0
            alive = ready
            fK = jnp.asarray(False)
            picks = []
            for _ in range(NA):
                mk = jnp.where(alive, key, _INF)
                i = jnp.argmin(mk).astype(I32)
                ok_i = mk[i] < _INF
                if kind == "dream":
                    vals = jnp.where(idle, tau0 + st.c_lat[i], _INF)
                else:   # fcfs/edf: lowest latency, first-min ascending k
                    vals = jnp.where(idle, st.c_lat[i], _INF)
                k = jnp.argmin(vals).astype(I32)
                valid = ok_i & (vals[k] < _INF)
                c = st.c_lat[i, k]
                picks.append((valid, i, k, fK, c))
                idle = idle & ~((NAa == k) & valid)
                alive = alive & ~((NRa == i) & valid)
            return picks

        kern = kern_terastal if kind == "terastal" else kern_greedy

        # -- the event loop --------------------------------------------------
        def cond(st: St):
            active = (st.ai < ne) | jnp.any(st.run_req >= 0)
            if faulted:
                active = active | (st.fi < nf) | jnp.any(st.gh_t < _INF)
            return active & (st.it < max_it)

        def body(st: St):
            st = st._replace(it=st.it + 1)
            # pop: lexicographic (time, counter) min; arrivals beat
            # same-time finishes (their heap counters are always smaller).
            # With faults: arrival < fault < finish/ghost at equal times
            # (the reference allocates arrival counters first, then fault
            # counters, then dynamic finish counters), and ghost-vs-finish
            # ties break on the stored finish counters.
            arr_next = at[st.ai]
            ft_min = jnp.min(st.fin_t)
            k_f = jnp.argmin(
                jnp.where(st.fin_t == ft_min, st.fin_cnt, IMAXi)
            ).astype(I32)
            if faulted:
                f_next = fe_t[st.fi]
                gh_min = jnp.min(st.gh_t)
                oth = jnp.minimum(ft_min, gh_min)
                is_arr = arr_next <= jnp.minimum(f_next, oth)
                is_fault = (~is_arr) & (f_next <= oth)
                g_i = jnp.argmin(
                    jnp.where(st.gh_t == gh_min, st.gh_cnt, IMAXi)
                ).astype(I32)
                is_ghost = (~is_arr) & (~is_fault) & (
                    (gh_min < ft_min)
                    | ((gh_min == ft_min) & (st.gh_cnt[g_i] < st.fin_cnt[k_f]))
                )
                is_fin = (~is_arr) & (~is_fault) & (~is_ghost)
                now = jnp.where(
                    is_arr, arr_next,
                    jnp.where(is_fault, f_next,
                              jnp.where(is_ghost, gh_min, ft_min)),
                )
                # ghost pop: a stale finish is a no-op state-wise; its pop
                # still falls through to the round logic below
                st = st._replace(
                    gh_t=jnp.where(
                        NFa == jnp.where(is_ghost, g_i, NFi), _INF, st.gh_t
                    )
                )
            else:
                is_arr = arr_next <= ft_min
                is_fin = ~is_arr
                now = jnp.where(is_arr, arr_next, ft_min)

            # finish candidate (garbage when not is_fin; writes are masked)
            pop_rf = is_arr | is_fin
            r_f = st.run_req[k_f]
            r = jnp.where(is_arr, st.ai, r_f)  # slot == rid == stream index
            m = am[r]
            l_new = jnp.where(is_arr, 0, st.layer[r] + 1)
            done = is_fin & (l_new >= T.nl[m])

            hit_f = NAa == jnp.where(is_fin, k_f, NAi)
            r_m = jnp.where(pop_rf, r, NRi)
            hit_r = NRa == r_m
            hit_d = NRa == jnp.where(done, r, NRi)
            st = st._replace(
                ai=st.ai + is_arr.astype(I32),
                fin_t=jnp.where(hit_f, _INF, st.fin_t),
                run_req=jnp.where(hit_f, -1, st.run_req),
                layer=jnp.where(hit_r, l_new, st.layer),
                state=jnp.where(hit_r, jnp.where(done, 3, 1), st.state),
                missed=jnp.where(hit_d, now > d_eps12[r], st.missed),
                done_seq=jnp.where(hit_d, st.done_ctr, st.done_seq),
                done_ctr=st.done_ctr + done.astype(I32),
            )
            st = bind(st, pop_rf & ~done, r, l_new, m)

            if faulted:
                # ---- capability event (masked is_fault) -------------------
                fi_c = jnp.minimum(st.fi, NFi - 1)
                fk = fe_acc[fi_c]
                code = fe_code[fi_c]
                val = fe_val[fi_c]
                is_down = is_fault & (code == 0)
                is_up = is_fault & (code == 1)
                is_scale = is_fault & (code == 2)
                r_e = st.run_req[fk]
                has_run = r_e >= 0
                # down with an in-flight layer: undo the dispatch (variant
                # bookkeeping, un-run busy time) and re-enter the ready set
                ev = is_down & has_run
                r_ec = jnp.where(ev, r_e, NRi)
                l_e = st.layer[jnp.where(ev, r_e, 0)]
                m_e = am[jnp.where(ev, r_e, 0)]
                undo = ev & st.run_uv[fk]
                r_u = jnp.where(undo, r_e, NRi)
                st = st._replace(
                    # exact ret restore: the evicted variant is the
                    # request's most recent apply, so the pre-dispatch
                    # product saved at dispatch time is the undone value
                    ret=jnp.where(NRa == r_u, st.run_prev_ret[fk], st.ret),
                    app_seq=st.app_seq.at[r_u, l_e].set(-1, mode="drop"),
                    app_cnt=st.app_cnt.at[r_u].add(-1, mode="drop"),
                )
                # evict_busy_adjust replicated op-for-op in jnp
                t0 = st.disp_t0[fk]
                new_w = now - t0
                new_h = jnp.minimum(new_w, jnp.maximum(0.0, duration - t0))
                dw = new_w - st.disp_w[fk]
                dh = new_h - st.disp_h[fk]
                hit_e = NAa == jnp.where(ev, fk, NAi)
                # scale with an in-flight layer: re-time the finish by
                # new_scale / old_scale (retime_busy_adjust in jnp)
                old = st.fscale[fk]
                changed = is_scale & has_run & (val != old)
                fin_old = st.busy[fk]
                fin_new = now + (fin_old - now) * (val / old)
                nw2 = fin_new - t0
                nh2 = jnp.minimum(nw2, jnp.maximum(0.0, duration - t0))
                dw2 = nw2 - st.disp_w[fk]
                dh2 = nh2 - st.disp_h[fk]
                hit_s = NAa == jnp.where(changed, fk, NAi)
                # both eviction and re-time orphan the old finish event:
                # push it onto the ghost list (the reference leaves it in
                # the heap as a stale pop)
                ghost = ev | changed
                gh_hit = NFa == jnp.where(ghost, st.gh_n, NFi)
                hit_dn = NAa == jnp.where(is_down, fk, NAi)
                hit_up = NAa == jnp.where(is_up, fk, NAi)
                st = st._replace(
                    gh_t=jnp.where(gh_hit, st.fin_t[fk], st.gh_t),
                    gh_cnt=jnp.where(gh_hit, st.fin_cnt[fk], st.gh_cnt),
                    gh_n=st.gh_n + ghost.astype(I32),
                    busy=jnp.where(
                        hit_dn, _INF,
                        jnp.where(hit_up, now,
                                  jnp.where(hit_s, fin_new, st.busy)),
                    ),
                    busy_t=jnp.where(
                        hit_e, st.busy_t + dw,
                        jnp.where(hit_s, st.busy_t + dw2, st.busy_t),
                    ),
                    busy_h=jnp.where(
                        hit_e, st.busy_h + dh,
                        jnp.where(hit_s, st.busy_h + dh2, st.busy_h),
                    ),
                    fin_t=jnp.where(
                        hit_dn, _INF, jnp.where(hit_s, fin_new, st.fin_t)
                    ),
                    fin_cnt=jnp.where(hit_s, st.cnt, st.fin_cnt),
                    run_req=jnp.where(hit_dn, -1, st.run_req),
                    cnt=st.cnt + changed.astype(I32),
                    fscale=jnp.where(
                        NAa == jnp.where(is_scale, fk, NAi), val, st.fscale
                    ),
                    state=jnp.where(NRa == r_ec, 1, st.state),
                    ev_pend=jnp.where(NRa == r_ec, True, st.ev_pend),
                    evict_cnt=st.evict_cnt + (NRa == r_ec).astype(I32),
                    disp_w=jnp.where(hit_s, nw2, st.disp_w),
                    disp_h=jnp.where(hit_s, nh2, st.disp_h),
                    fi=st.fi + is_fault.astype(I32),
                )
                # re-bind the evicted row at its current layer with the
                # post-undo ret (variant feasibility may have changed)
                st = bind(st, ev, jnp.where(ev, r_e, NRi), l_e, m_e)

            # batch simultaneous events before scheduling (ref: abs < 1e-15
            # against the just-popped now; empty heap -> +inf -> round runs).
            # A suppressed round folds into the masks below (ready empty ->
            # the kernel emits nothing) instead of a whole-carry select.
            t_next = jnp.minimum(at[st.ai], jnp.min(st.fin_t))
            if faulted:
                t_next = jnp.minimum(
                    t_next, jnp.minimum(fe_t[st.fi], jnp.min(st.gh_t))
                )
            do_round = ~(jnp.abs(t_next - now) < 1e-15)

            st = st._replace(rounds=st.rounds + do_round.astype(I32))
            if faulted:
                # the round sees the CURRENT capability epoch: nominal
                # cache planes times the epoch multiplier (elementwise —
                # bit-equal to the effective tables the scalar engines
                # swap in), and the capability-derived scalar vectors
                # regathered from the epoch planes (vdl chains re-bound
                # to arrival + chain under retighten, effective
                # remaining-min for early-drop/EDF/DREAM keys)
                mult = MULT_EP[st.fi]
                vdlr_f = VDLR_EP[st.fi]
                rm_f = RM_EP[st.fi]
                minl_f = MINL_EP[st.fi]
                l_all = st.layer
                m_all = am
                LPi = jnp.asarray(LP, I32)
                LP1i = jnp.asarray(LP + 1, I32)
                has_nx = (l_all + 1) < T.nl[m_all]
                if use_budgets:
                    vdl_v = at[:NR] + vdlr_f[m_all, jnp.minimum(l_all, LPi)]
                    vdln_v = jnp.where(
                        has_nx,
                        at[:NR] + vdlr_f[m_all, jnp.minimum(l_all + 1, LPi)],
                        d_abs,
                    )
                else:
                    vdl_v = d_abs - rm_f[m_all, jnp.minimum(l_all + 1, LP1i)]
                    vdln_v = jnp.where(
                        has_nx,
                        d_abs - rm_f[m_all, jnp.minimum(l_all + 2, LP1i)],
                        d_abs,
                    )
                nm_v = jnp.where(
                    has_nx,
                    minl_f[m_all, jnp.minimum(l_all + 1, LPi - 1)],
                    0.0,
                )
                rm_v = rm_f[m_all, jnp.minimum(l_all, LP1i)]
                ek_v = d_abs - rm_f[m_all, jnp.minimum(l_all + 1, LP1i)]
                stk = st._replace(
                    c_lat=st.c_lat * mult[None, :],
                    c_latv=st.c_latv * mult[None, :],
                    c_vdl=vdl_v, c_vdln=vdln_v, c_nm=nm_v,
                    c_rm=rm_v, c_ek=ek_v,
                )
            else:
                stk = st
            ready0 = (st.state == 1) & do_round
            dropm = ready0 & ((now + stk.c_rm) > d_eps12)  # early-drop
            st = st._replace(
                state=jnp.where(dropm, 4, st.state),
                missed=st.missed | dropm,
            )
            ready = ready0 & ~dropm
            idle = st.busy <= now + 1e-15
            picks = kern(stk, ready, idle, now)

            # apply emissions: chained one-hot selects per pick.  Finish
            # counters are cnt + (# valid picks before this one) — the
            # compacted emission index, tracked as traced scalars.
            state_n, run_req = st.state, st.run_req
            fin_t, fin_cnt = st.fin_t, st.fin_cnt
            busy, busy_t, busy_h = st.busy, st.busy_t, st.busy_h
            disp_t0, disp_w, disp_h = st.disp_t0, st.disp_w, st.disp_h
            run_uv, run_prev = st.run_uv, st.run_prev_ret
            rem = duration - now
            rem = jnp.where(rem > 0.0, rem, 0.0)
            n_e = jnp.asarray(0, I32)
            rs, uvs, vas, vls = [], [], [], []
            for valid, i, k, uv, c in picks:
                fin = now + c
                hc = jnp.where(c <= rem, c, rem)
                hit_a = (NAa == k) & valid
                state_n = jnp.where((NRa == i) & valid, 2, state_n)
                run_req = jnp.where(hit_a, i, run_req)
                fin_t = jnp.where(hit_a, fin, fin_t)
                fin_cnt = jnp.where(hit_a, st.cnt + n_e, fin_cnt)
                busy = jnp.where(hit_a, fin, busy)
                busy_t = jnp.where(hit_a, busy_t + c, busy_t)
                busy_h = jnp.where(hit_a, busy_h + hc, busy_h)
                if faulted:
                    # dispatch bookkeeping eviction/re-timing must undo;
                    # run_prev snapshots the pre-apply retained product
                    disp_t0 = jnp.where(hit_a, now, disp_t0)
                    disp_w = jnp.where(hit_a, c, disp_w)
                    disp_h = jnp.where(hit_a, hc, disp_h)
                    run_uv = jnp.where(hit_a, uv, run_uv)
                    run_prev = jnp.where(hit_a, st.ret[i], run_prev)
                n_e = n_e + valid.astype(I32)
                rs.append(i)
                uvs.append(uv)
                vas.append(valid & uv)
                vls.append(valid)
            # variant bookkeeping: a picked row is unique per round, so the
            # pre-round app_cnt/layer reads are the scatter-time values; the
            # [NR, LP] sequence table keeps a true (vector) scatter
            r_vec = jnp.stack(rs)
            va = jnp.stack(vas)
            rv = jnp.where(va, r_vec, NRi)
            l_vec = st.layer[r_vec]
            st = st._replace(
                state=state_n, run_req=run_req,
                fin_t=fin_t, fin_cnt=fin_cnt,
                busy=busy, busy_t=busy_t, busy_h=busy_h,
                app_seq=st.app_seq.at[rv, l_vec].set(
                    st.app_cnt[r_vec], mode="drop"),
                app_cnt=st.app_cnt.at[rv].add(1, mode="drop"),
                ret=st.ret.at[rv].multiply(
                    T.factor[am[r_vec], l_vec], mode="drop"),
                cnt=st.cnt + n_e,
            )
            if faulted:
                # a dispatched evicted-pending request is remapped (SoA:
                # evicted_pending cleared + remapped += 1 at dispatch)
                valid_vec = jnp.stack(vls)
                was_pend = st.ev_pend[r_vec] & valid_vec
                st = st._replace(
                    disp_t0=disp_t0, disp_w=disp_w, disp_h=disp_h,
                    run_uv=run_uv, run_prev_ret=run_prev,
                    remap_cnt=st.remap_cnt.at[
                        jnp.where(was_pend, r_vec, NRi)
                    ].add(1, mode="drop"),
                    ev_pend=st.ev_pend.at[
                        jnp.where(valid_vec, r_vec, NRi)
                    ].set(False, mode="drop"),
                )
            return st

        z = jnp.zeros
        st0 = St(
            ai=jnp.asarray(0, I32), it=jnp.asarray(0, I32),
            cnt=jnp.asarray(0, I32), rounds=jnp.asarray(0, I32),
            done_ctr=jnp.asarray(0, I32),
            state=z(NR, I32), layer=z(NR, I32),
            c_lat=jnp.full((NR, NA), _INF), c_latv=jnp.full((NR, NA), _INF),
            c_vdl=z(NR), c_vdln=z(NR), c_nm=z(NR),
            c_rm=jnp.full(NR, _INF), c_ek=z(NR),
            ret=jnp.ones(NR), app_seq=jnp.full((NR, LP), -1, I32),
            app_cnt=z(NR, I32),
            missed=z(NR, bool), done_seq=jnp.full(NR, -1, I32),
            busy=z(NA), busy_t=z(NA), busy_h=z(NA),
            fin_t=jnp.full(NA, _INF), fin_cnt=z(NA, I32),
            run_req=jnp.full(NA, -1, I32),
            fi=jnp.asarray(0, I32), fscale=jnp.ones(NA),
            gh_t=jnp.full(NF, _INF), gh_cnt=z(NF, I32),
            gh_n=jnp.asarray(0, I32),
            disp_t0=z(NA), disp_w=z(NA), disp_h=z(NA),
            run_uv=z(NA, bool), run_prev_ret=jnp.ones(NA),
            ev_pend=z(NR, bool), evict_cnt=z(NR, I32), remap_cnt=z(NR, I32),
        )
        st = lax.while_loop(cond, body, st0)
        act = (st.ai < ne) | jnp.any(st.run_req >= 0)
        if faulted:
            act = act | (st.fi < nf) | jnp.any(st.gh_t < _INF)
        return _Out(
            state=st.state, missed=st.missed, app_seq=st.app_seq,
            app_cnt=st.app_cnt, done_seq=st.done_seq,
            busy_t=st.busy_t, busy_h=st.busy_h, rounds=st.rounds,
            drained=~act,
            evict_cnt=st.evict_cnt, remap_cnt=st.remap_cnt,
        )

    return jax.vmap(one_lane)(
        arr_t, arr_m, dl, dl12, n_ev,
        fe_t, fe_acc, fe_code, fe_val, n_f,
        mult_ep, vdlr_ep, rm_ep, minl_ep,
    )


# ------------------------------------------------------- host wrapper ----


def _validate(
    plans, tasks, scheduler, processes, policy, adm, fault_model=None
) -> None:
    """Static event-horizon validation: reject every axis whose events the
    speculative device rollout cannot cover.  Named errors, no fallback."""
    from repro.core.admission import NoAdmission
    from repro.core.budget_online import BudgetPolicy, StaticBudgetPolicy

    for p in plans:
        if p.dag is not None:
            raise BatchUnsupportedError(
                f"engine='batch' does not support DAG plans (model "
                f"{p.model.name!r}): sibling node entries of one request "
                "break the one-slot-per-request lane layout; use "
                "engine='soa' or engine='reference'"
            )
    if (
        fault_model is not None
        and fault_model.active
        and fault_model.interrupted == "resume"
    ):
        # The remaining eviction-timing caveat of the fault lane: under
        # ``resume`` an evicted layer carries fractional progress
        # (layer_frac) that rescales its next dispatch cost, which the
        # pre-bound epoch planes cannot express.  ``restart`` (the
        # default) fault injection is fully supported — capability events
        # are pre-bound as a time-indexed epoch schedule.
        raise BatchUnsupportedError(
            "engine='batch' does not support fault injection with the "
            f"'resume' interrupted-work policy ({fault_model.format()!r}): "
            "partial layer progress re-times re-dispatches mid-rollout, "
            "which the pre-bound capability epochs cannot express; use "
            "engine='soa' or engine='reference'"
        )
    if type(scheduler) not in (
        FcfsScheduler, EdfScheduler, DreamScheduler, TerastalScheduler
    ):
        raise BatchUnsupportedError(
            f"engine='batch' has no kernel for {type(scheduler).__name__}; "
            "custom Scheduler subclasses need the reference engine"
        )
    if type(policy) not in (StaticBudgetPolicy, BudgetPolicy):
        raise BatchUnsupportedError(
            f"engine='batch' does not support online budget policy "
            f"{type(policy).__name__}: per-event vdl mutation breaks the "
            "pre-bound virtual-deadline rows; use engine='soa'"
        )
    if policy.tick_interval > 0:
        raise BatchUnsupportedError(
            "engine='batch' does not support budget-policy tick events"
        )
    if adm is not None and type(adm) is not NoAdmission:
        raise BatchUnsupportedError(
            f"engine='batch' does not support admission policy "
            f"{type(adm).__name__}: backlog accounting is event-sequential; "
            "use engine='soa'"
        )
    for t_idx, task in enumerate(tasks):
        proc = processes[t_idx] if processes is not None else None
        proc = proc or task.arrival or DEFAULT_ARRIVAL
        if isinstance(proc, ClosedLoopClients):
            raise BatchUnsupportedError(
                "engine='batch' does not support closed-loop release "
                "coupling (ClosedLoopClients): completion-gated releases "
                "cannot be pre-generated; use engine='soa'"
            )


def simulate_batch(
    plans: Sequence[ModelPlan],
    tasks: Sequence[TaskSpec],
    duration: float,
    scheduler: Scheduler,
    seeds: Sequence[int],
    processes: Optional[Sequence[Optional[ArrivalProcess]]] = None,
    budget_policy=None,
    admission=None,
    faults=None,
) -> List[SimResult]:
    """Run B = ``len(seeds)`` trials of one cell as ONE device program.

    Same contract as ``simulate()`` for every supported axis — each
    returned :class:`SimResult` is fingerprint-identical to
    ``simulate(..., seed=s, engine="soa")`` (pinned by
    tests/test_engine_batch.py).  Unsupported axes raise
    :class:`BatchUnsupportedError` (see :func:`_validate`); an
    undrained lane (the speculation bound failed — an engine bug, not a
    workload property) raises ``RuntimeError``.
    """
    from repro.core.admission import make_admission_policy
    from repro.core.budget_online import make_budget_policy
    from repro.core.faults import make_fault_model
    from repro.core.workload import batch_release_events

    policy = make_budget_policy(budget_policy)
    policy.reset()
    adm = make_admission_policy(admission)
    adm.reset()
    fault_model = faults if not isinstance(faults, str) else make_fault_model(faults)
    _validate(plans, tasks, scheduler, processes, policy, adm, fault_model)

    kind = type(scheduler)
    if kind is TerastalScheduler:
        cfg = dict(
            kind="terastal", mode=scheduler.backfill_mode,
            use_budgets=scheduler.use_budgets,
            use_variants=scheduler.use_variants,
        )
    else:
        name = {FcfsScheduler: "fcfs", EdfScheduler: "edf",
                DreamScheduler: "dream"}[kind]
        cfg = dict(kind=name, mode="", use_budgets=False, use_variants=False)

    tables, LP, NA = _build_tables(plans)
    deadline_by_model = np.array([p.deadline for p in plans])
    events = batch_release_events(tasks, duration, seeds, processes)
    buf, b_pad, nr_pad = scheduler_jax.pack_trials(events, deadline_by_model)

    # exact event-count bound: each loop iteration pops exactly one event,
    # and the horizon holds n_ev arrivals plus at most one finish per
    # executed layer (sum of layer counts over released requests)
    nl_by_model = np.array([len(p.model.layers) for p in plans])
    max_it = 2 + max(
        (len(t) + int(nl_by_model[m].sum()) for t, m in events), default=2
    )

    faulted = fault_model is not None and fault_model.active
    if faulted:
        fbuf, nf_pad, n_spans = scheduler_jax.pack_fault_epochs(
            fault_model, plans, duration, seeds, b_pad, LP
        )
        # each fault event adds at most three pops: itself, the ghost of
        # an orphaned finish, and the re-dispatched layer's new finish
        max_it += 3 * int(fbuf["n_f"].max())
    else:
        # minimal dummies: the fault path is a static branch, so these
        # are never read — they only have to vmap over the lane axis
        n_spans = [0] * len(seeds)
        fbuf = {
            "fe_t": np.full((b_pad, 2), np.inf),
            "fe_acc": np.zeros((b_pad, 1), np.int32),
            "fe_code": np.zeros((b_pad, 1), np.int32),
            "fe_val": np.ones((b_pad, 1)),
            "n_f": np.zeros(b_pad, np.int32),
            "mult_ep": np.ones((b_pad, 1, NA)),
            "vdlr_ep": np.zeros((b_pad, 1, 1, 1)),
            "rm_ep": np.zeros((b_pad, 1, 1, 1)),
            "minl_ep": np.zeros((b_pad, 1, 1, 1)),
        }

    out: _Out = _run_trials(
        tables,
        jnp.asarray(buf["arr_t"]), jnp.asarray(buf["arr_m"]),
        jnp.asarray(buf["dl"]), jnp.asarray(buf["dl12"]),
        jnp.asarray(buf["n_ev"]),
        duration, np.int32(max_it),
        jnp.asarray(fbuf["fe_t"]), jnp.asarray(fbuf["fe_acc"]),
        jnp.asarray(fbuf["fe_code"]), jnp.asarray(fbuf["fe_val"]),
        jnp.asarray(fbuf["n_f"]),
        jnp.asarray(fbuf["mult_ep"]), jnp.asarray(fbuf["vdlr_ep"]),
        jnp.asarray(fbuf["rm_ep"]), jnp.asarray(fbuf["minl_ep"]),
        na=NA, lp=LP, faulted=faulted, **cfg,
    )
    out = jax.tree_util.tree_map(np.asarray, out)  # ONE host sync

    drained = out.drained[: len(seeds)]
    if not drained.all():
        raise RuntimeError(
            "engine='batch' lane(s) %s did not drain their event horizon "
            "within the exact bound — engine bug" % np.flatnonzero(~drained)
        )

    results: List[SimResult] = []
    for b, (times, models) in enumerate(events):
        n = len(times)
        state = out.state[b, :n]
        missed_f = out.missed[b, :n]
        app_cnt = out.app_cnt[b, :n]
        evict_c = out.evict_cnt[b, :n]
        remap_c = out.remap_cnt[b, :n]
        stats: Dict[int, ModelStats] = {t.model_idx: ModelStats() for t in tasks}
        for m in stats:
            mm = models[:n] == m
            st = stats[m]
            st.released = int(mm.sum())
            st.completed = int((mm & (state == 3)).sum())
            st.dropped = int((mm & (state == 4)).sum())
            st.missed = int((mm & missed_f).sum())
            # every released request ends completed, dropped, or in flight
            st.in_flight = st.released - st.completed - st.dropped
            st.variants_applied = int(app_cnt[mm].sum())
            st.evicted = int(evict_c[mm].sum())
            st.remapped = int(remap_c[mm].sum())
        # retained_sum: host replay in completion order, through the same
        # frozenset unions + combo_retained calls the reference performs
        done = np.flatnonzero(state == 3)
        for r in done[np.argsort(out.done_seq[b, done])]:
            m = int(models[r])
            applied = frozenset()
            seq = out.app_seq[b, r]
            order = np.flatnonzero(seq >= 0)
            for l in order[np.argsort(seq[order])]:
                applied = applied | {int(l)}
            stats[m].retained_sum += plans[m].combo_retained(applied)
        results.append(
            SimResult(
                duration=duration,
                per_model=stats,
                acc_busy_time=out.busy_t[b].copy(),
                scheduler_name=scheduler.name,
                acc_busy_in_horizon=out.busy_h[b].copy(),
                rounds=int(out.rounds[b]),
                faulted_spans=n_spans[b],
            )
        )
    return results

"""Sequential adaptive campaign sampler: stop cells when the CIs separate.

The fixed grids behind every headline claim (fig5/fig7/fig8) spend an
identical seed budget on cells whose verdict is obvious after three
replicates and on cells that genuinely need the full ladder.  PR 3 made
trials ~3.3x cheaper, so sampler logic — not trial cost — now bounds
campaign scale.  This module grows seed replicates per cell in rounds
and retires a cell as soon as its scheduler-vs-baseline comparison is
statistically settled:

* **Cells and pairing.**  A cell is one
  (scenario, platform, theta, scheduler, arrival, budget_policy)
  combination; cells that differ only in ``scheduler`` form a *group*.
  Within a group, every non-baseline scheduler is compared against the
  baseline (default ``terastal``) on *paired* per-seed metric
  differences — both cells replay the identical arrival realization per
  seed, so the pairing removes arrival noise from the gap estimate.

* **Stopping rule.**  After each round at ``k`` seeds, a comparison is
  declared *separated* when the paired percentile-bootstrap CI on the
  mean gap excludes zero at the Bonferroni-adjusted per-look level
  ``alpha / n_looks`` AND the exact paired t-test p-value clears the
  same level.  The naive small-``n`` percentile bootstrap is
  anticonservative (its measured false-separation rate exceeds the
  nominal alpha at n <= 8 — see ``tests/test_sampling_stats.py``); the
  t-gate restores family-wise type-I control over the whole sequential
  ladder, which the stats suite pins below the nominal alpha on null
  cells.  A comparison that never separates runs to the per-cell cap
  (the full seed ladder) and takes the fixed grid's verdict: the sign
  of the mean gap over all seeds.

* **Determinism contract.**  The trial stream per cell is the campaign's
  own PRNG-indexed seed ladder, consumed in order — the trials an
  adaptive run executes are exactly a prefix of ``Campaign.trials()``
  per cell.  Decisions are made at round barriers from seed-indexed
  prefixes of deterministic trial results, so parallel == serial ==
  fixed-grid-prefix, and with stopping disabled the sampler reproduces
  ``Campaign.run`` trial-for-trial (pinned by ``tests/test_sampling.py``).

* **Journal / resume.**  With ``journal=path`` every completed trial is
  appended to a JSON-lines file in deterministic order.  Re-running the
  same campaign+config against the journal replays the recorded prefix
  from cache (no re-execution) and continues bit-identically — the
  sampler is a pure function of trial results, and trial results are
  pure functions of their specs.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.campaign import (
    Campaign,
    CampaignResult,
    DegenerateSampleError,
    TrialExecutor,
    TrialResult,
    TrialSpec,
    bootstrap_ci,
)

#: Spec fields that identify a sampler cell (everything but the seed;
#: duration/engine are campaign-wide constants but kept for row identity).
CELL_FIELDS = ("scenario", "platform", "theta", "scheduler", "arrival", "budget_policy")
#: Cells that differ only in ``scheduler`` form a comparison group.
GROUP_FIELDS = tuple(f for f in CELL_FIELDS if f != "scheduler")


# ----------------------------------------------------- paired statistics ----


def _betacf(a: float, b: float, x: float) -> float:
    """Continued fraction for the regularized incomplete beta (NR 6.4)."""
    tiny, eps = 1e-30, 3e-14
    qab, qap, qam = a + b, a + 1.0, a - 1.0
    c, d = 1.0, 1.0 - qab * x / qap
    if abs(d) < tiny:
        d = tiny
    d = 1.0 / d
    h = d
    for m in range(1, 200):
        m2 = 2 * m
        aa = m * (b - m) * x / ((qam + m2) * (a + m2))
        d = 1.0 + aa * d
        if abs(d) < tiny:
            d = tiny
        c = 1.0 + aa / c
        if abs(c) < tiny:
            c = tiny
        d = 1.0 / d
        h *= d * c
        aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2))
        d = 1.0 + aa * d
        if abs(d) < tiny:
            d = tiny
        c = 1.0 + aa / c
        if abs(c) < tiny:
            c = tiny
        d = 1.0 / d
        de = d * c
        h *= de
        if abs(de - 1.0) < eps:
            break
    return h


def betainc(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta I_x(a, b) — no scipy in the image, so
    the t-test tail probability is computed from first principles."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    ln_bt = (
        math.lgamma(a + b)
        - math.lgamma(a)
        - math.lgamma(b)
        + a * math.log(x)
        + b * math.log(1.0 - x)
    )
    bt = math.exp(ln_bt)
    if x < (a + 1.0) / (a + b + 2.0):
        return bt * _betacf(a, b, x) / a
    return 1.0 - bt * _betacf(b, a, 1.0 - x) / b


def paired_t_pvalue(diffs: Sequence[float]) -> float:
    """Two-sided one-sample t-test p-value for mean(diffs) == 0.

    Degenerate variance (all diffs equal) is common in simulation —
    e.g. strictly periodic cells where every seed replays the identical
    arrival sequence: the gap is then *certain*, so p is 0.0 for a
    nonzero constant gap and 1.0 for an all-zero one."""
    d = np.asarray(list(diffs), dtype=float)
    if d.size < 2:
        raise DegenerateSampleError(
            f"paired_t_pvalue needs >= 2 paired differences, got {d.size}"
        )
    mean = float(d.mean())
    sd = float(d.std(ddof=1))
    if sd == 0.0:
        return 1.0 if mean == 0.0 else 0.0
    t = mean / (sd / math.sqrt(d.size))
    df = d.size - 1
    return betainc(df / 2.0, 0.5, df / (df + t * t))


def gap_separates(
    diffs: Sequence[float],
    alpha: float,
    n_boot: int = 1000,
    ci_seed: int = 0,
) -> Tuple[float, float, bool]:
    """One stopping-rule look: ``(ci_lo, ci_hi, separated)`` at level
    ``alpha`` (already Bonferroni-adjusted by the caller).

    Separation needs the paired percentile-bootstrap CI to exclude zero
    *and* the paired t-test to reject at the same level — the bootstrap
    alone under-covers at small n (measured in tests/test_sampling_stats
    .py), the t-gate keeps the false-separation rate below nominal."""
    lo, hi = bootstrap_ci(diffs, n_boot=n_boot, alpha=alpha, seed=ci_seed)
    separated = (lo > 0.0 or hi < 0.0) and paired_t_pvalue(diffs) <= alpha
    return lo, hi, separated


# ------------------------------------------------------------- sampler ----


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Stopping-rule knobs; the seed *cap* is the campaign's own ladder.

    ``alpha`` is the family-wise false-separation budget for one
    comparison across its whole sequential ladder; each look spends
    ``alpha / n_looks`` (Bonferroni), where the looks are at
    ``min_seeds, min_seeds + round_seeds, ..., cap``.  ``stopping=False``
    disables the rule entirely: every cell runs the full ladder and the
    sampler must reproduce ``Campaign.run`` exactly."""

    baseline: str = "terastal"
    metric: str = "mean_miss_rate"
    min_seeds: int = 3
    round_seeds: int = 1
    alpha: float = 0.05
    n_boot: int = 1000
    ci_seed: int = 0
    stopping: bool = True

    def __post_init__(self):
        if self.min_seeds < 2:
            raise ValueError(f"min_seeds must be >= 2, got {self.min_seeds}")
        if self.round_seeds < 1:
            raise ValueError(f"round_seeds must be >= 1, got {self.round_seeds}")
        if not 0.0 < self.alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {self.alpha}")

    def looks(self, cap: int) -> List[int]:
        """Seed counts at which the stopping rule is evaluated: the
        ``min_seeds + i * round_seeds`` ladder, always ending at ``cap``."""
        if not self.stopping:
            return [cap]
        return list(range(min(self.min_seeds, cap), cap, self.round_seeds)) + [cap]


@dataclasses.dataclass(frozen=True)
class GapVerdict:
    """Outcome of one scheduler-vs-baseline comparison.

    ``reason`` records how the sampler settled it: ``"separated"`` (the
    CI rule fired), ``"invariant"`` (both cells are seed-invariant —
    every replicate reproduced the identical simulation outcome — so the
    gap is a constant and the verdict certain; retires strictly periodic
    cells early), or ``"cap"`` (ran the full ladder and took the fixed
    grid's sign-of-mean verdict)."""

    group: Tuple  # GROUP_FIELDS values
    scheduler: str
    baseline: str
    n_seeds: int  # paired replicates consumed when the verdict was reached
    mean_gap: float  # mean over seeds of metric(scheduler) - metric(baseline)
    ci_lo: float
    ci_hi: float
    separated: bool  # True: the CI stopping rule fired before the cap
    winner: str  # scheduler name with the lower metric, or "tie"
    reason: str = "cap"  # "separated" | "invariant" | "cap"

    def row(self) -> Dict:
        d = dict(zip(GROUP_FIELDS, self.group))
        d.update(
            scheduler=self.scheduler,
            baseline=self.baseline,
            n_seeds=self.n_seeds,
            mean_gap=self.mean_gap,
            ci_lo=self.ci_lo,
            ci_hi=self.ci_hi,
            separated=self.separated,
            winner=self.winner,
            reason=self.reason,
        )
        return d


@dataclasses.dataclass
class AdaptiveResult:
    """Sampler output: the executed trials (grid order), per-comparison
    verdicts, and the budget accounting against the fixed grid."""

    campaign: Campaign
    config: SamplerConfig
    trials: List[TrialResult]
    verdicts: List[GapVerdict]
    rounds: int
    n_trials_cap: int  # what the fixed grid would have run (cells x cap)

    @property
    def n_trials(self) -> int:
        return len(self.trials)

    def trials_saved(self) -> float:
        """Fraction of the fixed grid's trial budget left unspent."""
        return 1.0 - self.n_trials / self.n_trials_cap if self.n_trials_cap else 0.0

    def campaign_result(self) -> CampaignResult:
        """Adapter so every ``CampaignResult`` consumer (``aggregate``,
        ``grouped``, the figure benchmarks) works on adaptive output."""
        return CampaignResult(list(self.trials))


def _outcome(res: TrialResult) -> Tuple:
    """Everything the simulation observably produced (spec and wall time
    excluded) — the equality key behind the certain-tie fast path."""
    return (
        res.mean_miss_rate,
        res.mean_accuracy_loss,
        res.released,
        res.completed,
        res.dropped,
        res.variants_applied,
        res.utilization,
    )


def _cell_of(spec: TrialSpec) -> Tuple:
    return tuple(getattr(spec, f) for f in CELL_FIELDS)


def _group_of(cell: Tuple) -> Tuple:
    return tuple(v for f, v in zip(CELL_FIELDS, cell) if f != "scheduler")


def _sched_of(cell: Tuple) -> str:
    return cell[CELL_FIELDS.index("scheduler")]


# ------------------------------------------------------------- journal ----

_JOURNAL_FORMAT = "terastal-sampler-journal"
_JOURNAL_VERSION = 1


def _json_normalize(obj):
    """Canonical JSON value (tuples -> lists) for header comparison."""
    return json.loads(json.dumps(obj))


def _header(campaign: Campaign, config: SamplerConfig) -> Dict:
    return _json_normalize(
        {
            "format": _JOURNAL_FORMAT,
            "version": _JOURNAL_VERSION,
            "campaign": dataclasses.asdict(campaign),
            "config": dataclasses.asdict(config),
        }
    )


def _rebuilt_header(head: Dict) -> Optional[Dict]:
    """Re-serialize a stored header through the CURRENT dataclasses.

    A journal written before a default-valued field existed (e.g.
    ``Campaign.round_kernel``) stores a header without it; rebuilding
    fills the default, so such journals stay resumable — exactly when
    the resumed campaign is otherwise identical.  Returns ``None`` for
    headers the current dataclasses cannot represent (removed/renamed
    fields), which the caller treats as a genuine mismatch."""
    try:
        return _header(
            Campaign(**head["campaign"]), SamplerConfig(**head["config"])
        )
    except (KeyError, TypeError):
        return None


def _result_record(res: TrialResult) -> Dict:
    d = dataclasses.asdict(res)
    spec = d.pop("spec")
    return {"kind": "trial", "spec": spec, "result": d}


def _result_from_record(rec: Dict) -> TrialResult:
    spec = TrialSpec(**rec["spec"])
    fields = dict(rec["result"])
    fields["utilization"] = tuple(fields["utilization"])
    return TrialResult(spec=spec, **fields)


class SamplerJournal:
    """Append-only JSON-lines record of completed trials.

    Line 1 is a header binding the journal to one (campaign, config)
    pair; every further line is one completed ``TrialResult``.  Floats
    survive the round trip exactly (``json`` emits shortest round-trip
    reprs), so a resumed run continues bit-identically.  A truncated
    final line — the signature of a killed run — is ignored."""

    def __init__(self, path: str, campaign: Campaign, config: SamplerConfig):
        self.path = path
        self.header = _header(campaign, config)
        self.cache: Dict[Tuple, TrialResult] = {}
        if os.path.exists(path):
            self._load()
        # (Re)write header + every recovered record: a killed run can
        # leave a truncated final line, and appending after it would
        # corrupt the next record too — rewriting from the loaded cache
        # heals the file and costs one linear pass.
        self._fh = open(path, "w")
        self._write_line(self.header)
        for res in self.cache.values():
            self._write_line(_result_record(res))

    def _load(self) -> None:
        with open(self.path) as fh:
            lines = fh.read().splitlines()
        if not lines:
            return
        try:
            head = json.loads(lines[0])
        except json.JSONDecodeError as e:
            raise ValueError(f"journal {self.path}: unreadable header: {e}") from e
        if head.get("format") != _JOURNAL_FORMAT:
            raise ValueError(f"journal {self.path}: not a sampler journal")
        if head != self.header and _rebuilt_header(head) != self.header:
            raise ValueError(
                f"journal {self.path} was written by a different campaign/"
                "config; refusing to resume (delete it to start over)"
            )
        for line in lines[1:]:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break  # truncated tail from a killed run: replay stops here
            if rec.get("kind") != "trial":
                continue
            res = _result_from_record(rec)
            self.cache[dataclasses.astuple(res.spec)] = res

    def _write_line(self, obj) -> None:
        self._fh.write(json.dumps(obj) + "\n")
        self._fh.flush()

    def record(self, res: TrialResult) -> None:
        key = dataclasses.astuple(res.spec)
        if key not in self.cache:
            self.cache[key] = res
            self._write_line(_result_record(res))

    def close(self) -> None:
        self._fh.close()


# ------------------------------------------------------------ main loop ----


def run_adaptive(
    campaign: Campaign,
    config: Optional[SamplerConfig] = None,
    *,
    parallel: bool = True,
    max_workers: Optional[int] = None,
    journal: Optional[str] = None,
) -> AdaptiveResult:
    """Run ``campaign`` through the sequential sampler (see module doc).

    The campaign's ``seeds`` ladder is both the replicate order and the
    per-cell cap; ``Campaign.run()`` on the same campaign is exactly the
    always-run-to-cap special case (``SamplerConfig(stopping=False)``
    reproduces it trial-for-trial)."""
    config = config or SamplerConfig()
    grid = campaign.trials()
    cap = len(campaign.seeds)
    if cap < 1:
        raise ValueError("campaign has no seeds")
    if config.stopping and cap < 2:
        raise DegenerateSampleError(
            "adaptive sampling needs a seed ladder of >= 2 (one seed has "
            "no paired variance); pass SamplerConfig(stopping=False) or "
            "grow Campaign.seeds"
        )

    # Cell -> its full seed-ladder spec list, in grid order.
    cell_specs: Dict[Tuple, List[TrialSpec]] = {}
    grid_index = {dataclasses.astuple(s): i for i, s in enumerate(grid)}
    for s in grid:
        cell_specs.setdefault(_cell_of(s), []).append(s)

    # Comparison topology: baseline vs every other scheduler per group.
    comparisons: List[Tuple[Tuple, str]] = []  # (group, scheduler)
    cell_by_group: Dict[Tuple, Dict[str, Tuple]] = {}
    for cell in cell_specs:
        cell_by_group.setdefault(_group_of(cell), {})[_sched_of(cell)] = cell
    if config.stopping:
        for group, scheds in cell_by_group.items():
            if config.baseline not in scheds:
                raise ValueError(
                    f"baseline scheduler {config.baseline!r} is not in the "
                    f"campaign grid for group {dict(zip(GROUP_FIELDS, group))}"
                )
            comparisons += [(group, s) for s in scheds if s != config.baseline]
        if not comparisons:
            raise ValueError(
                "nothing to compare: the grid only contains the baseline "
                f"scheduler {config.baseline!r} (add a second scheduler or "
                "pass SamplerConfig(stopping=False))"
            )

    looks = config.looks(cap)
    per_look_alpha = config.alpha / len(looks)

    jrnl = SamplerJournal(journal, campaign, config) if journal else None
    done: Dict[Tuple, List[TrialResult]] = {cell: [] for cell in cell_specs}
    undecided = dict.fromkeys(comparisons)  # insertion-ordered set
    verdicts: Dict[Tuple[Tuple, str], GapVerdict] = {}
    metric = config.metric
    rounds = 0

    def active_cells() -> List[Tuple]:
        if not config.stopping:
            return list(cell_specs)
        alive = set()
        for group, sched in undecided:
            alive.add(cell_by_group[group][sched])
            alive.add(cell_by_group[group][config.baseline])
        return [c for c in cell_specs if c in alive]

    try:
        with TrialExecutor(
            campaign.cell_keys(), parallel=parallel, max_workers=max_workers
        ) as ex:
            for k in looks:
                batch = [
                    spec
                    for cell in active_cells()
                    for spec in cell_specs[cell][len(done[cell]) : k]
                ]
                if batch:
                    rounds += 1
                # Serve journal-cached trials without re-execution; run the
                # rest through the pool, journaling in deterministic order.
                fresh = [
                    s
                    for s in batch
                    if jrnl is None or dataclasses.astuple(s) not in jrnl.cache
                ]
                # engine="batch" grids: run_batch groups a cell's fresh
                # seed replicates and runs each group as one device
                # program (campaign.run_trial_batch), so every sampler
                # look rides the batched engine without special-casing
                # here; journal order is unchanged (specs order).
                executed = ex.run_batch(
                    fresh, on_result=jrnl.record if jrnl else None
                )
                by_key = {dataclasses.astuple(r.spec): r for r in executed}
                for s in batch:
                    key = dataclasses.astuple(s)
                    res = by_key.get(key) or jrnl.cache[key]
                    done[_cell_of(s)].append(res)

                if not config.stopping:
                    continue
                final = k == looks[-1]
                for group, sched in list(undecided):
                    a = done[cell_by_group[group][sched]]
                    b = done[cell_by_group[group][config.baseline]]
                    if len(a) < k or len(b) < k:  # cap shorter than min_seeds
                        continue
                    pairs = list(zip(a[:k], b[:k]))
                    diffs = [
                        getattr(x, metric) - getattr(y, metric) for x, y in pairs
                    ]
                    # Seed-invariant cells: every replicate of *each* cell
                    # produced the identical simulation outcome (the
                    # signature of strictly periodic cells whose arrival
                    # stream consumes no randomness), so the paired gap is
                    # a constant and further seeds cannot move it.  A
                    # nonzero constant gap separates via the zero-variance
                    # t-test below; a zero one is a certain tie — stop
                    # instead of spending the rest of the ladder on a CI
                    # that will stay [0, 0].
                    invariant = (
                        len({_outcome(x) for x, _ in pairs}) == 1
                        and len({_outcome(y) for _, y in pairs}) == 1
                    )
                    lo, hi, sep = gap_separates(
                        diffs,
                        alpha=per_look_alpha,
                        n_boot=config.n_boot,
                        ci_seed=config.ci_seed,
                    )
                    if sep or invariant or final:
                        mean_gap = float(np.mean(diffs))
                        winner = (
                            "tie"
                            if mean_gap == 0.0
                            else (sched if mean_gap < 0.0 else config.baseline)
                        )
                        verdicts[(group, sched)] = GapVerdict(
                            group=group,
                            scheduler=sched,
                            baseline=config.baseline,
                            n_seeds=k,
                            mean_gap=mean_gap,
                            ci_lo=lo,
                            ci_hi=hi,
                            separated=sep,
                            winner=winner,
                            reason="separated"
                            if sep
                            else ("invariant" if invariant else "cap"),
                        )
                        del undecided[(group, sched)]
                if not undecided:
                    break
    finally:
        if jrnl is not None:
            jrnl.close()

    trials = sorted(
        (r for results in done.values() for r in results),
        key=lambda r: grid_index[dataclasses.astuple(r.spec)],
    )
    return AdaptiveResult(
        campaign=campaign,
        config=config,
        trials=trials,
        verdicts=[verdicts[c] for c in comparisons],
        rounds=rounds,
        n_trials_cap=len(grid),
    )


def fixed_grid_verdicts(
    result: CampaignResult,
    baseline: str = "terastal",
    metric: str = "mean_miss_rate",
) -> List[GapVerdict]:
    """The fixed grid's winner per comparison — the reference the
    adaptive sampler's verdicts are matched against (sign of the mean
    paired gap over the full seed ladder; no CI, the fixed grid never
    computed one to decide)."""
    by_cell: Dict[Tuple, List[TrialResult]] = {}
    for t in result.trials:
        by_cell.setdefault(_cell_of(t.spec), []).append(t)
    cell_by_group: Dict[Tuple, Dict[str, Tuple]] = {}
    for cell in by_cell:
        cell_by_group.setdefault(_group_of(cell), {})[_sched_of(cell)] = cell
    out = []
    for group, scheds in cell_by_group.items():
        if baseline not in scheds:
            continue
        base = by_cell[cell_by_group[group][baseline]]
        for sched in scheds:
            if sched == baseline:
                continue
            other = by_cell[cell_by_group[group][sched]]
            diffs = [
                getattr(x, metric) - getattr(y, metric)
                for x, y in zip(other, base)
            ]
            mean_gap = float(np.mean(diffs))
            winner = (
                "tie" if mean_gap == 0.0 else (sched if mean_gap < 0.0 else baseline)
            )
            out.append(
                GapVerdict(
                    group=group,
                    scheduler=sched,
                    baseline=baseline,
                    n_seeds=len(diffs),
                    mean_gap=mean_gap,
                    ci_lo=float("nan"),
                    ci_hi=float("nan"),
                    separated=False,
                    winner=winner,
                )
            )
    return out

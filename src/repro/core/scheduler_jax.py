"""Algorithm 2 as a jitted, fixed-shape ``jax.lax`` program.

One Terastal scheduling round (stage 1: urgency-ordered virtual-deadline
assignment with variant fallback; stage 2: earliest-finish-guarded
backfill by slack gain) re-expressed over padded arrays:

  ready_mask [NJ]          valid request-layer slots
  vdl       [NJ]           absolute virtual deadline of the ready layer
                           (static plan table OR the request's dynamic
                           ``vdl_abs`` state from an online budget
                           policy — pack_view resolves both through
                           ``TerastalScheduler.vdl``, so Python/JAX
                           parity holds under dynamic virtual deadlines)
  vdl_next  [NJ]           Eq. 8's d^v_{l+1} (absolute deadline if last)
  next_min  [NJ]           min_k c_{l+1,k}   (0 if last layer)
  lat       [NJ, NA]       original latencies
  lat_var   [NJ, NA]       variant latencies (+inf when no variant or the
                           accumulated combo would violate theta — the
                           host precomputes incremental V_m membership)
  tau       [NA]           accelerator next-free times
  idle_mask [NA]

Outputs: assign_acc [NJ] (-1 = unassigned), assign_var [NJ] (bool), and
assign_seq [NJ] — the reference emission order (stage-1 assignments
carry their sorted-order position, stage-2 assignments NJ + k), which
the SoA engine needs because the order assignments are emitted fixes
the finish-event push counters (how simultaneous finishes tie-break).

Tie-breaking matches the Python reference bit-for-bit (stable argsort on
best-case slack == sorted(..., key=(slack, rid)); first-minimum argmin ==
min(key=...); first-maximum argmax == strict-improvement replacement),
property-tested in tests/test_scheduler_jax.py.  The round runs in
float64 (x64 enabled at import): every add/sub/compare is then the same
IEEE op the Python kernels execute, so the jitted round is bit-identical
on arbitrary latency tables, not just dyadic ones — a requirement for
the engine dispatch path (``REPRO_ROUND_KERNEL=jax``), whose SimResults
are pinned against the reference engine.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax

# The jitted round must reproduce the Python schedulers' float64
# arithmetic exactly; without x64, inputs silently downcast to f32 and
# bit-parity only holds on dyadic grids.  Enabled before any tracing.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

EPS = 1e-15
NEG = -1e30

#: stage-2 guard variants of TerastalScheduler.backfill_mode (static
#: compile-time argument of :func:`terastal_round`).
BACKFILL_MODES = ("ef", "positive", "paper")


class RoundInputs(NamedTuple):
    ready_mask: jax.Array  # [NJ] bool
    vdl: jax.Array  # [NJ]
    vdl_next: jax.Array  # [NJ]
    next_min: jax.Array  # [NJ]
    lat: jax.Array  # [NJ, NA]
    lat_var: jax.Array  # [NJ, NA]
    tau: jax.Array  # [NA]
    idle_mask: jax.Array  # [NA] bool


class RoundOutputs(NamedTuple):
    assign_acc: jax.Array  # [NJ] int32, -1 = none
    assign_var: jax.Array  # [NJ] bool
    assign_seq: jax.Array  # [NJ] int32 emission order; NJ + NA = unassigned


def _best_case_slack(inp: RoundInputs, tau: jax.Array) -> jax.Array:
    finish = tau[None, :] + inp.lat  # [NJ, NA]
    return inp.vdl - finish.min(axis=1)


@partial(jax.jit, static_argnames=("mode",))
def terastal_round(inp: RoundInputs, mode: str = "ef") -> RoundOutputs:
    if mode not in BACKFILL_MODES:
        raise ValueError(f"unknown backfill mode {mode!r} (have {BACKFILL_MODES})")
    NJ, NA = inp.lat.shape
    inf = jnp.inf

    s_star0 = jnp.where(inp.ready_mask, _best_case_slack(inp, inp.tau), inf)
    order = jnp.argsort(s_star0, stable=True)  # ties -> lower slot index

    # ---------------- stage 1 ----------------
    def stage1_body(i, state):
        idle, tau, acc, var, seq, remaining = state
        j = order[i]
        active = inp.ready_mask[j] & remaining[j]
        d_v = inp.vdl[j]

        def try_impl(lat_row):
            finish = tau + lat_row
            cand = idle & (finish <= d_v + EPS) & jnp.isfinite(lat_row)
            masked = jnp.where(cand, finish, inf)
            k = jnp.argmin(masked)
            return cand.any(), k, lat_row[k]

        ok1, k1, c1 = try_impl(inp.lat[j])
        ok2, k2, c2 = try_impl(inp.lat_var[j])
        use1 = active & ok1
        use2 = active & ~ok1 & ok2
        k = jnp.where(use1, k1, k2)
        c = jnp.where(use1, c1, c2)
        assigned = use1 | use2
        idle = jnp.where(assigned, idle.at[k].set(False), idle)
        tau = jnp.where(assigned, tau.at[k].add(c), tau)
        acc = jnp.where(assigned, acc.at[j].set(k.astype(jnp.int32)), acc)
        var = jnp.where(assigned, var.at[j].set(use2), var)
        seq = jnp.where(assigned, seq.at[j].set(i.astype(jnp.int32)), seq)
        remaining = jnp.where(assigned, remaining.at[j].set(False), remaining)
        return idle, tau, acc, var, seq, remaining

    idle = inp.idle_mask
    tau = inp.tau
    acc0 = jnp.full((NJ,), -1, jnp.int32)
    var0 = jnp.zeros((NJ,), bool)
    seq0 = jnp.full((NJ,), NJ + NA, jnp.int32)
    remaining0 = inp.ready_mask
    idle, tau, acc, var, seq, remaining = jax.lax.fori_loop(
        0, NJ, stage1_body, (idle, tau, acc0, var0, seq0, remaining0)
    )

    # ---------------- stage 2: guarded backfill ----------------
    def stage2_body(k, state):
        idle, tau, acc, var, seq, remaining = state
        k_idle = idle[k]
        s_star = _best_case_slack(inp, tau)  # [NJ] current tau

        def score(lat_tab):
            c = lat_tab[:, k]
            finish = tau[k] + c
            allowed = remaining & jnp.isfinite(c)
            if mode == "ef":
                # earliest-finish optimality guard across ALL accelerators
                ef_all = (tau[None, :] + lat_tab).min(axis=1)
                allowed = allowed & (finish <= ef_all + EPS)
            s_f = inp.vdl_next - finish - inp.next_min
            return jnp.where(allowed, s_f - s_star, -inf)

        d_orig = score(inp.lat)  # [NJ] (slot order)
        d_var = score(inp.lat_var)
        # python iterates `remaining` in STAGE-1 SORTED order (j outer,
        # original-then-variant inner), replacing only on strictly-greater
        # (delta, -use_var) — permute through `order` and take the FIRST
        # maximum so exact ties resolve identically.
        d_orig_p, d_var_p = d_orig[order], d_var[order]
        flat = jnp.stack([d_orig_p, d_var_p], axis=1).reshape(-1)  # [NJ*2]
        rank = jnp.stack(
            [jnp.zeros_like(d_orig_p), -jnp.ones_like(d_var_p)], axis=1
        ).reshape(-1)
        best = jnp.argmax(flat)  # first max in sorted order
        is_max = flat == flat[best]
        best = jnp.argmax(jnp.where(is_max, rank, -inf))
        j = order[best // 2]
        use_var = (best % 2).astype(bool)
        have = k_idle & jnp.isfinite(flat[best]) & (flat[best] > -inf)
        if mode == "positive":
            have = have & (flat[best] > 0.0)
        c = jnp.where(use_var, inp.lat_var[j, k], inp.lat[j, k])
        idle = jnp.where(have, idle.at[k].set(False), idle)
        tau = jnp.where(have, tau.at[k].add(c), tau)
        acc = jnp.where(have, acc.at[j].set(jnp.int32(k)), acc)
        var = jnp.where(have, var.at[j].set(use_var), var)
        seq = jnp.where(have, seq.at[j].set(jnp.int32(NJ + k)), seq)
        remaining = jnp.where(have, remaining.at[j].set(False), remaining)
        return idle, tau, acc, var, seq, remaining

    idle, tau, acc, var, seq, remaining = jax.lax.fori_loop(
        0, NA, stage2_body, (idle, tau, acc, var, seq, remaining)
    )
    return RoundOutputs(acc, var, seq)


# --------------------------------------------------------------- adapter ----


#: smallest NJ bucket; NJ pads up to the next power of two above this.
BUCKET_MIN = 4

#: persistent host-side staging buffers, one set per (NJ_pad, NA) bucket.
#: Reused across pack_view calls so a sweep over ready-queue sizes does
#: not reallocate, and — the real win — ``terastal_round`` sees only
#: O(log max_NJ) distinct shapes, so it compiles once per bucket instead
#: of re-jitting on every ready-queue size.
_HOST_BUFFERS: dict = {}


def bucket_nj(nj: int) -> int:
    """Pad a ready-queue size to its power-of-two shape bucket."""
    if nj <= BUCKET_MIN:
        return BUCKET_MIN
    return 1 << (nj - 1).bit_length()


def _buffers(nj_pad: int, na: int):
    key = (nj_pad, na)
    buf = _HOST_BUFFERS.get(key)
    if buf is None:
        buf = {
            "ready": np.zeros(nj_pad, bool),
            "vdl": np.zeros(nj_pad),
            "vdl_next": np.zeros(nj_pad),
            "next_min": np.zeros(nj_pad),
            "lat": np.full((nj_pad, na), np.inf),
            "lat_var": np.full((nj_pad, na), np.inf),
        }
        _HOST_BUFFERS[key] = buf
    return buf


def pack_view(view, scheduler) -> Tuple[RoundInputs, list]:
    """Build RoundInputs from a SchedView + TerastalScheduler (host side).
    Returns (inputs, slot->request list).  ``vdl``/``vdl_next`` come from
    ``scheduler.vdl``, which prefers a request's dynamic ``vdl_abs`` state
    (online budget policies) over the frozen plan table — the jitted round
    needs no change for dynamic budgets.

    NJ is padded to a power-of-two shape bucket (>= ``BUCKET_MIN``) with
    persistent host buffers: padded slots have ``ready_mask=False`` (so
    stage 1 skips them and stage 2's ``remaining`` mask never admits
    them) and +inf latency rows, and ``terastal_round`` recompiles at
    most once per bucket per process instead of once per ready-queue
    size — pinned by a compilation-counter test."""
    reqs = sorted(view.ready, key=lambda r: r.rid)
    NJ, NA = len(reqs), view.n_acc
    NJ_pad = bucket_nj(NJ)
    buf = _buffers(NJ_pad, NA)
    ready = buf["ready"]
    vdl = buf["vdl"]
    vdl_next = buf["vdl_next"]
    next_min = buf["next_min"]
    lat = buf["lat"]
    lat_var = buf["lat_var"]
    # reset the pad region (buffers are reused across different NJ)
    ready[:NJ] = True
    ready[NJ:] = False
    vdl[NJ:] = 0.0
    vdl_next[NJ:] = 0.0
    next_min[NJ:] = 0.0
    lat[NJ:] = np.inf
    lat_var[NJ:] = np.inf
    for i, r in enumerate(reqs):
        plan = view.plans[r.model_idx]
        l = r.next_layer
        vdl[i] = scheduler.vdl(plan, r, l)
        if l + 1 < len(plan.model.layers):
            vdl_next[i] = scheduler.vdl(plan, r, l + 1)
            next_min[i] = float(plan.lat[l + 1].min())
        else:
            vdl_next[i] = r.deadline_abs
            next_min[i] = 0.0
        lat[i] = plan.lat[l]
        if scheduler._variant_ok(plan, r, l):
            lat_var[i] = plan.lat_var[l]
        else:
            lat_var[i] = np.inf
    tau = np.array([view.tau(k) for k in range(NA)])
    idle = np.array([view.acc_busy_until[k] <= view.now + 1e-15 for k in range(NA)])
    inp = RoundInputs(
        ready_mask=jnp.asarray(ready),
        vdl=jnp.asarray(vdl),
        vdl_next=jnp.asarray(vdl_next),
        next_min=jnp.asarray(next_min),
        lat=jnp.asarray(lat),
        lat_var=jnp.asarray(lat_var),
        tau=jnp.asarray(tau),
        idle_mask=jnp.asarray(idle),
    )
    return inp, reqs


def pack_arrays(
    vdl: np.ndarray,
    vdl_next: np.ndarray,
    next_min: np.ndarray,
    lat: np.ndarray,
    lat_var: np.ndarray,
    tau: np.ndarray,
    idle: np.ndarray,
) -> RoundInputs:
    """Stage already-vectorized per-slot arrays into the persistent
    bucket buffers — the SoA engine's deep-round path (its ready block
    keeps these exact arrays as incrementally maintained mirrors, so the
    host side of a jitted round is a handful of slice copies, not a
    per-request Python loop like :func:`pack_view`).  Slots must arrive
    in ascending-rid order (stable argsort ties = ``(slack, rid)``).
    One host->device staging per field; same pow2 NJ shape buckets."""
    NJ, NA = lat.shape
    NJ_pad = bucket_nj(NJ)
    buf = _buffers(NJ_pad, NA)
    ready = buf["ready"]
    ready[:NJ] = True
    ready[NJ:] = False
    for name, src, pad in (
        ("vdl", vdl, 0.0),
        ("vdl_next", vdl_next, 0.0),
        ("next_min", next_min, 0.0),
        ("lat", lat, np.inf),
        ("lat_var", lat_var, np.inf),
    ):
        dst = buf[name]
        dst[:NJ] = src
        dst[NJ:] = pad
    return RoundInputs(
        ready_mask=jnp.asarray(ready),
        vdl=jnp.asarray(buf["vdl"]),
        vdl_next=jnp.asarray(buf["vdl_next"]),
        next_min=jnp.asarray(buf["next_min"]),
        lat=jnp.asarray(buf["lat"]),
        lat_var=jnp.asarray(buf["lat_var"]),
        tau=jnp.asarray(tau),
        idle_mask=jnp.asarray(idle),
    )


# ------------------------------------------- batched trial staging ----

#: persistent seed-major staging buffers for the device-resident trial
#: engine (``repro.core.engine_batch``), one set per (B_pad, NR_pad)
#: bucket.  Same idea as ``_HOST_BUFFERS`` one level up: the batch
#: engine's jitted program sees only O(log max_B x log max_NR) distinct
#: shapes, so it compiles once per (seed-bucket, horizon-bucket) pair —
#: pinned by a compilation-counter test in tests/test_round_kernels.py.
_TRIAL_BUFFERS: dict = {}


def _trial_buffers(b_pad: int, nr_pad: int):
    key = (b_pad, nr_pad)
    buf = _TRIAL_BUFFERS.get(key)
    if buf is None:
        buf = {
            # +1 sentinel column: the event loop peeks arr_t[ai] with
            # ai == n_ev after the last arrival; the pad is +inf so the
            # peek reads "no more arrivals" without a bounds branch.
            "arr_t": np.full((b_pad, nr_pad + 1), np.inf),
            "arr_m": np.zeros((b_pad, nr_pad), np.int32),
            "dl": np.full((b_pad, nr_pad), np.inf),
            "dl12": np.full((b_pad, nr_pad), np.inf),
            "n_ev": np.zeros(b_pad, np.int32),
        }
        _TRIAL_BUFFERS[key] = buf
    return buf


def bucket_ev(n: int) -> int:
    """Pad an event-horizon length to its shape bucket.

    Finer-grained than ``bucket_nj``: rungs at every power of two AND at
    1.5x the previous one (..., 96, 128, 192, 256, 384, ...).  The batch
    engine's per-iteration cost is linear in the padded horizon, so pow2
    rounding's worst case (~2x dead width just past a boundary) is real
    wall-clock; the extra rungs cap the waste at ~33% for one more
    compile-cache entry per size class."""
    n = max(int(n), BUCKET_MIN)
    p = 1 << (n - 1).bit_length()
    h = (p >> 1) + (p >> 2)      # 1.5 * previous pow2 rung
    return h if n <= h else p


def pack_trials(events: "list[tuple]", deadline_by_model: np.ndarray):
    """Stage B seeds' pre-generated release events into the persistent
    seed-major trial buffers (the batched counterpart of
    :func:`pack_arrays`).

    ``events`` is ``[(times, models)]`` per seed — the output of
    ``workload.batch_release_events`` — and ``deadline_by_model`` maps
    model_idx -> relative deadline.  Both the seed axis and the event
    horizon are padded to pow2 shape buckets (``bucket_nj``), so the
    batch engine's jitted program compiles once per (B, NR) bucket pair;
    pad lanes carry ``n_ev = 0`` (immediately drained) and pad slots
    ``arr_t = +inf`` (never popped).  Absolute deadlines are computed
    here with the same IEEE-f64 adds the reference engine performs per
    request (``now + plan.deadline``; ``dl12 = dl + 1e-12`` mirrors its
    inline miss/drop epsilon), so downstream comparisons are bit-equal.

    Returns ``(buf, b_pad, nr_pad)`` where ``buf`` holds the padded
    numpy arrays (views of the persistent buffers — consume before the
    next call)."""
    B = len(events)
    NR = max((len(t) for t, _ in events), default=0)
    b_pad = bucket_nj(B)
    nr_pad = bucket_ev(max(NR, 1))
    buf = _trial_buffers(b_pad, nr_pad)
    buf["arr_t"][:] = np.inf
    buf["dl"][:] = np.inf
    buf["dl12"][:] = np.inf
    buf["arr_m"][:] = 0
    buf["n_ev"][:] = 0
    for b, (times, models) in enumerate(events):
        n = len(times)
        buf["n_ev"][b] = n
        if not n:
            continue
        buf["arr_t"][b, :n] = times
        buf["arr_m"][b, :n] = models
        dl = times + deadline_by_model[models]
        buf["dl"][b, :n] = dl
        buf["dl12"][b, :n] = dl + 1e-12
    return buf, b_pad, nr_pad


_FAULT_CODES = {"down": 0, "up": 1, "scale": 2}


def pack_fault_epochs(fault_model, plans, duration, seeds, b_pad: int, lp: int):
    """Pre-bind each lane's capability timeline as time-indexed epoch
    planes for the batch engine's fault path.

    A lane's capability state is piecewise-constant between its fault
    events, so the whole timeline is NF events plus NF+1 *epochs*; this
    stages, per lane, the event stream (``fe_t``/``fe_acc``/``fe_code``/
    ``fe_val``/``n_f``) and, per epoch, every capability-derived table
    the round kernels read — the ``[NA]`` latency multiplier
    (``mult_ep``), the virtual-deadline chains (``vdlr_ep``; the
    re-tightened chains under ``retighten=true`` via
    ``faults.retightened_vdl``, the frozen offline chains otherwise),
    the remaining-min suffix sums (``rm_ep``) and per-layer min
    latencies (``minl_ep``).  All planes are replayed event-by-event
    through the exact host helpers the scalar engines call
    (``effective_plans`` / ``fault_multipliers``), so fault-time
    arithmetic is bit-identical by construction.

    The event axis is padded to a pow2 bucket (one compile per bucket);
    pad events carry ``fe_t = +inf`` (never popped) and pad epochs
    repeat the lane's final capability state (never entered).  Returns
    ``(fbuf, nf_pad, n_spans)`` with ``n_spans`` the per-seed
    intersecting-window counts for ``SimResult.faulted_spans``.
    """
    from repro.core.faults import (
        effective_plans,
        fault_multipliers,
        retightened_vdl,
    )

    M = len(plans)
    NA = plans[0].platform.n_acc
    timelines = [fault_model.timeline(NA, duration, s) for s in seeds]
    NF = max((len(ev) for ev, _ in timelines), default=0)
    nf_pad = 1 << (max(NF, 1) - 1).bit_length()

    fbuf = {
        # +1 sentinel column: the loop peeks fe_t[fi] with fi == n_f
        # after the last fault; +inf reads "no more faults"
        "fe_t": np.full((b_pad, nf_pad + 1), np.inf),
        "fe_acc": np.zeros((b_pad, nf_pad), np.int32),
        "fe_code": np.zeros((b_pad, nf_pad), np.int32),
        "fe_val": np.ones((b_pad, nf_pad)),
        "n_f": np.zeros(b_pad, np.int32),
        "mult_ep": np.ones((b_pad, nf_pad + 1, NA)),
        "vdlr_ep": np.zeros((b_pad, nf_pad + 1, M, lp + 1)),
        "rm_ep": np.zeros((b_pad, nf_pad + 1, M, lp + 2)),
        "minl_ep": np.zeros((b_pad, nf_pad + 1, M, lp)),
    }

    def fill_epoch(b, e, eff, mult):
        fbuf["mult_ep"][b, e] = mult
        chains = (
            retightened_vdl(plans, eff)
            if fault_model.retighten
            else [None] * M
        )
        for m, (p, ep) in enumerate(zip(plans, eff)):
            L = len(p.model.layers)
            ch = chains[m]
            fbuf["vdlr_ep"][b, e, m, :L] = p.vdl_rel if ch is None else ch
            fbuf["rm_ep"][b, e, m, : L + 1] = ep.remaining_min
            fbuf["minl_ep"][b, e, m, :L] = ep.min_lat

    nominal = fault_multipliers([1.0] * NA, [True] * NA)
    fill_epoch(0, 0, plans, nominal)
    # broadcast the nominal epoch everywhere (pad lanes, epoch 0, and pad
    # epochs start from it; the replay below overwrites live epochs)
    for key in ("mult_ep", "vdlr_ep", "rm_ep", "minl_ep"):
        fbuf[key][:, :] = fbuf[key][0, 0]

    n_spans = []
    for b, (events, spans) in enumerate(timelines):
        n_spans.append(spans)
        fbuf["n_f"][b] = len(events)
        avail = [True] * NA
        fscale = [1.0] * NA
        for e_i, ev in enumerate(events):
            fbuf["fe_t"][b, e_i] = ev.t
            fbuf["fe_acc"][b, e_i] = ev.acc
            fbuf["fe_code"][b, e_i] = _FAULT_CODES[ev.code]
            fbuf["fe_val"][b, e_i] = ev.value if ev.code == "scale" else 1.0
            if ev.code == "down":
                avail[ev.acc] = False
            elif ev.code == "up":
                avail[ev.acc] = True
            else:
                fscale[ev.acc] = ev.value
            mult = fault_multipliers(fscale, avail)
            eff = effective_plans(plans, mult)
            fill_epoch(b, e_i + 1, eff, mult)
        # pad epochs (fi never reaches them) repeat the final state
        if len(events) < nf_pad:
            for key in ("mult_ep", "vdlr_ep", "rm_ep", "minl_ep"):
                fbuf[key][b, len(events) + 1 :] = fbuf[key][b, len(events)]
    return fbuf, nf_pad, n_spans

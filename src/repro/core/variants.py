"""Layer-variant design (paper Sec. IV-B) and the per-model offline plan.

Given a model's latency table and the Algorithm-1 budgets/constraint
levels, select latency-critical layers (those whose constraint level
excluded at least one accelerator), and for each design the minimum-gamma
S2D/D2S variant that brings the excluded accelerators' latency down to
the next constraint level or below the preferred accelerator's latency
(the paper's evaluation uses the latter criterion; gamma in {2, 3}).

The offline product is a :class:`ModelPlan`: latency tables for originals
and variants, virtual budgets, per-variant accuracy losses, and the valid
combination set ``V_m`` (all subsets whose retained accuracy >= theta_m).
Because adding a variant only ever reduces accuracy, validity is
*downward-closed*, so the scheduler's incremental membership test
``is_valid_combo(applied | {l})`` is exactly equivalent to consulting the
enumerated ``V_m`` — we provide both forms (enumeration for the paper's
figures, O(set) incremental check for the hot path).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.accuracy import combo_retained_fraction, layer_variant_loss
from repro.core.budget import BudgetResult, distribute_budgets, distribute_budgets_dag
from repro.core.dag import LayerDag
from repro.costmodel.dnn_zoo import DnnModel
from repro.costmodel.layers import LayerSpec, make_variant, variant_feasible
from repro.costmodel.maestro import Dataflow, Platform, layer_latency, model_latency_table

GAMMAS = (2, 3)  # paper Sec. V-B1: gamma in {2, 3} suffices


@dataclasses.dataclass(frozen=True)
class VariantInfo:
    layer_idx: int
    gamma: int
    direction: str  # "d2s" | "s2d"
    spec: LayerSpec
    latencies: np.ndarray  # [n_acc] profiled variant latency per accelerator
    loss: float  # relative accuracy loss of this single variant
    storage_weights: int  # extra weights stored


@dataclasses.dataclass
class ModelPlan:
    """Everything the online scheduler needs about one model."""

    model: DnnModel
    platform: Platform
    deadline: float
    lat: np.ndarray  # [L, n_acc] original latencies
    budget: BudgetResult
    variants: Dict[int, VariantInfo]  # layer_idx -> variant
    theta: float  # accuracy threshold (relative to baseline)
    #: precedence structure; None == linear chain (the degenerate case,
    #: which keeps every pre-DAG code path — and its floats — untouched)
    dag: Optional[LayerDag] = None

    # ---- derived tables (cached: consumed in the simulator hot loop) -------
    @functools.cached_property
    def lat_var(self) -> np.ndarray:
        """[L, n_acc] variant latencies; +inf where no variant exists."""
        out = np.full_like(self.lat, np.inf)
        for idx, v in self.variants.items():
            out[idx] = v.latencies
        return out

    @functools.cached_property
    def min_lat(self) -> np.ndarray:
        """[L] minimum achievable latency per layer (original impl)."""
        return self.lat.min(axis=1)

    @functools.cached_property
    def min_lat_any(self) -> np.ndarray:
        """[L] minimum over original AND variant implementations."""
        return np.minimum(self.lat.min(axis=1), self.lat_var.min(axis=1))

    @functools.cached_property
    def lat_skew(self) -> np.ndarray:
        """[L] cross-accelerator latency skew (max/min) per layer."""
        return self.lat.max(axis=1) / self.lat.min(axis=1)

    @functools.cached_property
    def remaining_min(self) -> np.ndarray:
        """[L+1] sum of min original latencies of layers >= l (for drops/EDF)."""
        rm = np.zeros(len(self.model.layers) + 1)
        rm[:-1] = np.cumsum(self.min_lat[::-1])[::-1]
        return rm

    @functools.cached_property
    def vdl_rel(self) -> np.ndarray:
        """[L] relative virtual deadlines (Eq. 2): cumsum of budgets for
        linear chains (same floats as ever), the critical-path targets
        computed by ``tighten_budgets_dag`` for DAG plans."""
        return self.budget.virtual_deadlines

    @functools.cached_property
    def crit_from(self) -> np.ndarray:
        """[L] minimum remaining work from node l to request completion,
        inclusive of l: the critical path over ``min_lat`` of the
        sub-DAG rooted at l.  For linear chains this IS
        ``remaining_min[:-1]`` (the same floats — a slice, not a
        recompute — which keeps EDF/DREAM/drop decisions bit-identical
        through the refactor)."""
        if self.dag is None:
            return self.remaining_min[:-1]
        cf = np.zeros(len(self.model.layers))
        for l in reversed(self.dag.topo):
            ss = self.dag.succs[l]
            tail = max((float(cf[s]) for s in ss), default=0.0)
            cf[l] = float(self.min_lat[l]) + tail
        return cf

    @functools.cached_property
    def crit_after(self) -> np.ndarray:
        """[L] minimum work strictly after node l (0.0 at the sink):
        ``remaining_min[1:]`` for linear chains, max over successors of
        ``crit_from`` for DAGs.  EDF's per-layer deadline and Terastal's
        budget-free virtual deadline read this."""
        if self.dag is None:
            return self.remaining_min[1:]
        ca = np.zeros(len(self.model.layers))
        for l in range(len(ca)):
            ca[l] = max(
                (float(self.crit_from[s]) for s in self.dag.succs[l]),
                default=0.0,
            )
        return ca

    @functools.cached_property
    def crit_total(self) -> float:
        """Minimum end-to-end work of one request (admission work
        estimates): ``remaining_min[0]`` for linear chains, the longest
        source-to-sink path for DAGs."""
        if self.dag is None:
            return float(self.remaining_min[0])
        return max(float(self.crit_from[s]) for s in self.dag.sources)

    # ---- scalar mirrors for the SoA engine's Python-level hot loops -------
    #
    # The structure-of-arrays simulator (repro.core.engine_soa) runs its
    # scheduler kernels on plain Python floats: for the tiny per-decision
    # working sets (n_acc ~ 3, a handful of ready layers) scalar arithmetic
    # beats NumPy's per-call dispatch by an order of magnitude, and IEEE
    # semantics are identical, so results stay bit-equal to the ndarray
    # reference path.  Cached once per plan; plans themselves are memoized
    # per process by the campaign layer, so every trial shares these.

    @functools.cached_property
    def lat_rows(self) -> Tuple[Tuple[float, ...], ...]:
        """[L][n_acc] original latencies as tuples of Python floats."""
        return tuple(tuple(float(x) for x in row) for row in self.lat)

    @functools.cached_property
    def lat_var_rows(self) -> Tuple[Optional[Tuple[float, ...]], ...]:
        """[L] variant latency rows (None where no variant exists)."""
        return tuple(
            tuple(float(x) for x in self.lat_var[l]) if l in self.variants else None
            for l in range(len(self.model.layers))
        )

    @functools.cached_property
    def remaining_min_list(self) -> Tuple[float, ...]:
        """[L+1] ``remaining_min`` as Python floats."""
        return tuple(float(x) for x in self.remaining_min)

    @functools.cached_property
    def vdl_rel_list(self) -> Tuple[float, ...]:
        """[L] ``vdl_rel`` as Python floats."""
        return tuple(float(x) for x in self.vdl_rel)

    @functools.cached_property
    def min_lat_list(self) -> Tuple[float, ...]:
        """[L] ``min_lat`` as Python floats (stage-2's min_k c_{l+1,k})."""
        return tuple(float(x) for x in self.min_lat)

    @functools.cached_property
    def crit_from_list(self) -> Tuple[float, ...]:
        """[L] ``crit_from`` as Python floats."""
        return tuple(float(x) for x in self.crit_from)

    @functools.cached_property
    def crit_after_list(self) -> Tuple[float, ...]:
        """[L] ``crit_after`` as Python floats."""
        return tuple(float(x) for x in self.crit_after)

    @functools.cached_property
    def acc_pref_rows(self) -> Tuple[Tuple[int, ...], ...]:
        """[L][n_acc] accelerator indices by ascending original latency
        (stable: ties keep lower index).  Walking this order and taking
        the first idle accelerator reproduces ``min(idle, key=latency)``
        exactly — the FCFS/EDF placement rule — without per-call float
        comparisons."""
        return tuple(
            tuple(int(k) for k in np.argsort(row, kind="stable")) for row in self.lat
        )

    @functools.cached_property
    def single_variant_ok(self) -> Tuple[bool, ...]:
        """[L] whether applying ONLY layer l's variant is a valid combo —
        the common ``applied_variants == frozenset()`` membership test,
        precomputed (requests that already carry variants fall back to the
        live ``is_valid_combo`` check)."""
        return tuple(
            l in self.variants and self.is_valid_combo(frozenset((l,)))
            for l in range(len(self.model.layers))
        )

    def loss_of(self, layer_idx: int) -> float:
        return self.variants[layer_idx].loss

    def combo_retained(self, combo: FrozenSet[int]) -> float:
        return combo_retained_fraction(self.variants[i].loss for i in combo)

    def is_valid_combo(self, combo: FrozenSet[int]) -> bool:
        return self.combo_retained(combo) >= self.theta

    def valid_combos(self, max_enum: int = 20) -> List[FrozenSet[int]]:
        """Enumerated V_m (paper Sec. IV-B). Exhaustive for <= max_enum
        variant layers; validity is downward-closed so enumeration by
        increasing size with pruning is exact."""
        idxs = sorted(self.variants)
        if len(idxs) > max_enum:
            raise ValueError(f"{len(idxs)} variant layers; use is_valid_combo")
        valid: List[FrozenSet[int]] = [frozenset()]
        frontier: List[FrozenSet[int]] = [frozenset()]
        while frontier:
            nxt: Set[FrozenSet[int]] = set()
            for combo in frontier:
                start = max(combo) + 1 if combo else 0
                for i in idxs:
                    if i < start or i in combo:
                        continue
                    cand = combo | {i}
                    if self.is_valid_combo(cand):
                        nxt.add(frozenset(cand))
            valid.extend(sorted(nxt, key=sorted))
            frontier = list(nxt)
        return valid

    @property
    def storage_overhead(self) -> float:
        """Extra weights stored for variants / original model weights."""
        total = self.model.total_weights
        if total == 0:
            return 0.0
        return sum(v.storage_weights for v in self.variants.values()) / total


def _design_layer_variant(
    spec: LayerSpec,
    lat_row: np.ndarray,
    levels: np.ndarray,
    rho: int,
    platform: Platform,
) -> Optional[Tuple[int, str, LayerSpec, np.ndarray]]:
    """Pick (gamma, direction) for one latency-critical layer, or None.

    Target accelerators: those excluded at constraint level rho, i.e. with
    original latency > c^{down(rho)} ... >= c^{down(1)}.  Success criterion
    (paper Sec. V-A): the variant's latency on every target accelerator is
    at or below the preferred accelerator's original latency — relaxed to
    the next constraint level if that is looser (Sec. IV-A last para).
    """
    if rho <= 0:
        return None  # no accelerator excluded; no variant needed
    c_ref = levels[rho]
    targets = [k for k in range(len(lat_row)) if lat_row[k] > c_ref + 1e-15]
    if not targets:
        return None
    preferred_lat = float(lat_row.min())
    # allow meeting the *next* level below the current one when that is
    # looser than the preferred latency (paper allows either).
    goal = max(preferred_lat, float(levels[min(rho + 1, len(levels) - 1)]))
    # direction: counteract the dataflow of the slowest excluded accelerator
    worst_k = max(targets, key=lambda k: lat_row[k])
    tgt_df = platform.accelerators[worst_k].dataflow
    direction = "d2s" if tgt_df == Dataflow.OS else "s2d"
    for gamma in GAMMAS:
        if not variant_feasible(spec, gamma, direction):
            continue
        vspec = make_variant(spec, gamma, direction)
        vlat = np.array([layer_latency(vspec, a, platform) for a in platform.accelerators])
        if all(vlat[k] <= goal + 1e-15 for k in targets) and all(
            vlat[k] < lat_row[k] for k in targets
        ):
            return gamma, direction, vspec, vlat
    return None


def build_model_plan(
    model: DnnModel,
    platform: Platform,
    deadline: float,
    theta: float = 0.90,
    enable_variants: bool = True,
) -> ModelPlan:
    """The full offline stage for one model: budgets + variant design.

    A model carrying a :class:`LayerDag` routes through the
    critical-path tightening (``distribute_budgets_dag``); linear models
    keep the exact pre-DAG path (``distribute_budgets``), bit for bit.
    """
    lat = model_latency_table(model.layers, platform)
    dag = getattr(model, "dag", None)
    if dag is not None and dag.is_linear:
        dag = None  # degenerate case: use the linear path (and its floats)
    if dag is not None:
        budget = distribute_budgets_dag(lat, deadline, dag)
    else:
        budget = distribute_budgets(lat, deadline)
    variants: Dict[int, VariantInfo] = {}
    if enable_variants and budget.feasible:
        for idx, spec in enumerate(model.layers):
            got = _design_layer_variant(
                spec, lat[idx], budget.levels[idx], int(budget.rho[idx]), platform
            )
            if got is None:
                continue
            gamma, direction, vspec, vlat = got
            loss = layer_variant_loss(model.name, spec.name, model.redundancy, gamma)
            variants[idx] = VariantInfo(
                layer_idx=idx,
                gamma=gamma,
                direction=direction,
                spec=vspec,
                latencies=vlat,
                loss=loss,
                storage_weights=vspec.weights,
            )
    return ModelPlan(
        model=model,
        platform=platform,
        deadline=deadline,
        lat=lat,
        budget=budget,
        variants=variants,
        theta=theta,
        dag=dag,
    )

"""Online schedulers: Terastal (Algorithm 2), FCFS, EDF, DREAM, ablations.

All policies share one interface: given a :class:`SchedView` snapshot
(ready request-layer pairs, accelerator availability, offline plans) they
return a list of :class:`Assignment` for *idle* accelerators.  The
event-driven simulator (``repro.core.simulator``) invokes the scheduler
whenever an accelerator becomes idle or a request arrives, exactly as the
paper specifies, and applies the same early-drop policy to every policy
(paper Sec. IV-C last paragraph / Sec. V-A).

Fidelity notes
--------------
* FCFS / EDF follow Sec. V-A: FCFS orders ready layers by request arrival
  time; EDF by layer deadlines derived from minimum execution times; both
  map the selected layer to the idle accelerator with the lowest execution
  latency for that layer.
* DREAM is re-implemented from the DREAM paper's published mechanism
  (dynamic urgency-based priority with heterogeneity awareness), with the
  objective reduced to deadline-miss-rate per Terastal Sec. V-A.  Where
  internals are under-specified here, the approximation is confined to
  :class:`DreamScheduler` and marked ``# APPROX``.
* Terastal follows Algorithm 2 line-by-line (stage 1: best-case-slack
  order, original first then variant; stage 2: backfill by future
  potential slack gain, Eqs. 8-9).  ``use_budgets=False`` reproduces the
  "Terastal-no budgeting" ablation (EDF-style virtual deadlines);
  ``use_variants=False`` reproduces "Terastal-no variants".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.dag import DagRun
from repro.core.variants import ModelPlan


# ---------------------------------------------------------------- state ----


@dataclasses.dataclass(slots=True)
class Request:
    rid: int
    model_idx: int
    arrival: float
    deadline_abs: float
    next_layer: int = 0
    applied_variants: FrozenSet[int] = frozenset()
    done_time: Optional[float] = None
    dropped: bool = False
    # Closed-loop origin: (task_idx, user) when a ClosedLoopClients
    # release source issued this request — its completion or drop gates
    # that user's next release.  None = open-loop (pre-generated arrival).
    client: Optional[Tuple[int, int]] = None
    # Fault-axis state (repro.core.faults).  ``layer_frac`` is the
    # already-executed fraction of ``next_layer`` under the ``resume``
    # interrupted-work policy (0.0 = fresh layer); ``evicted_pending``
    # marks a fault-evicted request whose next dispatch counts as a
    # re-map.  Both stay at their defaults on fault-free trials.
    layer_frac: float = 0.0
    evicted_pending: bool = False
    # Integer-ns minimum work this request was ADMITTED at (admission
    # backlog accounting).  Frozen per request so add/remove symmetry
    # survives mid-trial capability changes: under ``retighten=true``
    # the engines' work tables re-derive from degraded capacity, and a
    # request must decrement exactly what it incremented.  0 when no
    # backlog-tracking admission policy is active.
    work_ns: int = 0
    # DAG-request bookkeeping: sibling ready entries of one request (one
    # per precedence-unblocked node) share a DagRun; None = linear chain.
    # compare=False keeps entry equality keyed on (rid, next_layer, ...)
    # exactly as before the DAG axis.
    dag: Optional[DagRun] = dataclasses.field(default=None, compare=False)
    # Per-request ABSOLUTE virtual deadlines, [L].  None = the offline
    # plan's frozen ``vdl_rel`` table (the paper / seed behavior).  Online
    # budget policies (repro.core.budget_online) install and mutate this;
    # budget-using schedulers read it through ``TerastalScheduler.vdl``.
    # compare=False: an ndarray in dataclass __eq__ would make equality
    # between equal requests raise instead of returning a bool.
    vdl_abs: Optional[np.ndarray] = dataclasses.field(default=None, compare=False)

    def is_finished(self, n_layers: int) -> bool:
        return self.next_layer >= n_layers


@dataclasses.dataclass(frozen=True)
class Assignment:
    req: Request
    layer: int
    acc: int
    use_variant: bool
    est_latency: float  # c_{m,l,k} (or variant) used for the decision


@dataclasses.dataclass
class SchedView:
    """Snapshot handed to a policy at invocation time ``now``.

    Virtual deadlines are carried by the ready :class:`Request` objects
    themselves (``vdl_abs`` when an online budget policy is active, the
    plan's frozen table otherwise), so one view serves both static and
    dynamic budget modes.
    """

    now: float
    ready: List[Request]  # each request exposes exactly one ready layer
    acc_busy_until: np.ndarray  # [n_acc] absolute times
    plans: Sequence[ModelPlan]

    @property
    def n_acc(self) -> int:
        return len(self.acc_busy_until)

    def tau(self, k: int) -> float:
        """Next available time of accelerator k (Eq. 4's tau_k(t))."""
        return max(self.now, float(self.acc_busy_until[k]))

    def idle_accs(self) -> List[int]:
        return [k for k in range(self.n_acc) if self.acc_busy_until[k] <= self.now + 1e-15]


class Scheduler:
    name = "base"
    uses_variants = False

    def schedule(self, view: SchedView) -> List[Assignment]:  # pragma: no cover
        raise NotImplementedError


# ------------------------------------------------------------- helpers ----


def _lat(plan: ModelPlan, layer: int, k: int) -> float:
    return float(plan.lat[layer, k])


def _assign_min_latency(
    view: SchedView, order: List[Request], idle: List[int]
) -> List[Assignment]:
    """Shared FCFS/EDF body: walk ``order``, map each ready layer to the
    idle accelerator with the lowest execution latency for that layer."""
    out: List[Assignment] = []
    idle = list(idle)
    for req in order:
        if not idle:
            break
        plan = view.plans[req.model_idx]
        l = req.next_layer
        k_star = min(idle, key=lambda k: _lat(plan, l, k))
        out.append(Assignment(req, l, k_star, False, _lat(plan, l, k_star)))
        idle.remove(k_star)
    return out


# ---------------------------------------------------------------- FCFS ----


class FcfsScheduler(Scheduler):
    name = "fcfs"

    def schedule(self, view: SchedView) -> List[Assignment]:
        # third tie element: DAG sibling entries share (arrival, rid); the
        # node id totalizes the order (no-op for linear — rids are unique)
        order = sorted(view.ready, key=lambda r: (r.arrival, r.rid, r.next_layer))
        return _assign_min_latency(view, order, view.idle_accs())


# ----------------------------------------------------------------- EDF ----


def edf_layer_deadline(plan: ModelPlan, req: Request, layer: int) -> float:
    """Layer deadline derived from minimum execution times: the request's
    absolute deadline minus the min-latency work remaining after ``layer``
    (the critical path below it, for DAG plans — ``crit_after`` is the
    exact ``remaining_min[layer + 1]`` slice on linear chains)."""
    return req.deadline_abs - plan.crit_after_list[layer]


class EdfScheduler(Scheduler):
    name = "edf"

    def schedule(self, view: SchedView) -> List[Assignment]:
        order = sorted(
            view.ready,
            key=lambda r: (
                edf_layer_deadline(view.plans[r.model_idx], r, r.next_layer),
                r.rid,
                r.next_layer,
            ),
        )
        return _assign_min_latency(view, order, view.idle_accs())


# --------------------------------------------------------------- DREAM ----


class DreamScheduler(Scheduler):
    """Heterogeneity-aware dynamic scheduler (DREAM [1], miss-rate objective).

    # APPROX — re-derived from DREAM's published mechanism with the
    objective reduced to deadline-miss-rate (paper Sec. V-A): ready layers
    are prioritized by least model-level slack (slack uses the
    heterogeneity-aware minimum remaining execution time — DREAM's
    latency-table awareness), and each is mapped eagerly to the idle
    accelerator with the earliest estimated finish.  DREAM has no
    layer-wise virtual deadlines, so it cannot reason about whether
    waiting for a preferred accelerator is safe — the "limited layer-wise
    timing insight" the Terastal paper calls out.
    """

    name = "dream"

    def schedule(self, view: SchedView) -> List[Assignment]:
        idle = view.idle_accs()
        out: List[Assignment] = []

        def slack(r: Request) -> float:
            plan = view.plans[r.model_idx]
            return r.deadline_abs - view.now - plan.crit_from_list[r.next_layer]

        for req in sorted(view.ready, key=lambda r: (slack(r), r.rid, r.next_layer)):
            if not idle:
                break
            plan = view.plans[req.model_idx]
            l = req.next_layer
            k_star = min(idle, key=lambda k: view.tau(k) + _lat(plan, l, k))
            c = _lat(plan, l, k_star)
            out.append(Assignment(req, l, k_star, False, c))
            idle.remove(k_star)
        return out


# ------------------------------------------------------------- Terastal ----


class TerastalScheduler(Scheduler):
    """Algorithm 2 with Eq. 4-9 semantics.

    ``use_budgets=False``  -> "Terastal-no budgeting" (EDF-style virtual
    deadlines derived from minimum execution times).
    ``use_variants=False`` -> "Terastal-no variants".

    ``backfill_mode`` selects the stage-2 guard (the paper's text -
    "each remaining idle accelerator is assigned the layer with the
    highest Delta-s" - is silent on whether a harmful backfill should
    still be taken; unconditional backfill measurably *hurts* Terastal
    below FCFS in several cells, so the paper's intended semantics must
    include a guard):

    * ``"ef"`` (default): a layer may be backfilled onto idle accelerator
      k only when k is earliest-finish-optimal for it across ALL
      accelerators including waiting for busy ones - i.e. idling is
      avoided exactly when it cannot help.  Work-conserving for late
      requests, and never blocks a slow accelerator with a non-preferred
      layer whose preferred accelerator frees up sooner.
    * ``"positive"``: require Delta-s > 0.
    * ``"paper"``: unconditional (the literal text), kept for ablation.
    """

    def __init__(
        self,
        use_budgets: bool = True,
        use_variants: bool = True,
        backfill_mode: str = "ef",
    ):
        assert backfill_mode in ("ef", "positive", "paper")
        self.use_budgets = use_budgets
        self.use_variants = use_variants
        self.backfill_mode = backfill_mode
        self.uses_variants = use_variants
        self.name = {
            (True, True): "terastal",
            (True, False): "terastal_no_variants",
            (False, True): "terastal_no_budgeting",
            (False, False): "terastal_no_budget_no_var",
        }[(use_budgets, use_variants)]

    # -- virtual deadline of a request's ready layer (Eq. 2) ---------------
    def vdl(self, plan: ModelPlan, req: Request, layer: int) -> float:
        if self.use_budgets:
            if req.vdl_abs is not None:  # online policy installed dynamic state
                return float(req.vdl_abs[layer])
            return req.arrival + float(plan.vdl_rel[layer])
        return edf_layer_deadline(plan, req, layer)

    def _variant_ok(self, plan: ModelPlan, req: Request, layer: int) -> bool:
        """LayerVariantFeasible: variant exists and accumulated set stays
        within the valid combination set V_m (downward-closed check)."""
        if not self.use_variants or layer not in plan.variants:
            return False
        return plan.is_valid_combo(req.applied_variants | {layer})

    def schedule(self, view: SchedView) -> List[Assignment]:
        idle: List[int] = view.idle_accs()
        if not idle:
            return []
        tau = np.array([view.tau(k) for k in range(view.n_acc)])
        out: List[Assignment] = []

        ready = list(view.ready)

        def best_case_slack(req: Request) -> float:
            plan = view.plans[req.model_idx]
            l = req.next_layer
            d_v = self.vdl(plan, req, l)
            finishes = tau + plan.lat[l]  # Eq. 4 over all k
            return float(d_v - finishes.min())  # Eq. 6-7

        # ---- stage 1: most-urgent-first, meet virtual deadlines ----------
        order = sorted(ready, key=lambda r: (best_case_slack(r), r.rid, r.next_layer))
        remaining: List[Request] = []
        for req in order:
            plan = view.plans[req.model_idx]
            l = req.next_layer
            d_v = self.vdl(plan, req, l)
            # original layer on an idle accelerator meeting d_v (lines 4-10)
            cands = [k for k in idle if tau[k] + plan.lat[l, k] <= d_v + 1e-15]
            if cands:
                k_star = min(cands, key=lambda k: tau[k] + plan.lat[l, k])
                c = _lat(plan, l, k_star)
                out.append(Assignment(req, l, k_star, False, c))
                idle.remove(k_star)
                tau[k_star] += c  # round-local update (Sec. IV-C)
                continue
            # variant on an idle accelerator meeting d_v (lines 11-18)
            if self._variant_ok(plan, req, l):
                lat_v = plan.lat_var[l]
                cands = [k for k in idle if tau[k] + lat_v[k] <= d_v + 1e-15]
                if cands:
                    k_star = min(cands, key=lambda k: tau[k] + lat_v[k])
                    c = float(lat_v[k_star])
                    out.append(Assignment(req, l, k_star, True, c))
                    idle.remove(k_star)
                    tau[k_star] += c
                    continue
            remaining.append(req)

        # ---- stage 2: backfill remaining idle accelerators (lines 19-23) -
        for k in list(idle):
            if not remaining:
                break
            best: Optional[Tuple[float, int, Request, bool, float]] = None
            for req in remaining:
                plan = view.plans[req.model_idx]
                l = req.next_layer
                s_star = best_case_slack(req)
                for use_var in (False, True):
                    if use_var:
                        if not self._variant_ok(plan, req, l):
                            continue
                        row = plan.lat_var[l]
                    else:
                        row = plan.lat[l]
                    c = float(row[k])
                    if not np.isfinite(c):
                        continue
                    finish = tau[k] + c
                    if self.backfill_mode == "ef":
                        # guard: k must be earliest-finish-optimal for this
                        # implementation across all accelerators (incl.
                        # waiting for busy ones) — idle only when it helps.
                        ef_all = float((tau + row).min())
                        if finish > ef_all + 1e-15:
                            continue
                    # Eq. 8: future potential slack for the NEXT layer.  On a DAG
                    # the "next layer" is the BINDING successor — the one
                    # with the tightest (vdl - min_lat) target, which is
                    # finish-independent (lowest node id on ties); the sink
                    # has no successor and falls back to the request
                    # deadline exactly like a linear chain's last layer.
                    if plan.dag is not None:
                        s_next = binding_successor(self, plan, req, l)
                        if s_next >= 0:
                            d_v_next = self.vdl(plan, req, s_next)
                            s_f = d_v_next - finish - plan.min_lat_list[s_next]
                        else:
                            s_f = req.deadline_abs - finish
                    elif l + 1 < len(plan.model.layers):
                        d_v_next = self.vdl(plan, req, l + 1)
                        s_f = d_v_next - finish - float(plan.lat[l + 1].min())
                    else:
                        s_f = req.deadline_abs - finish
                    delta = s_f - s_star  # Eq. 9
                    key = (delta, -int(use_var))  # prefer original on ties
                    if best is None or key > (best[0], -int(best[3])):
                        best = (delta, l, req, use_var, c)
            if best is None:
                continue
            if self.backfill_mode == "positive" and best[0] <= 0.0:
                continue
            _, l, req, use_var, c = best
            out.append(Assignment(req, l, k, use_var, c))
            tau[k] += c
            remaining.remove(req)
        return out


def binding_successor(
    sched: TerastalScheduler, plan: ModelPlan, req: Request, layer: int
) -> int:
    """The successor node whose virtual-deadline target ``vdl(s) -
    min_lat(s)`` is tightest — the one Eq. 8's future-slack term binds on
    for a DAG node.  Finish-independent (so the SoA engine can cache the
    winning ``(vdl, min_lat)`` pair per slot); lowest node id on float
    ties (the scan keeps the first minimum).  Returns -1 at the sink."""
    best = -1
    bv = 0.0
    for s in plan.dag.succs[layer]:
        v = sched.vdl(plan, req, s) - plan.min_lat_list[s]
        if best < 0 or v < bv:
            bv, best = v, s
    return best


# ---------------------------------------------------------------- registry -


def make_scheduler(name: str) -> Scheduler:
    """Build a scheduler from a name or call-spec string.

    Plain names (``"edf"``, ``"terastal"``, ablation aliases) behave as
    before; Terastal variants additionally accept keyword call-specs —
    e.g. ``"terastal(backfill_mode=paper)"`` — so campaign grids can
    sweep policy knobs without constructing instances by hand.
    """
    from repro.core.specs import parse_call_spec

    name, kwargs = parse_call_spec(name.lower())
    terastal_flags = {
        "terastal": (True, True),
        "terastal_no_variants": (True, False),
        "no_variants": (True, False),
        "terastal_no_budgeting": (False, True),
        "no_budgeting": (False, True),
    }
    baselines = {"fcfs": FcfsScheduler, "edf": EdfScheduler, "dream": DreamScheduler}
    if name not in terastal_flags and name not in baselines:
        raise KeyError(f"unknown scheduler '{name}'")
    if name in baselines:
        if kwargs:
            raise KeyError(f"scheduler '{name}' takes no keyword spec arguments")
        return baselines[name]()
    return TerastalScheduler(*terastal_flags[name], **kwargs)


ALL_SCHEDULERS = (
    "fcfs",
    "edf",
    "dream",
    "terastal_no_budgeting",
    "terastal_no_variants",
    "terastal",
)

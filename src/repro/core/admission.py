"""Admission / shedding policies: overload control at the release door.

The saturation grid (``saturation_{3,5,8}x``) shows why pure scheduling
loses at overload: the early-drop rule only fires once a request's
*remaining minimum* execution no longer fits its deadline, so under 5x
offered load most requests execute a few layers, age in a deep ready
queue, and are dropped mid-chain — the accelerators spend over half
their cycles on work that is then thrown away.  An admission policy
decides *at release time* whether a request enters the system at all;
a shed request is counted ``released`` + ``missed`` + ``dropped`` +
``shed`` (shedding never flatters the miss rate — it wins only by
letting the admitted requests actually complete on time).

Policies (call-spec strings, the same grid-axis shape as
``repro.core.budget_online``):

* ``none`` — admit everything: bit-identical to the pre-admission
  simulator (pinned by ``tests/test_admission.py``).
* ``shed_early(margin=...)`` — admit iff the request could still meet
  its deadline after an estimated queueing wait: ``now + margin *
  backlog / n_acc + min_exec <= deadline``, where ``backlog`` is the
  total remaining minimum work of live admitted requests spread over
  the accelerators.  ``margin`` scales the wait estimate (0 degenerates
  to the early-drop test applied at the door).
* ``token_bucket(rate=...,burst=...)`` — a global token bucket caps the
  *admitted* rate near system capacity regardless of the offered rate;
  the queue stays shallow, so admitted requests complete instead of
  aging and being dropped mid-chain.

Determinism contract (both engines): admission decisions happen at
arrival events, which both engines process in the identical heap order,
so stateful policies (the token bucket) see the same decision sequence.
The backlog accumulator is maintained by the engines in INTEGER
nanoseconds — integer adds are associative, so the two engines'
differing drop *orders* (reference: ready-insertion order; SoA:
reverse-slot order) cannot produce divergent backlog floats.
"""

from __future__ import annotations

import inspect
from typing import TYPE_CHECKING, Union

if TYPE_CHECKING:  # annotation only
    from repro.core.scheduler import Request


class AdmissionPolicy:
    """Per-release admit/shed decision.

    ``needs_backlog`` tells the engines to maintain the live-work
    accumulator (skipped entirely for policies that never read it, so
    ``none`` and ``token_bucket`` add no per-event work).  ``admit`` is
    invoked once per release, before the request enters the ready set;
    ``backlog_ns`` is the total remaining minimum execution time of
    admitted, not-yet-finished requests in integer nanoseconds, and
    ``min_work_s`` is this request's own total minimum execution time.
    ``bind(n_acc)`` is called once per run, after ``reset()``.
    """

    name = "none"
    needs_backlog = False

    def reset(self) -> None:
        """Clear cross-run state (instances may be reused across seeds)."""

    def bind(self, n_acc: int) -> None:
        self.n_acc = int(n_acc)

    def admit(
        self, req: "Request", now: float, backlog_ns: int, min_work_s: float
    ) -> bool:
        return True


class NoAdmission(AdmissionPolicy):
    """Admit everything — the pre-admission simulator, bit-identical."""

    name = "none"


class ShedEarlyAdmission(AdmissionPolicy):
    """Shed at the door when the estimated wait already dooms the request.

    The wait estimate is the admitted backlog (remaining minimum work of
    live requests) spread evenly over the accelerators, scaled by
    ``margin``.  With ``margin=0`` this degenerates to applying the
    early-drop test at release time (almost never sheds — the queue wait
    is what kills requests at saturation); larger margins shed earlier
    and keep the ready queue shallower.
    """

    name = "shed_early"
    needs_backlog = True

    def __init__(self, margin: float = 1.0):
        if margin < 0.0:
            raise ValueError(f"margin must be >= 0, got {margin}")
        self.margin = float(margin)

    def admit(
        self, req: "Request", now: float, backlog_ns: int, min_work_s: float
    ) -> bool:
        wait_est = self.margin * (backlog_ns * 1e-9) / self.n_acc
        return now + wait_est + min_work_s <= req.deadline_abs + 1e-12


class TokenBucketAdmission(AdmissionPolicy):
    """Global token bucket over all models: ``rate`` admissions/second
    sustained, bursts up to ``burst`` tokens.  The bucket starts full and
    refills continuously; an arrival that finds no whole token is shed.
    State updates only happen at arrival events, which both engines
    process in the identical order, so the float bucket state stays
    bit-identical across engines.
    """

    name = "token_bucket"

    def __init__(self, rate: float, burst: float = 8.0):
        if rate <= 0.0:
            raise ValueError(f"rate must be > 0 admissions/s, got {rate}")
        if burst < 1.0:
            raise ValueError(f"burst must be >= 1 token, got {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self.reset()

    def reset(self) -> None:
        self._tokens = self.burst
        self._last = 0.0

    def admit(
        self, req: "Request", now: float, backlog_ns: int, min_work_s: float
    ) -> bool:
        dt = now - self._last
        if dt > 0.0:
            refill = self._tokens + dt * self.rate
            self._tokens = refill if refill < self.burst else self.burst
            self._last = now
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False


ADMISSION_POLICIES = {
    "none": NoAdmission,
    "shed_early": ShedEarlyAdmission,
    "token_bucket": TokenBucketAdmission,
}


def make_admission_policy(
    spec: Union[str, AdmissionPolicy, None]
) -> AdmissionPolicy:
    """Build an :class:`AdmissionPolicy` from a call-spec string.

    ``"none"``, ``"shed_early(margin=1.5)"``,
    ``"token_bucket(rate=100,burst=10)"`` ...; instances pass through
    unchanged and ``None`` means admit-everything (the pre-admission
    simulator, bit-identical).
    """
    from repro.core.specs import parse_call_spec

    if spec is None:
        return NoAdmission()
    if isinstance(spec, AdmissionPolicy):
        return spec
    name, kwargs = parse_call_spec(spec)
    if name not in ADMISSION_POLICIES:
        raise KeyError(
            f"unknown admission policy '{name}' (have {sorted(ADMISSION_POLICIES)})"
        )
    cls = ADMISSION_POLICIES[name]
    try:
        return cls(**kwargs)
    except TypeError as e:
        params = sorted(set(inspect.signature(cls.__init__).parameters) - {"self"})
        raise ValueError(
            f"bad arguments for admission policy '{name}': {e}; "
            f"valid parameters: {params or 'none'}"
        ) from e

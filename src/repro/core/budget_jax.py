"""Algorithm 1 as a jittable ``jax.lax`` program.

The greedy tightening loop is re-expressed as a fixed-shape
``lax.while_loop`` over padded per-layer level tables; ``vmap`` batches it
across models (layer counts padded with zero-latency phantom layers).

Bit-compatibility with the NumPy reference: the tie-break (lowest layer
index among maximal gaps) matches ``np.argmax``; property tests in
``tests/test_budget.py`` check agreement on randomized instances.
"""

from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.budget import latency_levels


def pack_levels(lat_table: np.ndarray, r_max: int | None = None) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side: build the padded [L, R_max] decreasing level table.

    Padding repeats each layer's last (fastest) distinct latency so that
    padded positions contribute zero gap and are never tightenable.
    """
    L = lat_table.shape[0]
    levels = [latency_levels(lat_table[l]) for l in range(L)]
    R = np.array([len(lv) for lv in levels], dtype=np.int32)
    if r_max is None:
        r_max = int(R.max())
    packed = np.zeros((L, r_max), dtype=lat_table.dtype)
    for l, lv in enumerate(levels):
        packed[l, : len(lv)] = lv[:r_max]
        packed[l, len(lv):] = lv[min(len(lv), r_max) - 1]
    return packed, np.minimum(R, r_max)


class BudgetJaxResult(NamedTuple):
    feasible: jax.Array  # bool scalar
    budgets: jax.Array  # [L]
    rho: jax.Array  # [L] int32
    c_ref: jax.Array  # [L]


def distribute_budgets_jax(
    levels: jax.Array,  # [L, R_max] decreasing, padded
    R: jax.Array,  # [L] number of real levels per layer
    deadline: jax.Array,  # scalar
    layer_mask: jax.Array | None = None,  # [L] bool; False = phantom layer
    rho0: jax.Array | None = None,  # [L] starting constraint levels (incremental)
) -> BudgetJaxResult:
    """The tightening kernel; ``rho0=None`` (zeros) is offline Algorithm 1,
    a nonzero ``rho0`` re-distributes a remaining deadline from a request's
    current constraint levels (mirrors ``budget.tighten_budgets``)."""
    L, r_max = levels.shape
    if layer_mask is None:
        layer_mask = jnp.ones((L,), dtype=bool)
    lidx = jnp.arange(L)

    def c_of(rho):
        return jnp.where(layer_mask, levels[lidx, rho], 0.0)

    def cond(rho):
        c_total = c_of(rho).sum()
        tight = layer_mask & (rho < R - 1)
        return (c_total > deadline) & tight.any()

    def body(rho):
        cur = levels[lidx, rho]
        nxt = levels[lidx, jnp.minimum(rho + 1, r_max - 1)]
        tight = layer_mask & (rho < R - 1)
        gaps = jnp.where(tight, cur - nxt, -jnp.inf)
        l_star = jnp.argmax(gaps)
        return rho.at[l_star].add(1)

    if rho0 is None:
        rho0 = jnp.zeros((L,), dtype=jnp.int32)
    rho = jax.lax.while_loop(cond, body, jnp.asarray(rho0, dtype=jnp.int32))
    c_ref = c_of(rho)
    c_total = c_ref.sum()
    feasible = c_total <= deadline
    budgets = jnp.where(feasible, deadline * c_ref / jnp.maximum(c_total, 1e-30), 0.0)
    budgets = jnp.where(layer_mask, budgets, 0.0)
    return BudgetJaxResult(feasible, budgets, rho, c_ref)


distribute_budgets_jax_jit = jax.jit(distribute_budgets_jax)


def distribute_budgets_batch(
    levels_b: jax.Array,  # [M, L, R_max]
    R_b: jax.Array,  # [M, L]
    deadlines: jax.Array,  # [M]
    layer_mask_b: jax.Array,  # [M, L]
) -> BudgetJaxResult:
    """vmapped Algorithm 1 across a fleet of models (padded layout)."""
    return jax.vmap(distribute_budgets_jax)(levels_b, R_b, deadlines, layer_mask_b)

"""Event-driven multi-accelerator, multi-DNN inference simulator.

Semantics follow Sec. IV of the paper exactly:

* Layer-granularity, non-preemptive jobs; decisions only at layer
  boundaries.  The scheduler is invoked whenever an accelerator becomes
  idle (layer finish) and at request arrivals.
* All accelerators share on-chip memory, so consecutive layers of one
  request may run on different accelerators with no migration penalty
  beyond what the latency model already charges.
* Per-layer latencies are deterministic constants from the offline
  profile (original and variant tables in the :class:`ModelPlan`).
* Early-drop (all policies): a request whose remaining minimum execution
  time can no longer meet its absolute deadline is dropped (counts as a
  miss) to free resources.
* Periodic tasks: request ``j`` of model ``m`` arrives at ``j / fps`` (a
  task with ``prob < 1`` fires each period with that probability — the
  Hand S/P "Prob: 0.5" entry of Table II), with relative deadline
  ``D_m = 1 / fps``.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import os
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple, Union

if TYPE_CHECKING:  # annotation only; the runtime import is lazy in simulate()
    from repro.core.admission import AdmissionPolicy
    from repro.core.budget_online import BudgetPolicy
    from repro.core.faults import FaultModel

import numpy as np

from repro.core.dag import DagRun
from repro.core.scheduler import Assignment, Request, SchedView, Scheduler
from repro.core.specs import parse_call_spec
from repro.core.variants import ModelPlan


# ----------------------------------------------------------- arrivals ----
#
# The seed simulator hard-coded strictly periodic releases.  Real traffic
# is not periodic (DREAM-style multi-tenant traces are bursty), and the
# Monte-Carlo campaign engine sweeps arrival models as a grid dimension,
# so arrival generation is a pluggable strategy.  All processes draw from
# ONE shared per-trial rng stream, consumed in task order — with the
# default PeriodicArrivals this makes `generate_arrivals` bit-identical
# to the seed implementation (pinned by tests/test_campaign.py).


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Generates arrival times for one task over ``[0, duration)``.

    Subclasses are frozen dataclasses: stateless, hashable, picklable —
    one instance may be shared across tasks and process-pool workers.
    Per-task firing probability (``TaskSpec.prob``) is applied by the
    process itself, one ``rng.random()`` draw per candidate arrival, so
    thinning stays on the shared stream.
    """

    kind = "base"

    def sample(self, task: "TaskSpec", duration: float, rng: np.random.Generator) -> List[float]:
        raise NotImplementedError

    @staticmethod
    def _fires(task: "TaskSpec", rng: np.random.Generator) -> bool:
        return task.prob >= 1.0 or rng.random() < task.prob


@dataclasses.dataclass(frozen=True)
class PeriodicArrivals(ArrivalProcess):
    """Release ``j`` at ``j / fps`` (the paper's Table-II model), with
    optional uniform jitter of up to ``jitter`` periods added per release.

    ``jitter=0`` consumes the rng stream exactly like the seed
    implementation (prob draws only), so default campaigns reproduce the
    seed's per-seed results bit-for-bit.
    """

    kind = "periodic"
    jitter: float = 0.0

    def sample(self, task: "TaskSpec", duration: float, rng: np.random.Generator) -> List[float]:
        n = int(np.floor(duration * task.fps))
        # Vectorized fast paths.  Each consumes the shared rng stream in
        # exactly the per-release order of the loop below (batched
        # ``rng.random(n)`` draws the same variates as n scalar calls),
        # pinned by tests/test_campaign.py — bit-identical, just not one
        # Python iteration per release.
        if task.prob >= 1.0:
            base = np.arange(n) * task.period  # _fires short-circuits: no draws
            if self.jitter > 0.0:
                # same association as the scalar loop: (u * jitter) * period
                base = base + rng.random(n) * self.jitter * task.period
            return base.tolist()
        if self.jitter <= 0.0:
            # one thinning draw per candidate release, nothing interleaved
            fires = rng.random(n) < task.prob
            return (np.flatnonzero(fires) * task.period).tolist()
        # prob < 1 AND jitter > 0: the jitter draw happens only when the
        # thinning draw fires, so the stream interleaves data-dependently —
        # keep the scalar loop (cannot batch without changing the stream).
        out: List[float] = []
        for j in range(n):
            if self._fires(task, rng):
                out.append(j * task.period + rng.random() * self.jitter * task.period)
        return out


def _exp_stream(
    rng: np.random.Generator, scale: float, t0: float, limit: float
) -> List[float]:
    """Arrival times ``t0 + cumsum(Exp(scale))`` strictly below ``limit``,
    consuming the shared rng stream EXACTLY as the scalar loop

    .. code-block:: python

        t = t0 + rng.exponential(scale)
        while t < limit:
            out.append(t); t += rng.exponential(scale)

    i.e. one draw per arrival plus the final crossing draw.  Batched
    ``rng.exponential(scale, n)`` produces the same variates as n scalar
    calls (numpy fills the array sequentially from the bit stream, and a
    shorter batch is a prefix of a longer one), and ``np.cumsum``
    accumulates left-to-right, so the times are bit-identical.  The one
    subtlety is stopping: a batch may consume more draws than the scalar
    loop would have, so the generator state is snapshotted before each
    batch and, when the crossing lands mid-batch, rewound and re-drawn
    for exactly the right count — the stream position afterwards equals
    the scalar loop's, which matters because later tasks continue
    drawing from the same stream.  Pinned draw-for-draw (values AND
    final generator state) by ``tests/test_simulator.py``."""
    n_est = max(0.0, (limit - t0) / scale)
    chunk = int(n_est + 4.0 * n_est**0.5 + 8.0)
    out: List[float] = []
    t = t0
    while True:
        state = rng.bit_generator.state
        e = rng.exponential(scale, chunk)
        # e[0] += t then a sequential cumsum reproduces the scalar loop's
        # fl(fl(t + e0) + e1)... rounding chain exactly; the result is
        # non-decreasing, so the crossing index is a searchsorted
        e[0] += t
        ts = np.cumsum(e)
        idx = int(np.searchsorted(ts, limit))  # first ts >= limit
        if idx < chunk:
            if idx + 1 < chunk:  # scalar loop stops after draw idx+1
                rng.bit_generator.state = state
                rng.exponential(scale, idx + 1)
            out.extend(ts[:idx].tolist())
            return out
        out.extend(ts.tolist())
        t = float(ts[-1])
        chunk *= 2


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson process with mean rate ``fps * rate_scale``."""

    kind = "poisson"
    rate_scale: float = 1.0

    def sample(self, task: "TaskSpec", duration: float, rng: np.random.Generator) -> List[float]:
        rate = task.fps * self.rate_scale
        out: List[float] = []
        if rate <= 0.0:
            return out
        if task.prob >= 1.0:
            # _fires short-circuits, so the stream is pure exponentials:
            # batch them (stream-identical — see _exp_stream)
            return _exp_stream(rng, 1.0 / rate, 0.0, duration)
        # prob < 1: one thinning draw interleaves after every arrival
        # below the horizon, so the raw-stream layout is data-dependent —
        # keep the scalar loop (same reasoning as PeriodicArrivals'
        # prob<1 + jitter case).
        t = rng.exponential(1.0 / rate)
        while t < duration:
            if self._fires(task, rng):
                out.append(t)
            t += rng.exponential(1.0 / rate)
        return out


@dataclasses.dataclass(frozen=True)
class MmppArrivals(ArrivalProcess):
    """Two-state Markov-modulated Poisson process (on-off bursts).

    * ``burstiness`` — ON-state rate as a multiple of the mean rate
      (``burstiness=1`` degenerates to plain Poisson).
    * ``on_fraction`` — long-run fraction of time spent in the ON state.
    * ``mean_cycle`` — mean ON+OFF cycle length in task periods.

    The OFF-state rate is solved so the long-run mean rate stays
    ``task.fps`` for every parameterization: when ``burstiness`` exceeds
    ``1/on_fraction`` (where the OFF rate would have to go negative),
    ``on_fraction`` is clamped down to ``1/burstiness`` — bursts become
    rarer rather than the offered load silently doubling, so a
    burstiness sweep measures burstiness, not overload.  Sojourn times
    are exponential, so state holding times are memoryless (a true
    MMPP, not a square wave).
    """

    kind = "mmpp"
    burstiness: float = 4.0
    on_fraction: float = 0.25
    mean_cycle: float = 20.0

    def sample(self, task: "TaskSpec", duration: float, rng: np.random.Generator) -> List[float]:
        b = max(1.0, float(self.burstiness))
        p = min(max(float(self.on_fraction), 1e-6), 1.0, 1.0 / b)
        rate_on = task.fps * b
        rate_off = task.fps * max(0.0, 1.0 - p * b) / (1.0 - p) if p < 1.0 else task.fps
        cycle = self.mean_cycle * task.period
        mean_soj = {True: p * cycle, False: (1.0 - p) * cycle}
        out: List[float] = []
        t = 0.0
        on = rng.random() < p  # start from the stationary distribution
        fast = task.prob >= 1.0  # no interleaved thinning draws
        while t < duration:
            end = min(t + rng.exponential(mean_soj[on]), duration)
            rate = rate_on if on else rate_off
            if rate > 0.0:
                if fast:
                    # per-segment batched exponentials (stream-identical;
                    # the sojourn draw above stays scalar, so segment
                    # boundaries interleave exactly as before)
                    out.extend(_exp_stream(rng, 1.0 / rate, t, end))
                else:
                    nxt = t + rng.exponential(1.0 / rate)
                    while nxt < end:
                        if self._fires(task, rng):
                            out.append(nxt)
                        nxt += rng.exponential(1.0 / rate)
            t = end
            on = not on
        return out


@dataclasses.dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay recorded arrival times (seconds from trace start).

    ``span`` is the trace's total covered duration (defaults to the last
    timestamp); when ``cycle`` is set the trace tiles every ``span``
    seconds until the horizon.  ``prob`` thinning still applies, so a
    trace can serve several tasks with independent subsampling.
    """

    kind = "trace"
    times: Tuple[float, ...] = ()
    span: Optional[float] = None
    cycle: bool = True

    def __post_init__(self):
        # an explicit span of 0.0 used to silently fall back to the
        # trace-derived span (`if self.span` is falsy for 0.0); validate
        # instead, matching the make_arrival_process error convention
        if self.span is not None and self.span <= 0.0:
            raise ValueError(
                f"bad arguments for arrival process 'trace': span must be "
                f"> 0 seconds (or None for the trace-derived span), got "
                f"{self.span}"
            )

    def sample(self, task: "TaskSpec", duration: float, rng: np.random.Generator) -> List[float]:
        ts = sorted(float(t) for t in self.times if t >= 0.0)
        if not ts:
            return []
        span = float(self.span) if self.span is not None else max(ts[-1], task.period)
        out: List[float] = []
        rep = 0
        while True:
            base = rep * span
            if base >= duration:
                break
            for x in ts:
                t = base + x
                if t >= duration:
                    break
                if self._fires(task, rng):
                    out.append(t)
            if not self.cycle:
                break
            rep += 1
        return out


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Inhomogeneous Poisson process with a sinusoidal rate curve — the
    compressed diurnal cycle of a serving fleet.  The instantaneous rate
    is ``fps * (1 + depth * sin(2*pi*(t/period + phase)))``; the long-run
    mean stays ``task.fps``.  Sampled by thinning against the peak rate
    (one acceptance draw per candidate), so it pre-generates like every
    other open-loop process.
    """

    kind = "diurnal"
    period: float = 4.0  # seconds per rate cycle (simulation scale)
    depth: float = 0.8  # peak-to-trough modulation, in [0, 1)
    phase: float = 0.0  # cycle fraction offset at t=0

    def __post_init__(self):
        if self.period <= 0.0:
            raise ValueError(
                f"bad arguments for arrival process 'diurnal': period must "
                f"be > 0 seconds, got {self.period}"
            )
        if not 0.0 <= self.depth < 1.0:
            raise ValueError(
                f"bad arguments for arrival process 'diurnal': depth must "
                f"be in [0, 1), got {self.depth}"
            )

    def sample(self, task: "TaskSpec", duration: float, rng: np.random.Generator) -> List[float]:
        peak = task.fps * (1.0 + self.depth)
        out: List[float] = []
        if peak <= 0.0:
            return out
        two_pi = 2.0 * np.pi
        t = rng.exponential(1.0 / peak)
        while t < duration:
            lam = task.fps * (
                1.0 + self.depth * float(np.sin(two_pi * (t / self.period + self.phase)))
            )
            if rng.random() * peak < lam and self._fires(task, rng):
                out.append(t)
            t += rng.exponential(1.0 / peak)
        return out


#: rng-stream salt for closed-loop per-user think-time streams; disjoint
#: from the shared open-loop arrival stream (seeded on the bare seed).
_CLIENT_SALT = 0x434C4F53  # "CLOS"


@dataclasses.dataclass(frozen=True)
class ClosedLoopClients(ArrivalProcess):
    """Closed-loop user pool: ``n_users`` clients that each keep exactly
    one request in flight — a release happens only after the user's
    previous request *left the system* (completed, early-dropped, or
    admission-shed) plus an exponential think time.

    This cannot be pre-generated (releases gate on completions), so both
    engines integrate it into the event loop directly via
    :func:`generate_release_events`; :meth:`sample` raises.  Each user
    draws think times from its own rng stream, which makes the two
    engines bit-identical even though they retire requests in different
    within-round orders (pinned by ``tests/test_closed_loop.py``).

    * ``session_len`` > 0 with ``respawn=False``: each user retires
      after issuing that many requests (drain; the flash-crowd shape).
      With ``respawn=True`` (default) users keep issuing forever.
    * ``stagger=True`` staggers first releases by one think time;
      ``stagger=False`` releases every user at ``start`` simultaneously
      (the flash-crowd front).
    * ``TaskSpec.fps`` still sets the relative deadline (1/fps); the
      offered rate is emergent (~ ``n_users / (think + response)``).
      ``TaskSpec.prob`` thinning does not apply to closed-loop tasks.
    """

    kind = "closed_loop"
    n_users: int = 4
    think_time: float = 0.1
    session_len: int = 0
    respawn: bool = True
    start: float = 0.0
    stagger: bool = True

    def __post_init__(self):
        if self.n_users < 1:
            raise ValueError(
                f"bad arguments for arrival process 'closed_loop': n_users "
                f"must be >= 1, got {self.n_users}"
            )
        if self.think_time <= 0.0:
            raise ValueError(
                f"bad arguments for arrival process 'closed_loop': "
                f"think_time must be > 0 seconds, got {self.think_time}"
            )
        if self.session_len < 0:
            raise ValueError(
                f"bad arguments for arrival process 'closed_loop': "
                f"session_len must be >= 0 (0 = unlimited), got {self.session_len}"
            )
        if self.start < 0.0:
            raise ValueError(
                f"bad arguments for arrival process 'closed_loop': start "
                f"must be >= 0, got {self.start}"
            )

    def sample(self, task: "TaskSpec", duration: float, rng: np.random.Generator) -> List[float]:
        raise ValueError(
            "closed-loop releases gate on completions and cannot be "
            "pre-generated; pass ClosedLoopClients as the task's arrival "
            "process to simulate() — both engines integrate it into the "
            "event loop directly (generate_release_events)"
        )

    def runtime(self, task_idx: int, seed: int, duration: float) -> "_ClientRuntime":
        return _ClientRuntime(self, task_idx, seed, duration)


class _ClientRuntime:
    """Mutable per-trial state of one closed-loop task's user pool.

    Each user draws think times from its OWN rng stream
    (``default_rng([salt, seed, task_idx, user])``): a user's next draw
    never depends on how an engine interleaves *other* users'
    completions and drops within a round, which is what keeps the two
    engines bit-identical despite their different drop orders."""

    __slots__ = ("spec", "task_idx", "duration", "rngs", "issued")

    def __init__(self, spec: ClosedLoopClients, task_idx: int, seed: int, duration: float):
        self.spec = spec
        self.task_idx = task_idx
        self.duration = duration
        self.rngs = [
            np.random.default_rng([_CLIENT_SALT, seed, task_idx, u])
            for u in range(spec.n_users)
        ]
        self.issued = [0] * spec.n_users

    def initial(self) -> List[Tuple[float, int]]:
        """[(release_time, user)] — each user's first release."""
        sp = self.spec
        out: List[Tuple[float, int]] = []
        for u in range(sp.n_users):
            t = sp.start
            if sp.stagger:
                t += float(self.rngs[u].exponential(sp.think_time))
            if t < self.duration:
                self.issued[u] += 1
                out.append((t, u))
        return out

    def next_release(self, u: int, now: float) -> Optional[float]:
        """User ``u``'s next release after its request left the system at
        ``now`` (completed, dropped, or shed); None when the session is
        over or the release would fall past the horizon."""
        sp = self.spec
        if sp.session_len > 0 and not sp.respawn and self.issued[u] >= sp.session_len:
            return None
        t = now + float(self.rngs[u].exponential(sp.think_time))
        if t >= self.duration:
            return None
        self.issued[u] += 1
        return t


@dataclasses.dataclass(frozen=True)
class _NullArrivals(ArrivalProcess):
    """Stand-in for closed-loop tasks inside ``generate_arrivals``: draws
    nothing from the shared open-loop stream and releases nothing, so the
    open-loop tasks' variates are exactly as if the closed-loop tasks
    were absent."""

    kind = "null"

    def sample(self, task: "TaskSpec", duration: float, rng: np.random.Generator) -> List[float]:
        return []


_NULL_ARRIVAL = _NullArrivals()


ARRIVAL_PROCESSES = {
    "periodic": PeriodicArrivals,
    "poisson": PoissonArrivals,
    "mmpp": MmppArrivals,
    "trace": TraceArrivals,
    "diurnal": DiurnalArrivals,
    "closed_loop": ClosedLoopClients,
}

DEFAULT_ARRIVAL = PeriodicArrivals()


def make_arrival_process(spec) -> ArrivalProcess:
    """Build an :class:`ArrivalProcess` from a call-spec string.

    ``"periodic"``, ``"periodic(jitter=0.5)"``, ``"poisson"``,
    ``"mmpp(burstiness=4,on_fraction=0.2)"`` ...; instances pass through
    unchanged and ``None`` means the default periodic process.
    """
    if spec is None:
        return DEFAULT_ARRIVAL
    if isinstance(spec, ArrivalProcess):
        return spec
    name, kwargs = parse_call_spec(spec)
    if name not in ARRIVAL_PROCESSES:
        raise KeyError(f"unknown arrival process '{name}' (have {sorted(ARRIVAL_PROCESSES)})")
    if name == "trace":
        # a bare "trace" would replay an empty times tuple — every trial
        # releasing 0 requests looks like a perfect scheduler, not an error
        raise ValueError("trace arrivals need a times tuple; construct TraceArrivals directly")
    cls = ARRIVAL_PROCESSES[name]
    try:
        return cls(**kwargs)
    except TypeError as e:
        # "mmpp(burstines=4)" would otherwise surface as a bare dataclass
        # TypeError deep inside a pool worker — name the process and its
        # valid parameters at the point of parsing instead.
        params = sorted(f.name for f in dataclasses.fields(cls))
        raise ValueError(
            f"bad arguments for arrival process '{name}': {e}; "
            f"valid parameters: {params or 'none'}"
        ) from e


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One task entry of a workload scenario (Table II row item).

    ``arrival`` selects the release process (``None`` -> strictly
    periodic, the paper's model); ``fps`` always sets the mean rate and
    the relative deadline ``1/fps`` regardless of process.
    """

    model_idx: int
    fps: float
    prob: float = 1.0
    arrival: Optional[ArrivalProcess] = None

    @property
    def period(self) -> float:
        return 1.0 / self.fps


@dataclasses.dataclass
class ModelStats:
    """Per-model counters.  Conservation law (property-tested on both
    engines, ``tests/test_conservation.py``): every released request is
    accounted for exactly once —

        released == completed + dropped + in_flight

    with ``shed <= dropped`` (admission rejections are a kind of drop)
    and ``missed >= dropped`` (drops always miss; completions may)."""

    released: int = 0
    completed: int = 0
    missed: int = 0  # late completions + drops
    dropped: int = 0  # early-drops + admission sheds
    retained_sum: float = 0.0  # sum of retained-accuracy fractions
    variants_applied: int = 0
    # Admission-policy rejections (subset of ``dropped``): requests shed
    # at the release door, before entering the ready set.
    shed: int = 0
    # Requests still in the system (ready or running) when the event
    # stream drained — released but neither completed nor dropped.
    in_flight: int = 0
    # Fault counters (``repro.core.faults``).  ``evicted`` counts in-flight
    # layer interruptions (a request can be evicted more than once);
    # ``remapped`` counts evicted requests that were subsequently
    # re-dispatched — so ``remapped <= evicted`` and eviction is never a
    # terminal state by itself (an evicted request re-enters the ready set
    # and later completes, early-drops, or drains as in_flight, keeping
    # the conservation law above intact under faults).
    evicted: int = 0
    remapped: int = 0

    @property
    def admitted(self) -> int:
        return self.released - self.shed

    @property
    def miss_rate(self) -> float:
        return self.missed / self.released if self.released else 0.0

    @property
    def mean_retained(self) -> float:
        """Mean retained-accuracy fraction over COMPLETED requests; NaN
        when the model completed nothing.  (It used to report 1.0 — at
        saturation a model that completed zero requests read as "no
        accuracy loss", silently flattering the headline metric pair.)"""
        return self.retained_sum / self.completed if self.completed else float("nan")

    @property
    def mean_norm_accuracy_loss(self) -> float:
        return 1.0 - self.mean_retained


@dataclasses.dataclass
class SimResult:
    duration: float
    per_model: Dict[int, ModelStats]
    acc_busy_time: np.ndarray
    scheduler_name: str
    # Busy time counted only up to the horizon.  Layers dispatched near
    # the horizon run past ``duration`` but ``acc_busy_time`` charges
    # their full latency, so the raw ratio can exceed 1.0; this field
    # clamps each dispatch's contribution to the time remaining before
    # the horizon.  ``None`` (externally constructed results) falls back
    # to the raw ratio.
    acc_busy_in_horizon: Optional[np.ndarray] = None
    # Scheduling rounds executed: one per distinct event timestamp after
    # simultaneous-event batching.  Per-result telemetry (both engines
    # count identically — pinned by the differential tests), so campaign
    # pool workers report real values instead of mutating module state.
    # ``None`` on externally constructed results.
    rounds: Optional[int] = None
    # Fault windows (``repro.core.faults``) that intersected the horizon;
    # 0 on fault-free trials (and on externally constructed results).
    faulted_spans: int = 0

    @property
    def mean_miss_rate(self) -> float:
        """Average of per-model deadline miss rates (paper's metric)."""
        rates = [s.miss_rate for s in self.per_model.values() if s.released]
        return float(np.mean(rates)) if rates else 0.0

    def accuracy_loss_stats(
        self, plans: Sequence[ModelPlan]
    ) -> Tuple[float, int, int]:
        """``(mean_loss, models_counted, models_with_variants)``.

        The mean normalized accuracy loss over variant-bearing models
        that completed at least one request.  Zero-completion models are
        EXCLUDED from the mean and surfaced through the counts
        (``models_counted < models_with_variants`` flags the exclusion);
        when NO variant-bearing model completed anything the mean is NaN
        — never a flattering 0.0.  Report the loss jointly with
        ``models_counted`` whenever the workload can saturate."""
        with_var = [m for m, s in sorted(self.per_model.items()) if plans[m].variants]
        counted = [m for m in with_var if self.per_model[m].completed]
        mean = (
            float(np.mean([self.per_model[m].mean_norm_accuracy_loss for m in counted]))
            if counted
            else float("nan")
        )
        return mean, len(counted), len(with_var)

    def mean_accuracy_loss(self, plans: Sequence[ModelPlan]) -> float:
        """Average normalized accuracy loss across models WITH variants
        that completed at least one request; NaN when none did (see
        :meth:`accuracy_loss_stats` for the documented contract and the
        exclusion counts)."""
        return self.accuracy_loss_stats(plans)[0]

    def fingerprint(self) -> tuple:
        """Canonical exact-equality key: every observable field — busy
        arrays, clamped busy arrays, the scheduling-round count, per-model
        integer counters AND the float retained-accuracy sums.  The one
        definition the engine/kernel differential suites and the
        benchmark bit-identity gates compare, so a newly added SimResult
        field only needs to be wired in here to be pinned everywhere."""
        return (
            self.scheduler_name,
            self.rounds,
            self.acc_busy_time.tolist(),
            None if self.acc_busy_in_horizon is None
            else self.acc_busy_in_horizon.tolist(),
            {
                m: (s.released, s.completed, s.missed, s.dropped,
                    s.variants_applied, s.retained_sum, s.shed, s.in_flight,
                    s.evicted, s.remapped)
                for m, s in sorted(self.per_model.items())
            },
            self.faulted_spans,
        )

    def utilization(self, clamp: bool = True) -> np.ndarray:
        """Per-accelerator busy fraction of the horizon, in [0, 1].

        ``clamp=False`` restores the historical accounting that charges
        the full latency of every dispatched layer — including the tail
        that runs past the horizon — and can therefore exceed 1.0."""
        if clamp and self.acc_busy_in_horizon is not None:
            return self.acc_busy_in_horizon / self.duration
        return self.acc_busy_time / self.duration


_ARRIVAL, _FINISH, _TICK, _FAULT = 0, 1, 2, 3


def generate_arrivals(
    tasks: Sequence[TaskSpec],
    duration: float,
    seed: int = 0,
    processes: Optional[Sequence[Optional[ArrivalProcess]]] = None,
) -> List[Tuple[float, int]]:
    """[(arrival_time, model_idx)] honoring per-task firing probability.

    ``processes`` (one per task) overrides each task's own ``arrival``;
    either being ``None`` falls back to the strictly periodic default.
    One rng stream is consumed in task order, so the all-periodic path
    reproduces the seed implementation exactly.
    """
    rng = np.random.default_rng(seed)
    out: List[Tuple[float, int]] = []
    for t_idx, task in enumerate(tasks):
        proc = processes[t_idx] if processes is not None else None
        proc = proc or task.arrival or DEFAULT_ARRIVAL
        for t in proc.sample(task, duration, rng):
            out.append((t, task.model_idx))
    out.sort()
    return out


def generate_release_events(
    tasks: Sequence[TaskSpec],
    duration: float,
    seed: int = 0,
    processes: Optional[Sequence[Optional[ArrivalProcess]]] = None,
) -> Tuple[List[tuple], Dict[int, _ClientRuntime]]:
    """Open-loop arrivals plus closed-loop first releases, for the engines.

    Returns ``(events, clients)``.  With no closed-loop task, ``events``
    IS the ``generate_arrivals`` output (``[(t, model_idx)]`` — the
    pre-closed-loop event order, and therefore every open-loop
    fingerprint, is untouched) and ``clients`` is empty.  With
    closed-loop tasks, every event is ``(t, model_idx, task_idx, user)``
    with ``task_idx = user = -1`` marking open-loop entries; the list is
    sorted on the full tuple — a fixed tie order both engines share —
    and ``clients`` maps task_idx to the mutable :class:`_ClientRuntime`
    whose ``next_release`` the engines invoke when that task's requests
    complete, drop, or are shed.  Open-loop tasks draw from the shared
    per-trial stream exactly as if the closed-loop tasks were absent
    (their slots consume nothing)."""
    resolved: List[ArrivalProcess] = []
    for t_idx, task in enumerate(tasks):
        proc = processes[t_idx] if processes is not None else None
        resolved.append(proc or task.arrival or DEFAULT_ARRIVAL)
    clients: Dict[int, _ClientRuntime] = {}
    if not any(isinstance(p, ClosedLoopClients) for p in resolved):
        return generate_arrivals(tasks, duration, seed, processes=processes), clients
    open_procs: List[ArrivalProcess] = []
    for t_idx, proc in enumerate(resolved):
        if isinstance(proc, ClosedLoopClients):
            clients[t_idx] = proc.runtime(t_idx, seed, duration)
            open_procs.append(_NULL_ARRIVAL)
        else:
            open_procs.append(proc)
    events: List[tuple] = [
        (t, m, -1, -1)
        for t, m in generate_arrivals(tasks, duration, seed, processes=open_procs)
    ]
    for t_idx, rt in clients.items():
        m = tasks[t_idx].model_idx
        for t, u in rt.initial():
            events.append((t, m, t_idx, u))
    events.sort()
    return events, clients


def drop_hopeless(
    now: float,
    ready: List[Request],
    remaining_min: Sequence[np.ndarray],
    stats: Dict[int, ModelStats],
) -> List[Request]:
    """Early-drop (all policies, paper Sec. IV-C): drop ready requests whose
    remaining minimum execution time can no longer meet the deadline.
    Module-level so campaign-style trial runners and tests share the exact
    bookkeeping the event loop uses (mutates ``ready`` and ``stats``).
    Returns the dropped requests in ready-insertion order, so the event
    loop can settle their backlog/closed-loop obligations.

    ``remaining_min[m][l]`` is the minimum remaining work from layer
    ``l`` INCLUSIVE — ``ModelPlan.crit_from``, which on a DAG plan is the
    critical path of the sub-DAG at ``l`` (every node is an ancestor of
    the sink, so a hopeless ready node makes the whole request hopeless).
    A DAG request drops ONCE: the first hopeless node entry marks the
    shared :class:`DagRun` and returns as the request's representative;
    sibling entries are swept out of ``ready`` uncounted, and a running
    sibling's eventual finish is a no-op.
    """
    out: List[Request] = []
    any_dag_drop = False
    for req in list(ready):
        plan_idx = req.model_idx
        min_rem = float(remaining_min[plan_idx][req.next_layer])
        if now + min_rem > req.deadline_abs + 1e-12:
            dr = req.dag
            if dr is not None:
                if dr.dropped:  # sibling already dropped this round
                    req.dropped = True
                    ready.remove(req)
                    continue
                dr.dropped = True
                any_dag_drop = True
            req.dropped = True
            ready.remove(req)
            st = stats[plan_idx]
            st.missed += 1
            st.dropped += 1
            out.append(req)
    if any_dag_drop:
        # sweep sibling entries examined before their request's drop
        for req in list(ready):
            if req.dag is not None and req.dag.dropped:
                req.dropped = True
                ready.remove(req)
    return out


#: engines accepted by :func:`simulate`; "auto" picks the SoA engine for
#: the built-in scheduler classes and falls back to the reference event
#: loop for custom ``Scheduler`` subclasses (whose ``schedule()`` needs a
#: :class:`SchedView`).  REPRO_SIM_ENGINE overrides the default.
#: "batch" is the device-resident batched engine
#: (``repro.core.engine_batch``) — it is NEVER auto-picked: it must be
#: requested explicitly (it jit-compiles whole-trial programs, which only
#: pays off across a seed batch), and an unsupported axis raises its
#: named ``BatchUnsupportedError`` instead of silently falling back.
SIM_ENGINES = ("auto", "soa", "reference", "batch")


def simulate(
    plans: Sequence[ModelPlan],
    tasks: Sequence[TaskSpec],
    duration: float,
    scheduler: Scheduler,
    seed: int = 0,
    processes: Optional[Sequence[Optional[ArrivalProcess]]] = None,
    budget_policy: Union["BudgetPolicy", str, None] = None,
    engine: Optional[str] = None,
    round_kernel: Optional[str] = None,
    admission: Union["AdmissionPolicy", str, None] = None,
    faults: Union["FaultModel", str, None] = None,
) -> SimResult:
    """``faults`` selects the accelerator fault model (a call-spec string
    like ``"down(acc=0,start=0.1,duration=0.2)"`` — several joined with
    ``+`` — a :class:`repro.core.faults.FaultModel`, or ``None`` ==
    ``"none"``: fault-free, bit-identical to the pre-fault-axis
    simulator).  Fault windows resolve into capability events merged into
    the event loop: a down accelerator is busy-forever and evicts its
    in-flight layer (``restart`` | ``resume`` interrupted-work policy), a
    throttled one scales its latency column, and schedulers see the
    masked/reweighted tables; see ``repro.core.faults``.

    ``admission`` selects the overload-control policy applied at every
    request release (a call-spec string like ``"shed_early(margin=1.5)"``
    / ``"token_bucket(rate=100,burst=10)"``, an instance, or ``None`` ==
    ``"none"`` — admit everything, bit-identical to the pre-admission
    simulator).  A shed request counts released + missed + dropped +
    shed and never enters the ready set; see ``repro.core.admission``.

    ``budget_policy`` selects the online virtual-budget policy (a
    call-spec string like ``"reclaim"`` / ``"adaptive(tick=0.02)"``, an
    instance, or ``None`` == ``"static"`` — the paper's offline budgets,
    bit-identical to the seed simulator).  The policy is invoked at
    request release, at every non-final layer finish (slack reclamation),
    and — when it defines a positive ``tick_interval`` — at periodic
    controller tick events interleaved with the regular event stream
    (ticks see the ready queue and accelerator availability; see
    ``repro.core.budget_online`` for what each policy does with them).

    ``engine`` selects the event-loop implementation: ``"soa"`` is the
    structure-of-arrays engine (``repro.core.engine_soa``, several times
    faster, bit-identical — pinned by the differential tests),
    ``"reference"`` is the retained original event loop (the oracle),
    ``"auto"``/``None`` picks SoA whenever the scheduler is one of the
    built-in classes it has a kernel for.  The REPRO_SIM_ENGINE
    environment variable overrides ``None``/``"auto"`` (so a campaign —
    whose TrialSpecs carry the default ``"auto"`` — can be forced onto
    one engine without touching call sites); an explicit ``"soa"`` or
    ``"reference"`` argument always wins.

    ``round_kernel`` selects the SoA engine's Terastal round
    implementation for deep ready queues — ``"python"`` (scalar and
    vectorized kernels, depth-dispatched), ``"jax"`` (force the jitted
    ``scheduler_jax.terastal_round``), or ``"auto"``/``None`` (python
    below the calibrated crossover; see ``engine_soa.round_crossover``).
    ``REPRO_ROUND_KERNEL`` overrides ``None``.  All choices are
    bit-identical (pinned by the differential suites); the knob exists
    for performance and for the differential tests themselves.  Ignored
    by the reference engine.
    """
    from repro.core.admission import make_admission_policy
    from repro.core.budget_online import make_budget_policy
    from repro.core.faults import make_fault_model

    if engine is None or engine == "auto":
        engine = os.environ.get("REPRO_SIM_ENGINE") or "auto"
    if engine not in SIM_ENGINES:
        raise ValueError(f"unknown engine {engine!r} (have {SIM_ENGINES})")
    fault_model = make_fault_model(faults)
    if engine == "batch":
        # the degenerate B=1 batch: same contract, one device program per
        # call — use engine_batch.simulate_batch directly for real batches
        from repro.core import engine_batch

        return engine_batch.simulate_batch(
            plans, tasks, duration, scheduler, [seed], processes=processes,
            budget_policy=budget_policy, admission=admission,
            faults=fault_model,
        )[0]
    policy = make_budget_policy(budget_policy)
    policy.reset()  # instances may be reused across runs (e.g. seed sweeps)
    adm = make_admission_policy(admission)
    adm.reset()

    # ---- DAG-plan axis gating (repro.core.dag) --------------------------
    # Precedence-aware scheduling composes with schedulers, arrivals,
    # admission, closed-loop clients, and (since the fault-aware
    # critical-path re-tightening landed) accelerator faults on both
    # scalar engines.  Online budget policies stay linear-chain only:
    # they rebase vdl chains with cumsum, which cannot express a DAG's
    # overlapping branch budgets — refuse loudly instead of silently
    # mis-simulating.
    dag_model = next((p.model.name for p in plans if p.dag is not None), None)
    if dag_model is not None:
        if policy.name != "static" or policy.tick_interval > 0:
            raise ValueError(
                f"budget policy {policy.name!r} is linear-chain only; DAG plans "
                f"(model {dag_model!r}) support only the static offline budgets"
            )

    if engine != "reference":
        from repro.core import engine_soa

        supported = engine_soa.supports_scheduler(scheduler)
        if engine == "soa" and not supported:
            raise ValueError(
                f"engine='soa' has no kernel for {type(scheduler).__name__}; "
                "use engine='auto' (falls back) or engine='reference'"
            )
        if supported:
            return engine_soa.simulate_soa(
                plans, tasks, duration, scheduler, seed, processes, policy,
                round_kernel=round_kernel, admission=adm,
                fault_model=fault_model,
            )
    return _simulate_reference(
        plans, tasks, duration, scheduler, seed, processes, policy, adm,
        fault_model,
    )


def _simulate_reference(
    plans: Sequence[ModelPlan],
    tasks: Sequence[TaskSpec],
    duration: float,
    scheduler: Scheduler,
    seed: int,
    processes: Optional[Sequence[Optional[ArrivalProcess]]],
    policy: "BudgetPolicy",
    admission: "AdmissionPolicy" = None,
    fault_model: "FaultModel" = None,
) -> SimResult:
    """The original per-object event loop, retained verbatim as the
    differential oracle for the SoA engine (every optimization must stay
    bit-identical to THIS implementation)."""
    from repro.core.admission import NoAdmission
    from repro.core.faults import (
        degraded_work_tables,
        effective_plans,
        evict_busy_adjust,
        fault_multipliers,
        retightened_vdl,
        retime_busy_adjust,
    )

    n_acc = plans[0].platform.n_acc
    acc_busy_until = np.zeros(n_acc)
    acc_busy_time = np.zeros(n_acc)
    acc_busy_in_horizon = np.zeros(n_acc)
    stats: Dict[int, ModelStats] = {t.model_idx: ModelStats() for t in tasks}

    # Precompute hot per-plan tables once.  ``crit_from`` is the minimum
    # remaining work (critical path to the sink on DAG plans); on linear
    # chains it is the exact ``remaining_min[:-1]`` slice, so the rename
    # is bitwise inert for every pre-DAG scenario.
    n_layers = [len(p.model.layers) for p in plans]
    remaining_min = [p.crit_from for p in plans]

    # Fault state (``repro.core.faults``).  ``eff_plans`` are the
    # capability-masked plan copies every scheduling decision reads; with
    # no fault model they ARE the offline plans, so the fault-off path is
    # bit-identical to the pre-fault-axis loop.  Budget-policy hooks and
    # completed-accuracy accounting keep the ORIGINAL plans (budgets and
    # losses are offline objects; faults change capability, not accuracy).
    # With ``retighten=false`` the admission work tables and every vdl
    # chain stay frozen at fault-free values (the original fault axis);
    # ``retighten=true`` re-derives both from degraded capability on
    # every capability event (see ``refresh_tables``).
    fm = fault_model if fault_model is not None and fault_model.active else None
    eff_plans = list(plans)
    faulted_spans = 0
    retighten = fm is not None and fm.retighten
    cur_chain: List[Optional[np.ndarray]] = [None] * len(plans)
    if fm is not None:
        fault_events, faulted_spans = fm.timeline(n_acc, duration, seed)
        avail = [True] * n_acc
        fscale = [1.0] * n_acc
        cur_fin = [-1] * n_acc  # counter of each acc's valid finish event
        disp_start = [0.0] * n_acc  # in-flight dispatch: start time and the
        disp_w = [0.0] * n_acc  # wall / in-horizon busy amounts credited
        disp_h = [0.0] * n_acc
        resume = fm.interrupted == "resume"

    # Admission state.  ``backlog_ns`` is the remaining minimum work of
    # admitted, not-yet-finished requests in INTEGER nanoseconds —
    # integer adds are order-independent, so the SoA engine's different
    # within-round drop order cannot produce divergent backlog values.
    adm = None if admission is None or type(admission) is NoAdmission else admission
    if adm is not None:
        adm.bind(n_acc)
    need_backlog = adm is not None and adm.needs_backlog
    backlog_ns = 0
    min_work_s = [p.crit_total for p in plans]
    work_ns = [int(round(w * 1e9)) for w in min_work_s]

    events, clients = generate_release_events(tasks, duration, seed, processes)
    heap: List[Tuple[float, int, int, object]] = []
    counter = itertools.count()
    for evt in events:
        if len(evt) == 2:
            t, payload = evt
        else:
            t, m, t_idx, u = evt
            payload = m if t_idx < 0 else (m, t_idx, u)
        heapq.heappush(heap, (t, next(counter), _ARRIVAL, payload))
    if fm is not None:
        # capability events enter the heap after all arrivals and before
        # the tick, so same-timestamp ordering (arrival < fault < tick <
        # finish) is fixed by counters identically in both engines
        for fe in fault_events:
            heapq.heappush(heap, (fe.t, next(counter), _FAULT, fe))
    if policy.tick_interval > 0 and heap:
        heapq.heappush(heap, (policy.tick_interval, next(counter), _TICK, None))

    ready: List[Request] = []
    running: Dict[int, Tuple[Request, bool]] = {}  # acc -> (req, used_variant)
    rid_counter = itertools.count()
    rounds = 0  # scheduling rounds, reported on SimResult.rounds

    def push_release(client: Tuple[int, int], t: float) -> None:
        """Schedule a closed-loop user's next release after its request
        left the system at ``t``."""
        t_idx, u = client
        nxt = clients[t_idx].next_release(u, t)
        if nxt is not None:
            heapq.heappush(
                heap,
                (nxt, next(counter), _ARRIVAL, (tasks[t_idx].model_idx, t_idx, u)),
            )

    def invoke_scheduler(now: float) -> None:
        nonlocal rounds, backlog_ns
        rounds += 1
        dropped_now = drop_hopeless(now, ready, remaining_min, stats)
        if dropped_now:
            if need_backlog:
                for r in dropped_now:
                    backlog_ns -= r.work_ns
            if clients:
                # canonical per-round release order (sorted by client):
                # both engines drop the same SET in different orders, so
                # the release pushes sort to keep event counters identical
                for r in sorted(
                    (r for r in dropped_now if r.client is not None),
                    key=lambda r: r.client,
                ):
                    push_release(r.client, now)
        if not ready:
            return
        view = SchedView(now=now, ready=list(ready), acc_busy_until=acc_busy_until.copy(), plans=eff_plans)
        for a in scheduler.schedule(view):
            if a.req not in ready:  # defensive: policy returned stale item
                continue
            if acc_busy_until[a.acc] > now + 1e-15:
                continue  # defensive: policy targeted a busy accelerator
            plan = eff_plans[a.req.model_idx]
            c = float(plan.lat_var[a.layer, a.acc]) if a.use_variant else float(plan.lat[a.layer, a.acc])
            ready.remove(a.req)
            if a.use_variant:
                a.req.applied_variants = a.req.applied_variants | {a.layer}
                stats[a.req.model_idx].variants_applied += 1
                dr = a.req.dag
                if dr is not None:
                    # the request-wide variant set lives on the shared
                    # DagRun; live sibling entries refresh so combo
                    # validity sees it from the next round on (decisions
                    # WITHIN this round were already taken from pre-round
                    # state — both engines share that quirk)
                    dr.applied_variants = dr.applied_variants | {a.layer}
                    for r in ready:
                        if r.dag is dr:
                            r.applied_variants = dr.applied_variants
            if fm is not None:
                if a.req.evicted_pending:
                    a.req.evicted_pending = False
                    stats[a.req.model_idx].remapped += 1
                if a.req.layer_frac > 0.0:
                    # resume policy: only the un-executed remainder of the
                    # interrupted layer runs (schedulers still estimate
                    # with the full row — a documented estimation error)
                    c = c * (1.0 - a.req.layer_frac)
            acc_busy_until[a.acc] = now + c
            acc_busy_time[a.acc] += c
            h = min(c, max(0.0, duration - now))
            acc_busy_in_horizon[a.acc] += h
            running[a.acc] = (a.req, a.use_variant)
            fin_cnt = next(counter)
            heapq.heappush(heap, (now + c, fin_cnt, _FINISH, a.acc))
            if fm is not None:
                cur_fin[a.acc] = fin_cnt
                disp_start[a.acc] = now
                disp_w[a.acc] = c
                disp_h[a.acc] = h

    def evict(k: int, now: float) -> None:
        """A down event interrupted acc ``k``'s in-flight layer: undo the
        dispatch (variant bookkeeping, un-run busy time), carry progress
        under ``resume``, and re-enqueue the request for re-mapping.

        DAG entries: the variant undo also retracts the node from the
        shared ``DagRun`` set (and refreshes live siblings' snapshots),
        and a request whose run was already counted dropped is NOT
        re-enqueued — its eviction is a busy-time correction only,
        mirroring how a dropped run's still-running finish is a no-op."""
        req, used_var = running.pop(k)
        dr = req.dag
        run_dropped = dr is not None and dr.dropped
        if used_var:
            req.applied_variants = req.applied_variants - {req.next_layer}
            stats[req.model_idx].variants_applied -= 1
            if dr is not None:
                dr.applied_variants = dr.applied_variants - {req.next_layer}
                for r in ready:
                    if r.dag is dr:
                        r.applied_variants = dr.applied_variants
        fin_old = float(acc_busy_until[k])
        t0 = disp_start[k]
        if resume and fin_old > t0:
            req.layer_frac = req.layer_frac + (1.0 - req.layer_frac) * (
                (now - t0) / (fin_old - t0)
            )
        else:
            req.layer_frac = 0.0
        dw, dh = evict_busy_adjust(t0, now, duration, disp_w[k], disp_h[k])
        acc_busy_time[k] += dw
        acc_busy_in_horizon[k] += dh
        if run_dropped:
            return  # drop already counted; nothing left to re-map
        req.evicted_pending = True
        stats[req.model_idx].evicted += 1
        ready.append(req)

    def refresh_tables(now: float) -> None:
        """Capability changed: swap the effective tables and — under
        ``retighten=true`` — re-run the tightening kernel, rebind every
        live request's vdl chain, and re-derive the admission work
        tables from degraded capacity.  Finishes with the capability
        hook so online budget policies observe the event."""
        nonlocal eff_plans, remaining_min, min_work_s, work_ns
        eff_plans = effective_plans(plans, fault_multipliers(fscale, avail))
        remaining_min = [p.crit_from for p in eff_plans]
        if retighten:
            cur_chain[:] = retightened_vdl(plans, eff_plans)
            for r in ready:
                ch = cur_chain[r.model_idx]
                r.vdl_abs = None if ch is None else r.arrival + ch
            for r, _ in running.values():
                ch = cur_chain[r.model_idx]
                r.vdl_abs = None if ch is None else r.arrival + ch
            if adm is not None:
                min_work_s, work_ns = degraded_work_tables(eff_plans, duration)
                adm.bind(max(1, sum(avail)))
        policy.on_capability(now, ready, plans, eff_plans, acc_busy_until)

    while heap:
        now, evt_cnt, kind, payload = heapq.heappop(heap)
        if kind == _ARRIVAL:
            if type(payload) is tuple:
                m, t_idx, u = payload
                client = (t_idx, u)
            else:
                m = payload
                client = None
            req = Request(
                rid=next(rid_counter),
                model_idx=m,
                arrival=now,
                deadline_abs=now + plans[m].deadline,
                client=client,
            )
            dag = plans[m].dag
            if dag is not None:
                # one logical request, one rid, one shared DagRun; the
                # representative entry sits at the lowest source node and
                # is the one admission judges
                req.next_layer = dag.sources[0]
                req.dag = DagRun.fresh(dag)
            if adm is not None and not adm.admit(req, now, backlog_ns, min_work_s[m]):
                # shed at the door: released+missed+dropped+shed, never
                # enters ready and the budget policy never sees it
                req.dropped = True
                st = stats[m]
                st.released += 1
                st.missed += 1
                st.dropped += 1
                st.shed += 1
                if client is not None:
                    push_release(client, now)
            else:
                policy.on_release(req, plans[m], now)
                if retighten and cur_chain[m] is not None:
                    # released into degraded capability: bind the
                    # re-tightened chain (overriding any policy install)
                    req.vdl_abs = now + cur_chain[m]
                stats[m].released += 1
                if need_backlog:
                    # the admitted work rides on the request (frozen at
                    # admission, so add/remove stays symmetric even when
                    # retighten re-derives the tables mid-trial)
                    req.work_ns = work_ns[m]
                    backlog_ns += req.work_ns
                ready.append(req)
                if dag is not None:
                    # sibling ready entries for the remaining source
                    # nodes, ascending — one per precedence-unblocked
                    # node, all sharing rid/deadline/client/DagRun
                    for s in dag.sources[1:]:
                        ready.append(
                            Request(
                                rid=req.rid,
                                model_idx=m,
                                arrival=now,
                                deadline_abs=req.deadline_abs,
                                next_layer=s,
                                client=client,
                                dag=req.dag,
                                vdl_abs=req.vdl_abs,
                                work_ns=req.work_ns,
                            )
                        )
        elif kind == _TICK:
            policy.on_tick(now, ready, plans, acc_busy_until)
            # keep ticking only while real events remain, so the loop
            # always terminates (there is at most one tick in the heap)
            if heap:
                heapq.heappush(
                    heap, (now + policy.tick_interval, next(counter), _TICK, None)
                )
        elif kind == _FAULT:
            fe = payload
            k = fe.acc
            if fe.code == "down":
                avail[k] = False
                if k in running:
                    evict(k, now)
                acc_busy_until[k] = np.inf  # down == busy forever
                cur_fin[k] = -1
                refresh_tables(now)
            elif fe.code == "up":
                avail[k] = True
                acc_busy_until[k] = now
                refresh_tables(now)
            else:  # scale: throttle multiplier transition
                old = fscale[k]
                fscale[k] = fe.value
                if k in running and fe.value != old:
                    # re-time the in-flight layer: remaining wall time
                    # stretches (or shrinks) by new_scale / old_scale
                    fin_old = float(acc_busy_until[k])
                    fin_new = now + (fin_old - now) * (fe.value / old)
                    acc_busy_until[k] = fin_new
                    dw, dh, disp_w[k], disp_h[k] = retime_busy_adjust(
                        disp_start[k], fin_new, duration, disp_w[k], disp_h[k]
                    )
                    acc_busy_time[k] += dw
                    acc_busy_in_horizon[k] += dh
                    fin_cnt = next(counter)
                    heapq.heappush(heap, (fin_new, fin_cnt, _FINISH, k))
                    cur_fin[k] = fin_cnt
                refresh_tables(now)
        elif fm is not None and evt_cnt != cur_fin[payload]:
            pass  # stale finish: its dispatch was evicted or re-timed
        else:  # _FINISH
            acc = payload
            req, _ = running.pop(acc)
            if req.dag is not None:
                # DAG node finish: no layer increment — the entry IS one
                # node.  A dropped request's still-running sibling
                # finishes as a no-op (its busy time already accrued;
                # drop accounting happened once at drop time).
                dr = req.dag
                if not dr.dropped:
                    m = req.model_idx
                    dag = plans[m].dag
                    node = req.next_layer
                    dr.n_done += 1
                    if node == dag.sink:
                        # every node is an ancestor of the unique sink,
                        # so sink finish == request completion
                        req.done_time = now
                        st = stats[m]
                        st.completed += 1
                        if now > req.deadline_abs + 1e-12:
                            st.missed += 1
                        st.retained_sum += plans[m].combo_retained(dr.applied_variants)
                        if need_backlog:
                            backlog_ns -= req.work_ns
                        if req.client is not None:
                            push_release(req.client, now)
                    else:
                        for s in dag.succs[node]:
                            dr.pending[s] -= 1
                            if dr.pending[s] == 0:
                                ready.append(
                                    Request(
                                        rid=req.rid,
                                        model_idx=m,
                                        arrival=req.arrival,
                                        deadline_abs=req.deadline_abs,
                                        next_layer=s,
                                        applied_variants=dr.applied_variants,
                                        client=req.client,
                                        dag=dr,
                                        vdl_abs=req.vdl_abs,
                                        work_ns=req.work_ns,
                                    )
                                )
                if heap and abs(heap[0][0] - now) < 1e-15:
                    continue
                invoke_scheduler(now)
                continue
            req.next_layer += 1
            if fm is not None:
                req.layer_frac = 0.0
            if req.is_finished(n_layers[req.model_idx]):
                req.done_time = now
                st = stats[req.model_idx]
                st.completed += 1
                if now > req.deadline_abs + 1e-12:
                    st.missed += 1
                st.retained_sum += plans[req.model_idx].combo_retained(req.applied_variants)
                if need_backlog:
                    backlog_ns -= req.work_ns
                if req.client is not None:
                    push_release(req.client, now)
            else:
                policy.on_layer_finish(req, plans[req.model_idx], req.next_layer - 1, now)
                ready.append(req)
        # batch-process simultaneous events before scheduling
        if heap and abs(heap[0][0] - now) < 1e-15:
            continue
        invoke_scheduler(now)

    # Horizon drain: a DAG request may be split over several sibling
    # entries (ready and/or running) — count the logical request once,
    # and not at all if it was already counted dropped.
    seen_runs: set = set()

    def drain_in_flight(r: Request) -> None:
        if r.dag is None:
            stats[r.model_idx].in_flight += 1
        elif not r.dag.dropped and id(r.dag) not in seen_runs:
            seen_runs.add(id(r.dag))
            stats[r.model_idx].in_flight += 1

    for r in ready:
        drain_in_flight(r)
    for r, _ in running.values():
        drain_in_flight(r)

    return SimResult(
        duration=duration,
        per_model=stats,
        acc_busy_time=acc_busy_time,
        scheduler_name=scheduler.name,
        acc_busy_in_horizon=acc_busy_in_horizon,
        rounds=rounds,
        faulted_spans=faulted_spans,
    )

"""Event-driven multi-accelerator, multi-DNN inference simulator.

Semantics follow Sec. IV of the paper exactly:

* Layer-granularity, non-preemptive jobs; decisions only at layer
  boundaries.  The scheduler is invoked whenever an accelerator becomes
  idle (layer finish) and at request arrivals.
* All accelerators share on-chip memory, so consecutive layers of one
  request may run on different accelerators with no migration penalty
  beyond what the latency model already charges.
* Per-layer latencies are deterministic constants from the offline
  profile (original and variant tables in the :class:`ModelPlan`).
* Early-drop (all policies): a request whose remaining minimum execution
  time can no longer meet its absolute deadline is dropped (counts as a
  miss) to free resources.
* Periodic tasks: request ``j`` of model ``m`` arrives at ``j / fps`` (a
  task with ``prob < 1`` fires each period with that probability — the
  Hand S/P "Prob: 0.5" entry of Table II), with relative deadline
  ``D_m = 1 / fps``.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import Assignment, Request, SchedView, Scheduler
from repro.core.variants import ModelPlan


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One periodic entry of a workload scenario (Table II row item)."""

    model_idx: int
    fps: float
    prob: float = 1.0

    @property
    def period(self) -> float:
        return 1.0 / self.fps


@dataclasses.dataclass
class ModelStats:
    released: int = 0
    completed: int = 0
    missed: int = 0  # late completions + drops
    dropped: int = 0
    retained_sum: float = 0.0  # sum of retained-accuracy fractions
    variants_applied: int = 0

    @property
    def miss_rate(self) -> float:
        return self.missed / self.released if self.released else 0.0

    @property
    def mean_retained(self) -> float:
        return self.retained_sum / self.completed if self.completed else 1.0

    @property
    def mean_norm_accuracy_loss(self) -> float:
        return 1.0 - self.mean_retained


@dataclasses.dataclass
class SimResult:
    duration: float
    per_model: Dict[int, ModelStats]
    acc_busy_time: np.ndarray
    scheduler_name: str

    @property
    def mean_miss_rate(self) -> float:
        """Average of per-model deadline miss rates (paper's metric)."""
        rates = [s.miss_rate for s in self.per_model.values() if s.released]
        return float(np.mean(rates)) if rates else 0.0

    def mean_accuracy_loss(self, plans: Sequence[ModelPlan]) -> float:
        """Average normalized accuracy loss across models WITH variants."""
        losses = [
            s.mean_norm_accuracy_loss
            for m, s in self.per_model.items()
            if plans[m].variants and s.completed
        ]
        return float(np.mean(losses)) if losses else 0.0

    def utilization(self) -> np.ndarray:
        return self.acc_busy_time / self.duration


_ARRIVAL, _FINISH = 0, 1


def generate_arrivals(
    tasks: Sequence[TaskSpec], duration: float, seed: int = 0
) -> List[Tuple[float, int]]:
    """[(arrival_time, model_idx)] honoring per-task firing probability."""
    rng = np.random.default_rng(seed)
    out: List[Tuple[float, int]] = []
    for t_idx, task in enumerate(tasks):
        n = int(np.floor(duration * task.fps))
        for j in range(n):
            if task.prob >= 1.0 or rng.random() < task.prob:
                out.append((j * task.period, task.model_idx))
    out.sort()
    return out


def simulate(
    plans: Sequence[ModelPlan],
    tasks: Sequence[TaskSpec],
    duration: float,
    scheduler: Scheduler,
    seed: int = 0,
) -> SimResult:
    n_acc = plans[0].platform.n_acc
    acc_busy_until = np.zeros(n_acc)
    acc_busy_time = np.zeros(n_acc)
    stats: Dict[int, ModelStats] = {t.model_idx: ModelStats() for t in tasks}

    # Precompute hot per-plan tables once.
    n_layers = [len(p.model.layers) for p in plans]
    remaining_min = [p.remaining_min for p in plans]

    heap: List[Tuple[float, int, int, object]] = []
    counter = itertools.count()
    for arr, m in generate_arrivals(tasks, duration, seed):
        heapq.heappush(heap, (arr, next(counter), _ARRIVAL, m))

    ready: List[Request] = []
    running: Dict[int, Tuple[Request, bool]] = {}  # acc -> (req, used_variant)
    rid_counter = itertools.count()

    def drop_hopeless(now: float) -> None:
        for req in list(ready):
            plan_idx = req.model_idx
            min_rem = float(remaining_min[plan_idx][req.next_layer])
            if now + min_rem > req.deadline_abs + 1e-12:
                req.dropped = True
                ready.remove(req)
                st = stats[plan_idx]
                st.missed += 1
                st.dropped += 1

    def invoke_scheduler(now: float) -> None:
        drop_hopeless(now)
        if not ready:
            return
        view = SchedView(now=now, ready=list(ready), acc_busy_until=acc_busy_until.copy(), plans=plans)
        for a in scheduler.schedule(view):
            if a.req not in ready:  # defensive: policy returned stale item
                continue
            if acc_busy_until[a.acc] > now + 1e-15:
                continue  # defensive: policy targeted a busy accelerator
            plan = plans[a.req.model_idx]
            c = float(plan.lat_var[a.layer, a.acc]) if a.use_variant else float(plan.lat[a.layer, a.acc])
            ready.remove(a.req)
            if a.use_variant:
                a.req.applied_variants = a.req.applied_variants | {a.layer}
                stats[a.req.model_idx].variants_applied += 1
            acc_busy_until[a.acc] = now + c
            acc_busy_time[a.acc] += c
            running[a.acc] = (a.req, a.use_variant)
            heapq.heappush(heap, (now + c, next(counter), _FINISH, a.acc))

    while heap:
        now, _, kind, payload = heapq.heappop(heap)
        if kind == _ARRIVAL:
            m = payload
            req = Request(
                rid=next(rid_counter),
                model_idx=m,
                arrival=now,
                deadline_abs=now + plans[m].deadline,
            )
            stats[m].released += 1
            ready.append(req)
        else:  # _FINISH
            acc = payload
            req, _ = running.pop(acc)
            req.next_layer += 1
            if req.is_finished(n_layers[req.model_idx]):
                req.done_time = now
                st = stats[req.model_idx]
                st.completed += 1
                if now > req.deadline_abs + 1e-12:
                    st.missed += 1
                st.retained_sum += plans[req.model_idx].combo_retained(req.applied_variants)
            else:
                ready.append(req)
        # batch-process simultaneous events before scheduling
        if heap and abs(heap[0][0] - now) < 1e-15:
            continue
        invoke_scheduler(now)

    return SimResult(
        duration=duration,
        per_model=stats,
        acc_busy_time=acc_busy_time,
        scheduler_name=scheduler.name,
    )

"""Online virtual-budget policies: per-request budgets as runtime state.

The offline stage (Algorithm 1) freezes one ``vdl_rel`` table per model,
calibrated for periodic releases.  This module makes virtual budgets
*mutable per-request state*: every policy manipulates ``Request.vdl_abs``
(absolute per-layer virtual deadlines) through the same incremental
tightening kernel the offline algorithm uses
(:func:`repro.core.budget.tighten_budgets`), re-distributing the
*remaining* deadline over the *remaining* layers.

Fidelity notes
--------------
* ``static`` is the paper: budgets are assigned offline by Algorithm 1
  and never touched again.  It leaves ``Request.vdl_abs`` unset, so the
  schedulers read the frozen ``ModelPlan.vdl_rel`` table and the
  simulator is bit-identical to the seed/PR-1 implementation (pinned by
  ``tests/test_budget_online.py``).
* ``reclaim`` — # APPROX (beyond paper): when a layer finishes ahead of
  its virtual deadline, the unused slack is pushed into the downstream
  layers' budgets by re-running the proportional distribution over the
  remaining layers at the request's *current* constraint levels (the
  kernel with ``rho0 = rho_offline``).  Slack reclamation is the
  standard bridge from static budgets to dynamic workloads in the
  real-time literature (arXiv:2505.11970, PAPERS.md); the proportional
  form is ours, chosen so ``static`` is the exact fixed point when every
  layer finishes precisely on its virtual deadline.
* ``adaptive`` — # APPROX (beyond paper): burst-gated, skew-gated
  reclamation with a staleness-repair controller.  A release-rate
  detector keeps the policy *exactly static* under the paper's periodic
  regime (and plain Poisson); inside detected bursts, reclaimed
  (tightened) milestones are applied only to layers whose
  cross-accelerator latency skew makes a mis-placement catastrophic,
  and controller ticks restore any reclaimed chain that observed
  congestion has made unattainable back to the offline kernel
  distribution.  This is the "budget re-distribution under observed
  burstiness" item from ROADMAP.md; every gate and threshold here is an
  engineering choice validated by `benchmarks/fig8_adaptive_budgets.py`,
  not from the paper.  A design fact the gates rest on (pinned by the
  ``monotone`` regression test): the static absolute chain is the
  loosest member of the re-anchoring family, so every online move is a
  *tightening* whose value depends on which placements it revokes.

Invariants (all policies, property-tested): a request's remaining
budgets always sum to at most its remaining deadline, and no layer's
budget ever falls below that layer's minimum achievable latency.  A
re-distribution that would be infeasible leaves the request's state
unchanged — the simulator's early-drop then handles it, exactly as for
static budgets.
"""

from __future__ import annotations

import collections
import inspect
from typing import Deque, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.budget import tighten_budgets
from repro.core.scheduler import Request
from repro.core.variants import ModelPlan


class BudgetPolicy:
    """Hooks the event-driven simulator invokes around budget state.

    ``tick_interval == 0`` disables controller ticks; the base class is
    fully inert (no per-request state is ever created), which is exactly
    the ``static`` policy.

    Contract for implementations: a chain update must REBIND
    ``req.vdl_abs`` (assign a fresh array), never mutate the existing
    array in place.  All built-ins do; the SoA simulation engine
    (``repro.core.engine_soa``) relies on object identity to detect
    which cached virtual-deadline scalars a hook invalidated, and the
    reference engine's ``reclaim``/``adaptive`` semantics (``is``
    comparisons in :meth:`AdaptiveBudgetPolicy.on_layer_finish`) already
    assume it.
    """

    name = "static"
    tick_interval: float = 0.0

    def reset(self) -> None:
        """Clear any cross-run state.  ``simulate()`` calls this before
        every run so one policy instance can be reused across seeds
        without leaking burst-detector or cache state between runs."""

    def on_release(self, req: Request, plan: ModelPlan, now: float) -> None:
        """Request released at ``now``: initialize its budget state."""

    def on_layer_finish(self, req: Request, plan: ModelPlan, layer: int, now: float) -> None:
        """Layer ``layer`` of ``req`` finished at ``now`` (request not done)."""

    def on_tick(
        self,
        now: float,
        ready: List[Request],
        plans: Sequence[ModelPlan],
        acc_busy_until: np.ndarray,
    ) -> None:
        """Periodic controller tick over the queued (ready) requests."""

    def on_capability(
        self,
        now: float,
        ready: List[Request],
        plans: Sequence[ModelPlan],
        eff_plans: Sequence[ModelPlan],
        acc_busy_until: np.ndarray,
    ) -> None:
        """Capability event at ``now`` (accelerator down/up/throttle —
        ``repro.core.faults``): the fourth hook, alongside on-release /
        on-layer-finish / on-tick.  Both engines invoke it after the
        fault handler swapped its effective tables (``eff_plans`` are the
        capability-masked plan copies; ``plans`` the offline originals)
        and — under ``retighten=true`` — after the engine rebound every
        live request's ``vdl_abs`` to the re-tightened chain.  The REBIND
        contract applies here too: chain updates must assign a fresh
        array so the SoA engine's identity check catches them.  The base
        policy ignores capability events (budgets stay as they are)."""


class StaticBudgetPolicy(BudgetPolicy):
    """The paper's offline budgets, untouched at runtime."""

    name = "static"


def _rebase(
    req: Request, l0: int, now: float, budgets: np.ndarray, monotone: bool = False
) -> None:
    """Write absolute virtual deadlines for layers >= l0 from ``now``.

    ``monotone=True`` takes the elementwise max with the current chain:
    milestones only ever loosen, so stage-1 admissions can only widen
    relative to the schedule already in force.
    """
    vdl = req.vdl_abs.copy()
    chain = now + np.cumsum(budgets)
    vdl[l0:] = np.maximum(vdl[l0:], chain) if monotone else chain
    req.vdl_abs = vdl


class ReclaimBudgetPolicy(BudgetPolicy):
    """Push slack from early layer finishes into downstream budgets.

    The re-distribution re-anchors the remaining budget chain at the
    actual finish time: each downstream layer's budget grows, while the
    near-term virtual deadlines tighten relative to the stale offline
    schedule (the chain no longer starts at the missed-by-a-mile offline
    milestone).  ``spread`` in [0, 1] controls how much of the remaining
    deadline beyond the constraint-level floor flows into the budgets:
    1 = full proportional re-distribution, 0 = budgets pinned at the
    constraint levels (maximally tight — every placement that cannot
    match the constraint-level pace is pushed to the earliest-finish-
    guarded backfill stage).
    """

    name = "reclaim"

    def __init__(self, spread: float = 1.0, min_slack: float = 0.0, monotone: bool = False):
        if not 0.0 <= spread <= 1.0:
            raise ValueError(f"spread must be in [0, 1], got {spread}")
        if not 0.0 <= min_slack < 1.0:
            raise ValueError(f"min_slack must be in [0, 1), got {min_slack}")
        self.spread = float(spread)
        self.min_slack = float(min_slack)
        self.monotone = bool(monotone)

    def _has_slack(self, plan: ModelPlan) -> bool:
        """Reclaim only models whose offline schedule actually has slack:
        when minimum execution already consumes most of the deadline,
        there is nothing meaningful to reclaim and re-anchoring the
        nearly-slackless chain only tightens its milestones."""
        if self.min_slack <= 0.0:
            return True
        return 1.0 - float(plan.min_lat.sum()) / plan.deadline >= self.min_slack

    def _spread_budgets(self, res, remaining: float) -> np.ndarray:
        """Blend kernel budgets between the constraint-level floor
        (spread=0) and the full proportional distribution (spread=1)."""
        c_total = float(res.c_ref.sum())
        return res.c_ref * (1.0 + self.spread * (remaining - c_total) / c_total)

    def on_release(self, req: Request, plan: ModelPlan, now: float) -> None:
        if plan.budget.feasible:
            req.vdl_abs = req.arrival + plan.vdl_rel  # fresh array per request

    def on_layer_finish(self, req: Request, plan: ModelPlan, layer: int, now: float) -> None:
        if req.vdl_abs is None or not self._has_slack(plan):
            return
        l0 = layer + 1
        if l0 >= len(plan.model.layers):
            return
        if now >= float(req.vdl_abs[layer]) - 1e-15:
            return  # finished at/after its virtual deadline: nothing to reclaim
        remaining = req.deadline_abs - now
        res = tighten_budgets(
            plan.budget.levels[l0:],
            remaining,
            rho0=plan.budget.rho[l0:],
        )
        # always feasible: remaining exceeds the current downstream budgets,
        # each of which is at least its layer's minimum latency
        if res.feasible:
            _rebase(req, l0, now, self._spread_budgets(res, remaining), self.monotone)


class AdaptiveBudgetPolicy(ReclaimBudgetPolicy):
    """Skew-gated reclamation plus a staleness-repair controller.

    Reclamation only ever *tightens* virtual-deadline milestones relative
    to the offline schedule (the static absolute chain is the loosest
    member of the re-anchoring family — pinned by the ``monotone``
    regression test).  Whether a tighter milestone helps depends on the
    layer: it revokes stage-1 admission to the accelerators the offline
    constraint level tolerated, pushing the placement into Algorithm 2's
    earliest-finish-guarded backfill.  That is a win exactly where a
    mis-placement is expensive — layers whose cross-accelerator latency
    skew is catastrophic — and measurably a loss where second-choice
    accelerators are mildly slower but productive.  ``adaptive``
    therefore applies the reclaimed (tightened) milestones only to
    layers with ``max/min`` latency skew at least ``skew_min``; all
    other layers keep their offline milestones, with per-layer minimum
    latencies enforced across the mixed chain.

    Both moves are gated on *observed burstiness*: a detector compares
    the release rate over the last ``window`` releases against the
    long-run mean rate (both observable through ``on_release``).  While
    the recent rate stays below ``burst`` x the mean — the paper's
    periodic regime, or plain Poisson — the policy is exactly static,
    where the offline calibration is provably good.  Inside a burst the
    skew-gated reclamation engages.

    The controller tick is the repair loop: a reclaimed chain whose
    current milestone congestion has made unattainable (stale — below
    ``now`` plus the layer's fastest implementation) is restored to the
    offline kernel distribution, so requests that fell behind re-enter
    the exact triage order the offline schedule defines.
    """

    name = "adaptive"

    def __init__(
        self,
        tick: float = 0.01,
        spread: float = 1.0,
        min_slack: float = 0.0,
        skew_min: float = 10.0,
        reset_stale: bool = True,
        burst: float = 1.5,
        window: int = 32,
    ):
        super().__init__(spread=spread, min_slack=min_slack)
        if tick <= 0.0:
            raise ValueError(f"adaptive budget policy needs tick > 0, got {tick}")
        if skew_min < 1.0:
            raise ValueError(f"skew_min must be >= 1, got {skew_min}")
        if burst < 1.0:
            raise ValueError(f"burst threshold must be >= 1, got {burst}")
        if window < 2:
            raise ValueError(f"window must be >= 2 releases, got {window}")
        self.tick_interval = float(tick)
        self.skew_min = float(skew_min)
        self.reset_stale = bool(reset_stale)
        self.burst = float(burst)
        self.window = int(window)
        self.reset()

    def reset(self) -> None:
        self._recent: Deque[float] = collections.deque(maxlen=self.window)
        self._released = 0
        self._t0: Optional[float] = None

    # -- burst detector ----------------------------------------------------
    def on_release(self, req: Request, plan: ModelPlan, now: float) -> None:
        super().on_release(req, plan, now)
        if self._t0 is None:
            self._t0 = now
        self._released += 1
        self._recent.append(now)

    def bursting(self, now: float) -> bool:
        """Recent release rate exceeds ``burst`` x the long-run mean."""
        if len(self._recent) < self.window or self._t0 is None:
            return False
        elapsed = now - self._t0
        span = now - self._recent[0]
        if elapsed <= 0.0 or span <= 0.0:
            return False
        return (len(self._recent) / span) > self.burst * (self._released / elapsed)

    # -- burst-gated, skew-gated reclamation -------------------------------
    def on_layer_finish(self, req: Request, plan: ModelPlan, layer: int, now: float) -> None:
        if not self.bursting(now):
            return
        before = req.vdl_abs
        super().on_layer_finish(req, plan, layer, now)
        if req.vdl_abs is before or req.vdl_abs is None:
            return  # no reclamation happened
        # skew gate: tightened milestones only where mis-placement is
        # catastrophic; offline milestones elsewhere.  Walk the chain to
        # keep it monotone with every budget >= the layer minimum.
        l0 = layer + 1
        skew = plan.lat_skew
        static_abs = req.arrival + plan.vdl_rel
        mixed = req.vdl_abs.copy()
        prev = now
        for l in range(l0, len(mixed)):
            target = mixed[l] if skew[l] >= self.skew_min else static_abs[l]
            prev = max(target, prev + float(plan.min_lat[l]))
            mixed[l] = prev
        req.vdl_abs = mixed

    def on_tick(
        self,
        now: float,
        ready: List[Request],
        plans: Sequence[ModelPlan],
        acc_busy_until: np.ndarray,
    ) -> None:
        if not self.reset_stale:
            return
        for req in ready:
            if req.vdl_abs is None:
                continue
            plan = plans[req.model_idx]
            l0 = req.next_layer
            static0 = req.arrival + float(plan.vdl_rel[l0])
            cur = float(req.vdl_abs[l0])
            if cur >= static0 - 1e-15:
                continue  # chain is not tightened: nothing to repair
            if cur < now + float(plan.min_lat_any[l0]):
                # reclaimed milestone went stale: restore the offline
                # kernel distribution (Algorithm 1's budgets, anchored at
                # arrival) so the request rejoins the static triage order
                req.vdl_abs = req.arrival + plan.vdl_rel


BUDGET_POLICIES = {
    "static": StaticBudgetPolicy,
    "reclaim": ReclaimBudgetPolicy,
    "adaptive": AdaptiveBudgetPolicy,
}


def make_budget_policy(spec: Union[str, BudgetPolicy, None]) -> BudgetPolicy:
    """Build a :class:`BudgetPolicy` from a call-spec string.

    ``"static"``, ``"reclaim"``, ``"adaptive"``,
    ``"adaptive(tick=0.02,skew_min=5)"`` ...; instances pass through
    unchanged and ``None`` means static (the paper's offline budgets).
    """
    from repro.core.specs import parse_call_spec

    if spec is None:
        return StaticBudgetPolicy()
    if isinstance(spec, BudgetPolicy):
        return spec
    name, kwargs = parse_call_spec(spec)
    if name not in BUDGET_POLICIES:
        raise KeyError(
            f"unknown budget policy '{name}' (have {sorted(BUDGET_POLICIES)})"
        )
    cls = BUDGET_POLICIES[name]
    try:
        return cls(**kwargs)
    except TypeError as e:
        params = sorted(set(inspect.signature(cls.__init__).parameters) - {"self"})
        raise ValueError(
            f"bad arguments for budget policy '{name}': {e}; "
            f"valid parameters: {params or 'none'}"
        ) from e

"""Workload scenarios (Table II) and their hardware pairings (Table I).

Each scenario lists (model, fps, prob) with relative deadline = period =
1/fps.  Models marked with * in the paper have layer variants — in our
build that emerges from the offline stage (variants are designed where
Algorithm 1's constraint levels exclude accelerators), matching the
paper's starred set.

Load calibration (recorded per DESIGN.md): the paper matches scenarios to
PE counts "avoiding trivial all-pass or all-fail".  Absolute MAESTRO
latencies are not published, so we calibrate via input resolution — the
multi-camera scenarios use camera-stream resolutions (448/512), the AR
scenarios use the models' native resolutions.  The resulting bottleneck
utilizations land in the paper's interesting regime (checked by
``tests/test_workload.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.simulator import (
    ArrivalProcess,
    ClosedLoopClients,
    DiurnalArrivals,
    MmppArrivals,
    PeriodicArrivals,
    PoissonArrivals,
    TaskSpec,
    make_arrival_process,
)
from repro.core.variants import ModelPlan, build_model_plan
from repro.costmodel.dnn_zoo import (
    DnnModel,
    asr_encdec,
    fbnet_c,
    hand_sp,
    inceptionv3,
    mobilenetv2_ssd,
    moe_4expert,
    planercnn,
    resnet50,
    sp2dense,
    swin_tiny,
    vgg11,
    vlm_2branch,
)
from repro.costmodel.maestro import PLATFORMS, Platform


@dataclasses.dataclass(frozen=True)
class ScenarioEntry:
    model: DnnModel
    fps: float
    prob: float = 1.0
    # Per-entry release process; None = scenario/trial default (periodic).
    arrival: Optional[ArrivalProcess] = None
    # Relative deadline; None = the paper's 1/fps.  The saturation family
    # decouples the two: ``fps`` keeps setting the mean offered rate, the
    # deadline stays anchored to the non-overloaded period, so overload
    # deepens the ready queue instead of just mass-dropping requests.
    deadline: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    entries: Tuple[ScenarioEntry, ...]
    platform_names: Tuple[str, ...]  # Table I pairings
    # Default fault-model call-spec for trials of this scenario (see
    # ``repro.core.faults.make_fault_model``); None = fault-free.  A
    # TrialSpec with ``faults="scenario"`` (the default) resolves to
    # this, so the FAULT_SCENARIOS catalog carries its own injections
    # while every pre-existing catalog stays bit-identical.
    faults: Optional[str] = None

    def plans(
        self,
        platform: Platform,
        theta: float = 0.90,
        enable_variants: bool = True,
        arrival: Union[ArrivalProcess, str, None] = None,
    ) -> Tuple[List[ModelPlan], List[TaskSpec]]:
        """Offline stage for one (scenario, platform) cell.

        ``arrival`` sets the release process for every entry (a call-spec
        string like ``"mmpp(burstiness=4)"`` or an instance); an entry's
        own ``arrival`` takes precedence.  ``None`` keeps the paper's
        strictly periodic releases.
        """
        default_arrival = make_arrival_process(arrival) if arrival is not None else None
        plans, tasks = [], []
        for i, e in enumerate(self.entries):
            plans.append(
                build_model_plan(
                    e.model,
                    platform,
                    deadline=e.deadline if e.deadline is not None else 1.0 / e.fps,
                    theta=theta,
                    enable_variants=enable_variants,
                )
            )
            tasks.append(
                TaskSpec(
                    model_idx=i,
                    fps=e.fps,
                    prob=e.prob,
                    arrival=e.arrival or default_arrival,
                )
            )
        return plans, tasks


def _scenarios() -> Dict[str, Scenario]:
    return {
        "ar_social": Scenario(
            "ar_social",
            (
                ScenarioEntry(fbnet_c(224), 60),
                ScenarioEntry(hand_sp(256), 30, prob=0.5),
                ScenarioEntry(sp2dense(224), 30),
                ScenarioEntry(mobilenetv2_ssd(300), 30),
            ),
            ("4k_1ws2os", "4k_1os2ws", "6k_1ws2os", "6k_1os2ws"),
        ),
        "ar_gaming_light": Scenario(
            "ar_gaming_light",
            (
                ScenarioEntry(hand_sp(256), 30),
                ScenarioEntry(planercnn(384), 10),
                ScenarioEntry(sp2dense(224), 30),
                ScenarioEntry(mobilenetv2_ssd(300), 30),
            ),
            ("4k_1ws2os", "4k_1os2ws"),
        ),
        "ar_gaming_heavy": Scenario(
            "ar_gaming_heavy",
            (
                ScenarioEntry(hand_sp(256), 45),
                ScenarioEntry(planercnn(384), 15),
                ScenarioEntry(sp2dense(224), 30),
                ScenarioEntry(mobilenetv2_ssd(300), 45),
            ),
            ("6k_1ws2os", "6k_1os2ws"),
        ),
        "multicam_light": Scenario(
            "multicam_light",
            (
                ScenarioEntry(mobilenetv2_ssd(512), 45),
                ScenarioEntry(resnet50(448), 15),
                ScenarioEntry(vgg11(384), 15),
                ScenarioEntry(inceptionv3(299), 15),
                ScenarioEntry(swin_tiny(224), 10),
            ),
            ("4k_1ws2os", "4k_1os2ws"),
        ),
        "multicam_heavy": Scenario(
            "multicam_heavy",
            (
                ScenarioEntry(mobilenetv2_ssd(512), 60),
                ScenarioEntry(resnet50(448), 30),
                ScenarioEntry(vgg11(384), 30),
                ScenarioEntry(inceptionv3(299), 15),
                ScenarioEntry(swin_tiny(224), 30),
            ),
            ("6k_1ws2os", "6k_1os2ws"),
        ),
    }


SCENARIOS: Dict[str, Scenario] = _scenarios()


# ------------------------------------------------- saturation family ----
#
# Deep-queue stress catalog (NOT part of the paper's Table II, and kept
# out of SCENARIOS so default campaigns and the fig5 grid are unchanged):
# the multicam model mix overdriven to 3-8x offered load with mixed
# release processes — bursty MMPP cameras, Poisson event streams, and a
# jittered periodic pipeline — the multi-tenant regime where ready
# queues go tens of layers deep and the scheduler round itself becomes
# the bottleneck (the `bench_scheduler_round` grid).  Deadlines stay
# anchored to the non-overloaded camera periods (x DEADLINE_SLACK, so
# requests remain schedulable long enough to queue up rather than being
# early-dropped on arrival); `fps` scales only the offered rate.

#: relative deadline as a multiple of the base (non-overloaded) period.
SATURATION_DEADLINE_SLACK = 4.0

#: base offered rates of the saturation mix (requests/s at 1x load).
_SATURATION_BASE = (
    # (model ctor, resolution, base fps, arrival process)
    (mobilenetv2_ssd, 512, 45.0, MmppArrivals(burstiness=4)),
    (resnet50, 448, 15.0, PoissonArrivals()),
    (vgg11, 384, 15.0, PeriodicArrivals(jitter=0.5)),
    (inceptionv3, 299, 15.0, MmppArrivals(burstiness=8, on_fraction=0.125)),
    (swin_tiny, 224, 10.0, PoissonArrivals()),
)


def saturation_scenario(load: float) -> Scenario:
    """One overloaded multi-camera cell at ``load`` x the base rate."""
    entries = tuple(
        ScenarioEntry(
            ctor(res),
            fps=base_fps * load,
            arrival=arr,
            deadline=SATURATION_DEADLINE_SLACK / base_fps,
        )
        for ctor, res, base_fps, arr in _SATURATION_BASE
    )
    name = f"saturation_{load:g}x"
    return Scenario(name, entries, ("4k_1ws2os", "6k_1ws2os"))


SATURATION_SCENARIOS: Dict[str, Scenario] = {
    sc.name: sc for sc in (saturation_scenario(m) for m in (3.0, 5.0, 8.0))
}


def _overload_scenarios() -> Dict[str, Scenario]:
    """Overload-control catalog: traffic shapes the admission/shedding
    axis and the closed-loop client model exist for.  All cells reuse the
    saturation mix's hardware pairings so results compare directly
    against the ``saturation_*`` grid."""
    platforms = ("4k_1ws2os", "6k_1ws2os")
    # Diurnal rate curve: 3x mean load, sinusoidal peaks to ~5.4x — the
    # compressed day/night cycle; phase-staggered so model peaks overlap
    # only partially.
    diurnal = Scenario(
        "overload_diurnal",
        tuple(
            ScenarioEntry(
                ctor(res),
                fps=base_fps * 3.0,
                arrival=DiurnalArrivals(period=1.0, depth=0.8, phase=i / 5.0),
                deadline=SATURATION_DEADLINE_SLACK / base_fps,
            )
            for i, (ctor, res, base_fps, _arr) in enumerate(_SATURATION_BASE)
        ),
        platforms,
    )
    # Flash crowd: a front of closed-loop users all releasing at t=0 with
    # short drain sessions, over a steady open-loop background.
    flash = Scenario(
        "overload_flash",
        (
            ScenarioEntry(
                mobilenetv2_ssd(512),
                fps=45.0,
                arrival=ClosedLoopClients(
                    n_users=24, think_time=0.02, session_len=8,
                    respawn=False, stagger=False,
                ),
                deadline=SATURATION_DEADLINE_SLACK / 45.0,
            ),
            ScenarioEntry(
                resnet50(448),
                fps=15.0,
                arrival=PoissonArrivals(),
                deadline=SATURATION_DEADLINE_SLACK / 15.0,
            ),
            ScenarioEntry(
                swin_tiny(224),
                fps=10.0,
                arrival=ClosedLoopClients(
                    n_users=8, think_time=0.05, session_len=4,
                    respawn=False, stagger=False,
                ),
                deadline=SATURATION_DEADLINE_SLACK / 10.0,
            ),
        ),
        platforms,
    )
    # Two-tier SLO mix: the same model served at a premium (tight
    # deadline) and a best-effort (2x slack) tier, with a heavy light
    # model load on top — admission decides which tier eats the loss.
    two_tier = Scenario(
        "overload_two_tier",
        (
            ScenarioEntry(
                resnet50(448), fps=30.0,
                deadline=SATURATION_DEADLINE_SLACK / 30.0,
            ),
            ScenarioEntry(
                resnet50(448), fps=30.0,
                deadline=2.0 * SATURATION_DEADLINE_SLACK / 30.0,
            ),
            ScenarioEntry(
                mobilenetv2_ssd(512), fps=90.0,
                arrival=MmppArrivals(burstiness=4),
                deadline=SATURATION_DEADLINE_SLACK / 90.0,
            ),
        ),
        platforms,
    )
    # Closed-loop saturation: every model behind a persistent user pool —
    # the workload self-throttles (releases gate on completions), the
    # closed-loop counterpart of ``saturation_5x``.
    closed = Scenario(
        "overload_closed_loop",
        tuple(
            ScenarioEntry(
                ctor(res),
                fps=base_fps,
                arrival=ClosedLoopClients(n_users=8, think_time=1.0 / base_fps),
                deadline=SATURATION_DEADLINE_SLACK / base_fps,
            )
            for ctor, res, base_fps, _arr in _SATURATION_BASE
        ),
        platforms,
    )
    return {sc.name: sc for sc in (diurnal, flash, two_tier, closed)}


OVERLOAD_SCENARIOS: Dict[str, Scenario] = _overload_scenarios()


def _fault_scenarios() -> Dict[str, Scenario]:
    """Fault-tolerance catalog: workloads paired with deterministic
    capability faults (``Scenario.faults``), the degraded-mode regimes
    the fault axis and fig10 exist for.

    The dropout/brownout cells reuse the ``multicam_heavy`` mix with the
    paper's tight 1/fps deadlines: variants only engage when virtual
    deadlines bind (the saturation family's 4x slack keeps them loose
    enough that even an outage never triggers the variant lever), and on
    this mix the lever is measurably load-bearing — dropping the lead
    accelerator costs variant-enabled Terastal ~10 miss-rate points
    FEWER than its no-variant ablation (the fig10 gate)."""
    mix = SCENARIOS["multicam_heavy"].entries
    platforms = SCENARIOS["multicam_heavy"].platform_names
    # Single-accelerator dropout: the platform's lead accelerator goes
    # dark mid-horizon and comes back.  The surviving columns are the
    # slow ones — exactly where layer variants shrink the latency gap —
    # so variant-enabled Terastal degrades gracefully while the
    # no-variant ablation (and the baselines) miss through the outage.
    dropout = Scenario(
        "fault_dropout",
        mix,
        platforms,
        faults="down(acc=0,start=0.5,duration=1.0)",
    )
    # Rolling brownout: a thermal throttle wave sweeps one accelerator
    # at a time (no two degraded at once); capacity never disappears,
    # it migrates — the re-mapping stress without any eviction storm.
    brownout = Scenario(
        "fault_brownout",
        mix,
        platforms,
        faults=(
            "throttle(acc=0,start=0.2,duration=0.5,factor=3.0)"
            "+throttle(acc=1,start=0.7,duration=0.5,factor=3.0)"
            "+throttle(acc=2,start=1.2,duration=0.5,factor=3.0)"
        ),
    )
    # Flash crowd plus failure: a closed-loop user front lands while an
    # accelerator permanently dies under it — peak demand meeting a
    # permanent capacity cut, the worst-case compound of the overload
    # and fault axes.
    flash = Scenario(
        "fault_flash_crowd",
        (
            ScenarioEntry(
                mobilenetv2_ssd(512),
                fps=45.0,
                arrival=ClosedLoopClients(
                    n_users=24, think_time=0.02, session_len=8,
                    respawn=False, stagger=False,
                ),
                deadline=SATURATION_DEADLINE_SLACK / 45.0,
            ),
            ScenarioEntry(
                resnet50(448),
                fps=15.0,
                arrival=PoissonArrivals(),
                deadline=SATURATION_DEADLINE_SLACK / 15.0,
            ),
            ScenarioEntry(
                swin_tiny(224),
                fps=10.0,
                arrival=ClosedLoopClients(
                    n_users=8, think_time=0.05, session_len=4,
                    respawn=False, stagger=False,
                ),
                deadline=SATURATION_DEADLINE_SLACK / 10.0,
            ),
        ),
        platforms,
        faults="permanent(acc=1,start=0.4,interrupted=resume)",
    )
    # DAG under outage: the two-branch VLM mix (fan-out AND fan-in)
    # loses its lead accelerator mid-horizon, with budget re-tightening
    # on — the PR 10 composition cell.  Evicting one branch node must
    # refresh its siblings' deadline snapshots and re-tightening must
    # rebind every in-flight chain against the degraded tables; this is
    # the faults x DAG gate lifted, as a first-class catalog cell.
    dag_dropout = Scenario(
        "fault_dag_dropout",
        (
            ScenarioEntry(vlm_2branch(224), fps=60.0, deadline=0.003),
            ScenarioEntry(fbnet_c(224), fps=60.0),
            ScenarioEntry(hand_sp(256), fps=30.0),
        ),
        ("6k_1ws2os", "6k_1os2ws"),
        faults="down(acc=0,start=0.5,duration=1.0,retighten=true)",
    )
    return {sc.name: sc for sc in (dropout, brownout, flash, dag_dropout)}


FAULT_SCENARIOS: Dict[str, Scenario] = _fault_scenarios()


def _dag_scenarios() -> Dict[str, Scenario]:
    """DAG-structured workload catalog: multi-branch models whose plans
    carry a :class:`repro.core.dag.LayerDag`, mixed with linear
    background load so precedence-aware placement actually contends for
    accelerators.

    Deadlines are explicit and tight — variants only exist where
    Algorithm 1 has to tighten (``_design_layer_variant`` returns None
    at rho <= 0), and the DAG models' critical paths sit just inside
    these deadlines on the 6k platforms, so the variant lever and the
    Eq. 8 binding-successor slack both engage.  The ``dag_asr_encdec``
    cell is the fig11 separation gate: an encoder/decoder fan-in whose
    two source chains (audio encoder, text embedder) can run
    concurrently on different accelerators."""
    platforms = ("6k_1ws2os", "6k_1os2ws")
    # Encoder/decoder split: audio chain (3 conv) and text chain
    # (embed+proj) join at a fusion matmul — two sources, one fan-in.
    asr = Scenario(
        "dag_asr_encdec",
        (
            ScenarioEntry(asr_encdec(80), fps=30.0, deadline=0.006),
            ScenarioEntry(mobilenetv2_ssd(300), fps=30.0),
            ScenarioEntry(sp2dense(224), fps=30.0),
        ),
        platforms,
    )
    # Two-branch VLM: shared stem fans out into vision and text towers
    # that rejoin at a fusion layer — fan-out AND fan-in in one model.
    vlm = Scenario(
        "dag_vlm_2branch",
        (
            ScenarioEntry(vlm_2branch(224), fps=60.0, deadline=0.003),
            ScenarioEntry(fbnet_c(224), fps=60.0),
            ScenarioEntry(hand_sp(256), fps=30.0),
        ),
        platforms,
    )
    # Mixture-of-experts: router fans out to 4 parallel experts that
    # all join at the combine layer — the widest intra-request
    # parallelism in the catalog (4 sibling nodes in flight).
    moe = Scenario(
        "dag_moe_4expert",
        (
            ScenarioEntry(moe_4expert(224), fps=90.0, deadline=0.003),
            ScenarioEntry(fbnet_c(224), fps=60.0),
        ),
        platforms,
    )
    return {sc.name: sc for sc in (asr, vlm, moe)}


DAG_SCENARIOS: Dict[str, Scenario] = _dag_scenarios()

#: catalog registry searched by :func:`get_scenario`, in lookup order.
SCENARIO_CATALOGS: Dict[str, Dict[str, Scenario]] = {
    "SCENARIOS": SCENARIOS,
    "SATURATION_SCENARIOS": SATURATION_SCENARIOS,
    "OVERLOAD_SCENARIOS": OVERLOAD_SCENARIOS,
    "FAULT_SCENARIOS": FAULT_SCENARIOS,
    "DAG_SCENARIOS": DAG_SCENARIOS,
}


def get_scenario(name: str) -> Scenario:
    """Resolve a scenario by name across every catalog (the paper's
    SCENARIOS, the saturation stress family, the overload-control
    catalog, and the fault-tolerance catalog — campaign trial specs
    accept all of them).  Unknown names raise a ``ValueError`` naming
    each catalog searched."""
    for catalog in SCENARIO_CATALOGS.values():
        sc = catalog.get(name)
        if sc is not None:
            return sc
    searched = ", ".join(
        f"{cname} ({', '.join(sorted(cat))})"
        for cname, cat in SCENARIO_CATALOGS.items()
    )
    raise ValueError(
        f"unknown scenario {name!r}; searched catalogs: {searched}"
    )


def scenario_platform_pairs() -> List[Tuple[Scenario, Platform]]:
    """All (scenario, hardware setting) cells of the Fig. 5 comparison."""
    out = []
    for sc in SCENARIOS.values():
        for pn in sc.platform_names:
            out.append((sc, PLATFORMS[pn]))
    return out


# ------------------------------------------- multi-seed release events ----


def batch_release_events(
    tasks: Sequence[TaskSpec],
    duration: float,
    seeds: Sequence[int],
    processes: Optional[Sequence[Optional[ArrivalProcess]]] = None,
) -> List[Tuple["np.ndarray", "np.ndarray"]]:
    """Pre-generate the full open-loop release horizon for B seeds.

    Returns ``[(times, model_idxs)]`` per seed — each entry is the
    sorted ``generate_arrivals`` stream for that seed as ndarrays
    (f64 times, int32 model indices), ready for
    ``scheduler_jax.pack_trials`` to stage seed-major.  The per-seed
    variate streams are exactly the single-trial ones (one
    ``default_rng(seed)`` per seed, consumed in task order), so a
    batched trial sees the identical event horizon as
    ``simulate(seed=s)`` — the arrival index in the sorted stream IS
    the reference engine's ``rid``.

    Open-loop processes only: a :class:`ClosedLoopClients` release
    source gates future releases on completions, which cannot be
    pre-generated — the batch engine rejects such tasks with a named
    error (``engine_batch.BatchUnsupportedError``) before calling this.
    """
    import numpy as np

    from repro.core.simulator import generate_arrivals

    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for seed in seeds:
        ev = generate_arrivals(tasks, duration, seed, processes=processes)
        times = np.fromiter((t for t, _ in ev), dtype=np.float64, count=len(ev))
        models = np.fromiter((m for _, m in ev), dtype=np.int32, count=len(ev))
        out.append((times, models))
    return out

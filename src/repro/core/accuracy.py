"""Per-variant / per-combination accuracy model.

The paper trains each variant on the original dataset (layer swapped in,
all other layers frozen) and measures: individual VGG11 variants lose
7.0%-17.0% (Fig. 3 bottom); architecturally redundant models (ResNet50,
Swin-Tiny, Sp2Dense) stay robust under multiple variants while compact
models degrade quickly (Fig. 4); combination loss compounds with the
specific set of layers modified, not just the count.

Offline in this container (no ImageNet/VOC/KITTI), we use a calibrated
deterministic proxy with exactly those properties:

    delta(layer) = BASE * (1 - redundancy) * (0.55 + 0.9*u) * (1 + 0.35*(gamma-2))

where ``u`` is a per-(model, layer) hash-uniform in [0, 1] — fixed across
runs, varying across layers (Fig. 3's layer-dependence) — clipped to
[0.5%, 25%].  With VGG11's redundancy of 0.35 this spans ~6.8%-16.3% per
individual gamma=2 variant, matching Fig. 3.  Combinations compound
multiplicatively on retained accuracy with a mild interaction exponent:

    retained(V) = prod_i (1 - delta_i) ** INTERACTION

``examples/variant_training.py`` grounds the proxy's shape with a real
S2D/D2S variant trained in JAX on a small CNN.
"""

from __future__ import annotations

import hashlib
from typing import Dict, FrozenSet, Iterable, Mapping

BASE_LOSS = 0.19
INTERACTION = 1.1
MIN_LOSS, MAX_LOSS = 0.005, 0.25


def _hash_uniform(model_name: str, layer_name: str) -> float:
    h = hashlib.sha256(f"{model_name}/{layer_name}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


def layer_variant_loss(
    model_name: str, layer_name: str, redundancy: float, gamma: int
) -> float:
    """Relative accuracy loss of swapping in this single variant."""
    u = _hash_uniform(model_name, layer_name)
    delta = BASE_LOSS * (1.0 - redundancy) * (0.55 + 0.9 * u)
    delta *= 1.0 + 0.35 * max(0, gamma - 2)
    return float(min(MAX_LOSS, max(MIN_LOSS, delta)))


def combo_retained_fraction(losses: Iterable[float]) -> float:
    """Retained accuracy fraction (relative to baseline) of a variant set."""
    r = 1.0
    for d in losses:
        r *= (1.0 - d) ** INTERACTION
    return r


def combo_loss(losses: Iterable[float]) -> float:
    return 1.0 - combo_retained_fraction(losses)


def service_quality(miss_rate: float, mean_accuracy_loss: float) -> float:
    """Degraded-mode service quality in [0, 1] for fault-axis reporting.

    The fraction of requests that met their deadline, discounted by the
    mean accuracy retained on completions::

        quality = (1 - miss_rate) * (1 - mean_accuracy_loss)

    This is the graceful-degradation ordering fig10 reports: trading a
    deadline miss (zero utility) for a variant completion (slightly
    reduced accuracy, full timeliness) raises quality, so a scheduler
    that uses the variant lever under faults dominates one that keeps
    nominal accuracy but misses through the outage.  A NaN accuracy
    loss (no variant-bearing model completed anything — see
    ``SimResult.accuracy_loss_stats``) counts as zero loss."""
    loss = mean_accuracy_loss
    if loss != loss:  # NaN
        loss = 0.0
    q = (1.0 - miss_rate) * (1.0 - loss)
    return float(min(1.0, max(0.0, q)))

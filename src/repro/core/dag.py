"""Layer DAGs — precedence structure for non-linear models.

A :class:`LayerDag` attaches an explicit predecessor structure to a
model's layer list: node ``l`` may only start once every node in
``preds[l]`` has finished.  Linear chains are the degenerate case
(``preds[l] == (l-1,)``) and every consumer in the stack keeps its
original linear code path when ``plan.dag is None`` — the DAG machinery
is strictly additive, which is what keeps the pre-PR linear-chain
fingerprints bit-identical (``tests/data_pre_pr9_fingerprints.py``).

Validation (:meth:`LayerDag.validate`, run at construction) rejects
malformed specs with a :class:`DagValidationError` naming the offending
node: self-edges, unknown/out-of-range predecessor ids, duplicate
predecessors, cycles (Kahn's algorithm), multiple sinks, and nodes from
which the sink is unreachable (a "disconnected sink" — work that could
never contribute to the request completing).

The runtime side is :class:`DagRun` — one per in-flight DAG request,
shared by that request's per-node ready entries: it tracks how many
predecessors each node still waits on, how many nodes finished, the
union of applied variants, and whether the request was dropped (a drop
of any ready node drops the whole request exactly once).

The digraph idiom (topologically staged nodes with explicit predecessor
sets) follows the zigzag workload-as-digraph pattern referenced from
ROADMAP item 3.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Set, Tuple


class DagValidationError(ValueError):
    """A malformed layer-DAG spec; the message names the offending node."""


@dataclass(frozen=True)
class LayerDag:
    """Immutable precedence structure over ``n_nodes`` layers.

    ``preds[l]`` is the tuple of node ids that must finish before node
    ``l`` may start; sources have ``preds[l] == ()``.  Derived fields
    (``succs``, ``topo``, ``sources``, ``sink``) are computed once at
    construction by :meth:`validate`.
    """

    preds: Tuple[Tuple[int, ...], ...]
    succs: Tuple[Tuple[int, ...], ...] = field(default=(), compare=False)
    topo: Tuple[int, ...] = field(default=(), compare=False)
    sources: Tuple[int, ...] = field(default=(), compare=False)
    sink: int = field(default=-1, compare=False)

    def __post_init__(self):
        object.__setattr__(
            self, "preds", tuple(tuple(int(p) for p in ps) for ps in self.preds)
        )
        self.validate()

    @property
    def n_nodes(self) -> int:
        return len(self.preds)

    @property
    def is_linear(self) -> bool:
        """True iff this DAG is exactly the linear chain 0 -> 1 -> ... ."""
        return all(
            ps == (() if l == 0 else (l - 1,)) for l, ps in enumerate(self.preds)
        )

    def validate(self) -> None:
        n = len(self.preds)
        if n == 0:
            raise DagValidationError("empty DAG: no nodes")
        succs: List[List[int]] = [[] for _ in range(n)]
        for l, ps in enumerate(self.preds):
            seen: Set[int] = set()
            for p in ps:
                if p == l:
                    raise DagValidationError(f"node {l}: self-edge {l} -> {l}")
                if p < 0 or p >= n:
                    raise DagValidationError(
                        f"node {l}: unknown predecessor id {p} (have 0..{n - 1})"
                    )
                if p in seen:
                    raise DagValidationError(
                        f"node {l}: duplicate predecessor {p}"
                    )
                seen.add(p)
                succs[p].append(l)
        # Kahn's algorithm: topological order, or the cycle's witness node
        indeg = [len(ps) for ps in self.preds]
        stack = sorted((l for l in range(n) if indeg[l] == 0), reverse=True)
        topo: List[int] = []
        while stack:
            l = stack.pop()
            topo.append(l)
            for s in succs[l]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    stack.append(s)
            stack.sort(reverse=True)
        if len(topo) < n:
            witness = min(l for l in range(n) if indeg[l] > 0)
            raise DagValidationError(f"node {witness}: unreachable (cycle)")
        sinks = [l for l in range(n) if not succs[l]]
        if len(sinks) != 1:
            raise DagValidationError(
                f"node {sinks[1]}: multiple sinks {sinks} (a model completes "
                "at exactly one terminal node)"
            )
        sink = sinks[0]
        # every node must reach the sink, else its work can never count
        reach = [False] * n
        reach[sink] = True
        for l in reversed(topo):
            if not reach[l] and any(reach[s] for s in succs[l]):
                reach[l] = True
        for l in range(n):
            if not reach[l]:
                raise DagValidationError(
                    f"node {l}: disconnected from sink {sink}"
                )
        object.__setattr__(self, "succs", tuple(tuple(s) for s in succs))
        object.__setattr__(self, "topo", tuple(topo))
        object.__setattr__(
            self, "sources", tuple(l for l in range(n) if not self.preds[l])
        )
        object.__setattr__(self, "sink", sink)

    @staticmethod
    def linear(n_nodes: int) -> "LayerDag":
        return LayerDag(tuple(() if l == 0 else (l - 1,) for l in range(n_nodes)))

    def spec(self) -> str:
        """Compact edge-spec string (see ``specs.format_dag_edges``)."""
        from repro.core.specs import format_dag_edges

        return format_dag_edges(self.preds)

    @staticmethod
    def from_spec(spec: str) -> "LayerDag":
        from repro.core.specs import parse_dag_edges

        return LayerDag(parse_dag_edges(spec))


@dataclass
class DagRun:
    """Per-request runtime state shared by a DAG request's node entries.

    ``pending[l]`` counts unfinished predecessors of node ``l`` (a node
    becomes ready when it hits 0); ``n_done`` counts finished nodes;
    ``applied_variants`` is the union over nodes (the per-node entries
    carry snapshots refreshed by the engines on every application, so
    variant-combo validity sees the whole request); ``dropped`` makes
    the drop-once semantics explicit: the first hopeless ready node
    drops the request, sibling entries are removed, and an already
    running sibling finishes as a no-op.
    """

    pending: List[int]
    n_done: int = 0
    applied_variants: frozenset = frozenset()
    dropped: bool = False

    @staticmethod
    def fresh(dag: LayerDag) -> "DagRun":
        return DagRun(pending=[len(ps) for ps in dag.preds])

"""Algorithm 1 — Offline Layer-Wise Virtual Budget Distribution.

Decomposes a model's relative deadline ``D_m`` into per-layer virtual
budgets ``b_{m,l}`` with ``sum(b) == D_m`` (Eq. 1), via per-layer
*constraint levels* ``rho`` into the decreasing list of distinct
cross-accelerator latencies.  The paper's loop: propose proportional
budgets at the current levels; while the proposal's reference total
exceeds ``D_m``, tighten the layer with the largest gap to its next-lower
latency level.  Fails iff even every layer's minimum latency does not fit.

This module is the reference (NumPy) implementation; ``budget_jax`` is a
bit-compatible ``jax.lax`` program property-tested against it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

# Latencies closer than this are treated as the same "distinct" level
# (identical accelerators produce exactly equal latencies; this guard is
# for float noise only).
_LEVEL_ATOL = 1e-12


@dataclasses.dataclass(frozen=True)
class BudgetResult:
    feasible: bool
    budgets: np.ndarray  # [L] seconds; zeros if infeasible
    rho: np.ndarray  # [L] final constraint level (0-indexed)
    levels: List[np.ndarray]  # per-layer distinct latencies, decreasing
    c_ref: np.ndarray  # [L] c^{down(rho)} used for the proportion
    #: relative virtual deadlines for DAG plans (critical-path completion
    #: targets, NOT a cumsum — set only by :func:`tighten_budgets_dag`).
    #: Linear plans leave it None and keep the exact cumsum floats.
    vdl: Optional[np.ndarray] = None

    @property
    def virtual_deadlines(self) -> np.ndarray:
        """Relative virtual deadlines: cumsum of budgets (Eq. 2 minus
        t^a) for linear chains; the topologically accumulated per-node
        targets when a DAG tightening set ``vdl`` explicitly."""
        if self.vdl is not None:
            return self.vdl
        return np.cumsum(self.budgets)


def latency_levels(lat_row: Sequence[float]) -> np.ndarray:
    """Distinct latencies of one layer across accelerators, decreasing."""
    vals = np.asarray(sorted(set(float(x) for x in lat_row), reverse=True))
    if len(vals) > 1:
        keep = [0]
        for i in range(1, len(vals)):
            if vals[keep[-1]] - vals[i] > _LEVEL_ATOL:
                keep.append(i)
        vals = vals[keep]
    return vals


def tighten_budgets(
    levels: Sequence[np.ndarray],
    deadline: float,
    rho0: Optional[Sequence[int]] = None,
) -> BudgetResult:
    """The Algorithm-1 tightening loop as a reusable incremental kernel.

    Re-distributes a (possibly *remaining*) ``deadline`` over the given
    per-layer level tables, starting from constraint levels ``rho0``
    (zeros = the offline algorithm; a request's current levels = online
    re-distribution over its remaining layers).  Propose proportional
    budgets at the current levels; while the proposal's reference total
    exceeds ``deadline``, tighten the layer with the largest gap to its
    next-lower latency level.  Fails iff even every layer's minimum
    latency does not fit.

    Tie-break: when several layers share the maximal gap, the lowest layer
    index is tightened (matches ``jnp.argmax`` semantics in budget_jax).
    """
    levels = [np.asarray(lv, dtype=np.float64) for lv in levels]
    L = len(levels)
    R = np.array([len(lv) for lv in levels])
    rho = (
        np.zeros(L, dtype=np.int64)
        if rho0 is None
        else np.asarray(rho0, dtype=np.int64).copy()
    )

    while True:
        c_ref = np.array([levels[l][rho[l]] for l in range(L)])
        c_total = float(c_ref.sum())
        if c_total <= deadline:
            budgets = deadline * c_ref / c_total
            return BudgetResult(True, budgets, rho.copy(), levels, c_ref)
        tightenable = rho < (R - 1)
        if not tightenable.any():
            return BudgetResult(
                False, np.zeros(L), rho.copy(), levels, c_ref
            )
        gaps = np.full(L, -np.inf)
        for l in range(L):
            if tightenable[l]:
                gaps[l] = levels[l][rho[l]] - levels[l][rho[l] + 1]
        l_star = int(np.argmax(gaps))
        rho[l_star] += 1


def distribute_budgets(lat_table: np.ndarray, deadline: float) -> BudgetResult:
    """Run Algorithm 1 on a [L, n_acc] latency table (offline entry point:
    build the level tables, then run the tightening kernel from level 0)."""
    lat_table = np.asarray(lat_table, dtype=np.float64)
    levels = [latency_levels(lat_table[l]) for l in range(lat_table.shape[0])]
    return tighten_budgets(levels, deadline)


def tighten_budgets_dag(
    levels: Sequence[np.ndarray],
    deadline: float,
    dag,
    rho0: Optional[Sequence[int]] = None,
) -> BudgetResult:
    """Algorithm 1 generalized to a layer DAG: distribute the deadline
    over the *critical path* instead of the layer sum.

    At the current constraint levels the earliest completion of node
    ``l`` is ``ecl[l] = max(ecl[p] for p in preds) + c_ref[l]`` (topo
    order) and the proposal's reference total is the critical-path
    length ``cp = ecl[sink]``.  Feasible iff ``cp <= deadline``: each
    node's budget is its reference latency scaled by ``deadline / cp``
    and its relative virtual deadline is ``ecl[l]`` scaled the same way
    (so virtual deadlines are strictly increasing along every edge, and
    every source-to-sink path's targets stretch proportionally —
    parallel branches get overlapping budgets, which a layer-sum cumsum
    cannot express).  While infeasible, tighten the largest-gap
    tightenable node *on a critical path* — tightening off-path nodes
    can never shorten ``cp`` — lowest node id on gap ties; fail iff no
    critical node is tightenable.

    Linear chains must NOT route through this function: ``deadline *
    cumsum(c_ref) / c_total`` differs from ``cumsum(deadline * c_ref /
    c_total)`` in the last float, and the linear pins are bit-exact.
    ``build_model_plan`` only calls it when the model carries a DAG.
    """
    levels = [np.asarray(lv, dtype=np.float64) for lv in levels]
    L = len(levels)
    if dag.n_nodes != L:
        raise ValueError(
            f"DAG has {dag.n_nodes} nodes but the latency table has {L} layers"
        )
    R = np.array([len(lv) for lv in levels])
    rho = (
        np.zeros(L, dtype=np.int64)
        if rho0 is None
        else np.asarray(rho0, dtype=np.int64).copy()
    )
    topo, preds, succs, sink = dag.topo, dag.preds, dag.succs, dag.sink

    while True:
        c_ref = np.array([levels[l][rho[l]] for l in range(L)])
        ecl = np.zeros(L)
        for l in topo:
            ps = preds[l]
            ecl[l] = (max(ecl[p] for p in ps) if ps else 0.0) + c_ref[l]
        cp = float(ecl[sink])
        if cp <= deadline:
            scale = deadline / cp
            budgets = c_ref * scale
            vdl = ecl * scale
            return BudgetResult(True, budgets, rho.copy(), levels, c_ref, vdl=vdl)
        # tail[l]: longest reference path strictly below l (0 at the sink)
        tail = np.zeros(L)
        for l in reversed(topo):
            ss = succs[l]
            if ss:
                tail[l] = max(tail[s] + c_ref[s] for s in ss)
        critical = ecl + tail >= cp - _LEVEL_ATOL
        tightenable = critical & (rho < (R - 1))
        if not tightenable.any():
            return BudgetResult(
                False, np.zeros(L), rho.copy(), levels, c_ref, vdl=np.zeros(L)
            )
        gaps = np.full(L, -np.inf)
        for l in range(L):
            if tightenable[l]:
                gaps[l] = levels[l][rho[l]] - levels[l][rho[l] + 1]
        l_star = int(np.argmax(gaps))
        rho[l_star] += 1


def distribute_budgets_dag(
    lat_table: np.ndarray, deadline: float, dag
) -> BudgetResult:
    """Offline entry point for DAG plans (critical-path Algorithm 1)."""
    lat_table = np.asarray(lat_table, dtype=np.float64)
    levels = [latency_levels(lat_table[l]) for l in range(lat_table.shape[0])]
    return tighten_budgets_dag(levels, deadline, dag)


def virtual_deadline(arrival: float, budgets: np.ndarray, layer: int) -> float:
    """Eq. 2: d^v_{j,m,l} = t^a + sum_{l'<=l} b."""
    return float(arrival + budgets[: layer + 1].sum())


def proportional_budgets_worstcase(lat_table: np.ndarray, deadline: float) -> np.ndarray:
    """Eq. 3 — the naive proportional-to-worst-case assignment (often
    infeasible on heterogeneous platforms; kept for tests/ablation)."""
    worst = np.asarray(lat_table).max(axis=1)
    return deadline * worst / worst.sum()

"""Algorithm 1 — Offline Layer-Wise Virtual Budget Distribution.

Decomposes a model's relative deadline ``D_m`` into per-layer virtual
budgets ``b_{m,l}`` with ``sum(b) == D_m`` (Eq. 1), via per-layer
*constraint levels* ``rho`` into the decreasing list of distinct
cross-accelerator latencies.  The paper's loop: propose proportional
budgets at the current levels; while the proposal's reference total
exceeds ``D_m``, tighten the layer with the largest gap to its next-lower
latency level.  Fails iff even every layer's minimum latency does not fit.

This module is the reference (NumPy) implementation; ``budget_jax`` is a
bit-compatible ``jax.lax`` program property-tested against it.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

# Latencies closer than this are treated as the same "distinct" level
# (identical accelerators produce exactly equal latencies; this guard is
# for float noise only).
_LEVEL_ATOL = 1e-12


@dataclasses.dataclass(frozen=True)
class BudgetResult:
    feasible: bool
    budgets: np.ndarray  # [L] seconds; zeros if infeasible
    rho: np.ndarray  # [L] final constraint level (0-indexed)
    levels: List[np.ndarray]  # per-layer distinct latencies, decreasing
    c_ref: np.ndarray  # [L] c^{down(rho)} used for the proportion

    @property
    def virtual_deadlines(self) -> np.ndarray:
        """Relative virtual deadlines: cumsum of budgets (Eq. 2 minus t^a)."""
        return np.cumsum(self.budgets)


def latency_levels(lat_row: Sequence[float]) -> np.ndarray:
    """Distinct latencies of one layer across accelerators, decreasing."""
    vals = np.asarray(sorted(set(float(x) for x in lat_row), reverse=True))
    if len(vals) > 1:
        keep = [0]
        for i in range(1, len(vals)):
            if vals[keep[-1]] - vals[i] > _LEVEL_ATOL:
                keep.append(i)
        vals = vals[keep]
    return vals


def tighten_budgets(
    levels: Sequence[np.ndarray],
    deadline: float,
    rho0: Optional[Sequence[int]] = None,
) -> BudgetResult:
    """The Algorithm-1 tightening loop as a reusable incremental kernel.

    Re-distributes a (possibly *remaining*) ``deadline`` over the given
    per-layer level tables, starting from constraint levels ``rho0``
    (zeros = the offline algorithm; a request's current levels = online
    re-distribution over its remaining layers).  Propose proportional
    budgets at the current levels; while the proposal's reference total
    exceeds ``deadline``, tighten the layer with the largest gap to its
    next-lower latency level.  Fails iff even every layer's minimum
    latency does not fit.

    Tie-break: when several layers share the maximal gap, the lowest layer
    index is tightened (matches ``jnp.argmax`` semantics in budget_jax).
    """
    levels = [np.asarray(lv, dtype=np.float64) for lv in levels]
    L = len(levels)
    R = np.array([len(lv) for lv in levels])
    rho = (
        np.zeros(L, dtype=np.int64)
        if rho0 is None
        else np.asarray(rho0, dtype=np.int64).copy()
    )

    while True:
        c_ref = np.array([levels[l][rho[l]] for l in range(L)])
        c_total = float(c_ref.sum())
        if c_total <= deadline:
            budgets = deadline * c_ref / c_total
            return BudgetResult(True, budgets, rho.copy(), levels, c_ref)
        tightenable = rho < (R - 1)
        if not tightenable.any():
            return BudgetResult(
                False, np.zeros(L), rho.copy(), levels, c_ref
            )
        gaps = np.full(L, -np.inf)
        for l in range(L):
            if tightenable[l]:
                gaps[l] = levels[l][rho[l]] - levels[l][rho[l] + 1]
        l_star = int(np.argmax(gaps))
        rho[l_star] += 1


def distribute_budgets(lat_table: np.ndarray, deadline: float) -> BudgetResult:
    """Run Algorithm 1 on a [L, n_acc] latency table (offline entry point:
    build the level tables, then run the tightening kernel from level 0)."""
    lat_table = np.asarray(lat_table, dtype=np.float64)
    levels = [latency_levels(lat_table[l]) for l in range(lat_table.shape[0])]
    return tighten_budgets(levels, deadline)


def virtual_deadline(arrival: float, budgets: np.ndarray, layer: int) -> float:
    """Eq. 2: d^v_{j,m,l} = t^a + sum_{l'<=l} b."""
    return float(arrival + budgets[: layer + 1].sum())


def proportional_budgets_worstcase(lat_table: np.ndarray, deadline: float) -> np.ndarray:
    """Eq. 3 — the naive proportional-to-worst-case assignment (often
    infeasible on heterogeneous platforms; kept for tests/ablation)."""
    worst = np.asarray(lat_table).max(axis=1)
    return deadline * worst / worst.sum()

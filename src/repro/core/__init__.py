"""Terastal core: virtual budgets, layer variants, online scheduling, simulator."""

from repro.core.budget import BudgetResult, distribute_budgets, latency_levels
from repro.core.scheduler import (
    ALL_SCHEDULERS,
    Assignment,
    DreamScheduler,
    EdfScheduler,
    FcfsScheduler,
    Request,
    SchedView,
    Scheduler,
    TerastalScheduler,
    make_scheduler,
)
from repro.core.simulator import SimResult, TaskSpec, simulate
from repro.core.variants import ModelPlan, VariantInfo, build_model_plan
from repro.core.workload import SCENARIOS, Scenario, scenario_platform_pairs

__all__ = [
    "BudgetResult",
    "distribute_budgets",
    "latency_levels",
    "ALL_SCHEDULERS",
    "Assignment",
    "DreamScheduler",
    "EdfScheduler",
    "FcfsScheduler",
    "Request",
    "SchedView",
    "Scheduler",
    "TerastalScheduler",
    "make_scheduler",
    "SimResult",
    "TaskSpec",
    "simulate",
    "ModelPlan",
    "VariantInfo",
    "build_model_plan",
    "SCENARIOS",
    "Scenario",
    "scenario_platform_pairs",
]

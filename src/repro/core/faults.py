"""Accelerator fault injection (platform degradation axis).

A :class:`FaultModel` attaches deterministic, seed-derived fault
processes to the platform's accelerators:

* ``down(acc=K,start=S,duration=D)`` — transient dropout: accelerator K
  is unavailable over ``[S, S+D)``;
* ``throttle(acc=K,start=S,duration=D,factor=F)`` — thermal throttling:
  K's latency column is multiplied by F over the window;
* ``permanent(acc=K,start=S)`` — K fails at S and never recovers;
* ``intermittent(acc=K,rate=R,mean_down=M)`` — a seed-derived renewal
  process: exponential time-to-failure at rate R failures/s, each outage
  exponential with mean M seconds (drawn from a PRNG stream salted away
  from the arrival streams, so adding faults never perturbs arrivals).

Fault windows resolve (per trial, via :meth:`FaultModel.timeline`) into
timestamped capability events — ``down`` / ``up`` / ``scale`` — that both
bit-parity engines merge into their event heaps exactly like arrivals.
On a ``down`` the accelerator's in-flight layer is evicted and re-enqueued
under the model's interrupted-work policy (``restart`` re-executes the
layer from scratch; ``resume`` carries the completed fraction over to the
next dispatch).  Schedulers see faults as masked / reweighted latency
columns (:func:`effective_plans`): a down accelerator is "busy forever"
and its columns are ``+inf``, a throttled one costs ``factor`` x nominal —
so Terastal's variant selection becomes the graceful-degradation lever
while FCFS/EDF/DREAM get the same masking without the variant escape
hatch.

Grid axes carry fault models as call-spec strings (picklable, printable):
a single spec, or several joined with ``+`` —
``"down(acc=0,start=0.1,duration=0.2)+throttle(acc=1,start=0.1,duration=0.3,factor=2)"``.
An ``interrupted=restart|resume`` kwarg on any component sets the
model-wide policy.  ``"none"`` (or an empty model) is the fault-free
identity and is bit-identical to the pre-fault-axis simulator.

A ``retighten=true`` kwarg (model-wide, like ``interrupted=``) turns on
fault-aware budget re-tightening and degraded-capacity admission: on
every capability event both engines re-run the Algorithm-1 tightening
kernel over the *effective* latency tables (:func:`retightened_vdl`),
rebind every live request's absolute virtual-deadline chain, and
recompute the admission layer's minimum-work estimates
(:func:`degraded_work_tables`) so ``shed_early`` / ``token_bucket``
judge against the capacity that actually exists.  With the flag off
(the default) budgets and admission stay frozen at nominal capability —
bit-identical to the original fault axis.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.specs import format_call_spec, parse_call_spec

FAULT_KINDS = ("down", "throttle", "permanent", "intermittent")
INTERRUPTED_POLICIES = ("restart", "resume")

# PRNG salt for intermittent fault streams; disjoint from the arrival
# salts in repro.core.simulator so fault draws never shift arrivals.
_FAULT_SALT = 0x5EED_FA17


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault process on one accelerator (see module doc for kinds)."""

    kind: str
    acc: int
    start: float = 0.0
    duration: float = math.inf
    factor: float = 1.0
    rate: float = 0.0  # intermittent: failures per second
    mean_down: float = 0.0  # intermittent: mean outage length (s)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {FAULT_KINDS}"
            )
        if not isinstance(self.acc, int) or isinstance(self.acc, bool) or self.acc < 0:
            raise ValueError(f"fault acc must be a non-negative int, got {self.acc!r}")
        for field in ("start", "duration", "factor", "rate", "mean_down"):
            v = getattr(self, field)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                raise ValueError(f"fault {field} must be a number, got {v!r}")
            if math.isnan(v) or v < 0:
                raise ValueError(f"fault {field} must be >= 0 and not NaN, got {v!r}")
        if self.kind == "throttle" and (
            self.factor <= 0 or not math.isfinite(self.factor)
        ):
            raise ValueError(f"throttle factor must be finite and > 0, got {self.factor!r}")
        if self.kind == "intermittent":
            if not math.isfinite(self.rate) or self.rate <= 0:
                raise ValueError(
                    f"intermittent rate must be finite and > 0, got {self.rate!r}"
                )
            if not math.isfinite(self.mean_down) or self.mean_down <= 0:
                raise ValueError(
                    f"intermittent mean_down must be finite and > 0, got {self.mean_down!r}"
                )
        elif self.kind != "permanent" and not math.isfinite(self.duration):
            raise ValueError(
                f"{self.kind} duration must be finite, got {self.duration!r}"
            )

    @property
    def end(self) -> float:
        """Window end (``inf`` for permanent failures)."""
        if self.kind == "permanent":
            return math.inf
        return self.start + self.duration

    def format(self) -> str:
        kw: Dict[str, object] = {"acc": self.acc}
        if self.kind == "intermittent":
            kw.update(rate=self.rate, mean_down=self.mean_down)
        else:
            kw["start"] = self.start
            if self.kind != "permanent":
                kw["duration"] = self.duration
            if self.kind == "throttle":
                kw["factor"] = self.factor
        return format_call_spec(self.kind, kw)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One capability transition, merged into the engines' event heaps."""

    t: float
    acc: int
    code: str  # "down" | "up" | "scale"
    value: float = 1.0  # scale: the new latency multiplier


@dataclasses.dataclass(frozen=True)
class FaultModel:
    faults: Tuple[FaultSpec, ...] = ()
    interrupted: str = "restart"
    #: fault-aware budget re-tightening + degraded-capacity admission
    #: (module doc).  False = budgets/admission frozen at nominal
    #: capability, bit-identical to the original fault axis.
    retighten: bool = False

    def __post_init__(self):
        if self.interrupted not in INTERRUPTED_POLICIES:
            raise ValueError(
                f"unknown interrupted-work policy {self.interrupted!r}; "
                f"expected one of {INTERRUPTED_POLICIES}"
            )
        if not isinstance(self.retighten, bool):
            raise ValueError(
                f"retighten must be a bool, got {self.retighten!r}"
            )
        # Windows on one accelerator must be unambiguous: deterministic
        # windows pairwise disjoint (a second permanent failure — or any
        # window at/after one — "overlaps" its infinite tail), and an
        # intermittent process owns its accelerator outright (its windows
        # are seed-dependent, so static disjointness cannot be checked
        # against anything else).
        by_acc: Dict[int, List[FaultSpec]] = {}
        for f in self.faults:
            by_acc.setdefault(f.acc, []).append(f)
        for acc, specs in by_acc.items():
            if any(f.kind == "intermittent" for f in specs) and len(specs) > 1:
                raise ValueError(
                    f"accelerator {acc}: an intermittent fault cannot be "
                    "combined with other faults on the same accelerator"
                )
            windows = sorted((f.start, f.end, f.kind) for f in specs)
            for (s0, e0, k0), (s1, e1, k1) in zip(windows, windows[1:]):
                if s1 < e0:
                    what = (
                        "overlapping permanent failures"
                        if k0 == "permanent" and k1 == "permanent"
                        else f"overlapping fault windows ({k0} and {k1})"
                    )
                    raise ValueError(
                        f"accelerator {acc}: {what} — "
                        f"[{s0}, {e0}) intersects [{s1}, {e1})"
                    )

    @property
    def active(self) -> bool:
        return bool(self.faults)

    def max_acc(self) -> int:
        return max((f.acc for f in self.faults), default=-1)

    def format(self) -> str:
        if not self.faults:
            return "none"
        parts = [f.format() for f in self.faults]
        extra: Dict[str, object] = {}
        if self.interrupted != "restart":
            extra["interrupted"] = self.interrupted
        if self.retighten:
            extra["retighten"] = True
        if extra:
            head, kw = parse_call_spec(parts[0])
            kw.update(extra)
            parts[0] = format_call_spec(head, kw)
        return "+".join(parts)

    def _windows(self, spec: FaultSpec, duration: float, seed: int) -> List[Tuple[float, float]]:
        """Concrete fault windows of one spec within ``[0, duration)``."""
        if spec.kind == "intermittent":
            # Renewal process: Exp(rate) up-time, Exp(1/mean_down) outage.
            # Seeded off (salt, trial seed, accelerator) so every trial
            # seed draws an independent but reproducible outage pattern.
            rng = np.random.default_rng([_FAULT_SALT, seed, spec.acc])
            out: List[Tuple[float, float]] = []
            t = 0.0
            while True:
                t += float(rng.exponential(1.0 / spec.rate))
                if t >= duration:
                    return out
                d = float(rng.exponential(spec.mean_down))
                out.append((t, t + d))
                t += d
        if spec.start >= duration:
            return []
        return [(spec.start, spec.end)]

    def timeline(
        self, n_acc: int, duration: float, seed: int
    ) -> Tuple[List[FaultEvent], int]:
        """Resolve to ``(capability events sorted by time, n_spans)``.

        ``n_spans`` counts the fault windows intersecting the horizon
        (the trial's ``SimResult.faulted_spans``).  Closing ``up`` /
        ``scale 1.0`` events may land past the horizon — the event loops
        drain them exactly like post-horizon layer finishes.
        """
        for f in self.faults:
            if f.acc >= n_acc:
                raise ValueError(
                    f"fault acc {f.acc} out of range for a platform with "
                    f"{n_acc} accelerators"
                )
        events: List[FaultEvent] = []
        n_spans = 0
        for f in self.faults:
            throttled = f.kind == "throttle"
            for s, e in self._windows(f, duration, seed):
                n_spans += 1
                if throttled:
                    events.append(FaultEvent(s, f.acc, "scale", f.factor))
                    events.append(FaultEvent(e, f.acc, "scale", 1.0))
                else:
                    events.append(FaultEvent(s, f.acc, "down"))
                    if math.isfinite(e):
                        events.append(FaultEvent(e, f.acc, "up"))
        # Stable by time: same-timestamp events keep spec order, so both
        # engines process identical sequences (heap counters follow this
        # list order).
        events.sort(key=lambda ev: ev.t)
        return events, n_spans


def make_fault_model(
    spec: Union[str, FaultModel, None]
) -> Optional[FaultModel]:
    """``"none"`` / ``None`` -> None; a ``+``-joined call-spec string (or a
    ready FaultModel) -> a validated :class:`FaultModel`.

    Raises ``ValueError`` on unknown kinds, malformed numbers
    (negative/NaN rates or durations), overlapping windows, or an unknown
    ``interrupted=`` policy.
    """
    if spec is None or isinstance(spec, FaultModel):
        return spec if spec is not None and spec.active else None
    if not isinstance(spec, str):
        raise ValueError(f"fault spec must be a string or FaultModel, got {spec!r}")
    if spec.strip() in ("", "none"):
        return None
    faults: List[FaultSpec] = []
    interrupted: Optional[str] = None
    retighten: Optional[bool] = None
    for part in spec.split("+"):
        name, kwargs = parse_call_spec(part)
        pol = kwargs.pop("interrupted", None)
        if pol is not None:
            if interrupted is not None and pol != interrupted:
                raise ValueError(
                    f"fault spec {spec!r}: conflicting interrupted= policies "
                    f"({interrupted!r} vs {pol!r})"
                )
            interrupted = pol
        rt = kwargs.pop("retighten", None)
        if rt is not None:
            if not isinstance(rt, bool):
                raise ValueError(
                    f"fault spec {spec!r}: retighten= must be true or false, "
                    f"got {rt!r}"
                )
            if retighten is not None and rt != retighten:
                raise ValueError(
                    f"fault spec {spec!r}: conflicting retighten= values"
                )
            retighten = rt
        if name not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {name!r} in {spec!r}; expected one of "
                f"{FAULT_KINDS}"
            )
        try:
            faults.append(FaultSpec(kind=name, **kwargs))
        except TypeError as e:
            raise ValueError(f"fault spec {part!r}: {e}") from e
    return FaultModel(
        faults=tuple(faults),
        interrupted=interrupted or "restart",
        retighten=bool(retighten),
    )


# ------------------------------------------------ capability masking ----


def fault_multipliers(scale: Sequence[float], avail: Sequence[bool]) -> np.ndarray:
    """[n_acc] latency multipliers: ``scale`` where up, ``+inf`` where down."""
    return np.array(
        [s if a else math.inf for s, a in zip(scale, avail)], dtype=float
    )


def effective_plans(plans: Sequence, mult: np.ndarray) -> List:
    """Fault-adjusted copies of the offline plans.

    Original and variant latency columns are multiplied by ``mult``
    (``+inf`` masks a down accelerator), so every derived table —
    ``remaining_min`` (drop test), ``min_lat`` (backfill), EDF keys,
    FCFS/EDF placement preferences — re-derives under the degraded
    capability.  Budgets, deadlines, and accuracy losses are untouched.
    Both engines build their working tables from the same helper, so
    fault-time arithmetic is bit-identical by construction.
    """
    if np.all(mult == 1.0):
        return list(plans)
    out = []
    for p in plans:
        variants = {
            idx: dataclasses.replace(v, latencies=v.latencies * mult)
            for idx, v in p.variants.items()
        }
        out.append(dataclasses.replace(p, lat=p.lat * mult, variants=variants))
    return out


def retightened_vdl(plans: Sequence, eff_plans: Sequence) -> List[Optional[np.ndarray]]:
    """Per-model re-tightened RELATIVE virtual-deadline chains under the
    current capability (``retighten=true`` — module doc).

    Re-runs the Algorithm-1 tightening kernel over each plan's
    *effective* latency table: :func:`~repro.core.budget.tighten_budgets`
    on linear chains, :func:`~repro.core.budget.tighten_budgets_dag` on
    DAG plans (critical-path re-tightening over the masked tables, so
    virtual deadlines stay strictly increasing along every edge whenever
    the tightening is feasible).  Returns one entry per model:

    * ``None`` — keep the frozen offline chain.  Either capability is
      nominal for this model (``eff is plan``, the ``effective_plans``
      identity fast path, where recomputing would reproduce the offline
      chain bit-for-bit anyway) or the degraded table is infeasible even
      fully tightened (e.g. every accelerator down) — deterministically
      fall back to the offline schedule and let early-drop triage.
    * an ``[L]`` float64 array — the re-tightened relative chain; both
      engines rebind every live request to ``arrival + chain``.

    Shared by the reference, SoA, and batch engines so fault-time budget
    arithmetic is bit-identical by construction.
    """
    from repro.core.budget import latency_levels, tighten_budgets, tighten_budgets_dag

    out: List[Optional[np.ndarray]] = []
    for p, ep in zip(plans, eff_plans):
        if ep is p:  # nominal capability: effective_plans identity fast path
            out.append(None)
            continue
        levels = [latency_levels(ep.lat[l]) for l in range(ep.lat.shape[0])]
        if p.dag is not None:
            res = tighten_budgets_dag(levels, p.deadline, p.dag)
        else:
            res = tighten_budgets(levels, p.deadline)
        out.append(res.virtual_deadlines if res.feasible else None)
    return out


def degraded_work_tables(
    eff_plans: Sequence, duration: float
) -> Tuple[List[float], List[int]]:
    """Admission work estimates under the current capability
    (``retighten=true``): per-model ``(min_work_s, work_ns)`` from the
    *effective* critical-path totals, replacing the frozen nominal values
    so ``shed_early`` / ``token_bucket`` judge against real capacity.

    A model with no live accelerator has ``crit_total == inf``: admission
    then rejects every release (``inf`` compares correctly in the float
    test), and its integer backlog weight is clamped to the horizon so
    ``int(round(...))`` stays finite.  At nominal capability the values
    are bit-identical to the frozen tables (same floats, same rounding).
    """
    min_work_s = [p.crit_total for p in eff_plans]
    work_ns = [
        int(round((w if math.isfinite(w) else duration) * 1e9))
        for w in min_work_s
    ]
    return min_work_s, work_ns


def evict_busy_adjust(
    t0: float, now: float, duration: float, disp_w: float, disp_h: float
) -> Tuple[float, float]:
    """Busy-time deltas when an in-flight dispatch ends early at ``now``.

    ``disp_w``/``disp_h`` are the wall / in-horizon amounts currently
    credited for the dispatch that started at ``t0``.  Shared by both
    engines so the float arithmetic matches bit-for-bit.
    """
    new_w = now - t0
    new_h = min(new_w, max(0.0, duration - t0))
    return new_w - disp_w, new_h - disp_h


def retime_busy_adjust(
    t0: float, fin_new: float, duration: float, disp_w: float, disp_h: float
) -> Tuple[float, float, float, float]:
    """Busy-time deltas (and new credited amounts) when a throttle change
    re-times an in-flight dispatch to finish at ``fin_new``."""
    new_w = fin_new - t0
    new_h = min(new_w, max(0.0, duration - t0))
    return new_w - disp_w, new_h - disp_h, new_w, new_h

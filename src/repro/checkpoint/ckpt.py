"""Sharding-aware checkpointing (msgpack + atomic rename).

* ``save``: gathers each leaf to host (replicated read), serializes the
  flattened {path: (dtype, shape, bytes)} map with msgpack, writes to a
  temp file, fsyncs, renames — a crash mid-save never corrupts the last
  good checkpoint.
* ``restore``: rebuilds the pytree and ``device_put``s each leaf with the
  *target* NamedSharding — restoring onto a different mesh shape
  (elastic up/down-scaling) is therefore free: the same checkpoint
  reshards to whatever mesh the new job brings up.
* ``latest_step`` + step-numbered directories give restart-after-failure
  semantics; the trainer in ``repro.launch.train`` checkpoints every N
  steps and resumes from the newest complete checkpoint.
"""

from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Params = Any

_SEP = "/"


def _flatten(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _tree_like(tree: Params, flat: Dict[str, np.ndarray]) -> Params:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs model {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _encode(flat: Dict[str, np.ndarray]) -> bytes:
    payload = {
        k: {
            "dtype": str(v.dtype),
            "shape": list(v.shape),
            "data": (v.astype(np.float32).tobytes() if v.dtype == jnp.bfloat16 else v.tobytes()),
            "bf16": v.dtype == jnp.bfloat16,
        }
        for k, v in flat.items()
    }
    return msgpack.packb(payload, use_bin_type=True)


def _decode(raw: bytes) -> Dict[str, np.ndarray]:
    payload = msgpack.unpackb(raw, raw=False)
    out = {}
    for k, meta in payload.items():
        if meta.get("bf16"):
            arr = np.frombuffer(meta["data"], dtype=np.float32).reshape(meta["shape"])
            arr = jnp.asarray(arr, jnp.bfloat16)
            out[k] = np.asarray(arr)
        else:
            out[k] = np.frombuffer(meta["data"], dtype=np.dtype(meta["dtype"])).reshape(meta["shape"])
    return out


def save(path: str, step: int, tree: Params) -> str:
    """Atomic checkpoint write; returns the checkpoint directory."""
    ckpt_dir = os.path.join(path, f"step_{step:08d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    target = os.path.join(ckpt_dir, "state.msgpack")
    raw = _encode(_flatten(tree))
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(raw)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, target)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    # completion marker makes partially-written checkpoints detectable
    with open(os.path.join(ckpt_dir, "COMMITTED"), "w") as f:
        f.write(str(step))
    return ckpt_dir


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = []
    for d in os.listdir(path):
        if d.startswith("step_") and os.path.exists(os.path.join(path, d, "COMMITTED")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(path: str, step: int, like: Params, shardings: Optional[Params] = None) -> Params:
    """Load ``step`` and place leaves with the target shardings (may be a
    different mesh than the one that saved — elastic restore)."""
    target = os.path.join(path, f"step_{step:08d}", "state.msgpack")
    with open(target, "rb") as f:
        flat = _decode(f.read())
    tree = _tree_like(like, flat)
    if shardings is None:
        return jax.tree.map(jnp.asarray, tree)
    return jax.tree.map(
        lambda arr, leaf_like, sh: jax.device_put(jnp.asarray(arr, leaf_like.dtype), sh),
        tree,
        like,
        shardings,
    )

"""Oracle for the GQA decode-attention kernel (single-token query
against a KV cache) — re-exports the model-level implementation."""

from repro.models.common import decode_attention

__all__ = ["decode_attention"]

"""Pallas TPU kernel: GQA decode attention (one query token, long cache).

Decode attention is memory-bound: the whole KV cache streams HBM->VMEM
once per step.  Grid: (batch, kv_heads, L/chunk) with the cache-length
axis sequential; online-softmax running stats (m, l) and the weighted
accumulator [G, Dh] live in VMEM scratch, so the output is written once
at the final chunk.  The query tile [G, Dh] (G = H/Hkv grouped heads)
rides along every chunk step — G x chunk MXU matmuls keep the VPU/MXU
busy while the next KV chunk streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params_cls


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref, *, n_chunks: int, scale: float):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # [G, Dh]
    k = k_ref[0].astype(jnp.float32)[:, 0]  # [Lc, Dh]
    v = v_ref[0].astype(jnp.float32)[:, 0]  # [Lc, Dh]
    Lc = k.shape[0]
    valid_len = len_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, Lc]
    pos = c * Lc + jax.lax.broadcasted_iota(jnp.int32, (1, Lc), 1)
    s = jnp.where(pos < valid_len, s, -1e30)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))  # [G, 1]
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(c == n_chunks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attn_pallas(
    q: jax.Array,  # [B, H, Dh] single-token queries
    cache_k: jax.Array,  # [B, L, Hkv, Dh]
    cache_v: jax.Array,
    valid_len: jax.Array,  # [B] number of valid cache positions (pos+1)
    chunk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Dh = q.shape
    _, L, Hkv, _ = cache_k.shape
    G = H // Hkv
    Lc = min(chunk, L)
    assert L % Lc == 0
    nc = L // Lc
    scale = 1.0 / (Dh**0.5)
    qg = q.reshape(B, Hkv, G, Dh)
    vlen = valid_len.astype(jnp.int32).reshape(B, 1)
    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, n_chunks=nc, scale=scale),
        grid=(B, Hkv, nc),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, Lc, 1, Dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Lc, 1, Dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dh), jnp.float32),
        ],
        compiler_params=compiler_params_cls()(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qg, cache_k, cache_v, vlen)
    return out.reshape(B, H, Dh)

"""jit'd wrapper for the decode-attention kernel with oracle fallback."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attn_pallas
from repro.models.common import decode_attention


@functools.partial(jax.jit, static_argnames=("backend", "chunk", "interpret"))
def gqa_decode_attention(
    q: jax.Array,  # [B, 1, H, Dh]
    cache_k: jax.Array,  # [B, L, Hkv, Dh]
    cache_v: jax.Array,
    pos: jax.Array,  # [] int32 (position of the newest token)
    backend: str = "pallas",
    chunk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    if backend == "jnp":
        return decode_attention(q, cache_k, cache_v, pos)
    B = q.shape[0]
    valid = jnp.broadcast_to(pos + 1, (B,))
    out = decode_attn_pallas(q[:, 0], cache_k, cache_v, valid, chunk=chunk, interpret=interpret)
    return out[:, None]  # [B, 1, H, Dh]

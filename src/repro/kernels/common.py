"""Shared Pallas-TPU shims used by the kernel implementations."""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def compiler_params_cls():
    # Newer JAX exposes pltpu.CompilerParams (TPUCompilerParams is a
    # deprecated alias there); older JAX has only TPUCompilerParams.
    # Prefer the non-deprecated name, fall back for old versions.
    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

"""Pure-jnp oracle for the fused D2S -> pointwise conv -> S2D variant.

This is the Terastal layer variant (paper Fig. 1) for a 1x1 convolution
(pointwise convs and conv-equivalent FC/matmul layers are the main
variant targets in modern nets; R x S > 1 convs route through an im2col
wrapper in ops.py).  Given x: [B, H, W, C] and variant weights
w: [C/g^2, K/g^2]:

    d2s:  (B, H, W, C) -> (B, gH, gW, C/g^2)   (channels -> space)
    conv: 1x1 matmul over channels
    s2d:  (B, gH, gW, K/g^2) -> (B, H, W, K)   (space -> channels)

The TPU insight (DESIGN.md §3): a conv with C < 128 under-utilizes the
128x128 MXU contraction; folding space into channels raises the
contraction width.  Fusing the two reshapes into the kernel keeps them
out of HBM entirely.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def d2s(x: jax.Array, gamma: int) -> jax.Array:
    """Depth-to-space: (B, H, W, C) -> (B, gH, gW, C/g^2)."""
    B, H, W, C = x.shape
    g = gamma
    assert C % (g * g) == 0
    x = x.reshape(B, H, W, g, g, C // (g * g))
    x = x.transpose(0, 1, 3, 2, 4, 5)  # B, H, g, W, g, C'
    return x.reshape(B, H * g, W * g, C // (g * g))


def s2d(x: jax.Array, gamma: int) -> jax.Array:
    """Space-to-depth: (B, gH, gW, K') -> (B, H, W, K' * g^2)."""
    B, Hg, Wg, K = x.shape
    g = gamma
    assert Hg % g == 0 and Wg % g == 0
    x = x.reshape(B, Hg // g, g, Wg // g, g, K)
    x = x.transpose(0, 1, 3, 2, 4, 5)  # B, H, W, g, g, K
    return x.reshape(B, Hg // g, Wg // g, K * g * g)


def s2d_conv_ref(x: jax.Array, w: jax.Array, gamma: int) -> jax.Array:
    """x: [B, H, W, C], w: [C/g^2, K/g^2] -> [B, H, W, K]."""
    B, H, W, C = x.shape
    g2 = gamma * gamma
    Cv, Kv = w.shape
    assert Cv == C // g2
    y = d2s(x, gamma)  # [B, gH, gW, C/g^2]
    y = jnp.einsum("bhwc,ck->bhwk", y, w, preferred_element_type=jnp.float32)
    y = y.astype(x.dtype)
    return s2d(y, gamma)  # [B, H, W, Kv*g^2]

"""Pallas TPU kernel: fused D2S -> 1x1 conv -> S2D (the Terastal variant).

Grid: (B, H/th, W/tw).  Each program reads one x tile
[1, th, tw, C] from VMEM, performs the depth-to-space rearrangement as a
register-level reshape/transpose (never touching HBM), runs the MXU
matmul against the resident variant weights [C/g^2, K/g^2], folds space
back into depth, and writes the [1, th, tw, K'] output tile.

BlockSpec sizing: th*tw*g^2 rows of C/g^2 contraction — tiles are chosen
so rows are a multiple of 8 (VPU sublane) and the contraction/output dims
align to 128 (MXU lane) where the layer allows; the wrapper in ops.py
picks tile sizes against a 16 MiB VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _s2d_conv_kernel(x_ref, w_ref, o_ref, *, gamma: int):
    # x_ref: [1, th, tw, C]; w_ref: [C/g^2, Kv]; o_ref: [1, th, tw, Kv*g^2]
    g = gamma
    g2 = g * g
    th, tw, C = x_ref.shape[1], x_ref.shape[2], x_ref.shape[3]
    Cv = C // g2
    x = x_ref[0]  # [th, tw, C]
    # ---- D2S within the tile: (th, tw, C) -> (th*g * tw*g, C/g^2) ------
    x = x.reshape(th, tw, g, g, Cv)
    x = x.transpose(0, 2, 1, 3, 4)  # th, g, tw, g, Cv
    x = x.reshape(th * g * tw * g, Cv)
    # ---- MXU matmul ------------------------------------------------------
    y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    y = y.astype(o_ref.dtype)  # [th*g*tw*g, Kv]
    # ---- S2D back: fold the g x g spatial expansion into channels --------
    Kv = y.shape[-1]
    y = y.reshape(th, g, tw, g, Kv)
    y = y.transpose(0, 2, 1, 3, 4)  # th, tw, g, g, Kv
    o_ref[0] = y.reshape(th, tw, Kv * g2)


def s2d_conv_pallas(
    x: jax.Array,  # [B, H, W, C]
    w: jax.Array,  # [C/g^2, K/g^2]
    gamma: int,
    tile_h: int = 8,
    tile_w: int = 8,
    interpret: bool = False,
) -> jax.Array:
    B, H, W, C = x.shape
    g2 = gamma * gamma
    Cv, Kv = w.shape
    assert Cv * g2 == C, (C, Cv, gamma)
    K = Kv * g2
    th, tw = min(tile_h, H), min(tile_w, W)
    assert H % th == 0 and W % tw == 0, (H, W, th, tw)
    grid = (B, H // th, W // tw)
    return pl.pallas_call(
        functools.partial(_s2d_conv_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, th, tw, C), lambda b, i, j: (b, i, j, 0)),
            pl.BlockSpec((Cv, Kv), lambda b, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, K), lambda b, i, j: (b, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, K), x.dtype),
        interpret=interpret,
    )(x, w)

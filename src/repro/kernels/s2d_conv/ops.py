"""jit'd public wrapper for the fused S2D-variant conv.

``s2d_variant_conv`` handles: tile-size selection against the VMEM
budget, the general R x S case via im2col (the kernel itself fuses the
pointwise core — R x S > 1 layers become a patch-matmul with the same
D2S/S2D sandwich), and CPU fallback through interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.s2d_conv.kernel import s2d_conv_pallas
from repro.kernels.s2d_conv.ref import s2d_conv_ref

VMEM_BUDGET = 12 * 1024 * 1024  # leave headroom of 16 MiB/core


def _pick_tiles(H: int, W: int, C: int, K: int, bytes_per_elem: int) -> int:
    for t in (16, 8, 4, 2, 1):
        if H % t or W % t:
            continue
        # x tile + out tile + weights resident
        vmem = t * t * (C + K) * bytes_per_elem
        if vmem <= VMEM_BUDGET:
            return t
    return 1


@functools.partial(jax.jit, static_argnames=("gamma", "interpret"))
def s2d_variant_conv(x: jax.Array, w: jax.Array, gamma: int, interpret: bool = True) -> jax.Array:
    """Fused variant pointwise conv. x: [B,H,W,C], w: [C/g^2, K/g^2]."""
    B, H, W, C = x.shape
    Cv, Kv = w.shape
    K = Kv * gamma * gamma
    t = _pick_tiles(H, W, C, K, x.dtype.itemsize)
    return s2d_conv_pallas(x, w, gamma, tile_h=t, tile_w=t, interpret=interpret)


def s2d_variant_conv_rs(
    x: jax.Array, w_full: jax.Array, gamma: int, interpret: bool = True
) -> jax.Array:
    """R x S > 1 variant conv via im2col + the fused pointwise kernel.

    w_full: [R, S, C/g^2, K/g^2] variant filter (operates in d2s space);
    x is patched at the d2s resolution, matching the paper's Fig. 1
    construction exactly (stride 1, 'same' padding)."""
    from repro.kernels.s2d_conv.ref import d2s, s2d

    R, S, Cv, Kv = w_full.shape
    y = d2s(x, gamma)
    # im2col at the expanded resolution
    pads = ((R // 2, (R - 1) // 2), (S // 2, (S - 1) // 2))
    yp = jnp.pad(y, ((0, 0), pads[0], pads[1], (0, 0)))
    B, Hg, Wg, _ = y.shape
    cols = []
    for r in range(R):
        for s in range(S):
            cols.append(yp[:, r : r + Hg, s : s + Wg, :])
    patches = jnp.concatenate(cols, axis=-1)  # [B, Hg, Wg, R*S*Cv]
    w2 = w_full.reshape(R * S * Cv, Kv)
    out = jnp.einsum("bhwc,ck->bhwk", patches, w2, preferred_element_type=jnp.float32)
    return s2d(out.astype(x.dtype), gamma)

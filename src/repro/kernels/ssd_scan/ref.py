"""Oracle for the SSD chunk kernel: the sequential recurrence.

Re-exports the model-level reference so kernel tests and model tests
share a single source of truth.
"""

from repro.models.mamba2 import ssd_chunked, ssd_naive

__all__ = ["ssd_naive", "ssd_chunked"]

"""jit'd wrapper: drop-in SSD mixer backed by the Pallas chunk kernel.

``ssd_scan(..., backend="pallas")`` matches ``repro.models.mamba2
.ssd_chunked`` numerically (tests sweep shapes/dtypes against
``ssd_naive``); the mamba2/zamba2 models call through here so the kernel
can be toggled per deployment (interpret=True on CPU, compiled on TPU).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.models.mamba2 import ssd_chunked, ssd_naive


@functools.partial(jax.jit, static_argnames=("chunk", "backend", "interpret"))
def ssd_scan(x, log_a, B, C, dt, chunk: int = 256, backend: str = "jnp", interpret: bool = True):
    if backend == "pallas":
        return ssd_scan_pallas(x, log_a, B, C, dt, chunk=chunk, interpret=interpret)
    if backend == "jnp":
        return ssd_chunked(x, log_a, B, C, dt, chunk)
    if backend == "naive":
        return ssd_naive(x, log_a, B, C, dt)
    raise ValueError(backend)

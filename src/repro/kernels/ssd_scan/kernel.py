"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid: (batch, heads, n_chunks) with the chunk axis sequential
("arbitrary" semantics); the inter-chunk SSM state [N, P] lives in VMEM
scratch and persists across chunk steps — the recurrence never round-
trips HBM.  Each chunk step computes the intra-chunk quadratic term on
the MXU (Q x Q decay-masked C.B^T against the chunk inputs) plus the
inter-chunk contribution from the carried state, then advances the state.

Block shapes: x [Q, P], B/C [Q, N], log_a/dt [Q] — with the production
Q=256, N=128, P=64 this is ~0.5 MiB of VMEM per step, and the Q x Q
decay matrix (256 KiB f32) stays in registers/VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params_cls


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, dt_ref, o_ref, state_ref, *, n_chunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # [Q, P]
    la = la_ref[0, 0].astype(jnp.float32)  # [Q]
    B = b_ref[0].astype(jnp.float32)  # [Q, N]
    C = c_ref[0].astype(jnp.float32)  # [Q, N]
    dt = dt_ref[0, 0].astype(jnp.float32)  # [Q]
    Q = x.shape[0]

    xdt = x * dt[:, None]  # [Q, P]
    cum = jnp.cumsum(la)  # [Q]
    total = cum[-1]

    # intra-chunk: decay-masked quadratic term
    seg = cum[:, None] - cum[None, :]  # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32) * decay  # [Q, Q]
    y = jnp.dot(scores, xdt, preferred_element_type=jnp.float32)  # [Q, P]

    # inter-chunk: contribution of the carried state
    S = state_ref[...]  # [N, P]
    y += jnp.exp(cum)[:, None] * jnp.dot(C, S, preferred_element_type=jnp.float32)

    # state update: S' = e^total * S + sum_j e^(total - cum_j) B_j (x) xdt_j
    w = jnp.exp(total - cum)  # [Q]
    state_ref[...] = jnp.exp(total) * S + jnp.dot((B * w[:, None]).T, xdt, preferred_element_type=jnp.float32)

    o_ref[0, 0] = y.astype(o_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,  # [Bt, L, H, P]
    log_a: jax.Array,  # [Bt, L, H]
    B: jax.Array,  # [Bt, L, N]
    C: jax.Array,  # [Bt, L, N]
    dt: jax.Array,  # [Bt, L, H]
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    Bt, L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q
    # layout: head-major so each (b, h) streams its own chunks
    xh = x.transpose(0, 2, 1, 3)  # [Bt, H, L, P]
    lah = log_a.transpose(0, 2, 1)  # [Bt, H, L]
    dth = dt.transpose(0, 2, 1)
    grid = (Bt, H, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, H, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=compiler_params_cls()(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xh, lah, B, C, dth)
    return out.transpose(0, 2, 1, 3)  # [Bt, L, H, P]

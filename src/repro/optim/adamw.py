"""Pure-JAX AdamW with global-norm clipping and LR schedule.

Parameters live in the model dtype (bf16 by default); first/second
moments are f32 and sharded identically to their parameters (the
optimizer update is elementwise, so m/v inherit the param
PartitionSpecs — this is what keeps the 400B-param configs within
per-device HBM on the production mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


def init_opt_state(params: Params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def opt_state_specs(param_specs: Params) -> OptState:
    """m/v shard exactly like their parameters; step replicated."""
    from jax.sharding import PartitionSpec as P

    return OptState(step=P(), m=param_specs, v=jax.tree.map(lambda s: s, param_specs))


def zero1_opt_specs(param_specs: Params, opt_shape: "OptState" = None) -> OptState:
    """ZeRO-1: parameters replicated, f32 moments sharded across every
    mesh axis.  Shape-aware: each moment leaf is sharded on its largest
    dim divisible by the full device count (256/512 both divide when 512
    does not, fitted_shardings drops the pod axis), else by 16, else
    replicated (only tiny norm/bias leaves)."""
    from jax.sharding import PartitionSpec as P

    ALL = ("pod", "data", "model")

    def leaf_spec(shape_leaf):
        dims = shape_leaf.shape
        best = None
        for want in (512, 256, 32, 16):
            cands = [d for d in range(len(dims)) if dims[d] % want == 0 and dims[d] >= want]
            if cands:
                best = max(cands, key=lambda d: dims[d])
                break
        if best is None:
            return P()
        entries = [None] * len(dims)
        entries[best] = ALL if dims[best] % 256 == 0 else ("data",)
        return P(*entries)

    if opt_shape is not None:
        m_specs = jax.tree.map(leaf_spec, opt_shape.m)
        return OptState(step=P(), m=m_specs, v=jax.tree.map(lambda s: s, m_specs))
    shard = jax.tree.map(
        lambda s: P(ALL), param_specs, is_leaf=lambda x: isinstance(x, P)
    )
    return OptState(step=P(), m=shard, v=jax.tree.map(lambda s: s, shard))


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = cfg.lr * (step + 1) / max(1, cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos).astype(jnp.float32)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    cfg: OptConfig, params: Params, grads: Params, state: OptState
) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, state.step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}


def make_train_step(loss_fn: Callable, opt_cfg: OptConfig):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step

"""Architecture registry: ``--arch <id>`` resolution.

``long_500k`` applicability: only the sub-quadratic families (ssm,
hybrid) run the 524288-token decode shape; the 8 pure full-attention
architectures skip it (recorded in DESIGN.md §4).
"""

from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig
from repro.models.model_api import SHAPES

_MODULES: Dict[str, str] = {
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "gemma-7b": "repro.configs.gemma_7b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "whisper-base": "repro.configs.whisper_base",
    "llava-next-34b": "repro.configs.llava_next_34b",
}

ARCHS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    try:
        mod = _MODULES[arch]
    except KeyError:
        raise KeyError(f"unknown arch '{arch}'; available: {list(ARCHS)}") from None
    return importlib.import_module(mod).CONFIG


def list_archs() -> List[str]:
    return list(ARCHS)


def shape_applicable(cfg: ModelConfig, shape_name: str) -> bool:
    """Which (arch x shape) dry-run cells run (see DESIGN.md §4)."""
    if shape_name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True


def all_cells() -> List[Tuple[str, str]]:
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape_applicable(cfg, shape):
                cells.append((arch, shape))
    return cells

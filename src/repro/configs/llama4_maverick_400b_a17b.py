"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1, interleaved MoE every 2nd
block (the public Llama-4 interleave; yields ~400B total / ~17B active).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,          # dense-block FFN width
    vocab_size=202048,
    n_experts=128,
    experts_per_token=1,
    moe_d_ff=8192,
    moe_every=2,
    rope_theta=5e5,
)

"""zamba2-2.7b [hybrid] — 54L d_model=2560, Mamba2 backbone +
SHARED attention block (32H, kv=32) every 6 blocks, ssm_state=64,
vocab=32000, d_ff=10240.  [arXiv:2411.15242; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    hybrid_attn_every=6,
    tie_embeddings=True,
)

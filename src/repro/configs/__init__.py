"""Exact configs for the ten assigned architectures + registry.

Every config is selectable via ``--arch <id>`` in the launchers.  Each
module exposes ``CONFIG`` (the full published architecture) — smoke tests
use ``CONFIG.reduced()``.
"""

from repro.configs.registry import ARCHS, get_config, list_archs

__all__ = ["ARCHS", "get_config", "list_archs"]

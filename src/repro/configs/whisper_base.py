"""whisper-base [audio] — enc-dec, 6L encoder + 6L decoder, d_model=512
8H (kv=8) d_ff=2048 vocab=51865; conv frontend STUB (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    n_encoder_layers=6,
    encoder_seq=1500,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    tie_embeddings=True,
)

"""Mamba2 (SSD — state-space duality) mixer and model. [arXiv:2405.21060]

The SSD layer computes, per head h with state size N and head dim P:

    S_t = a_t * S_{t-1} + dt_t * B_t (x) x_t        (S: [N, P])
    y_t = C_t . S_t + D * x_t,   a_t = exp(dt_t * A)

``ssd_naive`` is the step-by-step oracle; ``ssd_chunked`` is the
O(L * Q) blocked algorithm from the paper (intra-chunk quadratic term +
inter-chunk state recurrence), written so the chunk loop is a
``lax.scan`` — the same blocking the Pallas kernel in
``repro.kernels.ssd_scan`` uses on TPU.

Decode is the O(1)-per-token recurrent update on a carried (conv window,
SSM state) cache — this is what makes the 500k-token long-context shape
runnable for the SSM/hybrid architectures.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (
    AX_DATA,
    AX_MODEL,
    chunked_softmax_xent,
    dtype_of,
    embed,
    init_embedding,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
)
from repro.models.config import ModelConfig
from repro.models.transformer import _lm_head_w, _stack

Params = Dict[str, Any]


# ------------------------------------------------------------------ SSD -----


def ssd_naive(x, log_a, B, C, dt):
    """Sequential oracle.  x: [Bt, L, H, P]; log_a: [Bt, L, H];
    B, C: [Bt, L, N]; dt: [Bt, L, H] -> y: [Bt, L, H, P]."""
    Bt, L, H, Pd = x.shape
    N = B.shape[-1]

    def step(S, inputs):
        xt, lat, Bt_, Ct_, dtt = inputs  # [Bt,H,P],[Bt,H],[Bt,N],[Bt,N],[Bt,H]
        a = jnp.exp(lat)[..., None, None]  # [Bt,H,1,1]
        upd = jnp.einsum("bn,bhp,bh->bhnp", Bt_, xt, dtt)
        S = a * S + upd
        y = jnp.einsum("bn,bhnp->bhp", Ct_, S)
        return S, y

    S0 = jnp.zeros((Bt, H, N, Pd), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        log_a.transpose(1, 0, 2).astype(jnp.float32),
        B.transpose(1, 0, 2).astype(jnp.float32),
        C.transpose(1, 0, 2).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3)  # [Bt, L, H, P]


def _segsum(log_a):
    """log_a: [..., Q] -> [..., Q, Q] with out[i, j] = sum_{j < k <= i}."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, log_a, B, C, dt, chunk: int):
    """Blocked SSD (paper Listing 1 semantics). Shapes as ssd_naive."""
    Bt, L, H, Pd = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    f32 = jnp.float32
    xc = x.reshape(Bt, nc, Q, H, Pd).astype(f32)
    lac = log_a.reshape(Bt, nc, Q, H).astype(f32)
    Bc = B.reshape(Bt, nc, Q, N).astype(f32)
    Cc = C.reshape(Bt, nc, Q, N).astype(f32)
    dtc = dt.reshape(Bt, nc, Q, H).astype(f32)
    xdt = xc * dtc[..., None]  # [Bt,nc,Q,H,P]

    # intra-chunk (quadratic) term
    seg = _segsum(lac.transpose(0, 1, 3, 2))  # [Bt,nc,H,Q,Q]
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # [Bt,nc,Q,Q]
    M = CB[:, :, None] * jnp.exp(seg)  # [Bt,nc,H,Q,Q]
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", M, xdt)

    # per-chunk terminal states
    cum = jnp.cumsum(lac, axis=2)  # [Bt,nc,Q,H]
    total = cum[:, :, -1]  # [Bt,nc,H]
    decay_to_end = jnp.exp(total[:, :, None] - cum)  # [Bt,nc,Q,H]
    S_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_to_end, xdt)

    # inter-chunk recurrence
    def scan_fn(S, inp):
        S_c, tot = inp  # [Bt,H,N,P], [Bt,H]
        S_new = jnp.exp(tot)[..., None, None] * S + S_c
        return S_new, S  # emit the state *entering* this chunk

    S0 = jnp.zeros((Bt, H, N, Pd), f32)
    _, S_in = jax.lax.scan(
        scan_fn,
        S0,
        (S_chunk.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    S_in = S_in.transpose(1, 0, 2, 3, 4)  # [Bt,nc,H,N,P]

    # inter-chunk contribution
    state_decay_in = jnp.exp(cum)  # [Bt,nc,Q,H]
    y_off = jnp.einsum("bcin,bchnp,bcih->bcihp", Cc, S_in, state_decay_in)

    y = (y_diag + y_off).reshape(Bt, L, H, Pd)
    return y.astype(x.dtype)


# ------------------------------------------------------------- the block ----


def init_mamba_block(key, cfg: ModelConfig, dtype) -> Params:
    D, Din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    conv_ch = Din + 2 * N
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * Din + 2 * N + H
    return {
        "norm": init_rmsnorm(D),
        "in_proj": init_linear(k1, D, d_in_proj, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, conv_ch), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2))).astype(jnp.float32),
        "out_norm": init_rmsnorm(Din),
        "out_proj": init_linear(k3, Din, D, dtype, scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    Din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    z, xbc, dt = jnp.split(zxbcdt, [Din, 2 * Din + 2 * N], axis=-1)
    return z, xbc, dt  # xbc = conv input (x, B, C); dt: [.., H]


def _ssm_from_xbc(cfg: ModelConfig, p: Params, xbc: jax.Array, dt_raw: jax.Array):
    Din, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    x, Bm, Cm = jnp.split(xbc, [Din, Din + N], axis=-1)
    Bsz, L = x.shape[0], x.shape[1]
    xh = x.reshape(Bsz, L, H, Pd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,L,H]
    A = -jnp.exp(p["A_log"])  # [H]
    log_a = dt * A  # [B,L,H]
    return xh, log_a, Bm, Cm, dt


def mamba_block_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    res = x
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    z, xbc, dt_raw = _split_in_proj(cfg, linear(p["in_proj"], h))
    # causal depthwise conv1d (width W) over the (x, B, C) channels
    W = cfg.ssm_conv_width
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(pad[:, i : i + xbc.shape[1], :] * p["conv_w"][i] for i in range(W))
    xbc = jax.nn.silu((conv + p["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    xh, log_a, Bm, Cm, dt = _ssm_from_xbc(cfg, p, xbc, dt_raw)
    y = ssd_chunked(xh, log_a, Bm, Cm, dt, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(x.shape[0], x.shape[1], cfg.d_inner)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    return res + linear(p["out_proj"], y)


# -------------------------------------------------------------- decode ------


def mamba_init_state(cfg: ModelConfig, batch: int):
    Din, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    conv_ch = Din + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype_of(cfg.dtype)),
        "ssm": jnp.zeros((batch, H, N, Pd), jnp.float32),
    }


def mamba_block_decode(cfg: ModelConfig, p: Params, x1: jax.Array, state: Params):
    """x1: [B, 1, D]; O(1) recurrent update."""
    res = x1
    h = rmsnorm(p["norm"], x1, cfg.norm_eps)
    z, xbc, dt_raw = _split_in_proj(cfg, linear(p["in_proj"], h))
    window = jnp.concatenate([state["conv"], xbc], axis=1)  # [B, W, ch]
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"])[:, None, :]
    new_conv_state = window[:, 1:, :]
    xbc = jax.nn.silu((conv + p["conv_b"]).astype(jnp.float32)).astype(x1.dtype)
    xh, log_a, Bm, Cm, dt = _ssm_from_xbc(cfg, p, xbc, dt_raw)
    # single-step state update
    a = jnp.exp(log_a[:, 0])[..., None, None]  # [B,H,1,1]
    upd = jnp.einsum("bn,bhp,bh->bhnp", Bm[:, 0].astype(jnp.float32), xh[:, 0].astype(jnp.float32), dt[:, 0])
    S = a * state["ssm"] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), S)
    y = y + p["D"][None, :, None] * xh[:, 0].astype(jnp.float32)
    y = y.reshape(x1.shape[0], 1, cfg.d_inner).astype(x1.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps)
    return res + linear(p["out_proj"], y), {"conv": new_conv_state, "ssm": S}


# ------------------------------------------------------------- full model ---


def init_ssm_model(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_blocks = jax.random.split(key)
    blocks = jax.vmap(lambda k: init_mamba_block(k, cfg, dtype))(jax.random.split(k_blocks, cfg.n_layers))
    return {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def ssm_loss(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    x = embed(params["embed"], tokens)

    def body(h, p_block):
        return mamba_block_apply(cfg, p_block, h), None

    from repro.models.common import maybe_remat

    body = maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, x, params["blocks"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    # mamba2-1.3b ties embeddings (GPT-NeoX tokenizer family)
    return chunked_softmax_xent(h, params["embed"]["emb"].T, labels, chunk=cfg.logits_chunk)


def ssm_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    per = mamba_init_state(cfg, batch)
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (cfg.n_layers, *a.shape)), per)


def ssm_decode_step(cfg: ModelConfig, params: Params, token: jax.Array, cache: Params, pos: jax.Array):
    x1 = embed(params["embed"], token)[:, None, :]

    def body(h, layer_in):
        p_block, conv_s, ssm_s = layer_in
        h, new_state = mamba_block_decode(cfg, p_block, h, {"conv": conv_s, "ssm": ssm_s})
        return h, (new_state["conv"], new_state["ssm"])

    h, (conv_s, ssm_s) = jax.lax.scan(body, x1, (params["blocks"], cache["conv"], cache["ssm"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["embed"]["emb"].T).astype(jnp.float32)
    return logits, {"conv": conv_s, "ssm": ssm_s}


# --------------------------------------------------------------- shardings --


def ssm_param_specs(cfg: ModelConfig, mode: str = "train") -> Params:
    if cfg.fsdp_all_axes:
        # Small-model ZeRO-1 profile (EXPERIMENTS.md §Perf, mamba2 train):
        # NO tensor parallelism — batch data-parallel across
        # (data, model), parameters REPLICATED (a 1.3B model fits), and
        # only the f32 optimizer moments sharded (see
        # repro.optim.adamw.zero1_opt_specs).  Eliminates both the
        # per-block TP all-reduces AND the per-layer FSDP weight gathers
        # (iteration 2 showed naive all-axes FSDP regathers 143 GB/step);
        # the only collectives left are one gradient all-reduce + the
        # updated-parameter all-gather.
        block = {
            "norm": {"scale": P(None)},
            "in_proj": {"w": P(None, None)},
            "conv_w": P(None, None),
            "conv_b": P(None),
            "A_log": P(None),
            "D": P(None),
            "dt_bias": P(None),
            "out_norm": {"scale": P(None)},
            "out_proj": {"w": P(None, None)},
        }
        return {
            "embed": {"emb": P(None, None)},
            "blocks": _stack(block),
            "final_norm": {"scale": P(None)},
        }
    block = {
        "norm": {"scale": P(None)},
        "in_proj": {"w": P(AX_DATA, AX_MODEL)},
        "conv_w": P(None, AX_MODEL),
        "conv_b": P(AX_MODEL),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "out_norm": {"scale": P(AX_MODEL)},
        "out_proj": {"w": P(AX_MODEL, AX_DATA)},
    }
    return {
        "embed": {"emb": P(AX_MODEL, AX_DATA)},
        "blocks": _stack(block),
        "final_norm": {"scale": P(None)},
    }


def ssm_cache_specs(cfg: ModelConfig, seq_shard: bool = False) -> Params:
    return {
        "conv": P(None, AX_DATA, None, AX_MODEL),
        "ssm": P(None, AX_DATA, AX_MODEL, None, None),
    }

"""Dense decoder-only transformer (llama / qwen / gemma / mistral families).

Layers are *stacked* (leading ``n_layers`` axis) and applied with
``lax.scan`` + optional remat: this keeps the lowered HLO size and compile
time independent of depth — essential for 94-layer configs on the 512-way
dry-run — and is also what makes the activation-checkpoint policy uniform.

The same attention core is reused by the MoE / hybrid / enc-dec / VLM
families (they import from here).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (
    AX_DATA,
    AX_MODEL,
    apply_rope,
    chunked_softmax_xent,
    decode_attention,
    dtype_of,
    embed,
    flash_attention,
    glu_activation,
    init_embedding,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
)
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ----------------------------------------------------------------- blocks ---


def init_attn(key, cfg: ModelConfig, dtype) -> Params:
    dh = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, cfg.d_model, cfg.n_heads * dh, dtype),
        "wk": init_linear(k2, cfg.d_model, cfg.n_kv_heads * dh, dtype),
        "wv": init_linear(k3, cfg.d_model, cfg.n_kv_heads * dh, dtype),
        "wo": init_linear(k4, cfg.n_heads * dh, cfg.d_model, dtype, scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(k1, cfg.d_model, d_ff, dtype),
        "w_up": init_linear(k2, cfg.d_model, d_ff, dtype),
        "w_down": init_linear(k3, d_ff, cfg.d_model, dtype, scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


def init_dense_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_attn(k1, cfg, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k2, cfg, dtype),
    }


def attn_apply_train(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array) -> jax.Array:
    B, L, D = x.shape
    dh = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(B, L, cfg.n_heads, dh)
    k = linear(p["wk"], x).reshape(B, L, cfg.n_kv_heads, dh)
    v = linear(p["wv"], x).reshape(B, L, cfg.n_kv_heads, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=True, q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    return linear(p["wo"], o.reshape(B, L, cfg.n_heads * dh))


def mlp_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    a = linear(p["w_gate"], x)
    b = linear(p["w_up"], x)
    return linear(p["w_down"], glu_activation(cfg.activation, a, b))


def dense_block_apply(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.parallel_block:
        # PaLM-style parallel formulation: both branches read the same
        # input; their partial sums merge into ONE TP all-reduce per block
        # (EXPERIMENTS.md §Perf, llama4 train cell).
        a = attn_apply_train(cfg, p["attn"], rmsnorm(p["attn_norm"], x, cfg.norm_eps), positions)
        m = mlp_apply(cfg, p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
        return x + a + m
    x = x + attn_apply_train(cfg, p["attn"], rmsnorm(p["attn_norm"], x, cfg.norm_eps), positions)
    x = x + mlp_apply(cfg, p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x


# -------------------------------------------------------- decode (1 token) --


KV_QUANT_SCALE = 32.0  # int8 KV cache: symmetric, fixed scale


def _kv_quant(x: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(x.astype(jnp.float32) * KV_QUANT_SCALE), -127, 127).astype(jnp.int8)


def _kv_dequant(x: jax.Array, dtype) -> jax.Array:
    return (x.astype(jnp.float32) * (1.0 / KV_QUANT_SCALE)).astype(dtype)


def attn_apply_decode(
    cfg: ModelConfig,
    p: Params,
    x1: jax.Array,  # [B, 1, D]
    cache_k: jax.Array,  # [B, Lmax, Hkv, Dh]
    cache_v: jax.Array,
    pos: jax.Array,  # [] int32
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B = x1.shape[0]
    dh = cfg.resolved_head_dim
    q = linear(p["wq"], x1).reshape(B, 1, cfg.n_heads, dh)
    k = linear(p["wk"], x1).reshape(B, 1, cfg.n_kv_heads, dh)
    v = linear(p["wv"], x1).reshape(B, 1, cfg.n_kv_heads, dh)
    pos_arr = pos[None] if pos.ndim == 0 else pos
    q = apply_rope(q, jnp.broadcast_to(pos_arr, (B, 1)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(pos_arr, (B, 1)), cfg.rope_theta)
    if cfg.kv_cache_quant:
        # int8 cache: HBM streams 1 byte/elem; dequant fuses into the
        # attention matmul load (EXPERIMENTS.md §Perf, decode cell).
        kq, vq = _kv_quant(k), _kv_quant(v)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, kq, pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, vq, pos, axis=1)
        dt = x1.dtype
        o = decode_attention(q, _kv_dequant(cache_k, dt), _kv_dequant(cache_v, dt), pos)
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
        o = decode_attention(q, cache_k, cache_v, pos)
    return linear(p["wo"], o.reshape(B, 1, cfg.n_heads * dh)), cache_k, cache_v


def dense_block_decode(cfg, p, x1, cache_k, cache_v, pos):
    a, ck, cv = attn_apply_decode(cfg, p["attn"], rmsnorm(p["attn_norm"], x1, cfg.norm_eps), cache_k, cache_v, pos)
    x1 = x1 + a
    x1 = x1 + mlp_apply(cfg, p["mlp"], rmsnorm(p["mlp_norm"], x1, cfg.norm_eps))
    return x1, ck, cv


# ------------------------------------------------------------- full model ---


def init_dense_model(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_dense_block(k, cfg, dtype))(block_keys)
    params = {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": blocks,
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params


def _lm_head_w(cfg: ModelConfig, params: Params) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["emb"].T
    return params["lm_head"]["w"]


def forward_hidden_dense(cfg: ModelConfig, params: Params, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Embedding-space input -> final hidden states, scanning the stack."""

    def body(h, p_block):
        return dense_block_apply(cfg, p_block, h, positions), None

    from repro.models.common import maybe_remat

    body = maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, x, params["blocks"])
    return rmsnorm(params["final_norm"], h, cfg.norm_eps)


def dense_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    B, L = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    h = forward_hidden_dense(cfg, params, x, positions)
    return chunked_softmax_xent(h, _lm_head_w(cfg, params), labels, chunk=cfg.logits_chunk)


def dense_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dh = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, dh)
    dt = jnp.int8 if cfg.kv_cache_quant else dtype_of(cfg.dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def dense_decode_step(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,  # [B] int32 — current token ids
    cache: Params,
    pos: jax.Array,  # [] int32
) -> Tuple[jax.Array, Params]:
    """One serving step: consume `token` at `pos`, return next-token logits
    and the updated cache."""
    B = token.shape[0]
    x1 = embed(params["embed"], token)[:, None, :]  # [B,1,D]

    def body(h, layer_in):
        p_block, ck, cv = layer_in
        h, ck, cv = dense_block_decode(cfg, p_block, h, ck, cv, pos)
        return h, (ck, cv)

    h, (new_k, new_v) = jax.lax.scan(body, x1, (params["blocks"], cache["k"], cache["v"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ _lm_head_w(cfg, params)).astype(jnp.float32)
    return logits, {"k": new_k, "v": new_v}


# --------------------------------------------------------------- shardings --


def _attn_specs() -> Params:
    return {
        "wq": {"w": P(AX_DATA, AX_MODEL)},
        "wk": {"w": P(AX_DATA, AX_MODEL)},
        "wv": {"w": P(AX_DATA, AX_MODEL)},
        "wo": {"w": P(AX_MODEL, AX_DATA)},
    }


def _mlp_specs() -> Params:
    return {
        "w_gate": {"w": P(AX_DATA, AX_MODEL)},
        "w_up": {"w": P(AX_DATA, AX_MODEL)},
        "w_down": {"w": P(AX_MODEL, AX_DATA)},
    }


def _stack(tree: Params) -> Params:
    """Prepend the scanned layer axis (unsharded) to every leaf spec."""
    return jax.tree.map(lambda s: P(None, *s), tree, is_leaf=lambda x: isinstance(x, P))


def replicate_specs(tree: Params) -> Params:
    """ZeRO-1 profile: every parameter replicated (optimizer moments are
    sharded separately via repro.optim.adamw.zero1_opt_specs)."""
    return jax.tree.map(
        lambda s: P(*([None] * len(s))), tree, is_leaf=lambda x: isinstance(x, P)
    )


def dense_param_specs(cfg: ModelConfig, mode: str = "train") -> Params:
    """PartitionSpec tree matching init_dense_model's params.

    ``train``: FSDP over (pod, data) x TP over model.
    ``serve``: weights sharded over BOTH axes (no optimizer state, small
    batch; maximal weight distribution keeps giant models resident)."""
    block = {
        "attn_norm": {"scale": P(None)},
        "attn": _attn_specs(),
        "mlp_norm": {"scale": P(None)},
        "mlp": _mlp_specs(),
    }
    specs = {
        "embed": {"emb": P(AX_MODEL, AX_DATA)},
        "blocks": _stack(block),
        "final_norm": {"scale": P(None)},
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"w": P(AX_DATA, AX_MODEL)}
    if cfg.fsdp_all_axes and mode == "train":
        return replicate_specs(specs)
    return specs


TP_SIZE = 16  # model-axis size of both production meshes (fixed by target)


def kv_cache_spec(cfg: ModelConfig, seq_shard: bool, extra_lead: int = 0) -> P:
    """Cache sharding for [*, B, L, Hkv, Dh]: shard heads over `model`
    when divisible by the TP width, else shard the sequence dim; batch
    goes to the data axis unless batch==1 (seq_shard), in which case the
    sequence takes the data axis too."""
    lead = (None,) * (1 + extra_lead)
    heads_ok = cfg.n_kv_heads % TP_SIZE == 0
    if seq_shard:
        if heads_ok:
            return P(*lead, None, AX_DATA, AX_MODEL, None)
        return P(*lead, None, ("pod", "data", "model"), None, None)
    if heads_ok:
        return P(*lead, AX_DATA, None, AX_MODEL, None)
    return P(*lead, AX_DATA, AX_MODEL, None, None)


def dense_cache_specs(cfg: ModelConfig, seq_shard: bool = False) -> Params:
    spec = kv_cache_spec(cfg, seq_shard)
    return {"k": spec, "v": spec}

"""Model configuration for the assigned architecture families.

One frozen dataclass covers dense / MoE / SSM / hybrid / enc-dec / VLM
backbones; family-specific fields are zero/None when unused.  Exact
configs for the ten assigned architectures live in ``repro.configs``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    activation: str = "swiglu"  # swiglu | geglu
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ---- MoE ----
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1  # MoE layer every k-th block (llama4: 2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group_size: int = 4096  # GShard dispatch group size (tokens)

    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0  # N
    ssm_headdim: int = 64  # P
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # ---- hybrid (zamba2): shared attention block every k mamba blocks ----
    hybrid_attn_every: int = 6

    # ---- enc-dec (whisper) ----
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # stub frame count

    # ---- VLM (llava) ----
    n_patches: int = 0  # prepended patch-embedding stub tokens

    # ---- numerics / compile ----
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs) | none
    # ---- perf knobs (EXPERIMENTS.md §Perf) ----
    parallel_block: bool = False  # PaLM-style attn+MLP in parallel: 1 TP
    #                               all-reduce per block instead of 2
    fsdp_all_axes: bool = False  # small models: pure DP/FSDP over every
    #                              mesh axis, no TP collectives at all
    kv_cache_quant: bool = False  # int8 KV cache (decode memory roofline)
    logits_chunk: int = 1024  # CE computed over seq chunks to bound memory
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            moe_d_ff=64 if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=32,
            n_patches=min(self.n_patches, 16),
            hybrid_attn_every=2,
            moe_group_size=64,
            logits_chunk=32,
            attn_q_chunk=16,
            attn_k_chunk=16,
            name=self.name + "-reduced",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)

"""LLaVA-NeXT-style VLM backbone. [llava-hf/llava-v1.6]

The vision tower + anyres tiling frontend is a STUB per the assignment:
``input_specs`` provides precomputed patch embeddings
[B, n_patches, d_model] (post-projector).  The backbone is the dense
decoder from ``repro.models.transformer``; training prepends patch
embeddings to the token embeddings and masks the loss to text positions;
decoding reuses the dense KV-cache step (patch positions occupy the
cache prefix after prefill).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import chunked_softmax_xent, embed, rmsnorm
from repro.models.config import ModelConfig
from repro.models.transformer import (
    _lm_head_w,
    dense_cache_specs,
    dense_decode_step,
    dense_init_cache,
    dense_param_specs,
    forward_hidden_dense,
    init_dense_model,
)

Params = Dict[str, Any]

init_vlm_model = init_dense_model
vlm_param_specs = dense_param_specs
vlm_decode_step = dense_decode_step
vlm_cache_specs = dense_cache_specs


def vlm_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    # cache must hold the patch prefix + generated text
    return dense_init_cache(cfg, batch, max_len)


def vlm_loss(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    """batch: patch_embeds [B, Np, D], tokens [B, Lt], labels [B, Lt]."""
    patches, tokens, labels = batch["patch_embeds"], batch["tokens"], batch["labels"]
    B, Np, D = patches.shape
    Lt = tokens.shape[1]
    x_text = embed(params["embed"], tokens)
    x = jnp.concatenate([patches.astype(x_text.dtype), x_text], axis=1)
    L = Np + Lt
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    h = forward_hidden_dense(cfg, params, x, positions)
    # loss on text positions only
    h_text = h[:, Np:, :]
    return chunked_softmax_xent(h_text, _lm_head_w(cfg, params), labels, chunk=cfg.logits_chunk)

"""Unified model API: family dispatch + input specs for every shape.

``build_model(cfg)`` returns a :class:`Model` bundle with functional
entry points shared by the trainer, the serving runtime and the dry-run:

  init(key) -> params
  loss(params, batch) -> scalar            (training objective)
  prefill(params, batch) -> logits         (inference-prefill forward)
  init_cache(batch, max_len) -> cache
  decode_step(params, token, cache, pos) -> (logits, cache)
  param_specs(mode) / cache_specs(seq_shard) / batch_specs(kind)
  input_specs(shape) -> ShapeDtypeStruct pytrees (no allocation)

Shape kinds (the assigned input-shape set):
  train_4k    — train_step(tokens/labels [B, L])
  prefill_32k — prefill forward, last-position logits
  decode_32k  — one decode step against a seq_len cache
  long_500k   — one decode step at 524288 context (SSM/hybrid only)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import hybrid, mamba2, moe, transformer, vlm, whisper
from repro.models.common import AX_DATA, AX_MODEL, dtype_of, embed, rmsnorm
from repro.models.config import ModelConfig

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    loss: Callable[[Params, Dict[str, jax.Array]], jax.Array]
    init_cache: Callable[[int, int], Params]
    decode_step: Callable[..., Tuple[jax.Array, Params]]
    param_specs: Callable[[str], Params]
    cache_specs: Callable[[bool], Params]

    # ---- prefill: forward producing last-position logits ------------------
    def prefill(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "vlm"):
            tokens = batch["tokens"]
            B, L = tokens.shape
            x = embed(params["embed"], tokens)
            if fam == "vlm" and "patch_embeds" in batch:
                x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
                L = x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
            h = transformer.forward_hidden_dense(cfg, params, x, positions)
            return (h[:, -1] @ transformer._lm_head_w(cfg, params)).astype(jnp.float32)
        if fam == "moe":
            tokens = batch["tokens"]
            B, L = tokens.shape
            x = embed(params["embed"], tokens)
            positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
            h, _ = moe.forward_hidden_moe(cfg, params, x, positions)
            return (h[:, -1] @ transformer._lm_head_w(cfg, params)).astype(jnp.float32)
        if fam == "ssm":
            x = embed(params["embed"], batch["tokens"])

            def body(h, p_block):
                return mamba2.mamba_block_apply(cfg, p_block, h), None

            from repro.models.common import maybe_remat

            body = maybe_remat(body, cfg)
            h, _ = jax.lax.scan(body, x, params["blocks"])
            h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
            return (h[:, -1] @ params["embed"]["emb"].T).astype(jnp.float32)
        if fam == "hybrid":
            tokens = batch["tokens"]
            B, L = tokens.shape
            x = embed(params["embed"], tokens)
            positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
            shared = params["shared_attn"]

            def body(h, p_group):
                h = transformer.dense_block_apply(cfg, shared, h, positions)
                for i in range(cfg.hybrid_attn_every):
                    pb = jax.tree.map(lambda a: a[i], p_group)
                    h = mamba2.mamba_block_apply(cfg, pb, h)
                return h, None

            from repro.models.common import maybe_remat

            body = maybe_remat(body, cfg)
            h, _ = jax.lax.scan(body, x, params["mamba_blocks"])
            h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
            return (h[:, -1] @ params["embed"]["emb"].T).astype(jnp.float32)
        if fam == "encdec":
            enc = whisper.encode(cfg, params, batch["frames"])
            h = whisper.decoder_hidden(cfg, params, batch["tokens"], enc)
            return (h[:, -1] @ params["embed"]["emb"].T).astype(jnp.float32)
        raise ValueError(fam)

    # ---- ShapeDtypeStruct stand-ins (no allocation) ------------------------
    def input_specs(self, shape_name: str) -> Dict[str, Any]:
        cfg = self.cfg
        sh = SHAPES[shape_name]
        B, L = sh.global_batch, sh.seq_len
        tok = jax.ShapeDtypeStruct((B, L), jnp.int32)
        dt = dtype_of(cfg.dtype)
        if sh.kind in ("train", "prefill"):
            batch = {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, L), jnp.int32)}
            if cfg.family == "encdec":
                batch["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
            if cfg.family == "vlm":
                batch["patch_embeds"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), dt)
            if sh.kind == "prefill":
                batch.pop("labels")
            return batch
        # decode: one token step against a seq_len cache
        cache = jax.eval_shape(lambda: self.init_cache(B, L))
        return {
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def batch_specs(self, shape_name: str) -> Dict[str, Any]:
        """Input shardings matching input_specs."""
        cfg = self.cfg
        sh = SHAPES[shape_name]
        data = P(("data", "model") if cfg.fsdp_all_axes else AX_DATA, None)
        if sh.kind in ("train", "prefill"):
            specs = {"tokens": data}
            if sh.kind == "train":
                specs["labels"] = data
            if cfg.family == "encdec":
                specs["frames"] = P(AX_DATA, None, None)
            if cfg.family == "vlm":
                specs["patch_embeds"] = P(AX_DATA, None, None)
            return specs
        seq_shard = sh.global_batch == 1
        return {
            "token": P(None) if seq_shard else P(AX_DATA),
            "cache": self.cache_specs(seq_shard),
            "pos": P(),
        }


# ------------------------------------------------------------------ build ---


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense",):
        return Model(
            cfg,
            init=lambda key: transformer.init_dense_model(key, cfg),
            loss=lambda p, b: transformer.dense_loss(cfg, p, b),
            init_cache=lambda B, L: transformer.dense_init_cache(cfg, B, L),
            decode_step=lambda p, t, c, pos: transformer.dense_decode_step(cfg, p, t, c, pos),
            param_specs=lambda mode="train": transformer.dense_param_specs(cfg, mode),
            cache_specs=lambda seq_shard=False: transformer.dense_cache_specs(cfg, seq_shard),
        )
    if fam == "vlm":
        return Model(
            cfg,
            init=lambda key: vlm.init_vlm_model(key, cfg),
            loss=lambda p, b: vlm.vlm_loss(cfg, p, b),
            init_cache=lambda B, L: vlm.vlm_init_cache(cfg, B, L),
            decode_step=lambda p, t, c, pos: vlm.vlm_decode_step(cfg, p, t, c, pos),
            param_specs=lambda mode="train": vlm.vlm_param_specs(cfg, mode),
            cache_specs=lambda seq_shard=False: vlm.vlm_cache_specs(cfg, seq_shard),
        )
    if fam == "moe":
        return Model(
            cfg,
            init=lambda key: moe.init_moe_model(key, cfg),
            loss=lambda p, b: moe.moe_loss(cfg, p, b),
            init_cache=lambda B, L: moe.moe_init_cache(cfg, B, L),
            decode_step=lambda p, t, c, pos: moe.moe_decode_step(cfg, p, t, c, pos),
            param_specs=lambda mode="train": moe.moe_param_specs(cfg, mode),
            cache_specs=lambda seq_shard=False: moe.moe_cache_specs(cfg, seq_shard),
        )
    if fam == "ssm":
        return Model(
            cfg,
            init=lambda key: mamba2.init_ssm_model(key, cfg),
            loss=lambda p, b: mamba2.ssm_loss(cfg, p, b),
            init_cache=lambda B, L: mamba2.ssm_init_cache(cfg, B, L),
            decode_step=lambda p, t, c, pos: mamba2.ssm_decode_step(cfg, p, t, c, pos),
            param_specs=lambda mode="train": mamba2.ssm_param_specs(cfg, mode),
            cache_specs=lambda seq_shard=False: mamba2.ssm_cache_specs(cfg, seq_shard),
        )
    if fam == "hybrid":
        return Model(
            cfg,
            init=lambda key: hybrid.init_hybrid_model(key, cfg),
            loss=lambda p, b: hybrid.hybrid_loss(cfg, p, b),
            init_cache=lambda B, L: hybrid.hybrid_init_cache(cfg, B, L),
            decode_step=lambda p, t, c, pos: hybrid.hybrid_decode_step(cfg, p, t, c, pos),
            param_specs=lambda mode="train": hybrid.hybrid_param_specs(cfg, mode),
            cache_specs=lambda seq_shard=False: hybrid.hybrid_cache_specs(cfg, seq_shard),
        )
    if fam == "encdec":
        return Model(
            cfg,
            init=lambda key: whisper.init_encdec_model(key, cfg),
            loss=lambda p, b: whisper.encdec_loss(cfg, p, b),
            init_cache=lambda B, L: whisper.encdec_init_cache(cfg, B, L),
            decode_step=lambda p, t, c, pos: whisper.encdec_decode_step(cfg, p, t, c, pos),
            param_specs=lambda mode="train": whisper.encdec_param_specs(cfg, mode),
            cache_specs=lambda seq_shard=False: whisper.encdec_cache_specs(cfg, seq_shard),
        )
    raise ValueError(f"unknown family '{fam}'")

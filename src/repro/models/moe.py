"""Mixture-of-Experts transformer (llama4-maverick, qwen3-moe families).

Routing is GShard/Switch-style dense dispatch with *groups*: tokens are
split into groups of ``moe_group_size`` and each group dispatches into
per-expert capacity buffers via one-hot einsums.  This formulation is
fully static-shaped, shards cleanly under GSPMD (tokens -> data axis,
experts -> model axis => the dispatch einsum lowers to an all-to-all),
and bounds the dispatch tensor to [S, E_local, C] per device.

llama4-maverick interleaves dense and MoE blocks (``moe_every = 2``,
matching the public Llama-4 interleave); qwen3 is MoE in every block.
The scan runs over *super-groups* of (moe_every - 1) dense blocks + 1 MoE
block so the stack still compiles as a single scan.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (
    AX_DATA,
    AX_MODEL,
    chunked_softmax_xent,
    dtype_of,
    embed,
    init_embedding,
    init_linear,
    init_rmsnorm,
    rmsnorm,
)
from repro.models.config import ModelConfig
from repro.models.transformer import (
    _lm_head_w,
    _stack,
    attn_apply_decode,
    attn_apply_train,
    dense_block_apply,
    dense_block_decode,
    dense_param_specs,
    init_attn,
    init_dense_block,
    glu_activation,
)

Params = Dict[str, Any]


# ------------------------------------------------------------------ layer ---


def init_moe_layer(key, cfg: ModelConfig, dtype) -> Params:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 0.02
    return {
        "router": {"w": jax.random.normal(k1, (D, E), jnp.float32) * s},
        "w_gate": (jax.random.normal(k2, (E, D, F), jnp.float32) * s).astype(dtype),
        "w_up": (jax.random.normal(k3, (E, D, F), jnp.float32) * s).astype(dtype),
        "w_down": (jax.random.normal(k4, (E, F, D), jnp.float32) * s / max(1, 2 * cfg.n_layers) ** 0.5).astype(dtype),
    }


def _capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = int(group_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(4, -(-c // 4) * 4)


def moe_dispatch(cfg: ModelConfig, router_w: jax.Array, x: jax.Array):
    """x: [G, S, D] -> (dispatch [G,S,E,C], combine [G,S,E,C], aux_loss)."""
    G, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    C = _capacity(cfg, S)
    logits = (x.astype(jnp.float32) @ router_w)  # [G,S,E]
    gates = jax.nn.softmax(logits, axis=-1)

    # iterative top-k with per-k expert one-hots
    g = gates
    sel_gate, sel_onehot = [], []
    for _ in range(K):
        idx = jnp.argmax(g, axis=-1)  # [G,S]
        oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # [G,S,E]
        sel_gate.append((g * oh).sum(-1))
        sel_onehot.append(oh)
        g = g * (1.0 - oh)

    # capacity positions: priority by (k, token) — earlier k first.
    dispatch = jnp.zeros((G, S, E, C), jnp.float32)
    combine = jnp.zeros((G, S, E, C), jnp.float32)
    gate_sum = sum(sel_gate)
    counts = jnp.zeros((G, E), jnp.float32)
    for k in range(K):
        oh = sel_onehot[k]  # [G,S,E]
        pos_in_e = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]  # [G,S,E]
        counts = counts + oh.sum(axis=1)
        keep = (pos_in_e < C) * oh  # [G,S,E]
        pos = (pos_in_e * keep).sum(-1)  # [G,S] (0 when dropped)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)  # [G,S,C]
        d_k = keep[..., None] * pos_oh[:, :, None, :]  # [G,S,E,C]
        dispatch = dispatch + d_k
        gate_k = sel_gate[k] / jnp.maximum(gate_sum, 1e-9)  # renormalized
        combine = combine + d_k * gate_k[..., None, None]

    # load-balance auxiliary loss (Switch): E * sum_e f_e * p_e
    me = gates.mean(axis=1)  # [G,E] mean router prob
    ce = sel_onehot[0].mean(axis=1)  # [G,E] fraction routed (top-1 proxy)
    aux = (E * (me * ce).sum(-1)).mean()
    return dispatch, combine, aux


def moe_ffn_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B, L, D] -> (y, aux_loss).

    Sharding choreography (EXPERIMENTS.md §Perf, llama4 iter 4): token
    groups enter data-sharded on G; the dispatch einsum's output is
    constrained to E->data / G-released, which GSPMD lowers to a
    token-sized all-to-all over the data axis (expert parallelism on the
    token axis).  Expert matmuls then run with weights IN PLACE
    (E->data, F->model), and the combine einsum all-to-alls results
    back.  Without these hints GSPMD all-gathers the multi-GB expert
    bank once per layer instead."""
    from repro.models.common import shard_hint

    B, L, D = x.shape
    T = B * L
    S = min(cfg.moe_group_size, T)
    G = T // S
    assert G * S == T, f"tokens {T} not divisible by group {S}"
    xg = shard_hint(x.reshape(G, S, D), AX_DATA, None, None)
    dispatch, combine, aux = moe_dispatch(cfg, p["router"]["w"], xg)
    dtype = x.dtype
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch.astype(dtype), xg)
    # local compute (g->data), THEN reshard the same tensor to e->data:
    # the sharding transition lowers to a token-sized all-to-all.
    expert_in = shard_hint(expert_in, AX_DATA, None, None, None)
    expert_in = shard_hint(expert_in, None, AX_DATA, None, None)  # a2a g->e
    a = jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])
    b = jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    h = glu_activation(cfg.activation, shard_hint(a, None, AX_DATA, None, AX_MODEL),
                       shard_hint(b, None, AX_DATA, None, AX_MODEL))
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    expert_out = shard_hint(expert_out, None, AX_DATA, None, None)
    expert_out = shard_hint(expert_out, AX_DATA, None, None, None)  # a2a e->g
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(dtype), expert_out)  # local
    y = shard_hint(y, AX_DATA, None, None)
    return y.reshape(B, L, D), aux


def init_moe_block(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_attn(k1, cfg, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "moe": init_moe_layer(k2, cfg, dtype),
    }


def moe_block_apply(cfg, p, x, positions):
    if cfg.parallel_block:
        a = attn_apply_train(cfg, p["attn"], rmsnorm(p["attn_norm"], x, cfg.norm_eps), positions)
        y, aux = moe_ffn_apply(cfg, p["moe"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
        return x + a + y, aux
    x = x + attn_apply_train(cfg, p["attn"], rmsnorm(p["attn_norm"], x, cfg.norm_eps), positions)
    y, aux = moe_ffn_apply(cfg, p["moe"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x + y, aux


def moe_block_decode(cfg, p, x1, cache_k, cache_v, pos):
    a, ck, cv = attn_apply_decode(cfg, p["attn"], rmsnorm(p["attn_norm"], x1, cfg.norm_eps), cache_k, cache_v, pos)
    x1 = x1 + a
    y, _ = moe_ffn_apply(cfg, p["moe"], rmsnorm(p["mlp_norm"], x1, cfg.norm_eps))
    return x1 + y, ck, cv


# ------------------------------------------------------------- full model ---


def _n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.moe_every == 0
    return cfg.n_layers // cfg.moe_every


def init_moe_model(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_moe, k_dense, k_head = jax.random.split(key, 4)
    ng = _n_groups(cfg)
    moe_blocks = jax.vmap(lambda k: init_moe_block(k, cfg, dtype))(jax.random.split(k_moe, ng))
    params = {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "moe_blocks": moe_blocks,
        "final_norm": init_rmsnorm(cfg.d_model),
        "lm_head": init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype),
    }
    n_dense_per_group = cfg.moe_every - 1
    if n_dense_per_group:
        dkeys = jax.random.split(k_dense, ng * n_dense_per_group).reshape(ng, n_dense_per_group, 2)
        params["dense_blocks"] = jax.vmap(
            jax.vmap(lambda k: init_dense_block(k, cfg, dtype))
        )(dkeys)
    return params


def forward_hidden_moe(cfg: ModelConfig, params: Params, x: jax.Array, positions: jax.Array):
    has_dense = "dense_blocks" in params
    n_dense = cfg.moe_every - 1

    def body(carry, group):
        h, aux = carry
        if has_dense:
            p_moe, p_dense = group
            for i in range(n_dense):
                pd_i = jax.tree.map(lambda a: a[i], p_dense)
                h = dense_block_apply(cfg, pd_i, h, positions)
        else:
            p_moe = group
        h, a = moe_block_apply(cfg, p_moe, h, positions)
        return (h, aux + a), None

    from repro.models.common import maybe_remat

    body = maybe_remat(body, cfg)
    xs = (params["moe_blocks"], params["dense_blocks"]) if has_dense else params["moe_blocks"]
    (h, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    return rmsnorm(params["final_norm"], h, cfg.norm_eps), aux / _n_groups(cfg)


def moe_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    B, L = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    h, aux = forward_hidden_moe(cfg, params, x, positions)
    ce = chunked_softmax_xent(h, _lm_head_w(cfg, params), labels, chunk=cfg.logits_chunk)
    return ce + cfg.router_aux_weight * aux


def moe_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dh = cfg.resolved_head_dim
    dt = dtype_of(cfg.dtype)
    ng, nd = _n_groups(cfg), cfg.moe_every - 1
    cache = {
        "moe_k": jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, dh), dt),
        "moe_v": jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, dh), dt),
    }
    if nd:
        cache["dense_k"] = jnp.zeros((ng, nd, batch, max_len, cfg.n_kv_heads, dh), dt)
        cache["dense_v"] = jnp.zeros((ng, nd, batch, max_len, cfg.n_kv_heads, dh), dt)
    return cache


def moe_decode_step(cfg: ModelConfig, params: Params, token: jax.Array, cache: Params, pos: jax.Array):
    has_dense = "dense_blocks" in params
    n_dense = cfg.moe_every - 1
    x1 = embed(params["embed"], token)[:, None, :]

    def body(h, layer_in):
        if has_dense:
            p_moe, p_dense, mk, mv, dk, dv = layer_in
            new_dk, new_dv = [], []
            for i in range(n_dense):
                pd_i = jax.tree.map(lambda a: a[i], p_dense)
                h, ck, cv = dense_block_decode(cfg, pd_i, h, dk[i], dv[i], pos)
                new_dk.append(ck)
                new_dv.append(cv)
            h, mk, mv = moe_block_decode(cfg, p_moe, h, mk, mv, pos)
            return h, (mk, mv, jnp.stack(new_dk), jnp.stack(new_dv))
        else:
            p_moe, mk, mv = layer_in
            h, mk, mv = moe_block_decode(cfg, p_moe, h, mk, mv, pos)
            return h, (mk, mv)

    if has_dense:
        xs = (params["moe_blocks"], params["dense_blocks"], cache["moe_k"], cache["moe_v"], cache["dense_k"], cache["dense_v"])
        h, (mk, mv, dk, dv) = jax.lax.scan(body, x1, xs)
        new_cache = {"moe_k": mk, "moe_v": mv, "dense_k": dk, "dense_v": dv}
    else:
        xs = (params["moe_blocks"], cache["moe_k"], cache["moe_v"])
        h, (mk, mv) = jax.lax.scan(body, x1, xs)
        new_cache = {"moe_k": mk, "moe_v": mv}
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ _lm_head_w(cfg, params)).astype(jnp.float32)
    return logits, new_cache


# --------------------------------------------------------------- shardings --


def moe_param_specs(cfg: ModelConfig, mode: str = "train") -> Params:
    # 2D expert sharding in BOTH modes: experts -> DATA axis (expert
    # parallelism on the same axis tokens are sharded on, so dispatch
    # lowers to token-sized all-to-alls), d_ff -> model axis (TP within
    # each expert).  Weights stay put and tokens move — the naive
    # experts-FSDP-over-data layout all-gathered ~12 GB of expert weights
    # per layer-group per device (EXPERIMENTS.md §Perf llama4 iter 1-3).
    moe = {
        "router": {"w": P(None, None)},
        "w_gate": P(AX_DATA, None, AX_MODEL),
        "w_up": P(AX_DATA, None, AX_MODEL),
        "w_down": P(AX_DATA, AX_MODEL, None),
    }
    from repro.models.transformer import _attn_specs

    moe_block = {
        "attn_norm": {"scale": P(None)},
        "attn": _attn_specs(),
        "mlp_norm": {"scale": P(None)},
        "moe": moe,
    }
    specs = {
        "embed": {"emb": P(AX_MODEL, AX_DATA)},
        "moe_blocks": _stack(moe_block),
        "final_norm": {"scale": P(None)},
        "lm_head": {"w": P(AX_DATA, AX_MODEL)},
    }
    if cfg.moe_every > 1:
        dense_block = dense_param_specs(cfg, mode)["blocks"]  # already stacked once
        specs["dense_blocks"] = jax.tree.map(
            lambda s: P(None, *s), dense_block, is_leaf=lambda x: isinstance(x, P)
        )
    return specs


def moe_cache_specs(cfg: ModelConfig, seq_shard: bool = False) -> Params:
    from repro.models.transformer import kv_cache_spec

    spec = kv_cache_spec(cfg, seq_shard)
    out = {"moe_k": spec, "moe_v": spec}
    if cfg.moe_every > 1:
        dspec = kv_cache_spec(cfg, seq_shard, extra_lead=1)
        out["dense_k"] = dspec
        out["dense_v"] = dspec
    return out

"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

The audio conv frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings [B, T_frames, d_model].  The
encoder runs bidirectional self-attention over frames; the decoder is
causal self-attention + cross-attention to the encoder output.  Plain
(non-gated) GELU MLPs, per the Whisper architecture.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (
    AX_DATA,
    AX_MODEL,
    apply_rope,
    chunked_softmax_xent,
    decode_attention,
    dtype_of,
    embed,
    flash_attention,
    init_embedding,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
)
from repro.models.config import ModelConfig
from repro.models.transformer import _stack, init_attn

Params = Dict[str, Any]


def init_gelu_mlp(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w1": init_linear(k1, cfg.d_model, cfg.d_ff, dtype),
        "w2": init_linear(k2, cfg.d_ff, cfg.d_model, dtype, scale=0.02 / max(1, 2 * cfg.n_layers) ** 0.5),
    }


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    h = linear(p["w1"], x)
    return linear(p["w2"], jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype))


def init_cross_attn(key, cfg: ModelConfig, dtype) -> Params:
    return init_attn(key, cfg, dtype)  # same shapes: wq, wk, wv, wo


def _mha(cfg: ModelConfig, p: Params, xq, xkv, causal: bool, rope: bool):
    B, Lq, D = xq.shape
    dh = cfg.resolved_head_dim
    q = linear(p["wq"], xq).reshape(B, Lq, cfg.n_heads, dh)
    k = linear(p["wk"], xkv).reshape(B, xkv.shape[1], cfg.n_kv_heads, dh)
    v = linear(p["wv"], xkv).reshape(B, xkv.shape[1], cfg.n_kv_heads, dh)
    if rope:
        posq = jnp.broadcast_to(jnp.arange(Lq)[None], (B, Lq))
        posk = jnp.broadcast_to(jnp.arange(xkv.shape[1])[None], (B, xkv.shape[1]))
        q = apply_rope(q, posq, cfg.rope_theta)
        k = apply_rope(k, posk, cfg.rope_theta)
    o = flash_attention(q, k, v, causal=causal, q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    return linear(p["wo"], o.reshape(B, Lq, cfg.n_heads * dh))


def init_enc_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_attn(k1, cfg, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_gelu_mlp(k2, cfg, dtype),
    }


def init_dec_block(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": init_rmsnorm(cfg.d_model),
        "self_attn": init_attn(k1, cfg, dtype),
        "cross_norm": init_rmsnorm(cfg.d_model),
        "cross_attn": init_cross_attn(k2, cfg, dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_gelu_mlp(k3, cfg, dtype),
    }


def init_encdec_model(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    ke, kd, kemb = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: init_enc_block(k, cfg, dtype))(jax.random.split(ke, cfg.n_encoder_layers))
    dec = jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(jax.random.split(kd, cfg.n_layers))
    return {
        "embed": init_embedding(kemb, cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": enc,
        "dec_blocks": dec,
        "enc_norm": init_rmsnorm(cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: [B, T_enc, D] (stub frontend output) -> encoder states."""

    def body(h, p):
        h = h + _mha(cfg, p["attn"], rmsnorm(p["attn_norm"], h, cfg.norm_eps),
                     rmsnorm(p["attn_norm"], h, cfg.norm_eps), causal=False, rope=True)
        h = h + gelu_mlp(p["mlp"], rmsnorm(p["mlp_norm"], h, cfg.norm_eps))
        return h, None

    from repro.models.common import maybe_remat

    body = maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, frames, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def decoder_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array, enc: jax.Array) -> jax.Array:
    x = embed(params["embed"], tokens)

    def body(h, p):
        h = h + _mha(cfg, p["self_attn"], rmsnorm(p["self_norm"], h, cfg.norm_eps),
                     rmsnorm(p["self_norm"], h, cfg.norm_eps), causal=True, rope=True)
        h = h + _mha(cfg, p["cross_attn"], rmsnorm(p["cross_norm"], h, cfg.norm_eps),
                     enc, causal=False, rope=False)
        h = h + gelu_mlp(p["mlp"], rmsnorm(p["mlp_norm"], h, cfg.norm_eps))
        return h, None

    from repro.models.common import maybe_remat

    body = maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return rmsnorm(params["final_norm"], h, cfg.norm_eps)


def encdec_loss(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
    enc = encode(cfg, params, frames)
    h = decoder_hidden(cfg, params, tokens, enc)
    return chunked_softmax_xent(h, params["embed"]["emb"].T, labels, chunk=cfg.logits_chunk)


def encdec_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dh = cfg.resolved_head_dim
    dt = dtype_of(cfg.dtype)
    nl = cfg.n_layers
    return {
        "k": jnp.zeros((nl, batch, max_len, cfg.n_kv_heads, dh), dt),
        "v": jnp.zeros((nl, batch, max_len, cfg.n_kv_heads, dh), dt),
        # cross K/V precomputed from encoder output at prefill time
        "xk": jnp.zeros((nl, batch, cfg.encoder_seq, cfg.n_kv_heads, dh), dt),
        "xv": jnp.zeros((nl, batch, cfg.encoder_seq, cfg.n_kv_heads, dh), dt),
    }


def encdec_prefill_cross(cfg: ModelConfig, params: Params, enc: jax.Array, cache: Params) -> Params:
    """Compute per-decoder-layer cross K/V from encoder states."""
    B, T, D = enc.shape
    dh = cfg.resolved_head_dim

    def per_layer(p):
        k = linear(p["cross_attn"]["wk"], enc).reshape(B, T, cfg.n_kv_heads, dh)
        v = linear(p["cross_attn"]["wv"], enc).reshape(B, T, cfg.n_kv_heads, dh)
        return k, v

    xk, xv = jax.vmap(per_layer)(params["dec_blocks"])
    return dict(cache, xk=xk.astype(cache["xk"].dtype), xv=xv.astype(cache["xv"].dtype))


def encdec_decode_step(cfg: ModelConfig, params: Params, token: jax.Array, cache: Params, pos: jax.Array):
    B = token.shape[0]
    dh = cfg.resolved_head_dim
    x1 = embed(params["embed"], token)[:, None, :]

    def body(h, layer_in):
        p, ck, cv, xk, xv = layer_in
        # causal self-attention against the cache
        hn = rmsnorm(p["self_norm"], h, cfg.norm_eps)
        q = linear(p["self_attn"]["wq"], hn).reshape(B, 1, cfg.n_heads, dh)
        k = linear(p["self_attn"]["wk"], hn).reshape(B, 1, cfg.n_kv_heads, dh)
        v = linear(p["self_attn"]["wv"], hn).reshape(B, 1, cfg.n_kv_heads, dh)
        pos_b = jnp.broadcast_to(pos[None] if pos.ndim == 0 else pos, (B, 1))
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos, axis=1)
        o = decode_attention(q, ck, cv, pos)
        h = h + linear(p["self_attn"]["wo"], o.reshape(B, 1, cfg.n_heads * dh))
        # cross-attention against precomputed encoder K/V (full visibility)
        hn = rmsnorm(p["cross_norm"], h, cfg.norm_eps)
        q = linear(p["cross_attn"]["wq"], hn).reshape(B, 1, cfg.n_heads, dh)
        o = decode_attention(q, xk, xv, jnp.int32(xk.shape[1] - 1))
        h = h + linear(p["cross_attn"]["wo"], o.reshape(B, 1, cfg.n_heads * dh))
        h = h + gelu_mlp(p["mlp"], rmsnorm(p["mlp_norm"], h, cfg.norm_eps))
        return h, (ck, cv)

    xs = (params["dec_blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    h, (ck, cv) = jax.lax.scan(body, x1, xs)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["embed"]["emb"].T).astype(jnp.float32)
    return logits, dict(cache, k=ck, v=cv)


def encdec_param_specs(cfg: ModelConfig, mode: str = "train") -> Params:
    from repro.models.transformer import _attn_specs, replicate_specs

    mlp = {"w1": {"w": P(AX_DATA, AX_MODEL)}, "w2": {"w": P(AX_MODEL, AX_DATA)}}
    enc_block = {
        "attn_norm": {"scale": P(None)},
        "attn": _attn_specs(),
        "mlp_norm": {"scale": P(None)},
        "mlp": mlp,
    }
    dec_block = {
        "self_norm": {"scale": P(None)},
        "self_attn": _attn_specs(),
        "cross_norm": {"scale": P(None)},
        "cross_attn": _attn_specs(),
        "mlp_norm": {"scale": P(None)},
        "mlp": mlp,
    }
    specs = {
        "embed": {"emb": P(AX_MODEL, AX_DATA)},
        "enc_blocks": _stack(enc_block),
        "dec_blocks": _stack(dec_block),
        "enc_norm": {"scale": P(None)},
        "final_norm": {"scale": P(None)},
    }
    if cfg.fsdp_all_axes and mode == "train":
        return replicate_specs(specs)
    return specs


def encdec_cache_specs(cfg: ModelConfig, seq_shard: bool = False) -> Params:
    from repro.models.transformer import kv_cache_spec

    spec = kv_cache_spec(cfg, seq_shard)
    # cross K/V has encoder_seq (1500) length: dryrun's fitted_shardings
    # drops non-divisible axes automatically
    return {"k": spec, "v": spec, "xk": spec, "xv": spec}

"""Shared JAX building blocks: norms, RoPE, GQA flash attention, losses.

Everything is functional: parameters are plain dict pytrees created by
``init_*`` helpers, applied by pure functions.  Compute runs in the
config dtype (bf16 by default) with f32 accumulations where it matters
(norm statistics, softmax, losses, RoPE phases).

The attention here is a chunked online-softmax ("flash") implementation
built from ``lax.scan`` so that 32k-token prefill compiles with bounded
memory on the production mesh; ``naive_attention`` is the test oracle.
A Pallas TPU kernel for the decode path lives in ``repro.kernels``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

# Logical sharding axes used across the model zoo; `mesh_rules` maps them
# onto physical mesh axes (see repro.launch.mesh).
AX_DATA = ("pod", "data")  # batch / fsdp axis
AX_MODEL = "model"  # tensor-parallel axis


def shard_hint(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint against the ambient mesh, dropping axis
    names the mesh does not have (so the same hint serves the single-pod
    and multi-pod meshes) — no-op outside a mesh context."""
    try:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            return x
        names = set(mesh.axis_names)
    except Exception:
        return x
    from jax.sharding import PartitionSpec as _P

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _fit(entry, dim):
        if entry is None:
            return None
        axes = [entry] if isinstance(entry, str) else list(entry)
        axes = [a for a in axes if a in names]
        while axes:
            n = 1
            for a in axes:
                n *= sizes[a]
            if dim % n == 0:
                break
            axes.pop()
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else tuple(axes)

    out = [_fit(e, x.shape[d]) for d, e in enumerate(entries)]
    return jax.lax.with_sharding_constraint(x, _P(*out))


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


def maybe_remat(body, cfg):
    """Wrap a scan body per the config's activation-checkpoint policy.

    ``full``: recompute everything in the backward pass (lowest memory,
    +1 forward of recompute FLOPs).  ``dots``: save matmul outputs with
    no batch dims (weight-stationary dots) — trades memory for ~4/3 x
    fewer computed FLOPs (EXPERIMENTS §Perf remat iteration).  ``none``:
    no checkpointing (save all residuals)."""
    if not cfg.remat or cfg.remat_policy == "none":
        return body
    policy = (
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        if cfg.remat_policy == "dots"
        else None
    )
    return jax.checkpoint(body, prevent_cse=False, policy=policy)


# ------------------------------------------------------------------ norms ---


def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


# ------------------------------------------------------------------- rope ---


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., L, H, Dh]; positions: [..., L] (int)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [Dh/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., L, 1, Dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention ---


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B, Lq, Hkv, G, Dh]; k: [B, Lk, Hkv, Dh] -> [B, Hkv, G, Lq, Lk]."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_offset: int = 0,
) -> jax.Array:
    """Oracle attention. q: [B, Lq, H, Dh], k/v: [B, Lk, Hkv, Dh]."""
    B, Lq, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = float(1.0 / np.sqrt(Dh))  # Python float: weak type, dtype-stable under x64
    qg = q.reshape(B, Lq, Hkv, G, Dh)
    s = _gqa_scores(qg, k) * scale  # [B, Hkv, G, Lq, Lk]
    if causal:
        qpos = jnp.arange(Lq) + q_offset
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Lq, H, Dh)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jax.Array:
    """Chunked online-softmax attention with GQA, bounded memory.

    q: [B, Lq, H, Dh]; k/v: [B, Lk, Hkv, Dh].  Non-chunk-divisible
    lengths are padded internally (padded key positions are masked out;
    padded query rows are sliced off)."""
    B, Lq0, H, Dh = q.shape
    _, Lk0, Hkv, _ = k.shape
    G = H // Hkv
    q_chunk = min(q_chunk, Lq0)
    k_chunk = min(k_chunk, Lk0)
    pad_q = (-Lq0) % q_chunk
    pad_k = (-Lk0) % k_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Lq, Lk = Lq0 + pad_q, Lk0 + pad_k
    nq, nk = Lq // q_chunk, Lk // k_chunk
    scale = float(1.0 / np.sqrt(Dh))  # Python float: weak type, dtype-stable under x64

    qg = q.reshape(B, nq, q_chunk, Hkv, G, Dh).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, k_chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, k_chunk, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(Lk).reshape(nk, k_chunk)

    def per_q_chunk(qi, q_blk):
        # q_blk: [B, qc, Hkv, G, Dh]
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def body(carry, inputs):
            m, l, acc = carry
            k_blk, v_blk, kp = inputs
            s = _gqa_scores(q_blk, k_blk) * scale  # [B,Hkv,G,qc,kc] f32
            mask = kp[None, :] < Lk0  # padded keys invisible
            if causal:
                mask = mask & (qpos[:, None] >= kp[None, :])
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, kpos))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 3, 1, 2, 4)  # [B, qc, Hkv, G, Dh]

    out = jax.lax.map(lambda args: per_q_chunk(*args), (jnp.arange(nq), qg))
    # [nq, B, qc, Hkv, G, Dh] -> [B, Lq, H, Dh]
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Lq, H, Dh)
    if pad_q:
        out = out[:, :Lq0]
    return out.astype(q.dtype)


def decode_attention(
    q1: jax.Array,  # [B, 1, H, Dh] — the new token's query
    cache_k: jax.Array,  # [B, L, Hkv, Dh]
    cache_v: jax.Array,
    pos: jax.Array,  # [] int — index of the new token in the cache
) -> jax.Array:
    B, L, Hkv, Dh = cache_k.shape
    H = q1.shape[2]
    G = H // Hkv
    scale = float(1.0 / np.sqrt(Dh))  # Python float: weak type, dtype-stable under x64
    qg = q1.reshape(B, 1, Hkv, G, Dh)
    s = _gqa_scores(qg, cache_k) * scale  # [B, Hkv, G, 1, L]
    mask = jnp.arange(L) <= pos
    s = jnp.where(mask[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cache_v.dtype), cache_v)
    return o.reshape(B, 1, H, Dh)


# ------------------------------------------------------------------ dense ---


def init_linear(key, d_in: int, d_out: int, dtype, scale: float = 0.02) -> Params:
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}


def linear(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["w"]


def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"emb": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["emb"], tokens, axis=0)


# ------------------------------------------------------------------- loss ---


def chunked_softmax_xent(
    hidden: jax.Array,  # [B, L, D]
    w_out: jax.Array,  # [D, V]
    labels: jax.Array,  # [B, L] int32
    mask: Optional[jax.Array] = None,  # [B, L]
    chunk: int = 1024,
) -> jax.Array:
    """Mean cross-entropy computed over sequence chunks so the full
    [B, L, V] logits tensor is never materialized."""
    B, L, D = hidden.shape
    chunk = min(chunk, L)
    n = L // chunk
    body = n * chunk
    hs = hidden[:, :body].reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ys = labels[:, :body].reshape(B, n, chunk).transpose(1, 0, 2)
    ms = (
        mask[:, :body].reshape(B, n, chunk).transpose(1, 0, 2)
        if mask is not None
        else jnp.ones((n, B, chunk), jnp.float32)
    )

    def body(carry, inputs):
        tot, cnt = carry
        h, y, m = inputs
        logits = (h @ w_out).astype(jnp.float32)  # [B, chunk, V]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (tot + nll.sum(), cnt + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ys, ms))
    rem = L - n * chunk
    if rem:  # tail (static)
        h, y = hidden[:, n * chunk :], labels[:, n * chunk :]
        logits = (h @ w_out).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        m = mask[:, n * chunk :] if mask is not None else jnp.ones_like(lse)
        tot = tot + ((lse - gold) * m).sum()
        cnt = cnt + m.sum()
    return tot / jnp.maximum(cnt, 1.0)


# ------------------------------------------------------------- activations --


def glu_activation(kind: str, a: jax.Array, b: jax.Array) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(a.astype(jnp.float32)).astype(a.dtype) * b
    if kind == "geglu":
        return jax.nn.gelu(a.astype(jnp.float32), approximate=True).astype(a.dtype) * b
    raise ValueError(kind)

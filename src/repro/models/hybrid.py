"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block
applied every ``hybrid_attn_every`` mamba blocks. [arXiv:2411.15242]

The shared block's weights are reused at every application site (Zamba's
parameter-sharing trick), but each site keeps its own KV cache.  The
stack is scanned over groups of ``hybrid_attn_every`` mamba blocks with
the shared attention applied at the head of each group.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import (
    AX_DATA,
    AX_MODEL,
    chunked_softmax_xent,
    dtype_of,
    embed,
    init_embedding,
    init_linear,
    init_rmsnorm,
    rmsnorm,
)
from repro.models.config import ModelConfig
from repro.models.mamba2 import (
    init_mamba_block,
    mamba_block_apply,
    mamba_block_decode,
    mamba_init_state,
    ssm_param_specs,
)
from repro.models.transformer import (
    _attn_specs,
    _mlp_specs,
    _stack,
    dense_block_apply,
    dense_block_decode,
    init_dense_block,
)

Params = Dict[str, Any]


def _n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.hybrid_attn_every == 0
    return cfg.n_layers // cfg.hybrid_attn_every


def init_hybrid_model(key, cfg: ModelConfig) -> Params:
    dtype = dtype_of(cfg.dtype)
    k_emb, k_m, k_a = jax.random.split(key, 3)
    ng, per = _n_groups(cfg), cfg.hybrid_attn_every
    mkeys = jax.random.split(k_m, cfg.n_layers).reshape(ng, per, 2)
    mamba = jax.vmap(jax.vmap(lambda k: init_mamba_block(k, cfg, dtype)))(mkeys)
    return {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "mamba_blocks": mamba,  # [ng, per, ...]
        "shared_attn": init_dense_block(k_a, cfg, dtype),  # ONE set of weights
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def hybrid_loss(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    tokens, labels = batch["tokens"], batch["labels"]
    B, L = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    shared = params["shared_attn"]
    per = cfg.hybrid_attn_every

    def body(h, p_group):
        h = dense_block_apply(cfg, shared, h, positions)  # shared weights
        for i in range(per):
            pb = jax.tree.map(lambda a: a[i], p_group)
            h = mamba_block_apply(cfg, pb, h)
        return h, None

    from repro.models.common import maybe_remat

    body = maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, x, params["mamba_blocks"])
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return chunked_softmax_xent(h, params["embed"]["emb"].T, labels, chunk=cfg.logits_chunk)


def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    ng, per = _n_groups(cfg), cfg.hybrid_attn_every
    dh = cfg.resolved_head_dim
    dt = dtype_of(cfg.dtype)
    m = mamba_init_state(cfg, batch)
    return {
        "attn_k": jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, dh), dt),
        "attn_v": jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, dh), dt),
        "conv": jnp.broadcast_to(m["conv"][None, None], (ng, per, *m["conv"].shape)),
        "ssm": jnp.broadcast_to(m["ssm"][None, None], (ng, per, *m["ssm"].shape)),
    }


def hybrid_decode_step(cfg: ModelConfig, params: Params, token: jax.Array, cache: Params, pos: jax.Array):
    x1 = embed(params["embed"], token)[:, None, :]
    shared = params["shared_attn"]
    per = cfg.hybrid_attn_every

    def body(h, layer_in):
        p_group, ak, av, conv_s, ssm_s = layer_in
        h, ak, av = dense_block_decode(cfg, shared, h, ak, av, pos)
        new_conv, new_ssm = [], []
        for i in range(per):
            pb = jax.tree.map(lambda a: a[i], p_group)
            h, st = mamba_block_decode(cfg, pb, h, {"conv": conv_s[i], "ssm": ssm_s[i]})
            new_conv.append(st["conv"])
            new_ssm.append(st["ssm"])
        return h, (ak, av, jnp.stack(new_conv), jnp.stack(new_ssm))

    xs = (params["mamba_blocks"], cache["attn_k"], cache["attn_v"], cache["conv"], cache["ssm"])
    h, (ak, av, conv_s, ssm_s) = jax.lax.scan(body, x1, xs)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = (h[:, 0, :] @ params["embed"]["emb"].T).astype(jnp.float32)
    return logits, {"attn_k": ak, "attn_v": av, "conv": conv_s, "ssm": ssm_s}


def hybrid_param_specs(cfg: ModelConfig, mode: str = "train") -> Params:
    from repro.models.transformer import replicate_specs

    mamba_block = ssm_param_specs(cfg, mode)["blocks"]  # stacked once
    specs = _hybrid_specs_inner(cfg, mamba_block)
    if cfg.fsdp_all_axes and mode == "train":
        return replicate_specs(specs)
    return specs


def _hybrid_specs_inner(cfg: ModelConfig, mamba_block) -> Params:
    return {
        "embed": {"emb": P(AX_MODEL, AX_DATA)},
        "mamba_blocks": jax.tree.map(lambda s: P(None, *s), mamba_block, is_leaf=lambda x: isinstance(x, P)),
        "shared_attn": {
            "attn_norm": {"scale": P(None)},
            "attn": _attn_specs(),
            "mlp_norm": {"scale": P(None)},
            "mlp": _mlp_specs(),
        },
        "final_norm": {"scale": P(None)},
    }


def hybrid_cache_specs(cfg: ModelConfig, seq_shard: bool = False) -> Params:
    from repro.models.transformer import kv_cache_spec

    attn = kv_cache_spec(cfg, seq_shard)
    bdim = None if seq_shard else AX_DATA
    return {
        "attn_k": attn,
        "attn_v": attn,
        "conv": P(None, None, bdim, None, AX_MODEL),
        "ssm": P(None, None, bdim, AX_MODEL, None, None),
    }

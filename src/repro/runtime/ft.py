"""Fault tolerance & elasticity for the training loop.

Mechanisms (designed for 1000+ node fleets, exercised here single-host):

* **Checkpoint/restart** — periodic atomic checkpoints (see
  ``repro.checkpoint.ckpt``); on startup the supervisor resumes from the
  newest COMMITTED step.  Because the data pipeline is a pure function of
  (seed, step), restart reproduces the exact batch sequence.
* **Preemption safety** — SIGTERM triggers a final checkpoint before
  exit (maintenance events on cloud TPU pods send SIGTERM).
* **Bad-step quarantine** — a non-finite loss/grad-norm rolls back to the
  last checkpoint and *skips* the offending data step (data-induced
  divergence is the common cause at scale; skipping is the standard
  mitigation).
* **Straggler detection** — per-step wall times feed an EWMA; steps
  slower than ``straggler_factor`` x the running median raise an event.
  On a real fleet the action is to exclude/replace the slow host and
  elastically re-mesh; here the policy object records events and the
  elastic path is exercised by re-sharding a checkpoint onto a different
  mesh (``elastic_remesh``), which tests/test_ft.py covers.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 32
    times: List[float] = dataclasses.field(default_factory=list)
    events: List[Tuple[int, float, float]] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        med = float(np.median(self.times[-self.window :])) if self.times else dt
        self.times.append(dt)
        if len(self.times) >= 8 and dt > self.factor * med:
            self.events.append((step, dt, med))
            return True
        return False


@dataclasses.dataclass
class Supervisor:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    straggler: StragglerMonitor = dataclasses.field(default_factory=StragglerMonitor)
    _last_good: Optional[int] = None
    _term_requested: bool = False

    def install_signal_handler(self) -> None:
        def _on_term(signum, frame):
            self._term_requested = True

        signal.signal(signal.SIGTERM, _on_term)

    # ---- resume ------------------------------------------------------------
    def resume_step(self) -> Optional[int]:
        return ckpt_lib.latest_step(self.ckpt_dir)

    def restore(self, step: int, like, shardings=None):
        self._last_good = step
        return ckpt_lib.restore(self.ckpt_dir, step, like, shardings)

    # ---- per-step bookkeeping ----------------------------------------------
    def checkpoint(self, step: int, state) -> None:
        ckpt_lib.save(self.ckpt_dir, step, state)
        self._last_good = step
        self._gc()

    def _gc(self) -> None:
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and os.path.exists(os.path.join(self.ckpt_dir, d, "COMMITTED"))
        )
        import shutil

        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"), ignore_errors=True)

    def on_step(
        self, step: int, dt: float, metrics: Dict[str, Any], state
    ) -> Tuple[str, Optional[int]]:
        """Returns (action, rollback_step). Actions: 'ok' | 'rollback' |
        'checkpoint_and_exit'."""
        if self._term_requested:
            self.checkpoint(step, state)
            return "checkpoint_and_exit", None
        loss = float(metrics.get("loss", 0.0))
        gnorm = float(metrics.get("grad_norm", 0.0))
        if not (np.isfinite(loss) and np.isfinite(gnorm)):
            return "rollback", self._last_good
        self.straggler.observe(step, dt)
        if self.ckpt_every and step > 0 and step % self.ckpt_every == 0:
            self.checkpoint(step, state)
        return "ok", None


def elastic_remesh(ckpt_dir: str, step: int, like, new_mesh, spec_tree):
    """Restore a checkpoint onto a DIFFERENT mesh (scale up/down): the
    checkpoint stores full (unsharded) arrays, so resharding is just
    device_put with the new mesh's NamedShardings."""
    from repro.launch.mesh import fitted_shardings

    shardings = fitted_shardings(spec_tree, like, new_mesh)
    return ckpt_lib.restore(ckpt_dir, step, like, shardings)

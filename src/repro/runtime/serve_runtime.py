"""Terastal as a first-class LM serving controller.

Maps the paper's abstractions onto a TPU pod (DESIGN.md §3):

* **Heterogeneous accelerators**  -> mesh *partitions* of different TP
  width (e.g. one tp=16 slice + two tp=4 slices carved from a pod).  A
  wide slice is the "preferred accelerator" for big-model decode steps
  (more FLOPs/HBM per step) while narrow slices serve small models with
  less collective overhead — the same preferred/non-preferred latency
  structure Terastal exploits, with per-(model, partition) step
  latencies derived from the analytic roofline
  (``repro.launch.analytics``).
* **Layers** -> token *chunks*: generating T tokens is a chain of T/K
  non-preemptive chunk jobs, schedulable on different partitions at
  chunk boundaries (KV migration rides the shared pod interconnect; its
  cost is charged into the latency table).
* **Layer variants** -> shape-preserving reduced blocks (d_ff / gamma^2)
  with latency scaled by the active-FLOP ratio and accuracy loss from
  the calibrated proxy — exactly the paper's variant trade, generalized
  to transformer blocks.

Offline: Algorithm 1 decomposes each request deadline into chunk
budgets and selects which models get block variants.  Online:
Algorithm 2 (the *same* scheduler class as the faithful reproduction)
maps chunk jobs to partitions.  The event-driven simulator provides the
serving-loop clock, so FCFS/EDF/DREAM/Terastal are directly comparable
on LM traffic (see examples/lm_serve_terastal.py and benchmarks).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.budget import distribute_budgets
from repro.core.scheduler import Scheduler, make_scheduler
from repro.core.simulator import (
    ArrivalProcess,
    SimResult,
    TaskSpec,
    make_arrival_process,
    simulate,
)
from repro.core.variants import ModelPlan, VariantInfo
from repro.costmodel.dnn_zoo import DnnModel
from repro.costmodel.layers import matmul
from repro.costmodel.maestro import Accelerator, Dataflow, Platform
from repro.launch.analytics import HBM_BW, ICI_BW, PEAK_FLOPS, active_params, cache_bytes
from repro.models.config import ModelConfig
from repro.models.model_api import SHAPES, ShapeSpec


@dataclasses.dataclass(frozen=True)
class MeshPartition:
    name: str
    n_chips: int
    # effective per-step efficiency: wide slices lose more to collectives
    collective_overhead_s: float = 3e-5


def default_partitions() -> Tuple[MeshPartition, ...]:
    """One 16x16 pod carved into 1 wide + 2 narrow serving slices.

    The width spread is deliberately large (192 / 32 / 32): big-model
    chunks are ~2x slower on narrow slices (HBM-bound weight streaming)
    while small-model chunks are ~2x slower on the wide slice
    (collective-overhead-bound) — the skewed preferred/non-preferred
    structure the paper's scheduling targets."""
    return (
        MeshPartition("wide_tp192", 192, collective_overhead_s=8e-5),
        MeshPartition("narrow_tp32a", 32, collective_overhead_s=3e-5),
        MeshPartition("narrow_tp32b", 32, collective_overhead_s=3e-5),
    )


def decode_chunk_latency(
    cfg: ModelConfig, part: MeshPartition, chunk_tokens: int, ctx_len: int, batch: int,
    dff_scale: float = 1.0,
) -> float:
    """Analytic per-chunk decode latency on a partition: memory term
    (weights + cache stream per token) + compute + per-step collective
    overhead.  ``dff_scale`` < 1 models a gamma-variant block."""
    n = part.n_chips
    p_active = active_params(cfg) * dff_scale
    shape = ShapeSpec("x", ctx_len, batch, "decode")
    bytes_per_step = p_active * 2 + cache_bytes(cfg, shape)
    flops_per_step = 2.0 * p_active * batch
    t_mem = bytes_per_step / (n * HBM_BW)
    t_comp = flops_per_step / (n * PEAK_FLOPS)
    t_coll = part.collective_overhead_s * np.log2(max(2, n))
    return chunk_tokens * (max(t_mem, t_comp) + t_coll)


@dataclasses.dataclass(frozen=True)
class ServingModel:
    cfg: ModelConfig
    tokens_out: int = 64  # tokens generated per request
    chunk: int = 16  # scheduling granularity (tokens)
    ctx_len: int = 2048
    batch: int = 8  # requests micro-batched per step
    redundancy: float = 0.7
    variant_gamma: int = 2


def build_serving_plan(
    sm: ServingModel,
    partitions: Sequence[MeshPartition],
    deadline: float,
    theta: float = 0.90,
    enable_variants: bool = True,
) -> ModelPlan:
    """Construct a ModelPlan whose 'layers' are decode chunks and whose
    'accelerators' are mesh partitions — the faithful Terastal machinery
    then runs unchanged on LM serving."""
    n_chunks = sm.tokens_out // sm.chunk
    cfg = sm.cfg
    # synthetic DnnModel: one matmul LayerSpec per chunk (bookkeeping only)
    layers = [
        matmul(f"chunk{i}", sm.chunk * sm.batch, cfg.d_model, cfg.d_model)
        for i in range(n_chunks)
    ]
    dnn = DnnModel(name=cfg.name, layers=layers, redundancy=sm.redundancy)
    plat = Platform(
        name="pod_partitions",
        accelerators=tuple(
            Accelerator(p.name, Dataflow.WS, p.n_chips) for p in partitions
        ),
    )
    lat = np.zeros((n_chunks, len(partitions)))
    for k, p in enumerate(partitions):
        lat[:, k] = decode_chunk_latency(cfg, p, sm.chunk, sm.ctx_len, sm.batch)
    budget = distribute_budgets(lat, deadline)
    variants: Dict[int, VariantInfo] = {}
    if enable_variants and budget.feasible:
        g2 = sm.variant_gamma**2
        # variant block: d_ff / gamma^2 => active-FLOP ratio
        p_full = active_params(cfg)
        ffn = 3 * cfg.d_model * (cfg.moe_d_ff or cfg.d_ff) * (
            cfg.experts_per_token if cfg.family == "moe" else 1
        ) * cfg.n_layers
        scale = max(0.05, (p_full - ffn * (1 - 1.0 / g2)) / p_full)
        from repro.core.accuracy import layer_variant_loss

        for i in range(n_chunks):
            rho = int(budget.rho[i])
            if rho <= 0:
                continue
            vlat = np.array([
                decode_chunk_latency(cfg, p, sm.chunk, sm.ctx_len, sm.batch, dff_scale=scale)
                for p in partitions
            ])
            loss = layer_variant_loss(cfg.name, f"chunk{i}", sm.redundancy, sm.variant_gamma)
            variants[i] = VariantInfo(
                layer_idx=i,
                gamma=sm.variant_gamma,
                direction="d2s",
                spec=layers[i],
                latencies=vlat,
                loss=loss,
                storage_weights=int(ffn / g2),
            )
    return ModelPlan(
        model=dnn, platform=plat, deadline=deadline, lat=lat, budget=budget,
        variants=variants, theta=theta,
    )


def serve_workload(
    models: Sequence[ServingModel],
    rates_fps: Sequence[float],
    scheduler: str = "terastal",
    duration: float = 5.0,
    partitions: Optional[Sequence[MeshPartition]] = None,
    theta: float = 0.90,
    seed: int = 0,
    budget_policy: str = "static",
    admission: str = "none",
    arrival: Union[ArrivalProcess, str, None] = None,
) -> SimResult:
    """``budget_policy`` ("static" | "reclaim" | "adaptive(...)") selects
    the online chunk-budget policy — on LM traffic, slack reclamation
    moves unused chunk budget to later decode chunks of the same request,
    and the adaptive policy engages that reclamation only inside detected
    request bursts, repairing any chunk schedule the burst outruns back
    to the offline distribution (see ``repro.core.budget_online``).

    ``admission`` ("none" | "shed_early(...)" | "token_bucket(...)") is
    the overload-control axis (``repro.core.admission``); ``arrival``
    sets every model's release process — pass
    ``ClosedLoopClients(n_users=..., think_time=...)`` (or its
    ``"closed_loop(...)"`` call-spec) for closed-loop traffic where
    releases gate on completions."""
    if len(models) != len(rates_fps):
        raise ValueError(
            f"serve_workload: models and rates_fps must have the same "
            f"length, got {len(models)} models and {len(rates_fps)} rates"
        )
    partitions = partitions or default_partitions()
    plans = [
        build_serving_plan(sm, partitions, deadline=1.0 / r, theta=theta)
        for sm, r in zip(models, rates_fps)
    ]
    proc = make_arrival_process(arrival) if arrival is not None else None
    tasks = [
        TaskSpec(model_idx=i, fps=r, arrival=proc)
        for i, r in enumerate(rates_fps)
    ]
    return simulate(
        plans, tasks, duration, make_scheduler(scheduler), seed=seed,
        budget_policy=budget_policy, admission=admission,
    )

"""Layer workload descriptions and the S2D/D2S layer-variant transform.

A :class:`LayerSpec` describes the *computation* of one DNN layer in the
units the WS/OS dataflow cost model needs (Terastal paper, Sec. III):

  conv    : K filters of (R x S x C) over an (H x W x C) input, stride t.
  dwconv  : depthwise conv, one filter of (R x S) per channel C.
  fc      : fully connected = conv whose kernel covers the full input
            spatial extent (paper Sec. III last paragraph).
  matmul  : an (M x Kd) @ (Kd x N) GEMM (attention / transformer blocks),
            mapped as a 1x1 conv with M output pixels, N filters, Kd chans.
  pool / eltwise : bandwidth-bound reshaping ops (no MACs).

The variant transform implements Fig. 1 of the paper:

  forward (WS-preferred layer, target OS):
      D2S(gamma) on input:  (H, W, C)      -> (gH, gW, C/g^2)
      conv:                 K/g^2 filters of (R x S x C/g^2)
      S2D(gamma) on output: (gHo, gWo, K/g^2) -> (Ho, Wo, K)
      => weights / g^4, MACs / g^2, output-side parallelism * g^2.

  reverse (OS-preferred layer, target WS):
      S2D(gamma) on input:  (H, W, C)      -> (H/g, W/g, g^2 C)
      conv:                 g^2 K filters of (R x S x g^2 C)
      D2S(gamma) on output.
      => channel-side parallelism * g^4 (weights * g^4) — only useful for
      layers that badly under-utilize a WS array; the selection logic in
      ``repro.core.variants`` only keeps variants that actually reduce the
      modeled latency on the target accelerator.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional


class LayerKind(str, enum.Enum):
    CONV = "conv"
    DWCONV = "dwconv"
    FC = "fc"
    MATMUL = "matmul"
    POOL = "pool"
    ELTWISE = "eltwise"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer's workload. All sizes in elements (dtype handled by model)."""

    kind: LayerKind
    name: str = ""
    # conv-family parameters (also encode fc / matmul, see constructors).
    K: int = 0  # number of filters / output channels
    C: int = 0  # input channels (contraction size per spatial tap)
    R: int = 1  # filter height
    S: int = 1  # filter width
    H: int = 0  # input height
    W: int = 0  # input width
    stride: int = 1
    pad: str = "same"  # "same": Ho = ceil(H/stride); "valid": sliding window
    # variant bookkeeping
    gamma: int = 1  # 1 == original layer
    variant_dir: str = ""  # "" | "d2s" (forward) | "s2d" (reverse)

    # ---- derived geometry -------------------------------------------------
    @property
    def Ho(self) -> int:
        if self.kind in (LayerKind.FC, LayerKind.MATMUL):
            return 1
        if self.pad == "same":
            return max(1, -(-self.H // self.stride))
        return max(1, (self.H - self.R) // self.stride + 1) if self.H >= self.R else 1

    @property
    def Wo(self) -> int:
        if self.kind == LayerKind.FC:
            return 1
        if self.kind == LayerKind.MATMUL:
            return self.H  # M output "pixels" stored in H
        if self.pad == "same":
            return max(1, -(-self.W // self.stride))
        return max(1, (self.W - self.S) // self.stride + 1) if self.W >= self.S else 1

    @property
    def out_pixels(self) -> int:
        if self.kind == LayerKind.MATMUL:
            return self.H  # M
        return self.Ho * self.Wo

    @property
    def macs(self) -> int:
        if self.kind in (LayerKind.POOL, LayerKind.ELTWISE):
            return 0
        if self.kind == LayerKind.DWCONV:
            return self.C * self.R * self.S * self.out_pixels
        return self.K * self.C * self.R * self.S * self.out_pixels

    @property
    def weights(self) -> int:
        if self.kind in (LayerKind.POOL, LayerKind.ELTWISE):
            return 0
        if self.kind == LayerKind.DWCONV:
            return self.C * self.R * self.S
        return self.K * self.C * self.R * self.S

    @property
    def input_elems(self) -> int:
        if self.kind == LayerKind.MATMUL:
            return self.H * self.C  # M x Kd
        return self.H * self.W * self.C

    @property
    def output_elems(self) -> int:
        if self.kind == LayerKind.DWCONV:
            return self.C * self.out_pixels
        if self.kind in (LayerKind.POOL, LayerKind.ELTWISE):
            return self.C * self.out_pixels
        return self.K * self.out_pixels

    def with_name(self, name: str) -> "LayerSpec":
        return dataclasses.replace(self, name=name)


# ---- constructors ----------------------------------------------------------


def conv(name: str, K: int, C: int, R: int, S: int, H: int, W: int, stride: int = 1) -> LayerSpec:
    return LayerSpec(LayerKind.CONV, name, K=K, C=C, R=R, S=S, H=H, W=W, stride=stride)


def dwconv(name: str, C: int, R: int, S: int, H: int, W: int, stride: int = 1) -> LayerSpec:
    return LayerSpec(LayerKind.DWCONV, name, K=C, C=C, R=R, S=S, H=H, W=W, stride=stride)


def fc(name: str, in_features: int, out_features: int) -> LayerSpec:
    # conv whose kernel covers the full (1x1) input spatial extent.
    return LayerSpec(LayerKind.FC, name, K=out_features, C=in_features, R=1, S=1, H=1, W=1)


def matmul(name: str, M: int, N: int, Kd: int) -> LayerSpec:
    # (M x Kd) @ (Kd x N): N filters, Kd channels, M output pixels.
    return LayerSpec(LayerKind.MATMUL, name, K=N, C=Kd, R=1, S=1, H=M, W=1)


def pool(name: str, C: int, H: int, W: int, R: int = 2, S: int = 2, stride: int = 2) -> LayerSpec:
    return LayerSpec(LayerKind.POOL, name, K=C, C=C, R=R, S=S, H=H, W=W, stride=stride)


def eltwise(name: str, C: int, H: int, W: int) -> LayerSpec:
    return LayerSpec(LayerKind.ELTWISE, name, K=C, C=C, R=1, S=1, H=H, W=W, stride=1)


# ---- the layer-variant transform (paper Sec. III, Fig. 1) ------------------


def variant_feasible(spec: LayerSpec, gamma: int, direction: str = "d2s") -> bool:
    """Divisibility conditions for an exact S2D/D2S variant."""
    if gamma < 2:
        return False
    if spec.kind not in (LayerKind.CONV, LayerKind.FC, LayerKind.MATMUL):
        # Depthwise convs / pools move no channel mass; the transform does
        # not apply (each output channel depends on exactly one input chan).
        return False
    g2 = gamma * gamma
    if direction == "d2s":
        # need C and K divisible by gamma^2 (paper: "assuming C divisible")
        return spec.C % g2 == 0 and spec.K % g2 == 0
    elif direction == "s2d":
        # spatial dims must fold: H, W divisible by gamma (conv only).
        if spec.kind != LayerKind.CONV:
            return False
        return spec.H % gamma == 0 and spec.W % gamma == 0 and spec.Ho % gamma == 0 and spec.Wo % gamma == 0
    return False


def make_variant(spec: LayerSpec, gamma: int, direction: str = "d2s") -> LayerSpec:
    """Construct the variant LayerSpec for ``spec`` at ratio ``gamma``.

    ``d2s`` (forward, Fig. 1): unfold channels into space before the conv;
    the variant conv sees a (gH x gW x C/g^2) input and K/g^2 filters.
    ``s2d`` (reverse): fold space into channels; (H/g x W/g x g^2 C) input
    and g^2 K filters.
    """
    if not variant_feasible(spec, gamma, direction):
        raise ValueError(f"variant infeasible for {spec.name} gamma={gamma} dir={direction}")
    g2 = gamma * gamma
    if direction == "d2s":
        if spec.kind in (LayerKind.FC, LayerKind.MATMUL):
            # FC/matmul: the "spatial" unfolding turns one big contraction
            # into g^2 output pixels of a g^2-smaller contraction.
            M = spec.H if spec.kind == LayerKind.MATMUL else 1
            return dataclasses.replace(
                spec,
                kind=LayerKind.MATMUL,
                name=spec.name + f"@d2s{gamma}",
                K=spec.K // g2,
                C=spec.C // g2,
                H=M * g2,
                gamma=gamma,
                variant_dir="d2s",
            )
        return dataclasses.replace(
            spec,
            name=spec.name + f"@d2s{gamma}",
            K=spec.K // g2,
            C=spec.C // g2,
            H=spec.H * gamma,
            W=spec.W * gamma,
            # NOTE: stride unchanged; R,S unchanged per Fig. 1.
            gamma=gamma,
            variant_dir="d2s",
        )
    else:  # s2d
        return dataclasses.replace(
            spec,
            name=spec.name + f"@s2d{gamma}",
            K=spec.K * g2,
            C=spec.C * g2,
            H=spec.H // gamma,
            W=spec.W // gamma,
            gamma=gamma,
            variant_dir="s2d",
        )


def variant_weight_ratio(spec: LayerSpec, gamma: int, direction: str = "d2s") -> float:
    """weights(variant)/weights(original): 1/g^4 forward, g^4 reverse."""
    base = spec.weights
    if base == 0:
        return 1.0
    return make_variant(spec, gamma, direction).weights / base


def variant_storage_overhead(spec: LayerSpec, gamma: int, direction: str = "d2s") -> int:
    """Extra weights (elements) stored to keep BOTH original and variant."""
    return make_variant(spec, gamma, direction).weights

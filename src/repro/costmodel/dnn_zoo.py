"""Layer graphs for the Table II workload models.

Each builder returns ``List[LayerSpec]`` — the per-layer workload sequence
the scheduler treats as a chain of non-preemptive jobs (paper Sec. IV:
"Each layer takes its previous layer's output as input").

Fidelity note (recorded in DESIGN.md): these are *shape-accurate
reconstructions* from the cited papers (VGG11, ResNet50, MobileNetV2-SSD,
InceptionV3, Swin-Tiny are exact up to head details; FBNet-C, Hand S/P,
Sp2Dense and PlaneRCNN are faithful approximations of the published
architectures at the layer-shape level).  The Terastal algorithms consume
only the (latency table, deadline, accuracy profile) triple, so what
matters is a realistic mix of WS- and OS-preferred layers at realistic
scale — which these provide.

``redundancy`` is the architectural-redundancy factor used by the accuracy
model (paper Fig. 4: ResNet50 / Swin-Tiny / Sp2Dense "remain robust under
multiple variants, while models with more compact architectures are more
sensitive").
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Callable, Dict, List, Optional

from repro.costmodel.layers import LayerKind, LayerSpec, conv, dwconv, eltwise, fc, matmul, pool

if TYPE_CHECKING:  # runtime import is lazy (repro.core pulls in this module)
    from repro.core.dag import LayerDag


@dataclasses.dataclass(frozen=True)
class DnnModel:
    name: str
    layers: List[LayerSpec]
    redundancy: float  # in (0, 1]; higher = more robust to variants
    task: str = "classification"  # metric family for accuracy reporting
    baseline_accuracy: float = 0.75  # task metric of the unmodified model
    #: layer precedence DAG (None = the default linear chain).  When set,
    #: ``layers[i]`` is node ``i`` of the DAG and ``build_model_plan``
    #: distributes budgets over its critical path instead of the chain sum.
    dag: Optional[LayerDag] = None

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def total_weights(self) -> int:
        return sum(l.weights for l in self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)


# ---------------------------------------------------------------- VGG11 ----


def vgg11(input_hw: int = 224) -> DnnModel:
    h = input_hw
    L: List[LayerSpec] = []
    cfg = [(64, 3, 1), (128, 64, 2), (256, 128, 2), (256, 256, 0),
           (512, 256, 2), (512, 512, 0), (512, 512, 2), (512, 512, 0)]
    c_in = 3
    for i, (k, c, pool_after) in enumerate(cfg):
        L.append(conv(f"conv{i+1}", k, c_in, 3, 3, h, h))
        c_in = k
        if pool_after:
            L.append(pool(f"pool{i+1}", k, h, h))
            h //= 2
    L.append(pool("pool_final", 512, h, h))
    h //= 2
    L.append(fc("fc1", 512 * h * h, 4096))
    L.append(fc("fc2", 4096, 4096))
    L.append(fc("fc3", 4096, 1000))
    return DnnModel("vgg11", L, redundancy=0.35, baseline_accuracy=0.886)  # top-5


# -------------------------------------------------------------- ResNet50 ----


def _bottleneck(L: List[LayerSpec], tag: str, c_in: int, c_mid: int, c_out: int,
                h: int, stride: int) -> int:
    L.append(conv(f"{tag}.conv1", c_mid, c_in, 1, 1, h, h))
    L.append(conv(f"{tag}.conv2", c_mid, c_mid, 3, 3, h, h, stride=stride))
    h2 = -(-h // stride)
    L.append(conv(f"{tag}.conv3", c_out, c_mid, 1, 1, h2, h2))
    if stride != 1 or c_in != c_out:
        L.append(conv(f"{tag}.down", c_out, c_in, 1, 1, h, h, stride=stride))
    L.append(eltwise(f"{tag}.add", c_out, h2, h2))
    return h2


def resnet50(input_hw: int = 224) -> DnnModel:
    L: List[LayerSpec] = []
    h = input_hw
    L.append(conv("stem", 64, 3, 7, 7, h, h, stride=2))
    h //= 2
    L.append(pool("maxpool", 64, h, h))
    h //= 2
    c_in = 64
    for s, (n_blocks, c_mid, c_out, stride) in enumerate(
        [(3, 64, 256, 1), (4, 128, 512, 2), (6, 256, 1024, 2), (3, 512, 2048, 2)]
    ):
        for b in range(n_blocks):
            st = stride if b == 0 else 1
            h = _bottleneck(L, f"s{s+1}b{b+1}", c_in, c_mid, c_out, h, st)
            c_in = c_out
    L.append(pool("gap", 2048, h, h, R=h, S=h, stride=h))
    L.append(fc("fc", 2048, 1000))
    return DnnModel("resnet50", L, redundancy=0.85, baseline_accuracy=0.929)  # top-5


# -------------------------------------------------- MobileNetV2 (+SSD) ----


def _inverted_residual(L: List[LayerSpec], tag: str, c_in: int, c_out: int,
                       h: int, stride: int, expand: int) -> int:
    c_mid = c_in * expand
    if expand != 1:
        L.append(conv(f"{tag}.pw", c_mid, c_in, 1, 1, h, h))
    L.append(dwconv(f"{tag}.dw", c_mid, 3, 3, h, h, stride=stride))
    h2 = -(-h // stride)
    L.append(conv(f"{tag}.pwl", c_out, c_mid, 1, 1, h2, h2))
    if stride == 1 and c_in == c_out:
        L.append(eltwise(f"{tag}.add", c_out, h2, h2))
    return h2


def mobilenetv2_ssd(input_hw: int = 300) -> DnnModel:
    L: List[LayerSpec] = []
    h = input_hw
    L.append(conv("stem", 32, 3, 3, 3, h, h, stride=2))
    h = -(-h // 2)
    c_in = 32
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    blk = 0
    feat19 = None  # SSD taps the 19x19 expansion
    for t, c, n, s in cfg:
        for i in range(n):
            st = s if i == 0 else 1
            h = _inverted_residual(L, f"b{blk}", c_in, c, h, st, t)
            c_in = c
            blk += 1
    L.append(conv("head", 1280, 320, 1, 1, h, h))
    # SSDLite extra feature layers + per-scale box/class predictors.
    extras = [(512, 2), (256, 2), (256, 2), (128, 2)]
    c_e = 1280
    he = h
    for i, (c, s) in enumerate(extras):
        L.append(conv(f"extra{i}.pw", c // 2, c_e, 1, 1, he, he))
        L.append(dwconv(f"extra{i}.dw", c // 2, 3, 3, he, he, stride=s))
        he = -(-he // s)
        L.append(conv(f"extra{i}.pwl", c, c // 2, 1, 1, he, he))
        c_e = c
    # predictors: (feature hw, channels) — 19x19 tap uses the b13 expansion (576).
    for i, (fh, c) in enumerate([(19, 576), (10, 1280), (5, 512), (3, 256), (2, 256), (1, 128)]):
        L.append(dwconv(f"pred{i}.dw", c, 3, 3, fh, fh))
        L.append(conv(f"pred{i}.box", 6 * 4, c, 1, 1, fh, fh))
        L.append(conv(f"pred{i}.cls", 6 * 21, c, 1, 1, fh, fh))
    return DnnModel("mobilenetv2_ssd", L, redundancy=0.55, task="detection",
                    baseline_accuracy=0.722)  # VOC mAP


# ------------------------------------------------------------ InceptionV3 ----


def inceptionv3(input_hw: int = 299) -> DnnModel:
    L: List[LayerSpec] = []
    h = input_hw

    def cv(tag, k, c, r, s, hh, stride=1, pad="same"):
        L.append(LayerSpec(kind=LayerKind.CONV, name=tag, K=k, C=c,
                           R=r, S=s, H=hh, W=hh, stride=stride, pad=pad))

    # stem
    cv("stem1", 32, 3, 3, 3, h, 2); h = -(-h // 2)
    cv("stem2", 32, 32, 3, 3, h)
    cv("stem3", 64, 32, 3, 3, h)
    L.append(pool("stem_pool", 64, h, h)); h //= 2
    cv("stem4", 80, 64, 1, 1, h)
    cv("stem5", 192, 80, 3, 3, h)
    L.append(pool("stem_pool2", 192, h, h)); h //= 2  # 35x35x192 (for 299 input)
    c_in = 192
    # 3x InceptionA
    for i, cpool in enumerate([32, 64, 64]):
        cv(f"A{i}.b1", 64, c_in, 1, 1, h)
        cv(f"A{i}.b5a", 48, c_in, 1, 1, h); cv(f"A{i}.b5b", 64, 48, 5, 5, h)
        cv(f"A{i}.b3a", 64, c_in, 1, 1, h); cv(f"A{i}.b3b", 96, 64, 3, 3, h)
        cv(f"A{i}.b3c", 96, 96, 3, 3, h)
        cv(f"A{i}.bp", cpool, c_in, 1, 1, h)
        c_in = 64 + 64 + 96 + cpool
    # ReductionA
    cv("RA.b3", 384, c_in, 3, 3, h, 2)
    cv("RA.d1", 64, c_in, 1, 1, h); cv("RA.d2", 96, 64, 3, 3, h)
    cv("RA.d3", 96, 96, 3, 3, h, 2)
    L.append(pool("RA.pool", c_in, h, h)); h = -(-h // 2)
    c_in = 384 + 96 + c_in  # 768
    # 4x InceptionB (7x7 factorized)
    for i, c7 in enumerate([128, 160, 160, 192]):
        cv(f"B{i}.b1", 192, c_in, 1, 1, h)
        cv(f"B{i}.s1", c7, c_in, 1, 1, h); cv(f"B{i}.s2", c7, c7, 1, 7, h)
        cv(f"B{i}.s3", 192, c7, 7, 1, h)
        cv(f"B{i}.d1", c7, c_in, 1, 1, h); cv(f"B{i}.d2", c7, c7, 7, 1, h)
        cv(f"B{i}.d3", c7, c7, 1, 7, h); cv(f"B{i}.d4", c7, c7, 7, 1, h)
        cv(f"B{i}.d5", 192, c7, 1, 7, h)
        cv(f"B{i}.bp", 192, c_in, 1, 1, h)
        c_in = 768
    # ReductionB
    cv("RB.s1", 192, c_in, 1, 1, h); cv("RB.s2", 320, 192, 3, 3, h, 2)
    cv("RB.d1", 192, c_in, 1, 1, h); cv("RB.d2", 192, 192, 1, 7, h)
    cv("RB.d3", 192, 192, 7, 1, h); cv("RB.d4", 192, 192, 3, 3, h, 2)
    L.append(pool("RB.pool", c_in, h, h)); h = -(-h // 2)
    c_in = 320 + 192 + 768  # 1280
    # 2x InceptionC
    for i in range(2):
        cv(f"C{i}.b1", 320, c_in, 1, 1, h)
        cv(f"C{i}.e1", 384, c_in, 1, 1, h); cv(f"C{i}.e2a", 384, 384, 1, 3, h)
        cv(f"C{i}.e2b", 384, 384, 3, 1, h)
        cv(f"C{i}.d1", 448, c_in, 1, 1, h); cv(f"C{i}.d2", 384, 448, 3, 3, h)
        cv(f"C{i}.d3a", 384, 384, 1, 3, h); cv(f"C{i}.d3b", 384, 384, 3, 1, h)
        cv(f"C{i}.bp", 192, c_in, 1, 1, h)
        c_in = 320 + 768 + 768 + 192  # 2048
    L.append(pool("gap", 2048, h, h, R=h, S=h, stride=h))
    L.append(fc("fc", 2048, 1000))
    return DnnModel("inceptionv3", L, redundancy=0.7, baseline_accuracy=0.937)


# -------------------------------------------------------------- Swin-Tiny ----


def swin_tiny(input_hw: int = 224) -> DnnModel:
    L: List[LayerSpec] = []
    L.append(conv("patch_embed", 96, 3, 4, 4, input_hw, input_hw, stride=4))
    n = (input_hw // 4) ** 2  # tokens
    dims = [96, 192, 384, 768]
    depths = [2, 2, 6, 2]
    win = 49  # 7x7 windows
    for s, (d, depth) in enumerate(zip(dims, depths)):
        for b in range(depth):
            t = f"s{s}b{b}"
            L.append(matmul(f"{t}.qkv", n, 3 * d, d))
            L.append(matmul(f"{t}.attn_qk", n, win, d))
            L.append(matmul(f"{t}.attn_v", n, d, win))
            L.append(matmul(f"{t}.proj", n, d, d))
            L.append(matmul(f"{t}.mlp1", n, 4 * d, d))
            L.append(matmul(f"{t}.mlp2", n, d, 4 * d))
        if s < 3:
            L.append(matmul(f"merge{s}", n // 4, 2 * d, 4 * d))
            n //= 4
    L.append(fc("head", 768, 1000))
    return DnnModel("swin_tiny", L, redundancy=0.85, baseline_accuracy=0.955)


# ---------------------------------------------------------------- FBNet-C ----


def fbnet_c(input_hw: int = 224) -> DnnModel:
    """FBNet-C (Wu et al. 2019) — searched MBConv stack, shape-level approx."""
    L: List[LayerSpec] = []
    h = input_hw
    L.append(conv("stem", 16, 3, 3, 3, h, h, stride=2))
    h = -(-h // 2)
    c_in = 16
    # (expand, c_out, n, stride, kernel)
    cfg = [(1, 16, 1, 1, 3), (6, 24, 1, 2, 3), (1, 24, 3, 1, 3),
           (6, 32, 1, 2, 5), (3, 32, 3, 1, 3), (6, 64, 1, 2, 5),
           (6, 64, 3, 1, 5), (6, 112, 1, 1, 5), (6, 112, 3, 1, 5),
           (6, 184, 1, 2, 5), (6, 184, 3, 1, 5), (6, 352, 1, 1, 3)]
    blk = 0
    for t, c, n, s, k in cfg:
        for i in range(n):
            st = s if i == 0 else 1
            c_mid = c_in * t
            tag = f"b{blk}"
            if t != 1:
                L.append(conv(f"{tag}.pw", c_mid, c_in, 1, 1, h, h))
            L.append(dwconv(f"{tag}.dw", c_mid, k, k, h, h, stride=st))
            h = -(-h // st)
            L.append(conv(f"{tag}.pwl", c, c_mid, 1, 1, h, h))
            c_in = c
            blk += 1
    L.append(conv("head", 1984, 352, 1, 1, h, h))
    L.append(pool("gap", 1984, h, h, R=h, S=h, stride=h))
    L.append(fc("fc", 1984, 1000))
    return DnnModel("fbnet_c", L, redundancy=0.45, baseline_accuracy=0.749)


# ---------------------------------------------------- Hand Shape/Pose ----


def hand_sp(input_hw: int = 256) -> DnnModel:
    """Ge et al. CVPR'19 3D hand shape & pose — hourglass encoder + graph
    CNN decoder, shape-level approximation."""
    L: List[LayerSpec] = []
    h = input_hw
    L.append(conv("stem", 64, 3, 7, 7, h, h, stride=2)); h //= 2
    L.append(conv("stem2", 128, 64, 3, 3, h, h))
    L.append(pool("pool1", 128, h, h)); h //= 2
    # 2-stack hourglass at 64x64, channels 160 (compact per Ge et al.)
    for s in range(2):
        ch = 160
        hh = h
        c_in = 128 if s == 0 else 160
        for d in range(3):  # down path
            L.append(conv(f"hg{s}.d{d}a", ch, c_in if d == 0 else ch, 3, 3, hh, hh))
            L.append(conv(f"hg{s}.d{d}b", ch, ch, 3, 3, hh, hh, stride=2))
            hh //= 2
        L.append(conv(f"hg{s}.mid", ch, ch, 3, 3, hh, hh))
        for d in range(3):  # up path
            hh *= 2
            L.append(conv(f"hg{s}.u{d}", ch, ch, 3, 3, hh, hh))
        L.append(conv(f"hg{s}.out", 160, ch, 1, 1, h, h))
    # latent feature + graph-CNN mesh decoder (matmuls over 1280-vertex mesh)
    L.append(conv("latent", 512, 160, 3, 3, h, h, stride=2))
    L.append(pool("gap", 512, h // 2, h // 2, R=h // 2, S=h // 2, stride=h // 2))
    L.append(fc("fc_latent", 512, 1024))
    for g in range(4):
        L.append(matmul(f"graph{g}", 1280, 96 if g < 3 else 3, 96))
    L.append(fc("pose_head", 1024, 63))  # 21 joints x 3
    return DnnModel("hand_sp", L, redundancy=0.5, task="pose",
                    baseline_accuracy=0.85)


# -------------------------------------------------------------- Sp2Dense ----


def sp2dense(input_hw: int = 224) -> DnnModel:
    """Ma & Karaman ICRA'18 sparse-to-dense depth — ResNet18-ish encoder +
    upconv decoder (shape-level approximation; RGBd input = 4 channels)."""
    L: List[LayerSpec] = []
    h = input_hw
    L.append(conv("stem", 64, 4, 7, 7, h, h, stride=2)); h //= 2
    L.append(pool("pool1", 64, h, h)); h //= 2
    c_in = 64
    for s, (c, stride) in enumerate([(64, 1), (128, 2), (256, 2), (512, 2)]):
        for b in range(2):  # basic blocks
            st = stride if b == 0 else 1
            L.append(conv(f"s{s}b{b}.c1", c, c_in, 3, 3, h, h, stride=st))
            h = -(-h // st)
            L.append(conv(f"s{s}b{b}.c2", c, c, 3, 3, h, h))
            if st != 1 or c_in != c:
                L.append(conv(f"s{s}b{b}.down", c, c_in, 1, 1, h * st, h * st, stride=st))
            L.append(eltwise(f"s{s}b{b}.add", c, h, h))
            c_in = c
    L.append(conv("bottleneck", 512, 512, 1, 1, h, h))
    # decoder: 4 upproj stages
    c_dec = 512
    for d in range(4):
        h *= 2
        L.append(conv(f"up{d}", c_dec // 2, c_dec, 5, 5, h, h))
        c_dec //= 2
    L.append(conv("pred", 1, c_dec, 3, 3, h, h))
    return DnnModel("sp2dense", L, redundancy=0.8, task="depth",
                    baseline_accuracy=0.81)  # delta1 accuracy


# -------------------------------------------------------------- PlaneRCNN ----


def planercnn(input_hw: int = 480) -> DnnModel:
    """Liu et al. CVPR'19 — Mask-RCNN-style plane detection on a ResNet50-FPN
    backbone (shape-level approximation incl. RPN + heads + mask deconv)."""
    base = resnet50(input_hw)
    L = [l for l in base.layers if not l.name.startswith(("gap", "fc"))]
    hs = [input_hw // 4, input_hw // 8, input_hw // 16, input_hw // 32]
    # FPN lateral + output convs
    for i, (c_in, h) in enumerate(zip([256, 512, 1024, 2048], hs)):
        L.append(conv(f"fpn.lat{i}", 256, c_in, 1, 1, h, h))
        L.append(conv(f"fpn.out{i}", 256, 256, 3, 3, h, h))
    # RPN on each level
    for i, h in enumerate(hs):
        L.append(conv(f"rpn{i}.conv", 256, 256, 3, 3, h, h))
        L.append(conv(f"rpn{i}.cls", 3, 256, 1, 1, h, h))
        L.append(conv(f"rpn{i}.box", 12, 256, 1, 1, h, h))
    # box head (RoIAlign 7x7, 256 rois -> batch as pixels) + mask head
    L.append(matmul("box.fc1", 256, 1024, 256 * 49))
    L.append(matmul("box.fc2", 256, 1024, 1024))
    for m in range(4):
        L.append(conv(f"mask.c{m}", 256, 256, 3, 3, 14, 14))
    L.append(conv("mask.deconv", 256, 256, 2, 2, 28, 28))
    L.append(conv("mask.pred", 2, 256, 1, 1, 28, 28))
    # plane params head
    L.append(matmul("plane.fc", 256, 3, 1024))
    return DnnModel("planercnn", L, redundancy=0.75, task="detection",
                    baseline_accuracy=0.60)


# --------------------------------------------------- DAG-structured models -
#
# Three multi-branch workloads exercising the layer-DAG axis (paper
# Sec. III generalized: "Each layer takes its previous layer's output as
# input" becomes per-edge precedence).  Node i of the DAG is layers[i];
# parallel branches let one request occupy several accelerators at once.


def asr_encdec(input_hw: int = 80) -> DnnModel:
    """Speech encoder/decoder split: the audio conv encoder and the text
    prompt embedding are independent sources that join at the cross-
    attention fusion, then a decoder chain produces tokens.

    ``0:aud_stem -> 1:aud_enc1 -> 2:aud_enc2 -\\
                                               > 5:fusion -> 6:dec1 -> 7:dec2 -> 8:lm_head
       3:txt_embed -> 4:txt_proj -------------/``
    """
    from repro.core.dag import LayerDag

    h = input_hw
    L: List[LayerSpec] = [
        conv("aud_stem", 256, 1, 3, 3, h, 3000 // 8),
        conv("aud_enc1", 384, 256, 3, 3, h // 2, 3000 // 16, stride=2),
        conv("aud_enc2", 512, 384, 3, 3, h // 4, 3000 // 32, stride=2),
        fc("txt_embed", 512, 1024),
        matmul("txt_proj", 448, 512, 1024),
        matmul("fusion", 448, 512, 512),
        matmul("dec1", 448, 2048, 512),
        matmul("dec2", 448, 512, 2048),
        fc("lm_head", 512, 8192),
    ]
    dag = LayerDag(preds=((), (0,), (1,), (), (3,), (2, 4), (5,), (6,), (7,)))
    return DnnModel("asr_encdec", L, redundancy=0.65, task="asr",
                    baseline_accuracy=0.88, dag=dag)


def vlm_2branch(input_hw: int = 224) -> DnnModel:
    """Two-branch vision-language model: a shared stem fans out into a
    conv vision encoder and a matmul text encoder that rejoin at a
    fusion layer feeding the answer head.

    ``0:stem -> 1:vis1 -> 2:vis2 -> 3:vis_proj -\\
                                                 > 7:fusion -> 8:head
       0:stem -> 4:txt1 -> 5:txt2 -> 6:txt_proj -/``
    """
    from repro.core.dag import LayerDag

    h = input_hw
    L: List[LayerSpec] = [
        conv("stem", 96, 3, 4, 4, h, h, stride=4),
        conv("vis1", 192, 96, 3, 3, h // 8, h // 8),
        conv("vis2", 384, 192, 3, 3, h // 16, h // 16),
        matmul("vis_proj", (h // 16) ** 2, 512, 384),
        matmul("txt1", 256, 1024, 512),
        matmul("txt2", 256, 1024, 1024),
        matmul("txt_proj", 256, 512, 1024),
        matmul("fusion", 256, 512, 512),
        fc("head", 512, 3129),
    ]
    dag = LayerDag(
        preds=((), (0,), (1,), (2,), (0,), (4,), (5,), (3, 6), (7,))
    )
    return DnnModel("vlm_2branch", L, redundancy=0.7, task="vqa",
                    baseline_accuracy=0.72, dag=dag)


def moe_4expert(input_hw: int = 224) -> DnnModel:
    """Mixture-of-experts block: a router fans out to four parallel
    expert FFNs whose outputs a combine node reduces before the head.

    ``0:router -> {1,2,3,4}:expert -> 5:combine -> 6:head``
    """
    from repro.core.dag import LayerDag

    L: List[LayerSpec] = [
        matmul("router", 196, 768, 768),
        matmul("expert0", 196, 3072, 768),
        matmul("expert1", 196, 3072, 768),
        matmul("expert2", 196, 3072, 768),
        matmul("expert3", 196, 3072, 768),
        matmul("combine", 196, 768, 3072),
        fc("head", 768, 1000),
    ]
    dag = LayerDag(
        preds=((), (0,), (0,), (0,), (0,), (1, 2, 3, 4), (5,))
    )
    return DnnModel("moe_4expert", L, redundancy=0.75,
                    baseline_accuracy=0.78, dag=dag)


# ------------------------------------------------------------------ registry -

ZOO: Dict[str, Callable[[], DnnModel]] = {
    "vgg11": vgg11,
    "resnet50": resnet50,
    "mobilenetv2_ssd": mobilenetv2_ssd,
    "inceptionv3": inceptionv3,
    "swin_tiny": swin_tiny,
    "fbnet_c": fbnet_c,
    "hand_sp": hand_sp,
    "sp2dense": sp2dense,
    "planercnn": planercnn,
    "asr_encdec": asr_encdec,
    "vlm_2branch": vlm_2branch,
    "moe_4expert": moe_4expert,
}


def get_model(name: str) -> DnnModel:
    try:
        return ZOO[name]()
    except KeyError:
        raise KeyError(f"unknown DNN '{name}'; available: {sorted(ZOO)}") from None

"""MAESTRO-lite analytical cost model for heterogeneous DNN accelerators.

This subpackage is the *faithful* experimental instrument of the Terastal
reproduction: the paper evaluates with a simulator built on MAESTRO [22]
cost analysis; we re-derive a first-order WS/OS dataflow latency model that
reproduces the paper's qualitative and quantitative latency structure
(Fig. 3: late VGG11 layers 2-8x slower on OS; variants close the gap).
"""

from repro.costmodel.layers import (
    LayerKind,
    LayerSpec,
    conv,
    dwconv,
    fc,
    matmul,
    pool,
    eltwise,
    make_variant,
    variant_weight_ratio,
)
from repro.costmodel.maestro import (
    Accelerator,
    Dataflow,
    Platform,
    layer_latency,
    model_latency_table,
    PLATFORMS,
)

__all__ = [
    "LayerKind",
    "LayerSpec",
    "conv",
    "dwconv",
    "fc",
    "matmul",
    "pool",
    "eltwise",
    "make_variant",
    "variant_weight_ratio",
    "Accelerator",
    "Dataflow",
    "Platform",
    "layer_latency",
    "model_latency_table",
    "PLATFORMS",
]

"""First-order WS/OS dataflow latency model (MAESTRO-lite).

The Terastal paper profiles per-layer latency with MAESTRO [22] on
accelerators that differ in PE count and dataflow (Table I).  MAESTRO
itself is a closed-form data-centric cost analysis; we re-derive the two
dataflows it is used for here:

  WS (NVDLA-like [2]) — weights stationary, the PE array parallelizes the
     (K x C) filter/channel cross-product with an adder tree over C; the
     R*S*out_pixels loop runs temporally:

         cycles_WS = ceil(K*C / P) * R * S * out_pixels

  OS (ShiDianNao-like [3]) — partial sums stationary, the PE array
     parallelizes output pixels of one output map; filters/channels run
     temporally:

         cycles_OS = ceil(out_pixels / P) * K * C * R * S

  (depthwise conv has no K*C cross-product: WS parallelizes only C,
  OS is unchanged per-channel.)

These two formulas produce the paper's affinity structure exactly:
many-channel / small-map layers (late VGG) are WS-preferred by 2-8x,
large-map / few-channel layers (stem convs, depthwise) are OS-preferred,
and a d2s-variant with ratio gamma cuts OS latency by ~gamma^2
(out-pixel parallelism * gamma^2, MACs / gamma^2) — reproducing Fig. 3.

Latency adds an off-chip-traffic roofline term (128 GB/s, Table I) and a
fixed dispatch overhead; per the paper, latencies are deterministic
constants profiled offline in isolation.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Sequence

import numpy as np

from repro.costmodel.layers import LayerKind, LayerSpec


class Dataflow(str, enum.Enum):
    WS = "ws"
    OS = "os"


@dataclasses.dataclass(frozen=True)
class Accelerator:
    name: str
    dataflow: Dataflow
    pes: int  # number of MAC units


@dataclasses.dataclass(frozen=True)
class Platform:
    """One hardware setting from Table I."""

    name: str
    accelerators: Sequence[Accelerator]
    sram_bytes: int = 8 * 1024 * 1024  # 8 MiB shared on-chip memory
    offchip_gbps: float = 128.0  # GB/s
    freq_hz: float = 1.0e9  # 1 GHz
    bytes_per_elem: int = 1  # int8 edge inference
    dispatch_overhead_s: float = 1.0e-6
    # Effective PE utilization derate: MAESTRO-profiled latencies include
    # pipeline fill, buffer stalls and NoC serialization that a first-order
    # roofline misses; 0.3 calibrates end-to-end model latencies into the
    # paper's deadline regime (Table II periods, non-trivial load).
    efficiency: float = 0.3

    @property
    def n_acc(self) -> int:
        return len(self.accelerators)


# ---- Table I hardware settings ---------------------------------------------

PLATFORMS: Dict[str, Platform] = {
    # 4K total PEs
    "4k_1ws2os": Platform(
        "4k_1ws2os",
        (
            Accelerator("WS0", Dataflow.WS, 2048),
            Accelerator("OS0", Dataflow.OS, 1024),
            Accelerator("OS1", Dataflow.OS, 1024),
        ),
    ),
    "4k_1os2ws": Platform(
        "4k_1os2ws",
        (
            Accelerator("OS0", Dataflow.OS, 2048),
            Accelerator("WS0", Dataflow.WS, 1024),
            Accelerator("WS1", Dataflow.WS, 1024),
        ),
    ),
    # 6K total PEs
    "6k_1ws2os": Platform(
        "6k_1ws2os",
        (
            Accelerator("WS0", Dataflow.WS, 2048),
            Accelerator("OS0", Dataflow.OS, 2048),
            Accelerator("OS1", Dataflow.OS, 2048),
        ),
    ),
    "6k_1os2ws": Platform(
        "6k_1os2ws",
        (
            Accelerator("OS0", Dataflow.OS, 2048),
            Accelerator("WS0", Dataflow.WS, 2048),
            Accelerator("WS1", Dataflow.WS, 2048),
        ),
    ),
}


# ---- cycle model ------------------------------------------------------------


def _cycles(spec: LayerSpec, dataflow: Dataflow, pes: int) -> float:
    if spec.kind in (LayerKind.POOL, LayerKind.ELTWISE):
        # No MACs: one ALU op per output element, fully parallel.
        return math.ceil(spec.output_elems / pes) * max(1, spec.R * spec.S)
    rs = spec.R * spec.S
    if spec.kind == LayerKind.DWCONV:
        if dataflow == Dataflow.WS:
            return math.ceil(spec.C / pes) * rs * spec.out_pixels
        return math.ceil(spec.out_pixels / pes) * spec.C * rs
    # conv / fc / matmul
    if dataflow == Dataflow.WS:
        return math.ceil(spec.K * spec.C / pes) * rs * spec.out_pixels
    return math.ceil(spec.out_pixels / pes) * spec.K * spec.C * rs


def _traffic_bytes(spec: LayerSpec, dataflow: Dataflow, pes: int, platform: Platform) -> float:
    b = platform.bytes_per_elem
    w_bytes = spec.weights * b
    i_bytes = spec.input_elems * b
    o_bytes = spec.output_elems * b
    if spec.kind in (LayerKind.POOL, LayerKind.ELTWISE):
        return i_bytes + o_bytes
    # Effective per-accelerator working buffer: half the shared SRAM pool
    # divided across accelerators (double-buffering).
    buf = platform.sram_bytes / (2 * platform.n_acc)
    if dataflow == Dataflow.WS:
        # weights stream once and stay; inputs refetched per weight tile if
        # they cannot be held in the buffer.
        n_tiles = math.ceil(max(1, spec.K * spec.C) / pes)
        i_refetch = 1 if i_bytes <= buf else min(n_tiles, math.ceil(i_bytes / buf))
        return w_bytes + i_bytes * i_refetch + o_bytes
    else:
        # inputs stream once (pixel-stationary reuse); weights refetched per
        # output tile if they cannot be held.
        n_tiles = math.ceil(spec.out_pixels / pes)
        w_refetch = 1 if w_bytes <= buf else min(n_tiles, math.ceil(w_bytes / buf))
        return i_bytes + w_bytes * w_refetch + o_bytes


def layer_latency(spec: LayerSpec, acc: Accelerator, platform: Platform) -> float:
    """Deterministic latency (seconds) of ``spec`` on ``acc`` in isolation."""
    compute_s = _cycles(spec, acc.dataflow, acc.pes) / (
        platform.freq_hz * platform.efficiency
    )
    traffic_s = _traffic_bytes(spec, acc.dataflow, acc.pes, platform) / (
        platform.offchip_gbps * 1e9
    )
    return max(compute_s, traffic_s) + platform.dispatch_overhead_s


def model_latency_table(layers: Sequence[LayerSpec], platform: Platform) -> np.ndarray:
    """latencies[L, n_acc] in seconds."""
    out = np.empty((len(layers), platform.n_acc), dtype=np.float64)
    for i, spec in enumerate(layers):
        for k, acc in enumerate(platform.accelerators):
            out[i, k] = layer_latency(spec, acc, platform)
    return out


def preferred_accelerator(spec: LayerSpec, platform: Platform) -> int:
    """Index of the lowest-latency accelerator for this layer."""
    lat = [layer_latency(spec, a, platform) for a in platform.accelerators]
    return int(np.argmin(lat))


def preferred_dataflow(spec: LayerSpec, platform: Platform) -> Dataflow:
    return platform.accelerators[preferred_accelerator(spec, platform)].dataflow


def min_latency(spec: LayerSpec, platform: Platform) -> float:
    return min(layer_latency(spec, a, platform) for a in platform.accelerators)

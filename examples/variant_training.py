"""Train a REAL S2D/D2S layer variant (paper Sec. III) in JAX.

Grounds the accuracy proxy used by the simulator: build a small CNN,
train it on a synthetic vision task, then swap one pointwise conv for
its gamma=2 variant (D2S -> conv with C/4 channels & K/4 filters -> S2D,
16x fewer weights in that layer), freeze every other layer, fine-tune
the variant alone (exactly the paper's per-variant training protocol),
and report the accuracy drop.

The variant forward pass runs through the fused Pallas kernel
(repro.kernels.s2d_conv) in interpret mode — the same op the TPU build
would execute.

Run:  PYTHONPATH=src python examples/variant_training.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.s2d_conv.ops import s2d_variant_conv
from repro.kernels.s2d_conv.ref import s2d_conv_ref

HW, C_IN, C_MID, C_OUT, N_CLS = 8, 8, 16, 32, 10


def make_data(n, key):
    """Class = dominant frequency pattern + noise."""
    k1, k2 = jax.random.split(key)
    y = jax.random.randint(k1, (n,), 0, N_CLS)
    xs = jax.random.normal(k2, (n, HW, HW, C_IN)) * 0.5
    ii = jnp.arange(HW)
    for c in range(N_CLS):
        pat = jnp.sin(ii[:, None] * (c + 1) * 0.7) * jnp.cos(ii[None, :] * (c + 1) * 0.4)
        xs = xs + (y == c)[:, None, None, None] * pat[None, :, :, None] * 1.5
    return xs.astype(jnp.float32), y


def init_params(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "conv1": jax.random.normal(k1, (3, 3, C_IN, C_MID)) * 0.1,
        "conv2": jax.random.normal(k2, (C_MID, C_OUT)) * 0.1,  # 1x1 pw
        "fc": jax.random.normal(k3, (HW * HW * C_OUT, N_CLS)) * 0.02,
    }


def forward(params, x, variant_w=None, gamma=2, use_kernel=False):
    h = jax.lax.conv_general_dilated(
        x, params["conv1"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    h = jax.nn.relu(h)
    if variant_w is None:
        h = jnp.einsum("bhwc,ck->bhwk", h, params["conv2"])
    elif use_kernel:
        h = s2d_variant_conv(h, variant_w, gamma)  # fused Pallas kernel
    else:
        # training path: the jnp reference is reverse-mode differentiable
        # (interpret-mode pallas_call is forward-only); tests assert the
        # two are bit-equal.
        h = s2d_conv_ref(h, variant_w, gamma)
    h = jax.nn.relu(h)
    return h.reshape(h.shape[0], -1) @ params["fc"]


def loss_fn(params, x, y, variant_w=None):
    logits = forward(params, x, variant_w)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])


def accuracy(params, x, y, variant_w=None, use_kernel=False):
    logits = forward(params, x, variant_w, use_kernel=use_kernel)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


def sgd(params, grads, lr):
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)


def main():
    key = jax.random.PRNGKey(0)
    xtr, ytr = make_data(512, key)
    xte, yte = make_data(256, jax.random.PRNGKey(1))
    params = init_params(jax.random.PRNGKey(2))

    step = jax.jit(lambda p, x, y: jax.grad(loss_fn)(p, x, y))
    for i in range(300):
        params = sgd(params, step(params, xtr, ytr), 0.15)
    base_acc = accuracy(params, xte, yte)
    print(f"baseline model test accuracy: {base_acc:.3f}")

    # ---- build + train the gamma=2 variant of conv2 ---------------------
    gamma = 2
    vshape = (C_MID // gamma**2, C_OUT // gamma**2)
    print(f"variant conv2: {C_MID}x{C_OUT} -> {vshape[0]}x{vshape[1]} "
          f"weights ({gamma**4}x fewer), trained with all other layers frozen")
    vw = jax.random.normal(jax.random.PRNGKey(3), vshape) * 0.1

    vgrad = jax.jit(lambda vw, p, x, y: jax.grad(
        lambda w: loss_fn(p, x, y, variant_w=w))(vw))
    for i in range(400):
        vw = vw - 0.15 * vgrad(vw, params, xtr, ytr)
    var_acc = accuracy(params, xte, yte, variant_w=vw, use_kernel=True)
    var_acc_ref = accuracy(params, xte, yte, variant_w=vw, use_kernel=False)
    assert abs(var_acc - var_acc_ref) < 1e-6, "kernel != reference"
    drop = (base_acc - var_acc) / base_acc
    print(f"variant model test accuracy: {var_acc:.3f} "
          f"(relative drop {100*drop:.1f}%; Pallas kernel == jnp reference)")
    print("paper Fig. 3 reports 7-17% per-variant drops on VGG11/ImageNet; "
          "the proxy in repro.core.accuracy is calibrated to that band.")


if __name__ == "__main__":
    main()

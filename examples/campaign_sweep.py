"""Monte-Carlo campaign walkthrough: stochastic arrivals + parallel sweeps.

Declares one campaign grid — a scenario cell x all schedulers x an
arrival-process ladder (periodic -> jittered -> Poisson -> bursty MMPP)
x many seeds — runs it across cores, and prints the miss-rate table
with bootstrap 95% confidence intervals.  This is the statistically
honest version of the paper's single-run comparisons: every number
comes with an interval, and arrival burstiness is a swept axis instead
of a baked-in periodic assumption.

Run:  PYTHONPATH=src python examples/campaign_sweep.py [--seeds 12]
"""

import argparse
import time

from repro.core import SCENARIOS, Campaign

ARRIVALS = (
    "periodic",
    "periodic(jitter=0.5)",
    "poisson",
    "mmpp(burstiness=4)",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="ar_gaming_heavy", choices=list(SCENARIOS))
    ap.add_argument("--platform", default=None, help="default: scenario's first Table-I pairing")
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--seeds", type=int, default=12)
    ap.add_argument("--serial", action="store_true", help="disable the process pool")
    ap.add_argument("--adaptive", action="store_true",
                    help="sequential sampler: grow seeds per cell in rounds and "
                    "stop each scheduler-vs-terastal comparison when its paired "
                    "CI separates from zero (spends the seed budget only where "
                    "the verdict is actually in doubt)")
    ap.add_argument("--journal", default=None,
                    help="adaptive only: JSON-lines trial journal; re-running "
                    "with the same grid resumes bit-identically from it")
    args = ap.parse_args()
    if args.seeds < 2:
        ap.error("--seeds must be >= 2: every table cell reports a bootstrap "
                 "CI over seeds, and a single replicate has no interval "
                 "(DegenerateSampleError)")
    sc = SCENARIOS[args.scenario]
    platform = args.platform or sc.platform_names[0]

    camp = Campaign(
        scenarios=(args.scenario,),
        platforms=(platform,),
        schedulers=("fcfs", "edf", "dream", "terastal"),
        arrivals=ARRIVALS,
        seeds=tuple(range(args.seeds)),
        duration=args.duration,
    )
    n = len(camp.trials())
    t0 = time.perf_counter()
    if args.adaptive:
        from repro.core import SamplerConfig, run_adaptive

        ares = run_adaptive(camp, SamplerConfig(baseline="terastal"),
                            parallel=not args.serial, journal=args.journal)
        result = ares.campaign_result()
        wall = time.perf_counter() - t0
        print(f"{args.scenario} on {platform}: {ares.n_trials}/{n} trials in "
              f"{wall:.1f}s wall ({100 * ares.trials_saved():.0f}% of the fixed "
              f"grid saved over {ares.rounds} rounds)")
        print(f"\n{'arrival':>22} {'vs terastal':>11} {'gap pp (CI)':>24} "
              f"{'n':>3} {'verdict':>10}")
        for v in ares.verdicts:
            # v.group follows GROUP_FIELDS; index 3 is the arrival spec
            print(f"{v.group[3]:>22} {v.scheduler:>11} "
                  f"{100 * v.mean_gap:+6.2f} [{100 * v.ci_lo:+6.2f}, {100 * v.ci_hi:+6.2f}] "
                  f"{v.n_seeds:3d} {v.reason:>10}")
    else:
        result = camp.run(parallel=not args.serial)
        wall = time.perf_counter() - t0
        sim_s = sum(t.wall_s for t in result.trials)
        print(f"{args.scenario} on {platform}: {n} trials in {wall:.1f}s wall "
              f"({sim_s:.1f}s of simulation -> {sim_s / wall:.1f}x parallel efficiency)")

    print(f"\n{'arrival':>22} {'scheduler':>10} {'miss% (95% CI)':>22} {'trials':>7}")
    for row in result.aggregate(by=("arrival", "scheduler")):
        m, lo, hi = (100 * row[k] for k in
                     ("mean_miss_rate", "mean_miss_rate_ci_lo", "mean_miss_rate_ci_hi"))
        print(f"{row['arrival']:>22} {row['scheduler']:>10} "
              f"{m:6.2f} [{lo:5.2f}, {hi:5.2f}] {row['n_trials']:7d}")


if __name__ == "__main__":
    main()

"""Monte-Carlo campaign walkthrough: stochastic arrivals + parallel sweeps.

Declares one campaign grid — a scenario cell x all schedulers x an
arrival-process ladder (periodic -> jittered -> Poisson -> bursty MMPP)
x many seeds — runs it across cores, and prints the miss-rate table
with bootstrap 95% confidence intervals.  This is the statistically
honest version of the paper's single-run comparisons: every number
comes with an interval, and arrival burstiness is a swept axis instead
of a baked-in periodic assumption.

Run:  PYTHONPATH=src python examples/campaign_sweep.py [--seeds 12]
"""

import argparse
import time

from repro.core import SCENARIOS, Campaign

ARRIVALS = (
    "periodic",
    "periodic(jitter=0.5)",
    "poisson",
    "mmpp(burstiness=4)",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="ar_gaming_heavy", choices=list(SCENARIOS))
    ap.add_argument("--platform", default=None, help="default: scenario's first Table-I pairing")
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--seeds", type=int, default=12)
    ap.add_argument("--serial", action="store_true", help="disable the process pool")
    args = ap.parse_args()
    sc = SCENARIOS[args.scenario]
    platform = args.platform or sc.platform_names[0]

    camp = Campaign(
        scenarios=(args.scenario,),
        platforms=(platform,),
        schedulers=("fcfs", "edf", "dream", "terastal"),
        arrivals=ARRIVALS,
        seeds=tuple(range(args.seeds)),
        duration=args.duration,
    )
    n = len(camp.trials())
    t0 = time.perf_counter()
    result = camp.run(parallel=not args.serial)
    wall = time.perf_counter() - t0
    sim_s = sum(t.wall_s for t in result.trials)
    print(f"{args.scenario} on {platform}: {n} trials in {wall:.1f}s wall "
          f"({sim_s:.1f}s of simulation -> {sim_s / wall:.1f}x parallel efficiency)")

    print(f"\n{'arrival':>22} {'scheduler':>10} {'miss% (95% CI)':>22} {'trials':>7}")
    for row in result.aggregate(by=("arrival", "scheduler")):
        m, lo, hi = (100 * row[k] for k in
                     ("mean_miss_rate", "mean_miss_rate_ci_lo", "mean_miss_rate_ci_hi"))
        print(f"{row['arrival']:>22} {row['scheduler']:>10} "
              f"{m:6.2f} [{lo:5.2f}, {hi:5.2f}] {row['n_trials']:7d}")


if __name__ == "__main__":
    main()

"""End-to-end driver (the paper's kind: real-time multi-DNN serving).

Serves the full Multi-Camera Vision (Heavy) scenario across all four
Table-I hardware settings with every scheduler, for several seconds of
simulated periodic camera traffic, and prints the Fig.5-style summary —
plus a per-request trace excerpt showing variant applications.

Run:  PYTHONPATH=src python examples/multi_dnn_serving.py [--duration 5]
"""

import argparse

import numpy as np

from repro.core import ALL_SCHEDULERS, SCENARIOS, make_scheduler, simulate
from repro.costmodel.maestro import PLATFORMS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=5.0)
    ap.add_argument("--scenario", default="multicam_heavy", choices=list(SCENARIOS))
    args = ap.parse_args()
    sc = SCENARIOS[args.scenario]

    for pn in sc.platform_names:
        plat = PLATFORMS[pn]
        plans, tasks = sc.plans(plat)
        print(f"\n=== {sc.name} on {pn} "
              f"({', '.join(a.name for a in plat.accelerators)}) ===")
        print(f"{'scheduler':>22} {'miss%':>7} {'accloss%':>9} {'drops':>6} {'util':>18}")
        for name in ALL_SCHEDULERS:
            res = simulate(plans, tasks, args.duration, make_scheduler(name), seed=0)
            drops = sum(s.dropped for s in res.per_model.values())
            print(f"{name:>22} {100*res.mean_miss_rate:7.2f} "
                  f"{100*res.mean_accuracy_loss(plans):9.2f} {drops:6d} "
                  f"{np.array2string(res.utilization(), precision=2):>18}")
        # variant usage detail under full Terastal
        res = simulate(plans, tasks, args.duration, make_scheduler("terastal"), seed=0)
        for m, s in res.per_model.items():
            if s.variants_applied:
                print(f"    {plans[m].model.name}: {s.variants_applied} variant "
                      f"applications over {s.completed} completions "
                      f"(mean retained accuracy {100*s.mean_retained:.1f}%)")


if __name__ == "__main__":
    main()

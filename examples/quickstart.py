"""Quickstart: the Terastal pipeline end-to-end in ~30 lines of API.

1. Build the offline plan for a model (Algorithm 1 budgets + variants).
2. Simulate a multi-DNN workload under FCFS vs Terastal.
3. Train a reduced LM config for a few steps (the JAX substrate).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import SCENARIOS, make_scheduler, simulate
from repro.core.variants import build_model_plan
from repro.costmodel.dnn_zoo import vgg11
from repro.costmodel.maestro import PLATFORMS


def main():
    # ---- offline stage: budgets + variants for one model ---------------
    plat = PLATFORMS["6k_1ws2os"]
    plan = build_model_plan(vgg11(384), plat, deadline=1 / 30)
    print(f"VGG11@30fps on {plat.name}: feasible={plan.budget.feasible}, "
          f"{len(plan.variants)} layer variants, "
          f"storage +{100*plan.storage_overhead:.2f}%")
    for idx, v in sorted(plan.variants.items()):
        print(f"  layer {plan.model.layers[idx].name}: gamma={v.gamma} "
              f"({v.direction}), acc loss {100*v.loss:.1f}%")

    # ---- online stage: schedule a whole scenario ------------------------
    sc = SCENARIOS["multicam_heavy"]
    plans, tasks = sc.plans(plat)
    for name in ("fcfs", "terastal"):
        res = simulate(plans, tasks, duration=2.0, scheduler=make_scheduler(name))
        print(f"{sc.name} under {name:>8}: mean miss rate "
              f"{100*res.mean_miss_rate:5.1f}%, accuracy loss "
              f"{100*res.mean_accuracy_loss(plans):.2f}%")

    # ---- the JAX substrate: train a reduced LM for a few steps ----------
    from repro.launch.train import run

    out = run("llama3.2-1b", steps=20, batch=4, seq=64, reduced=True, log_every=5)
    print(f"reduced llama3.2-1b: loss {out['losses'][0]:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()

"""Fault tolerance walk-through: train, crash, restart, re-mesh.

1. Train a reduced model with periodic checkpoints.
2. "Crash" (delete the newest checkpoint tail) and restart — trajectory
   resumes bit-exact because the data pipeline is a pure function of
   (seed, step).
3. Elastically restore the same checkpoint onto a DIFFERENT mesh shape
   (scale-down from 4 virtual devices to 1) — re-sharding is just
   device_put with the new NamedShardings.

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import shutil
import tempfile

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.launch.train import run


def main():
    tmp = tempfile.mkdtemp(prefix="repro_elastic_")
    try:
        a = os.path.join(tmp, "a")
        print("== full run (8 steps, checkpoint every 4) ==")
        out1 = run("llama3.2-1b", steps=8, batch=2, seq=32, reduced=True,
                   ckpt_dir=a, ckpt_every=4, log_every=4)

        b = os.path.join(tmp, "b")
        print("\n== identical run, then simulated crash after step 4 ==")
        run("llama3.2-1b", steps=8, batch=2, seq=32, reduced=True,
            ckpt_dir=b, ckpt_every=4, log_every=4)
        shutil.rmtree(os.path.join(b, "step_00000008"))
        print("   (deleted the step-8 checkpoint; newest committed = 4)")
        out2 = run("llama3.2-1b", steps=8, batch=2, seq=32, reduced=True,
                   ckpt_dir=b, ckpt_every=4, log_every=4)
        diff = abs(out1["final_loss"] - out2["final_loss"])
        print(f"   restart reproduces trajectory: |loss diff| = {diff:.2e}")
        assert diff < 1e-5

        print("\n== elastic re-mesh: restore the checkpoint onto a new mesh ==")
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        step = ckpt_lib.latest_step(a)
        like = {"params": out1["params"], "opt": None}
        # restore params-only onto a trivial 1x1 mesh with fresh shardings
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        flat_like = {"params": out1["params"]}
        shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), flat_like)
        state = ckpt_lib.restore(a, step, {"params": out1["params"],
                                           "opt": __import__("repro.optim.adamw", fromlist=["init_opt_state"]).init_opt_state(out1["params"])})
        print(f"   restored step {step} onto mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}; "
              f"{len(jax.tree.leaves(state))} leaves intact")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()

"""Terastal as an LM serving controller on TPU mesh partitions.

Four LMs (1B / 7B / 12B / 235B-MoE) serve periodic request streams with
deadlines on one 16x16 pod carved into heterogeneous slices (1 wide +
2 narrow).  Per-(model, partition) decode-chunk latencies come from the
analytic TPU roofline; the scheduling is the SAME Algorithm 1 + 2 code
as the faithful reproduction — see repro.runtime.serve_runtime.

Run:  PYTHONPATH=src python examples/lm_serve_terastal.py
"""

import numpy as np

from repro.configs import get_config
from repro.core.scheduler import ALL_SCHEDULERS
from repro.runtime.serve_runtime import (
    ServingModel,
    build_serving_plan,
    decode_chunk_latency,
    default_partitions,
    serve_workload,
)


def main():
    parts = default_partitions()
    models = [
        ServingModel(get_config("llama3.2-1b"), ctx_len=2048, batch=8, redundancy=0.5),
        ServingModel(get_config("gemma-7b"), ctx_len=4096, batch=8, redundancy=0.7),
        ServingModel(get_config("mistral-nemo-12b"), ctx_len=8192, batch=8, redundancy=0.7),
        ServingModel(get_config("qwen3-moe-235b-a22b"), ctx_len=4096, batch=4, redundancy=0.85),
    ]
    print("per-chunk decode latency (ms) by partition — the heterogeneity table:")
    print(f"{'model':>24} " + " ".join(f"{p.name:>14}" for p in parts))
    for sm in models:
        lats = [1e3 * decode_chunk_latency(sm.cfg, p, sm.chunk, sm.ctx_len, sm.batch) for p in parts]
        pref = int(np.argmin(lats))
        row = " ".join(f"{l:>13.2f}{'*' if i == pref else ' '}" for i, l in enumerate(lats))
        print(f"{sm.cfg.name:>24} {row}")

    from benchmarks.bench_lm_serving import _calibrated_rates

    rates = _calibrated_rates(models)
    print(f"\nrequest rates (1/s): {rates}")
    print(f"{'scheduler':>22} {'miss%':>7} {'accloss%':>9} {'util':>6}")
    for name in ALL_SCHEDULERS:
        res = serve_workload(models, rates, scheduler=name, duration=6.0)
        losses = [s.mean_norm_accuracy_loss for s in res.per_model.values() if s.completed]
        print(f"{name:>22} {100*res.mean_miss_rate:7.2f} "
              f"{100*float(np.mean(losses)) if losses else 0:9.2f} "
              f"{float(np.mean(res.utilization())):6.2f}")


if __name__ == "__main__":
    main()

"""SoA engine differential tests: the structure-of-arrays event loop
must reproduce the retained reference engine bit-for-bit — every
``SimResult`` field, across schedulers x arrival processes x budget
policies — plus engine-dispatch semantics and the scheduler-invocation
(batched simultaneous events) accounting."""

import numpy as np
import pytest

from repro.core import (
    ALL_SCHEDULERS,
    SCENARIOS,
    TaskSpec,
    make_scheduler,
    simulate,
)
from repro.core import engine_soa
from repro.core import simulator as simulator_mod
from repro.core.budget import distribute_budgets
from repro.core.scheduler import FcfsScheduler
from repro.core.simulator import SIM_ENGINES, make_arrival_process
from repro.core.variants import ModelPlan
from repro.costmodel.dnn_zoo import DnnModel
from repro.costmodel.layers import matmul
from repro.costmodel.maestro import PLATFORMS, Accelerator, Dataflow, Platform


def _fingerprint(res):
    """Every observable field, exact — the canonical SimResult equality
    key shared with the benchmark bit-identity gates."""
    return res.fingerprint()


def _both(plans, tasks, duration, sched_spec, seed, procs=None, policy="static"):
    ref = simulate(plans, tasks, duration, make_scheduler(sched_spec), seed=seed,
                   processes=procs, budget_policy=policy, engine="reference")
    soa = simulate(plans, tasks, duration, make_scheduler(sched_spec), seed=seed,
                   processes=procs, budget_policy=policy, engine="soa")
    return ref, soa


# ------------------------------------------------------------ parity ----


def test_soa_identical_all_schedulers_periodic():
    plans, tasks = SCENARIOS["ar_gaming_heavy"].plans(PLATFORMS["6k_1ws2os"])
    for name in ALL_SCHEDULERS:
        for seed in (0, 1):
            ref, soa = _both(plans, tasks, 1.0, name, seed)
            assert _fingerprint(ref) == _fingerprint(soa), (name, seed)


def test_soa_identical_across_arrivals_and_policies():
    plans, tasks = SCENARIOS["multicam_light"].plans(PLATFORMS["4k_1ws2os"])
    for arr in ("periodic(jitter=0.5)", "poisson", "mmpp(burstiness=8)"):
        procs = [make_arrival_process(arr)] * len(tasks)
        for name in ("fcfs", "edf", "dream", "terastal"):
            for policy in ("static", "reclaim", "adaptive"):
                ref, soa = _both(plans, tasks, 0.6, name, 3, procs, policy)
                assert _fingerprint(ref) == _fingerprint(soa), (arr, name, policy)


def test_soa_identical_backfill_ablations():
    """The stage-2 guard variants exercise the kernel's rarely-hit paths
    (unconditional backfill, positive-delta gate)."""
    plans, tasks = SCENARIOS["ar_social"].plans(PLATFORMS["4k_1ws2os"])
    procs = [make_arrival_process("mmpp(burstiness=4)")] * len(tasks)
    for spec in ("terastal(backfill_mode=paper)", "terastal(backfill_mode=positive)",
                 "terastal_no_budgeting", "terastal_no_variants"):
        ref, soa = _both(plans, tasks, 0.8, spec, 0, procs)
        assert _fingerprint(ref) == _fingerprint(soa), spec


def test_soa_identical_under_overload_drops():
    """Deep queues + early drops: the vectorized drop path and its scalar
    guard must fire exactly where the reference's per-request loop does."""
    from repro.costmodel.dnn_zoo import vgg11
    from repro.core.variants import build_model_plan

    plat = PLATFORMS["4k_1ws2os"]
    plan = build_model_plan(vgg11(448), plat, deadline=1 / 60)
    tasks = [TaskSpec(0, fps=60)]
    for name in ("fcfs", "terastal"):
        ref, soa = _both([plan], tasks, 1.0, name, 0)
        assert _fingerprint(ref) == _fingerprint(soa)
        assert sum(s.dropped for s in ref.per_model.values()) > 0  # drops exercised


# ------------------------------------------------- engine dispatch ----


class _CustomScheduler(FcfsScheduler):
    """A user subclass: schedule() semantics could differ, so 'auto' must
    route it through the reference engine rather than the FCFS kernel."""

    name = "custom"


def test_engine_dispatch_and_fallback():
    plans, tasks = SCENARIOS["ar_social"].plans(PLATFORMS["4k_1ws2os"])
    assert not engine_soa.supports_scheduler(_CustomScheduler())
    # auto == soa for built-ins
    auto = simulate(plans, tasks, 0.5, make_scheduler("edf"), seed=0)
    soa = simulate(plans, tasks, 0.5, make_scheduler("edf"), seed=0, engine="soa")
    assert _fingerprint(auto) == _fingerprint(soa)
    # subclass falls back to the reference loop but still runs fine
    ref = simulate(plans, tasks, 0.5, FcfsScheduler(), seed=0, engine="reference")
    via_auto = simulate(plans, tasks, 0.5, _CustomScheduler(), seed=0, engine="auto")
    got = _fingerprint(via_auto)
    want = _fingerprint(ref)
    assert got[1:] == want[1:]  # same trajectory, different scheduler_name
    # forcing soa on an unsupported scheduler is an explicit error
    with pytest.raises(ValueError, match="no kernel"):
        simulate(plans, tasks, 0.5, _CustomScheduler(), seed=0, engine="soa")
    with pytest.raises(ValueError, match="unknown engine"):
        simulate(plans, tasks, 0.5, FcfsScheduler(), seed=0, engine="fast")
    assert set(SIM_ENGINES) == {"auto", "soa", "reference", "batch"}


def test_env_var_selects_engine(monkeypatch):
    plans, tasks = SCENARIOS["ar_social"].plans(PLATFORMS["4k_1ws2os"])
    monkeypatch.setenv("REPRO_SIM_ENGINE", "reference")
    ref = simulate(plans, tasks, 0.3, make_scheduler("fcfs"), seed=0)
    monkeypatch.setenv("REPRO_SIM_ENGINE", "soa")
    soa = simulate(plans, tasks, 0.3, make_scheduler("fcfs"), seed=0)
    assert _fingerprint(ref) == _fingerprint(soa)
    # the override also reaches campaign trials, whose TrialSpecs carry
    # the explicit default "auto" (debugging escape hatch): with the env
    # forcing the reference engine, the SoA engine must not be entered
    calls = {"n": 0}
    orig_soa = engine_soa.simulate_soa

    def counting_soa(*a, **kw):
        calls["n"] += 1
        return orig_soa(*a, **kw)

    monkeypatch.setattr(engine_soa, "simulate_soa", counting_soa)
    monkeypatch.setenv("REPRO_SIM_ENGINE", "reference")
    simulate(plans, tasks, 0.3, make_scheduler("fcfs"), seed=0, engine="auto")
    assert calls["n"] == 0
    # ... while an explicit engine argument beats the env var
    simulate(plans, tasks, 0.3, make_scheduler("fcfs"), seed=0, engine="soa")
    assert calls["n"] == 1


# ------------------------- scheduler-invocation hot path (batching) ----


def _tiny_cell(n_models=3, n_acc=3):
    """K single-layer models released in lockstep: every arrival instant
    carries K simultaneous arrival events, and all finish events land at
    distinct timestamps (same latency row, distinct accelerators)."""
    lat = np.array([[0.0031, 0.0037, 0.0041]])[:, :n_acc]
    plat = Platform("t", tuple(
        Accelerator(f"a{k}", Dataflow.WS, 1024) for k in range(n_acc)
    ))
    plans = []
    for i in range(n_models):
        model = DnnModel(f"m{i}", [matmul("l0", 8, 8, 8)], redundancy=0.5)
        plans.append(ModelPlan(
            model=model, platform=plat, deadline=0.1, lat=lat.copy(),
            budget=distribute_budgets(lat, 0.1), variants={}, theta=0.9,
        ))
    tasks = [TaskSpec(model_idx=i, fps=10) for i in range(n_models)]
    return plans, tasks


def test_scheduler_invoked_once_per_distinct_timestamp():
    """The batched-simultaneous-events path (the |heap[0] - now| < 1e-15
    skip) must invoke the scheduler exactly once per distinct event
    timestamp, in BOTH engines: K simultaneous arrivals trigger one
    round, not K.  With K single-layer models at the same fps over T
    periods, the distinct timestamps are T arrival instants + K*T
    distinct finishes."""
    K = 3
    plans, tasks = _tiny_cell(n_models=K)
    duration = 1.05
    T = int(np.floor(duration * 10))  # releases per task
    expected_rounds = T + K * T

    # reference engine: count drop_hopeless calls == invoke_scheduler calls
    calls = {"n": 0}
    orig_drop = simulator_mod.drop_hopeless

    def counting_drop(*a, **kw):
        calls["n"] += 1
        return orig_drop(*a, **kw)

    simulator_mod.drop_hopeless = counting_drop
    try:
        ref = simulate(plans, tasks, duration, make_scheduler("fcfs"), seed=0,
                       engine="reference")
    finally:
        simulator_mod.drop_hopeless = orig_drop
    assert calls["n"] == expected_rounds

    assert ref.rounds == expected_rounds  # reference engine telemetry

    # SoA engine: the per-result round counter must agree exactly
    soa = simulate(plans, tasks, duration, make_scheduler("fcfs"), seed=0,
                   engine="soa")
    assert soa.rounds == expected_rounds
    assert _fingerprint(ref) == _fingerprint(soa)
    # sanity: everything released and completed, nothing dropped
    assert sum(s.released for s in soa.per_model.values()) == K * T
    assert sum(s.completed for s in soa.per_model.values()) == K * T


def test_soa_builds_no_schedview():
    """The SoA engine hands schedulers array state, never a SchedView."""
    import repro.core.scheduler as sched_mod

    plans, tasks = SCENARIOS["ar_social"].plans(PLATFORMS["4k_1ws2os"])
    constructed = {"n": 0}
    orig = sched_mod.SchedView.__init__

    def counting_init(self, *a, **kw):
        constructed["n"] += 1
        return orig(self, *a, **kw)

    sched_mod.SchedView.__init__ = counting_init
    try:
        simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0, engine="soa")
        assert constructed["n"] == 0
        simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0,
                 engine="reference")
        assert constructed["n"] > 0  # the reference builds one per invocation
    finally:
        sched_mod.SchedView.__init__ = orig


# ------------------------------------------------ hypothesis property ----

try:  # optional test extra — only the property test skips without it
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

_CELLS = (
    ("ar_social", "4k_1ws2os"),
    ("ar_gaming_heavy", "6k_1ws2os"),
    ("multicam_light", "4k_1ws2os"),
)
_SCHEDS = ALL_SCHEDULERS + (
    "terastal(backfill_mode=paper)",
    "terastal(backfill_mode=positive)",
)
_ARRIVALS = (None, "periodic(jitter=0.7)", "poisson", "mmpp(burstiness=8)",
             "mmpp(burstiness=2,on_fraction=0.5)")
_POLICIES = ("static", "reclaim", "reclaim(spread=0.5)", "adaptive",
             "adaptive(tick=0.02,skew_min=2)")


if _HAVE_HYPOTHESIS:

    @st.composite
    def _scenarios(draw):
        cell = draw(st.sampled_from(_CELLS))
        sched = draw(st.sampled_from(_SCHEDS))
        arr = draw(st.sampled_from(_ARRIVALS))
        policy = draw(st.sampled_from(_POLICIES))
        seed = draw(st.integers(0, 2**16))
        duration = draw(st.sampled_from((0.15, 0.3, 0.5)))
        return cell, sched, arr, policy, seed, duration

    @given(_scenarios())
    @settings(max_examples=30, deadline=None)
    def test_soa_engine_differential_property(case):
        """Random (scenario x scheduler x arrival x budget-policy x seed)
        draws: the SoA engine's SimResult must equal the reference
        engine's bit-for-bit."""
        (sc, pn), sched, arr, policy, seed, duration = case
        plans, tasks = SCENARIOS[sc].plans(PLATFORMS[pn])
        procs = [make_arrival_process(arr)] * len(tasks) if arr else None
        ref, soa = _both(plans, tasks, duration, sched, seed, procs, policy)
        assert _fingerprint(ref) == _fingerprint(soa)

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed (optional test extra)")
    def test_soa_engine_differential_property():
        pass

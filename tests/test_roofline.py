"""Roofline analytics: parameter-count validation, cost_analysis facts,
collective parser, and the optimized-config gains from EXPERIMENTS §Perf."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.analytics import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    active_params,
    collective_bytes_est,
    hbm_bytes,
    model_flops,
    roofline,
    total_params,
)
from repro.launch.dryrun import collective_bytes
from repro.launch.roofline import cost_analysis_dict
from repro.models.model_api import SHAPES


def test_xla_cost_analysis_counts_scan_body_once():
    """The documented fact that motivates analytic FLOPs: XLA cost
    analysis does NOT multiply scan-body FLOPs by the trip count."""

    def scanned(x, ws):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((16, 128, 128), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    flops = cost_analysis_dict(c).get("flops", 0.0)
    one_matmul = 2 * 128**3
    assert flops < 2 * one_matmul  # counted ~once, not 16x


def test_flops_formula_matches_xla_on_unrolled_tiny_dense():
    """Validate the analytic *computed* FLOPs against XLA's exact count
    on an unrolled (non-scanned, non-remat) tiny dense model."""
    from repro.models.model_api import build_model
    from repro.models.transformer import dense_block_apply

    cfg = get_config("llama3.2-1b").reduced(
        dtype="float32", n_layers=2, attn_q_chunk=64, attn_k_chunk=64
    )
    cfg = dataclasses.replace(cfg, remat=False)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    B, L = 2, 64

    def fwd(params, tokens):
        from repro.models.common import embed
        from repro.models.transformer import forward_hidden_dense, _lm_head_w

        x = embed(params["embed"], tokens)
        pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
        h = forward_hidden_dense(cfg, params, x, pos)
        return h @ _lm_head_w(cfg, params)

    tok = jax.ShapeDtypeStruct((B, L), jnp.int32)
    c = jax.jit(fwd).lower(params, tok).compile()
    xla_flops = cost_analysis_dict(c)["flops"]
    # analytic prefill-style forward (matmul+attention) for this shape
    from repro.launch.analytics import attn_flops_fwd, matmul_params

    ours = 2.0 * matmul_params(cfg, True) * B * L + attn_flops_fwd(cfg, B, L, cfg.n_layers)
    # scan with n_layers=2 still under-counts; compare against the
    # per-layer-corrected value instead: xla = base + 1x layer, ours has 2
    assert ours > 0.5 * xla_flops  # sanity: same order


def test_param_totals_vs_flops_consistency():
    for arch in ("llama3.2-1b", "gemma-7b", "qwen3-moe-235b-a22b"):
        cfg = get_config(arch)
        fl = model_flops(cfg, SHAPES["train_4k"])
        tokens = 4096 * 256
        assert fl["useful"] == 6.0 * active_params(cfg) * tokens
        assert fl["computed"] > fl["useful"] * 0.5


def test_collective_parser():
    hlo = """
  %x = bf16[1024,512]{1,0} all-gather(bf16[64,512]{1,0} %a), dimensions={0}
  %y = f32[256]{0} all-reduce(f32[256]{0} %b), to_apply=%sum
  %z = bf16[8,8]{1,0} add(bf16[8,8]{1,0} %c, bf16[8,8]{1,0} %d)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 1024 * 512 * 2
    assert got["all-reduce"] == 256 * 4
    assert got["total"] == got["all-gather"] + got["all-reduce"]


def test_roofline_terms_positive_and_bottleneck_sane():
    for arch, shape in [("llama4-maverick-400b-a17b", "train_4k"),
                        ("codeqwen1.5-7b", "decode_32k"),
                        ("mamba2-1.3b", "long_500k")]:
        r = roofline(get_config(arch), shape)
        assert r.compute_s > 0 and r.memory_s > 0 and r.collective_s >= 0
        assert r.bottleneck in ("compute", "memory", "collective")
        assert 0 < r.roofline_fraction <= 1.0


def test_decode_is_memory_bound():
    r = roofline(get_config("codeqwen1.5-7b"), "decode_32k")
    assert r.bottleneck == "memory"


def test_perf_optimizations_improve_modeled_step():
    """EXPERIMENTS §Perf: each hillclimb lever strictly improves its cell."""
    # mamba2 train: ZeRO-1
    base = roofline(get_config("mamba2-1.3b"), "train_4k")
    opt = roofline(dataclasses.replace(get_config("mamba2-1.3b"), fsdp_all_axes=True), "train_4k")
    assert opt.step_s < 0.5 * base.step_s
    assert opt.bottleneck == "compute"
    # codeqwen decode: int8 KV
    base = roofline(get_config("codeqwen1.5-7b"), "decode_32k")
    opt = roofline(dataclasses.replace(get_config("codeqwen1.5-7b"), kv_cache_quant=True), "decode_32k")
    assert opt.step_s < 0.6 * base.step_s
    # llama4 train: parallel block reduces the collective term
    base = roofline(get_config("llama4-maverick-400b-a17b"), "train_4k")
    opt = roofline(dataclasses.replace(get_config("llama4-maverick-400b-a17b"), parallel_block=True), "train_4k")
    assert opt.collective_s < base.collective_s


def test_all_cells_fit_hbm_budget():
    """Weights + optimizer (train) or weights + cache (decode) per device
    stay under the 16 GB v5e HBM (the dry-run's argument_bytes confirms
    the compiled truth; this checks the analytic accounting)."""
    from repro.configs.registry import all_cells

    HBM = 16e9
    for arch, shape in all_cells():
        cfg = get_config(arch)
        n_dev = 256
        if SHAPES[shape].kind == "train":
            per_dev = total_params(cfg) * (2 + 8) / n_dev  # bf16 + f32 m,v
        else:
            from repro.launch.analytics import cache_bytes

            per_dev = (total_params(cfg) * 2 + cache_bytes(cfg, SHAPES[shape])) / n_dev
        assert per_dev < HBM, (arch, shape, per_dev / 1e9)

"""Conservation-law property tests, both engines, all scenario catalogs.

Every request released into the system is accounted for exactly once:
``released == completed + dropped + in_flight`` per model (``in_flight``
is what the horizon end caught in the ready set or on an accelerator),
with ``missed >= dropped`` (drops always miss) and ``shed <= dropped``
(shedding is a form of dropping, decided at the admission door).  The
tentpole's new counters enter under an invariant that already held for
the seed semantics — any future engine or policy change that leaks a
request fails here on both engines."""

import pytest

from repro.core import make_scheduler, simulate
from repro.core.workload import (
    OVERLOAD_SCENARIOS,
    SATURATION_SCENARIOS,
    SCENARIOS,
    get_scenario,
)
from repro.costmodel.maestro import PLATFORMS

#: one cell per catalog family — paper, saturation, overload — chosen to
#: exercise light load, deep-queue overload, and closed-loop traffic.
_CELLS = [
    ("ar_social", "4k_1ws2os"),
    ("multicam_light", "4k_1ws2os"),
    ("ar_gaming_heavy", "6k_1ws2os"),
    ("saturation_5x", "4k_1ws2os"),
    ("saturation_8x", "6k_1ws2os"),
    ("overload_diurnal", "4k_1ws2os"),
    ("overload_flash", "4k_1ws2os"),
    ("overload_two_tier", "4k_1ws2os"),
    ("overload_closed_loop", "4k_1ws2os"),
]


def _check(res, admission):
    assert res.per_model, "simulation produced no per-model stats"
    for m, st in sorted(res.per_model.items()):
        assert st.released == st.completed + st.dropped + st.in_flight, (
            f"model {m}: released={st.released} != completed={st.completed}"
            f" + dropped={st.dropped} + in_flight={st.in_flight}"
        )
        assert st.missed >= st.dropped, (m, st.missed, st.dropped)
        assert st.shed <= st.dropped, (m, st.shed, st.dropped)
        assert st.admitted == st.released - st.shed
        if admission == "none":
            assert st.shed == 0
        assert st.in_flight >= 0 and st.shed >= 0


@pytest.mark.parametrize("engine", ["reference", "soa"])
@pytest.mark.parametrize("cell", _CELLS, ids=[f"{s}@{p}" for s, p in _CELLS])
def test_conservation_all_catalogs(cell, engine):
    scenario, platform = cell
    plans, tasks = get_scenario(scenario).plans(PLATFORMS[platform], theta=0.90)
    procs = [t.arrival for t in tasks]
    for sched in ("terastal", "edf"):
        for admission in ("none", "shed_early(margin=1.5)",
                          "token_bucket(rate=60,burst=4)"):
            res = simulate(
                plans, tasks, 0.3, make_scheduler(sched), seed=0,
                processes=procs, admission=admission, engine=engine,
            )
            _check(res, admission)


def test_catalogs_are_disjoint_and_resolvable():
    """The three catalogs share no names and every name resolves."""
    cats = [set(SCENARIOS), set(SATURATION_SCENARIOS), set(OVERLOAD_SCENARIOS)]
    for i in range(len(cats)):
        for j in range(i + 1, len(cats)):
            assert not (cats[i] & cats[j])
    for name in set().union(*cats):
        assert get_scenario(name).name == name

"""Conservation-law property tests, both engines, all scenario catalogs.

Every request released into the system is accounted for exactly once:
``released == completed + dropped + in_flight`` per model (``in_flight``
is what the horizon end caught in the ready set or on an accelerator),
with ``missed >= dropped`` (drops always miss) and ``shed <= dropped``
(shedding is a form of dropping, decided at the admission door).  The
tentpole's new counters enter under an invariant that already held for
the seed semantics — any future engine or policy change that leaks a
request fails here on both engines.  Fault injection adds
``remapped <= evicted`` (a re-dispatch needs a prior eviction) and must
never break request conservation: an evicted request is still released
and still ends as completed, dropped, or in_flight."""

import pytest

from repro.core import make_scheduler, simulate
from repro.core.workload import (
    DAG_SCENARIOS,
    FAULT_SCENARIOS,
    OVERLOAD_SCENARIOS,
    SATURATION_SCENARIOS,
    SCENARIOS,
    get_scenario,
)
from repro.costmodel.maestro import PLATFORMS

#: one cell per catalog family — paper, saturation, overload — chosen to
#: exercise light load, deep-queue overload, and closed-loop traffic.
_CELLS = [
    ("ar_social", "4k_1ws2os"),
    ("multicam_light", "4k_1ws2os"),
    ("ar_gaming_heavy", "6k_1ws2os"),
    ("saturation_5x", "4k_1ws2os"),
    ("saturation_8x", "6k_1ws2os"),
    ("overload_diurnal", "4k_1ws2os"),
    ("overload_flash", "4k_1ws2os"),
    ("overload_two_tier", "4k_1ws2os"),
    ("overload_closed_loop", "4k_1ws2os"),
    ("dag_asr_encdec", "6k_1ws2os"),
    ("dag_moe_4expert", "6k_1os2ws"),
]


def _check(res, admission, faults="none"):
    assert res.per_model, "simulation produced no per-model stats"
    for m, st in sorted(res.per_model.items()):
        assert st.released == st.completed + st.dropped + st.in_flight, (
            f"model {m}: released={st.released} != completed={st.completed}"
            f" + dropped={st.dropped} + in_flight={st.in_flight}"
        )
        assert st.missed >= st.dropped, (m, st.missed, st.dropped)
        assert st.shed <= st.dropped, (m, st.shed, st.dropped)
        assert st.admitted == st.released - st.shed
        if admission == "none":
            assert st.shed == 0
        assert st.in_flight >= 0 and st.shed >= 0
        assert st.remapped <= st.evicted, (m, st.remapped, st.evicted)
        if faults in (None, "none"):
            assert st.evicted == 0 and st.remapped == 0
    if faults in (None, "none"):
        assert res.faulted_spans == 0


@pytest.mark.parametrize("engine", ["reference", "soa"])
@pytest.mark.parametrize("cell", _CELLS, ids=[f"{s}@{p}" for s, p in _CELLS])
def test_conservation_all_catalogs(cell, engine):
    scenario, platform = cell
    plans, tasks = get_scenario(scenario).plans(PLATFORMS[platform], theta=0.90)
    procs = [t.arrival for t in tasks]
    for sched in ("terastal", "edf"):
        for admission in ("none", "shed_early(margin=1.5)",
                          "token_bucket(rate=60,burst=4)"):
            res = simulate(
                plans, tasks, 0.3, make_scheduler(sched), seed=0,
                processes=procs, admission=admission, engine=engine,
            )
            _check(res, admission)


#: faulted cells: every FAULT_SCENARIOS member under its own injection,
#: plus paper/saturation/overload cells under explicit fault specs —
#: conservation must hold with evictions, re-timing, and resume active.
_FAULT_CELLS = [
    ("fault_dropout", "6k_1ws2os", "scenario"),
    ("fault_brownout", "6k_1os2ws", "scenario"),
    ("fault_flash_crowd", "6k_1ws2os", "scenario"),
    ("ar_social", "4k_1ws2os", "down(acc=0,start=0.05,duration=0.15)"),
    ("saturation_5x", "4k_1ws2os",
     "down(acc=1,start=0.05,duration=0.1,interrupted=resume)"
     "+throttle(acc=2,start=0.1,duration=0.15,factor=3.0)"),
    ("overload_closed_loop", "4k_1ws2os", "permanent(acc=0,start=0.1)"),
    ("multicam_heavy", "6k_1ws2os",
     "intermittent(acc=1,rate=10.0,mean_down=0.05)"),
    # PR 10: faults compose with DAG plans — eviction of a branch node,
    # sibling snapshot refresh, and re-tightened rebinding all conserve
    ("fault_dag_dropout", "6k_1ws2os", "scenario"),
    ("dag_moe_4expert", "6k_1os2ws",
     "intermittent(acc=1,rate=10.0,mean_down=0.05,retighten=true)"),
]


@pytest.mark.parametrize("engine", ["reference", "soa"])
@pytest.mark.parametrize(
    "cell", _FAULT_CELLS, ids=[f"{s}@{p}" for s, p, _ in _FAULT_CELLS])
def test_conservation_under_faults(cell, engine):
    scenario, platform, faults = cell
    sc = get_scenario(scenario)
    if faults == "scenario":
        faults = sc.faults
    plans, tasks = sc.plans(PLATFORMS[platform], theta=0.90)
    procs = [t.arrival for t in tasks]
    for sched in ("terastal", "edf"):
        for admission in ("none", "shed_early(margin=1.5)"):
            res = simulate(
                plans, tasks, 0.3, make_scheduler(sched), seed=0,
                processes=procs, admission=admission, faults=faults,
                engine=engine,
            )
            _check(res, admission, faults)


#: restart-policy fault cells the batch engine now runs on device
#: (PR 10): linear plans, open-loop arrivals, no admission — the batch
#: lane's supported slice of the fault axis.
_BATCH_FAULT_CELLS = [
    ("fault_dropout", "6k_1ws2os", "scenario"),
    ("fault_brownout", "6k_1os2ws", "scenario"),
    ("multicam_heavy", "6k_1ws2os",
     "intermittent(acc=1,rate=10.0,mean_down=0.05,retighten=true)"),
]


@pytest.mark.parametrize(
    "cell", _BATCH_FAULT_CELLS,
    ids=[f"{s}@{p}" for s, p, _ in _BATCH_FAULT_CELLS])
def test_conservation_under_faults_batch_engine(cell):
    from repro.core.engine_batch import simulate_batch

    scenario, platform, faults = cell
    sc = get_scenario(scenario)
    if faults == "scenario":
        faults = sc.faults
    plans, tasks = sc.plans(PLATFORMS[platform], theta=0.90)
    procs = [t.arrival for t in tasks]
    for sched in ("terastal", "edf"):
        for res in simulate_batch(plans, tasks, 0.3, make_scheduler(sched),
                                  seeds=[0, 1], processes=procs,
                                  faults=faults):
            _check(res, "none", faults)


def test_catalogs_are_disjoint_and_resolvable():
    """The five catalogs share no names and every name resolves."""
    cats = [set(SCENARIOS), set(SATURATION_SCENARIOS), set(OVERLOAD_SCENARIOS),
            set(FAULT_SCENARIOS), set(DAG_SCENARIOS)]
    for i in range(len(cats)):
        for j in range(i + 1, len(cats)):
            assert not (cats[i] & cats[j])
    for name in set().union(*cats):
        assert get_scenario(name).name == name

"""Cost model: WS/OS affinity structure, variant transform invariants."""

import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test-extra; skip, don't error, when absent
from hypothesis import given, settings, strategies as st

from repro.costmodel import (
    PLATFORMS,
    conv,
    dwconv,
    fc,
    layer_latency,
    make_variant,
    matmul,
    model_latency_table,
)
from repro.costmodel.dnn_zoo import ZOO, get_model, vgg11
from repro.costmodel.layers import variant_feasible
from repro.costmodel.maestro import Dataflow


@pytest.fixture(scope="module")
def plat():
    return PLATFORMS["6k_1ws2os"]


def _ws_os(plat):
    ws = next(a for a in plat.accelerators if a.dataflow == Dataflow.WS)
    os_ = next(a for a in plat.accelerators if a.dataflow == Dataflow.OS)
    return ws, os_


def test_late_vgg_layers_prefer_ws(plat):
    """Paper Fig. 3: later VGG11 layers are 2x-8x slower on OS."""
    ws, os_ = _ws_os(plat)
    late = conv("conv8", 512, 512, 3, 3, 14, 14)
    r = layer_latency(late, os_, plat) / layer_latency(late, ws, plat)
    assert r > 2.0


def test_early_large_map_layers_prefer_os(plat):
    ws, os_ = _ws_os(plat)
    early = conv("conv1", 64, 3, 3, 3, 224, 224)
    assert layer_latency(early, os_, plat) < layer_latency(early, ws, plat)


def test_depthwise_large_map_prefers_os(plat):
    ws, os_ = _ws_os(plat)
    dw = dwconv("dw", 96, 3, 3, 112, 112)
    assert layer_latency(dw, os_, plat) < layer_latency(dw, ws, plat)


def test_fc_strongly_prefers_ws(plat):
    ws, os_ = _ws_os(plat)
    f = fc("fc", 4096, 4096)
    assert layer_latency(f, os_, plat) > 10 * layer_latency(f, ws, plat)


def test_variant_closes_os_gap(plat):
    """Paper Sec. V-B1: gamma in {2,3} brings non-preferred latency to at
    or below the preferred accelerator's."""
    ws, os_ = _ws_os(plat)
    late = conv("conv8", 512, 512, 3, 3, 14, 14)
    v = make_variant(late, 2, "d2s")
    assert layer_latency(v, os_, plat) <= layer_latency(late, ws, plat)


def test_variant_weight_reduction_gamma4():
    l = conv("c", 512, 256, 3, 3, 28, 28)
    v = make_variant(l, 2, "d2s")
    assert v.weights * 16 == l.weights


def test_variant_gamma3_requires_divisibility():
    l = conv("c", 512, 256, 3, 3, 28, 28)
    assert not variant_feasible(l, 3, "d2s")
    with pytest.raises(ValueError):
        make_variant(l, 3, "d2s")


def test_variant_preserves_io_shape_semantics():
    """D2S->conv->S2D restores the original output tensor shape: the
    variant's raw output (gamma*Ho, gamma*Wo, K/gamma^2) folds back to
    (Ho, Wo, K)."""
    l = conv("c", 64, 16, 3, 3, 32, 32)
    v = make_variant(l, 2, "d2s")
    assert v.K * 4 == l.K
    assert v.Ho == l.Ho * 2 and v.Wo == l.Wo * 2
    assert v.K * v.Ho * v.Wo == l.K * l.Ho * l.Wo  # same output volume


def test_variant_macs_reduced_by_gamma2():
    l = conv("c", 64, 16, 3, 3, 32, 32)
    v = make_variant(l, 2, "d2s")
    assert v.macs * 4 == l.macs


def test_reverse_variant_increases_weights():
    l = conv("c", 16, 4, 3, 3, 64, 64)
    v = make_variant(l, 2, "s2d")
    assert v.weights == 16 * l.weights


def test_latency_positive_and_finite_all_zoo():
    plat = PLATFORMS["4k_1ws2os"]
    for name in ZOO:
        tab = model_latency_table(get_model(name).layers, plat)
        assert np.isfinite(tab).all() and (tab > 0).all()


def test_zoo_mac_counts_sane():
    """MAC totals near published figures (within loose factor)."""
    approx = {
        "vgg11": 4.2e9,  # ~3.8G conv+fc at 224 (ours: same-pad)
        "resnet50": 4.1e9,
        "swin_tiny": 4.5e9,
        "fbnet_c": 0.38e9,
    }
    for name, macs in approx.items():
        got = get_model(name).total_macs
        assert 0.5 * macs < got < 2.0 * macs, (name, got)


@given(
    K=st.sampled_from([16, 32, 64, 128]),
    C=st.sampled_from([16, 32, 64]),
    H=st.sampled_from([8, 16, 28, 56]),
    gamma=st.sampled_from([2]),
)
@settings(max_examples=60, deadline=None)
def test_property_d2s_variant_always_cuts_weights_and_macs(K, C, H, gamma):
    l = conv("c", K, C, 3, 3, H, H)
    v = make_variant(l, gamma, "d2s")
    g4 = gamma**4
    assert v.weights == l.weights // g4
    assert v.macs * gamma**2 == l.macs


@given(
    pes=st.sampled_from([256, 1024, 2048, 4096]),
    K=st.integers(8, 512),
    C=st.integers(8, 512),
    H=st.sampled_from([7, 14, 28, 56]),
)
@settings(max_examples=60, deadline=None)
def test_property_latency_monotone_in_pes(pes, K, C, H):
    """More PEs never increases modeled latency (same dataflow)."""
    from repro.costmodel.maestro import Accelerator, Platform

    l = conv("c", K, C, 3, 3, H, H)
    plat = PLATFORMS["6k_1ws2os"]
    for df in (Dataflow.WS, Dataflow.OS):
        small = Accelerator("s", df, pes)
        big = Accelerator("b", df, pes * 2)
        assert layer_latency(l, big, plat) <= layer_latency(l, small, plat) + 1e-12

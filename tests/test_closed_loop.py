"""Closed-loop client model: releases gate on completions, so the event
loop integrates the release source directly in both engines — these tests
pin the ref-vs-SoA bit-identity, the self-throttling invariant (at most
one request in flight per user), session drain, flash-crowd fronts,
validation, and campaign determinism."""

import dataclasses
import math

import pytest

from repro.core import (
    Campaign,
    ClosedLoopClients,
    DiurnalArrivals,
    make_arrival_process,
    make_scheduler,
    simulate,
)
from repro.core.simulator import generate_arrivals, generate_release_events
from repro.core.workload import OVERLOAD_SCENARIOS, get_scenario
from repro.costmodel.maestro import PLATFORMS


def _cell(scenario, platform, theta=0.90):
    sc = get_scenario(scenario)
    return sc.plans(PLATFORMS[platform], theta=theta)


def _both(plans, tasks, duration, sched, procs, seed=0, policy="static",
          admission=None):
    ref = simulate(plans, tasks, duration, make_scheduler(sched), seed=seed,
                   processes=procs, budget_policy=policy, admission=admission,
                   engine="reference")
    soa = simulate(plans, tasks, duration, make_scheduler(sched), seed=seed,
                   processes=procs, budget_policy=policy, admission=admission,
                   engine="soa")
    return ref, soa


# --------------------------------------------- engine differentials ----


@pytest.mark.parametrize("sched", ["terastal", "terastal(backfill_mode=paper)",
                                   "edf", "fcfs", "dream"])
def test_closed_loop_ref_equals_soa(sched):
    plans, tasks = _cell("ar_gaming_heavy", "6k_1ws2os")
    cl = ClosedLoopClients(n_users=6, think_time=0.02)
    ref, soa = _both(plans, tasks, 0.4, sched, [cl] * len(tasks))
    assert ref.fingerprint() == soa.fingerprint()
    assert sum(s.released for s in ref.per_model.values()) > 0


def test_mixed_open_and_closed_ref_equals_soa():
    """Open-loop tasks keep their exact pre-PR variate stream while
    closed-loop tasks ride the event loop — mixed cells exercise the
    release-event merge in both engines."""
    plans, tasks = _cell("ar_gaming_heavy", "6k_1ws2os")
    cl = ClosedLoopClients(n_users=4, think_time=0.03)
    procs = [cl if i % 2 == 0 else None for i in range(len(tasks))]
    ref, soa = _both(plans, tasks, 0.4, "terastal", procs)
    assert ref.fingerprint() == soa.fingerprint()


@pytest.mark.parametrize("name", sorted(OVERLOAD_SCENARIOS))
def test_overload_scenarios_ref_equals_soa(name):
    """Every overload-catalog cell (diurnal, flash crowd, two-tier SLO,
    closed-loop saturation) is bit-identical across engines."""
    plans, tasks = _cell(name, "4k_1ws2os")
    procs = [t.arrival for t in tasks]
    ref, soa = _both(plans, tasks, 0.3, "terastal", procs)
    assert ref.fingerprint() == soa.fingerprint()


def test_closed_loop_with_admission_and_policy_ref_equals_soa():
    """The full stack at once: closed-loop releases + token-bucket
    shedding (shed requests trigger the user's next release too) + the
    adaptive budget policy."""
    plans, tasks = _cell("overload_closed_loop", "4k_1ws2os")
    procs = [t.arrival for t in tasks]
    ref, soa = _both(plans, tasks, 0.4, "terastal", procs,
                     admission="token_bucket(rate=50,burst=4)",
                     policy="adaptive")
    assert ref.fingerprint() == soa.fingerprint()
    assert sum(s.shed for s in ref.per_model.values()) > 0


# ------------------------------------------------- loop semantics ----


def test_closed_loop_self_throttles():
    """Each user keeps at most one request in flight: live requests per
    model never exceed n_users, and the conservation law holds."""
    plans, tasks = _cell("saturation_5x", "4k_1ws2os")
    cl = ClosedLoopClients(n_users=5, think_time=0.01)
    res = simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0,
                   processes=[cl] * len(tasks))
    for st in res.per_model.values():
        assert st.released == st.completed + st.dropped + st.in_flight
        # at most n_users requests can be live at any instant, including
        # the horizon end
        assert st.in_flight <= cl.n_users


def test_session_drain_bounds_releases():
    """respawn=False with session_len=k: each user issues at most k
    requests, so a model releases at most n_users * k total."""
    plans, tasks = _cell("ar_gaming_heavy", "6k_1ws2os")
    cl = ClosedLoopClients(n_users=3, think_time=0.001, session_len=4,
                           respawn=False, stagger=False)
    res = simulate(plans, tasks, 2.0, make_scheduler("terastal"), seed=0,
                   processes=[cl] * len(tasks))
    for st in res.per_model.values():
        assert 0 < st.released <= cl.n_users * cl.session_len


def test_flash_crowd_front_releases_simultaneously():
    """stagger=False puts every user's first release at exactly
    ``start`` — the flash-crowd front the overload_flash scenario uses."""
    plans, tasks = _cell("ar_gaming_heavy", "6k_1ws2os")
    cl = ClosedLoopClients(n_users=7, think_time=0.05, stagger=False)
    events, clients = generate_release_events(
        tasks[:1], 1.0, seed=0, processes=[cl])
    first = [e for e in events if e[2] >= 0]
    assert len(first) == 7
    assert all(e[0] == 0.0 for e in first)
    assert sorted(e[3] for e in first) == list(range(7))


def test_open_loop_stream_unchanged_by_closed_tasks():
    """A closed-loop task consumes NOTHING from the shared open-loop rng
    stream (its users have per-user streams), so the open-loop tasks draw
    exactly as if the closed-loop task were absent from the task list."""
    plans, tasks = _cell("multicam_light", "4k_1ws2os")
    procs = [make_arrival_process("mmpp(burstiness=4)")] * len(tasks)
    procs_mixed = list(procs)
    procs_mixed[0] = ClosedLoopClients(n_users=2, think_time=0.1)
    mixed, clients = generate_release_events(tasks, 1.0, seed=7,
                                             processes=procs_mixed)
    open_events = [(t, m) for t, m, ti, u in mixed if ti < 0]
    want = generate_arrivals(tasks[1:], 1.0, seed=7, processes=procs[1:])
    assert open_events == sorted(want)
    assert set(clients) == {0}


def test_pure_open_loop_release_events_match_generate_arrivals():
    plans, tasks = _cell("multicam_light", "4k_1ws2os")
    events, clients = generate_release_events(tasks, 1.0, seed=3)
    assert clients == {}
    assert events == generate_arrivals(tasks, 1.0, seed=3)


def test_closed_loop_seed_determinism():
    plans, tasks = _cell("ar_gaming_heavy", "6k_1ws2os")
    cl = ClosedLoopClients(n_users=6, think_time=0.02)
    procs = [cl] * len(tasks)
    a = simulate(plans, tasks, 0.4, make_scheduler("terastal"), seed=5,
                 processes=procs)
    b = simulate(plans, tasks, 0.4, make_scheduler("terastal"), seed=5,
                 processes=procs)
    c = simulate(plans, tasks, 0.4, make_scheduler("terastal"), seed=6,
                 processes=procs)
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != c.fingerprint()


# ------------------------------------------------------ validation ----


def test_closed_loop_sample_raises():
    cl = ClosedLoopClients()
    with pytest.raises(ValueError, match="cannot be pre-generated"):
        cl.sample(None, 1.0, None)


@pytest.mark.parametrize("kwargs,msg", [
    (dict(n_users=0), "n_users"),
    (dict(think_time=0.0), "think_time"),
    (dict(think_time=-1.0), "think_time"),
    (dict(session_len=-1), "session_len"),
    (dict(start=-0.1), "start"),
])
def test_closed_loop_validation(kwargs, msg):
    with pytest.raises(ValueError, match=msg):
        ClosedLoopClients(**kwargs)


@pytest.mark.parametrize("kwargs,msg", [
    (dict(period=0.0), "period"),
    (dict(depth=1.0), "depth"),
    (dict(depth=-0.1), "depth"),
])
def test_diurnal_validation(kwargs, msg):
    with pytest.raises(ValueError, match=msg):
        DiurnalArrivals(**kwargs)


def test_closed_loop_call_spec():
    p = make_arrival_process("closed_loop(n_users=9,think_time=0.25)")
    assert isinstance(p, ClosedLoopClients)
    assert p.n_users == 9 and p.think_time == 0.25
    d = make_arrival_process("diurnal(period=2.0,depth=0.5)")
    assert isinstance(d, DiurnalArrivals)
    assert d.period == 2.0 and d.depth == 0.5


# ------------------------------------------------- campaign plumbing ----


def test_closed_loop_campaign_parallel_equals_serial():
    camp = Campaign(
        scenarios=("overload_closed_loop",),
        platforms=("4k_1ws2os",),
        schedulers=("terastal",),
        admissions=("none", "token_bucket(rate=80)"),
        seeds=(0, 1),
        duration=0.3,
    )
    ser = camp.run(parallel=False)
    par = camp.run(parallel=True, max_workers=2)
    assert len(ser.trials) == 4
    for a, b in zip(ser.trials, par.trials):
        da = dataclasses.asdict(dataclasses.replace(a, wall_s=0.0))
        db = dataclasses.asdict(dataclasses.replace(b, wall_s=0.0))
        la, lb = da.pop("mean_accuracy_loss"), db.pop("mean_accuracy_loss")
        assert (la == lb) or (math.isnan(la) and math.isnan(lb))
        assert da == db

"""Campaign engine: arrival-process statistics, per-trial determinism,
parallel == serial, bootstrap aggregation math, and the regression pin
that the periodic process reproduces the seed simulator exactly."""

import numpy as np
import pytest

from repro.core import (
    Campaign,
    TrialSpec,
    bootstrap_ci,
    make_arrival_process,
    make_scheduler,
    run_trial,
    simulate,
)
from repro.core.simulator import (
    MmppArrivals,
    PeriodicArrivals,
    PoissonArrivals,
    TaskSpec,
    TraceArrivals,
    generate_arrivals,
)
from repro.core.specs import parse_call_spec
from repro.core.workload import SCENARIOS
from repro.costmodel.maestro import PLATFORMS


# ------------------------------------------------------ arrival processes -


def _seed_reference_arrivals(tasks, duration, seed):
    """The seed repo's generate_arrivals, verbatim: the regression oracle."""
    rng = np.random.default_rng(seed)
    out = []
    for task in tasks:
        n = int(np.floor(duration * task.fps))
        for j in range(n):
            if task.prob >= 1.0 or rng.random() < task.prob:
                out.append((j * task.period, task.model_idx))
    out.sort()
    return out


def test_periodic_process_bit_identical_to_seed_implementation():
    tasks = [TaskSpec(0, fps=60), TaskSpec(1, fps=30, prob=0.5), TaskSpec(2, fps=17)]
    for seed in range(5):
        ref = _seed_reference_arrivals(tasks, 3.0, seed)
        assert generate_arrivals(tasks, 3.0, seed) == ref
        procs = [PeriodicArrivals()] * len(tasks)
        assert generate_arrivals(tasks, 3.0, seed, processes=procs) == ref


def _periodic_sample_loop(proc, task, duration, rng):
    """The original per-release loop implementation of
    PeriodicArrivals.sample, verbatim: the fast-path regression oracle."""
    out = []
    n = int(np.floor(duration * task.fps))
    for j in range(n):
        if task.prob >= 1.0 or rng.random() < task.prob:
            t = j * task.period
            if proc.jitter > 0.0:
                t += rng.random() * proc.jitter * task.period
            out.append(t)
    return out


def test_periodic_fast_paths_match_loop_version():
    """The vectorized PeriodicArrivals paths (prob>=1 arange emission,
    batched thinning/jitter draws) must equal the scalar loop exactly —
    same values AND same rng-stream consumption, so everything drawn
    afterwards from the shared stream is unchanged too."""
    for prob, jitter in ((1.0, 0.0), (1.0, 0.4), (0.5, 0.0), (0.5, 0.4)):
        task = TaskSpec(0, fps=37, prob=prob)
        proc = PeriodicArrivals(jitter=jitter)
        for seed in range(4):
            r1, r2 = np.random.default_rng(seed), np.random.default_rng(seed)
            got = proc.sample(task, 3.0, r1)
            want = _periodic_sample_loop(proc, task, 3.0, r2)
            assert got == want, (prob, jitter, seed)
            assert all(isinstance(t, float) for t in got)
            # identical stream consumption: the next draw agrees
            assert r1.random() == r2.random(), (prob, jitter, seed)


def test_periodic_jitter_bounded_and_rate_preserving():
    task = TaskSpec(0, fps=30)
    rng = np.random.default_rng(7)
    times = PeriodicArrivals(jitter=0.5).sample(task, 4.0, rng)
    assert len(times) == int(np.floor(4.0 * 30))
    base = np.arange(len(times)) * task.period
    off = np.asarray(times) - base
    assert (off >= 0).all() and (off <= 0.5 * task.period + 1e-12).all()


def test_poisson_interarrival_statistics():
    task = TaskSpec(0, fps=200)
    rng = np.random.default_rng(0)
    times = np.asarray(PoissonArrivals().sample(task, 60.0, rng))
    gaps = np.diff(times)
    # mean rate ~ fps, exponential gaps: CV ~ 1
    assert len(times) == pytest.approx(200 * 60, rel=0.05)
    assert gaps.mean() == pytest.approx(1 / 200, rel=0.05)
    assert gaps.std() / gaps.mean() == pytest.approx(1.0, abs=0.1)


def test_mmpp_burstiness_and_mean_rate():
    task = TaskSpec(0, fps=200)
    rng = np.random.default_rng(0)
    times = np.asarray(MmppArrivals(burstiness=4.0).sample(task, 60.0, rng))
    gaps = np.diff(times)
    # long-run mean rate is preserved ...
    assert len(times) == pytest.approx(200 * 60, rel=0.10)
    # ... but arrivals are much burstier than Poisson (CV >> 1), and the
    # burst structure is real: within-ON gaps cluster near 1/(b*fps)
    assert gaps.std() / gaps.mean() > 2.0
    assert np.median(gaps) < 1.5 / (4.0 * 200)
    # burstiness=1 degenerates to ~Poisson
    rng = np.random.default_rng(0)
    g1 = np.diff(MmppArrivals(burstiness=1.0).sample(task, 60.0, rng))
    assert g1.std() / g1.mean() == pytest.approx(1.0, abs=0.15)
    # mean rate preserved even past the on-fraction boundary (b > 1/p):
    # on_fraction clamps down instead of the offered load doubling
    rng = np.random.default_rng(0)
    t8 = MmppArrivals(burstiness=8.0, on_fraction=0.25).sample(task, 60.0, rng)
    assert len(t8) == pytest.approx(200 * 60, rel=0.15)


def test_campaign_respects_per_entry_arrival():
    """A scenario entry that pins its own arrival process keeps it; the
    campaign's arrival spec only fills the unpinned entries."""
    tasks = [
        TaskSpec(0, fps=10, arrival=PeriodicArrivals()),
        TaskSpec(1, fps=10),
    ]
    proc = PoissonArrivals()
    arr = generate_arrivals(tasks, 2.0, seed=0, processes=[t.arrival or proc for t in tasks])
    t0 = sorted(a for a, m in arr if m == 0)
    t1 = [a for a, m in arr if m == 1]
    assert t0 == [j * 0.1 for j in range(20)]  # pinned entry stayed periodic
    assert len(t1) > 0 and t1 != [j * 0.1 for j in range(len(t1))]  # default applied


def test_trace_replay_cycles_and_clips():
    task = TaskSpec(0, fps=10)
    proc = TraceArrivals(times=(0.0, 0.25, 0.9), span=1.0)
    rng = np.random.default_rng(0)
    times = proc.sample(task, 2.5, rng)
    assert times == [0.0, 0.25, 0.9, 1.0, 1.25, 1.9, 2.0, 2.25]
    rng = np.random.default_rng(0)
    assert TraceArrivals(times=(0.0, 0.25, 0.9), span=1.0, cycle=False).sample(
        task, 2.5, rng
    ) == [0.0, 0.25, 0.9]


def test_make_arrival_process_specs():
    assert make_arrival_process(None) == PeriodicArrivals()
    assert make_arrival_process("periodic") == PeriodicArrivals()
    assert make_arrival_process("periodic(jitter=0.5)") == PeriodicArrivals(jitter=0.5)
    assert make_arrival_process("mmpp(burstiness=8,on_fraction=0.1)") == MmppArrivals(
        burstiness=8, on_fraction=0.1
    )
    p = PoissonArrivals(rate_scale=2.0)
    assert make_arrival_process(p) is p
    with pytest.raises(KeyError):
        make_arrival_process("weibull")
    with pytest.raises(ValueError):
        make_arrival_process("trace")  # empty replay would mask every miss
    # unknown kwargs name the process and its valid parameters instead of
    # surfacing a bare dataclass TypeError deep inside a pool worker
    with pytest.raises(ValueError, match=r"mmpp.*burstiness"):
        make_arrival_process("mmpp(burstines=4)")
    with pytest.raises(ValueError, match=r"periodic.*jitter"):
        make_arrival_process("periodic(jiter=0.5)")
    assert parse_call_spec("a(x=1,y=true,z=hi)") == ("a", {"x": 1, "y": True, "z": "hi"})
    with pytest.raises(ValueError):
        parse_call_spec("periodic(jitter=0.5))")  # stray paren must not become a str value


def test_make_scheduler_call_specs():
    s = make_scheduler("terastal(backfill_mode=paper)")
    assert s.name == "terastal" and s.backfill_mode == "paper"
    with pytest.raises(KeyError):
        make_scheduler("edf(backfill_mode=paper)")  # baselines take no kwargs
    with pytest.raises(TypeError):
        make_scheduler("terastal(bogus=1)")
    with pytest.raises(KeyError, match="unknown scheduler"):
        make_scheduler("terstal(backfill_mode=paper)")  # typo -> unknown, not kwargs error


# ------------------------------------------------------------ determinism -


def test_trial_deterministic_per_seed_and_seed_sensitive():
    spec = TrialSpec("ar_social", "4k_1ws2os", "terastal", arrival="mmpp(burstiness=4)",
                     seed=5, duration=1.0)
    import dataclasses

    a, b = run_trial(spec), run_trial(spec)
    assert dataclasses.replace(a, wall_s=0.0) == dataclasses.replace(b, wall_s=0.0)
    c = run_trial(TrialSpec("ar_social", "4k_1ws2os", "terastal",
                            arrival="mmpp(burstiness=4)", seed=6, duration=1.0))
    assert c.released != a.released or c.mean_miss_rate != a.mean_miss_rate


def test_campaign_parallel_equals_serial():
    camp = Campaign(scenarios=("ar_social",), platforms=("4k_1ws2os",),
                    schedulers=("fcfs", "terastal"), arrivals=("periodic", "poisson"),
                    seeds=(0, 1, 2), duration=0.5)
    ser = camp.run(parallel=False)
    par = camp.run(parallel=True, max_workers=2)
    assert [t.spec for t in ser.trials] == [s for s in camp.trials()]
    assert [(t.spec, t.mean_miss_rate, t.released, t.utilization) for t in ser.trials] == [
        (t.spec, t.mean_miss_rate, t.released, t.utilization) for t in par.trials
    ]


def test_campaign_trial_matches_direct_simulate():
    """The reusable trial runner is the seed serial loop, exactly."""
    sc, pn = "ar_gaming_light", "4k_1os2ws"
    plans, tasks = SCENARIOS[sc].plans(PLATFORMS[pn])
    for seed in (0, 1):
        ref = simulate(plans, tasks, 1.0, make_scheduler("edf"), seed=seed)
        got = run_trial(TrialSpec(sc, pn, "edf", seed=seed, duration=1.0))
        assert got.mean_miss_rate == ref.mean_miss_rate
        assert got.mean_accuracy_loss == ref.mean_accuracy_loss(plans)
        assert got.released == sum(s.released for s in ref.per_model.values())


def test_campaign_budget_policy_axis():
    """budget_policy is a first-class grid dimension: expansion order puts
    it between arrival and seed, and run_trial threads the call-spec
    through to the simulator."""
    camp = Campaign(scenarios=("ar_gaming_heavy",), platforms=("6k_1ws2os",),
                    schedulers=("terastal",), arrivals=("mmpp(burstiness=4)",),
                    budget_policies=("static", "adaptive(tick=0.02)"),
                    seeds=(0, 1), duration=1.0)
    specs = camp.trials()
    assert [(s.budget_policy, s.seed) for s in specs] == [
        ("static", 0), ("static", 1),
        ("adaptive(tick=0.02)", 0), ("adaptive(tick=0.02)", 1),
    ]
    # pass-through: the trial runner reproduces direct simulate() exactly
    plans, tasks = SCENARIOS["ar_gaming_heavy"].plans(PLATFORMS["6k_1ws2os"])
    proc = make_arrival_process("mmpp(burstiness=4)")
    for spec in specs:
        ref = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=spec.seed,
                       processes=[proc] * len(tasks), budget_policy=spec.budget_policy)
        got = run_trial(spec)
        assert got.mean_miss_rate == ref.mean_miss_rate
        assert got.released == sum(s.released for s in ref.per_model.values())
    # the policy axis genuinely changes terastal's behavior on bursty load
    res = camp.run(parallel=False)
    by_pol = {}
    for t in res.trials:
        by_pol.setdefault(t.spec.budget_policy, []).append(t.mean_miss_rate)
    assert by_pol["static"] != by_pol["adaptive(tick=0.02)"]


def test_warm_plan_cache_initializer(monkeypatch):
    """The pool initializer primes the per-process offline-plan cache for
    every campaign cell, so spawn workers skip the Algorithm-1 rebuild on
    their first trial (fork workers inherit it; the initializer is then a
    cache hit).  Campaign.run must hand the initializer + its cell keys
    to the executor it constructs."""
    from repro.core import campaign as campaign_mod
    from repro.core.campaign import _PLAN_CACHE, _warm_plan_cache

    key = ("ar_social", "4k_1ws2os", 0.90, True)
    _PLAN_CACHE.pop(key, None)
    _warm_plan_cache([key])
    assert key in _PLAN_CACHE
    plans, tasks = _PLAN_CACHE[key]
    assert len(plans) == len(tasks) == len(SCENARIOS["ar_social"].entries)

    # behavioral: Campaign.run wires the initializer into the pool it
    # builds (stub executor: run the initializer the way a fresh spawn
    # worker would, then map serially)
    captured = {}

    class FakeExecutor:
        def __init__(self, max_workers=None, mp_context=None,
                     initializer=None, initargs=()):
            captured["initializer"] = initializer
            captured["initargs"] = initargs

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            return False

        def map(self, fn, specs, chunksize=1):
            captured["initializer"](*captured["initargs"])  # worker startup
            return [fn(s) for s in list(specs)]

        def shutdown(self, wait=True):
            pass

    monkeypatch.setattr(
        campaign_mod.concurrent.futures, "ProcessPoolExecutor", FakeExecutor
    )
    camp = Campaign(scenarios=("ar_social",), platforms=("4k_1ws2os",),
                    schedulers=("fcfs",), seeds=(0, 1), duration=0.3)
    res = camp.run(parallel=True, max_workers=2)
    assert len(res.trials) == 2
    assert captured["initializer"] is campaign_mod._warm_plan_cache
    assert key in captured["initargs"][0]  # the campaign's cells were handed over


def test_campaign_engine_axis_threads_through():
    """TrialSpec.engine reaches simulate(): the reference and SoA engines
    must produce identical trial rows (the engine axis never changes any
    metric), and Campaign.engine stamps every spec."""
    import dataclasses

    camp = Campaign(scenarios=("ar_social",), platforms=("4k_1ws2os",),
                    schedulers=("terastal",), arrivals=("mmpp(burstiness=4)",),
                    seeds=(0, 1), duration=0.5, engine="reference")
    assert all(s.engine == "reference" for s in camp.trials())
    for spec in camp.trials():
        ref = run_trial(spec)
        soa = run_trial(dataclasses.replace(spec, engine="soa"))
        assert (ref.mean_miss_rate, ref.released, ref.utilization) == (
            soa.mean_miss_rate, soa.released, soa.utilization)


# ------------------------------------------------------------ aggregation -


def test_bootstrap_ci_math():
    rng = np.random.default_rng(0)
    vals = rng.normal(10.0, 2.0, size=200)
    lo, hi = bootstrap_ci(vals, n_boot=2000, seed=1)
    assert lo < vals.mean() < hi
    # ~95% CI of the mean of N(10, 2^2) with n=200: half-width ~ 1.96*2/sqrt(200)
    half = 1.96 * 2.0 / np.sqrt(200)
    assert (hi - lo) / 2 == pytest.approx(half, rel=0.25)
    # deterministic; degenerate samples raise a *named* error instead of
    # the old silent point/NaN intervals that dressed up nothing as a CI
    assert bootstrap_ci(vals, n_boot=2000, seed=1) == (lo, hi)
    from repro.core import DegenerateSampleError

    with pytest.raises(DegenerateSampleError, match=">= 2 values"):
        bootstrap_ci([3.0])
    with pytest.raises(DegenerateSampleError, match=">= 2 values"):
        bootstrap_ci([])
    assert issubclass(DegenerateSampleError, ValueError)  # catchable broadly
    # more trials -> tighter interval
    lo2, hi2 = bootstrap_ci(vals[:20], n_boot=2000, seed=1)
    assert (hi2 - lo2) > (hi - lo)


def test_campaign_aggregate_groups_in_grid_order():
    camp = Campaign(scenarios=("ar_social",), platforms=("4k_1ws2os",),
                    schedulers=("fcfs", "edf"), arrivals=("periodic",),
                    seeds=(0, 1, 2, 3), duration=0.5)
    res = camp.run(parallel=False)
    agg = res.aggregate(by=("scheduler",))
    assert [r["scheduler"] for r in agg] == ["fcfs", "edf"]
    for r in agg:
        assert r["n_trials"] == 4
        assert r["mean_miss_rate_ci_lo"] - 1e-12 <= r["mean_miss_rate"] <= r["mean_miss_rate_ci_hi"] + 1e-12
    vals = [t.mean_miss_rate for t in res.trials if t.spec.scheduler == "fcfs"]
    assert agg[0]["mean_miss_rate"] == pytest.approx(float(np.mean(vals)))


# ------------------------------------------------------------- regression -


def test_fig5_campaign_rows_match_seed_serial_loop():
    """The refactored fig5 must emit exactly what the seed's serial loop
    produced: same cells, same schedulers, bit-identical per-seed means."""
    import benchmarks.fig5_miss_rate as fig5
    from repro.core import ALL_SCHEDULERS
    from repro.core.workload import scenario_platform_pairs

    seeds, duration = (0,), 0.5
    rows = fig5.run(duration=duration, seeds=seeds)
    i = 0
    for sc, plat in scenario_platform_pairs():
        plans, tasks = sc.plans(plat)
        for name in ALL_SCHEDULERS:
            miss, acc = [], []
            for seed in seeds:
                res = simulate(plans, tasks, duration, make_scheduler(name), seed=seed)
                miss.append(res.mean_miss_rate)
                acc.append(res.mean_accuracy_loss(plans))
            r = rows[i]
            assert (r["scenario"], r["platform"], r["scheduler"]) == (sc.name, plat.name, name)
            assert r["miss_rate_pct"] == 100 * float(np.mean(miss))
            assert r["acc_loss_pct"] == 100 * float(np.mean(acc))
            i += 1
    assert i == len(rows)

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn.ops import gqa_decode_attention
from repro.kernels.s2d_conv.kernel import s2d_conv_pallas
from repro.kernels.s2d_conv.ops import s2d_variant_conv, s2d_variant_conv_rs
from repro.kernels.s2d_conv.ref import d2s, s2d, s2d_conv_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.models.common import decode_attention
from repro.models.mamba2 import ssd_chunked, ssd_naive

KEY = jax.random.PRNGKey(42)


# ------------------------------------------------------------- s2d_conv ----


def test_d2s_s2d_inverse():
    x = jax.random.normal(KEY, (2, 8, 8, 16))
    np.testing.assert_allclose(s2d(d2s(x, 2), 2), x)
    x3 = jax.random.normal(KEY, (1, 6, 6, 18))
    np.testing.assert_allclose(s2d(d2s(x3, 3), 3), x3)


@pytest.mark.parametrize("B,H,W,C,K,g", [
    (2, 8, 8, 16, 32, 2),
    (1, 16, 16, 64, 64, 2),
    (2, 12, 12, 36, 72, 3),
    (1, 8, 8, 256, 128, 2),
    (1, 4, 4, 512, 512, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_s2d_conv_matches_ref(B, H, W, C, K, g, dtype):
    x = jax.random.normal(KEY, (B, H, W, C), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (C // g**2, K // g**2), dtype)
    ref = s2d_conv_ref(x, w, g).astype(jnp.float32)
    got = s2d_conv_pallas(x, w, g, tile_h=4, tile_w=4, interpret=True).astype(jnp.float32)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(got, ref, atol=tol, rtol=tol)


def test_s2d_conv_tile_invariance():
    """Output independent of BlockSpec tiling."""
    x = jax.random.normal(KEY, (1, 16, 16, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
    outs = [
        s2d_conv_pallas(x, w, 2, tile_h=t, tile_w=t, interpret=True) for t in (2, 4, 8, 16)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5)


def test_s2d_variant_weight_count():
    """Fused variant uses 1/g^4 of the original layer's weights (paper)."""
    C, K, g = 64, 128, 2
    w_orig = C * K
    w_var = (C // g**2) * (K // g**2)
    assert w_var * g**4 == w_orig


def test_s2d_conv_rs_shapes():
    x = jax.random.normal(KEY, (1, 8, 8, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 8))
    out = s2d_variant_conv_rs(x, w, 2)
    assert out.shape == (1, 8, 8, 32)
    assert bool(jnp.isfinite(out).all())


# ------------------------------------------------------------- ssd_scan ----


@pytest.mark.parametrize("Bt,L,H,P,N,Q", [
    (2, 64, 4, 8, 16, 16),
    (1, 128, 2, 64, 128, 32),
    (2, 32, 8, 16, 8, 32),
    (1, 64, 1, 128, 64, 64),
])
def test_ssd_scan_matches_naive(Bt, L, H, P, N, Q):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, L, H, P))
    la = -jnp.abs(jax.random.normal(ks[1], (Bt, L, H))) * 0.3
    B = jax.random.normal(ks[2], (Bt, L, N))
    C = jax.random.normal(ks[3], (Bt, L, N))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (Bt, L, H)))
    ref = ssd_naive(x, la, B, C, dt)
    got = ssd_scan(x, la, B, C, dt, chunk=Q, backend="pallas", interpret=True)
    rel = float(jnp.abs(ref - got).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_dtypes(dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (1, 64, 2, 16), dtype)
    la = (-jnp.abs(jax.random.normal(ks[1], (1, 64, 2))) * 0.3).astype(dtype)
    B = jax.random.normal(ks[2], (1, 64, 8), dtype)
    C = jax.random.normal(ks[3], (1, 64, 8), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, 64, 2))).astype(dtype)
    ref = ssd_naive(x, la, B, C, dt).astype(jnp.float32)
    got = ssd_scan(x, la, B, C, dt, chunk=16, backend="pallas", interpret=True).astype(jnp.float32)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    rel = float(jnp.abs(ref - got).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < tol


def test_ssd_chunked_equals_pallas_paths():
    """The model-level jnp blocked path and the kernel agree (same math)."""
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (2, 64, 4, 8))
    la = -jnp.abs(jax.random.normal(ks[1], (2, 64, 4))) * 0.2
    B = jax.random.normal(ks[2], (2, 64, 16))
    C = jax.random.normal(ks[3], (2, 64, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (2, 64, 4)))
    a = ssd_chunked(x, la, B, C, dt, 16)
    b = ssd_scan(x, la, B, C, dt, chunk=16, backend="pallas", interpret=True)
    np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------- decode_attn ----


@pytest.mark.parametrize("B,L,H,Hkv,Dh,pos,chunk", [
    (2, 64, 8, 2, 16, 63, 16),
    (1, 128, 4, 4, 32, 80, 32),
    (3, 256, 16, 8, 64, 255, 64),
    (1, 64, 8, 1, 128, 10, 64),
])
def test_decode_attn_matches_ref(B, L, H, Hkv, Dh, pos, chunk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, H, Dh))
    k = jax.random.normal(ks[1], (B, L, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, L, Hkv, Dh))
    ref = decode_attention(q, k, v, jnp.int32(pos))
    got = gqa_decode_attention(q, k, v, jnp.int32(pos), backend="pallas", chunk=chunk, interpret=True)
    np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-4)


def test_decode_attn_respects_valid_length():
    """Entries beyond pos must not influence the output."""
    ks = jax.random.split(KEY, 3)
    B, L, H, Hkv, Dh = 1, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (B, 1, H, Dh))
    k = jax.random.normal(ks[1], (B, L, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, L, Hkv, Dh))
    pos = jnp.int32(20)
    out1 = gqa_decode_attention(q, k, v, pos, backend="pallas", chunk=16, interpret=True)
    k2 = k.at[:, 30:].set(999.0)
    v2 = v.at[:, 30:].set(-999.0)
    out2 = gqa_decode_attention(q, k2, v2, pos, backend="pallas", chunk=16, interpret=True)
    np.testing.assert_allclose(out1, out2, atol=1e-6)

"""Sequential adaptive sampler: determinism differentials and the journal.

The contract under test (see repro/core/sampling.py):

* stopping disabled  == ``Campaign.run`` — every ``TrialResult`` field
  (wall time zeroed: it measures the clock, not the simulation);
* parallel == serial — round barriers decide from seed-indexed result
  prefixes, so executor scheduling cannot leak into decisions;
* the executed trials per cell are a *prefix* of the campaign's own
  seed ladder (adaptive output is always a sub-grid of the fixed grid);
* a journal interrupted after any prefix resumes bit-identically, and
  a complete journal replays with zero re-execution.
"""

import dataclasses
import json
import os

import pytest

from repro.core import (
    Campaign,
    DegenerateSampleError,
    SamplerConfig,
    fixed_grid_verdicts,
    run_adaptive,
)
from repro.core.sampling import CELL_FIELDS, GROUP_FIELDS, _cell_of


def _strip(t):
    return dataclasses.replace(t, wall_s=0.0)


def _camp(**kw):
    base = dict(
        scenarios=("ar_gaming_heavy",),
        platforms=("6k_1ws2os",),
        schedulers=("fcfs", "edf", "terastal"),
        arrivals=("periodic", "mmpp(burstiness=4)"),
        seeds=tuple(range(5)),
        duration=0.5,
    )
    base.update(kw)
    return Campaign(**base)


# ---------------------------------------------------------- differential ----


def test_stopping_disabled_reproduces_campaign_run_exactly():
    """The sampler's always-run-to-cap special case IS the fixed grid:
    same trials, same order, every field equal — across schedulers x
    arrivals x budget policies, serial and parallel."""
    camp = _camp(
        schedulers=("edf", "terastal"),
        arrivals=("periodic", "poisson"),
        budget_policies=("static", "reclaim"),
        seeds=(0, 1, 2),
    )
    fixed = camp.run(parallel=False)
    cfg = SamplerConfig(stopping=False)
    for parallel in (False, True):
        res = run_adaptive(camp, cfg, parallel=parallel, max_workers=2)
        assert [_strip(t) for t in res.trials] == [_strip(t) for t in fixed.trials]
        assert res.verdicts == [] and res.n_trials == res.n_trials_cap
        assert res.trials_saved() == 0.0


def test_adaptive_parallel_equals_serial():
    camp = _camp()
    ser = run_adaptive(camp, parallel=False)
    par = run_adaptive(camp, parallel=True, max_workers=2)
    assert [_strip(t) for t in ser.trials] == [_strip(t) for t in par.trials]
    assert ser.verdicts == par.verdicts
    assert ser.rounds == par.rounds


def test_adaptive_trials_are_fixed_grid_prefix():
    """Per cell, the sampler consumes the campaign's seed ladder in
    order — the executed specs are a prefix of the fixed grid's specs
    for that cell, and the flattened result list follows grid order."""
    camp = _camp()
    res = run_adaptive(camp, parallel=False)
    grid = camp.trials()
    by_cell = {}
    for s in grid:
        by_cell.setdefault(_cell_of(s), []).append(s)
    got = {}
    for t in res.trials:
        got.setdefault(_cell_of(t.spec), []).append(t.spec)
    assert set(got) == set(by_cell)
    for cell, specs in got.items():
        assert specs == by_cell[cell][: len(specs)]  # prefix, in ladder order
    # grid order overall: positions strictly increase
    pos = {dataclasses.astuple(s): i for i, s in enumerate(grid)}
    idx = [pos[dataclasses.astuple(t.spec)] for t in res.trials]
    assert idx == sorted(idx)
    # and the sampler genuinely stopped early somewhere on this grid
    assert res.n_trials < res.n_trials_cap
    assert any(v.reason != "cap" for v in res.verdicts)


def test_adaptive_verdicts_match_fixed_grid_on_this_grid():
    """On the test grid the early-stopped winners equal the full-ladder
    winners (the property the efficiency benchmark enforces at scale)."""
    camp = _camp()
    fixed_w = {
        (v.group, v.scheduler): v.winner
        for v in fixed_grid_verdicts(camp.run(parallel=False))
    }
    res = run_adaptive(camp, parallel=False)
    assert len(res.verdicts) == len(fixed_w)
    for v in res.verdicts:
        assert v.winner == fixed_w[(v.group, v.scheduler)]
        assert v.baseline == "terastal"
        assert 2 <= v.n_seeds <= len(camp.seeds)
        assert v.reason in ("separated", "invariant", "cap")
        assert (v.reason == "separated") == v.separated


def test_campaign_result_adapter_aggregates():
    camp = _camp(seeds=(0, 1, 2, 3))
    res = run_adaptive(camp, parallel=False)
    agg = res.campaign_result().aggregate(by=("scheduler", "arrival"))
    assert {(r["scheduler"], r["arrival"]) for r in agg} == {
        (s, a) for s in camp.schedulers for a in camp.arrivals
    }
    for r in agg:
        assert 2 <= r["n_trials"] <= len(camp.seeds)


# --------------------------------------------------------------- journal ----


def test_journal_kill_after_any_prefix_resumes_bit_identical(tmp_path):
    """Truncate the journal after every prefix length — including mid-
    line, the signature of a killed process — and resume: the final
    trials and verdicts must be bit-identical to the uninterrupted run,
    and the journal must be healed to a complete, parseable file."""
    camp = _camp(seeds=(0, 1, 2, 3))
    path = str(tmp_path / "journal.jsonl")
    full = run_adaptive(camp, parallel=False, journal=path)
    lines = open(path).read().splitlines()
    assert len(lines) == 1 + full.n_trials  # header + one line per trial
    for keep in (1, 2, len(lines) // 2, len(lines) - 1):
        trunc = "\n".join(lines[:keep]) + "\n" + '{"kind": "trial", "spe'
        with open(path, "w") as f:
            f.write(trunc)  # no trailing newline: killed mid-write
        res = run_adaptive(camp, parallel=False, journal=path)
        assert [_strip(t) for t in res.trials] == [_strip(t) for t in full.trials]
        assert res.verdicts == full.verdicts
        healed = [json.loads(l) for l in open(path).read().splitlines()]
        assert len(healed) == 1 + full.n_trials


def test_journal_complete_replay_runs_zero_trials(tmp_path, monkeypatch):
    """Resuming from a complete journal re-executes nothing: every trial
    is served from the cache (run_trial is forbidden via monkeypatch)."""
    from repro.core import campaign as campaign_mod

    camp = _camp(seeds=(0, 1, 2))
    path = str(tmp_path / "journal.jsonl")
    full = run_adaptive(camp, parallel=False, journal=path)

    def boom(spec):
        raise AssertionError(f"run_trial re-executed {spec} despite journal")

    monkeypatch.setattr(campaign_mod, "run_trial", boom)
    res = run_adaptive(camp, parallel=False, journal=path)
    assert res.trials == full.trials  # wall_s included: cached verbatim
    assert res.verdicts == full.verdicts


def test_journal_refuses_foreign_campaign(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    run_adaptive(_camp(seeds=(0, 1)), parallel=False, journal=path)
    with pytest.raises(ValueError, match="different campaign"):
        run_adaptive(_camp(seeds=(0, 1, 2)), parallel=False, journal=path)
    with open(path, "w") as f:
        f.write('{"something": "else"}\n')
    with pytest.raises(ValueError, match="not a sampler journal"):
        run_adaptive(_camp(seeds=(0, 1)), parallel=False, journal=path)


def test_journal_written_before_new_default_field_resumes(tmp_path, monkeypatch):
    """A journal from before a default-valued Campaign/TrialSpec field
    existed (e.g. ``round_kernel``) must still resume: the header is
    re-serialized through the current dataclasses, which fill the
    defaults.  Genuinely different campaigns keep being refused."""
    from repro.core import campaign as campaign_mod

    path = str(tmp_path / "journal.jsonl")
    camp = _camp(seeds=(0, 1))
    first = run_adaptive(camp, parallel=False, journal=path)

    # age the journal: strip the new field from the header and every
    # recorded trial spec, exactly what a pre-PR5 writer produced
    with open(path) as f:
        lines = [json.loads(line) for line in f.read().splitlines()]
    del lines[0]["campaign"]["round_kernel"]
    for rec in lines[1:]:
        del rec["spec"]["round_kernel"]
        del rec["result"]["rounds"]
    with open(path, "w") as f:
        for obj in lines:
            f.write(json.dumps(obj) + "\n")

    calls = {"n": 0}
    orig = campaign_mod.run_trial

    def counting(spec):
        calls["n"] += 1
        return orig(spec)

    monkeypatch.setattr(campaign_mod, "run_trial", counting)
    import repro.core.sampling as sampling_mod
    monkeypatch.setattr(sampling_mod, "run_trial", counting, raising=False)
    resumed = run_adaptive(camp, parallel=False, journal=path)
    assert calls["n"] == 0  # fully replayed from the aged journal
    assert [_cell_of(t.spec) for t in resumed.trials] == \
           [_cell_of(t.spec) for t in first.trials]
    # verdicts are a pure function of replayed results: identical
    assert [dataclasses.asdict(v) for v in resumed.verdicts] == \
           [dataclasses.asdict(v) for v in first.verdicts]


# ------------------------------------------------------------ validation ----


def test_sampler_config_validation():
    with pytest.raises(ValueError, match="min_seeds"):
        SamplerConfig(min_seeds=1)
    with pytest.raises(ValueError, match="round_seeds"):
        SamplerConfig(round_seeds=0)
    with pytest.raises(ValueError, match="alpha"):
        SamplerConfig(alpha=0.0)
    assert SamplerConfig(min_seeds=3, round_seeds=2).looks(8) == [3, 5, 7, 8]
    assert SamplerConfig(min_seeds=8).looks(8) == [8]
    assert SamplerConfig(min_seeds=5).looks(3) == [3]  # clamped to cap
    assert SamplerConfig(stopping=False).looks(8) == [8]


def test_run_adaptive_named_errors():
    with pytest.raises(DegenerateSampleError, match="seed ladder"):
        run_adaptive(_camp(seeds=(0,)), parallel=False)
    with pytest.raises(ValueError, match="baseline scheduler"):
        run_adaptive(_camp(schedulers=("fcfs", "edf")), parallel=False)
    with pytest.raises(ValueError, match="nothing to compare"):
        run_adaptive(_camp(schedulers=("terastal",)), parallel=False)
    # but both degenerate grids are fine with stopping disabled
    cfg = SamplerConfig(stopping=False)
    assert run_adaptive(_camp(schedulers=("terastal",), seeds=(0,), arrivals=("periodic",)),
                        cfg, parallel=False).n_trials == 1


def test_cell_and_group_field_contract():
    """The cell identity covers every spec axis except the seed (and the
    campaign-constant duration/engine); groups drop only the scheduler."""
    assert CELL_FIELDS == ("scenario", "platform", "theta", "scheduler",
                           "arrival", "budget_policy")
    assert GROUP_FIELDS == tuple(f for f in CELL_FIELDS if f != "scheduler")

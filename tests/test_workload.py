"""Workload scenarios: feasibility, variant emergence, load calibration."""

import numpy as np
import pytest

from repro.core.workload import (
    SATURATION_DEADLINE_SLACK,
    SATURATION_SCENARIOS,
    SCENARIOS,
    get_scenario,
    scenario_platform_pairs,
)
from repro.costmodel.maestro import PLATFORMS


def test_all_scenario_models_feasible():
    """Every (model, platform) pairing in every scenario admits a valid
    budget assignment (Algorithm 1 succeeds) — the paper's scenarios all
    run; infeasible pairings would be configuration bugs."""
    for sc, plat in scenario_platform_pairs():
        plans, _ = sc.plans(plat)
        for p in plans:
            assert p.budget.feasible, (sc.name, plat.name, p.model.name)


def test_load_nontrivial_but_not_saturated():
    """Paper Sec. V-A: hardware settings chosen 'avoiding trivial
    all-pass or all-fail cases' — min-latency demand sits in a sane band."""
    for sc, plat in scenario_platform_pairs():
        plans, tasks = sc.plans(plat)
        demand = sum(p.min_lat.sum() * t.fps * t.prob for p, t in zip(plans, tasks))
        frac = demand / plat.n_acc
        assert 0.10 < frac < 1.0, (sc.name, plat.name, frac)


def test_starred_models_have_variants():
    """Table II stars Sp2Dense, MobileNetV2-SSD, ResNet50, VGG11,
    InceptionV3, Swin-Tiny as variant-bearing.  Our offline stage derives
    variants from the latency tables; the starred set should largely
    emerge (cost-model differences may drop individual entries, but the
    multicam heavies must have them)."""
    from repro.costmodel.maestro import PLATFORMS

    sc = SCENARIOS["multicam_heavy"]
    plans, _ = sc.plans(PLATFORMS["6k_1ws2os"])
    with_variants = {p.model.name for p in plans if p.variants}
    assert {"resnet50", "vgg11", "swin_tiny"} <= with_variants


def test_budget_sums_match_deadlines():
    for sc, plat in scenario_platform_pairs()[:4]:
        plans, tasks = sc.plans(plat)
        for p, t in zip(plans, tasks):
            np.testing.assert_allclose(p.budget.budgets.sum(), 1.0 / t.fps, rtol=1e-9)


def test_theta_propagates():
    sc = SCENARIOS["multicam_heavy"]
    plans, _ = sc.plans(PLATFORMS["6k_1ws2os"], theta=0.75)
    assert all(p.theta == 0.75 for p in plans)


# ------------------------------------------------- saturation family ----


def test_saturation_scenarios_are_overloaded_but_feasible():
    """The deep-queue family must be genuinely overloaded (min-latency
    demand well past capacity — the opposite band from the paper cells)
    while every per-model budget assignment stays feasible, so requests
    queue rather than failing the offline stage."""
    assert set(SATURATION_SCENARIOS) == {"saturation_3x", "saturation_5x",
                                         "saturation_8x"}
    prev = 0.0
    for name in ("saturation_3x", "saturation_5x", "saturation_8x"):
        sc = SATURATION_SCENARIOS[name]
        for pn in sc.platform_names:
            plat = PLATFORMS[pn]
            plans, tasks = sc.plans(plat)
            for p in plans:
                assert p.budget.feasible, (name, pn, p.model.name)
            demand = sum(p.min_lat.sum() * t.fps * t.prob
                         for p, t in zip(plans, tasks))
            frac = demand / plat.n_acc
            # saturated by design: past capacity on every platform even
            # at the mild 3x rung (~1.16 on 4k; ~3.1 at 8x)
            assert frac > 1.05, (name, pn, frac)
        # offered load strictly increases along the family
        frac_4k = sum(
            p.min_lat.sum() * t.fps
            for p, t in zip(*sc.plans(PLATFORMS["4k_1ws2os"]))
        )
        assert frac_4k > prev
        prev = frac_4k


def test_saturation_deadlines_anchored_to_base_period():
    """fps scales only the offered rate; the relative deadline stays at
    SATURATION_DEADLINE_SLACK x the non-overloaded period, so overload
    deepens the ready queue instead of early-dropping every release."""
    sc3, sc8 = SATURATION_SCENARIOS["saturation_3x"], SATURATION_SCENARIOS["saturation_8x"]
    for e3, e8 in zip(sc3.entries, sc8.entries):
        assert e3.deadline == e8.deadline  # invariant across load
        base_fps = e3.fps / 3.0
        assert e3.deadline == pytest.approx(SATURATION_DEADLINE_SLACK / base_fps)
        assert e8.fps == pytest.approx(base_fps * 8.0)
        assert e3.arrival is not None  # mixed release processes, pinned


def test_saturation_mixed_release_processes():
    kinds = {e.arrival.kind for e in SATURATION_SCENARIOS["saturation_5x"].entries}
    assert {"mmpp", "poisson", "periodic"} <= kinds


def test_get_scenario_resolves_all_catalogs():
    from repro.core.workload import FAULT_SCENARIOS

    assert get_scenario("multicam_heavy") is SCENARIOS["multicam_heavy"]
    assert get_scenario("saturation_5x") is SATURATION_SCENARIOS["saturation_5x"]
    assert get_scenario("fault_dropout") is FAULT_SCENARIOS["fault_dropout"]
    # PR 10: the faults x DAG composition cell is a first-class member
    dd = get_scenario("fault_dag_dropout")
    assert dd is FAULT_SCENARIOS["fault_dag_dropout"]
    assert "retighten=true" in dd.faults
    # the paper grid is unchanged: stress catalogs stay out of SCENARIOS
    assert not set(SATURATION_SCENARIOS) & set(SCENARIOS)
    assert not set(FAULT_SCENARIOS) & set(SCENARIOS)


def test_get_scenario_unknown_name_lists_catalogs_searched():
    """Every catalog — all five, including DAG_SCENARIOS — appears in
    the unknown-name error, with member names so a typo is findable."""
    with pytest.raises(ValueError, match="unknown scenario") as ei:
        get_scenario("saturation_99x")
    msg = str(ei.value)
    for catalog in ("SCENARIOS", "SATURATION_SCENARIOS",
                    "OVERLOAD_SCENARIOS", "FAULT_SCENARIOS",
                    "DAG_SCENARIOS"):
        assert catalog in msg
    assert "fault_dropout" in msg  # names, so the typo is findable
    assert "fault_dag_dropout" in msg
    assert "dag_vlm_2branch" in msg

"""Workload scenarios: feasibility, variant emergence, load calibration."""

import numpy as np
import pytest

from repro.core.workload import SCENARIOS, scenario_platform_pairs


def test_all_scenario_models_feasible():
    """Every (model, platform) pairing in every scenario admits a valid
    budget assignment (Algorithm 1 succeeds) — the paper's scenarios all
    run; infeasible pairings would be configuration bugs."""
    for sc, plat in scenario_platform_pairs():
        plans, _ = sc.plans(plat)
        for p in plans:
            assert p.budget.feasible, (sc.name, plat.name, p.model.name)


def test_load_nontrivial_but_not_saturated():
    """Paper Sec. V-A: hardware settings chosen 'avoiding trivial
    all-pass or all-fail cases' — min-latency demand sits in a sane band."""
    for sc, plat in scenario_platform_pairs():
        plans, tasks = sc.plans(plat)
        demand = sum(p.min_lat.sum() * t.fps * t.prob for p, t in zip(plans, tasks))
        frac = demand / plat.n_acc
        assert 0.10 < frac < 1.0, (sc.name, plat.name, frac)


def test_starred_models_have_variants():
    """Table II stars Sp2Dense, MobileNetV2-SSD, ResNet50, VGG11,
    InceptionV3, Swin-Tiny as variant-bearing.  Our offline stage derives
    variants from the latency tables; the starred set should largely
    emerge (cost-model differences may drop individual entries, but the
    multicam heavies must have them)."""
    from repro.costmodel.maestro import PLATFORMS

    sc = SCENARIOS["multicam_heavy"]
    plans, _ = sc.plans(PLATFORMS["6k_1ws2os"])
    with_variants = {p.model.name for p in plans if p.variants}
    assert {"resnet50", "vgg11", "swin_tiny"} <= with_variants


def test_budget_sums_match_deadlines():
    for sc, plat in scenario_platform_pairs()[:4]:
        plans, tasks = sc.plans(plat)
        for p, t in zip(plans, tasks):
            np.testing.assert_allclose(p.budget.budgets.sum(), 1.0 / t.fps, rtol=1e-9)


def test_theta_propagates():
    from repro.costmodel.maestro import PLATFORMS

    sc = SCENARIOS["multicam_heavy"]
    plans, _ = sc.plans(PLATFORMS["6k_1ws2os"], theta=0.75)
    assert all(p.theta == 0.75 for p in plans)

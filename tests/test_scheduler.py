"""Online schedulers: Algorithm 2 semantics, baselines, and invariants."""

import numpy as np
import pytest

from repro.core import (
    ALL_SCHEDULERS,
    SCENARIOS,
    TerastalScheduler,
    make_scheduler,
    simulate,
)
from repro.core.scheduler import Request, SchedView
from repro.core.variants import build_model_plan
from repro.costmodel.dnn_zoo import get_model, vgg11
from repro.costmodel.maestro import PLATFORMS


def _mini_plan(deadline=0.05, platform="6k_1ws2os", model=None):
    return build_model_plan(model or vgg11(224), PLATFORMS[platform], deadline)


def _view(plans, now=0.0, busy=None, reqs=None):
    n_acc = plans[0].platform.n_acc
    busy_arr = np.zeros(n_acc) if busy is None else np.asarray(busy, float)
    return SchedView(now=now, ready=reqs or [], acc_busy_until=busy_arr, plans=plans)


def _req(rid, m, arrival, deadline, layer=0):
    return Request(rid=rid, model_idx=m, arrival=arrival, deadline_abs=arrival + deadline, next_layer=layer)


def test_fcfs_orders_by_arrival():
    plan = _mini_plan()
    r1 = _req(1, 0, 0.010, 0.05)
    r2 = _req(2, 0, 0.005, 0.05)
    view = _view([plan], now=0.02, reqs=[r1, r2])
    out = make_scheduler("fcfs").schedule(view)
    assert out[0].req is r2  # earlier arrival first


def test_fcfs_maps_to_lowest_latency_idle():
    plan = _mini_plan()
    r = _req(1, 0, 0.0, 0.05)
    view = _view([plan], now=0.0, reqs=[r])
    out = make_scheduler("fcfs").schedule(view)
    assert len(out) == 1
    a = out[0]
    assert a.est_latency == pytest.approx(float(plan.lat[0].min()))


def test_edf_prioritizes_tighter_derived_deadline():
    plan = _mini_plan(deadline=0.05)
    tight = _req(1, 0, 0.0, 0.05, layer=0)  # all work remaining
    loose = _req(2, 0, -0.01, 0.06, layer=len(plan.model.layers) - 1)
    # derived deadline: abs_deadline - remaining_min[l+1]; loose is at its
    # last layer so its derived deadline equals its absolute deadline.
    view = _view([plan], now=0.0, reqs=[loose, tight])
    out = make_scheduler("edf").schedule(view)
    d_tight = tight.deadline_abs - plan.remaining_min[1]
    d_loose = loose.deadline_abs
    expected_first = tight if d_tight < d_loose else loose
    assert out[0].req is expected_first


def test_terastal_stage1_meets_virtual_deadline():
    plan = _mini_plan(deadline=0.5)
    r = _req(1, 0, 0.0, 0.5)
    view = _view([plan], now=0.0, reqs=[r])
    out = TerastalScheduler().schedule(view)
    assert len(out) == 1
    a = out[0]
    vdl = r.arrival + plan.vdl_rel[0]
    assert a.est_latency <= vdl  # finish (tau=0 + c) meets virtual deadline


def test_terastal_uses_variant_when_original_cannot_meet_vdl():
    """Construct a synthetic plan where only the variant meets the vdl on
    the sole idle accelerator."""
    plan = build_model_plan(vgg11(384), PLATFORMS["6k_1ws2os"], 1 / 30, theta=0.80)
    assert plan.variants, "vgg11@384 at 30fps must design variants"
    # need a variant whose single-use combo passes theta
    valid = [i for i in sorted(plan.variants) if plan.is_valid_combo(frozenset({i}))]
    assert valid
    lidx = valid[0]
    v = plan.variants[lidx]
    k_best = int(np.argmin(v.latencies))
    c_orig = float(plan.lat[lidx, k_best])
    c_var = float(v.latencies[k_best])
    if not (c_var < c_orig):
        pytest.skip("variant not faster on its target here")
    # only k_best idle; choose arrival so the layer's absolute virtual
    # deadline sits between the variant's and the original's finish time.
    busy = np.full(plan.platform.n_acc, 1e3)
    busy[k_best] = 0.0
    now = 1.0
    vdl_abs_target = now + (c_orig + c_var) / 2
    arrival = vdl_abs_target - float(plan.vdl_rel[lidx])
    r = Request(rid=1, model_idx=0, arrival=arrival, deadline_abs=now + 10.0, next_layer=lidx)
    view = _view([plan], now=now, busy=busy, reqs=[r])
    out = TerastalScheduler().schedule(view)
    assert len(out) == 1
    assert out[0].use_variant


def test_terastal_reads_dynamic_vdl_state():
    """A request carrying ``vdl_abs`` (online budget policy state)
    overrides the plan's frozen table: loosening the ready layer's virtual
    deadline flips the decision from variant back to original."""
    plan = build_model_plan(vgg11(384), PLATFORMS["6k_1ws2os"], 1 / 30, theta=0.80)
    valid = [i for i in sorted(plan.variants) if plan.is_valid_combo(frozenset({i}))]
    assert valid
    lidx = valid[0]
    v = plan.variants[lidx]
    k_best = int(np.argmin(v.latencies))
    c_orig = float(plan.lat[lidx, k_best])
    c_var = float(v.latencies[k_best])
    if not (c_var < c_orig):
        pytest.skip("variant not faster on its target here")
    busy = np.full(plan.platform.n_acc, 1e3)
    busy[k_best] = 0.0
    now = 1.0
    vdl_abs_target = now + (c_orig + c_var) / 2  # between variant and original
    arrival = vdl_abs_target - float(plan.vdl_rel[lidx])
    n_layers = len(plan.model.layers)

    def req_with(vdl_at_lidx):
        r = Request(rid=1, model_idx=0, arrival=arrival, deadline_abs=now + 10.0,
                    next_layer=lidx)
        if vdl_at_lidx is not None:
            vdl = arrival + plan.vdl_rel.copy()
            vdl[lidx:] += vdl_at_lidx - vdl[lidx]  # shift suffix, keep monotone
            r.vdl_abs = vdl
        return r

    sched = TerastalScheduler()
    view = _view([plan], now=now, busy=busy, reqs=[req_with(None)])
    out = sched.schedule(view)
    assert len(out) == 1 and out[0].use_variant  # static table: too tight

    loose = now + 2 * c_orig
    view = _view([plan], now=now, busy=busy, reqs=[req_with(loose)])
    out = sched.schedule(view)
    assert len(out) == 1 and not out[0].use_variant  # dynamic state: original fits


def test_terastal_respects_accuracy_threshold():
    plan = _mini_plan(deadline=1 / 30, model=vgg11(384))
    assert plan.variants
    sched = TerastalScheduler()
    lidx = sorted(plan.variants)[0]
    r = _req(1, 0, 0.0, 0.08, layer=lidx)
    # poison: pretend every variant already applied -> combo invalid
    r.applied_variants = frozenset(plan.variants)
    assert not sched._variant_ok(plan, r, lidx)


def test_no_variants_flag_never_assigns_variants():
    sc = SCENARIOS["multicam_heavy"]
    plat = PLATFORMS["6k_1ws2os"]
    plans, tasks = sc.plans(plat)
    res = simulate(plans, tasks, 1.0, make_scheduler("terastal_no_variants"), seed=0)
    assert all(s.variants_applied == 0 for s in res.per_model.values())


def test_all_schedulers_return_valid_assignments():
    plan = _mini_plan(deadline=0.05)
    reqs = [_req(i, 0, 0.001 * i, 0.05) for i in range(5)]
    view = _view([plan], now=0.01, reqs=reqs)
    for name in ALL_SCHEDULERS:
        out = make_scheduler(name).schedule(view)
        accs = [a.acc for a in out]
        assert len(accs) == len(set(accs))  # one layer per accelerator
        assert len(out) <= plan.platform.n_acc
        for a in out:
            assert a.req in reqs
            assert a.layer == a.req.next_layer


def test_scheduler_only_targets_idle_accelerators():
    plan = _mini_plan(deadline=0.05)
    reqs = [_req(i, 0, 0.0, 0.05) for i in range(4)]
    busy = np.array([10.0, 0.0, 10.0])  # only acc 1 idle
    view = _view([plan], now=0.0, busy=busy, reqs=reqs)
    for name in ALL_SCHEDULERS:
        for a in make_scheduler(name).schedule(view):
            assert a.acc == 1

"""Property tests for fault-aware budget re-tightening (PR 10).

The re-tightening kernel (:func:`repro.core.faults.retightened_vdl`) and
the degraded admission tables (:func:`degraded_work_tables`) are shared
by all three engines, so their algebraic properties are the fault axis's
correctness surface:

* re-tightened virtual deadlines stay strictly increasing along every
  DAG edge whenever the tightening is feasible (the Eq. 2 invariant the
  precedence-aware dispatcher relies on);
* restoration is idempotent — nominal capability takes the
  ``effective_plans`` identity fast path and every chain falls back to
  the frozen offline schedule, bit-for-bit;
* feasibility is monotone under restoration — capability can only get
  easier when an accelerator comes back or a throttle lifts;
* every re-tightened budget floors at the layer's *effective* minimum
  latency and the chain terminal lands on the deadline (the whole
  deadline is redistributed, none is abandoned);
* uniform throttling is scale-equivariant: throttling every accelerator
  by ``f`` yields the chain of the nominal tables with deadline ``D/f``,
  stretched back by ``f``;
* degraded admission work estimates are monotone in capability and
  collapse to the frozen nominal tables at full capability.

The draws are seeded NumPy streams so the suite is deterministic without
the optional ``hypothesis`` extra; when hypothesis IS installed an extra
fuzzing pass hunts the same invariants over adversarial multipliers.
"""

import math

import numpy as np
import pytest

from repro.core.budget import latency_levels, tighten_budgets
from repro.core.faults import (
    degraded_work_tables,
    effective_plans,
    fault_multipliers,
    retightened_vdl,
)
from repro.core.workload import get_scenario
from repro.costmodel.maestro import PLATFORMS

_PLANS = {}


def _cell(name, platform="6k_1ws2os"):
    key = (name, platform)
    if key not in _PLANS:
        sc = get_scenario(name)
        _PLANS[key] = sc.plans(PLATFORMS[platform])
    return _PLANS[key]


def _draw_mult(rng, na):
    """One random capability: each accelerator independently down (p=.3)
    or throttled by a factor in [1, 5] (p=.5); at least one stays up."""
    avail = rng.random(na) > 0.3
    if not avail.any():
        avail[int(rng.integers(na))] = True
    throttled = rng.random(na) > 0.5
    scale = np.where(throttled, 1.0 + rng.random(na) * 4.0, 1.0)
    return fault_multipliers(scale.tolist(), avail.tolist())


def _milder(rng, mult):
    """A capability elementwise no harsher than ``mult``: throttles relax
    toward 1 and each down accelerator is restored with p=.5."""
    out = []
    for m in mult:
        if math.isinf(m):
            out.append(1.0 + rng.random() * 2.0 if rng.random() < 0.5
                       else math.inf)
        else:
            out.append(1.0 + (m - 1.0) * rng.random())
    return np.minimum(np.array(out), np.where(np.isinf(mult), np.inf, mult))


def _edges(dag):
    for l in range(dag.n_nodes):
        for s in dag.succs[l]:
            yield l, s


# ------------------------------------------------- the property bodies --


def _check_dag_edges_strictly_increasing(plans, mult):
    eff = effective_plans(plans, mult)
    chains = retightened_vdl(plans, eff)
    for p, ch in zip(plans, chains):
        if ch is None or p.dag is None:
            continue
        for u, v in _edges(p.dag):
            assert ch[v] > ch[u], (
                f"re-tightened vdl not increasing along edge {u}->{v}: "
                f"{ch[u]} -> {ch[v]} under mult={mult}"
            )


def _check_restoration_idempotent(plans):
    nominal = fault_multipliers([1.0] * plans[0].platform.n_acc,
                                [True] * plans[0].platform.n_acc)
    eff = effective_plans(plans, nominal)
    for p, ep in zip(plans, eff):
        assert ep is p  # identity fast path: same objects, zero copies
    assert retightened_vdl(plans, eff) == [None] * len(plans)
    # and the frozen admission tables come back bit-identical
    ms, wn = degraded_work_tables(eff, 2.0)
    assert ms == [p.crit_total for p in plans]
    assert wn == [int(round(p.crit_total * 1e9)) for p in plans]


def _check_feasibility_monotone(plans, mult, milder):
    eff1 = effective_plans(plans, mult)
    eff2 = effective_plans(plans, milder)
    ch1 = retightened_vdl(plans, eff1)
    ch2 = retightened_vdl(plans, eff2)
    for m, (c1, c2) in enumerate(zip(ch1, ch2)):
        if c1 is None:
            continue  # infeasible or nominal under the harsher capability
        if eff2[m] is plans[m]:
            continue  # fully restored: frozen chain, feasible by design
        assert c2 is not None, (
            f"model {m} feasible under mult={mult} but infeasible under "
            f"the milder {milder}"
        )


def _check_budget_floors_and_terminal(plans, mult):
    eff = effective_plans(plans, mult)
    chains = retightened_vdl(plans, eff)
    for p, ep, ch in zip(plans, eff, chains):
        if ch is None:
            continue
        minl = np.array([np.min(ep.lat[l][np.isfinite(ep.lat[l])])
                         for l in range(ep.lat.shape[0])])
        if p.dag is None:
            budgets = np.diff(np.concatenate([[0.0], ch]))
            sink_vdl = ch[-1]
        else:
            budgets = np.array([
                ch[l] - max((ch[q] for q in p.dag.preds[l]), default=0.0)
                for l in range(p.dag.n_nodes)
            ])
            sink_vdl = ch[p.dag.sink]
        assert np.all(budgets >= minl * (1.0 - 1e-9)), (
            "re-tightened budget below the effective minimum latency"
        )
        assert sink_vdl == pytest.approx(p.deadline, rel=1e-9), (
            "re-tightening abandoned part of the deadline"
        )


# ----------------------------------------------- seeded deterministic ---


@pytest.mark.parametrize("scenario", ["fault_dag_dropout", "multicam_heavy"])
def test_retightened_vdl_properties_seeded(scenario):
    plans, _ = _cell(scenario)
    na = plans[0].platform.n_acc
    _check_restoration_idempotent(plans)
    rng = np.random.default_rng(0)
    feasible_seen = 0
    for _ in range(40):
        mult = _draw_mult(rng, na)
        _check_dag_edges_strictly_increasing(plans, mult)
        _check_budget_floors_and_terminal(plans, mult)
        _check_feasibility_monotone(plans, mult, _milder(rng, mult))
        feasible_seen += sum(
            c is not None
            for c in retightened_vdl(plans, effective_plans(plans, mult))
        )
    assert feasible_seen > 0, "draws never produced a re-tightened chain"


def test_uniform_throttle_scale_equivariance():
    """Throttling every accelerator by ``f`` is the same tightening
    problem as nominal latencies with deadline ``D/f``, stretched back
    by ``f`` — the gap-ordering the tightening loop follows is invariant
    under a uniform scale."""
    plans, _ = _cell("multicam_heavy")
    na = plans[0].platform.n_acc
    for f in (1.5, 2.0, 3.0):
        mult = fault_multipliers([f] * na, [True] * na)
        chains = retightened_vdl(plans, effective_plans(plans, mult))
        for p, ch in zip(plans, chains):
            if p.dag is not None:
                continue
            levels = [latency_levels(p.lat[l]) for l in range(p.lat.shape[0])]
            res = tighten_budgets(levels, p.deadline / f)
            if ch is None:
                assert not res.feasible
                continue
            assert res.feasible
            np.testing.assert_allclose(
                ch, f * res.virtual_deadlines, rtol=1e-9)


def test_degraded_work_tables_monotone_and_clamped():
    plans, _ = _cell("multicam_heavy")
    na = plans[0].platform.n_acc
    rng = np.random.default_rng(1)
    duration = 2.0
    for _ in range(25):
        mult = _draw_mult(rng, na)
        milder = _milder(rng, mult)
        w1, n1 = degraded_work_tables(effective_plans(plans, mult), duration)
        w2, n2 = degraded_work_tables(effective_plans(plans, milder), duration)
        for a, b, ia, ib in zip(w1, w2, n1, n2):
            assert b <= a or (math.isinf(a) and math.isinf(b))
            assert isinstance(ia, int) and isinstance(ib, int)
            assert 0 <= ib <= ia <= int(round(duration * 1e9))
    # every accelerator down for a layer -> inf work, ns clamped to horizon
    dead = fault_multipliers([1.0] * na, [False] * na)
    # fault_multipliers requires one up in the engines; build the
    # all-down mask directly — the helper itself must stay total
    assert np.all(np.isinf(dead))
    wd, nd = degraded_work_tables(effective_plans(plans, dead), duration)
    assert all(math.isinf(w) for w in wd)
    assert all(n == int(round(duration * 1e9)) for n in nd)


# ------------------------------------------------- hypothesis fuzzing ---


try:  # optional test extra — the fuzzing pass skips without it
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @st.composite
    def _mults(draw, na):
        avail = draw(st.lists(st.booleans(), min_size=na, max_size=na)
                     .filter(lambda a: any(a)))
        scale = draw(st.lists(
            st.floats(min_value=1.0, max_value=16.0,
                      allow_nan=False, allow_infinity=False),
            min_size=na, max_size=na))
        return fault_multipliers(scale, avail)

    @settings(max_examples=60, deadline=None)
    @given(data=st.data())
    def test_retightened_vdl_properties_fuzzed(data):
        plans, _ = _cell("fault_dag_dropout")
        na = plans[0].platform.n_acc
        mult = data.draw(_mults(na))
        _check_dag_edges_strictly_increasing(plans, mult)
        _check_budget_floors_and_terminal(plans, mult)
        u = data.draw(st.lists(
            st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
            min_size=na, max_size=na))
        milder = np.array([
            1.0 + (m - 1.0) * f if math.isfinite(m) else math.inf
            for m, f in zip(mult, u)
        ])
        _check_feasibility_monotone(plans, mult, milder)

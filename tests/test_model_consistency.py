"""Cross-path consistency: for every family, token-by-token decode must
reproduce the train-mode forward logits exactly (same math, different
code paths: flash vs cached attention, chunked vs recurrent SSD)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test-extra; skip, don't error, when absent
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, get_config
from repro.models.common import embed, flash_attention, naive_attention, rmsnorm
from repro.models.model_api import build_model

KEY = jax.random.PRNGKey(7)
PREFIX = 8  # tokens decoded sequentially

# one representative arch per family (reduced configs)
FAMILY_ARCHS = [
    "llama3.2-1b",        # dense
    "qwen3-moe-235b-a22b",  # moe (every block)
    "llama4-maverick-400b-a17b",  # moe interleaved
    "mamba2-1.3b",        # ssm
    "zamba2-2.7b",        # hybrid
    "llava-next-34b",     # vlm (dense backbone path)
]


def _train_logits_at(cfg, model, params, tokens, t):
    """Train-mode forward, logits for position t."""
    from repro.models import hybrid, mamba2, moe, transformer

    B = tokens.shape[0]
    x = embed(params["embed"], tokens[:, : t + 1])
    positions = jnp.broadcast_to(jnp.arange(t + 1)[None], (B, t + 1))
    if cfg.family in ("dense", "vlm"):
        h = transformer.forward_hidden_dense(cfg, params, x, positions)
        w = transformer._lm_head_w(cfg, params)
    elif cfg.family == "moe":
        h, _ = moe.forward_hidden_moe(cfg, params, x, positions)
        w = transformer._lm_head_w(cfg, params)
    elif cfg.family == "ssm":
        hh = x
        for li in range(cfg.n_layers):
            pb = jax.tree.map(lambda a: a[li], params["blocks"])
            hh = mamba2.mamba_block_apply(cfg, pb, hh)
        h = rmsnorm(params["final_norm"], hh, cfg.norm_eps)
        w = params["embed"]["emb"].T
    elif cfg.family == "hybrid":
        hh = x
        shared = params["shared_attn"]
        ng = cfg.n_layers // cfg.hybrid_attn_every
        for g in range(ng):
            hh = transformer.dense_block_apply(cfg, shared, hh, positions)
            for i in range(cfg.hybrid_attn_every):
                pb = jax.tree.map(lambda a: a[g][i], params["mamba_blocks"])
                hh = mamba2.mamba_block_apply(cfg, pb, hh)
        h = rmsnorm(params["final_norm"], hh, cfg.norm_eps)
        w = params["embed"]["emb"].T
    else:
        raise ValueError(cfg.family)
    return (h[:, t] @ w).astype(jnp.float32)


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_decode_matches_train_forward(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    # chunk sizes that exercise multi-chunk paths at tiny lengths
    cfg = dataclasses.replace(cfg, ssm_chunk=4, attn_q_chunk=4, attn_k_chunk=4)
    model = build_model(cfg)
    params = model.init(KEY)
    B = 2
    tokens = jax.random.randint(KEY, (B, PREFIX), 0, cfg.vocab_size)
    cache = model.init_cache(B, PREFIX)
    step = jax.jit(model.decode_step)
    for i in range(PREFIX):
        logits_dec, cache = step(params, tokens[:, i], cache, jnp.int32(i))
    logits_train = _train_logits_at(cfg, model, params, tokens, PREFIX - 1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_train), atol=2e-4, rtol=2e-3
    )


def test_whisper_decode_matches_train():
    cfg = get_config("whisper-base").reduced(dtype="float32")
    from repro.models import whisper

    model = build_model(cfg)
    params = model.init(KEY)
    B = 2
    frames = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    tokens = jax.random.randint(KEY, (B, PREFIX), 0, cfg.vocab_size)
    enc = whisper.encode(cfg, params, frames)
    cache = model.init_cache(B, PREFIX)
    cache = whisper.encdec_prefill_cross(cfg, params, enc, cache)
    step = jax.jit(model.decode_step)
    for i in range(PREFIX):
        logits_dec, cache = step(params, tokens[:, i], cache, jnp.int32(i))
    h = whisper.decoder_hidden(cfg, params, tokens, enc)
    logits_train = (h[:, -1] @ params["embed"]["emb"].T).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_train), atol=2e-4, rtol=2e-3
    )


def test_int8_kv_decode_close_to_exact():
    """kv_cache_quant trades ~1e-2-scale logit error for 2x bandwidth."""
    cfg = get_config("llama3.2-1b").reduced(dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    cfg_q = dataclasses.replace(cfg, kv_cache_quant=True)
    model_q = build_model(cfg_q)
    B = 2
    tokens = jax.random.randint(KEY, (B, PREFIX), 0, cfg.vocab_size)
    c0, c1 = model.init_cache(B, PREFIX), model_q.init_cache(B, PREFIX)
    assert c1["k"].dtype == jnp.int8
    s0, s1 = jax.jit(model.decode_step), jax.jit(model_q.decode_step)
    for i in range(PREFIX):
        l0, c0 = s0(params, tokens[:, i], c0, jnp.int32(i))
        l1, c1 = s1(params, tokens[:, i], c1, jnp.int32(i))
    # same argmax, small numeric drift
    np.testing.assert_array_equal(np.argmax(l0, -1), np.argmax(l1, -1))
    assert float(jnp.abs(l0 - l1).max()) < 0.3


# ---------------------------- attention properties ---------------------------


@given(
    lq=st.integers(1, 40),
    lk=st.integers(1, 48),
    h=st.sampled_from([1, 2, 4, 8]),
    g=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
    qc=st.sampled_from([3, 8, 16]),
    kc=st.sampled_from([5, 16]),
)
@settings(max_examples=40, deadline=None)
def test_property_flash_matches_naive(lq, lk, h, g, causal, qc, kc):
    if causal and lq > lk:
        lq = lk  # causal with q beyond k has fully-masked rows
    hq = h * g
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(lq * 131 + lk), 3)
    q = jax.random.normal(k1, (2, lq, hq, 8))
    k = jax.random.normal(k2, (2, lk, h, 8))
    v = jax.random.normal(k3, (2, lk, h, 8))
    o1 = naive_attention(q, k, v, causal=causal)
    o2 = flash_attention(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1), atol=2e-5, rtol=2e-4)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and top-1 routing, dropped-token mass is
    bounded; y stays finite and gates renormalize."""
    from repro.models.config import ModelConfig
    from repro.models.moe import moe_dispatch

    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=4,
                      experts_per_token=2, moe_d_ff=16, capacity_factor=1.5,
                      moe_group_size=32)
    x = jax.random.normal(KEY, (2, 32, 16))
    router = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    dispatch, combine, aux = moe_dispatch(cfg, router, x)
    assert float(aux) > 0
    # every dispatched slot holds at most one token
    per_slot = dispatch.sum(axis=1)  # [G, E, C]
    assert float(per_slot.max()) <= 1.0 + 1e-6
    # combine weights within [0, 1]
    assert float(combine.max()) <= 1.0 + 1e-6 and float(combine.min()) >= 0.0

"""Statistical validity of the sequential sampler's machinery.

Three layers, all on seeded synthetic data (no simulator in the loop —
this suite tests the *statistics*, the differentials in
``tests/test_sampling.py`` test the plumbing):

1. the hand-rolled incomplete-beta / paired-t tail probabilities match
   textbook critical values (no scipy in the image to lean on);
2. ``bootstrap_ci`` empirical coverage on Bernoulli data matches both
   its nominal level and the analytic normal-approximation binomial CI
   computed on the identical draws;
3. the sequential stopping rule's family-wise false-separation rate on
   null (equal-mean) cells stays below its nominal alpha — and the
   suite also *documents the hazard the t-gate exists to prevent* by
   measuring that the naive small-n percentile bootstrap alone blows
   far past alpha under the same protocol.

Every test is deterministic (fixed PRNG seeds), so the measured rates
are regression pins, not flaky estimates.
"""

import numpy as np
import pytest

from repro.core import SamplerConfig, bootstrap_ci, gap_separates, paired_t_pvalue
from repro.core.sampling import betainc


# ------------------------------------------------- t-tail first principles -


def test_betainc_matches_textbook_t_critical_values():
    """Two-sided p of the t statistic is I_x(df/2, 1/2) with
    x = df/(df + t^2); the classic table rows must come back out."""
    for df, t, p_want in (
        (1, 12.706, 0.05),
        (4, 2.776, 0.05),
        (7, 2.365, 0.05),
        (9, 3.250, 0.01),
        (30, 2.042, 0.05),
    ):
        p = betainc(df / 2.0, 0.5, df / (df + t * t))
        assert p == pytest.approx(p_want, rel=2e-3), (df, t)
    # boundary behavior
    assert betainc(2.0, 0.5, 0.0) == 0.0
    assert betainc(2.0, 0.5, 1.0) == 1.0
    # symmetry of the regularized incomplete beta: I_x(a,b) = 1 - I_{1-x}(b,a)
    for a, b, x in ((2.0, 3.0, 0.3), (0.5, 5.0, 0.7)):
        assert betainc(a, b, x) == pytest.approx(1.0 - betainc(b, a, 1.0 - x), abs=1e-12)


def test_paired_t_pvalue_properties():
    rng = np.random.default_rng(0)
    d = rng.normal(0.0, 1.0, size=8)
    p = paired_t_pvalue(d)
    assert 0.0 < p <= 1.0
    # shifting the sample away from zero must shrink the p-value
    assert paired_t_pvalue(d + 2.0) < p
    # zero-variance degenerate cases: certainty, not NaN
    assert paired_t_pvalue([0.0, 0.0, 0.0]) == 1.0
    assert paired_t_pvalue([0.5, 0.5, 0.5]) == 0.0
    from repro.core import DegenerateSampleError

    with pytest.raises(DegenerateSampleError):
        paired_t_pvalue([1.0])


# ------------------------------------------------------ bootstrap coverage -


def test_bootstrap_ci_coverage_matches_analytic_binomial():
    """On Bernoulli(p) samples the percentile-bootstrap CI of the mean
    must cover the true p at ~ its nominal rate, and agree with the
    analytic normal-approximation binomial CI evaluated on the *same*
    draws (same point estimate, same n) — the analytic CI is the
    external yardstick the bootstrap has to reproduce."""
    p0, n, reps, alpha = 0.3, 40, 400, 0.10
    z = 1.6448536269514722  # Phi^{-1}(0.95)
    rng = np.random.default_rng(42)
    cov_boot = cov_wald = 0
    for r in range(reps):
        x = (rng.random(n) < p0).astype(float)
        lo, hi = bootstrap_ci(x, n_boot=400, alpha=alpha, seed=r)
        cov_boot += lo <= p0 <= hi
        m = x.mean()
        half = z * np.sqrt(m * (1.0 - m) / n)
        cov_wald += m - half <= p0 <= m + half
    cov_boot /= reps
    cov_wald /= reps
    # measured (pinned seeds): 0.91 for both at nominal 0.90
    assert cov_boot == pytest.approx(1.0 - alpha, abs=0.05)
    assert cov_boot == pytest.approx(cov_wald, abs=0.03)


# ------------------------------------------------- sequential type-I error -


def _sequential_walk(diffs, config, cap, separate_fn):
    """Replay the sampler's look ladder on a full diff vector: returns
    True if any look declares separation (the family-wise event)."""
    looks = config.looks(cap)
    alpha_look = config.alpha / len(looks)
    for k in looks:
        if separate_fn(diffs[:k], alpha_look):
            return True
    return False


def test_false_separation_rate_below_alpha_on_null_cells():
    """Null cells (paired diffs with mean zero): the full sequential
    ladder — every look, Bonferroni-adjusted, bootstrap CI + t-gate —
    must separate in at most an alpha fraction of replicates.  Two null
    shapes: Gaussian diffs, and differences of binomial miss-rate means
    (what paired campaign cells actually produce)."""
    config = SamplerConfig()  # alpha=0.05, min_seeds=3, round_seeds=1
    cap, reps = 8, 400

    def gated(d, a):
        return gap_separates(d, alpha=a, n_boot=300, ci_seed=0)[2]

    rng = np.random.default_rng(7)
    gauss = sum(
        _sequential_walk(rng.normal(0.0, 1.0, size=cap), config, cap, gated)
        for _ in range(reps)
    )
    binom = sum(
        _sequential_walk(
            (rng.binomial(100, 0.3, size=cap) - rng.binomial(100, 0.3, size=cap))
            / 100.0,
            config,
            cap,
            gated,
        )
        for _ in range(reps)
    )
    # measured (pinned seeds): ~0.03 gaussian, similar binomial
    assert gauss / reps <= config.alpha, f"gaussian null: {gauss}/{reps}"
    assert binom / reps <= config.alpha, f"binomial null: {binom}/{reps}"


def test_naive_bootstrap_alone_is_anticonservative_at_small_n():
    """Why the t-gate exists: the same sequential protocol deciding on
    the percentile-bootstrap CI alone false-separates on null cells at
    several times the nominal alpha.  This pin keeps anyone from
    'simplifying' gap_separates back to the bare bootstrap."""
    config = SamplerConfig()
    cap, reps = 8, 300

    def bare(d, a):
        lo, hi = bootstrap_ci(d, n_boot=300, alpha=a, seed=0)
        return lo > 0.0 or hi < 0.0

    rng = np.random.default_rng(7)
    naive = sum(
        _sequential_walk(rng.normal(0.0, 1.0, size=cap), config, cap, bare)
        for _ in range(reps)
    )
    # measured (pinned seeds): ~0.32 — an order of magnitude past alpha
    assert naive / reps > 3 * config.alpha


def test_stopping_rule_has_power_against_real_gaps():
    """The rule must actually stop early on separated cells, or the
    sampler saves nothing: with a 2-sigma standardized gap it should
    both (a) separate in most replicates before the cap and (b) spend
    clearly fewer looks than the ladder allows."""
    config = SamplerConfig()
    cap, reps = 8, 200
    looks = config.looks(cap)
    alpha_look = config.alpha / len(looks)
    rng = np.random.default_rng(1)
    stops = []
    for _ in range(reps):
        d = rng.normal(-2.0, 1.0, size=cap)
        stop = cap
        for k in looks:
            if gap_separates(d[:k], alpha=alpha_look, n_boot=300, ci_seed=0)[2]:
                stop = k
                break
        stops.append(stop)
    stops = np.asarray(stops)
    assert (stops < cap).mean() > 0.8  # measured: ~0.9 separate before cap
    assert stops.mean() < 6.5  # measured: ~5.5 of 8 seeds on average
    # and the zero-variance certainty path stops at the very first look
    const = [-0.25] * cap
    assert gap_separates(const[:3], alpha=alpha_look, n_boot=300, ci_seed=0)[2]

"""Fault tolerance: checkpoint/restart, rollback, straggler, elasticity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.runtime.ft import StragglerMonitor, Supervisor


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    ckpt_lib.save(str(tmp_path), 10, state)
    assert ckpt_lib.latest_step(str(tmp_path)) == 10
    restored = ckpt_lib.restore(str(tmp_path), 10, state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"]))
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_marker(tmp_path):
    state = _state()
    d = ckpt_lib.save(str(tmp_path), 5, state)
    # remove the COMMITTED marker -> checkpoint invisible to latest_step
    os.unlink(os.path.join(d, "COMMITTED"))
    assert ckpt_lib.latest_step(str(tmp_path)) is None


def test_supervisor_rollback_on_nan(tmp_path):
    sup = Supervisor(str(tmp_path), ckpt_every=1)
    state = _state()
    sup.checkpoint(3, state)
    action, rb = sup.on_step(4, 0.1, {"loss": float("nan"), "grad_norm": 1.0}, state)
    assert action == "rollback" and rb == 3


def test_supervisor_periodic_checkpoint_and_gc(tmp_path):
    sup = Supervisor(str(tmp_path), ckpt_every=2, keep_last=2)
    state = _state()
    for step in range(2, 11, 2):
        sup.on_step(step, 0.1, {"loss": 1.0, "grad_norm": 1.0}, state)
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert len(steps) == 2  # gc kept only last 2
    assert ckpt_lib.latest_step(str(tmp_path)) == 10


def test_straggler_detection():
    mon = StragglerMonitor(factor=3.0)
    for i in range(10):
        assert not mon.observe(i, 0.1)
    assert mon.observe(10, 1.0)  # 10x median
    assert mon.events and mon.events[0][0] == 10


def test_train_restart_reproduces_data(tmp_path):
    """Restarted training resumes from the checkpoint and regenerates the
    same data sequence (pure-function pipeline)."""
    from repro.launch.train import run

    import shutil

    out1 = run("llama3.2-1b", steps=6, batch=2, seq=32, reduced=True,
               ckpt_dir=str(tmp_path / "a"), ckpt_every=3, log_every=100)
    # same run, but crash after step 3: replay from the step-3 checkpoint
    run("llama3.2-1b", steps=6, batch=2, seq=32, reduced=True,
        ckpt_dir=str(tmp_path / "b"), ckpt_every=3, log_every=100)
    shutil.rmtree(tmp_path / "b" / "step_00000006")  # "crash" lost the tail
    out2 = run("llama3.2-1b", steps=6, batch=2, seq=32, reduced=True,
               ckpt_dir=str(tmp_path / "b"), ckpt_every=3, log_every=100)
    assert abs(out1["final_loss"] - out2["final_loss"]) < 1e-5


def test_elastic_restore_to_different_sharding(tmp_path):
    """A checkpoint saved unsharded restores onto a fresh mesh (elastic
    re-mesh: same bytes, new NamedShardings)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    state = _state()
    ckpt_lib.save(str(tmp_path), 1, state)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shardings = {
        "params": {"w": NamedSharding(mesh, P("data", None)), "b": NamedSharding(mesh, P(None))},
        "step": NamedSharding(mesh, P()),
    }
    restored = ckpt_lib.restore(str(tmp_path), 1, state, shardings)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"]))

"""Event-driven simulator: conservation laws, drops, reproduction claims."""

import numpy as np
import pytest

from repro.core import (
    ALL_SCHEDULERS,
    SCENARIOS,
    TaskSpec,
    make_scheduler,
    simulate,
)
from repro.core.simulator import MmppArrivals, PoissonArrivals, generate_arrivals
from repro.core.variants import build_model_plan
from repro.costmodel.dnn_zoo import vgg11
from repro.costmodel.maestro import PLATFORMS


def test_arrivals_periodic_and_probabilistic():
    tasks = [TaskSpec(0, fps=10), TaskSpec(1, fps=30, prob=0.5)]
    arr = generate_arrivals(tasks, duration=1.0, seed=0)
    t0 = [a for a, m in arr if m == 0]
    assert len(t0) == 10
    np.testing.assert_allclose(np.diff(t0), 0.1)
    t1 = [a for a, m in arr if m == 1]
    assert 5 <= len(t1) <= 25  # ~15 expected


# -------------------------- vectorized arrival streams (draw-for-draw) ----
#
# PoissonArrivals/MmppArrivals batch their exponential draws through
# `_exp_stream` (snapshot/rewind on the crossing batch).  The contract is
# draw-for-draw stream identity with the scalar loops below — which are
# literal copies of the pre-vectorization implementations — including the
# FINAL GENERATOR STATE, because all tasks of a trial consume one shared
# stream and a mispositioned stream would silently change every later task.


def _poisson_scalar(proc, task, duration, rng):
    rate = task.fps * proc.rate_scale
    out = []
    if rate <= 0.0:
        return out
    t = rng.exponential(1.0 / rate)
    while t < duration:
        if task.prob >= 1.0 or rng.random() < task.prob:
            out.append(t)
        t += rng.exponential(1.0 / rate)
    return out


def _mmpp_scalar(proc, task, duration, rng):
    b = max(1.0, float(proc.burstiness))
    p = min(max(float(proc.on_fraction), 1e-6), 1.0, 1.0 / b)
    rate_on = task.fps * b
    rate_off = task.fps * max(0.0, 1.0 - p * b) / (1.0 - p) if p < 1.0 else task.fps
    cycle = proc.mean_cycle * task.period
    mean_soj = {True: p * cycle, False: (1.0 - p) * cycle}
    out = []
    t = 0.0
    on = rng.random() < p
    while t < duration:
        end = min(t + rng.exponential(mean_soj[on]), duration)
        rate = rate_on if on else rate_off
        if rate > 0.0:
            nxt = t + rng.exponential(1.0 / rate)
            while nxt < end:
                if task.prob >= 1.0 or rng.random() < task.prob:
                    out.append(nxt)
                nxt += rng.exponential(1.0 / rate)
        t = end
        on = not on
    return out


@pytest.mark.parametrize("fps,duration,prob", [
    (60, 5.0, 1.0),   # fig7-scale rate, whole-horizon batch
    (10, 3.0, 1.0),   # sparse stream (few draws, crossing in first chunk)
    (45, 0.01, 1.0),  # horizon shorter than one period (often 0 arrivals)
    (360, 2.0, 1.0),  # saturation-scale rate (multi-chunk growth path)
    (30, 5.0, 0.5),   # prob < 1: interleaved thinning -> scalar fallback
])
def test_poisson_sample_draw_for_draw(fps, duration, prob):
    task = TaskSpec(0, fps=fps, prob=prob)
    for proc in (PoissonArrivals(), PoissonArrivals(rate_scale=3.0)):
        for seed in range(10):
            r1 = np.random.default_rng(seed)
            r2 = np.random.default_rng(seed)
            got = proc.sample(task, duration, r1)
            want = _poisson_scalar(proc, task, duration, r2)
            assert got == want  # bitwise: same floats, same count
            # identical stream position: the next draws must agree too
            assert r1.bit_generator.state == r2.bit_generator.state
            assert r1.random() == r2.random()


@pytest.mark.parametrize("fps,duration,prob", [
    (60, 5.0, 1.0),
    (360, 2.0, 1.0),
    (30, 5.0, 0.5),   # prob < 1 keeps the scalar per-segment loop
])
def test_mmpp_sample_draw_for_draw(fps, duration, prob):
    task = TaskSpec(0, fps=fps, prob=prob)
    for proc in (
        MmppArrivals(),
        MmppArrivals(burstiness=8, on_fraction=0.125),
        MmppArrivals(burstiness=2, on_fraction=0.5, mean_cycle=5),
        MmppArrivals(burstiness=1),  # degenerates to plain Poisson
    ):
        for seed in range(10):
            r1 = np.random.default_rng(seed)
            r2 = np.random.default_rng(seed)
            got = proc.sample(task, duration, r1)
            want = _mmpp_scalar(proc, task, duration, r2)
            assert got == want
            assert r1.bit_generator.state == r2.bit_generator.state
            assert r1.random() == r2.random()


def test_exp_stream_batched_prefix_property():
    """The rewind trick requires that a shorter batched draw is a prefix
    of a longer one from the same state — numpy's ziggurat fills
    sequentially; pin it so a numpy behavior change cannot silently
    corrupt arrival streams."""
    for seed in (0, 7):
        r1 = np.random.default_rng(seed)
        r2 = np.random.default_rng(seed)
        long = r1.exponential(2.0, 64)
        short = r2.exponential(2.0, 17)
        np.testing.assert_array_equal(long[:17], short)
        # and batched == repeated scalar draws
        r3 = np.random.default_rng(seed)
        scalars = [r3.exponential(2.0) for _ in range(17)]
        np.testing.assert_array_equal(short, scalars)


def test_single_model_light_load_all_meet():
    plat = PLATFORMS["6k_1ws2os"]
    plan = build_model_plan(vgg11(224), plat, deadline=0.2)
    res = simulate([plan], [TaskSpec(0, fps=5)], 1.0, make_scheduler("fcfs"))
    st = res.per_model[0]
    assert st.released == 5
    assert st.missed == 0
    assert st.completed == 5


def test_conservation_released_eq_completed_plus_dropped_or_inflight():
    sc = SCENARIOS["multicam_heavy"]
    plat = PLATFORMS["6k_1ws2os"]
    plans, tasks = sc.plans(plat)
    for name in ALL_SCHEDULERS:
        res = simulate(plans, tasks, 1.0, make_scheduler(name), seed=1)
        for m, s in res.per_model.items():
            # in-flight at horizon end are neither completed nor dropped
            assert s.completed + s.dropped <= s.released
            assert s.missed >= s.dropped


def test_overload_drops_requests():
    plat = PLATFORMS["4k_1ws2os"]
    plan = build_model_plan(vgg11(448), plat, deadline=1 / 60)
    # 60 fps VGG11@448 is far beyond one platform's capacity
    res = simulate([plan], [TaskSpec(0, fps=60)], 1.0, make_scheduler("fcfs"))
    st = res.per_model[0]
    assert st.dropped > 0
    assert st.miss_rate > 0.3


def test_utilization_bounded():
    sc = SCENARIOS["ar_social"]
    plat = PLATFORMS["4k_1ws2os"]
    plans, tasks = sc.plans(plat)
    res = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=0)
    u = res.utilization()
    assert (u >= 0).all() and (u <= 1.0 + 1e-9).all()


def test_utilization_clamped_to_horizon():
    """Layers dispatched near the horizon run past ``duration`` but their
    full latency used to be charged to busy time, pushing the raw ratio
    over 1.0.  utilization() now clamps each dispatch's contribution to
    the time left before the horizon; the unclamped accounting stays
    available (and is the one that can exceed 1.0)."""
    plat = PLATFORMS["4k_1ws2os"]
    plan = build_model_plan(vgg11(448), plat, deadline=0.5)
    # horizon shorter than one full execution: most busy time is overhang
    duration = float(plan.remaining_min[0]) * 0.25
    res = simulate([plan], [TaskSpec(0, fps=1 / duration)], duration,
                   make_scheduler("fcfs"))
    raw = res.utilization(clamp=False)
    clamped = res.utilization()
    assert raw.max() > 1.0  # the historical accounting overshoots
    assert (clamped >= 0).all() and (clamped <= 1.0 + 1e-9).all()
    assert (clamped <= raw + 1e-12).all()
    # layers run back-to-back from t=0 past the horizon, so the clamped
    # busy time sums to exactly the horizon (one accelerator at a time)
    np.testing.assert_allclose(clamped.sum(), 1.0)
    # both engines agree on both accountings
    ref = simulate([plan], [TaskSpec(0, fps=1 / duration)], duration,
                   make_scheduler("fcfs"), engine="reference")
    assert ref.acc_busy_time.tolist() == res.acc_busy_time.tolist()
    assert ref.acc_busy_in_horizon.tolist() == res.acc_busy_in_horizon.tolist()


def test_determinism_same_seed():
    sc = SCENARIOS["ar_gaming_heavy"]
    plat = PLATFORMS["6k_1ws2os"]
    plans, tasks = sc.plans(plat)
    r1 = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=3)
    r2 = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=3)
    assert r1.mean_miss_rate == r2.mean_miss_rate
    assert r1.acc_busy_time.tolist() == r2.acc_busy_time.tolist()


def test_headline_claim_ordering():
    """The paper's Fig. 5 ordering on the aggregate: full Terastal beats
    FCFS, EDF, DREAM, and its own ablations; no-variants beats the
    conventional baselines."""
    from repro.core.workload import scenario_platform_pairs

    means = {n: [] for n in ALL_SCHEDULERS}
    for sc, plat in scenario_platform_pairs():
        plans, tasks = sc.plans(plat)
        for name in ALL_SCHEDULERS:
            res = simulate(plans, tasks, 2.0, make_scheduler(name), seed=0)
            means[name].append(res.mean_miss_rate)
    agg = {n: float(np.mean(v)) for n, v in means.items()}
    assert agg["terastal"] < agg["fcfs"]
    assert agg["terastal"] < agg["edf"]
    assert agg["terastal"] < agg["dream"]
    assert agg["terastal"] <= agg["terastal_no_variants"]
    assert agg["terastal"] < agg["terastal_no_budgeting"]
    assert agg["terastal_no_variants"] < min(agg["fcfs"], agg["edf"], agg["dream"])


def test_accuracy_loss_within_threshold():
    """Normalized accuracy loss never exceeds 1 - theta for any model."""
    sc = SCENARIOS["multicam_heavy"]
    plat = PLATFORMS["6k_1ws2os"]
    theta = 0.90
    plans, tasks = sc.plans(plat, theta=theta)
    res = simulate(plans, tasks, 2.0, make_scheduler("terastal"), seed=0)
    for m, s in res.per_model.items():
        if s.completed:
            assert s.mean_norm_accuracy_loss <= (1 - theta) + 1e-9


# ------------------------- honest accuracy-loss metric (overload fix) ----


def test_zero_completion_model_mean_retained_is_nan():
    """saturation_8x pin: a model that released plenty but completed
    nothing reports NaN retained accuracy — the pre-fix 1.0 default read
    as "no loss" and silently flattered the headline metric pair."""
    from repro.core.workload import get_scenario

    plans, tasks = get_scenario("saturation_8x").plans(
        PLATFORMS["6k_1ws2os"], theta=0.90)
    procs = [t.arrival for t in tasks]
    res = simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0,
                   processes=procs)
    starved = [m for m, s in res.per_model.items()
               if s.released and not s.completed]
    assert starved, "saturation_8x no longer starves any model; re-pin"
    for m in starved:
        assert np.isnan(res.per_model[m].mean_retained)
        assert np.isnan(res.per_model[m].mean_norm_accuracy_loss)
    # saturation plans carry no variants (slack-4 deadlines keep
    # Algorithm 1 feasible), so the cell-level loss is NaN with an
    # explicit zero denominator — never a flattering 0.0
    loss, counted, with_var = res.accuracy_loss_stats(plans)
    assert np.isnan(loss) and counted == 0 and with_var == 0
    assert np.isnan(res.mean_accuracy_loss(plans))


def test_accuracy_loss_excludes_zero_completion_models():
    """Exclusion contract on a variant-bearing cell: zeroing one variant
    model's completions shrinks ``models_counted`` (flagging the
    exclusion) without dragging the mean toward zero loss."""
    import dataclasses as _dc

    sc = SCENARIOS["multicam_heavy"]
    plans, tasks = sc.plans(PLATFORMS["6k_1ws2os"], theta=0.90)
    res = simulate(plans, tasks, 2.0, make_scheduler("terastal"), seed=0)
    loss0, counted0, with_var0 = res.accuracy_loss_stats(plans)
    assert with_var0 >= 2 and counted0 == with_var0
    assert np.isfinite(loss0)
    victim = next(m for m, s in sorted(res.per_model.items())
                  if plans[m].variants)
    res.per_model[victim] = _dc.replace(
        res.per_model[victim], completed=0, retained_sum=0.0)
    loss1, counted1, with_var1 = res.accuracy_loss_stats(plans)
    assert with_var1 == with_var0
    assert counted1 == counted0 - 1
    survivors = [m for m, s in sorted(res.per_model.items())
                 if plans[m].variants and s.completed]
    want = float(np.mean([res.per_model[m].mean_norm_accuracy_loss
                          for m in survivors]))
    assert loss1 == want


# ----------------------------------- trace-span validation (bugfix) ----


def test_trace_arrivals_rejects_zero_and_negative_span():
    from repro.core.simulator import TraceArrivals, make_arrival_process

    for bad in (0.0, -1.0):
        with pytest.raises(ValueError, match="bad arguments for arrival "
                                             "process 'trace'"):
            TraceArrivals(times=(0.0, 0.1), span=bad)
    # None still means trace-derived span, and a positive span works
    p = TraceArrivals(times=(0.0, 0.1), span=None)
    q = TraceArrivals(times=(0.0, 0.1), span=0.2)
    t = TaskSpec(0, fps=10.0)
    rng = np.random.default_rng(0)
    np.testing.assert_allclose(q.sample(t, 0.5, rng),
                               [0.0, 0.1, 0.2, 0.3, 0.4], atol=1e-12)
    assert p.sample(t, 0.3, np.random.default_rng(0))  # derived span ok

"""Numerics: chunked CE vs naive, AdamW vs reference, RoPE laws, data
pipeline determinism, MoE dispatch conservation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test-extra; skip, don't error, when absent
from hypothesis import given, settings, strategies as st

from repro.models.common import apply_rope, chunked_softmax_xent, rmsnorm, init_rmsnorm
from repro.optim.adamw import OptConfig, adamw_update, global_norm, init_opt_state, lr_at

KEY = jax.random.PRNGKey(3)


# ----------------------------------------------------------- cross-entropy --


@given(B=st.integers(1, 3), L=st.sampled_from([4, 7, 16]), V=st.sampled_from([11, 32]),
       chunk=st.sampled_from([2, 4, 16]))
@settings(max_examples=30, deadline=None)
def test_chunked_xent_matches_naive(B, L, V, chunk):
    D = 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B * 100 + L), 3)
    h = jax.random.normal(k1, (B, L, D))
    w = jax.random.normal(k2, (D, V))
    y = jax.random.randint(k3, (B, L), 0, V)
    got = chunked_softmax_xent(h, w, y, chunk=chunk)
    logits = h @ w
    naive = -jnp.mean(
        jnp.take_along_axis(jax.nn.log_softmax(logits, -1), y[..., None], -1)
    )
    np.testing.assert_allclose(float(got), float(naive), rtol=1e-5)


def test_chunked_xent_mask():
    B, L, D, V = 2, 8, 4, 16
    h = jax.random.normal(KEY, (B, L, D))
    w = jax.random.normal(KEY, (D, V))
    y = jnp.zeros((B, L), jnp.int32)
    mask = jnp.zeros((B, L)).at[:, :4].set(1.0)
    full = chunked_softmax_xent(h[:, :4], w, y[:, :4], chunk=4)
    masked = chunked_softmax_xent(h, w, y, mask=mask, chunk=4)
    np.testing.assert_allclose(float(masked), float(full), rtol=1e-5)


# ------------------------------------------------------------------ adamw ---


def test_adamw_matches_reference_step():
    cfg = OptConfig(lr=0.1, warmup_steps=1, total_steps=100, weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st0 = init_opt_state(p)
    p1, st1, _ = adamw_update(cfg, p, g, st0)
    # reference: bias-corrected Adam first step => delta = lr * g/|g| elementwise sign-ish
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.05 * 0.25 / (1 - 0.95)
    lr0 = float(lr_at(cfg, jnp.int32(0)))
    expect = np.array([1.0, -2.0]) - lr0 * (m / (np.sqrt(v) + cfg.eps))
    np.testing.assert_allclose(np.asarray(p1["w"]), expect, rtol=1e-5)
    assert int(st1.step) == 1


def test_adamw_clips_global_norm():
    cfg = OptConfig(lr=1e-3, clip_norm=1.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in (0, 9, 10, 55, 99)]
    assert lrs[0] < lrs[1] <= 1.0  # warmup rises
    assert lrs[2] == pytest.approx(1.0, abs=0.1)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]  # cosine decays


# ------------------------------------------------------------------- rope ---


def test_rope_preserves_norm_and_relative_phase():
    B, L, H, Dh = 1, 6, 2, 8
    x = jax.random.normal(KEY, (B, L, H, Dh))
    pos = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    r = apply_rope(x, pos, theta=1e4)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(r), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )
    # relative property: <R(p)q, R(k)k'> depends only on p - k
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, Dh))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, Dh))

    def score(pq, pk):
        rq = apply_rope(q, jnp.array([[pq]]), 1e4)
        rk = apply_rope(k, jnp.array([[pk]]), 1e4)
        return float(jnp.sum(rq * rk))

    assert score(5, 3) == pytest.approx(score(7, 5), rel=1e-4)
    assert score(5, 3) != pytest.approx(score(5, 4), rel=1e-3)


def test_rmsnorm_scale_invariant_stat():
    p = init_rmsnorm(16)
    x = jax.random.normal(KEY, (2, 3, 16))
    y = rmsnorm(p, x)
    rms = np.sqrt(np.mean(np.asarray(y, np.float32) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


# ------------------------------------------------------------ data pipeline -


def test_pipeline_pure_function_of_step():
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, synth_batch

    cfg = get_config("llama3.2-1b").reduced()
    d = DataConfig(global_batch=4, seq_len=16, seed=7)
    a = synth_batch(cfg, d, 5)
    b = synth_batch(cfg, d, 5)
    c = synth_batch(cfg, d, 6)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < cfg.vocab_size


def test_pipeline_host_slicing_consistent():
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, synth_batch

    cfg = get_config("llama3.2-1b").reduced()
    full = synth_batch(cfg, DataConfig(global_batch=8, seq_len=16, seed=7), 3)
    lo = synth_batch(cfg, DataConfig(global_batch=8, seq_len=16, seed=7, row_start=0, row_end=4), 3)
    hi = synth_batch(cfg, DataConfig(global_batch=8, seq_len=16, seed=7, row_start=4, row_end=8), 3)
    np.testing.assert_array_equal(np.concatenate([lo["tokens"], hi["tokens"]]), full["tokens"])

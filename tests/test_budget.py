"""Algorithm 1 (virtual budget distribution): unit + property tests,
including agreement between the NumPy reference and the jax.lax program."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test-extra; skip, don't error, when absent
from hypothesis import given, settings, strategies as st

from repro.core.budget import (
    distribute_budgets,
    latency_levels,
    proportional_budgets_worstcase,
    tighten_budgets,
    virtual_deadline,
)


def test_latency_levels_distinct_decreasing():
    lv = latency_levels([3.0, 1.0, 3.0, 2.0])
    assert lv.tolist() == [3.0, 2.0, 1.0]


def test_budgets_sum_to_deadline():
    lat = np.array([[4.0, 1.0], [2.0, 2.0], [8.0, 3.0]])
    res = distribute_budgets(lat, deadline=20.0)
    assert res.feasible
    np.testing.assert_allclose(res.budgets.sum(), 20.0, rtol=1e-12)


def test_no_tightening_when_worst_fits():
    lat = np.array([[4.0, 1.0], [2.0, 2.0]])
    res = distribute_budgets(lat, deadline=10.0)  # 4 + 2 = 6 <= 10
    assert res.feasible
    assert res.rho.tolist() == [0, 0]
    # proportional to worst-case (Eq. 3 regime)
    np.testing.assert_allclose(res.budgets, [10 * 4 / 6, 10 * 2 / 6])


def test_tightens_largest_gap_first():
    # layer0 gap = 9, layer1 gap = 1; D forces exactly one tightening.
    lat = np.array([[10.0, 1.0], [3.0, 2.0]])
    res = distribute_budgets(lat, deadline=5.0)  # 13 > 5; after l0: 1+3=4 <= 5
    assert res.feasible
    assert res.rho.tolist() == [1, 0]
    np.testing.assert_allclose(res.budgets, [5 * 1 / 4, 5 * 3 / 4])


def test_infeasible_when_min_sum_exceeds_deadline():
    lat = np.array([[4.0, 3.0], [5.0, 2.0]])
    res = distribute_budgets(lat, deadline=4.0)  # min sum = 5 > 4
    assert not res.feasible


def test_virtual_deadline_cumsum():
    lat = np.array([[2.0, 1.0], [2.0, 2.0]])
    res = distribute_budgets(lat, deadline=8.0)
    d1 = virtual_deadline(100.0, res.budgets, 0)
    d2 = virtual_deadline(100.0, res.budgets, 1)
    assert d1 == pytest.approx(100.0 + res.budgets[0])
    assert d2 == pytest.approx(108.0)


def test_eq3_often_infeasible_quote():
    """The paper's motivation: worst-case-proportional budgets can fall
    below a layer's minimum achievable latency."""
    lat = np.array([[100.0, 1.0], [1.0, 1.0]])
    b = proportional_budgets_worstcase(lat, deadline=10.0)
    assert b[1] < lat[1].min()  # unattainable virtual deadline


# ------------------------- incremental kernel ------------------------------


def test_tighten_from_zero_equals_distribute():
    lat = np.array([[4.0, 1.0], [2.0, 2.0], [8.0, 3.0]])
    levels = [latency_levels(row) for row in lat]
    for deadline in (4.0, 6.5, 20.0):
        a = distribute_budgets(lat, deadline)
        b = tighten_budgets(levels, deadline)
        assert a.feasible == b.feasible
        assert a.rho.tolist() == b.rho.tolist()
        np.testing.assert_array_equal(a.budgets, b.budgets)


def test_tighten_suffix_redistributes_remaining_deadline():
    """The online use: re-distribute a remaining deadline over remaining
    layers from the request's current constraint levels."""
    lat = np.array([[10.0, 1.0], [3.0, 2.0], [6.0, 4.0]])
    off = distribute_budgets(lat, deadline=8.0)
    assert off.feasible
    # layer 0 finished early: more time than the static suffix budgets
    remaining = 9.0
    res = tighten_budgets(off.levels[1:], remaining, rho0=off.rho[1:])
    assert res.feasible
    assert res.rho.tolist() == off.rho[1:].tolist()  # no extra tightening
    np.testing.assert_allclose(res.budgets.sum(), remaining)
    np.testing.assert_allclose(
        res.budgets / res.budgets.sum(), off.c_ref[1:] / off.c_ref[1:].sum()
    )


def test_tighten_from_rho0_tightens_further():
    # from rho0=[1,0]: c_ref=[1,3]=4 > 3.5 -> tighten layer 1 -> [1,2]=3
    lat = np.array([[10.0, 1.0], [3.0, 2.0]])
    levels = [latency_levels(row) for row in lat]
    res = tighten_budgets(levels, 3.5, rho0=[1, 0])
    assert res.feasible
    assert res.rho.tolist() == [1, 1]
    np.testing.assert_allclose(res.budgets, [3.5 * 1 / 3, 3.5 * 2 / 3])
    # and rho0 already at the floor + deadline below min sum -> infeasible
    res = tighten_budgets(levels, 2.5, rho0=[1, 1])
    assert not res.feasible


@pytest.mark.parametrize("scale2", [0.5, 1.0, 2.0])
def test_jax_kernel_matches_reference_from_rho0(scale2):
    import jax.numpy as jnp

    from repro.core.budget_jax import distribute_budgets_jax, pack_levels

    lat = np.array(
        [[8.0, 1.0, 4.0], [3.0, 2.0, 2.0], [6.0, 4.0, 1.0], [5.0, 5.0, 5.0]]
    )
    off = distribute_budgets(lat, deadline=14.0)
    deadline2 = 14.0 * scale2
    ref = tighten_budgets(off.levels, deadline2, rho0=off.rho)
    packed, R = pack_levels(lat)
    out = distribute_budgets_jax(
        jnp.asarray(packed),
        jnp.asarray(R),
        deadline2,
        rho0=jnp.asarray(off.rho, dtype=jnp.int32),
    )
    assert bool(out.feasible) == ref.feasible
    assert np.asarray(out.rho).tolist() == ref.rho.tolist()
    if ref.feasible:
        np.testing.assert_allclose(np.asarray(out.budgets), ref.budgets, rtol=1e-5)


# ---------------------------- properties -----------------------------------


@st.composite
def _instances(draw):
    L = draw(st.integers(1, 12))
    n_acc = draw(st.integers(1, 4))
    lat = draw(
        st.lists(
            st.lists(
                st.floats(0.0001220703125, 10.0, allow_nan=False, width=32),
                min_size=n_acc,
                max_size=n_acc,
            ),
            min_size=L,
            max_size=L,
        )
    )
    lat = np.asarray(lat, dtype=np.float64)
    scale = draw(st.floats(0.3, 3.0))
    deadline = float(lat.min(axis=1).sum() * scale + 1e-6)
    return lat, deadline


@given(_instances())
@settings(max_examples=200, deadline=None)
def test_property_feasibility_iff_min_fits(inst):
    lat, deadline = inst
    res = distribute_budgets(lat, deadline)
    min_sum = lat.min(axis=1).sum()
    assert res.feasible == (min_sum <= deadline)
    if res.feasible:
        np.testing.assert_allclose(res.budgets.sum(), deadline, rtol=1e-9)
        assert (res.budgets > 0).all()
        # every layer's budget covers its selected-level latency
        assert (res.budgets >= res.c_ref * (1 - 1e-12)).all()


@given(_instances())
@settings(max_examples=100, deadline=None)
def test_property_jax_matches_reference(inst):
    import jax.numpy as jnp

    from repro.core.budget_jax import distribute_budgets_jax_jit, pack_levels

    lat, deadline = inst
    ref = distribute_budgets(lat, deadline)
    lat32 = lat.astype(np.float32)
    levels, R = pack_levels(lat32)
    out = distribute_budgets_jax_jit(jnp.asarray(levels), jnp.asarray(R), jnp.float32(deadline))
    # float32 rounding can flip razor-edge feasibility; only compare when
    # the margin is comfortably representable.
    margin = abs(lat.min(axis=1).sum() - deadline) / max(deadline, 1e-9)
    if margin > 1e-4:
        assert bool(out.feasible) == ref.feasible
        if ref.feasible:
            np.testing.assert_allclose(np.asarray(out.budgets), ref.budgets, rtol=5e-3, atol=1e-6)

"""Algorithm 1 (virtual budget distribution): unit + property tests,
including agreement between the NumPy reference and the jax.lax program."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test-extra; skip, don't error, when absent
from hypothesis import given, settings, strategies as st

from repro.core.budget import (
    distribute_budgets,
    latency_levels,
    proportional_budgets_worstcase,
    virtual_deadline,
)


def test_latency_levels_distinct_decreasing():
    lv = latency_levels([3.0, 1.0, 3.0, 2.0])
    assert lv.tolist() == [3.0, 2.0, 1.0]


def test_budgets_sum_to_deadline():
    lat = np.array([[4.0, 1.0], [2.0, 2.0], [8.0, 3.0]])
    res = distribute_budgets(lat, deadline=20.0)
    assert res.feasible
    np.testing.assert_allclose(res.budgets.sum(), 20.0, rtol=1e-12)


def test_no_tightening_when_worst_fits():
    lat = np.array([[4.0, 1.0], [2.0, 2.0]])
    res = distribute_budgets(lat, deadline=10.0)  # 4 + 2 = 6 <= 10
    assert res.feasible
    assert res.rho.tolist() == [0, 0]
    # proportional to worst-case (Eq. 3 regime)
    np.testing.assert_allclose(res.budgets, [10 * 4 / 6, 10 * 2 / 6])


def test_tightens_largest_gap_first():
    # layer0 gap = 9, layer1 gap = 1; D forces exactly one tightening.
    lat = np.array([[10.0, 1.0], [3.0, 2.0]])
    res = distribute_budgets(lat, deadline=5.0)  # 13 > 5; after l0: 1+3=4 <= 5
    assert res.feasible
    assert res.rho.tolist() == [1, 0]
    np.testing.assert_allclose(res.budgets, [5 * 1 / 4, 5 * 3 / 4])


def test_infeasible_when_min_sum_exceeds_deadline():
    lat = np.array([[4.0, 3.0], [5.0, 2.0]])
    res = distribute_budgets(lat, deadline=4.0)  # min sum = 5 > 4
    assert not res.feasible


def test_virtual_deadline_cumsum():
    lat = np.array([[2.0, 1.0], [2.0, 2.0]])
    res = distribute_budgets(lat, deadline=8.0)
    d1 = virtual_deadline(100.0, res.budgets, 0)
    d2 = virtual_deadline(100.0, res.budgets, 1)
    assert d1 == pytest.approx(100.0 + res.budgets[0])
    assert d2 == pytest.approx(108.0)


def test_eq3_often_infeasible_quote():
    """The paper's motivation: worst-case-proportional budgets can fall
    below a layer's minimum achievable latency."""
    lat = np.array([[100.0, 1.0], [1.0, 1.0]])
    b = proportional_budgets_worstcase(lat, deadline=10.0)
    assert b[1] < lat[1].min()  # unattainable virtual deadline


# ---------------------------- properties -----------------------------------


@st.composite
def _instances(draw):
    L = draw(st.integers(1, 12))
    n_acc = draw(st.integers(1, 4))
    lat = draw(
        st.lists(
            st.lists(
                st.floats(0.0001220703125, 10.0, allow_nan=False, width=32),
                min_size=n_acc,
                max_size=n_acc,
            ),
            min_size=L,
            max_size=L,
        )
    )
    lat = np.asarray(lat, dtype=np.float64)
    scale = draw(st.floats(0.3, 3.0))
    deadline = float(lat.min(axis=1).sum() * scale + 1e-6)
    return lat, deadline


@given(_instances())
@settings(max_examples=200, deadline=None)
def test_property_feasibility_iff_min_fits(inst):
    lat, deadline = inst
    res = distribute_budgets(lat, deadline)
    min_sum = lat.min(axis=1).sum()
    assert res.feasible == (min_sum <= deadline)
    if res.feasible:
        np.testing.assert_allclose(res.budgets.sum(), deadline, rtol=1e-9)
        assert (res.budgets > 0).all()
        # every layer's budget covers its selected-level latency
        assert (res.budgets >= res.c_ref * (1 - 1e-12)).all()


@given(_instances())
@settings(max_examples=100, deadline=None)
def test_property_jax_matches_reference(inst):
    import jax.numpy as jnp

    from repro.core.budget_jax import distribute_budgets_jax_jit, pack_levels

    lat, deadline = inst
    ref = distribute_budgets(lat, deadline)
    lat32 = lat.astype(np.float32)
    levels, R = pack_levels(lat32)
    out = distribute_budgets_jax_jit(jnp.asarray(levels), jnp.asarray(R), jnp.float32(deadline))
    # float32 rounding can flip razor-edge feasibility; only compare when
    # the margin is comfortably representable.
    margin = abs(lat.min(axis=1).sum() - deadline) / max(deadline, 1e-9)
    if margin > 1e-4:
        assert bool(out.feasible) == ref.feasible
        if ref.feasible:
            np.testing.assert_allclose(np.asarray(out.budgets), ref.budgets, rtol=5e-3, atol=1e-6)

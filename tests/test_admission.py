"""Admission/shedding policy axis: the pre-PR bit-identity pin
(admission="none" and open-loop arrivals must reproduce the fingerprints
captured before the overload-control PR, on both engines), ref-vs-SoA
differentials under every admission policy, shed accounting semantics,
call-spec errors, and token-bucket mechanics."""

import math

import pytest

from repro.core import (
    ADMISSION_POLICIES,
    NoAdmission,
    ShedEarlyAdmission,
    TokenBucketAdmission,
    make_admission_policy,
    make_scheduler,
    simulate,
)
from repro.core.workload import get_scenario
from repro.costmodel.maestro import PLATFORMS

from data_pre_pr_fingerprints import PRE_PR_FINGERPRINTS


def _cell(scenario, platform, arrival=None, theta=0.90):
    sc = get_scenario(scenario)
    return sc.plans(PLATFORMS[platform], theta=theta, arrival=arrival)


def _both(plans, tasks, duration, sched, admission, seed=0, procs=None,
          policy="static"):
    ref = simulate(plans, tasks, duration, make_scheduler(sched), seed=seed,
                   processes=procs, budget_policy=policy, admission=admission,
                   engine="reference")
    soa = simulate(plans, tasks, duration, make_scheduler(sched), seed=seed,
                   processes=procs, budget_policy=policy, admission=admission,
                   engine="soa")
    return ref, soa


# ------------------------------------------------ pre-PR bit-identity ----


@pytest.mark.parametrize("key", sorted(PRE_PR_FINGERPRINTS))
def test_admission_none_bit_identical_to_pre_pr(key):
    """The load-bearing pin of the whole axis: with admission left at its
    default, both engines reproduce the exact fingerprints captured at
    the commit before this PR (the new shed/in_flight counters are
    projected off; shed must be 0 everywhere)."""
    scenario, platform, arrival, duration, sched, engine = key
    plans, tasks = _cell(scenario, platform, arrival)
    res = simulate(plans, tasks, duration, make_scheduler(sched), seed=0,
                   engine=engine)
    name, rounds, bt, bh, per, fsp = res.fingerprint()
    got = (name, rounds, bt, bh, {m: tuple(v[:6]) for m, v in per.items()})
    old = PRE_PR_FINGERPRINTS[key]
    want = (old[0], old[1], old[2], old[3],
            {m: tuple(v) for m, v in old[4].items()})
    assert got == want
    assert fsp == 0  # no faults injected, no faulted spans
    for m, v in per.items():
        assert v[6] == 0  # shed == 0 under admission="none"


def test_admission_none_spec_is_noop():
    """admission="none", NoAdmission(), and the default all coincide."""
    plans, tasks = _cell("saturation_5x", "4k_1ws2os")
    base = simulate(plans, tasks, 0.3, make_scheduler("terastal"), seed=0)
    for adm in ("none", NoAdmission(), None):
        res = simulate(plans, tasks, 0.3, make_scheduler("terastal"), seed=0,
                       admission=adm)
        assert res.fingerprint() == base.fingerprint()


# --------------------------------------------- engine differentials ----


@pytest.mark.parametrize("sched", ["terastal", "terastal(backfill_mode=paper)",
                                   "edf", "fcfs", "dream"])
@pytest.mark.parametrize("adm", ["shed_early(margin=1.0)",
                                 "token_bucket(rate=100,burst=8)"])
def test_admission_ref_equals_soa(sched, adm):
    plans, tasks = _cell("saturation_5x", "4k_1ws2os")
    ref, soa = _both(plans, tasks, 0.4, sched, adm)
    assert ref.fingerprint() == soa.fingerprint()
    assert sum(s.shed for s in ref.per_model.values()) > 0


def test_admission_with_active_budget_policy_ref_equals_soa():
    """Admission composes with a stateful budget policy (the policy's
    on_release must never fire for shed requests, in either engine)."""
    plans, tasks = _cell("saturation_5x", "6k_1ws2os")
    ref, soa = _both(plans, tasks, 0.4, "terastal",
                     "shed_early(margin=1.5)", policy="adaptive")
    assert ref.fingerprint() == soa.fingerprint()


# --------------------------------------------------- shed semantics ----


def test_shed_accounting():
    """A shed request is released+missed+dropped+shed: shedding can never
    flatter the miss rate, only redirect capacity to admitted requests."""
    plans, tasks = _cell("saturation_5x", "4k_1ws2os")
    res = simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0,
                   admission="token_bucket(rate=60,burst=4)")
    tot_shed = 0
    for st in res.per_model.values():
        assert st.shed <= st.dropped
        assert st.missed >= st.dropped
        assert st.admitted == st.released - st.shed
        assert st.released == st.completed + st.dropped + st.in_flight
        tot_shed += st.shed
    assert tot_shed > 0


def test_shedding_beats_none_on_saturation():
    """The point of the axis: at 5x overload, shedding at the door frees
    the accelerators from work that would be dropped mid-chain, so the
    per-model mean miss rate improves even though shed requests count as
    missed.  (The full-scale >= 5-point separation claim is gated in
    benchmarks/fig9_overload_control.py.)"""
    plans, tasks = _cell("saturation_5x", "4k_1ws2os")
    base = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=0)
    shed = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=0,
                    admission="shed_early(margin=2.5)")
    assert shed.mean_miss_rate < base.mean_miss_rate - 0.05
    assert (sum(s.completed for s in shed.per_model.values())
            > sum(s.completed for s in base.per_model.values()))


# ----------------------------------------------- policy construction ----


def test_make_admission_policy_specs():
    assert isinstance(make_admission_policy(None), NoAdmission)
    assert isinstance(make_admission_policy("none"), NoAdmission)
    p = make_admission_policy("shed_early(margin=1.5)")
    assert isinstance(p, ShedEarlyAdmission) and p.margin == 1.5
    tb = make_admission_policy("token_bucket(rate=80,burst=4)")
    assert isinstance(tb, TokenBucketAdmission)
    assert tb.rate == 80.0 and tb.burst == 4.0
    inst = ShedEarlyAdmission(margin=0.5)
    assert make_admission_policy(inst) is inst
    assert set(ADMISSION_POLICIES) == {"none", "shed_early", "token_bucket"}


def test_make_admission_policy_errors():
    with pytest.raises(KeyError, match="unknown admission policy"):
        make_admission_policy("drop_tail")
    with pytest.raises(ValueError, match="bad arguments for admission policy"):
        make_admission_policy("shed_early(slack=2)")
    with pytest.raises(ValueError, match="margin must be >= 0"):
        make_admission_policy("shed_early(margin=-1)")
    with pytest.raises(ValueError, match="rate must be > 0"):
        make_admission_policy("token_bucket(rate=0)")
    with pytest.raises(ValueError, match="burst must be >= 1"):
        make_admission_policy("token_bucket(rate=10,burst=0.5)")


def test_token_bucket_mechanics():
    """Burst drains, then admissions are paced at the refill rate."""
    tb = TokenBucketAdmission(rate=10.0, burst=2.0)
    tb.bind(1)

    class _R:  # admit() only reads deadline_abs on shed_early
        deadline_abs = math.inf

    r = _R()
    assert tb.admit(r, 0.0, 0, 0.0)      # burst token 1
    assert tb.admit(r, 0.0, 0, 0.0)      # burst token 2
    assert not tb.admit(r, 0.0, 0, 0.0)  # bucket empty
    assert not tb.admit(r, 0.05, 0, 0.0)  # refilled 0.5 tokens: still short
    assert tb.admit(r, 0.1, 0, 0.0)      # one full token accumulated
    tb.reset()
    assert tb.admit(r, 0.0, 0, 0.0)      # reset restores the full burst


def test_shed_early_margin_zero_admits_feasible():
    """margin=0 degenerates to the early-drop test at the door: a request
    whose minimum execution fits its deadline is always admitted."""
    plans, tasks = _cell("saturation_5x", "4k_1ws2os")
    res = simulate(plans, tasks, 0.3, make_scheduler("terastal"), seed=0,
                   admission="shed_early(margin=0)")
    base = simulate(plans, tasks, 0.3, make_scheduler("terastal"), seed=0)
    # saturation deadlines have 4x slack: margin=0 never sheds here
    assert res.fingerprint() == base.fingerprint()

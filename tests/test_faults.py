"""Accelerator fault injection: spec validation, timeline determinism,
capability masking, fault-off bit-identity pins, ref-vs-SoA parity with
faults active, and the batch-engine rejection contract."""

import math

import numpy as np
import pytest

from repro.core import get_scenario, make_scheduler, simulate
from repro.core.campaign import _plans_for
from repro.core.engine_batch import BatchUnsupportedError, simulate_batch
from repro.core.faults import (
    FaultModel,
    FaultSpec,
    effective_plans,
    fault_multipliers,
    make_fault_model,
)
from repro.costmodel.maestro import PLATFORMS

from data_pre_pr8_fingerprints import PRE_PR8_FINGERPRINTS


def _cell(scenario, platform="6k_1ws2os", theta=0.90, variants=True):
    return _plans_for(scenario, platform, theta, variants)


def _both(plans, tasks, duration, sched, faults, seed=0, procs=None):
    ref = simulate(plans, tasks, duration, make_scheduler(sched), seed=seed,
                   processes=procs, faults=faults, engine="reference")
    soa = simulate(plans, tasks, duration, make_scheduler(sched), seed=seed,
                   processes=procs, faults=faults, engine="soa")
    return ref, soa


# ------------------------------------------------------ validation -------


def test_unknown_fault_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        make_fault_model("meltdown(acc=0,start=0.1)")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(kind="meltdown", acc=0)


@pytest.mark.parametrize("bad", [
    "down(acc=-1,start=0.1,duration=0.2)",
    "down(acc=0,start=-0.5,duration=0.2)",
    "down(acc=0,start=nan,duration=0.2)",
    "down(acc=0,start=0.1,duration=-1)",
    "throttle(acc=0,start=0.1,duration=0.2,factor=0)",
    "throttle(acc=0,start=0.1,duration=0.2,factor=inf)",
    "intermittent(acc=0,rate=0,mean_down=0.1)",
    "intermittent(acc=0,rate=5,mean_down=0)",
    "down(acc=0,start=0.1)",  # transient faults need a finite duration
])
def test_malformed_numbers_rejected(bad):
    with pytest.raises(ValueError):
        make_fault_model(bad)


def test_unknown_interrupted_policy_rejected():
    with pytest.raises(ValueError, match="interrupted-work policy"):
        make_fault_model("down(acc=0,start=0.1,duration=0.2,interrupted=pause)")


def test_conflicting_interrupted_policies_rejected():
    with pytest.raises(ValueError, match="conflicting interrupted"):
        make_fault_model(
            "down(acc=0,start=0.1,duration=0.2,interrupted=resume)"
            "+down(acc=1,start=0.1,duration=0.2,interrupted=restart)")


def test_overlapping_windows_rejected():
    with pytest.raises(ValueError, match="overlapping fault windows"):
        make_fault_model("down(acc=0,start=0.1,duration=0.5)"
                         "+throttle(acc=0,start=0.3,duration=0.2,factor=2)")
    with pytest.raises(ValueError, match="overlapping permanent"):
        make_fault_model("permanent(acc=2,start=0.1)+permanent(acc=2,start=0.5)")
    # different accelerators may overlap freely
    fm = make_fault_model("down(acc=0,start=0.1,duration=0.5)"
                          "+down(acc=1,start=0.1,duration=0.5)")
    assert fm.active and len(fm.faults) == 2


def test_intermittent_owns_its_accelerator():
    with pytest.raises(ValueError, match="intermittent fault cannot"):
        make_fault_model("intermittent(acc=0,rate=5,mean_down=0.05)"
                         "+down(acc=0,start=0.1,duration=0.2)")


def test_none_spellings_resolve_to_no_model():
    assert make_fault_model(None) is None
    assert make_fault_model("none") is None
    assert make_fault_model("  ") is None
    assert make_fault_model(FaultModel()) is None


def test_format_round_trips():
    for spec in (
        "down(acc=0,start=0.5,duration=1.0)",
        "throttle(acc=1,start=0.2,duration=0.5,factor=3.0)",
        "permanent(acc=1,start=0.4,interrupted=resume)",
        "intermittent(acc=2,rate=6.0,mean_down=0.08)",
        "down(acc=0,start=0.1,duration=0.2,interrupted=resume)"
        "+throttle(acc=2,start=0.2,duration=0.4,factor=2.5)",
    ):
        fm = make_fault_model(spec)
        again = make_fault_model(fm.format())
        assert again == fm, spec


def test_acc_out_of_platform_range_rejected_at_timeline():
    fm = make_fault_model("down(acc=7,start=0.1,duration=0.2)")
    with pytest.raises(ValueError, match="out of range"):
        fm.timeline(n_acc=3, duration=1.0, seed=0)


# -------------------------------------------------- timeline/masking ----


def test_timeline_deterministic_and_seed_varied():
    fm = make_fault_model("intermittent(acc=1,rate=8.0,mean_down=0.05)")
    ev0, n0 = fm.timeline(3, 2.0, seed=0)
    ev0b, n0b = fm.timeline(3, 2.0, seed=0)
    ev1, _ = fm.timeline(3, 2.0, seed=1)
    assert (ev0, n0) == (ev0b, n0b)  # reproducible per seed
    assert ev0 != ev1  # renewal draws differ across seeds
    assert n0 == sum(e.code == "down" for e in ev0)


def test_timeline_span_counting_and_clipping():
    fm = make_fault_model("down(acc=0,start=0.5,duration=1.0)"
                          "+down(acc=1,start=9.0,duration=1.0)")
    ev, n = fm.timeline(3, duration=2.0, seed=0)
    assert n == 1  # the acc=1 window starts past the horizon
    assert [(e.t, e.acc, e.code) for e in ev] == [(0.5, 0, "down"),
                                                  (1.5, 0, "up")]
    # permanent: down event only, no closing up
    evp, np_ = make_fault_model("permanent(acc=2,start=0.3)").timeline(3, 2.0, 0)
    assert np_ == 1 and [(e.t, e.code) for e in evp] == [(0.3, "down")]


def test_effective_plans_mask_and_scale():
    plans, _ = _cell("multicam_heavy")
    mult = fault_multipliers([1.0, 2.0, 1.0], [False, True, True])
    assert mult[0] == math.inf and mult[1] == 2.0
    eff = effective_plans(plans, mult)
    for p, q in zip(plans, eff):
        assert np.all(np.isinf(q.lat[:, 0]))
        np.testing.assert_allclose(q.lat[:, 1], 2.0 * p.lat[:, 1])
        np.testing.assert_allclose(q.lat[:, 2], p.lat[:, 2])
        for idx, v in q.variants.items():
            np.testing.assert_allclose(
                v.latencies[1], 2.0 * p.variants[idx].latencies[1])
        # budgets/accuracy untouched; originals not mutated
        assert q.budget is p.budget
    assert effective_plans(plans, np.ones(3))[0] is plans[0]  # identity


# ------------------------------------------- fault-off bit-identity -----


@pytest.mark.parametrize("key", sorted(PRE_PR8_FINGERPRINTS))
def test_fault_off_bit_identical_to_pre_pr(key):
    """The load-bearing pin of the whole axis: with no faults injected,
    both engines reproduce the exact fingerprints captured at the commit
    before this PR (the new evicted/remapped counters and faulted_spans
    are projected off and must be zero everywhere)."""
    scenario, platform, arrival, duration, sched, adm, engine = key
    sc = get_scenario(scenario)
    plans, tasks = sc.plans(PLATFORMS[platform],
                            arrival=None if arrival == "scenario" else arrival)
    res = simulate(plans, tasks, duration, make_scheduler(sched), seed=0,
                   processes=[t.arrival for t in tasks], admission=adm,
                   engine=engine)
    name, rounds, bt, bh, per, fsp = res.fingerprint()
    got = (name, rounds, bt, bh, {m: tuple(v[:8]) for m, v in per.items()})
    old = PRE_PR8_FINGERPRINTS[key]
    want = (old[0], old[1], old[2], old[3],
            {m: tuple(v) for m, v in old[4].items()})
    assert got == want
    assert fsp == 0
    for v in per.values():
        assert v[8] == 0 and v[9] == 0  # evicted == remapped == 0


def test_explicit_none_spec_is_noop():
    plans, tasks = _cell("multicam_heavy")
    base = simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0)
    none = simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0,
                    faults="none")
    assert base.fingerprint() == none.fingerprint()


def test_window_past_horizon_is_noop():
    plans, tasks = _cell("multicam_heavy")
    base = simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0)
    late = simulate(plans, tasks, 0.5, make_scheduler("terastal"), seed=0,
                    faults="down(acc=0,start=9.0,duration=1.0)")
    assert base.fingerprint() == late.fingerprint()
    assert late.faulted_spans == 0


# ------------------------------------------------- engine parity --------


FAULT_GRID = (
    "down(acc=0,start=0.1,duration=0.2)",
    "down(acc=0,start=0.1,duration=0.2,interrupted=resume)",
    "throttle(acc=1,start=0.05,duration=0.3,factor=2.5)",
    "permanent(acc=1,start=0.15)",
    "intermittent(acc=2,rate=8.0,mean_down=0.05)",
    "down(acc=0,start=0.1,duration=0.2,interrupted=resume)"
    "+throttle(acc=2,start=0.15,duration=0.25,factor=3.0)",
)


@pytest.mark.parametrize("faults", FAULT_GRID)
@pytest.mark.parametrize("sched", ["terastal", "edf", "dream", "fcfs"])
def test_ref_vs_soa_bit_identical_under_faults(sched, faults):
    plans, tasks = _cell("multicam_heavy")
    ref, soa = _both(plans, tasks, 0.6, sched, faults)
    assert ref.fingerprint() == soa.fingerprint()


@pytest.mark.parametrize("name", ["fault_dropout", "fault_brownout",
                                  "fault_flash_crowd"])
def test_catalog_cells_bit_identical(name):
    sc = get_scenario(name)
    plans, tasks = _cell(name)
    procs = [t.arrival for t in tasks]
    ref, soa = _both(plans, tasks, 1.0, "terastal", sc.faults, seed=1,
                     procs=procs)
    assert ref.fingerprint() == soa.fingerprint()
    assert ref.faulted_spans >= 1


def test_soa_jax_round_kernel_downgrades_under_faults():
    """An explicit round_kernel='jax' must silently fall back to the
    scalar rounds when faults are active (capability events mutate the
    latency tables mid-trial) and stay bit-identical."""
    plans, tasks = _cell("multicam_heavy")
    a = simulate(plans, tasks, 0.6, make_scheduler("terastal"), seed=0,
                 faults=FAULT_GRID[0], engine="soa", round_kernel="jax")
    b = simulate(plans, tasks, 0.6, make_scheduler("terastal"), seed=0,
                 faults=FAULT_GRID[0], engine="reference")
    assert a.fingerprint() == b.fingerprint()


# ------------------------------------------------- fault observables ----


def test_dropout_evicts_and_remaps():
    plans, tasks = _cell("multicam_heavy")
    res = simulate(plans, tasks, 0.6, make_scheduler("edf"), seed=0,
                   faults="down(acc=0,start=0.05,duration=0.3)")
    assert res.faulted_spans == 1
    evicted = sum(s.evicted for s in res.per_model.values())
    remapped = sum(s.remapped for s in res.per_model.values())
    assert evicted >= 1
    assert remapped <= evicted


def test_resume_differs_from_restart():
    """The interrupted-work policy only matters once the evicted request
    is re-dispatched; on this cell the acc=1 outage remaps it, so
    carrying the completed fraction over must change the trajectory."""
    plans, tasks = _cell("multicam_heavy")
    r = simulate(plans, tasks, 0.6, make_scheduler("edf"), seed=0,
                 faults="down(acc=1,start=0.05,duration=0.3)")
    s = simulate(plans, tasks, 0.6, make_scheduler("edf"), seed=0,
                 faults="down(acc=1,start=0.05,duration=0.3,interrupted=resume)")
    assert sum(st.remapped for st in r.per_model.values()) >= 1
    assert r.fingerprint() != s.fingerprint()


def test_variant_lever_degrades_gracefully():
    """The tentpole claim at test scale: on the dropout cell, variant-
    enabled Terastal misses strictly less than its no-variant ablation
    while the outage is active (fig10 gates the full-scale >= 5 pts)."""
    sc = get_scenario("fault_dropout")
    plans, tasks = _cell("fault_dropout")
    full = simulate(plans, tasks, 2.0, make_scheduler("terastal"), seed=0,
                    faults=sc.faults, engine="soa")
    abl = simulate(plans, tasks, 2.0, make_scheduler("terastal_no_variants"),
                   seed=0, faults=sc.faults, engine="soa")
    assert full.mean_miss_rate < abl.mean_miss_rate
    assert sum(s.variants_applied for s in full.per_model.values()) > 0


# ------------------------------------------ pre-PR10 bit-identity pin ----


from data_pre_pr10_fingerprints import PRE_PR10_FINGERPRINTS


@pytest.mark.parametrize("key", sorted(PRE_PR10_FINGERPRINTS))
def test_pre_pr10_cells_bit_identical(key):
    """The load-bearing pin of the re-tightening PR: the fault-off path
    and every faulted cell with ``retighten`` disabled reproduce the
    exact fingerprints captured at the PR 9 commit, on both engines —
    re-tightening, degraded admission, and the batch fault lane are
    strictly additive behind ``retighten=true``."""
    scenario, platform, duration, sched, adm, faults, engine = key
    sc = get_scenario(scenario)
    plans, tasks = sc.plans(PLATFORMS[platform])
    f = sc.faults if faults == "scenario" else (
        None if faults == "none" else faults)
    res = simulate(
        plans, tasks, duration, make_scheduler(sched), seed=0,
        processes=[t.arrival for t in tasks],
        admission=None if adm == "none" else adm,
        faults=f, engine=engine,
    )
    assert res.fingerprint() == PRE_PR10_FINGERPRINTS[key]


# --------------------------------------------------- batch rejection ----


def test_batch_engine_rejects_only_resume_faults():
    """PR 10 narrowed the rejection: restart-policy faults run on device
    (pre-bound capability epochs); only ``interrupted=resume`` stays out
    — fractional layer progress re-times re-dispatches mid-rollout,
    which a pre-bound epoch schedule cannot express."""
    plans, tasks = _cell("ar_social", platform="4k_1ws2os")
    resume = "down(acc=0,start=0.1,duration=0.2,interrupted=resume)"
    with pytest.raises(BatchUnsupportedError, match="resume"):
        simulate_batch(plans, tasks, 0.3, make_scheduler("terastal"),
                       seeds=[0], faults=resume)
    with pytest.raises(BatchUnsupportedError, match="resume"):
        simulate(plans, tasks, 0.3, make_scheduler("terastal"),
                 faults=resume, engine="batch")
    # restart-policy faults are now a supported batch axis
    res = simulate_batch(plans, tasks, 0.3, make_scheduler("terastal"),
                         seeds=[0], faults="down(acc=0,start=0.1,duration=0.2)")
    assert res[0].faulted_spans == 1
    # fault-off batch path unaffected ("none" strings included)
    res = simulate_batch(plans, tasks, 0.3, make_scheduler("terastal"),
                         seeds=[0], faults="none")
    assert res[0].per_model


# -------------------------------------------------- hypothesis parity ---


try:  # optional test extra — only the property test skips without it
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @st.composite
    def _fault_specs(draw):
        parts = []
        n = draw(st.integers(min_value=1, max_value=2))
        accs = draw(st.permutations(range(3)))
        for i in range(n):
            kind = draw(st.sampled_from(["down", "throttle", "permanent"]))
            start = round(draw(st.floats(0.0, 0.4)), 3)
            dur = round(draw(st.floats(0.05, 0.4)), 3)
            if kind == "down":
                parts.append(f"down(acc={accs[i]},start={start},duration={dur})")
            elif kind == "throttle":
                factor = round(draw(st.floats(1.2, 5.0)), 2)
                parts.append(f"throttle(acc={accs[i]},start={start},"
                             f"duration={dur},factor={factor})")
            else:
                parts.append(f"permanent(acc={accs[i]},start={start})")
        if draw(st.booleans()):
            head, close = parts[0][:-1], parts[0][-1]
            parts[0] = f"{head},interrupted=resume{close}"
        return "+".join(parts)

    @settings(max_examples=20, deadline=None)
    @given(spec=_fault_specs(), seed=st.integers(0, 3),
           sched=st.sampled_from(["terastal", "edf"]))
    def test_hypothesis_engine_parity_under_faults(spec, seed, sched):
        """Random fault-model draws (kind x window x factor x policy):
        the SoA engine's SimResult must equal the reference engine's
        bit-for-bit with the fault machinery live."""
        plans, tasks = _cell("multicam_heavy")
        ref, soa = _both(plans, tasks, 0.5, sched, spec, seed=seed)
        assert ref.fingerprint() == soa.fingerprint()

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed (optional test extra)")
    def test_hypothesis_engine_parity_under_faults():
        pass

"""DAG-structured workloads (repro.core.dag): validation contracts,
edge-spec round-trips, the pre-PR linear-chain bit-identity pins (both
engines), ref-vs-SoA differential over the DAG catalog, runtime
precedence / intra-request parallelism invariants observed through a
recording scheduler, critical-path budget properties, and the axis
gating that refuses combinations the DAG machinery cannot honor.

The property tests run twice: a deterministic seeded sweep that always
executes (tier-1 has no hypothesis), and a hypothesis fuzz layer that
widens the same generators when the optional extra is installed.
"""

import random

import numpy as np
import pytest

from repro.core import get_scenario, make_scheduler, simulate
from repro.core.budget import latency_levels, tighten_budgets_dag
from repro.core.dag import DagRun, DagValidationError, LayerDag
from repro.core.engine_batch import BatchUnsupportedError, simulate_batch
from repro.core.scheduler import Scheduler
from repro.core.simulator import TaskSpec
from repro.core.specs import format_dag_edges, parse_dag_edges
from repro.core.variants import build_model_plan
from repro.core.workload import DAG_SCENARIOS
from repro.costmodel.dnn_zoo import (
    DnnModel,
    asr_encdec,
    moe_4expert,
    vlm_2branch,
)
from repro.costmodel.layers import fc, matmul
from repro.costmodel.maestro import PLATFORMS

from data_pre_pr9_fingerprints import PRE_PR9_FINGERPRINTS

try:
    from hypothesis import given, settings, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on tier-1 images
    _HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not _HAVE_HYPOTHESIS, reason="hypothesis not installed (optional test extra)"
)


# ------------------------------------------------------- validation ------


def test_self_edge_rejected_naming_node():
    with pytest.raises(DagValidationError, match=r"node 1: self-edge 1 -> 1"):
        LayerDag(((), (1,)))


def test_unknown_pred_rejected_naming_node():
    with pytest.raises(DagValidationError, match=r"node 1: unknown predecessor id 5"):
        LayerDag(((), (5,)))


def test_duplicate_pred_rejected_naming_node():
    with pytest.raises(DagValidationError, match=r"node 2: duplicate predecessor 0"):
        LayerDag(((), (0,), (0, 0)))


def test_cycle_rejected_naming_witness():
    # 0 -> 1 -> 2 -> 0: every node sits on the cycle, the lowest id is
    # the witness Kahn's algorithm reports
    with pytest.raises(DagValidationError, match=r"node 0: unreachable \(cycle\)"):
        LayerDag(((2,), (0,), (1,)))


def test_multiple_sinks_rejected():
    with pytest.raises(DagValidationError, match=r"multiple sinks \[1, 2\]"):
        LayerDag(((), (0,), (0,)))


def test_empty_dag_rejected():
    with pytest.raises(DagValidationError, match="empty DAG"):
        LayerDag(())


def test_dag_validation_error_is_value_error():
    assert issubclass(DagValidationError, ValueError)


def test_linear_chain_is_degenerate_case():
    dag = LayerDag.linear(4)
    assert dag.is_linear
    assert dag.sources == (0,)
    assert dag.sink == 3
    assert dag.topo == (0, 1, 2, 3)
    assert not LayerDag(((), (0,), (0,), (1, 2))).is_linear


def test_derived_fields_of_fan_in_join():
    dag = LayerDag(((), (0,), (0,), (1, 2)))
    assert dag.sources == (0,)
    assert dag.sink == 3
    assert dag.succs == ((1, 2), (3,), (3,), ())
    assert list(dag.topo) == sorted(dag.topo)  # this DAG's ids are topo-sorted


def test_dagrun_fresh_counts_pending_preds():
    dag = LayerDag(((), (0,), (0,), (1, 2)))
    run = DagRun.fresh(dag)
    assert run.pending == [0, 1, 1, 2]
    assert run.n_done == 0 and not run.dropped


# ----------------------------------------------- edge-spec round-trip ----


def test_edge_spec_docstring_example():
    assert format_dag_edges(((), (0,), (0,), (1, 2))) == ";0;0;1,2"
    assert parse_dag_edges(";0;0;1,2") == ((), (0,), (0,), (1, 2))


@pytest.mark.parametrize("ctor", [asr_encdec, vlm_2branch, moe_4expert])
def test_zoo_dag_spec_round_trips(ctor):
    dag = ctor().dag
    assert dag is not None
    back = LayerDag.from_spec(dag.spec())
    assert back.preds == dag.preds
    assert back == dag


def test_malformed_edge_spec_rejected():
    with pytest.raises(ValueError, match="node 1 part 'x'"):
        parse_dag_edges(";x")


# -------------------------------------- linear-chain bit-identity pin ----


@pytest.mark.parametrize("key", sorted(PRE_PR9_FINGERPRINTS))
def test_linear_cells_bit_identical_to_pre_pr(key):
    """The load-bearing pin of the whole PR: every pre-existing catalog
    cell — paper grid, saturation, overload, faults — reproduces the
    exact fingerprint captured at the commit before the DAG refactor,
    on both engines.  The DAG machinery must be strictly additive."""
    scenario, platform, arrival, duration, sched, adm, engine = key
    sc = get_scenario(scenario)
    plans, tasks = sc.plans(
        PLATFORMS[platform], arrival=None if arrival == "scenario" else arrival
    )
    res = simulate(
        plans,
        tasks,
        duration,
        make_scheduler(sched),
        seed=0,
        processes=[t.arrival for t in tasks],
        admission=None if adm == "none" else adm,
        faults=sc.faults,
        engine=engine,
    )
    assert res.fingerprint() == PRE_PR9_FINGERPRINTS[key]


# --------------------------------------------------- plan-level facts ----


@pytest.mark.parametrize("ctor", [asr_encdec, vlm_2branch, moe_4expert])
def test_dag_plan_critical_path_beats_chain_sum(ctor):
    """The cost model sees the parallelism: the critical path (what the
    deadline is distributed over) is strictly shorter than the linear
    chain sum, and virtual deadlines strictly increase along every edge."""
    plan = build_model_plan(ctor(), PLATFORMS["6k_1ws2os"], deadline=0.006)
    assert plan.dag is not None
    assert plan.crit_total < sum(plan.min_lat_list) - 1e-15
    vdl = plan.vdl_rel
    for l, ps in enumerate(plan.dag.preds):
        for p in ps:
            assert vdl[l] > vdl[p]
    # crit_from[l] counts l itself; crit_after excludes it
    for l in range(len(plan.min_lat_list)):
        assert plan.crit_from_list[l] >= plan.min_lat_list[l] - 1e-15
        assert plan.crit_from_list[l] >= plan.crit_after_list[l]
    assert plan.crit_total == max(
        plan.crit_from_list[s] for s in plan.dag.sources
    )


def _toy_linear_model(dag):
    layers = [fc("a", 128, 128), fc("b", 128, 64), matmul("c", 64, 64, 64)]
    return DnnModel("toy", layers, redundancy=0.7, dag=dag)


def test_degenerate_linear_dag_is_identical_to_chain():
    """A model declaring the explicit linear chain as its DAG builds the
    exact same plan (dag=None, same budgets bitwise) as the plain model."""
    plat = PLATFORMS["4k_1ws2os"]
    plain = build_model_plan(_toy_linear_model(None), plat, deadline=0.01)
    chain = build_model_plan(_toy_linear_model(LayerDag.linear(3)), plat, deadline=0.01)
    assert chain.dag is None
    assert np.array_equal(plain.budget.budgets, chain.budget.budgets)
    assert np.array_equal(plain.vdl_rel, chain.vdl_rel)


# ------------------------------------------------------- axis gating -----


def _dag_cell(name="dag_moe_4expert", platform="6k_1ws2os"):
    sc = get_scenario(name)
    return sc.plans(PLATFORMS[platform])


def test_faults_with_dag_plans_run_with_engine_parity():
    """PR 10 lifted the faults x DAG gate: the handlers are DAG-aware
    (sibling vdl snapshots refreshed on evict, dropped runs not
    re-queued), so the axes compose with full ref-vs-SoA parity."""
    plans, tasks = _dag_cell()
    fm = "down(acc=0,start=0.02,duration=0.05,retighten=true)"
    ref = simulate(plans, tasks, 0.1, make_scheduler("terastal"), seed=0,
                   faults=fm, engine="reference")
    soa = simulate(plans, tasks, 0.1, make_scheduler("terastal"), seed=0,
                   faults=fm, engine="soa")
    assert ref.fingerprint() == soa.fingerprint()
    assert ref.faulted_spans == 1


@pytest.mark.parametrize("policy", ["reclaim", "adaptive"])
def test_online_budget_policies_with_dag_plans_rejected(policy):
    plans, tasks = _dag_cell()
    with pytest.raises(ValueError, match="linear-chain only; DAG plans"):
        simulate(
            plans, tasks, 0.1, make_scheduler("terastal"), seed=0,
            budget_policy=policy,
        )


def test_batch_engine_rejects_dag_plans():
    plans, tasks = _dag_cell()
    with pytest.raises(BatchUnsupportedError, match="does not support DAG plans"):
        simulate_batch(plans, tasks, 0.1, make_scheduler("terastal"), seeds=[0, 1])


# ------------------------------------------- ref-vs-SoA differential -----

_DAG_CELLS = [
    (name, pn)
    for name in sorted(DAG_SCENARIOS)
    for pn in DAG_SCENARIOS[name].platform_names
]


@pytest.mark.parametrize("cell", _DAG_CELLS, ids=[f"{s}@{p}" for s, p in _DAG_CELLS])
def test_dag_cells_reference_vs_soa_identical(cell):
    """Every DAG catalog cell x scheduler x arrival process:
    the SoA engine reproduces the reference fingerprint exactly."""
    name, platform = cell
    sc = get_scenario(name)
    for arrival in (None, "poisson", "mmpp(burstiness=4)"):
        plans, tasks = sc.plans(PLATFORMS[platform], arrival=arrival)
        procs = [t.arrival for t in tasks]
        for sched in ("fcfs", "edf", "dream", "terastal"):
            ref = simulate(plans, tasks, 0.25, make_scheduler(sched), seed=0,
                           processes=procs, engine="reference")
            soa = simulate(plans, tasks, 0.25, make_scheduler(sched), seed=0,
                           processes=procs, engine="soa")
            assert ref.fingerprint() == soa.fingerprint(), (name, platform, sched, arrival)


# ------------------------------------------------- conservation laws -----


def _check_laws(res, admission="none"):
    assert res.per_model
    for m, st_ in sorted(res.per_model.items()):
        assert st_.released == st_.completed + st_.dropped + st_.in_flight, (
            f"model {m}: released={st_.released} != completed={st_.completed}"
            f" + dropped={st_.dropped} + in_flight={st_.in_flight}"
        )
        assert st_.missed >= st_.dropped
        assert st_.shed <= st_.dropped
        if admission == "none":
            assert st_.shed == 0
        assert st_.in_flight >= 0


@pytest.mark.parametrize("engine", ["reference", "soa"])
def test_dag_conservation_both_engines(engine):
    """released == completed + dropped + in_flight on DAG trials: sibling
    node entries of one request must collapse to ONE accounting unit."""
    for name in ("dag_asr_encdec", "dag_vlm_2branch", "dag_moe_4expert"):
        plans, tasks = _dag_cell(name)
        procs = [t.arrival for t in tasks]
        for sched in ("fcfs", "terastal"):
            for admission in ("none", "shed_early(margin=1.5)"):
                res = simulate(
                    plans, tasks, 0.25, make_scheduler(sched), seed=0,
                    processes=procs, admission=admission, engine=engine,
                )
                _check_laws(res, admission)


# --------------------------------- runtime precedence / parallelism ------


class _RecordingScheduler(Scheduler):
    """Wraps a policy and records the dispatches the engine will accept,
    replicating ``invoke_scheduler``'s defensive filters (stale request,
    busy accelerator).  On fault-free trials the assignment latency IS
    the execution latency, so (start=now, finish=now+c) is exact."""

    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.uses_variants = inner.uses_variants
        self.records = []  # (start, finish, acc, model_idx, layer, DagRun|None)

    def schedule(self, view):
        out = self.inner.schedule(view)
        remaining = list(view.ready)
        busy = view.acc_busy_until.copy()
        for a in out:
            if a.req not in remaining:
                continue
            if busy[a.acc] > view.now + 1e-15:
                continue
            remaining.remove(a.req)
            plan = view.plans[a.req.model_idx]
            c = (
                float(plan.lat_var[a.layer, a.acc])
                if a.use_variant
                else float(plan.lat[a.layer, a.acc])
            )
            busy[a.acc] = view.now + c
            self.records.append(
                (view.now, view.now + c, a.acc, a.req.model_idx, a.layer, a.req.dag)
            )
        return out


def _run_recorded(plans, tasks, sched, duration=0.25, seed=0):
    rec = _RecordingScheduler(make_scheduler(sched))
    res = simulate(
        plans, tasks, duration, rec, seed=seed,
        processes=[t.arrival for t in tasks], engine="reference",
    )
    return res, rec.records


def _assert_precedence(plans, records):
    """No node of a DAG request starts before every predecessor of that
    same request has finished."""
    finish = {}
    for s, f, acc, m, l, run in records:
        if run is not None:
            finish[(id(run), l)] = f
    checked = 0
    for s, f, acc, m, l, run in records:
        if run is None:
            continue
        for p in plans[m].dag.preds[l]:
            assert (id(run), p) in finish, f"node {l} ran before pred {p} was dispatched"
            assert s >= finish[(id(run), p)] - 1e-12, (
                f"node {l} started {s} before pred {p} finished "
                f"{finish[(id(run), p)]}"
            )
            checked += 1
    return checked


@pytest.mark.parametrize("sched", ["fcfs", "terastal"])
def test_no_node_starts_before_preds_finish(sched):
    for name in sorted(DAG_SCENARIOS):
        plans, tasks = _dag_cell(name)
        _, records = _run_recorded(plans, tasks, sched)
        assert _assert_precedence(plans, records) > 0


def _overlapping_pair(records):
    """Two sibling nodes of ONE request in flight simultaneously on
    different accelerators — the parallelism the DAG axis exists for."""
    by_run = {}
    for r in records:
        if r[5] is not None:
            by_run.setdefault(id(r[5]), []).append(r)
    for recs in by_run.values():
        for i in range(len(recs)):
            for j in range(i + 1, len(recs)):
                s1, f1, a1 = recs[i][0], recs[i][1], recs[i][2]
                s2, f2, a2 = recs[j][0], recs[j][1], recs[j][2]
                if a1 != a2 and s1 < f2 - 1e-15 and s2 < f1 - 1e-15:
                    return recs[i], recs[j]
    return None


@pytest.mark.parametrize("sched", ["fcfs", "terastal"])
def test_intra_request_parallelism_observed(sched):
    """The acceptance-criterion probe: on the MoE cell two expert nodes
    of the same request overlap in time on different accelerators."""
    plans, tasks = _dag_cell("dag_moe_4expert")
    _, records = _run_recorded(plans, tasks, sched)
    pair = _overlapping_pair(records)
    assert pair is not None, "no intra-request parallelism observed"
    (s1, f1, a1, m1, l1, run1), (s2, f2, a2, m2, l2, run2) = pair
    assert run1 is run2 and a1 != a2


# ------------------------------------------ random-DAG property layer ----


def _random_dag_preds(rng, n):
    """Random valid predecessor structure: node ids are a topological
    order by construction, then a fix-up folds every would-be extra sink
    into node n-1 so the single-sink/connectivity contract holds."""
    preds = [()]
    for l in range(1, n):
        if rng.random() < 0.85:
            k = rng.randint(1, min(3, l))
            preds.append(tuple(sorted(rng.sample(range(l), k))))
        else:
            preds.append(())  # extra source
    has_succ = [False] * n
    for ps in preds:
        for p in ps:
            has_succ[p] = True
    last = set(preds[n - 1])
    for l in range(n - 1):
        if not has_succ[l]:
            last.add(l)
    preds[n - 1] = tuple(sorted(last))
    return tuple(preds)


def _random_levels(rng, n):
    """Per-node level tables like latency_levels over a random [n, 3]
    latency table (values in the platform's microsecond regime)."""
    return [
        latency_levels([rng.uniform(1e-4, 2e-3) for _ in range(3)])
        for _ in range(n)
    ]


def _check_budget_dag_properties(preds, levels, deadline):
    dag = LayerDag(preds)
    res = tighten_budgets_dag(levels, deadline, dag)
    if res.feasible:
        assert np.all(res.budgets > 0)
        vdl = res.virtual_deadlines
        for l, ps in enumerate(preds):
            for p in ps:
                assert vdl[l] > vdl[p]
        assert vdl[dag.sink] <= deadline + 1e-9
    # monotonicity under edge removal: dropping a precedence constraint
    # can only shorten the critical path, so feasibility is preserved
    # and (in the untightened regime) every budget can only grow
    nsucc = [0] * len(preds)
    for ps in preds:
        for p in ps:
            nsucc[p] += 1
    for l, ps in enumerate(preds):
        for p in ps:
            if nsucc[p] < 2:
                continue  # removal would create a second sink
            preds2 = list(preds)
            preds2[l] = tuple(x for x in ps if x != p)
            res2 = tighten_budgets_dag(levels, deadline, LayerDag(tuple(preds2)))
            if res.feasible:
                assert res2.feasible
                if not res.rho.any():
                    assert not res2.rho.any()
                    assert np.all(res2.budgets >= res.budgets - 1e-12)


def _random_dag_model(rng, preds):
    dims = (64, 128, 192, 256)
    layers = []
    for i in range(len(preds)):
        if rng.random() < 0.5:
            layers.append(fc(f"n{i}", rng.choice(dims), rng.choice(dims)))
        else:
            layers.append(
                matmul(f"n{i}", rng.choice(dims), rng.choice(dims), rng.choice(dims))
            )
    return DnnModel(f"rand_dag_{len(preds)}", layers, redundancy=0.7,
                    dag=LayerDag(preds))


def _check_random_dag_trial(n, seed):
    """One random DAG model end-to-end: precedence invariant on the
    reference engine, conservation laws, and ref-vs-SoA identity."""
    rng = random.Random(seed)
    plan = build_model_plan(
        _random_dag_model(rng, _random_dag_preds(rng, n)),
        PLATFORMS["6k_1ws2os"], deadline=0.01,
    )
    tasks = [TaskSpec(model_idx=0, fps=200.0)]
    res, records = _run_recorded([plan], tasks, "terastal", duration=0.05)
    if plan.dag is not None:
        _assert_precedence([plan], records)
    _check_laws(res)
    soa = simulate([plan], tasks, 0.05, make_scheduler("terastal"), seed=0,
                   processes=[t.arrival for t in tasks], engine="soa")
    assert soa.fingerprint() == res.fingerprint()


@pytest.mark.parametrize("seed", range(6))
def test_random_dag_trials_seeded(seed):
    _check_random_dag_trial(3 + (seed % 5), seed)


@pytest.mark.parametrize("seed", range(8))
def test_budget_dag_properties_seeded(seed):
    rng = random.Random(seed)
    n = rng.randint(2, 10)
    preds = _random_dag_preds(rng, n)
    levels = _random_levels(rng, n)
    # sweep tight -> loose deadlines around the minimum critical path
    floor = sum(lv[-1] for lv in levels)
    for scale in (0.3, 1.0, 3.0):
        _check_budget_dag_properties(preds, levels, floor * scale)


if _HAVE_HYPOTHESIS:

    @needs_hypothesis
    @settings(max_examples=30, deadline=None)
    @given(st.integers(min_value=2, max_value=12), st.integers(0, 10**6))
    def test_hypothesis_random_dag_valid_and_round_trips(n, seed):
        rng = random.Random(seed)
        dag = LayerDag(_random_dag_preds(rng, n))
        assert dag.sink == n - 1
        assert len(dag.topo) == n
        assert LayerDag.from_spec(dag.spec()) == dag

    @needs_hypothesis
    @settings(max_examples=30, deadline=None)
    @given(
        st.integers(min_value=2, max_value=10),
        st.integers(0, 10**6),
        st.floats(min_value=0.2, max_value=4.0),
    )
    def test_hypothesis_budget_monotone_under_edge_removal(n, seed, scale):
        rng = random.Random(seed)
        preds = _random_dag_preds(rng, n)
        levels = _random_levels(rng, n)
        floor = sum(lv[-1] for lv in levels)
        _check_budget_dag_properties(preds, levels, floor * scale)

    @needs_hypothesis
    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=3, max_value=8), st.integers(0, 10**6))
    def test_hypothesis_random_dag_precedence_conservation(n, seed):
        _check_random_dag_trial(n, seed)

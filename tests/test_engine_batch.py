"""Device-resident mega-batched trials (``engine="batch"``).

Pins the three contracts the batched engine ships with:

* **fingerprint parity** — every lane of one ``simulate_batch`` call
  matches ``simulate(..., engine="soa")`` exactly (the full
  :meth:`SimResult.fingerprint`: busy arrays, rounds, per-model integer
  counters and float retained sums) across the pinned differential grid
  of schedulers x arrival processes x inert budget axes;
* **named rejection** — every axis the device rollout cannot cover
  raises :class:`BatchUnsupportedError` (a ``ValueError``), never a
  silent fallback to another engine;
* **campaign integration** — ``run_trial_batch`` reproduces
  ``run_trial`` metric for metric, and ``TrialExecutor`` routes
  ``engine="batch"`` specs through the grouped device path while
  preserving result and callback order.
"""

import dataclasses
import math

import pytest

from repro.core import make_scheduler, simulate
from repro.core.campaign import TrialExecutor, TrialSpec, run_trial, run_trial_batch
from repro.core.engine_batch import BatchUnsupportedError, simulate_batch
from repro.core.scheduler import Scheduler, TerastalScheduler
from repro.core.simulator import ClosedLoopClients, make_arrival_process
from repro.core.workload import SATURATION_SCENARIOS
from repro.costmodel.maestro import PLATFORMS

SEEDS = [0, 1, 2]
DUR = 0.12
CELL, PLATFORM = "saturation_3x", "4k_1ws2os"


def _plans_tasks():
    return SATURATION_SCENARIOS[CELL].plans(PLATFORMS[PLATFORM])


def _procs(tasks, arrival):
    proc = make_arrival_process(arrival)
    return [t.arrival or proc for t in tasks]


def _soa_fingerprints(plans, tasks, sched_spec, procs, seeds, **kw):
    return [
        simulate(plans, tasks, DUR, make_scheduler(sched_spec), seed=s,
                 processes=procs, engine="soa", **kw).fingerprint()
        for s in seeds
    ]


# ------------------------------------------------------ differential grid ----


@pytest.mark.parametrize("sched_spec", [
    "fcfs", "edf", "dream",
    "terastal",                        # ef backfill, budgets + variants
    "terastal(backfill_mode=paper)",
])
@pytest.mark.parametrize("arrival", ["poisson", "periodic"])
def test_batch_matches_soa_on_differential_grid(sched_spec, arrival):
    """One vmapped device program vs B scalar SoA trials: the full
    SimResult fingerprint is identical on every lane, for every
    supported scheduler kernel and pre-generable arrival process."""
    plans, tasks = _plans_tasks()
    procs = _procs(tasks, arrival)
    batch = simulate_batch(plans, tasks, DUR, make_scheduler(sched_spec),
                           SEEDS, processes=procs)
    ref = _soa_fingerprints(plans, tasks, sched_spec, procs, SEEDS)
    for s, res, want in zip(SEEDS, batch, ref):
        assert res.fingerprint() == want, (sched_spec, arrival, s)


def test_batch_matches_soa_with_inert_budget_axes():
    """The inert budget axes — explicit static policy, admission="none"
    — are supported and stay fingerprint-exact; they must not be
    confused with the *online* axes the engine rejects."""
    plans, tasks = _plans_tasks()
    procs = _procs(tasks, "poisson")
    batch = simulate_batch(
        plans, tasks, DUR, make_scheduler("terastal"), SEEDS,
        processes=procs, budget_policy="static", admission="none")
    ref = _soa_fingerprints(plans, tasks, "terastal", procs, SEEDS,
                            budget_policy="static", admission="none")
    for s, res, want in zip(SEEDS, batch, ref):
        assert res.fingerprint() == want, s


def test_simulate_engine_batch_dispatch():
    """simulate(engine="batch") routes a single-seed trial through the
    batched engine and returns the same fingerprint as SoA."""
    plans, tasks = _plans_tasks()
    got = simulate(plans, tasks, DUR, make_scheduler("terastal"), seed=1,
                   engine="batch")
    want = simulate(plans, tasks, DUR, make_scheduler("terastal"), seed=1,
                    engine="soa")
    assert got.fingerprint() == want.fingerprint()


# --------------------------------------------------------- named rejection ----


def test_unsupported_axes_raise_named_errors():
    """Every unsupported axis raises BatchUnsupportedError (a ValueError
    subclass) with a message naming the axis — never a silent fallback."""
    assert issubclass(BatchUnsupportedError, ValueError)
    plans, tasks = _plans_tasks()
    sched = make_scheduler("terastal")

    class WeirdScheduler(Scheduler):
        name = "weird"

        def schedule_round(self, *a, **kw):  # pragma: no cover
            raise NotImplementedError

    with pytest.raises(BatchUnsupportedError, match="no kernel for WeirdScheduler"):
        simulate_batch(plans, tasks, DUR, WeirdScheduler(), SEEDS)
    # subclasses of supported kernels are rejected too (exact-type check:
    # an overridden method would silently diverge from the device kernel)
    class TweakedTerastal(TerastalScheduler):
        pass

    with pytest.raises(BatchUnsupportedError, match="no kernel"):
        simulate_batch(plans, tasks, DUR, TweakedTerastal(), SEEDS)
    with pytest.raises(BatchUnsupportedError, match="online budget policy"):
        simulate_batch(plans, tasks, DUR, sched, SEEDS, budget_policy="reclaim")
    from repro.core.budget_online import BudgetPolicy

    ticking = BudgetPolicy()
    ticking.tick_interval = 0.02
    with pytest.raises(BatchUnsupportedError, match="tick events"):
        simulate_batch(plans, tasks, DUR, sched, SEEDS, budget_policy=ticking)
    with pytest.raises(BatchUnsupportedError, match="admission policy"):
        simulate_batch(plans, tasks, DUR, sched, SEEDS,
                       admission="shed_early(margin=1.5)")
    closed = ClosedLoopClients(n_users=4, think_time=0.05)
    with pytest.raises(BatchUnsupportedError, match="closed-loop"):
        simulate_batch(plans, tasks, DUR, sched, SEEDS,
                       processes=[closed for _ in tasks])


def test_simulate_dispatch_propagates_named_error():
    plans, tasks = _plans_tasks()
    with pytest.raises(BatchUnsupportedError, match="admission policy"):
        simulate(plans, tasks, DUR, make_scheduler("terastal"), seed=0,
                 engine="batch", admission="shed_early(margin=1.5)")


# ----------------------------------------------------- campaign integration ----


def _spec(seed, **kw):
    return TrialSpec(CELL, PLATFORM, "terastal", duration=DUR, seed=seed, **kw)


def _metrics(tr):
    """Every TrialResult field except spec and wall_s (timing)."""
    return (tr.mean_miss_rate, tr.mean_accuracy_loss, tr.utilization,
            tr.rounds, tr.models_counted, tr.released, tr.completed,
            tr.dropped, tr.variants_applied, tr.shed)


def _assert_same_metrics(a, b):
    ma, mb = _metrics(a), _metrics(b)
    for x, y in zip(ma, mb):
        if isinstance(x, float) and math.isnan(x) and math.isnan(y):
            continue
        assert x == y, (ma, mb)


def test_run_trial_batch_matches_run_trial():
    specs = [_spec(s, engine="batch") for s in SEEDS]
    batched = run_trial_batch(specs)
    assert [r.spec for r in batched] == specs
    for sp, got in zip(specs, batched):
        want = run_trial(dataclasses.replace(sp, engine="soa"))
        _assert_same_metrics(got, want)


def test_run_trial_batch_rejects_mixed_specs():
    with pytest.raises(ValueError, match="identical except seed"):
        run_trial_batch([_spec(0, engine="batch"),
                         _spec(1, engine="batch", arrival="poisson")])


def test_executor_groups_batch_specs_preserving_order():
    """run_batch groups engine="batch" seed replicates into device
    programs, runs the rest through the scalar path, and emits results
    (and on_result callbacks) in the original specs order."""
    specs = [
        _spec(0, engine="batch"),
        _spec(0, engine="soa"),
        _spec(1, engine="batch"),
        _spec(2, engine="batch", arrival="poisson"),  # second group
        _spec(3, engine="batch"),
    ]
    seen = []
    ex = TrialExecutor(parallel=False)
    results = ex.run_batch(specs, on_result=lambda r: seen.append(r.spec))
    assert [r.spec for r in results] == specs
    assert seen == specs
    # the grouped lanes match their scalar twins
    for got in (results[0], results[2], results[4]):
        want = run_trial(dataclasses.replace(got.spec, engine="soa"))
        _assert_same_metrics(got, want)

"""Online budget policies: static bit-compat regression, slack
reclamation semantics, adaptive controller re-distribution, the budget
invariants under every policy, and baseline invariance."""

import numpy as np
import pytest

from repro.core import (
    ALL_SCHEDULERS,
    SCENARIOS,
    AdaptiveBudgetPolicy,
    BudgetPolicy,
    ReclaimBudgetPolicy,
    StaticBudgetPolicy,
    make_budget_policy,
    make_scheduler,
    simulate,
)
from repro.core.scheduler import Request
from repro.core.simulator import make_arrival_process
from repro.core.variants import build_model_plan
from repro.costmodel.dnn_zoo import resnet50, vgg11
from repro.costmodel.maestro import PLATFORMS


def _fingerprint(res):
    return (
        res.acc_busy_time.tolist(),
        {
            m: (s.released, s.completed, s.missed, s.dropped, s.variants_applied, s.retained_sum)
            for m, s in sorted(res.per_model.items())
        },
    )


# ------------------------------------------------------------- factory ----


def test_make_budget_policy_specs():
    assert isinstance(make_budget_policy(None), StaticBudgetPolicy)
    assert isinstance(make_budget_policy("static"), StaticBudgetPolicy)
    assert isinstance(make_budget_policy("reclaim"), ReclaimBudgetPolicy)
    ada = make_budget_policy("adaptive(tick=0.02,skew_min=5)")
    assert isinstance(ada, AdaptiveBudgetPolicy)
    assert ada.tick_interval == 0.02 and ada.skew_min == 5.0
    inst = ReclaimBudgetPolicy()
    assert make_budget_policy(inst) is inst
    with pytest.raises(KeyError, match="unknown budget policy"):
        make_budget_policy("slackful")
    with pytest.raises(ValueError, match="valid parameters"):
        make_budget_policy("adaptive(tck=0.01)")
    with pytest.raises(ValueError):
        make_budget_policy("adaptive(tick=0)")  # controller needs a period
    with pytest.raises(ValueError):
        make_budget_policy("reclaim(spread=2)")  # spread outside [0, 1]


# ------------------------------------------------- static == seed (pin) ----


def test_static_policy_bit_identical_to_seed_simulator():
    """budget_policy="static" (and None) must reproduce the pre-policy
    simulator bit-for-bit: same busy times, same per-model counters —
    across schedulers, arrival processes, and seeds."""
    sc = SCENARIOS["ar_gaming_heavy"]
    plans, tasks = sc.plans(PLATFORMS["6k_1ws2os"])
    mmpp = [make_arrival_process("mmpp(burstiness=4)")] * len(tasks)
    for name in ("fcfs", "terastal", "terastal_no_budgeting"):
        for procs in (None, mmpp):
            for seed in (0, 1):
                ref = simulate(plans, tasks, 1.0, make_scheduler(name), seed=seed,
                               processes=procs)
                stat = simulate(plans, tasks, 1.0, make_scheduler(name), seed=seed,
                                processes=procs, budget_policy="static")
                non = simulate(plans, tasks, 1.0, make_scheduler(name), seed=seed,
                               processes=procs, budget_policy=None)
                assert _fingerprint(stat) == _fingerprint(ref)
                assert _fingerprint(non) == _fingerprint(ref)


def test_non_budget_schedulers_invariant_under_all_policies():
    """FCFS/EDF/DREAM (and the no-budgeting ablation) never read virtual
    deadlines, so every budget policy must leave them bit-identical."""
    sc = SCENARIOS["ar_social"]
    plans, tasks = sc.plans(PLATFORMS["4k_1ws2os"])
    procs = [make_arrival_process("mmpp(burstiness=4)")] * len(tasks)
    for name in ("fcfs", "edf", "dream", "terastal_no_budgeting"):
        ref = simulate(plans, tasks, 1.0, make_scheduler(name), seed=0, processes=procs)
        for pol in ("reclaim", "adaptive"):
            got = simulate(plans, tasks, 1.0, make_scheduler(name), seed=0,
                           processes=procs, budget_policy=pol)
            assert _fingerprint(got) == _fingerprint(ref), (name, pol)


# ----------------------------------------------------------- reclaim ----


def _plan(deadline=1 / 30.0):
    return build_model_plan(resnet50(448), PLATFORMS["6k_1ws2os"], deadline)


def test_reclaim_initializes_and_reclaims_slack():
    plan = _plan()
    pol = ReclaimBudgetPolicy()
    req = Request(rid=0, model_idx=0, arrival=2.0, deadline_abs=2.0 + plan.deadline)
    pol.on_release(req, plan, 2.0)
    np.testing.assert_allclose(req.vdl_abs, 2.0 + plan.vdl_rel)

    # finish layer 0 well ahead of its virtual deadline
    t_fin = float(req.vdl_abs[0]) - 0.5 * float(plan.budget.budgets[0])
    req.next_layer = 1
    pol.on_layer_finish(req, plan, 0, t_fin)
    # every downstream layer's budget grows (the freed slack is spread
    # proportionally, re-anchored at the actual finish time) and the final
    # virtual deadline lands exactly on the request deadline
    b_new = np.diff(np.concatenate([[t_fin], req.vdl_abs[1:]]))
    assert (b_new > plan.budget.budgets[1:]).all()
    assert req.vdl_abs[-1] == pytest.approx(req.deadline_abs)
    assert b_new.sum() == pytest.approx(req.deadline_abs - t_fin)
    np.testing.assert_allclose(
        b_new / b_new.sum(), plan.budget.c_ref[1:] / plan.budget.c_ref[1:].sum(), rtol=1e-9
    )


def test_reclaim_noop_when_layer_finishes_late():
    plan = _plan()
    pol = ReclaimBudgetPolicy()
    req = Request(rid=0, model_idx=0, arrival=0.0, deadline_abs=plan.deadline)
    pol.on_release(req, plan, 0.0)
    old = req.vdl_abs.copy()
    req.next_layer = 1
    pol.on_layer_finish(req, plan, 0, float(old[0]) + 1e-6)  # after its vdl
    np.testing.assert_array_equal(req.vdl_abs, old)
    # last layer finish has no downstream layers to push slack into
    req.next_layer = len(plan.model.layers)
    pol.on_layer_finish(req, plan, len(plan.model.layers) - 1, 0.01)


# ----------------------------------------------------------- adaptive ----


def _synthetic_plan(lat, deadline):
    from repro.core.budget import distribute_budgets
    from repro.core.variants import ModelPlan
    from repro.costmodel.dnn_zoo import DnnModel
    from repro.costmodel.layers import matmul
    from repro.costmodel.maestro import Accelerator, Dataflow, Platform

    lat = np.asarray(lat, dtype=float)
    plat = Platform("t", tuple(
        Accelerator(f"a{k}", Dataflow.WS if k == 0 else Dataflow.OS, 1024)
        for k in range(lat.shape[1])
    ))
    model = DnnModel("m", [matmul(f"l{i}", 8, 8, 8) for i in range(lat.shape[0])],
                     redundancy=0.5)
    return ModelPlan(model=model, platform=plat, deadline=deadline, lat=lat,
                     budget=distribute_budgets(lat, deadline), variants={}, theta=0.9)


def _force_burst(pol, req, plan):
    """Feed the release stream so the detector reads a burst at the end
    (policy built with window=2: two back-to-back releases after a long
    quiet stretch push the recent rate far above the long-run mean)."""
    pol.on_release(Request(rid=90, model_idx=0, arrival=0.0,
                           deadline_abs=plan.deadline), plan, 0.0)
    pol.on_release(Request(rid=91, model_idx=0, arrival=req.arrival - 1e-3,
                           deadline_abs=req.arrival + plan.deadline), plan,
                   req.arrival - 1e-3)
    pol.on_release(req, plan, req.arrival)
    assert pol.bursting(req.arrival + 1e-4)


def test_adaptive_quiet_regime_is_inert():
    """Without a detected burst, adaptive never touches a chain even on an
    early finish — the paper's periodic regime stays exactly static."""
    plan = _plan()
    pol = AdaptiveBudgetPolicy()
    req = Request(rid=0, model_idx=0, arrival=0.0, deadline_abs=plan.deadline)
    pol.on_release(req, plan, 0.0)
    assert not pol.bursting(0.01)
    old = req.vdl_abs.copy()
    req.next_layer = 1
    pol.on_layer_finish(req, plan, 0, 0.25 * float(old[0]))  # well ahead
    np.testing.assert_array_equal(req.vdl_abs, old)


def test_adaptive_skew_gate_mixes_chains():
    """Inside a burst, reclaimed (tightened) milestones apply only to
    catastrophic-skew layers; mild-skew layers keep offline milestones."""
    # layer skews: 100, 1.5, 100, 1.5 -- deadline loose (no tightening)
    lat = [[1.0, 100.0], [2.0, 3.0], [1.0, 100.0], [2.0, 3.0]]
    plan = _synthetic_plan(lat, deadline=600.0)
    pol = AdaptiveBudgetPolicy(window=2, skew_min=10.0)
    req = Request(rid=0, model_idx=0, arrival=5.0, deadline_abs=5.0 + plan.deadline)
    _force_burst(pol, req, plan)
    static_abs = req.arrival + plan.vdl_rel
    # finish immediately (well ahead of the milestone, still in the burst)
    t_fin = req.arrival + 1e-3
    req.next_layer = 1
    pol.on_layer_finish(req, plan, 0, t_fin)
    # mild layer 1 keeps its offline milestone; skewed layer 2 tightens
    assert req.vdl_abs[1] == pytest.approx(static_abs[1])
    assert req.vdl_abs[2] < static_abs[2] - 1e-9
    # final milestone stays within the deadline, chain monotone, budgets
    # floored at per-layer minima
    assert req.vdl_abs[-1] <= req.deadline_abs + 1e-9
    b = np.diff(req.vdl_abs)
    assert (np.diff(req.vdl_abs) >= -1e-12).all()
    assert (b >= plan.min_lat[1:] - 1e-12).all()


def test_adaptive_tick_restores_stale_chains():
    """The controller tick repairs a reclaimed chain whose milestone has
    gone stale: the offline kernel distribution is restored."""
    lat = [[1.0, 100.0], [1.0, 100.0], [1.0, 100.0]]
    plan = _synthetic_plan(lat, deadline=30.0)
    pol = AdaptiveBudgetPolicy(window=2)
    req = Request(rid=0, model_idx=0, arrival=5.0, deadline_abs=5.0 + plan.deadline)
    _force_burst(pol, req, plan)
    t_fin = req.arrival + 1e-3  # finish immediately, still in the burst
    req.next_layer = 1
    pol.on_layer_finish(req, plan, 0, t_fin)
    assert req.vdl_abs[1] < req.arrival + plan.vdl_rel[1] - 1e-12  # tightened
    # not yet stale: tick leaves the reclaimed chain alone
    before = req.vdl_abs.copy()
    pol.on_tick(t_fin + 1e-6, [req], [plan], np.zeros(plan.platform.n_acc))
    np.testing.assert_array_equal(req.vdl_abs, before)
    # congestion outran the reclaimed milestone: restored to offline chain
    stale_now = float(req.vdl_abs[1])  # < vdl[1] + min_lat => stale
    pol.on_tick(stale_now, [req], [plan], np.zeros(plan.platform.n_acc))
    np.testing.assert_allclose(req.vdl_abs, req.arrival + plan.vdl_rel)


def test_monotone_reclaim_pins_static_as_loosest_chain():
    """Design fact the adaptive gates rest on: proportional re-anchoring
    never loosens any milestone, so elementwise-max (monotone) reclaim is
    bit-identical to static."""
    sc = SCENARIOS["ar_gaming_heavy"]
    plans, tasks = sc.plans(PLATFORMS["6k_1ws2os"])
    procs = [make_arrival_process("mmpp(burstiness=4)")] * len(tasks)
    ref = simulate(plans, tasks, 1.5, make_scheduler("terastal"), seed=0, processes=procs)
    mono = simulate(plans, tasks, 1.5, make_scheduler("terastal"), seed=0,
                    processes=procs, budget_policy="reclaim(monotone=true)")
    assert _fingerprint(mono) == _fingerprint(ref)


# ----------------------------------------------- invariants end-to-end ----


class _CheckedAdaptive(AdaptiveBudgetPolicy):
    """Asserts the budget invariants at every mutation point of a real
    simulation: after a reclamation the re-anchored budgets sum to <= the
    remaining deadline, never fall below the per-layer minimum latency,
    and never exceed the offline milestones; a tick repair restores the
    offline chain exactly."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.reclaims = 0
        self.repairs = 0

    def on_layer_finish(self, req, plan, layer, now):
        before = None if req.vdl_abs is None else req.vdl_abs
        super().on_layer_finish(req, plan, layer, now)
        if req.vdl_abs is None or req.vdl_abs is before:
            return
        l0 = req.next_layer
        vdl = req.vdl_abs
        b = np.diff(np.concatenate([[now], vdl[l0:]]))
        assert b.sum() <= (req.deadline_abs - now) + 1e-9
        assert (b >= plan.min_lat[l0:] - 1e-9).all()
        # tightening-only: never looser than the offline chain
        assert (vdl[l0:] <= req.arrival + plan.vdl_rel[l0:] + 1e-9).all()
        self.reclaims += 1

    def on_tick(self, now, ready, plans, acc_busy_until):
        before = {id(r): r.vdl_abs for r in ready}
        super().on_tick(now, ready, plans, acc_busy_until)
        for r in ready:
            if r.vdl_abs is not None and r.vdl_abs is not before[id(r)]:
                np.testing.assert_allclose(
                    r.vdl_abs, r.arrival + plans[r.model_idx].vdl_rel
                )
                self.repairs += 1


def test_budget_invariants_hold_throughout_simulation():
    sc = SCENARIOS["ar_gaming_heavy"]
    plans, tasks = sc.plans(PLATFORMS["6k_1ws2os"])
    procs = [make_arrival_process("mmpp(burstiness=8)")] * len(tasks)
    pol = _CheckedAdaptive(tick=0.01)
    res = simulate(plans, tasks, 2.0, make_scheduler("terastal"), seed=0,
                   processes=procs, budget_policy=pol)
    assert pol.reclaims > 20  # the burst-gated reclamation actually ran
    assert 0.0 <= res.mean_miss_rate <= 1.0


def test_policy_instance_reusable_across_runs():
    """One policy instance passed to several simulate() calls must give
    the same results as fresh instances: simulate() resets cross-run
    state (burst detector, caches) before each run."""
    sc = SCENARIOS["ar_gaming_heavy"]
    plans, tasks = sc.plans(PLATFORMS["6k_1ws2os"])
    procs = [make_arrival_process("mmpp(burstiness=8)")] * len(tasks)
    shared = AdaptiveBudgetPolicy()
    for seed in (0, 1):
        reused = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=seed,
                          processes=procs, budget_policy=shared)
        fresh = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=seed,
                         processes=procs, budget_policy="adaptive")
        assert _fingerprint(reused) == _fingerprint(fresh), seed


def test_all_schedulers_finite_under_every_policy():
    sc = SCENARIOS["multicam_light"]
    plans, tasks = sc.plans(PLATFORMS["4k_1ws2os"])
    for name in ALL_SCHEDULERS:
        for pol in ("static", "reclaim", "adaptive"):
            res = simulate(plans, tasks, 0.5, make_scheduler(name), seed=0,
                           budget_policy=pol)
            assert np.isfinite(res.mean_miss_rate)
            assert 0.0 <= res.mean_miss_rate <= 1.0

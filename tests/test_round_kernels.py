"""Deep-queue round kernels: scalar / vectorized / jitted parity at the
pow2 bucket boundaries, ready-block growth past the initial cap, and the
round-kernel dispatch plumbing (env var, TrialSpec axis, crossover).

The parity tests run on block states CAPTURED from real saturation
trials (clones snapshotted mid-simulation at exact target depths), so
the instances carry the true deep-queue structure — mixed layers,
variants, partially busy accelerators — rather than synthetic rounds.
"""

import os

import numpy as np
import pytest

from repro.core import make_scheduler, simulate
from repro.core import engine_soa
from repro.core.campaign import TrialSpec, run_trial
from repro.core.engine_soa import _ReadyBlock
from repro.core.workload import SATURATION_SCENARIOS, get_scenario
from repro.costmodel.maestro import PLATFORMS

#: either side of the pow2 shape buckets 16 and 64 (bucket_nj boundaries)
BOUNDARY_NJ = (15, 16, 17, 63, 64, 65)


# --------------------------------------------------------- state capture ----


def _capture(mode: str, targets, per_target=3, duration=1.5):
    """Clone real round states at exact depths from a saturation trial
    run with the given backfill mode (vectorized kernel forced on so the
    clones carry live deep mirrors)."""
    got = {nj: [] for nj in targets}
    want = set(targets)
    orig = engine_soa._kern_terastal_vec

    def capture(B, now, busy, idle_mask, n_idle, kmode):
        if B.n in want and len(got[B.n]) < per_target:
            got[B.n].append((B.clone(), now, list(busy), idle_mask, n_idle, kmode))
        return orig(B, now, busy, idle_mask, n_idle, kmode)

    engine_soa._kern_terastal_vec = capture
    old_env = os.environ.get("REPRO_ROUND_VEC_MIN")
    os.environ["REPRO_ROUND_VEC_MIN"] = "2"
    try:
        for cell in ("saturation_5x", "saturation_3x"):
            if all(len(v) >= per_target for v in got.values()):
                break
            plans, tasks = SATURATION_SCENARIOS[cell].plans(PLATFORMS["4k_1ws2os"])
            simulate(plans, tasks, duration,
                     make_scheduler(f"terastal(backfill_mode={mode})"),
                     seed=0, engine="soa", round_kernel="python")
    finally:
        engine_soa._kern_terastal_vec = orig
        if old_env is None:
            del os.environ["REPRO_ROUND_VEC_MIN"]
        else:
            os.environ["REPRO_ROUND_VEC_MIN"] = old_env
    return got


@pytest.mark.parametrize("mode", ["ef", "paper", "positive"])
def test_vec_kernel_parity_at_bucket_boundaries(mode):
    """Scalar and vectorized rounds emit identical assignment lists —
    slots, accelerators, variant flags, latencies, emission order — at
    every boundary depth, for every backfill mode."""
    states = _capture(mode, BOUNDARY_NJ)
    checked = 0
    for nj, instances in states.items():
        assert instances, f"no round captured at NJ={nj}"
        for args in instances:
            a = engine_soa._kern_terastal(*args)
            b = engine_soa._kern_terastal_vec(*args)
            assert a == b, (mode, nj)
            checked += 1
    assert checked >= len(BOUNDARY_NJ)


@pytest.mark.parametrize("mode", ["ef", "paper"])
def test_jax_round_parity_at_bucket_boundaries(mode):
    """The jitted round (through the engine's staging path) matches the
    scalar kernel on the same captured states — including the emission
    order reconstructed from assign_seq, which fixes finish-event
    tie-breaking downstream.  f64 end to end: the latency tables here
    are arbitrary floats, not the dyadic grid of the property test."""
    targets = (15, 16, 17) if mode == "paper" else BOUNDARY_NJ
    states = _capture(mode, targets, per_target=2)
    for nj, instances in states.items():
        for B, now, busy, idle_mask, n_idle, kmode in instances:
            ref = engine_soa._kern_terastal(B, now, busy, idle_mask, n_idle, kmode)
            jx = engine_soa._jax_round(B, now, busy, idle_mask, len(busy), kmode)
            assert jx == ref, (mode, nj)


# ------------------------------------------------------------ block grow ----


def test_ready_block_grows_past_initial_cap_with_mirrors():
    """grow() doubles every parallel field — scalar lists, drop arrays,
    and the deep mirrors — preserving live slot contents."""
    B = _ReadyBlock()
    assert B.cap == 64
    n_acc = 3
    B.activate_deep_terastal(n_acc)
    rows = {}
    for i in range(150):
        if B.n == B.cap:
            B.grow()
        n = B.n
        row = tuple(float(x) for x in np.random.default_rng(i).uniform(0.01, 0.2, n_acc))
        B.rid[n] = i
        B.dl[n] = 1.0 + i
        B.lat[n] = row
        B.vdl[n] = 0.5 + i
        B.min_rem_arr[n] = 0.1
        B.dl_eps_arr[n] = 1.0 + i
        B.guard_arr[n] = 0.9 + i
        B.rid_arr[n] = i
        B.vdl_arr[n] = 0.5 + i
        B.vdl_next_arr[n] = 0.6 + i
        B.next_min_arr[n] = 0.01
        B.lat_arr[:, n] = row
        B.latv_arr[:, n] = np.inf
        rows[i] = row
        B.n = n + 1
    assert B.cap == 256 and B.n == 150
    assert len(B.rid) == 256 and len(B.lat) == 256
    assert B.lat_arr.shape == (n_acc, 256) and B.min_rem_arr.shape == (256,)
    for i in (0, 63, 64, 127, 128, 149):  # survived both doublings
        assert B.rid[i] == i and B.rid_arr[i] == i
        assert B.lat[i] == rows[i]
        assert tuple(B.lat_arr[:, i]) == rows[i]
        assert B.vdl_arr[i] == 0.5 + i
    # swap_remove keeps mirrors coherent across the grown region
    B.swap_remove(0)
    assert B.rid[0] == 149 and B.rid_arr[0] == 149
    assert tuple(B.lat_arr[:, 0]) == rows[149]


def test_saturation_trial_exercises_growth_and_stays_bit_identical():
    """saturation_8x queues go past 128 ready layers (two grow()s) —
    and the whole trial still matches the reference engine exactly."""
    depths = []
    orig = engine_soa._kern_terastal_vec

    def probe(B, *a):
        depths.append(B.n)
        return orig(B, *a)

    engine_soa._kern_terastal_vec = probe
    try:
        plans, tasks = SATURATION_SCENARIOS["saturation_8x"].plans(
            PLATFORMS["4k_1ws2os"])
        soa = simulate(plans, tasks, 1.5, make_scheduler("terastal"), seed=0,
                       engine="soa")
    finally:
        engine_soa._kern_terastal_vec = orig
    assert max(depths) > 128  # grew 64 -> 128 -> 256
    ref = simulate(plans, tasks, 1.5, make_scheduler("terastal"), seed=0,
                   engine="reference")
    assert ref.rounds == soa.rounds
    assert ref.acc_busy_time.tolist() == soa.acc_busy_time.tolist()
    for m in ref.per_model:
        a, b = ref.per_model[m], soa.per_model[m]
        assert (a.released, a.completed, a.missed, a.dropped,
                a.variants_applied, a.retained_sum) == \
               (b.released, b.completed, b.missed, b.dropped,
                b.variants_applied, b.retained_sum)


# --------------------------------------------------------------- dispatch ----


def test_round_kernel_env_and_arg_validation(monkeypatch):
    plans, tasks = get_scenario("ar_social").plans(PLATFORMS["4k_1ws2os"])
    with pytest.raises(ValueError, match="unknown round kernel"):
        simulate(plans, tasks, 0.2, make_scheduler("terastal"), seed=0,
                 engine="soa", round_kernel="cuda")
    monkeypatch.setenv("REPRO_ROUND_KERNEL", "nope")
    with pytest.raises(ValueError, match="unknown round kernel"):
        simulate(plans, tasks, 0.2, make_scheduler("terastal"), seed=0,
                 engine="soa")
    # explicit argument beats the env var
    monkeypatch.setenv("REPRO_ROUND_KERNEL", "python")
    res = simulate(plans, tasks, 0.2, make_scheduler("terastal"), seed=0,
                   engine="soa", round_kernel="python")
    assert res.rounds is not None


def test_round_kernel_env_reaches_auto_trials(monkeypatch):
    """TrialSpecs carry the explicit default "auto", so the env var must
    apply THROUGH it (the REPRO_SIM_ENGINE precedent) — forcing jax
    process-wide has to reach campaign trials, not only direct callers."""
    plans, tasks = SATURATION_SCENARIOS["saturation_3x"].plans(
        PLATFORMS["4k_1ws2os"])
    calls = {"n": 0}
    orig = engine_soa._jax_round

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(engine_soa, "_jax_round", counting)
    monkeypatch.setenv("REPRO_ROUND_KERNEL", "jax")
    simulate(plans, tasks, 0.1, make_scheduler("terastal"), seed=0,
             engine="soa", round_kernel="auto")
    assert calls["n"] > 0  # env reached the "auto" trial
    # ... but an explicit python argument still beats the env var
    calls["n"] = 0
    simulate(plans, tasks, 0.1, make_scheduler("terastal"), seed=0,
             engine="soa", round_kernel="python")
    assert calls["n"] == 0


def test_round_kernel_axis_threads_through_campaign():
    """TrialSpec.round_kernel reaches the engine and never changes any
    result — the axis is a perf knob with bit-identical outputs."""
    base = TrialSpec("saturation_3x", "4k_1ws2os", "terastal", duration=0.5)
    auto = run_trial(base)
    python = run_trial(TrialSpec("saturation_3x", "4k_1ws2os", "terastal",
                                 duration=0.5, round_kernel="python"))
    assert auto.rounds > 0  # SimResult.rounds telemetry flows through
    assert (auto.mean_miss_rate, auto.released, auto.completed, auto.dropped,
            auto.utilization, auto.rounds) == \
           (python.mean_miss_rate, python.released, python.completed,
            python.dropped, python.utilization, python.rounds)


def test_round_crossover_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_ROUND_CROSSOVER", raising=False)
    engine_soa.set_round_crossover(None)
    assert engine_soa.round_crossover() == float("inf")  # honest default
    engine_soa.set_round_crossover(128)
    assert engine_soa.round_crossover() == 128.0
    monkeypatch.setenv("REPRO_ROUND_CROSSOVER", "96")
    assert engine_soa.round_crossover() == 96.0  # env wins
    monkeypatch.setenv("REPRO_ROUND_CROSSOVER", "inf")
    assert engine_soa.round_crossover() == float("inf")
    engine_soa.set_round_crossover(None)


def test_auto_inf_crossover_is_python():
    """REPRO_ROUND_CROSSOVER=inf + round_kernel="auto" takes the
    dead-weight fast path: the trial is bit-identical to an explicit
    "python" kernel AND the jax machinery is never imported — the whole
    point of the fast path is that auto costs nothing when the measured
    crossover says jax never wins.  Subprocess, so the import-set
    assertion sees a clean module table."""
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = (
        "import sys\n"
        "from repro.core import make_scheduler, simulate\n"
        "from repro.core.workload import SATURATION_SCENARIOS\n"
        "from repro.costmodel.maestro import PLATFORMS\n"
        "plans, tasks = SATURATION_SCENARIOS['saturation_3x'].plans("
        "PLATFORMS['4k_1ws2os'])\n"
        "auto = simulate(plans, tasks, 0.3, make_scheduler('terastal'),"
        " seed=0, engine='soa', round_kernel='auto')\n"
        "assert 'repro.core.scheduler_jax' not in sys.modules, "
        "'auto imported the jax machinery despite crossover=inf'\n"
        "assert 'jax' not in sys.modules\n"
        "py = simulate(plans, tasks, 0.3, make_scheduler('terastal'),"
        " seed=0, engine='soa', round_kernel='python')\n"
        "assert auto.fingerprint() == py.fingerprint()\n"
        "print('OK')\n"
    )
    env = dict(os.environ,
               PYTHONPATH=os.path.join(root, "src"),
               REPRO_ROUND_CROSSOVER="inf")
    env.pop("REPRO_ROUND_KERNEL", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "OK" in proc.stdout


def test_batch_trial_buffers_compile_once_per_bucket_pair():
    """The batched trial engine pads the event horizon (bucket_ev) and
    the seed axis (bucket_nj) into persistent seed-major buffers, so
    ``_run_trials`` compiles at most once per (NR bucket, B bucket) pair
    per kernel config — the pack_view recompile bound lifted to the
    batch axis.  Mid-trial growth is structurally absent here (the
    horizon is padded up front, unlike _ReadyBlock.grow()); what can
    grow mid-grid is the seed batch and the horizon between calls, and
    each rung crossing must cost exactly one compilation.  Unique B
    bucket (16) keeps the pairs disjoint from every other test in the
    process, so the counter deltas are exact."""
    from repro.core.engine_batch import _run_trials, simulate_batch
    from repro.core.scheduler_jax import pack_trials
    from repro.core.workload import batch_release_events

    plans, tasks = SATURATION_SCENARIOS["saturation_3x"].plans(
        PLATFORMS["4k_1ws2os"])
    dl = np.array([p.deadline for p in plans])

    def buckets(dur, seeds):
        ev = batch_release_events(tasks, dur, list(seeds))
        _, b_pad, nr_pad = pack_trials(ev, dl)
        return nr_pad, b_pad

    def run(dur, seeds):
        return simulate_batch(plans, tasks, dur,
                              make_scheduler("terastal"), list(seeds))

    # the shape assumptions this test rides on (seeded event generation
    # is deterministic, so these are stable):
    assert buckets(0.05, range(9)) == (48, 16)    # warm pair
    assert buckets(0.05, range(16)) == (48, 16)   # B grows inside bucket
    assert buckets(0.05, range(17)) == (48, 32)   # B crosses its bucket
    assert buckets(0.12, range(9)) == (96, 16)    # horizon crosses a rung

    run(0.05, range(9))  # warm the (48, 16) pair for this kernel config
    base = _run_trials._cache_size()
    run(0.05, range(16))  # same pair: B 9 -> 16 inside the bucket
    run(0.05, range(4, 13))  # same pair, disjoint seeds
    assert _run_trials._cache_size() == base
    run(0.05, range(17))  # seed axis crosses 16 -> 32: exactly one
    assert _run_trials._cache_size() == base + 1
    run(0.12, range(9))  # horizon crosses 48 -> 96: exactly one
    assert _run_trials._cache_size() == base + 2
    run(0.05, range(9))  # revisiting the warm pair stays free
    assert _run_trials._cache_size() == base + 2

"""Variant design + accuracy model: paper-calibrated bands and V_m laws."""

import numpy as np
import pytest

from repro.core.accuracy import combo_retained_fraction, layer_variant_loss
from repro.core.variants import build_model_plan
from repro.costmodel.dnn_zoo import get_model, resnet50, swin_tiny, vgg11
from repro.costmodel.maestro import PLATFORMS


def test_vgg11_individual_losses_in_paper_band():
    """Fig. 3 bottom: individual VGG11 variants lose ~7%-17%."""
    m = vgg11(224)
    losses = [
        layer_variant_loss(m.name, l.name, m.redundancy, 2) for l in m.layers[:8]
    ]
    assert min(losses) > 0.05
    assert max(losses) < 0.20


def test_redundant_models_more_robust():
    """Fig. 4: ResNet50/Swin-Tiny tolerate multiple variants."""
    r50, vgg = resnet50(), vgg11()
    loss_r = np.mean([layer_variant_loss(r50.name, l.name, r50.redundancy, 2) for l in r50.layers[:20]])
    loss_v = np.mean([layer_variant_loss(vgg.name, l.name, vgg.redundancy, 2) for l in vgg.layers[:8]])
    assert loss_r < 0.5 * loss_v


def test_combo_loss_compounds():
    losses = [0.05, 0.05, 0.05]
    r3 = combo_retained_fraction(losses)
    r1 = combo_retained_fraction(losses[:1])
    assert r3 < r1 < 1.0
    assert r3 < (1 - 0.05) ** 3 + 1e-12  # mild superadditivity


def test_gamma3_loses_more_than_gamma2():
    m = vgg11()
    l = m.layers[6]
    assert layer_variant_loss(m.name, l.name, m.redundancy, 3) > layer_variant_loss(
        m.name, l.name, m.redundancy, 2
    )


def _tight_plan(model, fps=30, platform="6k_1ws2os"):
    return build_model_plan(model, PLATFORMS[platform], deadline=1.0 / fps)


def test_variants_only_on_constrained_layers():
    plan = _tight_plan(vgg11(384))
    for idx in plan.variants:
        assert plan.budget.rho[idx] > 0


def test_variant_reduces_latency_on_excluded_accelerators():
    plan = _tight_plan(resnet50(448))
    assert plan.variants, "expected variants for resnet50@448 at 30fps"
    for idx, v in plan.variants.items():
        lat_row = plan.lat[idx]
        c_ref = plan.budget.levels[idx][plan.budget.rho[idx]]
        targets = [k for k in range(len(lat_row)) if lat_row[k] > c_ref + 1e-15]
        assert targets
        for k in targets:
            assert v.latencies[k] < lat_row[k]


def test_storage_overhead_in_paper_band():
    """Paper Sec. V-A: +0.5% to +5.9% per-model storage."""
    plan = _tight_plan(resnet50(448))
    assert 0.001 <= plan.storage_overhead <= 0.10


def test_valid_combos_downward_closed():
    plan = _tight_plan(swin_tiny(224))
    if len(plan.variants) < 2:
        pytest.skip("need >= 2 variants")
    combos = plan.valid_combos()
    valid_set = set(combos)
    assert frozenset() in valid_set
    for combo in combos:
        for i in combo:
            assert frozenset(combo - {i}) in valid_set  # subsets valid


def test_valid_combos_match_incremental_check():
    plan = _tight_plan(swin_tiny(224))
    if not plan.variants:
        pytest.skip("no variants")
    combos = set(plan.valid_combos())
    # exhaustive cross-check on small sets
    import itertools

    idxs = sorted(plan.variants)
    if len(idxs) > 12:
        idxs = idxs[:12]
    for r in range(len(idxs) + 1):
        for c in itertools.combinations(idxs, r):
            fc = frozenset(c)
            if set(fc) <= set(sorted(plan.variants)[:12]):
                in_enum = fc in combos
                ok = plan.is_valid_combo(fc)
                if not ok:
                    assert fc not in combos
                # enumerated set may include combos from the full index set;
                # only assert equivalence for the restricted universe when
                # the full universe equals the restricted one.
    if len(plan.variants) <= 12:
        for r in range(len(idxs) + 1):
            for c in itertools.combinations(idxs, r):
                assert (frozenset(c) in combos) == plan.is_valid_combo(frozenset(c))


def test_theta_one_disables_variant_use():
    """theta = 100%: no combination with any variant is valid (Fig. 6's
    rightmost point disallows all variants)."""
    plan = build_model_plan(vgg11(384), PLATFORMS["6k_1ws2os"], 1 / 30, theta=1.0)
    for idx in plan.variants:
        assert not plan.is_valid_combo(frozenset({idx}))

"""Property test: the jitted Terastal round matches the Python reference
assignment-for-assignment on randomized instances."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test-extra; skip, don't error, when absent
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.scheduler import Request, SchedView, TerastalScheduler
from repro.core.scheduler_jax import RoundInputs, pack_view, terastal_round
from repro.core.variants import ModelPlan
from repro.costmodel.dnn_zoo import DnnModel
from repro.costmodel.layers import matmul
from repro.costmodel.maestro import Accelerator, Dataflow, Platform
from repro.core.budget import distribute_budgets


def _grid(draw, st_, lo, hi, scale=256.0):
    return draw(st_.integers(lo, hi)) / scale


@st.composite
def _instances(draw):
    """Latencies/deadlines on a dyadic grid so f64(host) == f32-safe."""
    NA = draw(st.integers(1, 4))
    NJ = draw(st.integers(1, 8))
    n_layers = draw(st.integers(1, 4))
    lat = np.array(
        [[draw(st.integers(1, 64)) / 256.0 for _ in range(NA)] for _ in range(n_layers)]
    )
    plat = Platform(
        "t", tuple(Accelerator(f"a{k}", Dataflow.WS, 1024) for k in range(NA))
    )
    deadline = lat.min(axis=1).sum() * draw(st.integers(2, 8))
    budget = distribute_budgets(lat, deadline)
    layers = [matmul(f"l{i}", 8, 8, 8) for i in range(n_layers)]
    model = DnnModel("m", layers, redundancy=0.5)
    plan = ModelPlan(
        model=model, platform=plat, deadline=deadline, lat=lat, budget=budget,
        variants={}, theta=0.9,
    )
    now = 1.0
    reqs = []
    for j in range(NJ):
        arr = now - draw(st.integers(0, 64)) / 256.0
        layer = draw(st.integers(0, n_layers - 1))
        req = Request(rid=j, model_idx=0, arrival=arr, deadline_abs=arr + deadline, next_layer=layer)
        if draw(st.booleans()):
            # dynamic per-request virtual deadlines (online budget policy
            # state) on the same dyadic grid — parity must hold for these
            incs = np.array([draw(st.integers(1, 64)) / 256.0 for _ in range(n_layers)])
            req.vdl_abs = arr + np.cumsum(incs)
        reqs.append(req)
    busy = np.array([now + (draw(st.integers(-32, 32)) / 256.0 if draw(st.booleans()) else -1.0)
                     for _ in range(NA)])
    busy = np.maximum(busy, 0.0)
    return plan, reqs, busy, now


@given(_instances())
@settings(max_examples=150, deadline=None)
def test_jax_round_matches_python(inst):
    plan, reqs, busy, now = inst
    view = SchedView(now=now, ready=list(reqs), acc_busy_until=busy.copy(), plans=[plan])
    sched = TerastalScheduler()
    py = sched.schedule(view)
    py_map = {a.req.rid: (a.acc, a.use_variant) for a in py}

    view2 = SchedView(now=now, ready=list(reqs), acc_busy_until=busy.copy(), plans=[plan])
    inp, slots = pack_view(view2, sched)
    out = terastal_round(inp)
    jx_map = {}
    for i, r in enumerate(slots):
        k = int(out.assign_acc[i])
        if k >= 0:
            jx_map[r.rid] = (k, bool(out.assign_var[i]))
    assert jx_map == py_map, (jx_map, py_map)


def test_pack_view_shape_buckets_bound_recompiles():
    """pack_view pads NJ to power-of-two buckets with persistent host
    buffers, so terastal_round compiles at most once per (bucket, NA)
    per process — asserted via the jit compilation-cache counter."""
    from repro.core.scheduler_jax import BUCKET_MIN, bucket_nj

    assert bucket_nj(1) == BUCKET_MIN and bucket_nj(BUCKET_MIN) == BUCKET_MIN
    assert bucket_nj(BUCKET_MIN + 1) == 2 * BUCKET_MIN
    assert bucket_nj(9) == 16 and bucket_nj(16) == 16 and bucket_nj(17) == 32

    NA, n_layers = 2, 3
    lat = np.array([[1.0, 2.0]] * n_layers)
    plat = Platform("t", tuple(Accelerator(f"a{k}", Dataflow.WS, 1024) for k in range(NA)))
    deadline = 64.0
    budget = distribute_budgets(lat, deadline)
    model = DnnModel("m", [matmul(f"l{i}", 8, 8, 8) for i in range(n_layers)], redundancy=0.5)
    plan = ModelPlan(model=model, platform=plat, deadline=deadline, lat=lat,
                     budget=budget, variants={}, theta=0.9)
    sched = TerastalScheduler()

    def round_for(nj):
        reqs = [Request(rid=j, model_idx=0, arrival=0.0, deadline_abs=deadline,
                        next_layer=j % n_layers) for j in range(nj)]
        view = SchedView(now=1.0, ready=reqs, acc_busy_until=np.zeros(NA), plans=[plan])
        inp, slots = pack_view(view, sched)
        assert inp.lat.shape == (bucket_nj(nj), NA)
        out = terastal_round(inp)
        assert len(slots) == nj
        return out

    round_for(2)  # warm the BUCKET_MIN bucket for this NA
    base = terastal_round._cache_size()
    for nj in (1, 2, 3, 4):  # same bucket: zero new compilations
        round_for(nj)
    assert terastal_round._cache_size() == base
    round_for(5)  # next bucket: exactly one new compilation ...
    grown = terastal_round._cache_size()
    assert grown == base + 1
    for nj in (6, 7, 8):  # ... reused across the whole bucket
        round_for(nj)
    assert terastal_round._cache_size() == grown


def test_jax_round_with_variants():
    """Deterministic case exercising the variant path end-to-end."""
    from repro.core.variants import VariantInfo

    NA, n_layers = 2, 2
    lat = np.array([[1.0, 4.0], [1.0, 4.0]])
    plat = Platform("t", tuple(Accelerator(f"a{k}", Dataflow.WS, 1024) for k in range(NA)))
    deadline = 4.5
    budget = distribute_budgets(lat, deadline)
    layers = [matmul(f"l{i}", 8, 8, 8) for i in range(n_layers)]
    model = DnnModel("m", layers, redundancy=0.5)
    vlat = np.array([0.9, 0.8])
    variants = {0: VariantInfo(0, 2, "d2s", layers[0], vlat, 0.05, 10)}
    plan = ModelPlan(model=model, platform=plat, deadline=deadline, lat=lat,
                     budget=budget, variants=variants, theta=0.9)
    now = 10.0
    # acc0 busy, acc1 idle; original on acc1 misses vdl, variant makes it
    busy = np.array([now + 10.0, 0.0])
    vdl_rel = float(plan.vdl_rel[0])
    arrival = now + 2.0 - vdl_rel  # vdl_abs = now + 2.0; c_orig@1=4 > 2, c_var=0.8 < 2
    req = Request(rid=0, model_idx=0, arrival=arrival, deadline_abs=now + 100, next_layer=0)
    sched = TerastalScheduler()
    view = SchedView(now=now, ready=[req], acc_busy_until=busy.copy(), plans=[plan])
    py = sched.schedule(view)
    assert len(py) == 1 and py[0].use_variant and py[0].acc == 1
    view2 = SchedView(now=now, ready=[Request(rid=0, model_idx=0, arrival=arrival,
                                              deadline_abs=now + 100, next_layer=0)],
                      acc_busy_until=busy.copy(), plans=[plan])
    inp, slots = pack_view(view2, sched)
    out = terastal_round(inp)
    assert int(out.assign_acc[0]) == 1 and bool(out.assign_var[0])

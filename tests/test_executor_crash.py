"""TrialExecutor crash recovery: a worker pool broken by a dying trial
is rebuilt exactly once with the in-flight trials retried (never in the
parent), a second crash raises the named ExecutorCrashError, and the
sampler journal resumes bit-identically from any prefix — including one
left behind by a crashed run."""

import dataclasses
import os

import pytest

from repro.core import Campaign, ExecutorCrashError, run_trial
from repro.core.campaign import _CRASH_ENV, TrialExecutor, TrialSpec
from repro.core.sampling import SamplerConfig, run_adaptive


def _row(res):
    """TrialResult.row() minus the one nondeterministic field."""
    row = res.row()
    row.pop("wall_s", None)
    return row


def _specs(n_seeds=4):
    return [
        TrialSpec(scenario="ar_social", platform="4k_1ws2os",
                  scheduler="terastal", duration=0.2, seed=s)
        for s in range(n_seeds)
    ]


def _pooled_executor(specs):
    """A TrialExecutor with a real process pool, or None when the
    environment cannot provide one (sandboxed CI: the crash tests are
    meaningless without workers to kill — the serial fallback would run
    the self-killing trial in the parent and take pytest down with it)."""
    ex = TrialExecutor(
        cell_keys=[(s.scenario, s.platform, s.theta, s.enable_variants)
                   for s in specs],
        max_workers=2,
    )
    if ex._ensure_pool() is None:
        ex.close()
        return None
    return ex


def test_crash_hook_inert_when_unset(monkeypatch):
    monkeypatch.delenv(_CRASH_ENV, raising=False)
    res = run_trial(_specs(1)[0])
    assert res.released > 0


def test_pool_rebuilt_after_single_worker_crash(tmp_path, monkeypatch):
    """First worker to pick up a trial kills itself (atomic sentinel);
    the executor rebuilds the pool once, retries the voided trials in
    the fresh pool, and the batch completes with results identical to a
    crash-free serial run."""
    specs = _specs()
    monkeypatch.delenv(_CRASH_ENV, raising=False)
    want = [_row(run_trial(s)) for s in specs]

    ex = _pooled_executor(specs)
    if ex is None:
        pytest.skip("process pool unavailable in this environment")
    sentinel = tmp_path / "kill-once"
    monkeypatch.setenv(_CRASH_ENV, str(sentinel))
    with ex:
        with pytest.warns(UserWarning, match="rebuilding the pool"):
            results = ex.run_batch(specs)
    assert sentinel.exists()  # exactly one worker died through it
    assert ex._rebuilds == 1
    assert [_row(r) for r in results] == want


def test_second_crash_raises_named_error(tmp_path, monkeypatch):
    """REPRO_TRIAL_CRASH=always kills every worker that runs a trial:
    the one allowed rebuild crashes again and the executor surfaces the
    named ExecutorCrashError instead of retrying forever or running the
    killer trial in the parent."""
    specs = _specs(2)
    ex = _pooled_executor(specs)
    if ex is None:
        pytest.skip("process pool unavailable in this environment")
    monkeypatch.setenv(_CRASH_ENV, "always")
    with ex:
        with pytest.warns(UserWarning, match="rebuilding the pool"):
            with pytest.raises(ExecutorCrashError, match="parallel=False"):
                ex.run_batch(specs)


def test_retry_budget_env_var(tmp_path, monkeypatch):
    """REPRO_EXECUTOR_RETRIES resizes the rebuild budget: 0 fails fast
    on the first broken pool, N>1 spends N rebuilds (with backoff)
    before surfacing ExecutorCrashError."""
    specs = _specs(2)
    monkeypatch.setenv("REPRO_EXECUTOR_RETRIES", "0")
    ex = _pooled_executor(specs)
    if ex is None:
        pytest.skip("process pool unavailable in this environment")
    assert ex.max_rebuilds == 0
    monkeypatch.setenv(_CRASH_ENV, "always")
    with ex:
        with pytest.raises(ExecutorCrashError, match="0 rebuild"):
            ex.run_batch(specs)

    monkeypatch.setenv("REPRO_EXECUTOR_RETRIES", "2")
    ex = _pooled_executor(specs)
    assert ex is not None and ex.max_rebuilds == 2
    with ex:
        with pytest.warns(UserWarning, match="attempt 2/2"):
            with pytest.raises(ExecutorCrashError, match="2 rebuild"):
                ex.run_batch(specs)
    assert ex._rebuilds == 2


def test_retry_budget_env_var_validated(monkeypatch):
    from repro.core.campaign import _executor_retries
    monkeypatch.delenv("REPRO_EXECUTOR_RETRIES", raising=False)
    assert _executor_retries() == 1
    monkeypatch.setenv("REPRO_EXECUTOR_RETRIES", "3")
    assert _executor_retries() == 3
    for bad in ("-1", "two", "1.5"):
        monkeypatch.setenv("REPRO_EXECUTOR_RETRIES", bad)
        with pytest.raises(ValueError, match="non-negative integer"):
            _executor_retries()


def _sampler_campaign():
    return Campaign(
        scenarios=("ar_social",),
        platforms=("4k_1ws2os",),
        schedulers=("terastal", "edf"),
        seeds=(0, 1),
        duration=0.2,
    )


def _rows(adaptive_result):
    return [dataclasses.astuple(t.spec) + (t.mean_miss_rate, t.released,
                                           t.completed, t.dropped)
            for t in adaptive_result.trials]


def test_sampler_journal_resumes_from_any_prefix(tmp_path):
    """Kill-at-any-prefix resume: truncating the journal after any k
    completed trials and re-running serves the prefix from disk and
    re-executes only the tail — the final trial list is bit-identical
    for every k (k == n is the pure-replay case)."""
    camp, cfg = _sampler_campaign(), SamplerConfig()
    base_journal = tmp_path / "base.jsonl"
    base = run_adaptive(camp, cfg, parallel=False, journal=str(base_journal))
    want = _rows(base)
    lines = base_journal.read_text().splitlines()
    header, records = lines[0], lines[1:]
    assert len(records) == base.n_trials
    for k in range(len(records) + 1):
        path = tmp_path / f"prefix{k}.jsonl"
        path.write_text("\n".join([header] + records[:k]) + "\n")
        again = run_adaptive(camp, cfg, parallel=False, journal=str(path))
        assert _rows(again) == want, f"diverged resuming from prefix {k}"


def test_sampler_journal_truncated_tail_ignored(tmp_path):
    """A run killed mid-write leaves a torn final line; replay must stop
    at the clean prefix and heal the file rather than error."""
    camp, cfg = _sampler_campaign(), SamplerConfig()
    base_journal = tmp_path / "base.jsonl"
    base = run_adaptive(camp, cfg, parallel=False, journal=str(base_journal))
    lines = base_journal.read_text().splitlines()
    torn = tmp_path / "torn.jsonl"
    torn.write_text("\n".join(lines[:2]) + "\n" + lines[2][: len(lines[2]) // 2])
    again = run_adaptive(camp, cfg, parallel=False, journal=str(torn))
    assert _rows(again) == _rows(base)


def test_sampler_survives_worker_crash(tmp_path, monkeypatch):
    """The sampler's pooled path rides the same rebuild: one injected
    worker crash mid-campaign and the adaptive run still produces the
    crash-free trial list, with the journal intact."""
    camp, cfg = _sampler_campaign(), SamplerConfig()
    monkeypatch.delenv(_CRASH_ENV, raising=False)
    want = _rows(run_adaptive(camp, cfg, parallel=False))

    probe = _pooled_executor(camp.trials())
    if probe is None:
        pytest.skip("process pool unavailable in this environment")
    probe.close()
    sentinel = tmp_path / "kill-once"
    monkeypatch.setenv(_CRASH_ENV, str(sentinel))
    journal = tmp_path / "crashed.jsonl"
    # max_workers pinned > 1: on a single-CPU box the executor would
    # otherwise go serial and the self-killing trial would run in the
    # parent (the _pooled_executor probe above guards the same way)
    with pytest.warns(UserWarning, match="rebuilding the pool"):
        res = run_adaptive(camp, cfg, max_workers=2, journal=str(journal))
    assert sentinel.exists()
    assert _rows(res) == want
    # and the journal the crashed-then-recovered run wrote resumes clean
    monkeypatch.delenv(_CRASH_ENV)
    again = run_adaptive(camp, cfg, parallel=False, journal=str(journal))
    assert _rows(again) == want

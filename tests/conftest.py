"""Shared test configuration.

* Puts ``src/`` on ``sys.path`` so ``python -m pytest`` works with or
  without the ``PYTHONPATH=src`` prefix (CI uses the prefix; local
  one-off runs often forget it).
* Optional test extras (currently ``hypothesis``) must degrade to
  *skips*, never collection errors: every module that uses one starts
  with ``pytest.importorskip("<extra>")`` before importing it.
"""

from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

"""Per-architecture smoke tests: REDUCED same-family configs run one
forward/train step + one decode step on CPU; assert output shapes and
no NaNs.  The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.analytics import active_params, total_params
from repro.models.model_api import SHAPES, build_model
from repro.optim.adamw import OptConfig, init_opt_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _reduced_model(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    return cfg, build_model(cfg)


def _tiny_batch(cfg, B=2, L=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(rng.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg, model = _reduced_model(arch)
    params = model.init(KEY)
    batch = _tiny_batch(cfg)
    train_step = jax.jit(make_train_step(model.loss, OptConfig(warmup_steps=1, total_steps=10)))
    opt = init_opt_state(params)
    new_params, new_opt, metrics = train_step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    # loss near ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < loss < 2.0 * np.log(cfg.vocab_size)
    assert int(new_opt.step) == 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape
        assert np.isfinite(np.asarray(b, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg, model = _reduced_model(arch)
    params = model.init(KEY)
    B, L = 2, 32
    cache = model.init_cache(B, L)
    token = jnp.zeros((B,), jnp.int32)
    logits, new_cache = jax.jit(model.decode_step)(params, token, cache, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill(arch):
    cfg, model = _reduced_model(arch)
    params = model.init(KEY)
    batch = _tiny_batch(cfg)
    batch.pop("labels")
    logits = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_param_spec_tree_matches(arch):
    cfg, model = _reduced_model(arch)
    params = jax.eval_shape(model.init, KEY)
    specs = model.param_specs("train")
    # identical tree structures (will raise on mismatch)
    jax.tree.map(lambda a, b: None, params, specs,
                 is_leaf=lambda x: hasattr(x, "shape") or type(x).__name__ == "PartitionSpec")


def test_param_counts_match_published():
    """Analytic parameter totals land near the archs' advertised sizes."""
    expect = {
        "llama4-maverick-400b-a17b": (400e9, 0.35),
        "qwen3-moe-235b-a22b": (235e9, 0.25),
        "mamba2-1.3b": (1.3e9, 0.35),
        "codeqwen1.5-7b": (7e9, 0.30),
        "gemma-7b": (8.5e9, 0.25),  # gemma-7b is actually 8.5B
        "mistral-nemo-12b": (12e9, 0.30),
        "llama3.2-1b": (1.2e9, 0.35),
        "zamba2-2.7b": (2.7e9, 0.45),
        "whisper-base": (72e6, 0.7),
        "llava-next-34b": (34e9, 0.35),
    }
    for arch, (target, tol) in expect.items():
        n = total_params(get_config(arch))
        assert abs(n - target) / target < tol, (arch, n / 1e9)


def test_moe_active_params():
    cfg = get_config("llama4-maverick-400b-a17b")
    a, t = active_params(cfg), total_params(cfg)
    assert a < 0.12 * t  # ~17B active of ~400B
    cfg2 = get_config("qwen3-moe-235b-a22b")
    a2, t2 = active_params(cfg2), total_params(cfg2)
    assert 0.05 * t2 < a2 < 0.25 * t2  # ~22B of 235B

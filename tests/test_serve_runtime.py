"""LM-serving runtime smoke tests: default partitions, serving-plan
construction, a short simulation under each scheduler, and budget-policy
pass-through."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.scheduler import ALL_SCHEDULERS
from repro.runtime.serve_runtime import (
    ServingModel,
    build_serving_plan,
    decode_chunk_latency,
    default_partitions,
    serve_workload,
)


def _models():
    return [
        ServingModel(get_config("llama3.2-1b"), tokens_out=32, chunk=16, ctx_len=2048,
                     batch=8, redundancy=0.5),
        ServingModel(get_config("gemma-7b"), tokens_out=32, chunk=16, ctx_len=4096,
                     batch=8, redundancy=0.7),
    ]


def test_default_partitions_heterogeneous():
    parts = default_partitions()
    assert len(parts) == 3
    assert len({p.n_chips for p in parts}) == 2  # wide + narrow
    # the latency structure is genuinely heterogeneous: per-model preferred
    # partitions differ between a big and a small model
    small, big = _models()[0], _models()[1]
    lat_small = [decode_chunk_latency(small.cfg, p, small.chunk, small.ctx_len, small.batch)
                 for p in parts]
    lat_big = [decode_chunk_latency(big.cfg, p, big.chunk, big.ctx_len, big.batch) for p in parts]
    assert all(l > 0 for l in lat_small + lat_big)
    assert int(np.argmin(lat_small)) != int(np.argmin(lat_big))


def test_build_serving_plan_chunks_and_budgets():
    sm = _models()[0]
    parts = default_partitions()
    plan = build_serving_plan(sm, parts, deadline=1.0)
    assert plan.lat.shape == (sm.tokens_out // sm.chunk, len(parts))
    assert plan.budget.feasible
    np.testing.assert_allclose(plan.budget.budgets.sum(), 1.0, rtol=1e-9)


@pytest.mark.parametrize("name", ALL_SCHEDULERS)
def test_serve_workload_smoke_each_scheduler(name):
    models = _models()
    res = serve_workload(models, rates_fps=[4.0, 2.0], scheduler=name, duration=1.0)
    assert np.isfinite(res.mean_miss_rate)
    assert 0.0 <= res.mean_miss_rate <= 1.0
    assert all(s.released > 0 for s in res.per_model.values())
    u = res.utilization()
    assert (u >= 0).all() and (u <= 1.0 + 1e-9).all()


def test_serve_workload_budget_policy_passthrough():
    models = _models()
    kw = dict(rates_fps=[4.0, 2.0], scheduler="terastal", duration=1.0)
    ref = serve_workload(models, **kw)
    static = serve_workload(models, budget_policy="static", **kw)
    assert static.mean_miss_rate == ref.mean_miss_rate
    assert static.acc_busy_time.tolist() == ref.acc_busy_time.tolist()
    for pol in ("reclaim", "adaptive"):
        res = serve_workload(models, budget_policy=pol, **kw)
        assert np.isfinite(res.mean_miss_rate)
    with pytest.raises(KeyError, match="unknown budget policy"):
        serve_workload(models, budget_policy="slackful", **kw)


def test_serve_workload_length_mismatch_raises():
    """A dropped model used to look like a scheduling win: zip() silently
    truncated on models/rates length mismatch."""
    models = _models()
    with pytest.raises(ValueError, match="same length"):
        serve_workload(models, rates_fps=[4.0], duration=0.5)
    with pytest.raises(ValueError, match="same length"):
        serve_workload(models[:1], rates_fps=[4.0, 2.0], duration=0.5)


def test_serve_workload_admission_and_closed_loop():
    models = _models()
    kw = dict(rates_fps=[4.0, 2.0], scheduler="terastal", duration=1.0)
    ref = serve_workload(models, **kw)
    none = serve_workload(models, admission="none", **kw)
    assert none.fingerprint() == ref.fingerprint()
    shed = serve_workload(models, admission="token_bucket(rate=2,burst=1)", **kw)
    assert sum(s.shed for s in shed.per_model.values()) > 0
    closed = serve_workload(models, arrival="closed_loop(n_users=3,think_time=0.05)", **kw)
    for s in closed.per_model.values():
        assert s.released == s.completed + s.dropped + s.in_flight
    with pytest.raises(KeyError, match="unknown admission policy"):
        serve_workload(models, admission="bouncer", **kw)

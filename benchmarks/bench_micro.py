"""Microbenchmarks: scheduler/budget/kernel primitive timings on CPU.

Reports us_per_call for the hot primitives: one Terastal scheduling
round (Python and jitted JAX), Algorithm 1, the SSD chunk math, flash
attention, and the s2d_conv reference vs fused kernel (interpret mode is
correctness-only; the jnp reference timing is the CPU-meaningful one).
Also verifies the paper's Sec. IV-C claim that scheduler overhead is
lightweight relative to layer execution times.
"""

from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.budget import distribute_budgets
from repro.core.scheduler import Request, SchedView, TerastalScheduler
from repro.core.scheduler_jax import pack_view, terastal_round
from repro.core.variants import build_model_plan
from repro.costmodel.dnn_zoo import resnet50
from repro.costmodel.maestro import PLATFORMS
from repro.models.common import flash_attention
from repro.models.mamba2 import ssd_chunked


def _time(fn: Callable, n: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def run() -> List[dict]:
    rows = []
    plat = PLATFORMS["6k_1ws2os"]
    plan = build_model_plan(resnet50(448), plat, deadline=1 / 30)
    sched = TerastalScheduler()

    def mk_view(nj):
        reqs = [
            Request(rid=i, model_idx=0, arrival=-0.001 * i, deadline_abs=1 / 30 - 0.001 * i,
                    next_layer=i % 20)
            for i in range(nj)
        ]
        return SchedView(now=0.0, ready=reqs, acc_busy_until=np.zeros(plat.n_acc), plans=[plan])

    for nj in (4, 16, 64):
        view = mk_view(nj)
        us = _time(lambda: sched.schedule(SchedView(view.now, list(view.ready),
                                                    view.acc_busy_until.copy(), view.plans)))
        rows.append({"name": f"terastal_round_py_nj{nj}", "us_per_call": us,
                     "derived": f"n_acc={plat.n_acc}"})

    view = mk_view(16)
    inp, _ = pack_view(view, sched)
    terastal_round(inp)  # compile
    us = _time(lambda: jax.block_until_ready(terastal_round(inp)))
    rows.append({"name": "terastal_round_jax_nj16", "us_per_call": us, "derived": "jitted"})

    lat = plan.lat
    us = _time(lambda: distribute_budgets(lat, 1 / 30))
    rows.append({"name": "algorithm1_budget_resnet50", "us_per_call": us,
                 "derived": f"L={lat.shape[0]}"})

    # SSD chunk math
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (2, 512, 8, 64))
    la = -jnp.abs(jax.random.normal(ks[1], (2, 512, 8))) * 0.3
    B = jax.random.normal(ks[2], (2, 512, 128))
    C = jax.random.normal(ks[3], (2, 512, 128))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (2, 512, 8)))
    f = jax.jit(lambda *a: ssd_chunked(*a, 128))
    jax.block_until_ready(f(x, la, B, C, dt))
    us = _time(lambda: jax.block_until_ready(f(x, la, B, C, dt)), n=10)
    rows.append({"name": "ssd_chunked_B2_L512", "us_per_call": us, "derived": "Q=128"})

    q = jax.random.normal(ks[0], (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1024, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1024, 2, 64), jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention(q, k, v, q_chunk=256, k_chunk=256))
    jax.block_until_ready(fa(q, k, v))
    us = _time(lambda: jax.block_until_ready(fa(q, k, v)), n=10)
    rows.append({"name": "flash_attention_L1024", "us_per_call": us, "derived": "GQA 8/2"})
    return rows


def claims(rows: List[dict]):
    sched_us = next(r["us_per_call"] for r in rows if r["name"] == "terastal_round_py_nj16")
    # paper Sec. IV-C: overhead lightweight vs layer execution (~100us-1ms layers)
    return [("scheduler round lightweight vs layer latency", sched_us < 2000.0,
             f"{sched_us:.0f}us per invocation @16 ready")]

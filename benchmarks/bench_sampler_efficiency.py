"""Sampler efficiency: adaptive vs fixed-grid trial budget at matched verdicts.

Runs the pinned fig7-shaped grid (the fig7 cells x fcfs/edf/dream/
terastal x the arrival-burstiness ladder) twice: once as the fixed
seed grid every figure used before this PR, once through the sequential
adaptive sampler (``repro.core.sampling``), and scores the sampler on
the only two axes that matter:

* **Matched verdicts** — for every (cell, arrival, scheduler) comparison
  the adaptive winner (sign of the paired mean miss-rate gap at stop)
  must equal the fixed grid's winner over the full seed ladder.  A
  sampler that saves trials by changing answers saved nothing.
* **Trials saved** — the fraction of the fixed grid's trial budget the
  sampler left unspent.  The enforced floor is ``MIN_SAVED`` (30%): the
  fig7 grid mixes seed-invariant periodic cells (retired after
  ``min_seeds`` replicates), wide bursty gaps (separated early), and
  genuinely hard near-tie cells (run to the cap), so the floor holds
  only if the stopping rule actually discriminates between them.

Writes ``BENCH_sampler.json`` at the repo root — the next point on the
perf trajectory after ``BENCH_campaign.json`` (PR 3 made trials ~3.3x
cheaper; this PR makes campaigns need fewer of them).  CI runs this in
--smoke mode and uploads the JSON as an artifact; the committed file is
a full-mode measurement.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.core import Campaign
from repro.core.campaign import _plans_for
from repro.core.sampling import SamplerConfig, fixed_grid_verdicts, run_adaptive

#: trials-saved floor enforced by claims() — see module docstring.
MIN_SAVED = 0.30

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_sampler.json")


def run(duration: float = None) -> List[dict]:
    from benchmarks._scale import bench_duration, bench_mode
    from benchmarks.fig7_arrival_robustness import ARRIVAL_LADDER, CELLS, SCHEDULERS

    mode = bench_mode()
    duration = bench_duration(duration, smoke=0.4, fast=1.5, full=3.0)
    if mode == "smoke":
        # same 8-seed cap as full mode: the cap is what the sampler saves
        # against, so shrinking it squeezes the smoke savings below the
        # floor for free — shrink the grid, not the ladder
        cells, schedulers, seeds = CELLS[:1], ("fcfs", "edf", "terastal"), range(8)
        arrivals = ("periodic", "poisson", "mmpp(burstiness=8)")
    else:
        cells, schedulers, seeds = CELLS, SCHEDULERS, range(8)
        arrivals = tuple(spec for _, spec in ARRIVAL_LADDER)
    config = SamplerConfig(baseline="terastal")

    for sc, pn in cells:  # warm the offline plans out of the timed region
        _plans_for(sc, pn, 0.90, True)

    wall: Dict[str, float] = {}
    campaigns = [
        Campaign(
            scenarios=(sc,),
            platforms=(pn,),
            schedulers=schedulers,
            arrivals=arrivals,
            seeds=tuple(seeds),
            duration=duration,
        )
        for sc, pn in cells
    ]

    t0 = time.perf_counter()
    fixed = [c.run() for c in campaigns]
    wall["fixed"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    adaptive = [run_adaptive(c, config) for c in campaigns]
    wall["adaptive"] = time.perf_counter() - t0

    fixed_winner = {
        (v.group, v.scheduler): v.winner
        for res in fixed
        for v in fixed_grid_verdicts(res, baseline=config.baseline)
    }
    verdict_rows = []
    n_matched = 0
    for ares in adaptive:
        for v in ares.verdicts:
            want = fixed_winner[(v.group, v.scheduler)]
            matched = v.winner == want
            n_matched += matched
            verdict_rows.append(
                {**v.row(), "fixed_winner": want, "matched": matched}
            )

    n_fixed = sum(len(c.trials()) for c in campaigns)
    n_adaptive = sum(a.n_trials for a in adaptive)
    saved = 1.0 - n_adaptive / n_fixed
    by_reason: Dict[str, int] = {}
    for a in adaptive:
        for v in a.verdicts:
            by_reason[v.reason] = by_reason.get(v.reason, 0) + 1

    summary = {
        "benchmark": "sampler_efficiency",
        "mode": mode,
        "grid": {
            "cells": [list(c) for c in cells],
            "schedulers": list(schedulers),
            "arrivals": list(arrivals),
            "seeds": list(seeds),
            "duration": duration,
        },
        "sampler": {
            "baseline": config.baseline,
            "min_seeds": config.min_seeds,
            "round_seeds": config.round_seeds,
            "alpha": config.alpha,
        },
        "trials_fixed": n_fixed,
        "trials_adaptive": n_adaptive,
        "trials_saved_pct": round(100 * saved, 2),
        "min_saved_enforced_pct": round(100 * MIN_SAVED, 2),
        "verdicts_total": len(verdict_rows),
        "verdicts_matched": n_matched,
        "verdicts_by_reason": by_reason,
        "wall_s": {k: round(v, 3) for k, v in wall.items()},
        "verdicts": verdict_rows,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")
    return [
        {
            "trials_fixed": n_fixed,
            "trials_adaptive": n_adaptive,
            "trials_saved_pct": summary["trials_saved_pct"],
            "verdicts_matched": f"{n_matched}/{len(verdict_rows)}",
            "verdicts_by_reason": by_reason,
            "wall_fixed_s": summary["wall_s"]["fixed"],
            "wall_adaptive_s": summary["wall_s"]["adaptive"],
            "json": JSON_PATH,
        }
    ]


def claims(rows: List[dict]):
    r = rows[0]
    matched, total = (int(x) for x in r["verdicts_matched"].split("/"))
    return [
        ("adaptive sampler reaches the fixed grid's winner verdict in every "
         "(cell x arrival x scheduler) comparison",
         matched == total, f"{r['verdicts_matched']} matched"),
        (f"adaptive sampler runs >= {100 * MIN_SAVED:.0f}% fewer trials than "
         "the fixed seed grid",
         r["trials_saved_pct"] >= 100 * MIN_SAVED,
         f"{r['trials_adaptive']}/{r['trials_fixed']} trials = "
         f"{r['trials_saved_pct']}% saved"),
    ]


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid / short horizon (CI artifact mode)")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    sys.path.insert(0, _ROOT)  # make the `benchmarks` package importable
    rows = run()
    for r in rows:
        print(json.dumps(r))
    checks = claims(rows)
    n_ok = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} ({detail})")
        n_ok += bool(ok)
    if n_ok < len(checks) and not args.smoke:
        sys.exit(1)

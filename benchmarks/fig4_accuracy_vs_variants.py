"""Fig. 4: normalized accuracy vs number of applied layer variants —
mean and min-max band over all combinations of the same size."""

from __future__ import annotations

import itertools
from typing import Dict, List

import numpy as np

from repro.core.variants import build_model_plan
from repro.costmodel.dnn_zoo import get_model
from repro.costmodel.maestro import PLATFORMS

MODELS_FPS = {
    "resnet50": (448, 30),
    "vgg11": (384, 30),
    "swin_tiny": (224, 30),
    "mobilenetv2_ssd": (512, 60),
    "inceptionv3": (299, 15),
    "sp2dense": (224, 30),
}


def run(platform: str = "6k_1ws2os", max_variants: int = 12) -> List[dict]:
    plat = PLATFORMS[platform]
    rows = []
    for name, (res, fps) in MODELS_FPS.items():
        model = get_model(name)
        model = type(model)(**{**model.__dict__, "layers": get_model(name).layers})
        # rebuild at the scenario resolution
        from repro.costmodel import dnn_zoo

        model = getattr(dnn_zoo, name)(res)
        plan = build_model_plan(model, plat, deadline=1.0 / fps, theta=0.0)
        idxs = sorted(plan.variants)[:max_variants]
        for n in range(0, min(len(idxs), 6) + 1):
            rets = [
                plan.combo_retained(frozenset(c))
                for c in itertools.combinations(idxs, n)
            ]
            if not rets:
                continue
            rows.append({
                "model": name,
                "n_variants": n,
                "mean_retained": float(np.mean(rets)),
                "min_retained": float(np.min(rets)),
                "max_retained": float(np.max(rets)),
                "n_combos": len(rets),
            })
    return rows


def claims(rows: List[dict]):
    by_model: Dict[str, List[dict]] = {}
    for r in rows:
        by_model.setdefault(r["model"], []).append(r)
    # monotone degradation with more variants
    mono = all(
        all(a["mean_retained"] >= b["mean_retained"] - 1e-9
            for a, b in zip(sorted(v, key=lambda x: x["n_variants"]),
                            sorted(v, key=lambda x: x["n_variants"])[1:]))
        for v in by_model.values()
    )
    # redundant models (resnet50/swin) degrade slower than vgg11
    def drop_at(m, n=2):
        rs = [r for r in by_model.get(m, []) if r["n_variants"] == n]
        return 1 - rs[0]["mean_retained"] if rs else None

    d_r50, d_vgg = drop_at("resnet50"), drop_at("vgg11")
    redundant_ok = d_r50 is not None and d_vgg is not None and d_r50 < d_vgg
    return [
        ("accuracy degrades monotonically with #variants", mono, ""),
        ("redundant archs (resnet50) more robust than vgg11", redundant_ok,
         f"2-variant loss r50={d_r50} vgg={d_vgg}"),
    ]

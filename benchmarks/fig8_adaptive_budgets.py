"""Fig. 8 (beyond-paper): online budget policies under bursty arrivals.

Fig. 7 showed that Terastal's offline virtual budgets — calibrated for
periodic releases — leave headroom under bursty MMPP arrivals.  This
campaign sweeps the fig7 burstiness ladder x {static, reclaim, adaptive}
budget policies x every scheduler, with bootstrap CIs over seeds:

* ``static`` is the paper (offline Algorithm-1 budgets, frozen);
* ``reclaim`` pushes early-finish slack into downstream layer budgets;
* ``adaptive`` gates that reclamation on detected release bursts and on
  per-layer accelerator skew, with controller ticks restoring any
  reclaimed chain the burst has outrun (see repro.core.budget_online).

Only budget-using schedulers can react (FCFS/EDF/DREAM and the
no-budgeting ablation never read virtual deadlines), so the baselines
double as an invariance check: their rows must be identical across
policies.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core import Campaign

from benchmarks.fig7_arrival_robustness import ARRIVAL_LADDER, CELLS

SCHEDULERS = ("fcfs", "edf", "dream", "terastal")
POLICIES = ("static", "reclaim", "adaptive")
MMPP_SPECS = tuple(spec for b, spec in ARRIVAL_LADDER if spec.startswith("mmpp"))


def run(duration: float = None, seeds=tuple(range(8)), adaptive: bool = None) -> List[dict]:
    from benchmarks._scale import bench_adaptive, bench_duration, bench_mode, run_campaign

    mode = bench_mode()
    adaptive = bench_adaptive(adaptive)
    duration = bench_duration(duration, smoke=0.4, fast=1.0, full=3.0)
    if mode == "smoke":
        seeds = (0, 1)  # >= 2: aggregate()'s CIs refuse degenerate samples
    elif mode == "fast":
        seeds = (0, 1, 2)
    cells = CELLS[:1] if mode == "smoke" else CELLS
    burst_of = {spec: b for b, spec in ARRIVAL_LADDER}
    rows: List[dict] = []
    for sc, pn in cells:
        camp = Campaign(
            scenarios=(sc,),
            platforms=(pn,),
            schedulers=SCHEDULERS,
            arrivals=tuple(spec for _, spec in ARRIVAL_LADDER),
            budget_policies=POLICIES,
            seeds=tuple(seeds),
            duration=duration,
        )
        result = run_campaign(camp, adaptive)
        by = ("scenario", "platform", "scheduler", "arrival", "budget_policy")
        for agg in result.aggregate(by=by):
            rows.append({
                "scenario": agg["scenario"],
                "platform": agg["platform"],
                "scheduler": agg["scheduler"],
                "budget_policy": agg["budget_policy"],
                "arrival": agg["arrival"],
                "burstiness": burst_of[agg["arrival"]],
                "miss_rate_pct": 100 * agg["mean_miss_rate"],
                "ci_lo_pct": 100 * agg["mean_miss_rate_ci_lo"],
                "ci_hi_pct": 100 * agg["mean_miss_rate_ci_hi"],
                "n_trials": agg["n_trials"],
            })
    return rows


def _mean(rows: List[dict]) -> float:
    return float(np.mean([r["miss_rate_pct"] for r in rows]))


def claims(rows: List[dict]):
    cells = sorted({(r["scenario"], r["platform"]) for r in rows})
    n_expected = len(cells) * len(SCHEDULERS) * len(ARRIVAL_LADDER) * len(POLICIES)
    ci_sane = all(
        r["ci_lo_pct"] - 1e-9 <= r["miss_rate_pct"] <= r["ci_hi_pct"] + 1e-9 for r in rows
    )

    def pick(sched: str, policy: str, arrivals: Tuple[str, ...] = None) -> List[dict]:
        return [
            r for r in rows
            if r["scheduler"] == sched and r["budget_policy"] == policy
            and (arrivals is None or r["arrival"] in arrivals)
        ]

    # baselines never read virtual deadlines: policy rows must be identical
    invariant = all(
        pick(s, "static")[i]["miss_rate_pct"] == pick(s, pol)[i]["miss_rate_pct"]
        for s in ("fcfs", "edf", "dream")
        for pol in ("reclaim", "adaptive")
        for i in range(len(pick(s, "static")))
    )

    # the headline: online adaptation closes part of the fig7 burstiness
    # gap — adaptive Terastal below static Terastal on the MMPP ladder
    t_static_mmpp = _mean(pick("terastal", "static", MMPP_SPECS))
    t_adaptive_mmpp = _mean(pick("terastal", "adaptive", MMPP_SPECS))

    # aggregate over the whole ladder: adaptive never pays a net penalty
    t_static_all = _mean(pick("terastal", "static"))
    t_adaptive_all = _mean(pick("terastal", "adaptive"))

    # adaptive terastal still beats every conventional baseline everywhere
    base_mmpp = {s: _mean(pick(s, "static", MMPP_SPECS)) for s in ("fcfs", "edf", "dream")}

    return [
        ("full (cell x scheduler x arrival x policy) grid covered with sane CIs",
         len(rows) == n_expected and ci_sane, f"{len(rows)}/{n_expected} rows"),
        ("budget policies leave non-budget schedulers bit-identical",
         invariant, "fcfs/edf/dream rows equal across static/reclaim/adaptive"),
        ("adaptive terastal beats static terastal on the MMPP ladder",
         t_adaptive_mmpp < t_static_mmpp,
         f"adaptive {t_adaptive_mmpp:.2f}% vs static {t_static_mmpp:.2f}%"),
        ("adaptive terastal no worse than static over the full ladder",
         t_adaptive_all <= t_static_all + 1e-9,
         f"adaptive {t_adaptive_all:.2f}% vs static {t_static_all:.2f}%"),
        ("adaptive terastal beats every conventional baseline on the MMPP ladder",
         all(t_adaptive_mmpp < v for v in base_mmpp.values()),
         f"adaptive terastal {t_adaptive_mmpp:.2f}% vs "
         + ", ".join(f"{s} {v:.2f}%" for s, v in base_mmpp.items())),
    ]

"""Beyond-paper: Terastal as LM serving controller on mesh partitions —
multi-model deadline serving with FCFS/EDF/DREAM/Terastal on the
analytic TPU latency model (see repro.runtime.serve_runtime)."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.configs import get_config
from repro.core.scheduler import ALL_SCHEDULERS
from repro.runtime.serve_runtime import ServingModel, serve_workload


def _mix():
    return [
        ServingModel(get_config("llama3.2-1b"), tokens_out=64, chunk=16, ctx_len=2048,
                     batch=8, redundancy=0.5),
        ServingModel(get_config("gemma-7b"), tokens_out=64, chunk=16, ctx_len=4096,
                     batch=8, redundancy=0.7),
        ServingModel(get_config("mistral-nemo-12b"), tokens_out=64, chunk=16,
                     ctx_len=8192, batch=8, redundancy=0.7),
        ServingModel(get_config("qwen3-moe-235b-a22b"), tokens_out=64, chunk=16,
                     ctx_len=4096, batch=4, redundancy=0.85),
    ]


def _calibrated_rates(models, shares=(0.9, 0.7, 0.55, 0.45)):
    """fps such that each model's min-latency demand is `share` of one
    partition and its own deadline has ~30% headroom — feasible for all,
    contended on the preferred (wide) slice."""
    from repro.runtime.serve_runtime import build_serving_plan, default_partitions

    parts = default_partitions()
    rates = []
    for sm, share in zip(models, shares):
        probe = build_serving_plan(sm, parts, deadline=10.0, enable_variants=False)
        min_sum = float(probe.min_lat.sum())
        fps = min(share / min_sum, 1.0 / (min_sum * 1.3))
        rates.append(round(fps, 1))
    return rates


def run(duration: float = None) -> List[dict]:
    from benchmarks._scale import bench_duration

    duration = bench_duration(duration, smoke=0.5, fast=2.0, full=5.0)
    models = _mix()
    rates = _calibrated_rates(models)
    rows = []
    for name in ALL_SCHEDULERS:
        res = serve_workload(models, rates, scheduler=name, duration=duration)
        plans_losses = [s.mean_norm_accuracy_loss for s in res.per_model.values() if s.completed]
        rows.append({
            "scheduler": name,
            "miss_rate_pct": 100 * res.mean_miss_rate,
            "acc_loss_pct": 100 * float(np.mean(plans_losses)) if plans_losses else 0.0,
            "util": float(np.mean(res.utilization())),
        })
    return rows


def claims(rows: List[dict]):
    by = {r["scheduler"]: r["miss_rate_pct"] for r in rows}
    return [
        ("terastal <= conventional baselines on LM serving",
         by["terastal"] <= min(by["fcfs"], by["edf"], by["dream"]) + 1e-9,
         f"terastal={by['terastal']:.1f}% fcfs={by['fcfs']:.1f}% edf={by['edf']:.1f}% dream={by['dream']:.1f}%"),
    ]

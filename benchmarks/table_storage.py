"""Sec. V-A storage-overhead claim: variants add 0.5%-5.9% per model."""

from __future__ import annotations

from typing import List

from repro.core.workload import SCENARIOS
from repro.costmodel.maestro import PLATFORMS


def run() -> List[dict]:
    rows = []
    seen = set()
    for sc in SCENARIOS.values():
        plat = PLATFORMS[sc.platform_names[0]]
        plans, _ = sc.plans(plat)
        for e, p in zip(sc.entries, plans):
            key = (p.model.name, sc.name)
            if key in seen or not p.variants:
                continue
            seen.add(key)
            rows.append({
                "model": p.model.name,
                "scenario": sc.name,
                "n_variants": len(p.variants),
                "storage_overhead_pct": 100 * p.storage_overhead,
            })
    return rows


def claims(rows: List[dict]):
    vals = [r["storage_overhead_pct"] for r in rows]
    ok = bool(vals) and max(vals) < 10.0 and min(vals) > 0.0
    return [("storage overhead modest (paper: 0.5-5.9%)", ok,
             f"ours: {min(vals):.2f}-{max(vals):.2f}%" if vals else "no variants")]

"""Scheduler-round throughput: rounds/sec vs ready-queue depth (NJ).

The deep-queue regime (saturation scenarios: overloaded multi-camera
cells, 3-8x offered load, mixed release processes) is where the
scheduling round itself — not event bookkeeping — bounds campaign
throughput.  This benchmark measures one Terastal round at controlled
queue depths for all three kernel implementations:

* ``scalar`` — the pre-existing interpreted kernel
  (``engine_soa._kern_terastal``), the "current kernel" baseline;
* ``vec`` — the vectorized deep-round kernel
  (``engine_soa._kern_terastal_vec``), what ``REPRO_ROUND_KERNEL=python``
  dispatches to above ``VEC_MIN_NJ``;
* ``jax`` — the jitted ``scheduler_jax.terastal_round`` through the
  engine's ``_jax_round`` staging path (``REPRO_ROUND_KERNEL=jax``).

Round states are *captured from real saturation trials* (block clones
snapshotted mid-simulation at target depths), so the instance mix —
idle-accelerator counts, stage-2 frequency, variant availability — is
the true deep-queue distribution, not a synthetic best case.  All three
kernels are re-run on identical clones; outputs are asserted equal
instance-by-instance, and a full-simulation differential section pins
``SimResult`` equality (reference engine vs SoA x round kernels x
backfill modes) on fig5/fig7/fig8-shaped cells and the saturation grid.

The python->jax crossover for ``REPRO_ROUND_KERNEL=auto`` is measured
here (the smallest depth where the jitted round beats the vectorized
one) and recorded in the JSON; on CPU-only hosts per-call dispatch
(~1ms) keeps it at infinity — auto == python — which is an honest
negative result, not a wiring gap.  ``REPRO_ROUND_CROSSOVER`` pins it
manually on hosts where the measurement differs.

Writes ``BENCH_round.json``.  CI runs ``--smoke`` as a dedicated step
that FAILS on a floor regression (unlike the informational run.py smoke
claims): aggregate vec rounds/sec over deep rounds (NJ >= 64) must stay
>= MIN_DEEP_SPEEDUP x the scalar kernel.  Honest per-NJ scorecard: the
vectorized round has a ~13us flat numpy-dispatch floor, so the 3x line
is crossed between NJ ~ 64 and 96 (~2.6x at exactly 64, ~4-7x at
96-256); the aggregate over the saturation depth mix clears 3x with
margin because deep rounds cluster well past 64.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

#: aggregate deep-round speedup floor enforced by claims() — and by CI
#: even in --smoke mode (see module docstring).
MIN_DEEP_SPEEDUP = 3.0

#: queue depths measured (instances are captured at these exact NJ).
BUCKETS = (16, 24, 32, 48, 64, 96, 128, 192, 256)
DEEP_MIN_NJ = 64  # buckets >= this enter the enforced aggregate

SATURATION_CELLS = (
    ("saturation_3x", "4k_1ws2os"),
    ("saturation_5x", "4k_1ws2os"),
    ("saturation_8x", "4k_1ws2os"),
    ("saturation_8x", "6k_1ws2os"),
)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_round.json")


# ------------------------------------------------------ state capture ----


def _capture_instances(buckets, per_bucket: int, duration: float, seeds):
    """Clone real mid-trial round states at the target depths by running
    the saturation cells with the vectorized kernel forced on (so the
    deep mirrors exist in every captured clone)."""
    from repro.core import engine_soa
    from repro.core.campaign import _plans_for
    from repro.core.scheduler import make_scheduler
    from repro.core.simulator import simulate

    targets: Dict[int, List[tuple]] = {nj: [] for nj in buckets}
    want = set(buckets)
    orig = engine_soa._kern_terastal_vec

    def capture(B, now, busy, idle_mask, n_idle, mode):
        n = B.n
        if n in want and len(targets[n]) < per_bucket:
            targets[n].append((B.clone(), now, list(busy), idle_mask, n_idle, mode))
        return orig(B, now, busy, idle_mask, n_idle, mode)

    old_env = os.environ.get("REPRO_ROUND_VEC_MIN")
    os.environ["REPRO_ROUND_VEC_MIN"] = "2"
    engine_soa._kern_terastal_vec = capture
    try:
        for sc, pn in SATURATION_CELLS:
            plans, tasks = _plans_for(sc, pn, 0.90, True)
            for seed in seeds:
                simulate(plans, tasks, duration, make_scheduler("terastal"),
                         seed=seed, engine="soa", round_kernel="python")
    finally:
        engine_soa._kern_terastal_vec = orig
        if old_env is None:
            del os.environ["REPRO_ROUND_VEC_MIN"]
        else:
            os.environ["REPRO_ROUND_VEC_MIN"] = old_env
    return {nj: inst for nj, inst in targets.items() if inst}


# ------------------------------------------------------------ timing ----


def _time_kernel(fn, instances, reps: int) -> float:
    """Mean microseconds per round over the captured instance mix."""
    t0 = time.perf_counter()
    for _ in range(reps):
        for args in instances:
            fn(*args)
    return (time.perf_counter() - t0) / (reps * len(instances)) * 1e6


def _measure(targets, reps: int, with_jax: bool):
    from repro.core import engine_soa

    rows = []
    for nj in sorted(targets):
        inst = targets[nj]
        # identical outputs on every captured instance, all kernels
        for B, now, busy, idle_mask, n_idle, mode in inst:
            a = engine_soa._kern_terastal(B, now, busy, idle_mask, n_idle, mode)
            b = engine_soa._kern_terastal_vec(B, now, busy, idle_mask, n_idle, mode)
            assert a == b, f"scalar/vec round mismatch at NJ={nj}"
        t_scalar = _time_kernel(engine_soa._kern_terastal, inst, reps)
        t_vec = _time_kernel(engine_soa._kern_terastal_vec, inst, reps)
        row = {
            "nj": nj,
            "instances": len(inst),
            "us_scalar": round(t_scalar, 1),
            "us_vec": round(t_vec, 1),
            "speedup_vec": round(t_scalar / t_vec, 2),
        }
        if with_jax:
            jx = [(B, now, busy, idle_mask, len(busy), mode)
                  for B, now, busy, idle_mask, n_idle, mode in inst]
            for (B, now, busy, idle_mask, n_idle, mode), ja in zip(inst, jx):
                got = engine_soa._jax_round(*ja)  # also warms the bucket
                ref = engine_soa._kern_terastal(B, now, busy, idle_mask,
                                                n_idle, mode)
                assert got == ref, f"jax round mismatch at NJ={nj}"
            t_jax = _time_kernel(engine_soa._jax_round, jx, max(1, reps // 10))
            row["us_jax"] = round(t_jax, 1)
            row["speedup_jax"] = round(t_scalar / t_jax, 2)
        rows.append(row)
    return rows


def _aggregate_deep(rows) -> Optional[float]:
    """Aggregate rounds/sec ratio over the deep buckets (NJ >= 64),
    weighting each bucket's instance mix equally: total scalar time /
    total vec time across one pass of every deep instance."""
    deep = [r for r in rows if r["nj"] >= DEEP_MIN_NJ]
    if not deep:
        return None
    t_s = sum(r["us_scalar"] * r["instances"] for r in deep)
    t_v = sum(r["us_vec"] * r["instances"] for r in deep)
    return round(t_s / t_v, 2)


# ------------------------------------------------- simulation parity ----


def _differential(small: bool, with_jax: bool):
    """SimResult equality: reference engine vs SoA x round kernels, both
    backfill-mode ablations, on fig-shaped and saturation cells."""
    from repro.core.campaign import _plans_for
    from repro.core.scheduler import make_scheduler
    from repro.core.simulator import make_arrival_process, simulate

    cells = [
        ("ar_gaming_heavy", "6k_1ws2os", "periodic", 0.5),
        ("multicam_light", "4k_1ws2os", "mmpp(burstiness=8)", 0.5),
        ("saturation_5x", "4k_1ws2os", None, 0.5),
    ]
    scheds = ["terastal", "terastal(backfill_mode=paper)",
              "terastal(backfill_mode=positive)"]
    if not small:
        cells += [
            ("ar_social", "4k_1ws2os", "poisson", 0.6),
            ("multicam_heavy", "6k_1ws2os", "mmpp(burstiness=4)", 0.6),
            ("saturation_8x", "6k_1ws2os", None, 0.8),
        ]
        scheds += ["terastal_no_variants", "terastal_no_budgeting"]
    kernels = ["python"] + (["jax"] if with_jax else [])
    checked = 0
    for sc, pn, arr, dur in cells:
        plans, tasks = _plans_for(sc, pn, 0.90, True)
        procs = [make_arrival_process(arr)] * len(tasks) if arr else None
        for sched in scheds:
            ref = simulate(
                plans, tasks, dur, make_scheduler(sched), seed=0,
                processes=procs, engine="reference").fingerprint()
            for kern in kernels:
                got = simulate(
                    plans, tasks, dur, make_scheduler(sched), seed=0,
                    processes=procs, engine="soa",
                    round_kernel=kern).fingerprint()
                if got != ref:
                    return checked, False, f"{sc}/{sched}/{kern}"
                checked += 1
    return checked, True, ""


# --------------------------------------------------------------- run ----


def run(duration: float = None) -> List[dict]:
    from benchmarks._scale import bench_duration, bench_mode
    from repro.core import engine_soa

    mode = bench_mode()
    smoke = mode == "smoke"
    duration = bench_duration(duration, smoke=1.0, fast=1.5, full=2.5)
    buckets = {"smoke": (32, 64, 96, 128),
               "fast": (24, 48, 64, 96, 128, 192)}.get(mode, BUCKETS)
    per_bucket = {"smoke": 8, "fast": 12}.get(mode, 24)
    reps = {"smoke": 30, "fast": 60}.get(mode, 120)
    seeds = (0, 1) if mode == "full" else (0,)
    # the jitted-round path needs jax; measure it except when a host
    # explicitly opts out (keeps the bench usable on jax-less builds)
    with_jax = not os.environ.get("REPRO_BENCH_NO_JAX")

    targets = _capture_instances(buckets, per_bucket, duration, seeds)
    rows = _measure(targets, reps, with_jax)
    agg = _aggregate_deep(rows)

    # python->jax crossover for REPRO_ROUND_KERNEL=auto: the smallest
    # measured depth where the jitted round wins; +inf when it never does
    crossover: Optional[float] = None
    if with_jax:
        wins = [r["nj"] for r in rows
                if "us_jax" in r and r["us_jax"] < r["us_vec"]]
        crossover = float(min(wins)) if wins else float("inf")
        engine_soa.set_round_crossover(crossover)

    n_diff, identical, where = _differential(mode != "full", with_jax)

    summary = {
        "benchmark": "scheduler_round",
        "mode": mode,
        "grid": {
            "cells": [list(c) for c in SATURATION_CELLS],
            "buckets": list(targets),
            "per_bucket": per_bucket,
            "capture_duration": duration,
            "seeds": list(seeds),
        },
        "buckets": rows,
        "aggregate_deep_speedup_vec": agg,
        "deep_min_nj": DEEP_MIN_NJ,
        "min_deep_speedup_enforced": MIN_DEEP_SPEEDUP,
        "jax_crossover_nj": (None if crossover is None
                             else ("inf" if crossover == float("inf")
                                   else crossover)),
        "differential": {"simulations": n_diff, "bit_identical": identical,
                         "first_mismatch": where},
    }
    with open(JSON_PATH, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")
    return rows + [{
        "aggregate_deep_speedup_vec": agg,
        "jax_crossover_nj": summary["jax_crossover_nj"],
        "bit_identical": identical,
        "differential_simulations": n_diff,
        "first_mismatch": where,
        "json": JSON_PATH,
    }]


def claims(rows: List[dict]):
    tail = rows[-1]
    agg = tail["aggregate_deep_speedup_vec"]
    return [
        (f"vectorized round >= {MIN_DEEP_SPEEDUP}x rounds/sec over the "
         f"scalar kernel at NJ >= {DEEP_MIN_NJ} (saturation instance mix)",
         agg is not None and agg >= MIN_DEEP_SPEEDUP,
         f"aggregate {agg}x over deep buckets"),
        ("SimResults bit-identical: reference vs SoA x round kernels x "
         "backfill modes",
         bool(tail["bit_identical"]),
         f"{tail['differential_simulations']} simulations compared"
         + (f"; first mismatch {tail.get('first_mismatch')}" if not
            tail["bit_identical"] else "")),
    ]


def check_json(path: str = JSON_PATH):
    """Apply the floor/bit-identity claims to an already-written
    BENCH_round.json (e.g. the one run.py --smoke just produced) without
    re-measuring — the CI gate step, so the capture + timing +
    differential pipeline runs once per job, not twice."""
    with open(path) as f:
        summary = json.load(f)
    tail = {
        "aggregate_deep_speedup_vec": summary["aggregate_deep_speedup_vec"],
        "jax_crossover_nj": summary.get("jax_crossover_nj"),
        "bit_identical": summary["differential"]["bit_identical"],
        "differential_simulations": summary["differential"]["simulations"],
        "first_mismatch": summary["differential"].get("first_mismatch"),
    }
    return claims([tail])


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid; unlike run.py --smoke, the speedup "
                    "floor and bit-identity still FAIL the process (the CI "
                    "regression gate)")
    ap.add_argument("--check-json", action="store_true",
                    help="validate the claims against the existing "
                    f"{os.path.basename(JSON_PATH)} instead of re-measuring")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    sys.path.insert(0, _ROOT)  # make the `benchmarks` package importable
    if args.check_json:
        checks = check_json()
    else:
        out = run()
        for r in out:
            print(json.dumps(r))
        checks = claims(out)
    n_ok = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} ({detail})")
        n_ok += bool(ok)
    if n_ok < len(checks):
        sys.exit(1)

"""Fig. 5: average per-model deadline miss rate — all hardware settings
x scenarios x schedulers (the paper's headline table).

Runs through the Monte-Carlo campaign engine with the strictly periodic
arrival process, which reproduces the seed's serial loop bit-for-bit
per seed (pinned by tests/test_campaign.py) while executing trials in
parallel across cores.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import Campaign
from repro.core.workload import SCENARIOS


def run(duration: float = None, seeds=(0, 1, 2), adaptive: bool = None) -> List[dict]:
    from benchmarks._scale import bench_adaptive, bench_duration, bench_mode, run_campaign

    adaptive = bench_adaptive(adaptive)
    duration = bench_duration(duration, smoke=0.5, fast=2.0, full=5.0)
    if bench_mode() != "full":
        # the sampler needs >= 2 paired replicates to decide anything;
        # the fixed smoke path keeps the seed pin (regression oracle)
        seeds = (0, 1) if adaptive else (0,)
    result = run_campaign(
        Campaign(
            scenarios=tuple(SCENARIOS),  # platforms=None -> Table-I pairings
            arrivals=("periodic",),
            seeds=tuple(seeds),
            duration=duration,
        ),
        adaptive,
    )
    rows = []
    for (sc, pn, name), ts in result.grouped(("scenario", "platform", "scheduler")).items():
        miss = [t.mean_miss_rate for t in ts]
        acc = [t.mean_accuracy_loss for t in ts]
        rows.append({
            "scenario": sc,
            "platform": pn,
            "scheduler": name,
            "miss_rate_pct": 100 * float(np.mean(miss)),
            "acc_loss_pct": 100 * float(np.mean(acc)),
        })
    return rows


def claims(rows: List[dict]):
    agg: Dict[str, List[float]] = {}
    accs: Dict[str, List[float]] = {}
    for r in rows:
        agg.setdefault(r["scheduler"], []).append(r["miss_rate_pct"])
        accs.setdefault(r["scheduler"], []).append(r["acc_loss_pct"])
    mean = {k: float(np.mean(v)) for k, v in agg.items()}
    t = mean["terastal"]

    def red(b):
        return 100 * (mean[b] - t) / mean[b] if mean[b] > 0 else 0.0

    out = [
        (f"terastal reduces miss rate vs fcfs (paper: 40.58%)", t < mean["fcfs"],
         f"ours: {red('fcfs'):.1f}%"),
        (f"terastal reduces miss rate vs edf (paper: 30.53%)", t < mean["edf"],
         f"ours: {red('edf'):.1f}%"),
        (f"terastal reduces miss rate vs dream (paper: 36.27%)", t < mean["dream"],
         f"ours: {red('dream'):.1f}%"),
        ("no-variants beats all conventional baselines",
         mean["terastal_no_variants"] < min(mean["fcfs"], mean["edf"], mean["dream"]),
         f"{mean['terastal_no_variants']:.2f}% vs {min(mean['fcfs'], mean['edf'], mean['dream']):.2f}%"),
        ("full terastal beats no-variants (variants add benefit)",
         t <= mean["terastal_no_variants"],
         f"{t:.2f}% vs {mean['terastal_no_variants']:.2f}%"),
        ("no-budgeting worse than both budgeted versions",
         mean["terastal_no_budgeting"] > t
         and mean["terastal_no_budgeting"] > mean["terastal_no_variants"],
         f"{mean['terastal_no_budgeting']:.2f}%"),
        ("accuracy loss small (paper: 2.24% avg)", float(np.mean(accs["terastal"])) < 8.0,
         f"ours: {float(np.mean(accs['terastal'])):.2f}%"),
    ]
    return out

"""Ablation: Algorithm 2 stage-2 backfill guard interpretations.

The paper's text assigns argmax-Delta-s unconditionally ("paper" mode);
we found that measurably hurts (it eagerly blocks slow accelerators with
non-preferred layers), and ship the earliest-finish-optimality guard
("ef", DESIGN.md §7 / scheduler.py docstring).  This benchmark justifies
that reading empirically across the full Fig.5 matrix.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.scheduler import TerastalScheduler
from repro.core.simulator import simulate
from repro.core.workload import scenario_platform_pairs

MODES = ("paper", "positive", "ef")


def run(duration: float = None) -> List[dict]:
    from benchmarks._scale import bench_duration

    duration = bench_duration(duration, smoke=0.5, fast=2.0, full=4.0)
    agg = {m: [] for m in MODES}
    for sc, plat in scenario_platform_pairs():
        plans, tasks = sc.plans(plat)
        for mode in MODES:
            sched = TerastalScheduler(backfill_mode=mode)
            res = simulate(plans, tasks, duration, sched, seed=0)
            agg[mode].append(res.mean_miss_rate)
    return [
        {"backfill_mode": m, "mean_miss_rate_pct": 100 * float(np.mean(v))}
        for m, v in agg.items()
    ]


def claims(rows: List[dict]):
    by = {r["backfill_mode"]: r["mean_miss_rate_pct"] for r in rows}
    return [
        ("EF-guarded backfill beats the literal unconditional reading",
         by["ef"] < by["paper"],
         f"ef={by['ef']:.2f}% paper={by['paper']:.2f}% positive={by['positive']:.2f}%"),
    ]

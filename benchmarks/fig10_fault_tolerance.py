"""Fig. 10: fault tolerance — accelerator fault injection and
variant-based graceful degradation.

The fault axis (``repro.core.faults``) resolves deterministic capability
faults — transient dropout, thermal throttling, permanent failure,
seed-derived intermittent outages — into timestamped down/up/scale
events that both bit-parity engines merge into their event heaps.  A
down accelerator's latency columns go ``+inf`` and its in-flight layer
is evicted and re-enqueued (``interrupted=restart|resume``); a throttled
one costs ``factor`` x nominal.  Every scheduler sees the same masked
tables, but only variant-enabled Terastal holds the graceful-degradation
lever: when the surviving columns are the slow ones, swapping in layer
variants shrinks the latency gap and keeps virtual deadlines met.

Measures the FAULT_SCENARIOS catalog (dropout / rolling brownout /
flash-crowd-plus-permanent-failure) x schedulers x the ``faults`` grid
axis ("scenario" = the cell's own injection vs "none" = the fault-free
counterfactual), reporting miss rate, accuracy loss, the degraded-mode
``service_quality`` metric, and the eviction/remap accounting.  Two
bit-identity gates ride along: the fault-off path must reproduce the
pre-PR fingerprints captured before the fault axis existed (both
engines), and reference-vs-SoA must stay fingerprint-identical WITH
faults active.

Writes ``BENCH_faults.json``.  CI runs ``--smoke`` as a dedicated step
that FAILS on the separation claim: on the pinned dropout cell,
variant-enabled Terastal must beat its no-variant ablation by
>= MIN_SEPARATION_PTS miss-rate points (the PR's headline deliverable —
the variant lever is what degrades gracefully), and both identity gates
must hold.
"""

from __future__ import annotations

import argparse
import json
import math
import os
from typing import List, Optional, Tuple

import numpy as np

#: miss-rate separation floor (percentage points) on the gate cell:
#: variant-enabled terastal vs the terastal_no_variants ablation, both
#: under the cell's own fault injection — enforced by claims() and by
#: the CI gate even in --smoke mode.
MIN_SEPARATION_PTS = 5.0

#: the (scenario, platform) cell the separation claim is gated on.
GATE_CELL = ("fault_dropout", "6k_1ws2os")

#: the ablation pair the separation is measured between.
GATE_SCHEDULERS = ("terastal", "terastal_no_variants")

SCHEDULERS = ("terastal", "terastal_no_variants", "edf", "dream", "fcfs")

#: fault windows land at absolute times inside the horizon (the dropout
#: outage spans [0.5, 1.5), the brownout wave sweeps through 1.7s), so
#: the horizon is pinned rather than mode-scaled; smoke shrinks the grid
#: (gate cell only, fewer schedulers/seeds) instead.
DURATION = 2.0

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_faults.json")


def _nan_to_none(x: Optional[float]) -> Optional[float]:
    """NaN is not valid JSON; the honest-metric contract serializes it
    as null (paired with models_counted == 0)."""
    if x is None or (isinstance(x, float) and math.isnan(x)):
        return None
    return float(x)


# ------------------------------------------------------------- grids ----


def _campaign_rows(scenarios, duration, seeds,
                   schedulers=SCHEDULERS) -> List[dict]:
    from repro.core import Campaign
    from repro.core.accuracy import service_quality

    camp = Campaign(
        scenarios=tuple(scenarios),
        platforms=(GATE_CELL[1],),
        schedulers=tuple(schedulers),
        faults=("scenario", "none"),
        seeds=tuple(seeds),
        duration=duration,
    )
    result = camp.run()
    rows = []
    grouped = result.grouped(("scenario", "scheduler", "faults"))
    for (sc, sched, flt), ts in grouped.items():
        miss = float(np.mean([t.mean_miss_rate for t in ts]))
        acc = [t.mean_accuracy_loss for t in ts
               if not math.isnan(t.mean_accuracy_loss)]
        mean_acc = float(np.mean(acc)) if acc else float("nan")
        rows.append({
            "scenario": sc,
            "platform": GATE_CELL[1],
            "scheduler": sched,
            "faults": flt,
            "miss_rate_pct": 100 * miss,
            "acc_loss_pct": _nan_to_none(100 * mean_acc),
            "service_quality": service_quality(miss, mean_acc),
            "models_counted": ts[0].models_counted,
            "released": sum(t.released for t in ts),
            "completed": sum(t.completed for t in ts),
            "dropped": sum(t.dropped for t in ts),
            "evicted": sum(t.evicted for t in ts),
            "remapped": sum(t.remapped for t in ts),
            "seeds": len(ts),
        })
    return rows


def _separation(rows: List[dict], scenario: str) -> Tuple[Optional[dict],
                                                          float]:
    """(terastal_row, separation_pts): no-variant-ablation miss rate
    minus variant-enabled miss rate, both under the cell's faults."""
    mine = {r["scheduler"]: r for r in rows
            if r["scenario"] == scenario and r["faults"] == "scenario"
            and r["scheduler"] in GATE_SCHEDULERS}
    full = mine.get("terastal")
    ablated = mine.get("terastal_no_variants")
    if full is None or ablated is None:
        return None, float("-inf")
    return full, ablated["miss_rate_pct"] - full["miss_rate_pct"]


# -------------------------------------------- fault-off bit-identity ----


def _fault_off_identity() -> Tuple[int, bool, Optional[str]]:
    """Re-simulate every pre-PR pinned cell with the fault machinery in
    place (but no faults) and demand the exact pre-PR fingerprints on
    both engines — the new per-model evicted/remapped counters and the
    faulted_spans field are projected off and must all be zero."""
    import sys

    sys.path.insert(0, os.path.join(_ROOT, "tests"))
    from data_pre_pr8_fingerprints import PRE_PR8_FINGERPRINTS

    from repro.core import get_scenario, make_scheduler, simulate
    from repro.costmodel.maestro import PLATFORMS

    n = 0
    for key, old in sorted(PRE_PR8_FINGERPRINTS.items()):
        scenario, platform, arrival, duration, sched, adm, engine = key
        sc = get_scenario(scenario)
        plans, tasks = sc.plans(
            PLATFORMS[platform],
            arrival=None if arrival == "scenario" else arrival,
        )
        res = simulate(plans, tasks, duration, make_scheduler(sched),
                       seed=0, processes=[t.arrival for t in tasks],
                       admission=adm, engine=engine)
        name, rounds, bt, bh, per, fsp = res.fingerprint()
        got = (name, rounds, bt, bh, {m: tuple(v[:8]) for m, v in per.items()})
        want = (old[0], old[1], old[2], old[3],
                {m: tuple(v) for m, v in old[4].items()})
        zeroed = fsp == 0 and all(v[8] == 0 and v[9] == 0
                                  for v in per.values())
        n += 1
        if got != want or not zeroed:
            return n, False, f"{scenario}/{sched}/{adm}/{engine}"
    return n, True, None


# ------------------------------------------------------ differential ----


def _differential(smoke: bool) -> Tuple[int, bool, Optional[str]]:
    """Reference vs SoA fingerprints with faults ACTIVE: the catalog
    cells under their own injections plus explicit compound specs
    (eviction + throttle re-timing, resume vs restart, intermittent
    renewal) on the paper scenarios."""
    from repro.core import get_scenario, make_scheduler, simulate
    from repro.core.campaign import _plans_for

    def catalog(name):
        return get_scenario(name).faults

    cases = [
        ("fault_dropout", "6k_1ws2os", "terastal", catalog("fault_dropout"),
         1.0),
        ("multicam_heavy", "6k_1ws2os", "edf",
         "intermittent(acc=1,rate=6.0,mean_down=0.08)", 0.8),
    ]
    if not smoke:
        cases += [
            ("fault_dropout", "6k_1ws2os", "terastal",
             catalog("fault_dropout"), DURATION),
            ("fault_brownout", "6k_1ws2os", "terastal_no_variants",
             catalog("fault_brownout"), DURATION),
            ("fault_flash_crowd", "6k_1os2ws", "terastal",
             catalog("fault_flash_crowd"), 1.5),
            ("multicam_heavy", "4k_1ws2os", "dream",
             "down(acc=0,start=0.1,duration=0.3,interrupted=resume)"
             "+throttle(acc=2,start=0.2,duration=0.4,factor=2.5)", 1.0),
            ("ar_social", "4k_1ws2os", "fcfs", "permanent(acc=1,start=0.2)",
             1.0),
        ]
    n = 0
    for scenario, platform, sched, faults, dur in cases:
        plans, tasks = _plans_for(scenario, platform, 0.90, True)
        procs = [t.arrival for t in tasks]
        fps = []
        for engine in ("reference", "soa"):
            res = simulate(plans, tasks, dur, make_scheduler(sched), seed=0,
                           processes=procs, faults=faults, engine=engine)
            fps.append(res.fingerprint())
        n += 1
        if fps[0] != fps[1]:
            return n, False, f"{scenario}/{sched}/{faults}"
    return n, True, None


# --------------------------------------------------------------- run ----


def run(duration: float = None, seeds=(0, 1, 2)) -> List[dict]:
    from benchmarks._scale import bench_mode

    mode = bench_mode()
    smoke = mode == "smoke"
    duration = duration or DURATION
    if mode != "full":
        seeds = (0,) if smoke else (0, 1)
    scenarios = ((GATE_CELL[0],) if smoke
                 else ("fault_dropout", "fault_brownout",
                       "fault_flash_crowd"))
    schedulers = (GATE_SCHEDULERS + ("edf",)) if smoke else SCHEDULERS
    rows = _campaign_rows(scenarios, duration, seeds, schedulers)

    gate_row, sep = _separation(rows, GATE_CELL[0])
    n_pins, off_ok, off_where = _fault_off_identity()
    n_diff, identical, where = _differential(smoke)

    summary = {
        "benchmark": "fault_tolerance",
        "mode": mode,
        "grid": {
            "fault_scenarios": list(scenarios),
            "platform": GATE_CELL[1],
            "schedulers": list(schedulers),
            "faults_axis": ["scenario", "none"],
            "duration": duration,
            "seeds": list(seeds),
        },
        "rows": rows,
        "separation": {
            "cell": list(GATE_CELL),
            "schedulers": list(GATE_SCHEDULERS),
            "terastal_miss_pct": gate_row["miss_rate_pct"] if gate_row
            else None,
            "separation_pts": sep if sep != float("-inf") else None,
            "min_enforced_pts": MIN_SEPARATION_PTS,
        },
        "fault_off_identity": {"simulations": n_pins, "bit_identical": off_ok,
                               "first_mismatch": off_where},
        "differential": {"simulations": n_diff, "bit_identical": identical,
                         "first_mismatch": where},
    }
    with open(JSON_PATH, "w") as f:
        json.dump(summary, f, indent=2, allow_nan=False)
        f.write("\n")
    return rows + [{
        "separation_pts": summary["separation"]["separation_pts"],
        "terastal_miss_pct": summary["separation"]["terastal_miss_pct"],
        "fault_off_identical": off_ok,
        "fault_off_simulations": n_pins,
        "fault_off_first_mismatch": off_where,
        "bit_identical": identical,
        "differential_simulations": n_diff,
        "first_mismatch": where,
        "json": JSON_PATH,
    }]


def claims(rows: List[dict]):
    tail = rows[-1]
    grid = rows[:-1]
    sep = tail["separation_pts"]
    faulted = [r for r in grid if r["faults"] == "scenario"]
    clean = [r for r in grid if r["faults"] == "none"]
    acct_ok = (
        all(r["remapped"] <= r["evicted"] for r in grid)
        and all(r["evicted"] == 0 and r["remapped"] == 0 for r in clean)
        and any(r["evicted"] > 0 for r in faulted
                if r["scenario"] == GATE_CELL[0])
    )
    # faults must actually hurt on the gate cell: the fault-free
    # counterfactual of the SAME (scenario, scheduler) can't miss more
    damage_ok = all(
        f["miss_rate_pct"] >= c["miss_rate_pct"] - 1e-9
        for f in faulted for c in clean
        if (c["scenario"], c["scheduler"]) == (f["scenario"], f["scheduler"])
        and f["scenario"] == GATE_CELL[0]
    )
    return [
        (f"variant-enabled terastal beats its no-variant ablation on "
         f"{GATE_CELL[0]} by >= {MIN_SEPARATION_PTS} miss-rate points "
         "under the outage",
         sep is not None and sep >= MIN_SEPARATION_PTS,
         f"terastal={tail['terastal_miss_pct']:.1f}% "
         f"separation={sep:.1f} pts"
         if sep is not None else "no separation measured"),
        ("fault-off path is bit-identical to the pre-PR simulator "
         "(both engines, pre-PR fingerprint pins)",
         bool(tail["fault_off_identical"]),
         f"{tail['fault_off_simulations']} pinned cells reproduced"
         + ("" if tail["fault_off_identical"]
            else f"; first mismatch {tail.get('fault_off_first_mismatch')}")),
        ("SimResults bit-identical: reference vs SoA with faults active "
         "(eviction, re-timing, resume, intermittent)",
         bool(tail["bit_identical"]),
         f"{tail['differential_simulations']} simulations compared"
         + ("" if tail["bit_identical"]
            else f"; first mismatch {tail.get('first_mismatch')}")),
        ("fault accounting is honest: remapped <= evicted everywhere, "
         "fault-free rows evict nothing, and the outage actually hurts",
         acct_ok and damage_ok,
         f"{sum(r['evicted'] for r in grid)} evictions / "
         f"{sum(r['remapped'] for r in grid)} remaps across the grid"),
    ]


def check_json(path: str = JSON_PATH):
    """Apply the separation/bit-identity claims to an already-written
    BENCH_faults.json (e.g. the one run.py --smoke just produced)
    without re-measuring — the CI gate step."""
    with open(path) as f:
        summary = json.load(f)
    tail = {
        "separation_pts": summary["separation"]["separation_pts"],
        "terastal_miss_pct": summary["separation"]["terastal_miss_pct"],
        "fault_off_identical": summary["fault_off_identity"]["bit_identical"],
        "fault_off_simulations": summary["fault_off_identity"]["simulations"],
        "fault_off_first_mismatch":
            summary["fault_off_identity"].get("first_mismatch"),
        "bit_identical": summary["differential"]["bit_identical"],
        "differential_simulations": summary["differential"]["simulations"],
        "first_mismatch": summary["differential"].get("first_mismatch"),
    }
    return claims(summary["rows"] + [tail])


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid; unlike run.py --smoke, the separation "
                    "floor and both bit-identity gates still FAIL the "
                    "process (the CI regression gate)")
    ap.add_argument("--check-json", action="store_true",
                    help="validate the claims against the existing "
                    f"{os.path.basename(JSON_PATH)} instead of re-measuring")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    sys.path.insert(0, _ROOT)  # make the `benchmarks` package importable
    if args.check_json:
        checks = check_json()
    else:
        out = run()
        for r in out:
            print(json.dumps(r))
        checks = claims(out)
    n_ok = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} ({detail})")
        n_ok += bool(ok)
    if n_ok < len(checks):
        sys.exit(1)

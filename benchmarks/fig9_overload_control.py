"""Fig. 9: overload control — admission/shedding policies on the
saturation grid, plus closed-loop client traffic.

The saturation family (``saturation_{3,5,8}x``) is where every scheduler
collapses to 0.79-0.95 miss rate: under 5x offered load most requests
execute a few layers, age in a deep ready queue, and are early-dropped
mid-chain, so over half the accelerator cycles are spent on work that is
then thrown away.  The admission axis (``repro.core.admission``) decides
at the release door instead; a shed request still counts released +
missed + dropped (+ shed), so shedding can never flatter the miss rate —
it wins only by letting the admitted requests actually complete on time.

Measures the campaign grid (saturation cells x schedulers x admission
policies x seeds) and the overload catalog (diurnal rate curve, flash
crowd, two-tier SLO mix, closed-loop saturation — closed-loop releases
gate on completions inside both engines), reports the per-model mean
miss rate JOINTLY with the honest accuracy-loss metric
(``models_counted`` flags zero-completion exclusions; NaN — serialized
as null — when no variant-bearing model completed anything), and runs a
ref-vs-SoA differential with admission + closed-loop active.

Writes ``BENCH_overload.json``.  CI runs ``--smoke`` as a dedicated step
that FAILS on the separation claim: the best admission policy must beat
plain Terastal's per-model mean miss rate on ``saturation_5x`` by
>= MIN_SEPARATION_PTS points (the PR's headline deliverable), and the
engines must stay bit-identical.
"""

from __future__ import annotations

import argparse
import json
import math
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

#: miss-rate separation floor (percentage points) on saturation_5x:
#: best admission policy vs admission="none", same scheduler — enforced
#: by claims() and by the CI gate even in --smoke mode.
MIN_SEPARATION_PTS = 5.0

#: the cell the separation claim is gated on.
GATE_CELL = ("saturation_5x", "4k_1ws2os")

#: admission-policy grid axis ("none" is the baseline every separation
#: is measured against).
ADMISSIONS = (
    "none",
    "shed_early(margin=2.5)",
    "token_bucket(rate=80,burst=8)",
)

SCHEDULERS = ("terastal", "terastal(backfill_mode=paper)", "edf")

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_overload.json")


def _nan_to_none(x: Optional[float]) -> Optional[float]:
    """NaN is not valid JSON; the honest-metric contract serializes it
    as null (paired with models_counted == 0)."""
    if x is None or (isinstance(x, float) and math.isnan(x)):
        return None
    return float(x)


# ------------------------------------------------------------- grids ----


def _campaign_rows(scenarios, duration, seeds, schedulers=SCHEDULERS,
                   admissions=ADMISSIONS) -> List[dict]:
    from repro.core import Campaign

    camp = Campaign(
        scenarios=tuple(scenarios),
        platforms=("4k_1ws2os",),
        schedulers=tuple(schedulers),
        admissions=tuple(admissions),
        seeds=tuple(seeds),
        duration=duration,
    )
    result = camp.run()
    rows = []
    grouped = result.grouped(("scenario", "scheduler", "admission"))
    for (sc, sched, adm), ts in grouped.items():
        miss = [t.mean_miss_rate for t in ts]
        counted = ts[0].models_counted
        acc = [t.mean_accuracy_loss for t in ts if not math.isnan(t.mean_accuracy_loss)]
        rows.append({
            "scenario": sc,
            "platform": "4k_1ws2os",
            "scheduler": sched,
            "admission": adm,
            "miss_rate_pct": 100 * float(np.mean(miss)),
            "acc_loss_pct": _nan_to_none(
                100 * float(np.mean(acc)) if acc else float("nan")),
            "models_counted": counted,
            "released": sum(t.released for t in ts),
            "completed": sum(t.completed for t in ts),
            "shed": sum(t.shed for t in ts),
            "dropped": sum(t.dropped for t in ts),
            "seeds": len(ts),
        })
    return rows


def _separation(rows: List[dict], scenario: str,
                scheduler: str = "terastal") -> Tuple[Optional[dict], float]:
    """(best_row, separation_pts) of the best admission policy vs
    admission="none" for one (scenario, scheduler)."""
    mine = [r for r in rows
            if r["scenario"] == scenario and r["scheduler"] == scheduler]
    base = next((r for r in mine if r["admission"] == "none"), None)
    cands = [r for r in mine if r["admission"] != "none"]
    if base is None or not cands:
        return None, float("-inf")
    best = min(cands, key=lambda r: r["miss_rate_pct"])
    return best, base["miss_rate_pct"] - best["miss_rate_pct"]


# ------------------------------------------------------ differential ----


def _differential(smoke: bool) -> Tuple[int, bool, Optional[str]]:
    """Reference vs SoA fingerprints with the new machinery active:
    admission policies on saturation cells and closed-loop / mixed
    traffic from the overload catalog."""
    from repro.core import make_scheduler, simulate
    from repro.core.campaign import _plans_for

    cases = [
        ("saturation_5x", "terastal", "shed_early(margin=2.5)"),
        ("saturation_5x", "terastal", "token_bucket(rate=80,burst=8)"),
        ("overload_closed_loop", "terastal", "none"),
        ("overload_flash", "terastal", "token_bucket(rate=80,burst=8)"),
    ]
    if not smoke:
        cases += [
            ("saturation_8x", "terastal(backfill_mode=paper)",
             "shed_early(margin=2.5)"),
            ("saturation_3x", "edf", "token_bucket(rate=80,burst=8)"),
            ("overload_diurnal", "terastal", "shed_early(margin=2.5)"),
            ("overload_two_tier", "terastal", "shed_early(margin=2.5)"),
        ]
    dur = 0.4 if smoke else 1.0
    n = 0
    for scenario, sched, adm in cases:
        plans, tasks = _plans_for(scenario, "4k_1ws2os", 0.90, True)
        procs = [t.arrival for t in tasks]
        fps = []
        for engine in ("reference", "soa"):
            res = simulate(plans, tasks, dur, make_scheduler(sched), seed=0,
                           processes=procs, admission=adm, engine=engine)
            fps.append(res.fingerprint())
        n += 1
        if fps[0] != fps[1]:
            return n, False, f"{scenario}/{sched}/{adm}"
    return n, True, None


# --------------------------------------------------------------- run ----


def run(duration: float = None, seeds=(0, 1, 2)) -> List[dict]:
    from benchmarks._scale import bench_duration, bench_mode

    mode = bench_mode()
    smoke = mode == "smoke"
    duration = bench_duration(duration, smoke=0.5, fast=1.0, full=2.0)
    if mode != "full":
        seeds = (0, 1)
    sat_cells = (GATE_CELL[0],) if smoke else ("saturation_3x",
                                               "saturation_5x",
                                               "saturation_8x")
    rows = _campaign_rows(sat_cells, duration, seeds)
    # overload catalog: closed-loop + diurnal + flash + two-tier, plain
    # vs best-shedding Terastal (entries pin their own arrival processes)
    overload_names = (("overload_closed_loop", "overload_flash") if smoke
                      else ("overload_closed_loop", "overload_flash",
                            "overload_diurnal", "overload_two_tier"))
    rows += _campaign_rows(overload_names, duration, seeds,
                           schedulers=("terastal",),
                           admissions=("none", "shed_early(margin=2.5)"))

    best, sep = _separation(rows, GATE_CELL[0])
    n_diff, identical, where = _differential(smoke)

    summary = {
        "benchmark": "overload_control",
        "mode": mode,
        "grid": {
            "saturation_cells": list(sat_cells),
            "overload_scenarios": list(overload_names),
            "platform": "4k_1ws2os",
            "schedulers": list(SCHEDULERS),
            "admissions": list(ADMISSIONS),
            "duration": duration,
            "seeds": list(seeds),
        },
        "rows": rows,
        "separation": {
            "cell": list(GATE_CELL),
            "scheduler": "terastal",
            "best_admission": best["admission"] if best else None,
            "separation_pts": sep if sep != float("-inf") else None,
            "min_enforced_pts": MIN_SEPARATION_PTS,
        },
        "differential": {"simulations": n_diff, "bit_identical": identical,
                         "first_mismatch": where},
    }
    with open(JSON_PATH, "w") as f:
        json.dump(summary, f, indent=2, allow_nan=False)
        f.write("\n")
    return rows + [{
        "best_admission": summary["separation"]["best_admission"],
        "separation_pts": summary["separation"]["separation_pts"],
        "bit_identical": identical,
        "differential_simulations": n_diff,
        "first_mismatch": where,
        "json": JSON_PATH,
    }]


def claims(rows: List[dict]):
    tail = rows[-1]
    grid = rows[:-1]
    sep = tail["separation_pts"]
    shed_rows = [r for r in grid if r["admission"] != "none"]
    acct_ok = all(r["shed"] <= r["dropped"] for r in grid) and any(
        r["shed"] > 0 for r in shed_rows)
    # honest metric: no saturated row may pair a 0.0 loss with a zero
    # models_counted denominator — zero-completion cells report null
    honest_ok = all(
        (r["acc_loss_pct"] is None) == (r["models_counted"] == 0)
        for r in grid)
    return [
        (f"admission control beats plain terastal on {GATE_CELL[0]} by "
         f">= {MIN_SEPARATION_PTS} miss-rate points",
         sep is not None and sep >= MIN_SEPARATION_PTS,
         f"best={tail['best_admission']} separation={sep:.1f} pts"
         if sep is not None else "no separation measured"),
        ("shed accounting is honest: shed <= dropped everywhere and the "
         "shedding policies actually shed",
         acct_ok,
         f"{sum(r['shed'] for r in grid)} requests shed across the grid"),
        ("accuracy loss is reported jointly with models_counted "
         "(zero-completion cells -> null, never a flattering 0.0)",
         honest_ok,
         f"{sum(1 for r in grid if r['acc_loss_pct'] is None)} null-loss "
         f"rows of {len(grid)}"),
        ("SimResults bit-identical: reference vs SoA with admission + "
         "closed-loop active",
         bool(tail["bit_identical"]),
         f"{tail['differential_simulations']} simulations compared"
         + ("" if tail["bit_identical"]
            else f"; first mismatch {tail.get('first_mismatch')}")),
    ]


def check_json(path: str = JSON_PATH):
    """Apply the separation/bit-identity claims to an already-written
    BENCH_overload.json (e.g. the one run.py --smoke just produced)
    without re-measuring — the CI gate step."""
    with open(path) as f:
        summary = json.load(f)
    tail = {
        "best_admission": summary["separation"]["best_admission"],
        "separation_pts": summary["separation"]["separation_pts"],
        "bit_identical": summary["differential"]["bit_identical"],
        "differential_simulations": summary["differential"]["simulations"],
        "first_mismatch": summary["differential"].get("first_mismatch"),
    }
    return claims(summary["rows"] + [tail])


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid; unlike run.py --smoke, the separation "
                    "floor and bit-identity still FAIL the process (the CI "
                    "regression gate)")
    ap.add_argument("--check-json", action="store_true",
                    help="validate the claims against the existing "
                    f"{os.path.basename(JSON_PATH)} instead of re-measuring")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    sys.path.insert(0, _ROOT)  # make the `benchmarks` package importable
    if args.check_json:
        checks = check_json()
    else:
        out = run()
        for r in out:
            print(json.dumps(r))
        checks = claims(out)
    n_ok = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} ({detail})")
        n_ok += bool(ok)
    if n_ok < len(checks):
        sys.exit(1)

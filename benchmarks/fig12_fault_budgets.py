"""Fig. 12: fault-aware budget re-tightening and degraded-capacity
admission — what closing the faults x {DAG, batch} gates buys.

PR 10's tentpole: on every capability event (down/up/throttle/restore)
the simulator re-runs the Algorithm-1 tightening kernel over the
*effective* latency tables (``retighten=true`` on the fault spec),
rebinds every in-flight request's virtual-deadline chain, and recomputes
the admission layer's work estimates from degraded capacity.  The
frozen-nominal alternative keeps the offline chains and admission
tables through the outage: virtual deadlines then promise capacity that
is not there, variants engage too late, and ``shed_early`` admits work
the degraded platform can never finish — every one of those admissions
evicts budget from a request that could have made it.

Measures the pinned long-brownout cell (a 4x thermal throttle covering
70% of the horizon on the lead accelerator of ``saturation_3x``, under
Terastal + ``shed_early``) with ``retighten=true`` vs the
frozen-nominal ``retighten=false``, plus a companion grid (down-outage
and throttle variants, admission on/off) for context.  Three identity
gates ride along, one per gate this PR lifts:

* reference vs SoA stays fingerprint-identical on the gate cell with
  re-tightening active (the re-tightening hook is bit-parity code);
* the batch engine runs restart-policy fault cells end-to-end and
  matches the SoA fingerprints (the faults x batch gate — only
  ``interrupted=resume`` remains host-only);
* faults compose with DAG plans end-to-end (the faults x DAG gate),
  reference vs SoA identical on the ``fault_dag_dropout`` catalog cell.

Writes ``BENCH_fault_budgets.json``.  CI runs ``--smoke`` as a
dedicated step that FAILS unless re-tightening + degraded admission
beats frozen-nominal by >= MIN_SEPARATION_PTS miss-rate points on the
pinned cell and all three identity gates hold.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List, Optional, Tuple

import numpy as np

#: miss-rate separation floor (percentage points) on the gate cell:
#: frozen-nominal miss rate minus re-tightened miss rate, same seeds,
#: same admission policy — enforced by claims() and the CI gate even in
#: --smoke mode.  Measured headroom: ~10-14 pts per seed.
MIN_SEPARATION_PTS = 5.0

#: the pinned long-outage cell the separation claim is gated on: a 4x
#: thermal throttle on the lead accelerator covering [0.2, 1.6) of a
#: 2.0s horizon, Terastal + shed_early admission.
GATE_CELL = ("saturation_3x", "4k_1ws2os")
GATE_FAULT = "throttle(acc=0,start=0.2,duration=1.4,factor=4.0,retighten={rt})"
GATE_ADMISSION = "shed_early(margin=1.5)"
GATE_SCHEDULER = "terastal"

#: fault windows land at absolute times inside the horizon, so the
#: horizon is pinned rather than mode-scaled; smoke shrinks seeds and
#: the companion grid instead.
DURATION = 2.0

#: companion grid: the same re-tightening lever under a hard outage and
#: without admission, for the mechanism decomposition.
GRID_FAULTS = {
    "throttle4x": GATE_FAULT,
    "down": "down(acc=0,start=0.2,duration=1.4,retighten={rt})",
}
GRID_ADMISSIONS = ("none", GATE_ADMISSION)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_fault_budgets.json")


# ------------------------------------------------------------- grids ----


def _grid_rows(seeds, faults_grid) -> List[dict]:
    from repro.core import make_scheduler, simulate
    from repro.core.campaign import _plans_for

    scenario, platform = GATE_CELL
    plans, tasks = _plans_for(scenario, platform, 0.90, True)
    procs = [t.arrival for t in tasks]
    rows = []
    for fname, ftmpl in faults_grid.items():
        for adm in GRID_ADMISSIONS:
            for rt in ("false", "true"):
                per_seed = []
                evicted = remapped = shed = released = completed = 0
                for s in seeds:
                    res = simulate(
                        plans, tasks, DURATION,
                        make_scheduler(GATE_SCHEDULER), seed=s,
                        processes=procs,
                        admission=None if adm == "none" else adm,
                        faults=ftmpl.format(rt=rt), engine="soa",
                    )
                    per_seed.append(100 * res.mean_miss_rate)
                    for st in res.per_model.values():
                        evicted += st.evicted
                        remapped += st.remapped
                        shed += st.shed
                        released += st.released
                        completed += st.completed
                rows.append({
                    "scenario": scenario,
                    "platform": platform,
                    "scheduler": GATE_SCHEDULER,
                    "fault": fname,
                    "admission": adm,
                    "retighten": rt == "true",
                    "miss_rate_pct": float(np.mean(per_seed)),
                    "miss_rate_per_seed_pct": [round(m, 4) for m in per_seed],
                    "released": released,
                    "completed": completed,
                    "shed": shed,
                    "evicted": evicted,
                    "remapped": remapped,
                    "seeds": len(seeds),
                })
    return rows


def _separation(rows: List[dict]) -> Optional[float]:
    """Frozen-nominal minus re-tightened miss rate on the gate config
    (throttle4x fault, shed_early admission)."""
    gate = {r["retighten"]: r for r in rows
            if r["fault"] == "throttle4x" and r["admission"] == GATE_ADMISSION}
    if True not in gate or False not in gate:
        return None
    return gate[False]["miss_rate_pct"] - gate[True]["miss_rate_pct"]


# --------------------------------------------------- identity gates -----


def _gate_ref_vs_soa() -> Tuple[int, bool, Optional[str]]:
    """Reference vs SoA on the gate cell, re-tightening active — the
    re-tightening hook, rebinding, and degraded admission are bit-parity
    code on both scalar engines."""
    from repro.core import make_scheduler, simulate
    from repro.core.campaign import _plans_for

    scenario, platform = GATE_CELL
    plans, tasks = _plans_for(scenario, platform, 0.90, True)
    procs = [t.arrival for t in tasks]
    n = 0
    for rt in ("true", "false"):
        fps = []
        for engine in ("reference", "soa"):
            res = simulate(
                plans, tasks, DURATION, make_scheduler(GATE_SCHEDULER),
                seed=0, processes=procs, admission=GATE_ADMISSION,
                faults=GATE_FAULT.format(rt=rt), engine=engine,
            )
            fps.append(res.fingerprint())
        n += 1
        if fps[0] != fps[1]:
            return n, False, f"retighten={rt}"
    return n, True, None


def _gate_batch_parity(smoke: bool) -> Tuple[int, bool, Optional[str]]:
    """The faults x batch gate: restart-policy fault cells run on device
    and match the SoA fingerprints seed by seed."""
    from repro.core import get_scenario, make_scheduler, simulate
    from repro.core.campaign import _plans_for
    from repro.core.engine_batch import simulate_batch

    cases = [
        ("fault_dropout", "6k_1ws2os", "terastal",
         get_scenario("fault_dropout").faults, 1.0),
        ("multicam_heavy", "6k_1ws2os", "edf",
         "intermittent(acc=1,rate=8.0,mean_down=0.05,retighten=true)", 0.6),
    ]
    if not smoke:
        cases += [
            ("fault_brownout", "6k_1os2ws", "terastal",
             get_scenario("fault_brownout").faults, DURATION),
            ("saturation_3x", "4k_1ws2os", "terastal",
             "throttle(acc=0,start=0.2,duration=1.4,factor=4.0,"
             "retighten=true)", DURATION),
        ]
    seeds = [0] if smoke else [0, 1]
    n = 0
    for scenario, platform, sched, faults, dur in cases:
        plans, tasks = _plans_for(scenario, platform, 0.90, True)
        procs = [t.arrival for t in tasks]
        batch = simulate_batch(plans, tasks, dur, make_scheduler(sched),
                               seeds=seeds, processes=procs, faults=faults)
        for s, bres in zip(seeds, batch):
            sres = simulate(plans, tasks, dur, make_scheduler(sched), seed=s,
                            processes=procs, faults=faults, engine="soa")
            n += 1
            if bres.fingerprint() != sres.fingerprint():
                return n, False, f"{scenario}/{sched}/seed={s}"
    return n, True, None


def _gate_dag_faults() -> Tuple[int, bool, Optional[str], int]:
    """The faults x DAG gate: the catalog composition cell runs
    end-to-end on both scalar engines, fingerprint-identical, with the
    outage actually observed (faulted_spans > 0)."""
    from repro.core import get_scenario, make_scheduler, simulate
    from repro.costmodel.maestro import PLATFORMS

    sc = get_scenario("fault_dag_dropout")
    plans, tasks = sc.plans(PLATFORMS["6k_1ws2os"])
    procs = [t.arrival for t in tasks]
    fps, spans = [], 0
    for engine in ("reference", "soa"):
        res = simulate(plans, tasks, 1.0, make_scheduler("terastal"), seed=0,
                       processes=procs, faults=sc.faults, engine=engine)
        fps.append(res.fingerprint())
        spans = res.faulted_spans
    if fps[0] != fps[1]:
        return 2, False, "fault_dag_dropout/terastal", spans
    return 2, True, None, spans


# --------------------------------------------------------------- run ----


def run(seeds=(0, 1, 2)) -> List[dict]:
    from benchmarks._scale import bench_mode

    mode = bench_mode()
    smoke = mode == "smoke"
    if mode != "full":
        seeds = (0,) if smoke else (0, 1)
    faults_grid = ({"throttle4x": GATE_FAULT} if smoke else GRID_FAULTS)
    rows = _grid_rows(seeds, faults_grid)

    sep = _separation(rows)
    n_rs, rs_ok, rs_where = _gate_ref_vs_soa()
    n_bp, bp_ok, bp_where = _gate_batch_parity(smoke)
    n_dg, dg_ok, dg_where, dg_spans = _gate_dag_faults()

    summary = {
        "benchmark": "fault_budgets",
        "mode": mode,
        "grid": {
            "cell": list(GATE_CELL),
            "scheduler": GATE_SCHEDULER,
            "gate_fault": GATE_FAULT,
            "gate_admission": GATE_ADMISSION,
            "faults": list(faults_grid),
            "admissions": list(GRID_ADMISSIONS),
            "duration": DURATION,
            "seeds": list(seeds),
        },
        "rows": rows,
        "separation": {
            "cell": list(GATE_CELL),
            "separation_pts": sep,
            "min_enforced_pts": MIN_SEPARATION_PTS,
        },
        "ref_vs_soa": {"simulations": n_rs, "bit_identical": rs_ok,
                       "first_mismatch": rs_where},
        "batch_parity": {"simulations": n_bp, "bit_identical": bp_ok,
                         "first_mismatch": bp_where},
        "dag_faults": {"simulations": n_dg, "bit_identical": dg_ok,
                       "first_mismatch": dg_where,
                       "faulted_spans": dg_spans},
    }
    with open(JSON_PATH, "w") as f:
        json.dump(summary, f, indent=2, allow_nan=False)
        f.write("\n")
    return rows + [{
        "separation_pts": sep,
        "ref_vs_soa_ok": rs_ok, "ref_vs_soa_n": n_rs,
        "ref_vs_soa_where": rs_where,
        "batch_parity_ok": bp_ok, "batch_parity_n": n_bp,
        "batch_parity_where": bp_where,
        "dag_faults_ok": dg_ok, "dag_faults_n": n_dg,
        "dag_faults_where": dg_where, "dag_faulted_spans": dg_spans,
        "json": JSON_PATH,
    }]


def claims(rows: List[dict]):
    tail = rows[-1]
    grid = rows[:-1]
    sep = tail["separation_pts"]
    acct_ok = all(r["remapped"] <= r["evicted"] for r in grid) and all(
        r["shed"] == 0 for r in grid if r["admission"] == "none"
    )
    return [
        (f"re-tightening + degraded admission beats frozen-nominal by "
         f">= {MIN_SEPARATION_PTS} miss-rate points on the pinned "
         f"long-brownout cell {GATE_CELL[0]}",
         sep is not None and sep >= MIN_SEPARATION_PTS,
         f"separation={sep:.1f} pts" if sep is not None
         else "no separation measured"),
        ("reference vs SoA bit-identical on the gate cell with "
         "re-tightening and degraded admission active",
         bool(tail["ref_vs_soa_ok"]),
         f"{tail['ref_vs_soa_n']} simulations compared"
         + ("" if tail["ref_vs_soa_ok"]
            else f"; first mismatch {tail.get('ref_vs_soa_where')}")),
        ("faults x batch gate lifted: restart-policy fault cells run on "
         "device, fingerprint-identical to SoA",
         bool(tail["batch_parity_ok"]),
         f"{tail['batch_parity_n']} trials compared"
         + ("" if tail["batch_parity_ok"]
            else f"; first mismatch {tail.get('batch_parity_where')}")),
        ("faults x DAG gate lifted: the fault_dag_dropout catalog cell "
         "runs end-to-end, both scalar engines identical, outage observed",
         bool(tail["dag_faults_ok"]) and tail["dag_faulted_spans"] > 0,
         f"{tail['dag_faults_n']} simulations, "
         f"faulted_spans={tail['dag_faulted_spans']}"
         + ("" if tail["dag_faults_ok"]
            else f"; first mismatch {tail.get('dag_faults_where')}")),
        ("fault accounting honest across the grid: remapped <= evicted, "
         "admission-off rows shed nothing",
         acct_ok,
         f"{sum(r['evicted'] for r in grid)} evictions / "
         f"{sum(r['shed'] for r in grid)} shed across the grid"),
    ]


def check_json(path: str = JSON_PATH):
    """Apply the separation and identity-gate claims to an
    already-written BENCH_fault_budgets.json without re-measuring —
    the CI gate step."""
    with open(path) as f:
        summary = json.load(f)
    tail = {
        "separation_pts": summary["separation"]["separation_pts"],
        "ref_vs_soa_ok": summary["ref_vs_soa"]["bit_identical"],
        "ref_vs_soa_n": summary["ref_vs_soa"]["simulations"],
        "ref_vs_soa_where": summary["ref_vs_soa"].get("first_mismatch"),
        "batch_parity_ok": summary["batch_parity"]["bit_identical"],
        "batch_parity_n": summary["batch_parity"]["simulations"],
        "batch_parity_where": summary["batch_parity"].get("first_mismatch"),
        "dag_faults_ok": summary["dag_faults"]["bit_identical"],
        "dag_faults_n": summary["dag_faults"]["simulations"],
        "dag_faults_where": summary["dag_faults"].get("first_mismatch"),
        "dag_faulted_spans": summary["dag_faults"]["faulted_spans"],
    }
    return claims(summary["rows"] + [tail])


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid; unlike run.py --smoke, the separation "
                    "floor and all three identity gates still FAIL the "
                    "process (the CI regression gate)")
    ap.add_argument("--check-json", action="store_true",
                    help="validate the claims against the existing "
                    f"{os.path.basename(JSON_PATH)} instead of re-measuring")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    sys.path.insert(0, _ROOT)  # make the `benchmarks` package importable
    if args.check_json:
        checks = check_json()
    else:
        out = run()
        for r in out:
            print(json.dumps(r))
        checks = claims(out)
    n_ok = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} ({detail})")
        n_ok += bool(ok)
    if n_ok < len(checks):
        sys.exit(1)

"""Campaign throughput: SoA engine vs reference engine, trials/sec.

Runs the pinned fig7-shaped grid (the fig7 cells x fcfs/edf/dream/
terastal x the arrival-burstiness ladder) SERIALLY through ``run_trial``
once per engine, on warmed offline-plan caches, and reports trials/sec
plus the aggregate and per-scheduler speedup of the structure-of-arrays
engine over the retained reference event loop.  Both engines are
bit-identical (pinned here per trial and by tests/test_engine_soa.py),
so the speedup is pure implementation headroom — every campaign figure
gets that many more seeds per unit compute.

Writes ``BENCH_campaign.json`` at the repo root: the repo's first
perf-trajectory point.  CI runs this in --smoke mode and uploads the
JSON as an artifact, so the trajectory accumulates per PR; the
committed file is a full-mode measurement.

Honest scorecard: the issue that introduced the SoA engine targeted a
>= 5x aggregate; the measured aggregate on this grid is ~3.5x (per-cell
up to ~4.7x on bursty terastal rows).  The shortfall is a measurement
about the reference, not headroom left on the table: the reference loop
already costs only ~10us/event, so a 5x aggregate would need ~2us/event
— below what a per-event CPython loop can reach.  The claim below
enforces the conservative floor of what this refactor genuinely
delivers on any machine.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, List

from repro.core.campaign import TrialSpec, _plans_for, run_trial

# fig7's pinned shape: representative AR + multicam cells, conventional
# baselines + Terastal, the arrival-burstiness ladder.
CELLS = (
    ("ar_gaming_heavy", "6k_1ws2os"),
    ("multicam_light", "4k_1ws2os"),
)
SCHEDULERS = ("fcfs", "edf", "dream", "terastal")
ARRIVALS = (
    "periodic",
    "poisson",
    "mmpp(burstiness=2)",
    "mmpp(burstiness=4)",
    "mmpp(burstiness=8)",
)
SEEDS = (0,)

#: aggregate speedup floor enforced by claims() — see module docstring.
MIN_SPEEDUP = 2.0

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_campaign.json")


def _specs(duration: float, schedulers, arrivals) -> List[TrialSpec]:
    return [
        TrialSpec(sc, pn, sched, arrival=arr, seed=seed, duration=duration)
        for sc, pn in CELLS
        for sched in schedulers
        for arr in arrivals
        for seed in SEEDS
    ]


def _metric_key(t) -> tuple:
    # NaN loss (no variant-bearing model completed anything — the honest
    # zero-completion contract) compares unequal to itself; fold it to
    # None so identical trials stay identical, and carry the denominator
    loss = None if math.isnan(t.mean_accuracy_loss) else t.mean_accuracy_loss
    return (t.mean_miss_rate, loss, t.models_counted, t.released,
            t.completed, t.dropped, t.variants_applied, t.shed,
            t.utilization)


def run(duration: float = None) -> List[dict]:
    from benchmarks._scale import bench_duration, bench_mode

    mode = bench_mode()
    duration = bench_duration(duration, smoke=0.3, fast=1.5, full=3.0)
    schedulers = ("fcfs", "terastal") if mode == "smoke" else SCHEDULERS
    arrivals = ("periodic", "mmpp(burstiness=8)") if mode == "smoke" else ARRIVALS
    specs = _specs(duration, schedulers, arrivals)
    for sc, pn in CELLS:  # warm the offline plans out of the timed region
        _plans_for(sc, pn, 0.90, True)

    wall: Dict[str, float] = {}
    sched_wall: Dict[str, Dict[str, float]] = {}
    results: Dict[str, List[tuple]] = {}
    for engine in ("reference", "soa"):
        t0 = time.perf_counter()
        trials = [run_trial(dataclasses.replace(s, engine=engine)) for s in specs]
        wall[engine] = time.perf_counter() - t0
        results[engine] = [_metric_key(t) for t in trials]
        per = sched_wall.setdefault(engine, {})
        for s, t in zip(specs, trials):
            per[s.scheduler] = per.get(s.scheduler, 0.0) + t.wall_s

    identical = results["reference"] == results["soa"]
    speedup = wall["reference"] / wall["soa"]
    rows = [
        {
            "engine": engine,
            "trials": len(specs),
            "wall_s": round(wall[engine], 3),
            "trials_per_s": round(len(specs) / wall[engine], 2),
        }
        for engine in ("reference", "soa")
    ]
    per_sched = {
        name: round(sched_wall["reference"][name] / sched_wall["soa"][name], 2)
        for name in sched_wall["soa"]
    }
    summary = {
        "benchmark": "campaign_throughput",
        "mode": mode,
        "grid": {
            "cells": [list(c) for c in CELLS],
            "schedulers": list(schedulers),
            "arrivals": list(arrivals),
            "seeds": list(SEEDS),
            "duration": duration,
            "execution": "serial",
        },
        "engines": rows,
        "speedup": round(speedup, 2),
        "per_scheduler_speedup": per_sched,
        "bit_identical": identical,
        "target_speedup": 5.0,
        "min_speedup_enforced": MIN_SPEEDUP,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")
    return rows + [{"speedup": summary["speedup"],
                    "per_scheduler_speedup": per_sched,
                    "bit_identical": identical,
                    "json": JSON_PATH}]


def claims(rows: List[dict]):
    tail = rows[-1]
    by_engine = {r["engine"]: r for r in rows[:-1]}
    return [
        ("SoA engine bit-identical to reference across the whole grid",
         bool(tail["bit_identical"]), "per-trial metric tuples compared"),
        (f"SoA engine >= {MIN_SPEEDUP}x trials/sec over the reference engine "
         "(serial, warmed plans)",
         tail["speedup"] >= MIN_SPEEDUP,
         f"{by_engine['reference']['trials_per_s']} -> "
         f"{by_engine['soa']['trials_per_s']} trials/s = {tail['speedup']}x "
         f"(target was 5x; see module docstring)"),
    ]


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid / short horizon (CI artifact mode)")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    sys.path.insert(0, _ROOT)  # make the `benchmarks` package importable
    rows = run()
    for r in rows:
        print(json.dumps(r))
    checks = claims(rows)
    n_ok = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} ({detail})")
        n_ok += bool(ok)
    if n_ok < len(checks) and not args.smoke:
        sys.exit(1)
